# FSL-HDnn build/verify entry points. `make verify` is the tier-1 gate.

CARGO ?= cargo
## nightly invocation for the `simd` feature (std::simd is nightly-only)
CARGO_NIGHTLY ?= $(CARGO) +nightly
PYTHON ?= python3

.PHONY: verify build test bench bench-smoke bench-smoke-scalar bench-smoke-simd chaos doc fmt \
	clippy lint miri artifacts clean

## tier-1 verify: must pass from a clean checkout (artifact-dependent
## tests self-skip with a distinct `SKIPPED` line, see DESIGN.md §Test skips)
verify:
	$(CARGO) build --release && $(CARGO) test -q

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

## run every paper-figure bench (plain binaries, in-tree harness); the
## bench list lives in rust/Cargo.toml's [[bench]] entries only
bench:
	$(CARGO) bench

## bench-harness smoke (what CI runs): tiny budgets, all asserts live,
## refreshes BENCH_hotpath.json at the repo root (including the `serving`
## section from the gateway load generator). Runs both feature settings:
## the scalar leg on the default toolchain, then the simd leg on nightly
## (the lane bit-identity asserts run in both).
bench-smoke: bench-smoke-scalar bench-smoke-simd

bench-smoke-scalar:
	$(CARGO) bench --bench hotpath_micro -- --smoke
	$(CARGO) bench --bench fig05_chsub_sweep -- --smoke
	$(CARGO) bench --bench fig14_precision_sweep -- --smoke
	$(CARGO) bench --bench fig14_precision_sweep -- --smoke --backend ldc
	$(CARGO) bench --bench fig17_early_exit -- --smoke
	$(CARGO) run --release --example load_gen -- --smoke

## the explicit-vector lane of the two packed fast paths (DESIGN.md §SIMD
## datapath); needs a nightly toolchain for `--features simd`
bench-smoke-simd:
	$(CARGO_NIGHTLY) bench --bench hotpath_micro --features simd -- --smoke
	$(CARGO_NIGHTLY) bench --bench fig14_precision_sweep --features simd -- --smoke

## fault-tolerance drills (DESIGN.md §Fault model): the deterministic
## chaos battery (device kill mid-episode -> bit-identical recovery,
## strike-out, cascade loss, wire retries), an env-armed fail-point
## smoke, and the load_gen --chaos recovery-latency row
chaos:
	$(CARGO) test -q --test integration_chaos
	FSL_FAILPOINTS="device.query=latency-ms:1" $(CARGO) run --release --example load_gen -- --smoke
	$(CARGO) run --release --example load_gen -- --chaos

doc:
	$(CARGO) doc --no-deps

fmt:
	$(CARGO) fmt --all

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

## fsl-lint: the repo-invariant static analysis pass (DESIGN.md §Static
## analysis). Walks rust/src, rust/benches, rust/tests and examples/ and
## enforces the six repo rules (NaN-unsafe sorts, raw spawns, panics in
## serving modules, wall-clock reads in kernels, unguarded narrowing
## casts, fail-point/wire-codec registry coverage). Exits non-zero on any
## unsuppressed violation; suppressions need a justified
## `lint:allow(<rule>)` comment. Blocking in CI's lint job.
lint:
	$(CARGO) run --release --bin fsl_lint

## Miri over the unsafe core: runtime::pool's scope/lifetime transmutes
## are the only `unsafe` in the tree, so the interpreter run is scoped to
## the pool + shard-determinism tests to keep wall-clock sane. Needs a
## nightly toolchain with the miri component:
##   rustup +nightly component add miri
miri:
	MIRIFLAGS=-Zmiri-disable-isolation $(CARGO) +nightly miri test -p fsl-hdnn --lib runtime::pool
	MIRIFLAGS=-Zmiri-disable-isolation $(CARGO) +nightly miri test -p fsl-hdnn --lib util::parallel

## AOT compile path: lowers every L2 entrypoint to HLO-text artifacts under
## artifacts/ (manifest.json, *.hlo.txt, fe_weights.bin, goldens/). This is
## the only python step in the repo and it needs jax + numpy:
##   cd python && $(PYTHON) -m compile.aot --out ../artifacts
## Executing the artifacts from rust additionally requires building with
## `--features pjrt` and a vendored xla-rs (see DESIGN.md §PJRT gating);
## without artifacts the native backend runs on synthetic weights and every
## artifact-dependent test reports `SKIPPED`.
artifacts:
	@if $(PYTHON) -c "import jax" 2>/dev/null; then \
	  cd python && $(PYTHON) -m compile.aot --out ../artifacts; \
	else \
	  echo "make artifacts: python AOT step unavailable (jax not importable)."; \
	  echo "Install jax + numpy, then re-run: cd python && $(PYTHON) -m compile.aot --out ../artifacts"; \
	  echo "See DESIGN.md for what the artifacts contain and who consumes them."; \
	  exit 1; \
	fi

clean:
	$(CARGO) clean
	rm -rf artifacts
