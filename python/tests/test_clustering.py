"""Weight-clustering (Fig. 4a) unit + property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import clustering

SET = settings(max_examples=15, deadline=None)


@SET
@given(n=st.sampled_from([2, 4, 8, 16]), seed=st.integers(0, 2**31 - 1),
       size=st.integers(20, 300))
def test_kmeans_labels_are_nearest_centroid(n, seed, size):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=size).astype(np.float32)
    cents, labels = clustering.kmeans_1d(v, n)
    d = np.abs(v[:, None] - cents[None, :])
    np.testing.assert_array_equal(labels, d.argmin(axis=1))


def test_kmeans_exact_when_fewer_values_than_centroids():
    v = np.array([3.0, 1.0, 2.0])
    cents, labels = clustering.kmeans_1d(v, 8)
    np.testing.assert_allclose(cents[labels], v)


def test_kmeans_error_decreases_with_n():
    rng = np.random.default_rng(0)
    v = rng.normal(size=500)
    errs = []
    for n in (2, 4, 8, 16):
        cents, labels = clustering.kmeans_1d(v, n)
        errs.append(np.mean((v - cents[labels]) ** 2))
    assert errs == sorted(errs, reverse=True)


def test_cluster_layer_roundtrip_shapes():
    rng = np.random.default_rng(1)
    cout, k, cin, ch_sub, n = 6, 3, 16, 8, 4
    w = rng.normal(size=(cout, k, k, cin)).astype(np.float32)
    idx, cb = clustering.cluster_layer(w, ch_sub, n)
    assert idx.shape == (cout, k * k * cin)
    assert cb.shape == (cout, cin // ch_sub, n)
    assert idx.min() >= 0 and idx.max() < n
    dense = clustering.reconstruct(idx, cb, cin, k)
    assert dense.shape == w.shape
    # clustering with many centroids should track the original weights
    idx2, cb2 = clustering.cluster_layer(w, ch_sub, 64)
    dense2 = clustering.reconstruct(idx2, cb2, cin, k)
    assert np.mean((dense2 - w) ** 2) < np.mean((dense - w) ** 2) + 1e-9


def test_cluster_error_shrinks_with_smaller_groups():
    """Smaller Ch_sub = more codebooks = lower FE error (Fig. 5 trend)."""
    rng = np.random.default_rng(2)
    w = rng.normal(size=(4, 3, 3, 32)).astype(np.float32)
    errs = []
    for ch_sub in (4, 8, 16, 32):
        idx, cb = clustering.cluster_layer(w, ch_sub, 8)
        dense = clustering.reconstruct(idx, cb, 32, 3)
        errs.append(float(np.mean((dense - w) ** 2)))
    assert errs[0] <= errs[-1] + 1e-9


def test_compression_ratio_trend():
    """Compression improves with Ch_sub and saturates (~2x, Fig. 5)."""
    rs = [clustering.compression_ratio(512, 3, c, 16) for c in (8, 16, 32, 64, 128, 256)]
    assert all(b >= a - 1e-9 for a, b in zip(rs, rs[1:]))
    assert 1.5 < rs[-1] <= 2.1


def test_op_reduction_ratio_trend():
    rs = [clustering.op_reduction_ratio(3, 16, c, 512) for c in (8, 16, 32, 64, 128, 256)]
    assert all(b >= a - 1e-9 for a, b in zip(rs, rs[1:]))
    assert 1.8 < rs[-1] <= 2.0  # -> 2*K^2/(K^2) = 2 asymptote


def test_clustered_weights_have_at_most_n_uniques_per_group():
    rng = np.random.default_rng(3)
    w = rng.normal(size=(2, 3, 3, 8)).astype(np.float32)
    idx, cb = clustering.cluster_layer(w, 4, 4)
    dense = clustering.reconstruct(idx, cb, 8, 3).reshape(2, -1)
    ci = np.arange(dense.shape[1]) % 8
    for co in range(2):
        for g in range(2):
            vals = dense[co][(ci // 4) == g]
            assert len(np.unique(vals)) <= 4
