"""Pallas kernel vs pure-jnp/numpy oracle — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/seeds; sizes stay small because the kernels
run interpret=True on CPU.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import clustered_conv as cc
from compile.kernels import crp_encoder, hdc_ops, lfsr, ref

SET = settings(max_examples=12, deadline=None)


# ---------------- cRP encoder ----------------

@SET
@given(
    f16=st.integers(1, 6),
    d16=st.integers(1, 8),
    b=st.integers(1, 5),
    seed=st.integers(0, 2**32 - 1),
)
def test_crp_matches_dense_oracle(f16, d16, b, seed):
    f, d = 16 * f16, 16 * d16
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(b, f)).astype(np.float32)
    states = lfsr.all_row_states(seed, d).astype(np.int32)
    got = np.asarray(crp_encoder.crp_encode(jnp.asarray(x), jnp.asarray(states), d))
    want = ref.crp_encode_ref(x, seed, d)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_crp_linearity():
    """RP encoding is linear: h(a*x + y) = a*h(x) + h(y)."""
    rng = np.random.default_rng(3)
    f, d = 32, 64
    states = jnp.asarray(lfsr.all_row_states(11, d).astype(np.int32))
    x = rng.normal(size=(1, f)).astype(np.float32)
    y = rng.normal(size=(1, f)).astype(np.float32)
    hx = np.asarray(crp_encoder.crp_encode(jnp.asarray(x), states, d))
    hy = np.asarray(crp_encoder.crp_encode(jnp.asarray(y), states, d))
    hz = np.asarray(crp_encoder.crp_encode(jnp.asarray(2.5 * x + y), states, d))
    np.testing.assert_allclose(hz, 2.5 * hx + hy, rtol=1e-4, atol=1e-4)


def test_crp_batch_rows_independent():
    rng = np.random.default_rng(5)
    f, d = 32, 96
    states = jnp.asarray(lfsr.all_row_states(7, d).astype(np.int32))
    x = rng.normal(size=(3, f)).astype(np.float32)
    full = np.asarray(crp_encoder.crp_encode(jnp.asarray(x), states, d))
    for i in range(3):
        row = np.asarray(crp_encoder.crp_encode(jnp.asarray(x[i : i + 1]), states, d))
        np.testing.assert_allclose(full[i : i + 1], row, rtol=1e-5, atol=1e-5)


def test_crp_zero_padding_is_noop_on_prefix():
    """Padding features with zeros must not change the projection — the
    model relies on this to share one encoder across branch dims."""
    rng = np.random.default_rng(6)
    d = 64
    states = jnp.asarray(lfsr.all_row_states(13, d).astype(np.int32))
    x = rng.normal(size=(2, 32)).astype(np.float32)
    xp = np.concatenate([x, np.zeros((2, 32), np.float32)], axis=1)
    # padded encoding uses MORE column blocks, so it is a *different*
    # projection matrix over the prefix? No: blocks are per (row, col),
    # and cols 0..31 use the same LFSR sequence positions j=0,1 in both
    # cases — contributions from zero cols vanish, prefix cols identical.
    h32 = np.asarray(crp_encoder.crp_encode(jnp.asarray(x), states, d))
    h64 = np.asarray(crp_encoder.crp_encode(jnp.asarray(xp), states, d))
    np.testing.assert_allclose(h32, h64, rtol=1e-5, atol=1e-5)


def test_crp_dtype_promotion():
    """Integer features are accepted and cast to f32."""
    d = 32
    states = jnp.asarray(lfsr.all_row_states(1, d).astype(np.int32))
    x = np.arange(32, dtype=np.int32)[None, :]
    got = np.asarray(crp_encoder.crp_encode(jnp.asarray(x), states, d))
    want = ref.crp_encode_ref(x.astype(np.float32), 1, d)
    np.testing.assert_allclose(got, want, rtol=1e-4)


# ---------------- clustered conv ----------------

@SET
@given(
    cin_g=st.sampled_from([(4, 2), (8, 4), (8, 8), (6, 3)]),
    cout=st.integers(1, 6),
    n=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_clustered_conv_matches_oracle(cin_g, cout, n, seed):
    cin, ch_sub = cin_g
    k, hw = 3, 8
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(hw, hw, cin)).astype(np.float32)
    idx = rng.integers(0, n, size=(cout, k * k * cin))
    g = cin // ch_sub
    cb = rng.normal(size=(cout, g, n)).astype(np.float32)
    patches = np.asarray(cc.im2col(jnp.asarray(x), k))
    onehot = cc.build_onehot(idx, ch_sub, cin, n)
    got = np.asarray(cc.clustered_conv(
        jnp.asarray(patches), jnp.asarray(onehot),
        jnp.asarray(cb.reshape(cout, g * n)), pixel_tile=16))
    want = ref.clustered_conv_ref(patches, idx, cb, ch_sub, cin)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_clustered_conv_equals_dense_reconstruction():
    """The clustered two-phase computation == dense conv with the
    reconstructed weights (Fig. 4b's claim of exactness)."""
    rng = np.random.default_rng(1)
    k, cin, cout, ch_sub, n, hw = 3, 8, 5, 4, 4, 8
    x = rng.normal(size=(hw, hw, cin)).astype(np.float32)
    idx = rng.integers(0, n, size=(cout, k * k * cin))
    cb = rng.normal(size=(cout, cin // ch_sub, n)).astype(np.float32)
    patches = np.asarray(cc.im2col(jnp.asarray(x), k))
    w = ref.reconstruct_weights(idx, cb, ch_sub, cin)
    dense = patches @ w.T
    clustered = ref.clustered_conv_ref(patches, idx, cb, ch_sub, cin)
    np.testing.assert_allclose(clustered, dense, rtol=1e-4, atol=1e-4)


def test_im2col_layout():
    """k = (ky*K + kx)*Cin + ci layout, zero padding at borders."""
    x = np.arange(2 * 2 * 1, dtype=np.float32).reshape(2, 2, 1)
    p = np.asarray(cc.im2col(jnp.asarray(x), 3))
    assert p.shape == (4, 9)
    # center tap (ky=1,kx=1) of pixel 0 is x[0,0]
    assert p[0, 4] == x[0, 0, 0]
    # top-left tap of pixel 0 falls in padding
    assert p[0, 0] == 0.0


def test_build_onehot_routes_every_weight_once():
    rng = np.random.default_rng(2)
    cin, ch_sub, n, k, cout = 8, 4, 4, 3, 3
    idx = rng.integers(0, n, size=(cout, k * k * cin))
    oh = cc.build_onehot(idx, ch_sub, cin, n)
    assert oh.shape == (cout, k * k * cin, (cin // ch_sub) * n)
    np.testing.assert_array_equal(oh.sum(axis=2), 1.0)


# ---------------- HDC ops ----------------

@SET
@given(
    b=st.integers(1, 4),
    c=st.integers(1, 6),
    d16=st.integers(1, 6),
    seed=st.integers(0, 2**31 - 1),
)
def test_l1_distance_matches_oracle(b, c, d16, seed):
    d = 16 * d16
    rng = np.random.default_rng(seed)
    q = rng.normal(size=(b, d)).astype(np.float32)
    cls = rng.normal(size=(c, d)).astype(np.float32)
    got = np.asarray(hdc_ops.l1_distance(jnp.asarray(q), jnp.asarray(cls), seg=16))
    np.testing.assert_allclose(got, ref.l1_distance_ref(q, cls), rtol=1e-4, atol=1e-4)


def test_l1_distance_zero_for_identical():
    rng = np.random.default_rng(4)
    q = rng.normal(size=(2, 64)).astype(np.float32)
    d = np.asarray(hdc_ops.l1_distance(jnp.asarray(q), jnp.asarray(q), seg=16))
    assert abs(d[0, 0]) < 1e-5 and abs(d[1, 1]) < 1e-5
    assert d[0, 1] > 0


@SET
@given(k=st.integers(1, 8), d16=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_aggregate_matches_oracle(k, d16, seed):
    d = 16 * d16
    rng = np.random.default_rng(seed)
    hvs = rng.normal(size=(k, d)).astype(np.float32)
    got = np.asarray(hdc_ops.aggregate(jnp.asarray(hvs), seg=16))
    np.testing.assert_allclose(got, ref.aggregate_ref(hvs), rtol=1e-5, atol=1e-5)


def test_aggregate_single_is_identity():
    rng = np.random.default_rng(8)
    hv = rng.normal(size=(1, 32)).astype(np.float32)
    got = np.asarray(hdc_ops.aggregate(jnp.asarray(hv), seg=16))
    np.testing.assert_allclose(got, hv[0], rtol=1e-6)
