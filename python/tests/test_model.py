"""L2 model tests: FE shapes/branches, pipeline consistency, AOT manifest."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import resnet
from compile.model import FslHdnnModel

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

# tiny config so interpret-mode pallas stays fast
TINY = resnet.FeConfig(image_size=16, widths=(8, 16, 32, 32), seed=1)


@pytest.fixture(scope="module")
def model():
    return FslHdnnModel(TINY, d=128)


def test_fe_forward_shape(model):
    x = jnp.zeros((2, 16, 16, 3))
    f = model.fe_forward(x)
    assert f.shape == (2, 4, 32)


def test_branch_padding_is_zero(model):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1, 16, 16, 3)).astype(np.float32))
    f = np.asarray(model.fe_forward(x))
    # branch 0 has width 8 -> features 8..32 are padding
    assert (f[0, 0, 8:] == 0).all()
    assert (f[0, 1, 16:] == 0).all()
    assert np.abs(f[0, 0, :8]).sum() > 0


def test_fe_features_finite_and_scaled(model):
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 16, 16, 3)).astype(np.float32))
    f = np.asarray(model.fe_forward(x))
    assert np.isfinite(f).all()
    rms = np.sqrt((f[:, -1, :] ** 2).mean())
    assert 1e-3 < rms < 1e3, "RMS calibration failed"


def test_fe_pallas_stem_matches_lax(model):
    """Routing the stem through the L1 kernel must not change the math."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(1, 16, 16, 3)).astype(np.float32))
    with_pallas = np.asarray(model.fe_forward(x))
    model2 = FslHdnnModel(TINY, d=128, use_pallas_stem=False)
    without = np.asarray(model2.fe_forward(x))
    np.testing.assert_allclose(with_pallas, without, rtol=5e-4, atol=5e-4)


def test_fsl_infer_equals_staged_pipeline(model):
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(1, 16, 16, 3)).astype(np.float32))
    classes = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32))
    fused = np.asarray(model.fsl_infer(x, classes))
    feats = model.fe_forward(x)[:, -1, :]
    staged = np.asarray(model.hdc_infer(model.encode(feats), classes))
    np.testing.assert_allclose(fused, staged, rtol=1e-4, atol=1e-4)


def test_hdc_train_then_infer_recovers_class(model):
    """Aggregated class HVs classify their own shots (sanity of eq. 4+5)."""
    rng = np.random.default_rng(4)
    protos = rng.normal(size=(3, 32)).astype(np.float32) * 4.0
    shots = protos[:, None, :] + rng.normal(size=(3, 5, 32)).astype(np.float32) * 0.1
    classes = []
    for c in range(3):
        hv = model.encode(jnp.asarray(shots[c]))
        classes.append(np.asarray(model.hdc_train(hv)) / 5.0)
    q = model.encode(jnp.asarray(protos))
    dist = np.asarray(model.hdc_infer(q, jnp.asarray(np.stack(classes))))
    assert (dist.argmin(axis=1) == np.arange(3)).all()


def test_weight_export_roundtrip(model):
    manifest, blob = model.export_weights()
    total = sum(int(np.prod(l["shape"])) for l in manifest["layers"])
    assert len(blob) == 4 * total
    first = manifest["layers"][0]
    w = np.frombuffer(blob[: 4 * int(np.prod(first["shape"]))], dtype="<f4")
    np.testing.assert_allclose(
        w.reshape(first["shape"]), model.params[first["name"]], rtol=1e-6)


def test_cluster_meta_consistency(model):
    for name, (idx, cb) in model.cluster_meta.items():
        w = model.params[name]
        cout, k, _, cin = w.shape
        assert idx.shape == (cout, k * k * cin)
        assert idx.max() < cb.shape[2]


# ---------------- artifacts (require `make artifacts`) ----------------

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="run `make artifacts` first")


@needs_artifacts
def test_manifest_entries_exist():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert len(man["entries"]) >= 8
    for e in man["entries"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule"), e["file"]


@needs_artifacts
def test_manifest_config_matches_goldens():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    cfg = man["config"]
    with open(os.path.join(ART, "goldens", "goldens.json")) as f:
        g = json.load(f)
    assert g["master_seed"] == cfg["master_seed"]
    d = cfg["d"]
    assert g["shapes"]["hv"] == [2, d]
    hv = np.fromfile(os.path.join(ART, "goldens", "hv.bin"), dtype="<f4")
    assert hv.size == 2 * d and np.isfinite(hv).all()


@needs_artifacts
def test_golden_distances_consistent():
    with open(os.path.join(ART, "goldens", "goldens.json")) as f:
        g = json.load(f)
    hv = np.fromfile(os.path.join(ART, "goldens", "hv.bin"), dtype="<f4").reshape(g["shapes"]["hv"])
    classes = np.fromfile(os.path.join(ART, "goldens", "classes.bin"), dtype="<f4").reshape(g["shapes"]["classes"])
    dist = np.fromfile(os.path.join(ART, "goldens", "dist.bin"), dtype="<f4").reshape(g["shapes"]["dist"])
    want = np.abs(hv[:, None, :] - classes[None, :, :]).sum(-1)
    np.testing.assert_allclose(dist, want, rtol=1e-4)
