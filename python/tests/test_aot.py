"""AOT pipeline tests — especially the HLO-text pitfalls that produce
artifacts which *run but compute garbage* on xla_extension 0.5.1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text


def lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def test_no_elided_constants():
    """REGRESSION: the default HLO printer elides big constants as `{...}`
    and the 0.5.1 text parser silently materializes them as ZEROS. Every
    artifact bakes FE weights/seed tables in as constants, so elision ==
    all-zero features at runtime. to_hlo_text must print them in full."""
    big = jnp.asarray(np.arange(4096, dtype=np.float32))

    def fn(x):
        return (x + big,)

    text = to_hlo_text(lower(fn, jax.ShapeDtypeStruct((4096,), jnp.float32)))
    assert "{...}" not in text and "{ ... }" not in text, "large constants were elided"
    # spot-check an actual payload value made it into the text
    assert "4095" in text


def test_no_modern_metadata_attributes():
    """The 0.5.1 parser rejects source_end_line/source_end_column metadata
    that modern XLA prints by default."""
    def fn(x):
        return (x * 2.0,)

    text = to_hlo_text(lower(fn, jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert "source_end_line" not in text
    assert "source_end_column" not in text


def test_output_is_tuple_rooted():
    """aot lowers with return_tuple=True; the rust loader unwraps tuples."""
    def fn(x):
        return (x,)

    text = to_hlo_text(lower(fn, jax.ShapeDtypeStruct((4,), jnp.float32)))
    assert text.startswith("HloModule")
    # the ENTRY root should produce a tuple type
    entry = [l for l in text.splitlines() if "ROOT" in l]
    assert entry, "no ROOT instruction"
    assert any("(" in l and ")" in l for l in entry)


def test_pallas_kernel_lowers_to_plain_hlo():
    """interpret=True pallas must lower to plain HLO ops (no custom-call
    the CPU PJRT client cannot run)."""
    from compile.kernels import hdc_ops

    def fn(q, c):
        return (hdc_ops.l1_distance(q, c),)

    text = to_hlo_text(lower(
        fn,
        jax.ShapeDtypeStruct((1, 64), jnp.float32),
        jax.ShapeDtypeStruct((4, 64), jnp.float32),
    ))
    assert "custom-call" not in text.lower(), "pallas left a custom-call in the HLO"


def test_build_artifacts_smoke(tmp_path):
    """A miniature end-to-end artifact build: emits parseable modules, a
    consistent manifest, weights and goldens."""
    import json
    import os

    from compile.aot import build_artifacts

    out = tmp_path / "artifacts"
    build_artifacts(str(out), d=128, classes_max=4, shots=2, image_size=8,
                    widths=(4, 8, 8, 16), seed=3)
    man = json.loads((out / "manifest.json").read_text())
    assert len(man["entries"]) >= 8
    for e in man["entries"]:
        text = (out / e["file"]).read_text()
        assert text.startswith("HloModule")
        assert "{...}" not in text
    cfg = man["config"]
    assert cfg["d"] == 128 and cfg["feature_dim"] == 16
    # weights blob length matches the manifest shapes
    total = sum(int(np.prod(l["shape"])) for l in man["weights"]["layers"])
    assert os.path.getsize(out / "fe_weights.bin") == 4 * total
    # goldens are self-consistent
    g = json.loads((out / "goldens" / "goldens.json").read_text())
    hv = np.fromfile(out / "goldens" / "hv.bin", dtype="<f4")
    assert hv.size == int(np.prod(g["shapes"]["hv"]))
    assert np.isfinite(hv).all()
    dist = np.fromfile(out / "goldens" / "dist.bin", dtype="<f4").reshape(g["shapes"]["dist"])
    classes = np.fromfile(out / "goldens" / "classes.bin", dtype="<f4").reshape(g["shapes"]["classes"])
    want = np.abs(hv.reshape(g["shapes"]["hv"])[:, None, :] - classes[None]).sum(-1)
    np.testing.assert_allclose(dist, want, rtol=1e-4)
