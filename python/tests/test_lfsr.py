"""LFSR unit tests — the python half of the python/rust bit-exactness contract."""

import numpy as np
import pytest

from compile.kernels import lfsr


def test_step_is_16bit():
    s = 0xACE1
    for _ in range(1000):
        s = lfsr.lfsr16_step(s)
        assert 0 <= s <= 0xFFFF


def test_maximal_period():
    """Taps (16,15,13,4) must give the full 2^16-1 cycle."""
    s0 = 1
    s = lfsr.lfsr16_step(s0)
    n = 1
    while s != s0:
        s = lfsr.lfsr16_step(s)
        n += 1
        assert n <= 65535, "period exceeded 2^16-1: not maximal"
    assert n == 65535


def test_zero_is_lockup():
    assert lfsr.lfsr16_step(0) == 0


def test_step16_equals_16_steps():
    s = 0xBEEF
    expect = s
    for _ in range(16):
        expect = lfsr.lfsr16_step(expect)
    assert lfsr.lfsr16_step16(s) == expect


def test_row_states_deterministic_and_nonzero():
    a = lfsr.row_block_states(123, 5)
    b = lfsr.row_block_states(123, 5)
    assert (a == b).all()
    assert (a != 0).all()
    c = lfsr.row_block_states(124, 5)
    assert (a != c).any()


def test_row_states_differ_across_rows():
    s0 = lfsr.row_block_states(9, 0)
    s1 = lfsr.row_block_states(9, 1)
    assert (s0 != s1).any()


def test_block_signs_pm_one():
    states = lfsr.row_block_states(77, 3)
    signs = lfsr.block_signs(states)
    assert signs.shape == (16, 16)
    assert set(np.unique(signs)) <= {-1, 1}


def test_block_signs_bit_mapping():
    states = np.array([0b101] + [0] * 15, dtype=np.uint16)
    signs = lfsr.block_signs(states)
    assert signs[0, 0] == 1 and signs[0, 1] == -1 and signs[0, 2] == 1
    assert (signs[1:] == -1).all()


def test_base_matrix_shape_and_balance():
    m = lfsr.base_matrix(42, 64, 32)
    assert m.shape == (64, 32)
    assert set(np.unique(m)) <= {-1, 1}
    # pseudo-random ±1 entries should be roughly balanced
    assert abs(m.mean()) < 0.15


def test_base_matrix_rows_decorrelated():
    m = lfsr.base_matrix(42, 64, 64).astype(np.float64)
    gram = (m @ m.T) / m.shape[1]
    off = gram - np.eye(64)
    assert np.abs(off).mean() < 0.2


def test_golden_vectors_self_consistent():
    g = lfsr.golden_vectors()
    assert len(g["step_seq_from_ace1"]) == 64
    s = 0xACE1
    for v in g["step_seq_from_ace1"]:
        s = lfsr.lfsr16_step(s)
        assert s == v
    assert g["row0_states"] == [int(v) for v in lfsr.row_block_states(g["master_seed"], 0)]


def test_splitmix_known_mixing():
    # splitmix64 of distinct inputs should differ and stay in u64 range
    vals = {lfsr.splitmix64(i) for i in range(64)}
    assert len(vals) == 64
    assert all(0 <= v < 2**64 for v in vals)
