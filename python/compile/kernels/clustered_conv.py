"""L1 Pallas kernel: weight-clustered convolution — Fig. 4(b) / Fig. 8.

The chip's PE performs the clustered conv in two overlapped phases:

  phase 1 (accumulate): input activations sharing a weight *index* are
      summed into an N-entry register file (one partial sum per centroid,
      per Ch_sub channel group);
  phase 2 (MAC): the N partial sums are multiplied by the N codebook
      centroids and reduced — turning 2*K^2-1 ops into K^2 + N - 1.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the index->bin scatter is
re-expressed as a contraction with a static one-hot tensor so *both* phases
are MXU matmuls in sequence:

      bins(P, G*N) = patches(P, KKC) @ onehot(KKC, G*N)     # phase 1
      out (P,)     = bins @ codebook_flat(G*N,)             # phase 2

``onehot[k, g*N+n] = [group(k) == g && idx(k) == n]`` is built on the host
once per layer (it is static data derived from the clustered weights, the
analogue of the chip's 36 KB index memory). The codebook for one output
channel stays resident in VMEM while output-pixel tiles stream through —
the codebook-stationary dataflow of Fig. 7.

Runs interpret=True on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def build_onehot(idx: np.ndarray, ch_sub: int, cin: int, n: int) -> np.ndarray:
    """Static (Cout, KKC, G*N) one-hot routing tensor from weight indices.

    Layout of flat patch position k: ((ky*K + kx)*Cin + ci); the channel
    group is ci // ch_sub, matching ``ref.clustered_conv_ref``.
    """
    cout, kkc = idx.shape
    g = (cin + ch_sub - 1) // ch_sub
    ci = np.arange(kkc) % cin
    group = ci // ch_sub
    onehot = np.zeros((cout, kkc, g * n), dtype=np.float32)
    for co in range(cout):
        onehot[co, np.arange(kkc), group * n + idx[co]] = 1.0
    return onehot


def _cc_kernel(patches_ref, onehot_ref, cb_ref, o_ref):
    """One (pixel-tile, output-channel) cell of the clustered conv.

    patches_ref: (Pt, KKC) f32
    onehot_ref:  (1, KKC, GN) f32 — this channel's routing tensor
    cb_ref:      (1, GN) f32      — this channel's flattened codebook
    o_ref:       (Pt, 1) f32
    """
    patches = patches_ref[...]
    onehot = onehot_ref[0]
    bins = jnp.dot(patches, onehot)            # phase 1: (Pt, GN)
    out = jnp.dot(bins, cb_ref[0])             # phase 2: (Pt,)
    o_ref[...] = out[:, None]


@functools.partial(jax.jit, static_argnames=("pixel_tile",))
def clustered_conv(
    patches: jnp.ndarray,   # (P, KKC)
    onehot: jnp.ndarray,    # (Cout, KKC, GN)
    codebook: jnp.ndarray,  # (Cout, GN)
    pixel_tile: int = 64,
) -> jnp.ndarray:
    """Clustered convolution over im2col patches -> (P, Cout)."""
    p, kkc = patches.shape
    cout, kkc2, gn = onehot.shape
    assert kkc == kkc2 and codebook.shape == (cout, gn)
    assert p % pixel_tile == 0, "pad P to a multiple of pixel_tile"
    return pl.pallas_call(
        _cc_kernel,
        grid=(p // pixel_tile, cout),
        in_specs=[
            pl.BlockSpec((pixel_tile, kkc), lambda i, co: (i, 0)),
            pl.BlockSpec((1, kkc, gn), lambda i, co: (co, 0, 0)),
            pl.BlockSpec((1, gn), lambda i, co: (co, 0)),
        ],
        out_specs=pl.BlockSpec((pixel_tile, 1), lambda i, co: (i, co)),
        out_shape=jax.ShapeDtypeStruct((p, cout), jnp.float32),
        interpret=True,
    )(patches.astype(jnp.float32), onehot.astype(jnp.float32),
      codebook.astype(jnp.float32))


def im2col(x: jnp.ndarray, k: int, stride: int = 1, pad: int = 1) -> jnp.ndarray:
    """(H, W, Cin) -> (P, K*K*Cin) patches, layout (ky*K+kx)*Cin + ci."""
    h, w, cin = x.shape
    xp = jnp.pad(x, ((pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - k) // stride + 1
    wo = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            sl = xp[ky : ky + ho * stride : stride, kx : kx + wo * stride : stride, :]
            cols.append(sl.reshape(ho * wo, cin))
    return jnp.concatenate(cols, axis=1)
