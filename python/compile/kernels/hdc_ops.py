"""L1 Pallas kernels: HDC distance search (eq. 5) and class aggregation (eq. 4).

Distance search mirrors the chip's inference module (Fig. 9): per cycle a
256-bit HV segment is fetched from class memory, element-wise subtracted
from the query segment, absolute differences accumulated. Here each grid
step owns one D-segment and accumulates |q - C| into the (B, C) distance
table (the revisited output block is the accumulator — the distance-table
register of Fig. 11).

Aggregation mirrors the training module's HV updater: 16 parallel adders
summing k shot-HVs segment by segment.

Runs interpret=True on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_seg(d: int, seg: int) -> int:
    """Largest multiple-of-16 divisor of d that is <= seg."""
    s = min(seg, d)
    s -= s % 16
    while s > 16 and d % s != 0:
        s -= 16
    assert s >= 16 and d % s == 0, f"d={d} must be a multiple of 16"
    return s


def _l1_kernel(q_ref, c_ref, o_ref):
    """Accumulate one D-segment of the L1 distance table.

    q_ref: (B, TD), c_ref: (C, TD), o_ref: (B, C) — same block every step.
    """
    seg = jnp.abs(q_ref[...][:, None, :] - c_ref[...][None, :, :]).sum(-1)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += seg


@functools.partial(jax.jit, static_argnames=("seg",))
def l1_distance(q: jnp.ndarray, classes: jnp.ndarray, seg: int = 256) -> jnp.ndarray:
    """Manhattan distance table (B, D) x (C, D) -> (B, C)."""
    b, d = q.shape
    c, d2 = classes.shape
    assert d == d2
    seg = _pick_seg(d, seg)
    return pl.pallas_call(
        _l1_kernel,
        grid=(d // seg,),
        in_specs=[
            pl.BlockSpec((b, seg), lambda i: (0, i)),
            pl.BlockSpec((c, seg), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((b, c), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c), jnp.float32),
        interpret=True,
    )(q.astype(jnp.float32), classes.astype(jnp.float32))


def _agg_kernel(h_ref, o_ref):
    """Sum k shot-HVs over one D-segment: h_ref (k, TD) -> o_ref (1, TD)."""
    o_ref[...] = h_ref[...].sum(axis=0, keepdims=True)


@functools.partial(jax.jit, static_argnames=("seg",))
def aggregate(hvs: jnp.ndarray, seg: int = 256) -> jnp.ndarray:
    """Bundle k shot-HVs into one class HV: (k, D) -> (D,)."""
    k, d = hvs.shape
    seg = _pick_seg(d, seg)
    out = pl.pallas_call(
        _agg_kernel,
        grid=(d // seg,),
        in_specs=[pl.BlockSpec((k, seg), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, seg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, d), jnp.float32),
        interpret=True,
    )(hvs.astype(jnp.float32))
    return out[0]
