"""L1 Pallas kernel: cyclic Random Projection (cRP) encoding — Fig. 6(b).

The conventional RP encoder stores a D x F ±1 base matrix (256 KB at
F=512, D=4096). The chip's cRP encoder instead *streams* the matrix out of
16 LFSRs, 16x16 elements per cycle. This kernel is the TPU-shaped
re-expression of that datapath (DESIGN.md §Hardware-Adaptation):

  * grid program ``i`` owns a 16-row band of the output HV — the analogue
    of the chip's adder-tree bank;
  * the 16 LFSR states for the band live in registers/VMEM (shape (16,)),
    initialized from an O(D) seed table that a splitmix64 chain derives from
    one u64 master seed (the full base matrix NEVER exists in HBM);
  * a ``fori_loop`` over the F/16 column blocks advances each LFSR 16 steps
    (one fresh word), expands states into a 16x16 ±1 block in VMEM, and
    contracts it with the feature segment — the MXU-friendly version of the
    chip's 16 parallel 16-input adder trees.

VMEM footprint per program: (B,F) features + (16,16) block + (B,16)
accumulator — ~B*F*4 bytes, KBs at production sizes (F ≤ 1024).
Runs interpret=True on CPU (real-TPU lowering would emit Mosaic).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lfsr_step16(s: jnp.ndarray) -> jnp.ndarray:
    """Advance a vector of 16-bit Fibonacci LFSRs (taps 16,15,13,4) 16 steps.

    Operates on int32 lanes; must match ``lfsr.lfsr16_step16`` bit-exactly.
    """
    def body(_, s):
        fb = ((s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3)) & 1
        return ((s << 1) | fb) & 0xFFFF

    return jax.lax.fori_loop(0, 16, body, s)


def _block_signs(states: jnp.ndarray) -> jnp.ndarray:
    """(16,) int32 LFSR states -> (16,16) ±1 f32 block (bit c of state r)."""
    bits = (states[:, None] >> jnp.arange(16, dtype=jnp.int32)[None, :]) & 1
    return (2 * bits - 1).astype(jnp.float32)


def _crp_kernel(states_ref, x_ref, o_ref, *, n_col_blocks: int):
    """One 16-row band of h = B @ x for the whole batch.

    states_ref: (1, 16) int32 — initial LFSR states for this row band
    x_ref:      (B, F)  f32   — full feature block (F small, stays in VMEM)
    o_ref:      (B, 16) f32   — output band
    """
    x = x_ref[...]
    b = x.shape[0]
    init = (states_ref[0, :], jnp.zeros((b, 16), jnp.float32))

    def body(j, carry):
        states, acc = carry
        states = _lfsr_step16(states)
        signs = _block_signs(states)  # (16 rows, 16 cols)
        seg = jax.lax.dynamic_slice_in_dim(x, j * 16, 16, axis=1)  # (B, 16)
        # acc[b, r] += sum_c signs[r, c] * seg[b, c]
        acc = acc + jnp.dot(seg, signs.T)
        return states, acc

    _, acc = jax.lax.fori_loop(0, n_col_blocks, body, init)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("d",))
def crp_encode(x: jnp.ndarray, row_states: jnp.ndarray, d: int) -> jnp.ndarray:
    """Encode features (B, F) into hypervectors (B, D).

    ``row_states`` is the (D/16, 16) int32 seed table from
    ``lfsr.all_row_states`` — O(D) bytes, the only stored randomness.
    """
    b, f = x.shape
    assert f % 16 == 0 and d % 16 == 0
    assert row_states.shape == (d // 16, 16)
    kernel = functools.partial(_crp_kernel, n_col_blocks=f // 16)
    return pl.pallas_call(
        kernel,
        grid=(d // 16,),
        in_specs=[
            pl.BlockSpec((1, 16), lambda i: (i, 0)),
            pl.BlockSpec((b, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, 16), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(row_states.astype(jnp.int32), x.astype(jnp.float32))
