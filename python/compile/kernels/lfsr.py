"""16-bit Fibonacci LFSR — the cRP encoder's pseudo-random source.

This module is the *contract* between the python (artifact-time) and rust
(request-time) cyclic Random Projection (cRP) encoders: both must generate
bit-identical ±1 blocks from the same master seed. The paper (Section IV-B2)
uses 16 LFSRs, each emitting a 16-bit word per cycle, so one "cyclic block"
is a 16x16 ±1 matrix (256 bits).

Polynomial: x^16 + x^15 + x^13 + x^4 + 1 (taps 16,15,13,4 — maximal length,
period 2^16-1; Xilinx XAPP052 table). Fibonacci form, left shift:

    fb = bit15 ^ bit14 ^ bit12 ^ bit3
    s' = ((s << 1) | fb) & 0xFFFF

Seeding uses splitmix64 so that a single u64 master seed deterministically
derives every LFSR state without storing any matrix — the O(B) memory
property of the chip's cRP encoder (vs O(F*D) for explicit RP).

Block schedule (documented deviation from the chip, see DESIGN.md
§Hardware-Adaptation): the chip advances its LFSRs strictly sequentially
across the whole matrix; we re-derive the 16 LFSR states per *row-block*
``i`` (16 rows of the D x F base matrix) from ``splitmix64`` so that row
bands can be generated in parallel by independent kernel programs, and
advance each LFSR 16 steps per *column-block* ``j`` so consecutive blocks
carry fresh state. Statistically this is the same family of pseudo-random
±1 matrices; memory stays O(1) per band.
"""

from __future__ import annotations

import numpy as np

MASK16 = 0xFFFF
GOLDEN = 0x9E3779B97F4A7C15


def splitmix64(x: int) -> int:
    """One splitmix64 output for state ``x`` (returns the mixed value)."""
    x = (x + GOLDEN) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


def lfsr16_step(s: int) -> int:
    """One Fibonacci LFSR step (taps 16,15,13,4)."""
    fb = ((s >> 15) ^ (s >> 14) ^ (s >> 12) ^ (s >> 3)) & 1
    return ((s << 1) | fb) & MASK16


def lfsr16_step16(s: int) -> int:
    """Advance 16 steps — one fresh 16-bit word."""
    for _ in range(16):
        s = lfsr16_step(s)
    return s


def row_block_states(master_seed: int, i: int) -> np.ndarray:
    """Initial states of the 16 LFSRs for row-block ``i`` (shape (16,) u16).

    Derivation: chain splitmix64 from ``master_seed ^ (i+1)*GOLDEN`` and take
    the low 16 bits of each output; the all-zero lockup state is remapped to
    0xACE1.
    """
    s = (master_seed ^ (((i + 1) * GOLDEN) & 0xFFFFFFFFFFFFFFFF)) & 0xFFFFFFFFFFFFFFFF
    out = np.empty(16, dtype=np.uint16)
    for r in range(16):
        s = splitmix64(s)
        v = s & MASK16
        out[r] = v if v != 0 else 0xACE1
    return out


def all_row_states(master_seed: int, d: int) -> np.ndarray:
    """States for every row-block of a D-dimensional encoder: (d//16, 16) u16."""
    assert d % 16 == 0
    return np.stack([row_block_states(master_seed, i) for i in range(d // 16)])


def block_signs(states: np.ndarray) -> np.ndarray:
    """Expand 16 LFSR states into a 16x16 ±1 block.

    Element (r, c) = +1 if bit ``c`` of state ``r`` is set, else -1.
    """
    s = states.astype(np.int64)[:, None]
    bits = (s >> np.arange(16)[None, :]) & 1
    return (2 * bits - 1).astype(np.int32)


def base_matrix(master_seed: int, d: int, f: int) -> np.ndarray:
    """Materialize the full D x F ±1 base matrix (test/oracle use only).

    The production encoders never build this; it exists so ``ref.py`` can
    check the streaming kernels against a dense matmul.
    """
    assert d % 16 == 0 and f % 16 == 0
    mat = np.empty((d, f), dtype=np.int32)
    for i in range(d // 16):
        states = row_block_states(master_seed, i).astype(np.int64)
        for j in range(f // 16):
            states = np.array([lfsr16_step16(int(s)) for s in states], dtype=np.int64)
            mat[i * 16 : (i + 1) * 16, j * 16 : (j + 1) * 16] = block_signs(states)
    return mat


def golden_vectors(master_seed: int = 0xF51_4D17, n: int = 64) -> dict:
    """Golden test vectors consumed by both pytest and `cargo test`."""
    seq = []
    s = 0xACE1
    for _ in range(n):
        s = lfsr16_step(s)
        seq.append(int(s))
    states0 = row_block_states(master_seed, 0)
    states7 = row_block_states(master_seed, 7)
    return {
        "master_seed": master_seed,
        "step_seq_from_ace1": seq,
        "row0_states": [int(v) for v in states0],
        "row7_states": [int(v) for v in states7],
        "row0_step16": [int(lfsr16_step16(int(v))) for v in states0],
        "block0_sign_row0": [int(v) for v in block_signs(
            np.array([lfsr16_step16(int(v)) for v in states0], dtype=np.uint16))[0]],
    }
