"""Pure-jnp / numpy correctness oracles for every Pallas kernel.

These are the semantics the kernels must match (``assert_allclose`` in
python/tests). They are deliberately written in the most direct dense form —
no streaming, no blocking — so a reviewer can audit the math against the
paper's equations:

  * cRP encode         — eq. (3): h = B @ x with the LFSR base matrix
  * class aggregation  — eq. (4): C_j = sum_i h_i^j
  * distance search    — eq. (5): argmin_j Distance(q, C_j)
  * clustered conv     — Fig. 4(b): bin-accumulate by weight index, then
                         multiply by the codebook centroids
"""

from __future__ import annotations

import numpy as np

from . import lfsr


def crp_encode_ref(x: np.ndarray, master_seed: int, d: int) -> np.ndarray:
    """Dense-oracle cRP encoding: (B, F) -> (B, D) via the full base matrix."""
    x = np.asarray(x, dtype=np.float32)
    b_mat = lfsr.base_matrix(master_seed, d, x.shape[-1]).astype(np.float32)
    return x @ b_mat.T


def aggregate_ref(hvs: np.ndarray) -> np.ndarray:
    """Class-HV aggregation (bundling): (k, D) -> (D,)."""
    return np.asarray(hvs, dtype=np.float32).sum(axis=0)


def l1_distance_ref(q: np.ndarray, classes: np.ndarray) -> np.ndarray:
    """Manhattan distance table: (B, D) x (C, D) -> (B, C)."""
    q = np.asarray(q, dtype=np.float32)
    c = np.asarray(classes, dtype=np.float32)
    return np.abs(q[:, None, :] - c[None, :, :]).sum(axis=-1)


def dot_score_ref(q: np.ndarray, classes: np.ndarray) -> np.ndarray:
    """Dot-product similarity table (cosine numerator): (B,D)x(C,D)->(B,C)."""
    return np.asarray(q, np.float32) @ np.asarray(classes, np.float32).T


def clustered_conv_ref(
    patches: np.ndarray,  # (P, KKC) im2col patches
    idx: np.ndarray,      # (Cout, KKC) weight index in [0, N)
    codebook: np.ndarray, # (Cout, G, N) centroid values
    ch_sub: int,
    cin: int,
) -> np.ndarray:
    """Weight-clustered convolution oracle, written as the PE does it:

    1. accumulation: bin patch entries by (group, weight index)
    2. MAC: multiply the N bins of each group by the codebook and sum
    """
    p, kkc = patches.shape
    cout, g, n = codebook.shape
    assert idx.shape == (cout, kkc)
    # group of flat position k: layout k = (ky*K + kx)*Cin + ci
    ci = np.arange(kkc) % cin
    group = ci // ch_sub
    assert group.max() + 1 == g
    out = np.zeros((p, cout), dtype=np.float32)
    for co in range(cout):
        bins = np.zeros((p, g, n), dtype=np.float32)
        for k in range(kkc):
            bins[:, group[k], idx[co, k]] += patches[:, k]
        out[:, co] = np.einsum("pgn,gn->p", bins, codebook[co]).astype(np.float32)
    return out


def reconstruct_weights(
    idx: np.ndarray, codebook: np.ndarray, ch_sub: int, cin: int
) -> np.ndarray:
    """Expand (index, codebook) back to dense weights (Cout, KKC).

    ``clustered_conv_ref(patches, ...) == patches @ reconstruct_weights(...).T``
    up to float association — used by the L2 model to run full conv layers
    through lax.conv with *numerically identical* clustered weights.
    """
    cout, kkc = idx.shape
    ci = np.arange(kkc) % cin
    group = ci // ch_sub
    w = np.empty((cout, kkc), dtype=np.float32)
    for co in range(cout):
        w[co] = codebook[co, group, idx[co]]
    return w
