"""L2: the FSL-HDnn compute graph — FE forward, cRP encode, HDC train/infer.

Every public function here is an AOT entrypoint: ``aot.py`` jit-lowers it
once to HLO text and the rust coordinator executes the compiled artifact on
the PJRT CPU client at request time. The Pallas kernels (L1) are called
from inside these functions so they lower into the same HLO module.

Weights are *baked into the artifacts as constants* — the FE is frozen
(transfer-learning, Section III-A), so the artifact is the exact analogue
of the chip's pre-loaded index/codebook memories.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import clustering, resnet
from .kernels import clustered_conv as cc
from .kernels import crp_encoder, hdc_ops, lfsr


class FslHdnnModel:
    """Frozen clustered FE + cRP/HDC classifier, ready for AOT lowering."""

    def __init__(self, cfg: resnet.FeConfig, d: int = 4096,
                 master_seed: int = 0xF51_4D17, use_pallas_stem: bool = True):
        self.cfg = cfg
        self.d = d
        self.master_seed = master_seed
        self.use_pallas_stem = use_pallas_stem

        raw = resnet.init_params(cfg)
        raw = resnet.rms_calibrate(raw, cfg)
        # weight clustering (Fig. 4a) on every conv layer, then reconstruct
        # dense clustered weights so lax.conv computes the identical math.
        self.cluster_meta: dict = {}
        self.params: dict = {}
        for name in resnet.conv_layer_names(raw):
            w = np.asarray(raw[name])
            cout, k, _, cin = w.shape
            idx, codebook = clustering.cluster_layer(w, cfg.ch_sub, cfg.n_centroids)
            self.cluster_meta[name] = (idx, codebook)
            dense = clustering.reconstruct(idx, codebook, cin, k)
            self.params[name] = dense.reshape(cout, k, k, cin)
        # static routing tensors for the pallas stem conv
        stem = self.params["stem"]
        cout, k, _, cin = stem.shape
        idx, codebook = self.cluster_meta["stem"]
        self._stem_onehot = cc.build_onehot(idx, cfg.ch_sub, cin, cfg.n_centroids)
        g = codebook.shape[1]
        self._stem_cb = codebook.reshape(cout, g * cfg.n_centroids)
        # cRP seed table — the only stored randomness, O(D) bytes (Fig. 6b)
        self.row_states = lfsr.all_row_states(master_seed, d).astype(np.int32)

    # ---------------- FE ----------------

    def _stem_pallas(self, x: jnp.ndarray) -> jnp.ndarray:
        """Stem conv routed through the L1 clustered-conv kernel."""
        b, h, w, cin = x.shape
        patches = jax.vmap(lambda im: cc.im2col(im, 3, 1, 1))(x)  # (B,P,KKC)
        p = patches.shape[1]
        flat = patches.reshape(b * p, -1)
        tile = 64 if (b * p) % 64 == 0 else 16
        out = cc.clustered_conv(flat, jnp.asarray(self._stem_onehot),
                                jnp.asarray(self._stem_cb), pixel_tile=tile)
        cout = self._stem_cb.shape[0]
        return jax.nn.relu(out.reshape(b, h, w, cout))

    def fe_forward(self, x: jnp.ndarray) -> jnp.ndarray:
        """(B,H,W,Cin) -> (B, 4, Fmax): per-stage branch features, each
        zero-padded to Fmax = widths[-1] so one cRP artifact serves all
        branches (padding contributes 0 to the projection)."""
        cfg = self.cfg
        if self.use_pallas_stem:
            h = self._stem_pallas(x)
            branches = self._stages(h)
        else:
            branches = resnet.forward(self.params, x, cfg)
        fmax = cfg.feature_dim
        padded = [jnp.pad(f, ((0, 0), (0, fmax - f.shape[1]))) for f in branches]
        return jnp.stack(padded, axis=1)

    def _stages(self, h: jnp.ndarray) -> list:
        """Stage stack after the stem (mirrors resnet.forward)."""
        cfg, params = self.cfg, self.params
        branches = []
        for s, w in enumerate(cfg.widths):
            stride = 1 if s == 0 else 2
            for b in range(cfg.blocks_per_stage):
                pre = f"s{s}b{b}"
                st = stride if b == 0 else 1
                y = jax.nn.relu(resnet._conv(h, params[f"{pre}_conv1"], stride=st))
                y = resnet._conv(y, params[f"{pre}_conv2"], stride=1)
                if f"{pre}_proj" in params:
                    skip = resnet._conv(h, params[f"{pre}_proj"], stride=st)
                elif st != 1:
                    skip = h[:, ::st, ::st, :]
                else:
                    skip = h
                h = jax.nn.relu(y + skip)
            branches.append(h.mean(axis=(1, 2)))
        return branches

    # ---------------- HDC ----------------

    def encode(self, feats: jnp.ndarray) -> jnp.ndarray:
        """cRP encode (B, Fmax) -> (B, D) via the L1 kernel."""
        return crp_encoder.crp_encode(feats, jnp.asarray(self.row_states), self.d)

    def hdc_train(self, hvs: jnp.ndarray) -> jnp.ndarray:
        """Single-pass class-HV aggregation (k, D) -> (D,) — eq. (4)."""
        return hdc_ops.aggregate(hvs)

    def hdc_infer(self, q: jnp.ndarray, classes: jnp.ndarray) -> jnp.ndarray:
        """L1-distance table (B, D) x (C, D) -> (B, C) — eq. (5)."""
        return hdc_ops.l1_distance(q, classes)

    def fsl_infer(self, x: jnp.ndarray, classes: jnp.ndarray) -> jnp.ndarray:
        """Fused serving path: image -> final-branch feature -> HV ->
        distance table. The early-exit path instead calls fe_forward +
        encode + hdc_infer per branch from the rust coordinator."""
        feats = self.fe_forward(x)[:, -1, :]
        q = self.encode(feats)
        return self.hdc_infer(q, classes)

    # ---------------- export ----------------

    def export_weights(self) -> tuple[dict, bytes]:
        """(layer manifest, packed f32 LE blob) of clustered dense weights."""
        layers = []
        blob = bytearray()
        for name in resnet.conv_layer_names(self.params):
            w = self.params[name]
            layers.append({"name": name, "shape": list(w.shape)})
            blob.extend(np.ascontiguousarray(w, dtype="<f4").tobytes())
        return {"layers": layers}, bytes(blob)
