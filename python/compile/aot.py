"""AOT compile path: lower every L2 entrypoint to HLO *text* artifacts.

This is the only python that ever runs (`make artifacts`); the rust binary
loads `artifacts/*.hlo.txt` via PJRT and is self-contained afterwards.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (all under --out, default ../artifacts):
  *.hlo.txt          one module per entrypoint x static shape
  manifest.json      entrypoint -> file + input/output shapes + model config
  fe_weights.bin     clustered dense FE weights (f32 LE) for the rust-native
                     FE and the chip simulator
  goldens/           deterministic input/output vectors cross-checked by
                     both pytest and `cargo test`
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import resnet
from .kernels import lfsr
from .model import FslHdnnModel


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True).

    CRITICAL: print with `print_large_constants=True`. The default printer
    elides big constant arrays as `{...}`, and xla_extension 0.5.1's text
    parser silently materializes those as ZEROS — the FE weights and cRP
    seed tables are baked-in constants, so default printing produces
    artifacts that run but compute garbage (all-zero features).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # modern metadata attributes (source_end_line etc.) are rejected by the
    # 0.5.1 text parser — strip them
    opts.print_metadata = False
    return comp.get_hlo_module().to_string(opts)


def _spec(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shapes(tree):
    out = []
    for leaf in jax.tree_util.tree_leaves(tree):
        out.append({"shape": list(leaf.shape), "dtype": str(leaf.dtype)})
    return out


def build_artifacts(out_dir: str, d: int = 4096, classes_max: int = 32,
                    shots: int = 5, image_size: int = 32,
                    widths=(16, 32, 64, 128), seed: int = 2024) -> None:
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "goldens"), exist_ok=True)
    cfg = resnet.FeConfig(image_size=image_size, widths=tuple(widths), seed=seed)
    model = FslHdnnModel(cfg, d=d)
    fmax = cfg.feature_dim
    c3 = cfg.in_channels

    entries = []

    def emit(name: str, fn, *args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "file": fname,
            "inputs": _shapes(args),
            "outputs": _shapes(jax.eval_shape(fn, *args)),
        })
        print(f"  emitted {fname} ({len(text)} chars)")

    for b in (1, 8):
        emit(f"fe_forward_b{b}", model.fe_forward, _spec(b, image_size, image_size, c3))
        emit(f"crp_encode_b{b}", model.encode, _spec(b, fmax))
        emit(f"hdc_infer_b{b}", model.hdc_infer, _spec(b, d), _spec(classes_max, d))
    emit(f"hdc_train_k{shots}", model.hdc_train, _spec(shots, d))
    emit("fsl_infer_b1", model.fsl_infer, _spec(1, image_size, image_size, c3),
         _spec(classes_max, d))

    # --- weights export (rust-native FE + chip simulator) ---
    wmanifest, blob = model.export_weights()
    with open(os.path.join(out_dir, "fe_weights.bin"), "wb") as f:
        f.write(blob)

    # --- goldens: the python pipeline's answers on fixed inputs ---
    g = os.path.join(out_dir, "goldens")
    rng = np.random.default_rng(7)
    x = rng.normal(0.0, 1.0, size=(2, image_size, image_size, c3)).astype(np.float32)
    feats = np.asarray(model.fe_forward(jnp.asarray(x)))            # (2,4,Fmax)
    hv = np.asarray(model.encode(jnp.asarray(feats[:, -1, :])))     # (2,D)
    cls_feats = rng.normal(0.0, 1.0, size=(4, fmax)).astype(np.float32)
    # batch rows of the cRP encoder are independent, so one 4-row call
    # produces exactly what four 1-row calls would
    classes = np.asarray(model.encode(jnp.asarray(cls_feats)))
    dist = np.asarray(model.hdc_infer(jnp.asarray(hv), jnp.asarray(classes)))
    agg = np.asarray(model.hdc_train(jnp.asarray(classes[: shots - 1]))) \
        if shots - 1 <= 4 else None

    def dump(name, arr):
        np.ascontiguousarray(arr, dtype="<f4").tofile(os.path.join(g, name))

    dump("x.bin", x)
    dump("feats.bin", feats)
    dump("hv.bin", hv)
    dump("class_feats.bin", cls_feats)
    dump("classes.bin", classes)
    dump("dist.bin", dist)
    if agg is not None:
        dump("agg.bin", agg)

    goldens = lfsr.golden_vectors(model.master_seed)
    goldens.update({
        "shapes": {
            "x": list(x.shape), "feats": list(feats.shape),
            "hv": list(hv.shape), "class_feats": list(cls_feats.shape),
            "classes": list(classes.shape), "dist": list(dist.shape),
            "agg": [int(hv.shape[1])] if agg is not None else [],
        },
        "input_seed": 7,
    })
    with open(os.path.join(g, "goldens.json"), "w") as f:
        json.dump(goldens, f, indent=1)

    manifest = {
        "entries": entries,
        "weights": wmanifest,
        "config": {
            "image_size": image_size, "in_channels": c3,
            "widths": list(widths), "feature_dim": fmax,
            "n_branches": len(widths), "d": d, "classes_max": classes_max,
            "shots": shots, "master_seed": model.master_seed,
            "ch_sub": cfg.ch_sub, "n_centroids": cfg.n_centroids,
            "seed": seed,
        },
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} modules + weights + goldens to {out_dir}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--d", type=int, default=4096)
    p.add_argument("--classes-max", type=int, default=32)
    p.add_argument("--shots", type=int, default=5)
    p.add_argument("--image-size", type=int, default=32)
    args = p.parse_args()
    build_artifacts(args.out, d=args.d, classes_max=args.classes_max,
                    shots=args.shots, image_size=args.image_size)


if __name__ == "__main__":
    main()
