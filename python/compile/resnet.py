"""ResNet-18-shaped feature extractor with per-block branch taps (Fig. 11).

The paper freezes an ImageNet-pretrained ResNet-18 and taps the output of
each of the four CONV stages (average-pooled) as "branch features" for the
early-exit mechanism. We reproduce the *structure* — 4 stages of 2 basic
blocks, stride-2 downsampling, branch taps after every stage — at a
configurable width so the whole FE fits the PJRT-CPU budget (DESIGN.md
substitution table: FE experiments depend on conv structure, not ImageNet
semantics).

Weights are deterministic (seeded He init) and then RMS-calibrated on a
probe batch so activations are well-conditioned without batch norm
(equivalent to folding frozen BN scales into the convs).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class FeConfig:
    """Feature-extractor hyperparameters."""
    image_size: int = 32
    in_channels: int = 3
    widths: tuple = (16, 32, 64, 128)
    blocks_per_stage: int = 2
    seed: int = 2024
    ch_sub: int = 64          # weight-clustering group size (paper: 64)
    n_centroids: int = 16     # centroids per codebook

    @property
    def feature_dim(self) -> int:
        return self.widths[-1]

    @property
    def branch_dims(self) -> tuple:
        return self.widths


def _conv_init(rng: np.random.Generator, k: int, cin: int, cout: int) -> np.ndarray:
    """He-normal (Cout, K, K, Cin)."""
    std = float(np.sqrt(2.0 / (k * k * cin)))
    return rng.normal(0.0, std, size=(cout, k, k, cin)).astype(np.float32)


def init_params(cfg: FeConfig) -> dict:
    """Deterministic parameter pytree. Conv weights as (Cout,K,K,Cin) f32."""
    rng = np.random.default_rng(cfg.seed)
    params: dict = {"stem": _conv_init(rng, 3, cfg.in_channels, cfg.widths[0])}
    for s, w in enumerate(cfg.widths):
        cin = cfg.widths[s - 1] if s > 0 else cfg.widths[0]
        for b in range(cfg.blocks_per_stage):
            bcin = cin if b == 0 else w
            pre = f"s{s}b{b}"
            params[f"{pre}_conv1"] = _conv_init(rng, 3, bcin, w)
            params[f"{pre}_conv2"] = _conv_init(rng, 3, w, w)
            if bcin != w:
                params[f"{pre}_proj"] = _conv_init(rng, 1, bcin, w)
    return params


def _conv(x: jnp.ndarray, w: np.ndarray, stride: int = 1) -> jnp.ndarray:
    """NHWC conv with SAME padding; w is (Cout, K, K, Cin)."""
    kernel = jnp.transpose(jnp.asarray(w), (1, 2, 3, 0))  # HWIO
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def forward(params: dict, x: jnp.ndarray, cfg: FeConfig) -> list:
    """FE forward pass. x: (B, H, W, Cin). Returns 4 branch features,
    each (B, width_s) — global average pool of the stage output."""
    h = jax.nn.relu(_conv(x, params["stem"], stride=1))
    branches = []
    for s, w in enumerate(cfg.widths):
        stride = 1 if s == 0 else 2
        for b in range(cfg.blocks_per_stage):
            pre = f"s{s}b{b}"
            st = stride if b == 0 else 1
            y = jax.nn.relu(_conv(h, params[f"{pre}_conv1"], stride=st))
            y = _conv(y, params[f"{pre}_conv2"], stride=1)
            if f"{pre}_proj" in params:
                skip = _conv(h, params[f"{pre}_proj"], stride=st)
            elif st != 1:
                skip = h[:, ::st, ::st, :]
            else:
                skip = h
            h = jax.nn.relu(y + skip)
        branches.append(h.mean(axis=(1, 2)))  # (B, width_s)
    return branches


def rms_calibrate(params: dict, cfg: FeConfig, probe_batch: int = 8) -> dict:
    """Rescale each conv so its stage activations have ~unit RMS (frozen-BN
    fold-in). Deterministic: the probe batch comes from the config seed."""
    rng = np.random.default_rng(cfg.seed + 1)
    x = jnp.asarray(rng.normal(size=(probe_batch, cfg.image_size,
                                     cfg.image_size, cfg.in_channels)).astype(np.float32))
    params = dict(params)
    # iterate a couple of times: each conv rescale shifts downstream stats
    for _ in range(2):
        h = jax.nn.relu(_conv(x, params["stem"], stride=1))
        rms = float(jnp.sqrt(jnp.mean(h * h)) + 1e-8)
        params["stem"] = params["stem"] / rms
        h = h / rms
        for s, w in enumerate(cfg.widths):
            stride = 1 if s == 0 else 2
            for b in range(cfg.blocks_per_stage):
                pre = f"s{s}b{b}"
                st = stride if b == 0 else 1
                y1 = jax.nn.relu(_conv(h, params[f"{pre}_conv1"], stride=st))
                r1 = float(jnp.sqrt(jnp.mean(y1 * y1)) + 1e-8)
                params[f"{pre}_conv1"] = params[f"{pre}_conv1"] / r1
                y1 = y1 / r1
                y2 = _conv(y1, params[f"{pre}_conv2"], stride=1)
                r2 = float(jnp.sqrt(jnp.mean(y2 * y2)) + 1e-8)
                params[f"{pre}_conv2"] = params[f"{pre}_conv2"] / r2
                y2 = y2 / r2
                if f"{pre}_proj" in params:
                    skip = _conv(h, params[f"{pre}_proj"], stride=st)
                    rp = float(jnp.sqrt(jnp.mean(skip * skip)) + 1e-8)
                    params[f"{pre}_proj"] = params[f"{pre}_proj"] / rp
                    skip = skip / rp
                elif st != 1:
                    skip = h[:, ::st, ::st, :]
                else:
                    skip = h
                h = jax.nn.relu(y2 + skip)
    return params


def conv_layer_names(params: dict) -> list:
    """Deterministic ordering of conv layers (export / clustering)."""
    return sorted(params.keys())
