"""Weight clustering (Fig. 4a): K-means over conv weights per Ch_sub group.

After (pre-)training, the weights of each output channel are partitioned by
input-channel group (``ch_sub`` channels per group) and each group's scalar
weights are clustered into N centroids. The layer is then stored as

  * index memory:  log2(N)-bit centroid index per weight   (36 KB on chip)
  * codebook:      N bf16 centroids per (channel, group)    (4 KB on chip)

This module performs the clustering and computes the Fig. 5 metrics
(compression ratio and op-reduction ratio vs an INT8 baseline).
"""

from __future__ import annotations

import numpy as np


def kmeans_1d(values: np.ndarray, n: int, iters: int = 15) -> tuple[np.ndarray, np.ndarray]:
    """Lloyd's k-means on scalar weights. Returns (centroids (n,), labels).

    Initialization: evenly spaced quantiles (deterministic, no RNG) —
    well-behaved for the roughly-Gaussian weight distributions of conv
    layers and reproducible across python/rust.
    """
    v = np.asarray(values, dtype=np.float64).ravel()
    if v.size <= n:
        cents = np.zeros(n)
        cents[: v.size] = np.sort(v)
        labels = np.argsort(np.argsort(v))
        return cents.astype(np.float32), labels.astype(np.int64)
    qs = (np.arange(n) + 0.5) / n
    cents = np.quantile(v, qs)
    # ensure distinct starting centroids
    eps = 1e-12 + 1e-9 * (v.max() - v.min())
    for i in range(1, n):
        if cents[i] <= cents[i - 1]:
            cents[i] = cents[i - 1] + eps
    for _ in range(iters):
        labels = np.argmin(np.abs(v[:, None] - cents[None, :]), axis=1)
        for j in range(n):
            sel = labels == j
            if sel.any():
                cents[j] = v[sel].mean()
    labels = np.argmin(np.abs(v[:, None] - cents[None, :]), axis=1)
    return cents.astype(np.float32), labels.astype(np.int64)


def cluster_layer(
    w: np.ndarray, ch_sub: int, n: int
) -> tuple[np.ndarray, np.ndarray]:
    """Cluster one conv layer's weights.

    w: (Cout, K, K, Cin) dense weights.
    Returns (idx (Cout, K*K*Cin) int64, codebook (Cout, G, N) f32) in the
    flat layout k = (ky*K + kx)*Cin + ci shared with the kernels.
    """
    cout, k, _, cin = w.shape
    ch_sub = min(ch_sub, cin)
    g = (cin + ch_sub - 1) // ch_sub
    flat = w.reshape(cout, k * k, cin)
    idx = np.empty((cout, k * k * cin), dtype=np.int64)
    codebook = np.zeros((cout, g, n), dtype=np.float32)
    ci = np.arange(k * k * cin) % cin
    group_of = ci // ch_sub
    for co in range(cout):
        wflat = flat[co].reshape(-1)  # layout (ky*K+kx)*Cin + ci
        for gi in range(g):
            sel = group_of == gi
            cents, labels = kmeans_1d(wflat[sel], n)
            codebook[co, gi] = cents
            idx[co, sel] = labels
    return idx, codebook


def reconstruct(idx: np.ndarray, codebook: np.ndarray, cin: int, k: int) -> np.ndarray:
    """(idx, codebook) -> dense (Cout, K, K, Cin) clustered weights."""
    cout, g, n = codebook.shape
    kkc = idx.shape[1]
    ch_sub = (cin + g - 1) // g
    ci = np.arange(kkc) % cin
    group_of = ci // ch_sub
    dense = np.empty((cout, kkc), dtype=np.float32)
    for co in range(cout):
        dense[co] = codebook[co, group_of, idx[co]]
    return dense.reshape(cout, k, k, cin)


def compression_ratio(cin: int, k: int, ch_sub: int, n: int,
                      baseline_bits: int = 8, value_bits: int = 16) -> float:
    """Model-size ratio vs an INT8 baseline (Fig. 5, left axis).

    Clustered storage per output channel = K*K*Cin indices of log2(N) bits
    + G codebooks of N x value_bits.
    """
    ch_sub = min(ch_sub, cin)
    g = (cin + ch_sub - 1) // ch_sub
    base = k * k * cin * baseline_bits
    ours = k * k * cin * int(np.ceil(np.log2(n))) + g * n * value_bits
    return base / ours


def op_reduction_ratio(k: int, n: int, ch_sub: int, cin: int) -> float:
    """MAC-op ratio vs a dense conv (Fig. 5, right axis).

    Dense: 2*K^2-1 ops per (pixel, channel-group window of one input chan)
    — following the paper's per-window accounting: the clustered PE does
    K^2 accumulations once per Ch_sub block plus N codebook MACs, i.e.
    dense 2*K^2*Ch_sub vs clustered (K^2*Ch_sub + 2*N).
    """
    ch_sub = min(ch_sub, cin)
    dense = 2.0 * k * k * ch_sub
    ours = 1.0 * k * k * ch_sub + 2.0 * n
    return dense / ours
