//! Fleet serving: a router fans Poisson-arriving few-shot sessions over
//! several FSL-HDnn devices, reporting SLO attainment — the multi-device
//! deployment story the single-chip paper motivates (edge hubs gang
//! accelerators behind one endpoint).
//!
//! Run with:  cargo run --release --example fleet_serving -- [devices] [sessions]

use std::time::Instant;

use fsl_hdnn::config::EeConfig;
use fsl_hdnn::coordinator::{DeviceRouter, Placement};
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::data::trace::{SloReport, TraceGen, TraceOp};
use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let n_devices: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(2);
    let n_sessions: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(6);
    let dir = std::path::PathBuf::from("artifacts");
    let model = ComputeEngine::open_or_synthetic(Backend::Native, &dir)?.model().clone();

    let gen_trace = TraceGen { n_way: 5, k_shot: 5, queries_per_session: 15, ..Default::default() };
    let mut rng = Rng::new(31);
    let trace = gen_trace.generate(n_sessions, &mut rng);
    println!(
        "== fleet serving: {n_devices} devices, {n_sessions} sessions, {} events ==",
        trace.len()
    );

    let mut router = DeviceRouter::start(n_devices, gen_trace.k_shot, Placement::LeastLoaded,
        move |_i| {
            let d = dir.clone();
            move || ComputeEngine::open_or_synthetic(Backend::Native, &d)
        })?;

    let images = ImageGen::new(model.image_size, 64, 5);
    // map trace session slots -> (router session id, drawn pool classes)
    let mut slots: Vec<Option<(u64, Vec<usize>)>> = vec![None; n_sessions];
    let mut slo_query = SloReport::new(50.0);
    let mut slo_shot = SloReport::new(100.0);
    let mut correct = 0usize;
    let mut total = 0usize;
    let ee = EeConfig::paper_default();
    let t0 = Instant::now();
    for (_t, op) in &trace {
        match op {
            TraceOp::NewSession { n_way } => {
                let sid = router.create_session(*n_way, 4)?;
                let classes = rng.choose_k(images.n_classes, *n_way);
                let slot = slots.iter().position(|s| s.is_none()).unwrap();
                slots[slot] = Some((sid, classes));
            }
            TraceOp::Shot { session_slot, class } => {
                let (sid, classes) = slots[*session_slot].as_ref().unwrap();
                let img = images.sample(classes[*class], &mut rng);
                let t = Instant::now();
                router.add_shot(*sid, *class, img)?;
                slo_shot.record(t.elapsed().as_secs_f64() * 1e3);
            }
            TraceOp::Train { session_slot } => {
                let (sid, _) = slots[*session_slot].as_ref().unwrap();
                router.finish_training(*sid)?;
            }
            TraceOp::Query { session_slot, class } => {
                let (sid, classes) = slots[*session_slot].as_ref().unwrap();
                let img = images.sample(classes[*class], &mut rng);
                let t = Instant::now();
                let out = router.query(*sid, img, Some(ee))?;
                slo_query.record(t.elapsed().as_secs_f64() * 1e3);
                correct += (out.prediction == *class) as usize;
                total += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut t = Table::new("fleet summary", &["metric", "value"]);
    t.row(&["devices".into(), format!("{n_devices} (loads {:?})", router.loads())]);
    t.row(&["events replayed".into(), trace.len().to_string()]);
    t.row(&["query accuracy".into(), format!("{:.1}%", 100.0 * correct as f64 / total as f64)]);
    t.row(&["query p50 / p99".into(),
        format!("{:.1} / {:.1} ms", slo_query.p50(), slo_query.p99())]);
    t.row(&["query SLO (50 ms) attainment".into(),
        format!("{:.1}%", 100.0 * slo_query.attainment())]);
    t.row(&["shot p50".into(), format!("{:.1} ms", slo_shot.p50())]);
    t.row(&["wall-clock".into(), format!("{wall:.1} s")]);
    t.print();
    for (i, m) in router.fleet_metrics().iter().enumerate() {
        println!(
            "device {i}: {} shots, {} queries, query {:.1} ms mean, EE rate {:.0}%",
            m.shots, m.queries, m.query_ms_mean, 100.0 * m.early_exit_rate
        );
    }
    Ok(())
}
