//! Quickstart: one 5-way 5-shot few-shot learning episode, end to end.
//!
//!   1. open the compute engine over the AOT artifacts (PJRT if available,
//!      falling back to the native mirror),
//!   2. start the coordinator (the "device"),
//!   3. stream 25 labeled shots — the batcher groups them per class and
//!      trains the HDC model in a single pass (Fig. 12),
//!   4. classify query images with and without early exit (Fig. 11).
//!
//! Run with:  cargo run --release --example quickstart
//! Add `-- --clustered` to run the FE through the packed weight-clustered
//! kernel (Fig. 4b) — the chip's cheap path — instead of the dense conv.
//! `--hv-bits N` (1..=16) picks the class-memory precision and
//! `--metric l1|dot|cosine|hamming` the distance metric of the packed HDC
//! datapath (`--hv-bits 1 --metric hamming` is the binary popcount path).
//! `--ee E_S,E_C` picks the early-exit operating point (default the
//! paper's 2,2); queries run the staged loop, so an exit at block b means
//! the remaining FE stages are never computed — the printed layer
//! counters prove it. `--backend hdc|ldc` selects the classifier seam:
//! `ldc` folds branch HVs to low-D prototypes (`--ldc-d`, 0 = auto) for
//! ~8x less class memory at the paper's D=4096.

use fsl_hdnn::classifier::ClassifierBackend;
use fsl_hdnn::config::{ClassifierConfig, EeConfig, HdcConfig, ModelConfig};
use fsl_hdnn::coordinator::Coordinator;
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::hdc::Distance;
use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::util::args::{arg_flag, arg_str, arg_usize};
use fsl_hdnn::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let cfg = ModelConfig { clustered: arg_flag("--clustered"), ..ModelConfig::default() };
    let hv_bits = arg_usize("--hv-bits", HdcConfig::default().hv_bits as usize) as u32;
    let metric = Distance::from_name(&arg_str("--metric", HdcConfig::default().metric.name()))?;
    let ee = EeConfig::parse(&arg_str("--ee", "2,2"))?;
    let cls = ClassifierConfig {
        backend: ClassifierBackend::from_name(&arg_str("--backend", "hdc"))?,
        ldc_d: arg_usize("--ldc-d", 0),
    };
    // read geometry on the caller side; build the engine inside the worker.
    // Without `make artifacts` the native backend runs synthetic weights.
    let model = ComputeEngine::open_or_synthetic_with(
        Backend::Native,
        &dir,
        ModelConfig { clustered: false, ..cfg.clone() },
    )?
    .model()
    .clone();
    // the clustered flag only applies if the native fallback runs; the
    // PJRT-first path below says which backend was actually taken
    println!(
        "model: {0}x{0}x{1} image -> F={2}, D={3}, clustered FE (native only): {4}, \
         class HVs {5}-bit / {6}, classifier {7}",
        model.image_size,
        model.in_channels,
        model.feature_dim,
        model.d,
        cfg.clustered,
        hv_bits,
        metric.name(),
        cls.backend.name()
    );

    let (n_way, k_shot) = (5, 5);
    let dir2 = dir.clone();
    let coord = Coordinator::start_with_classifier(
        move || {
            ComputeEngine::open(Backend::Pjrt, &dir2)
                .or_else(|e| {
                    eprintln!("PJRT unavailable ({e}), using native backend");
                    ComputeEngine::open_or_synthetic_with(Backend::Native, &dir2, cfg)
                })
        },
        k_shot,
        cls,
    )?;

    // synthetic class-structured images (per-class texture families)
    let gen = ImageGen::new(model.image_size, 32, 7);
    let mut rng = Rng::new(7);
    let classes = rng.choose_k(gen.n_classes, n_way);

    // --- single-pass training ---
    let session = coord.create_session_full(n_way, hv_bits, metric, cls.backend)?;
    for (label, &cls) in classes.iter().enumerate() {
        for _ in 0..k_shot {
            coord.add_shot(session, label, gen.sample(cls, &mut rng))?;
        }
    }
    let shots = coord.finish_training(session)?;
    println!("trained on {shots} shots ({n_way}-way {k_shot}-shot), single pass");

    // --- queries ---
    let mut correct_full = 0;
    let mut correct_ee = 0;
    let mut blocks_ee = 0usize;
    let queries = 10;
    for (label, &cls) in classes.iter().enumerate() {
        for _ in 0..queries {
            let img = gen.sample(cls, &mut rng);
            let full = coord.query(session, img.clone(), None)?;
            let out = coord.query(session, img, Some(ee))?;
            correct_full += (full.prediction == label) as usize;
            correct_ee += (out.prediction == label) as usize;
            blocks_ee += out.blocks_used;
        }
    }
    let total = n_way * queries;
    println!(
        "accuracy: full {:.1}% | early-exit (E_s={},E_c={}) {:.1}% using {:.2}/{} blocks \
         on average",
        100.0 * correct_full as f64 / total as f64,
        ee.e_s,
        ee.e_c,
        100.0 * correct_ee as f64 / total as f64,
        blocks_ee as f64 / total as f64,
        model.n_branches()
    );
    let m = coord.metrics();
    println!(
        "device latency: add_shot {:.2} ms, query {:.2} ms (early-exit rate {:.0}%)",
        m.add_shot_ms_mean, m.query_ms_mean, 100.0 * m.early_exit_rate
    );
    // staged inference: these counters report FE work that actually ran —
    // the skipped layers were never computed, not replayed post hoc
    let fe_total = m.fe_layers_executed + m.fe_layers_skipped;
    println!(
        "staged FE work: {} conv layers executed, {} skipped by early exit ({:.0}%), \
         {} branch HVs encoded",
        m.fe_layers_executed,
        m.fe_layers_skipped,
        100.0 * m.fe_layers_skipped as f64 / fe_total.max(1) as f64,
        m.branch_hvs_encoded
    );
    Ok(())
}
