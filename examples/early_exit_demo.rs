//! Early-exit configuration sweep on a live session (Fig. 17's knobs).
//!
//! Trains one session, then classifies the same query set under every
//! (E_s, E_c) configuration, showing the accuracy-vs-depth tradeoff the
//! paper tunes to (E_s=2, E_c=2).
//!
//! Run with:  cargo run --release --example early_exit_demo

use fsl_hdnn::config::EeConfig;
use fsl_hdnn::coordinator::Coordinator;
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::table::Table;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let model = ComputeEngine::open_or_synthetic(Backend::Native, &dir)?.model().clone();
    let (n_way, k_shot, queries) = (5, 5, 12);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        move || ComputeEngine::open_or_synthetic(Backend::Native, &dir2),
        k_shot,
    )?;
    let gen = ImageGen::new(model.image_size, 32, 99);
    let mut rng = Rng::new(99);
    let classes = rng.choose_k(gen.n_classes, n_way);

    let sid = coord.create_session(n_way, 4)?;
    for (label, &cls) in classes.iter().enumerate() {
        for _ in 0..k_shot {
            coord.add_shot(sid, label, gen.sample(cls, &mut rng))?;
        }
    }
    coord.finish_training(sid)?;

    // fixed query set so configurations are directly comparable
    let mut queryset = Vec::new();
    for (label, &cls) in classes.iter().enumerate() {
        for _ in 0..queries {
            queryset.push((gen.sample(cls, &mut rng), label));
        }
    }

    let mut t = Table::new(
        "early-exit sweep (Fig. 17 axes): accuracy vs average depth",
        &["config (E_s,E_c)", "accuracy", "avg blocks", "layers skipped"],
    );
    let mut configs: Vec<(String, Option<EeConfig>)> = vec![("none (full)".into(), None)];
    for e_s in 1..=3usize {
        for e_c in 1..=3usize {
            if e_s - 1 + e_c <= model.n_branches() {
                configs.push((format!("{e_s},{e_c}"), Some(EeConfig { e_s, e_c })));
            }
        }
    }
    for (name, ee) in configs {
        let mut correct = 0;
        let mut blocks = 0usize;
        for (img, label) in &queryset {
            let out = coord.query(sid, img.clone(), ee)?;
            correct += (out.prediction == *label) as usize;
            blocks += out.blocks_used;
        }
        let n = queryset.len();
        let avg_blocks = blocks as f64 / n as f64;
        t.row(&[
            name,
            format!("{:.1}%", 100.0 * correct as f64 / n as f64),
            format!("{:.2}/{}", avg_blocks, model.n_branches()),
            format!("{:.0}%", 100.0 * (1.0 - avg_blocks / model.n_branches() as f64)),
        ]);
    }
    t.print();
    println!("(the paper's operating point is E_s=2, E_c=2: 20-25% of layers skipped, <1% loss)");
    Ok(())
}
