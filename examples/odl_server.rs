//! End-to-end ODL serving driver — the EXPERIMENTS.md validation run.
//!
//! Reproduces the paper's deployment story at system level: a device
//! coordinator serving a stream of 10-way 5-shot personalization tasks,
//! with the PJRT artifacts as the compute "chip". For every episode it
//! (a) streams 50 labeled shots (batched single-pass training),
//! (b) serves 100 queries with the paper's early-exit setting, and
//! (c) attaches the chip simulator's latency/energy estimate for the same
//!     workload at the measured corners — the numbers Table I reports.
//!
//! Run with:  cargo run --release --example odl_server -- [episodes] [backend]
//! Add `--clustered` to serve through the packed weight-clustered FE,
//! `--hv-bits N` / `--metric m` to pick the class-memory precision and
//! distance metric of the packed HDC datapath, `--ee E_S,E_C` to move the
//! early-exit operating point (default 2,2), and `--backend hdc|ldc` /
//! `--ldc-d N` to pick the classifier seam (the positional `backend`
//! stays the compute engine, native|pjrt). Queries run the staged
//! inference loop, so the reported `FE layers skipped` were never
//! computed, and the energy table prices each exit depth separately.

use std::time::Instant;

use fsl_hdnn::classifier::ClassifierBackend;
use fsl_hdnn::config::{ChipConfig, ClassifierConfig, EeConfig, HdcConfig, ModelConfig};
use fsl_hdnn::coordinator::Coordinator;
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::hdc::Distance;
use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::sim::{Chip, EnergyModel};
use fsl_hdnn::util::args::{arg_flag, arg_str, arg_usize};
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::stats;
use fsl_hdnn::util::table::Table;

fn main() -> anyhow::Result<()> {
    // positionals come before the first `--flag` (a value-taking flag like
    // `--hv-bits 1` would otherwise put its value where a positional goes)
    let pos: Vec<String> =
        std::env::args().skip(1).take_while(|s| !s.starts_with("--")).collect();
    let episodes: usize = pos.first().and_then(|s| s.parse().ok()).unwrap_or(5);
    // native by default so the driver runs from a clean checkout; pass
    // `pjrt` explicitly once `make artifacts` has produced the modules and
    // the crate is built with the `pjrt` feature
    let backend = Backend::from_name(pos.get(1).map(|s| s.as_str()).unwrap_or("native"))?;
    let cfg = ModelConfig { clustered: arg_flag("--clustered"), ..ModelConfig::default() };
    let hv_bits = arg_usize("--hv-bits", HdcConfig::default().hv_bits as usize) as u32;
    let metric = Distance::from_name(&arg_str("--metric", HdcConfig::default().metric.name()))?;
    let cls = ClassifierConfig {
        backend: ClassifierBackend::from_name(&arg_str("--backend", "hdc"))?,
        ldc_d: arg_usize("--ldc-d", 0),
    };
    let (n_way, k_shot, queries_per_class) = (10, 5, 10);
    let dir = std::path::PathBuf::from("artifacts");
    let model = ComputeEngine::open_or_synthetic_with(
        Backend::Native,
        &dir,
        ModelConfig { clustered: false, ..cfg.clone() },
    )?
    .model()
    .clone();

    // clustering is a native-backend knob; report what actually runs
    let eff_clustered = backend == Backend::Native && cfg.clustered;
    if cfg.clustered && !eff_clustered {
        eprintln!("note: --clustered is a native-backend knob; PJRT ignores it");
    }
    println!("== FSL-HDnn ODL serving driver ==");
    println!(
        "backend={backend:?}, {episodes} episodes of {n_way}-way {k_shot}-shot, {} queries \
         each, clustered FE: {eff_clustered}, class HVs {hv_bits}-bit / {}, classifier {}",
        n_way * queries_per_class,
        metric.name(),
        cls.backend.name()
    );

    let dir2 = dir.clone();
    let coord = Coordinator::start_with_classifier(
        move || ComputeEngine::open_or_synthetic_with(backend, &dir2, cfg),
        k_shot,
        cls,
    )?;
    let gen = ImageGen::new(model.image_size, 64, 2024);
    let mut rng = Rng::new(2024);
    let ee = EeConfig::parse(&arg_str("--ee", "2,2"))?;

    let mut accs = Vec::new();
    let mut train_wall_s = Vec::new();
    let mut query_wall_ms = Vec::new();
    let mut blocks = Vec::new();
    // class-memory gating while a session is live (sessions are closed at
    // episode end, so the final snapshot would show an empty memory)
    let mut live_metrics = None;
    let t_total = Instant::now();
    for ep in 0..episodes {
        let classes = rng.choose_k(gen.n_classes, n_way);
        let sid = coord.create_session_full(n_way, hv_bits, metric, cls.backend)?;
        let t0 = Instant::now();
        for (label, &cls) in classes.iter().enumerate() {
            for _ in 0..k_shot {
                coord.add_shot(sid, label, gen.sample(cls, &mut rng))?;
            }
        }
        coord.finish_training(sid)?;
        let train_s = t0.elapsed().as_secs_f64();
        train_wall_s.push(train_s);

        let mut pairs = Vec::new();
        for (label, &cls) in classes.iter().enumerate() {
            for _ in 0..queries_per_class {
                let tq = Instant::now();
                let out = coord.query(sid, gen.sample(cls, &mut rng), Some(ee))?;
                query_wall_ms.push(tq.elapsed().as_secs_f64() * 1e3);
                pairs.push((out.prediction, label));
                blocks.push(out.blocks_used as f64);
            }
        }
        let acc = stats::accuracy(&pairs);
        accs.push(acc);
        println!(
            "episode {ep}: trained {} shots in {:.2}s, accuracy {:.1}%",
            n_way * k_shot,
            train_s,
            100.0 * acc
        );
        live_metrics = Some(coord.metrics());
        coord.call(fsl_hdnn::coordinator::Request::CloseSession { session: sid });
    }
    let wall = t_total.elapsed().as_secs_f64();
    let m = coord.metrics();

    let mut t = Table::new("end-to-end serving summary", &["metric", "value"]);
    t.row(&["episodes".into(), episodes.to_string()]);
    t.row(&["mean accuracy".into(),
        format!("{:.1}% ± {:.1}", 100.0 * stats::mean(&accs), 100.0 * stats::ci95(&accs))]);
    t.row(&["training wall-clock / episode".into(),
        format!("{:.2} s ({:.1} images/s)", stats::mean(&train_wall_s),
            (n_way * k_shot) as f64 / stats::mean(&train_wall_s))]);
    t.row(&["query latency p50 / p95".into(),
        format!("{:.1} / {:.1} ms", stats::percentile(&query_wall_ms, 50.0),
            stats::percentile(&query_wall_ms, 95.0))]);
    t.row(&[format!("avg CONV blocks used (EE {},{})", ee.e_s, ee.e_c),
        format!("{:.2} / {}", stats::mean(&blocks), model.n_branches())]);
    t.row(&["early-exit rate".into(), format!("{:.0}%", 100.0 * m.early_exit_rate)]);
    // staged inference work counters: the skipped layers were truncated
    // out of the FE, not replayed post hoc
    let fe_total = m.fe_layers_executed + m.fe_layers_skipped;
    t.row(&["FE layers executed / skipped".into(),
        format!("{} / {} ({:.0}% skipped)", m.fe_layers_executed, m.fe_layers_skipped,
            100.0 * m.fe_layers_skipped as f64 / fe_total.max(1) as f64)]);
    t.row(&["branch HVs encoded".into(), m.branch_hvs_encoded.to_string()]);
    t.row(&["queries by exit depth (1..)".into(),
        format!("{:?}", &m.query_depth_hist[..model.n_branches().min(8)])]);
    if let Some(lm) = live_metrics {
        // the bank-gating story (Fig. 9): occupancy -> powered banks ->
        // standby mW the energy model says gating saved
        let em = EnergyModel::default();
        let banks = lm.class_mem_active_banks + lm.class_mem_gated_banks;
        let saved = em.class_mem_static_mw(lm.class_mem_gated_banks, 1.2, 250.0);
        t.row(&["class memory (while serving)".into(),
            format!("{} KB used, {}/{} banks gated (saves {:.1} mW standby)",
                lm.class_mem_used_bits / 8192, lm.class_mem_gated_banks, banks, saved)]);
    }
    t.row(&["total wall-clock".into(), format!("{wall:.1} s")]);
    t.print();

    // --- chip-simulator projection of the same workload (Table I row) ---
    let chip = Chip::paper(ChipConfig::default());
    let train = chip.train_episode(n_way, k_shot, true, true);
    let exit_stages: Vec<usize> = blocks.iter().map(|&b| b as usize - 1).collect();
    let infer = chip.infer_with_exit_distribution(32, &exit_stages);
    let mut t2 = Table::new(
        "simulated FSL-HDnn chip on this workload (ResNet-18 @224, 250 MHz, 1.2 V)",
        &["metric", "value"],
    );
    t2.row(&["training latency".into(), format!("{:.1} ms/image", train.latency_ms_per_image)]);
    t2.row(&["training energy".into(), format!("{:.2} mJ/image", train.energy_mj_per_image)]);
    t2.row(&["training throughput".into(),
        format!("{:.1} images/s", 1e3 / train.latency_ms_per_image)]);
    t2.row(&["inference latency (measured EE mix)".into(), format!("{:.2} ms", infer.latency_ms)]);
    t2.row(&["inference energy (measured EE mix)".into(), format!("{:.3} mJ", infer.energy_mj)]);
    // energy-per-query split by exit depth: each depth priced separately,
    // weighted by the coordinator's live exit histogram
    let depth_table = chip.infer_depth_table(n_way);
    for (s, r) in depth_table.iter().enumerate() {
        let count = m.query_depth_hist.get(s).copied().unwrap_or(0);
        if count > 0 {
            t2.row(&[format!("  @ exit block {} (x{count} queries)", s + 1),
                format!("{:.2} ms / {:.3} mJ each", r.latency_ms, r.energy_mj)]);
        }
    }
    t2.row(&["avg power".into(), format!("{:.0} mW", train.avg_power_mw)]);
    t2.print();
    Ok(())
}
