//! Serving load generator: N concurrent clients against the TCP gateway,
//! reporting p50/p99 query latency and aggregate QPS — the serving-path
//! counterpart of the kernel microbenches, written into the `serving`
//! section of `BENCH_hotpath.json` (EXPERIMENTS.md §Perf).
//!
//! Each client connects a [`WireClient`] to a loopback [`Gateway`], runs
//! one few-shot session (create → train → query stream) and times every
//! query round trip. A `Busy` response (admission-control shed) is
//! counted and retried after a short backoff, so the shed path shows up
//! in the report instead of failing the run. An in-process single-client
//! baseline row prices the wire + gateway overhead.
//!
//! Run with:  cargo run --release --example load_gen -- \
//!              [--clients N] [--queries N] [--workers N] [--high-water N]
//! `--smoke` (CI, `make bench-smoke`): 2 clients x 20 queries on the tiny
//! synthetic geometry, with sanity asserts on the recorded rows.
//! `--chaos` (CI, `make chaos`): kill a device mid-episode on a 2-device
//! router and record the caller-observed recovery latency (fault
//! detection + journal replay + retry) as a `chaos_recovery` row.

use std::time::{Duration, Instant};

use fsl_hdnn::config::{EeConfig, ModelConfig, ParallelConfig, ServingConfig};
use fsl_hdnn::coordinator::{Coordinator, Gateway, Response, WireClient};
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::runtime::engine::ComputeEngine;
use fsl_hdnn::util::args::{arg_flag, arg_usize};
use fsl_hdnn::util::bench_log::BenchLog;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::stats;

const N_WAY: usize = 3;
const K_SHOT: usize = 2;

/// One client's measured run: per-query latencies and sheds survived.
struct ClientRun {
    latencies_ms: Vec<f64>,
    sheds_seen: u64,
}

/// Issue one request through `call`, retrying `Busy` sheds with a short
/// backoff (counted into `sheds`) — exactly the client behaviour the
/// admission-control contract prescribes.
fn call_admitted<E: std::fmt::Debug>(
    call: &mut impl FnMut(fsl_hdnn::coordinator::Request) -> Result<Response, E>,
    sheds: &mut u64,
    req: fsl_hdnn::coordinator::Request,
) -> Response {
    loop {
        match call(req.clone()).expect("transport failed") {
            Response::Busy { .. } => {
                *sheds += 1;
                std::thread::sleep(Duration::from_millis(1));
            }
            other => return other,
        }
    }
}

/// Train one session and time `queries` query round trips through `call`.
/// Shared by the wire clients and the in-process baseline so both rows
/// measure exactly the same workload.
fn run_session<E: std::fmt::Debug>(
    queries: usize,
    seed: u64,
    image_size: usize,
    mut call: impl FnMut(fsl_hdnn::coordinator::Request) -> Result<Response, E>,
) -> ClientRun {
    let gen = ImageGen::new(image_size, 8, seed);
    let mut rng = Rng::new(seed);
    let mut sheds_seen = 0u64;
    let sid = match call_admitted(
        &mut call,
        &mut sheds_seen,
        fsl_hdnn::coordinator::Request::CreateSession {
            n_way: N_WAY,
            hv_bits: 16,
            metric: fsl_hdnn::hdc::Distance::L1,
            backend: fsl_hdnn::classifier::ClassifierBackend::Hdc,
        },
    ) {
        Response::SessionCreated { session } => session,
        other => panic!("create failed: {other:?}"),
    };
    for class in 0..N_WAY {
        for _ in 0..K_SHOT {
            let req = fsl_hdnn::coordinator::Request::AddShot {
                session: sid,
                class,
                image: gen.sample(class, &mut rng),
            };
            let resp = call_admitted(&mut call, &mut sheds_seen, req);
            assert!(matches!(resp, Response::ShotAccepted { .. }), "{resp:?}");
        }
    }
    let resp = call_admitted(
        &mut call,
        &mut sheds_seen,
        fsl_hdnn::coordinator::Request::FinishTraining { session: sid },
    );
    assert!(matches!(resp, Response::TrainingDone { .. }), "{resp:?}");

    let ee = Some(EeConfig { e_s: 1, e_c: 1 });
    let mut latencies_ms = Vec::with_capacity(queries);
    for q in 0..queries {
        let image = gen.sample(q % N_WAY, &mut rng);
        // time the successful attempt only: a shed-and-retry is backoff,
        // not service latency — it shows up in the shed count instead
        loop {
            let t0 = Instant::now();
            let req =
                fsl_hdnn::coordinator::Request::Query { session: sid, image: image.clone(), ee };
            match call(req).expect("transport failed") {
                Response::QueryResult { .. } => {
                    latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
                    break;
                }
                Response::Busy { .. } => {
                    sheds_seen += 1;
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => panic!("query failed: {other:?}"),
            }
        }
    }
    let resp = call_admitted(
        &mut call,
        &mut sheds_seen,
        fsl_hdnn::coordinator::Request::CloseSession { session: sid },
    );
    assert!(matches!(resp, Response::SessionClosed { .. }), "{resp:?}");
    ClientRun { latencies_ms, sheds_seen }
}

/// `--chaos`: the recovery-latency drill. A 10-way 5-shot episode on a
/// 2-device router; `device.train=panic-once` kills the hosting device's
/// worker mid-training, and the training call that rides through fault
/// detection + shot-journal replay + retry is timed as the
/// caller-observed recovery latency (EXPERIMENTS.md §Perf, `serving`
/// section).
fn run_chaos() -> anyhow::Result<()> {
    use fsl_hdnn::classifier::ClassifierBackend;
    use fsl_hdnn::coordinator::{DeviceHealth, DeviceRouter, Placement};
    use fsl_hdnn::util::failpoint;

    let (n_way, k_shot) = (10usize, 5usize);
    let kill_at = 6usize; // classes already journaled when the device dies
    let cfg = ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4, 8],
        blocks_per_stage: 1,
        feature_dim: 8,
        d: 64,
        ch_sub: 4,
        n_centroids: 8,
        ..Default::default()
    };
    let image_size = cfg.image_size;
    let par = ParallelConfig { workers: 2, min_batch_per_worker: 1 };
    let mut router = DeviceRouter::start(2, k_shot, Placement::LeastLoaded, move |_i| {
        let c = cfg.clone();
        move || Ok(ComputeEngine::from_config(c).with_parallelism(par))
    })?;
    println!("load_gen --chaos: 2 devices, {n_way}-way {k_shot}-shot, kill at class {kill_at}");

    let gen = ImageGen::new(image_size, 16, 42);
    let mut rng = Rng::new(42);
    let sid = router.create_session_full(n_way, 16, fsl_hdnn::hdc::Distance::L1,
        ClassifierBackend::Hdc)?;
    let batch = |class: usize, rng: &mut Rng| -> Vec<Vec<f32>> {
        (0..k_shot).map(|_| gen.sample(class, rng)).collect()
    };
    for class in 0..kill_at {
        router.add_shot_batch(sid, class, batch(class, &mut rng))?;
    }

    // the next training request panics the hosting device's worker; the
    // timed call covers detection, re-placement (journal replay of the
    // classes above) and the retry that finally lands
    failpoint::arm_spec("device.train=panic-once")?;
    let t0 = Instant::now();
    router.add_shot_batch(sid, kill_at, batch(kill_at, &mut rng))?;
    let recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
    failpoint::disarm_all();

    for class in kill_at + 1..n_way {
        router.add_shot_batch(sid, class, batch(class, &mut rng))?;
    }
    assert_eq!(router.finish_training(sid)?, n_way * k_shot);
    for i in 0..20 {
        router.query(sid, gen.sample(i % n_way, &mut rng), None)?;
    }

    let m = router.metrics();
    assert_eq!(m.device_failures, 1, "exactly one device died");
    assert_eq!(m.sessions_replaced, 1, "the session was re-placed once");
    let dead = (0..router.n_devices())
        .filter(|&d| router.health(d) == DeviceHealth::Dead)
        .count();
    assert_eq!(dead, 1, "one Dead device after the drill");
    println!(
        "chaos   : recovery {recovery_ms:.3} ms (journal retrain {:.3} ms) \
         | {} session re-placed | {} device failure",
        m.retrain_ms, m.sessions_replaced, m.device_failures
    );

    let mut log = BenchLog::new("serving");
    log.record_values(
        "chaos_recovery",
        &[
            ("recovery_ms", recovery_ms),
            ("retrain_ms", m.retrain_ms),
            ("shots_replayed", (kill_at * k_shot) as f64),
            ("sessions_replaced", m.sessions_replaced as f64),
            ("device_failures", m.device_failures as f64),
        ],
    );
    let path = log.write()?;
    println!("wrote serving section -> {}", path.display());
    println!("chaos OK");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    if arg_flag("--chaos") {
        return run_chaos();
    }
    let smoke = arg_flag("--smoke");
    let clients = arg_usize("--clients", if smoke { 2 } else { 4 });
    let queries = arg_usize("--queries", if smoke { 20 } else { 200 });
    let workers = arg_usize("--workers", 0); // 0 = one per core
    let high_water = arg_usize("--high-water", ServingConfig::default().high_water);

    // smoke runs the tiny synthetic geometry so CI stays fast; the full
    // run uses the default model (synthetic weights without artifacts)
    let cfg = if smoke {
        ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8],
            blocks_per_stage: 1,
            feature_dim: 8,
            d: 64,
            ch_sub: 4,
            n_centroids: 8,
            ..Default::default()
        }
    } else {
        ModelConfig::default()
    };
    let image_size = cfg.image_size;
    let par = ParallelConfig { workers, min_batch_per_worker: 1 };
    let coord = Coordinator::start(
        move || Ok(ComputeEngine::from_config(cfg).with_parallelism(par)),
        K_SHOT,
    )?;
    let serving = ServingConfig { high_water, ..Default::default() };
    let gateway = Gateway::bind(coord.client(), &serving)?;
    let addr = gateway.local_addr();
    println!(
        "load_gen: {clients} clients x {queries} queries via {addr} \
         (workers={}, high_water={high_water}{})",
        par.resolved_workers(),
        if smoke { ", smoke" } else { "" }
    );

    // --- concurrent wire clients ---------------------------------------
    // scoped join instead of raw spawns (fsl_lint raw-spawn): every client
    // provably finishes inside this block, so a panicking client surfaces
    // here instead of leaving a detached thread behind the summary lines
    let t0 = Instant::now();
    let runs: Vec<ClientRun> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                s.spawn(move || {
                    let mut wc = WireClient::connect(addr).expect("connect");
                    run_session(queries, 7000 + c as u64, image_size, |req| wc.call(&req))
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut all_ms: Vec<f64> = runs.iter().flat_map(|r| r.latencies_ms.iter().copied()).collect();
    let sheds_seen: u64 = runs.iter().map(|r| r.sheds_seen).sum();
    all_ms.sort_by(f64::total_cmp);
    let total_queries = (clients * queries) as f64;
    let qps = total_queries / wall_s;
    let (p50, p99) = (stats::percentile(&all_ms, 50.0), stats::percentile(&all_ms, 99.0));
    let shed_metric = coord.metrics().requests_shed;
    println!(
        "gateway : p50 {p50:.3} ms | p99 {p99:.3} ms | mean {:.3} ms | {qps:.0} qps \
         | shed {shed_metric}",
        stats::mean(&all_ms)
    );

    // --- in-process baseline (same workload, one client, no wire) ------
    let t1 = Instant::now();
    let base = run_session(queries, 7000, image_size, |req| {
        Ok::<Response, std::convert::Infallible>(coord.call(req))
    });
    let base_wall_s = t1.elapsed().as_secs_f64();
    let mut base_ms = base.latencies_ms.clone();
    base_ms.sort_by(f64::total_cmp);
    let base_p50 = stats::percentile(&base_ms, 50.0);
    let base_p99 = stats::percentile(&base_ms, 99.0);
    println!(
        "in-proc : p50 {base_p50:.3} ms | p99 {base_p99:.3} ms | mean {:.3} ms | {:.0} qps",
        stats::mean(&base_ms),
        queries as f64 / base_wall_s
    );

    let mut log = BenchLog::new("serving");
    log.record_values(
        "gateway_query_latency",
        &[
            ("p50_ms", p50),
            ("p99_ms", p99),
            ("mean_ms", stats::mean(&all_ms)),
            ("qps", qps),
            ("clients", clients as f64),
            ("workers", par.resolved_workers() as f64),
            ("requests_shed", shed_metric as f64),
        ],
    );
    log.record_values(
        "inproc_query_latency",
        &[
            ("p50_ms", base_p50),
            ("p99_ms", base_p99),
            ("mean_ms", stats::mean(&base_ms)),
            ("qps", queries as f64 / base_wall_s),
            ("clients", 1.0),
            ("workers", par.resolved_workers() as f64),
        ],
    );
    let path = log.write()?;
    println!("wrote serving section -> {}", path.display());

    if smoke {
        // CI sanity: every query answered, latencies sane, and the shed
        // counter consistent with what the clients saw
        assert_eq!(all_ms.len(), clients * queries, "every query must be answered");
        assert!(p50 > 0.0 && p99 >= p50, "percentiles must be ordered: {p50} / {p99}");
        assert!(base_p50 > 0.0, "baseline must measure real work");
        assert_eq!(shed_metric, sheds_seen, "gateway sheds == Busy responses clients saw");
        println!("smoke OK");
    }
    Ok(())
}
