//! Chip-simulator tour: voltage/frequency sweep, batching effect, early
//! exit effect, and energy breakdown on the paper's ResNet-18 @224
//! workload — the quickest way to see the Table I / Figs. 14-18 numbers.
//!
//! Run with:  cargo run --release --example chip_sim

use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::sim::{Chip, EnergyModel};
use fsl_hdnn::util::table::Table;

fn main() {
    let energy = EnergyModel::default();

    // --- V/f sweep (Fig. 14b) ---
    let mut t = Table::new(
        "voltage/frequency sweep — 10-way 5-shot batched training (Fig. 14b)",
        &["V", "MHz", "ms/image", "mJ/image", "avg mW", "TOPS/W"],
    );
    for &v in &[0.9, 1.0, 1.1, 1.2] {
        let f = energy.freq_at_voltage(v);
        let chip = Chip::paper(ChipConfig { voltage: v, freq_mhz: f, ..Default::default() });
        let r = chip.train_episode(10, 5, true, false);
        t.row(&[
            format!("{v:.1}"),
            format!("{f:.0}"),
            format!("{:.1}", r.latency_ms_per_image),
            format!("{:.2}", r.energy_mj_per_image),
            format!("{:.0}", r.avg_power_mw),
            format!("{:.2}", chip.tops_per_watt(&r)),
        ]);
    }
    t.print();

    // --- batching (Fig. 16) ---
    let mut t = Table::new(
        "batched single-pass training effect (Fig. 16)",
        &["MHz", "no batch ms/img", "batched ms/img", "latency saving", "energy saving"],
    );
    for &f in &[100.0, 150.0, 200.0, 250.0] {
        let v = 0.9 + (f - 100.0) / 150.0 * 0.3;
        let chip = Chip::paper(ChipConfig { voltage: v, freq_mhz: f, ..Default::default() });
        let nb = chip.train_episode(10, 5, false, false);
        let b = chip.train_episode(10, 5, true, false);
        t.row(&[
            format!("{f:.0}"),
            format!("{:.1}", nb.latency_ms_per_image),
            format!("{:.1}", b.latency_ms_per_image),
            format!("{:.0}%", 100.0 * (1.0 - b.latency_ms_per_image / nb.latency_ms_per_image)),
            format!("{:.0}%", 100.0 * (1.0 - b.energy_mj_per_image / nb.energy_mj_per_image)),
        ]);
    }
    t.print();

    // --- early exit (Fig. 18's effect) ---
    let chip = Chip::paper(ChipConfig::default());
    let mut t = Table::new(
        "inference vs exit depth (10 classes, Fig. 18's mechanism)",
        &["exit after block", "ms/image", "mJ/image", "conv layers"],
    );
    for s in 0..4 {
        let r = chip.infer_image(10, Some(s));
        t.row(&[
            (s + 1).to_string(),
            format!("{:.2}", r.latency_ms),
            format!("{:.3}", r.energy_mj),
            format!("{}/{}", r.conv_layers_run, r.conv_layers_total),
        ]);
    }
    t.print();

    // --- where the cycles go ---
    let r_nb = chip.train_episode(10, 5, false, false);
    let r_b = chip.train_episode(10, 5, true, false);
    let mut t =
        Table::new("cycle accounting, 50-image training", &["mode", "total Mcycles", "PE util"]);
    t.row(&[
        "non-batched".into(),
        format!("{:.1}", r_nb.cycles as f64 / 1e6),
        format!("{:.0}%", 100.0 * r_nb.pe_utilization),
    ]);
    t.row(&[
        "batched".into(),
        format!("{:.1}", r_b.cycles as f64 / 1e6),
        format!("{:.0}%", 100.0 * r_b.pe_utilization),
    ]);
    t.print();
}
