//! Coordinator integration: full session lifecycle over the native
//! backend, error paths, metrics accounting and early-exit behaviour.
//!
//! Skipped (with a distinct `SKIPPED` line, see tests/common/mod.rs) when
//! `make artifacts` has not run: the learning-quality assertions here are
//! calibrated against the AOT-exported weights, not the synthetic FE.

mod common;

use fsl_hdnn::config::{EeConfig, ModelConfig, ParallelConfig};
use fsl_hdnn::coordinator::{Coordinator, Request, Response};
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::util::prng::Rng;

fn start_native(test: &str) -> Option<Coordinator> {
    let dir = common::artifacts_or_skip(test)?;
    Some(Coordinator::start(move || ComputeEngine::open(Backend::Native, &dir), 3).unwrap())
}

/// Artifact-free coordinator on the synthetic native engine — these tests
/// run from a clean checkout (no `SKIPPED`).
fn start_synthetic(k_shot: usize, par: ParallelConfig) -> Coordinator {
    start_synthetic_cfg(k_shot, par, false)
}

/// The tiny synthetic geometry the artifact-free tests run on (2 branches;
/// plan: stem + s0b0's 2 convs + s1b0's 2 convs + projection = 6 layers).
fn synthetic_cfg(clustered: bool) -> ModelConfig {
    ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4, 8],
        blocks_per_stage: 1,
        feature_dim: 8,
        d: 64,
        ch_sub: 4,
        n_centroids: 8,
        clustered,
        ..Default::default()
    }
}

fn start_synthetic_cfg(k_shot: usize, par: ParallelConfig, clustered: bool) -> Coordinator {
    let cfg = synthetic_cfg(clustered);
    Coordinator::start(
        move || Ok(ComputeEngine::from_config(cfg).with_parallelism(par)),
        k_shot,
    )
    .unwrap()
}

fn model_geometry() -> (usize, usize) {
    let dir = common::artifacts_dir().expect("caller already checked artifacts presence");
    let m = ComputeEngine::open(Backend::Native, &dir).unwrap().model().clone();
    (m.image_size, m.in_channels)
}

#[test]
fn session_lifecycle_and_learning() {
    let Some(coord) = start_native("session_lifecycle_and_learning") else { return };
    let (size, chans) = model_geometry();
    let gen = ImageGen::new(size, 8, 5);
    let mut rng = Rng::new(5);
    let sid = coord.create_session(3, 16).unwrap();
    // 3 classes x 3 shots; batcher trains each class when it reaches k=3
    for class in 0..3 {
        for _ in 0..3 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    let shots = coord.finish_training(sid).unwrap();
    assert_eq!(shots, 9);
    let mut correct = 0;
    let total = 12;
    for i in 0..total {
        let class = i % 3;
        let out = coord.query(sid, gen.sample(class, &mut rng), None).unwrap();
        correct += (out.prediction == class) as usize;
        assert_eq!(out.blocks_used, 4);
        assert!(!out.exited_early);
    }
    assert!(correct * 2 > total, "learned sessions must beat chance: {correct}/{total}");
    let _ = chans;
    match coord.call(Request::CloseSession { session: sid }) {
        Response::SessionClosed { session } => assert_eq!(session, sid),
        other => panic!("unexpected {other:?}"),
    }
    // closed session rejects further work
    assert!(coord.query(sid, gen.sample(0, &mut rng), None).is_err());
}

#[test]
fn error_paths_reported_not_panicked() {
    let Some(coord) = start_native("error_paths_reported_not_panicked") else { return };
    let (size, _) = model_geometry();
    // unknown session
    assert!(coord.add_shot(999, 0, vec![0.0; size * size * 3]).is_err());
    assert!(coord.finish_training(999).is_err());
    // class out of range
    let sid = coord.create_session(2, 16).unwrap();
    assert!(coord.add_shot(sid, 7, vec![0.0; size * size * 3]).is_err());
    // wrong image size surfaces as an error when the batch flushes
    coord.add_shot(sid, 0, vec![0.0; 3]).unwrap(); // accepted into batcher...
    let r = coord.finish_training(sid);
    assert!(r.is_err(), "bad image must fail at FE time: {r:?}");
    // coordinator still alive afterwards
    let m = coord.metrics();
    assert!(m.errors >= 3, "errors must be counted: {m:?}");
}

#[test]
fn early_exit_uses_fewer_blocks_on_confident_queries() {
    let Some(coord) = start_native("early_exit_uses_fewer_blocks_on_confident_queries") else {
        return;
    };
    let (size, _) = model_geometry();
    let gen = ImageGen::new(size, 8, 11);
    let mut rng = Rng::new(11);
    let sid = coord.create_session(2, 16).unwrap();
    for class in 0..2 {
        for _ in 0..3 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    let ee = EeConfig { e_s: 1, e_c: 2 };
    let mut total_blocks = 0;
    let n = 10;
    for i in 0..n {
        let out = coord.query(sid, gen.sample(i % 2, &mut rng), Some(ee)).unwrap();
        total_blocks += out.blocks_used;
        assert!(out.blocks_used >= 2, "(1,2) needs at least 2 blocks");
    }
    assert!(
        total_blocks < n * 4,
        "some queries must exit early: {total_blocks} blocks for {n} queries"
    );
    let m = coord.metrics();
    assert!(m.early_exit_rate > 0.0);
    assert!(m.avg_blocks_used >= 2.0 && m.avg_blocks_used <= 4.0);
}

#[test]
fn metrics_count_operations() {
    let Some(coord) = start_native("metrics_count_operations") else { return };
    let (size, _) = model_geometry();
    let gen = ImageGen::new(size, 4, 13);
    let mut rng = Rng::new(13);
    let sid = coord.create_session(2, 16).unwrap();
    for class in 0..2 {
        for _ in 0..3 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    coord.query(sid, gen.sample(0, &mut rng), None).unwrap();
    coord.query(sid, gen.sample(1, &mut rng), None).unwrap();
    let m = coord.metrics();
    assert_eq!(m.shots, 6);
    assert_eq!(m.trains, 1);
    assert_eq!(m.queries, 2);
    assert!(m.query_ms_mean > 0.0);
}

#[test]
fn concurrent_sessions_are_isolated() {
    let Some(coord) = start_native("concurrent_sessions_are_isolated") else { return };
    let (size, _) = model_geometry();
    let gen = ImageGen::new(size, 8, 17);
    let mut rng = Rng::new(17);
    let s1 = coord.create_session(2, 16).unwrap();
    let s2 = coord.create_session(3, 16).unwrap();
    assert_ne!(s1, s2);
    // interleave shots of both sessions
    for i in 0..3 {
        coord.add_shot(s1, 0, gen.sample(0, &mut rng)).unwrap();
        coord.add_shot(s2, i % 3, gen.sample(4 + (i % 3), &mut rng)).unwrap();
        coord.add_shot(s1, 1, gen.sample(1, &mut rng)).unwrap();
    }
    coord.add_shot(s2, 1, gen.sample(5, &mut rng)).unwrap();
    coord.add_shot(s2, 2, gen.sample(6, &mut rng)).unwrap();
    let n1 = coord.finish_training(s1).unwrap();
    let n2 = coord.finish_training(s2).unwrap();
    assert_eq!(n1, 6);
    assert_eq!(n2, 5);
    // each session answers in its own label space
    let o1 = coord.query(s1, gen.sample(0, &mut rng), None).unwrap();
    assert!(o1.prediction < 2);
    let o2 = coord.query(s2, gen.sample(5, &mut rng), None).unwrap();
    assert!(o2.prediction < 3);
}

#[test]
fn router_places_and_isolates_sessions() {
    use fsl_hdnn::coordinator::{DeviceRouter, Placement};
    let Some(dir) = common::artifacts_or_skip("router_places_and_isolates_sessions") else {
        return;
    };
    let (size, _) = model_geometry();
    let mut router = DeviceRouter::start(2, 2, Placement::LeastLoaded, move |_i| {
        let d = dir.clone();
        move || ComputeEngine::open(Backend::Native, &d)
    })
    .unwrap();
    let gen = ImageGen::new(size, 8, 19);
    let mut rng = Rng::new(19);
    // four sessions -> least-loaded should balance 2/2
    let sids: Vec<u64> = (0..4).map(|_| router.create_session(2, 4).unwrap()).collect();
    assert_eq!(router.loads(), &[2, 2], "least-loaded must balance");
    // train + query one session on each device
    for &sid in &sids[..2] {
        for class in 0..2 {
            for _ in 0..2 {
                router.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
            }
        }
        assert_eq!(router.finish_training(sid).unwrap(), 4);
        let out = router.query(sid, gen.sample(0, &mut rng), None).unwrap();
        assert!(out.prediction < 2);
    }
    // closing rebalances
    router.close_session(sids[0]).unwrap();
    assert_eq!(router.loads().iter().sum::<usize>(), 3);
    assert!(router.query(sids[0], gen.sample(0, &mut rng), None).is_err());
    // global ids are unique even across devices
    let mut uniq = sids.clone();
    uniq.sort_unstable();
    uniq.dedup();
    assert_eq!(uniq.len(), 4);
}

#[test]
fn router_spills_to_other_device_when_full() {
    use fsl_hdnn::coordinator::{DeviceRouter, Placement};
    let Some(dir) = common::artifacts_or_skip("router_spills_to_other_device_when_full") else {
        return;
    };
    let mut router = DeviceRouter::start(2, 2, Placement::RoundRobin, move |_i| {
        let d = dir.clone();
        move || ComputeEngine::open(Backend::Native, &d)
    })
    .unwrap();
    // 32-way @ 4-bit x 4 branches fills one device's 256 KB class memory
    let a = router.create_session(32, 4).unwrap();
    let b = router.create_session(32, 4).unwrap();
    let pa = router.placement(a).unwrap();
    let pb = router.placement(b).unwrap();
    assert_ne!(pa.device, pb.device, "second big session must spill");
    // a third cannot fit anywhere
    assert!(router.create_session(32, 4).is_err(), "fleet-wide backpressure");
}

#[test]
fn class_batches_route_through_batched_training() {
    // ClassBatcher -> batched-train integration: the same shots arriving
    // per-shot (serial engine) and as class batches (worker-sharded
    // engine) must produce identical trained sessions — queries agree
    // bit-for-bit because the parallel path is bit-identical to serial.
    let serial = start_synthetic(3, ParallelConfig::default());
    let batched = start_synthetic(3, ParallelConfig { workers: 7, min_batch_per_worker: 1 });
    let n_way = 3;
    let mk_shots = |class: usize| -> Vec<Vec<f32>> {
        let gen = ImageGen::new(8, 8, 29);
        let mut rng = Rng::new(100 + class as u64);
        (0..3).map(|_| gen.sample(class, &mut rng)).collect()
    };
    let s1 = serial.create_session(n_way, 16).unwrap();
    let s2 = batched.create_session(n_way, 16).unwrap();
    for class in 0..n_way {
        for img in mk_shots(class) {
            serial.add_shot(s1, class, img).unwrap();
        }
        // whole class batch in one request: k reached -> trains immediately
        batched.add_shot_batch(s2, class, mk_shots(class)).unwrap();
    }
    assert_eq!(serial.finish_training(s1).unwrap(), 9);
    assert_eq!(batched.finish_training(s2).unwrap(), 9);
    // both coordinators saw 9 shots; the batch path used 3 requests
    assert_eq!(serial.metrics().shots, 9);
    assert_eq!(batched.metrics().shots, 9);
    let gen = ImageGen::new(8, 8, 29);
    let mut rng = Rng::new(777);
    for i in 0..9 {
        let img = gen.sample(i % n_way, &mut rng);
        let a = serial.query(s1, img.clone(), None).unwrap();
        let b = batched.query(s2, img, None).unwrap();
        assert_eq!(a.prediction, b.prediction, "query {i}: batched/parallel must match serial");
    }
}

#[test]
fn clustered_engine_serves_sessions_end_to_end() {
    // the packed weight-clustered FE through the full coordinator path:
    // serial and worker-sharded clustered engines must answer identically
    // (clustering is deterministic, sharding is bit-identical), and the
    // quantized FE must still learn class structure above chance
    let serial = start_synthetic_cfg(3, ParallelConfig::default(), true);
    let sharded =
        start_synthetic_cfg(3, ParallelConfig { workers: 5, min_batch_per_worker: 1 }, true);
    let n_way = 3;
    let s1 = serial.create_session(n_way, 16).unwrap();
    let s2 = sharded.create_session(n_way, 16).unwrap();
    let mk_shots = |class: usize| -> Vec<Vec<f32>> {
        let gen = ImageGen::new(8, 8, 43);
        let mut rng = Rng::new(200 + class as u64);
        (0..3).map(|_| gen.sample(class, &mut rng)).collect()
    };
    for class in 0..n_way {
        for img in mk_shots(class) {
            serial.add_shot(s1, class, img).unwrap();
        }
        sharded.add_shot_batch(s2, class, mk_shots(class)).unwrap();
    }
    assert_eq!(serial.finish_training(s1).unwrap(), 9);
    assert_eq!(sharded.finish_training(s2).unwrap(), 9);
    let gen = ImageGen::new(8, 8, 43);
    let mut rng = Rng::new(888);
    let mut correct = 0;
    let total = 12;
    for i in 0..total {
        let class = i % n_way;
        let img = gen.sample(class, &mut rng);
        let a = serial.query(s1, img.clone(), None).unwrap();
        let b = sharded.query(s2, img, None).unwrap();
        assert_eq!(a.prediction, b.prediction, "query {i}: sharded clustered must match serial");
        correct += (a.prediction == class) as usize;
    }
    assert!(correct * n_way > total, "clustered FE must beat chance: {correct}/{total}");
}

#[test]
fn paper_capacity_128way_4bit_and_129_rejected() {
    // ISSUE 4 acceptance: the paper's capacity table (Section IV-B3) at
    // D=4096, single branch — 128-way @ 4-bit fills the 256 KB class
    // memory exactly; 129-way is rejected through ClassMemoryManager
    let cfg = ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4],
        blocks_per_stage: 1,
        feature_dim: 4,
        d: 4096,
        ..Default::default()
    };
    let coord = {
        let c = cfg.clone();
        Coordinator::start(move || Ok(ComputeEngine::from_config(c)), 1).unwrap()
    };
    let sid = coord.create_session(128, 4).unwrap();
    // the memory is now exactly full: nothing more fits at any precision
    let err = coord.create_session(1, 1).unwrap_err().to_string();
    assert!(err.contains("exhausted"), "{err}");
    let m = coord.metrics();
    assert_eq!(m.class_mem_used_bits, 128 * 4096 * 4, "128-way @ 4-bit is an exact fit");
    assert_eq!(m.class_mem_gated_banks, 0, "a full memory powers every bank");
    coord.call(Request::CloseSession { session: sid });
    // one class over capacity never fits, even on an empty device
    let err = coord.create_session(129, 4).unwrap_err().to_string();
    assert!(err.contains("exhausted"), "129-way @ 4-bit must be rejected: {err}");
    // the 16-bit boundary from the same table: 32 fits, 33 does not
    let sid = coord.create_session(32, 16).unwrap();
    assert!(coord.create_session(1, 16).is_err());
    coord.call(Request::CloseSession { session: sid });
    assert!(coord.create_session(33, 16).is_err());
    // and after the exact-fit session is gone, bank gating resumes
    let _small = coord.create_session(2, 4).unwrap();
    let m = coord.metrics();
    assert!(m.class_mem_gated_banks > 0, "a near-empty memory gates banks: {m:?}");
}

#[test]
fn hamming_metric_sessions_serve_queries() {
    // the packed 1-bit popcount path end to end through the coordinator
    // (D=256 keeps the binarized code distance well above sampling noise)
    let cfg = ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4, 8],
        blocks_per_stage: 1,
        feature_dim: 8,
        d: 256,
        ..Default::default()
    };
    let coord = {
        let c = cfg.clone();
        Coordinator::start(move || Ok(ComputeEngine::from_config(c)), 3).unwrap()
    };
    let sid = coord.create_session_with(2, 1, fsl_hdnn::hdc::Distance::Hamming).unwrap();
    let gen = ImageGen::new(8, 8, 53);
    let mut rng = Rng::new(53);
    for class in 0..2 {
        for _ in 0..3 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    let mut correct = 0;
    let total = 12;
    for i in 0..total {
        let class = i % 2;
        let out = coord.query(sid, gen.sample(class, &mut rng), None).unwrap();
        correct += (out.prediction == class) as usize;
    }
    assert!(correct * 2 > total, "binary hamming session must beat chance: {correct}/{total}");
}

#[test]
fn oversized_class_batch_flushes_in_k_shot_groups() {
    // 7 shots at k=3: two full batches train through the batched FE path,
    // one shot stays pending until FinishTraining flushes it
    let coord = start_synthetic(3, ParallelConfig { workers: 2, min_batch_per_worker: 1 });
    let sid = coord.create_session(2, 16).unwrap();
    let gen = ImageGen::new(8, 8, 31);
    let mut rng = Rng::new(31);
    let shots: Vec<Vec<f32>> = (0..7).map(|_| gen.sample(0, &mut rng)).collect();
    coord.add_shot_batch(sid, 0, shots).unwrap();
    match coord.call(Request::GetMetrics) {
        Response::Metrics(m) => assert_eq!(m.shots, 7),
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(coord.finish_training(sid).unwrap(), 7);
}

#[test]
fn out_of_range_hv_bits_rejected_not_panicked() {
    let coord = start_synthetic(3, ParallelConfig::default());
    for bits in [0u32, 17, 64] {
        let err = coord.create_session(2, bits).unwrap_err().to_string();
        assert!(err.contains("1..=16"), "bits={bits}: {err}");
    }
    // the worker survived and still serves valid requests
    assert!(coord.create_session(2, 16).is_ok());
}

#[test]
fn batch_error_paths_reported_not_panicked() {
    let coord = start_synthetic(3, ParallelConfig::default());
    // unknown session
    assert!(coord.add_shot_batch(999, 0, vec![vec![0.0; 8 * 8 * 3]]).is_err());
    // class out of range
    let sid = coord.create_session(2, 16).unwrap();
    assert!(coord.add_shot_batch(sid, 5, vec![vec![0.0; 8 * 8 * 3]]).is_err());
    // wrong image size fails when the k-shot group flushes to the FE
    let r = coord.add_shot_batch(sid, 0, vec![vec![0.0; 5]; 3]);
    assert!(r.is_err(), "bad image must fail at FE time: {r:?}");
    // coordinator still alive
    assert!(coord.metrics().errors >= 3);
}

#[test]
fn empty_feature_rejected_short_feature_pad_counted() {
    // regression: an empty feature used to zero-pad into a valid all-zero
    // HV and silently train a garbage class prototype
    let coord = start_synthetic(3, ParallelConfig::default());
    let sid = coord.create_session(2, 16).unwrap();
    let empty_train =
        coord.call(Request::AddFeatureShot { session: sid, class: 0, feature: vec![] });
    assert!(matches!(empty_train, Response::Error(_)), "empty feature must be rejected");
    let empty_query = coord.call(Request::QueryFeature { session: sid, feature: vec![] });
    assert!(matches!(empty_query, Response::Error(_)));
    let m = coord.metrics();
    assert!(m.errors >= 2);
    assert_eq!(m.feature_pads, 0, "rejections are not pads");
    // short (but non-empty) features still work, with the pad counted
    let short =
        coord.call(Request::AddFeatureShot { session: sid, class: 0, feature: vec![0.5; 4] });
    assert!(matches!(short, Response::ShotAccepted { .. }));
    assert_eq!(coord.metrics().feature_pads, 1);
    // exact-length features never count as pads (feature_dim = 8 here)
    let exact =
        coord.call(Request::AddFeatureShot { session: sid, class: 0, feature: vec![0.5; 8] });
    assert!(matches!(exact, Response::ShotAccepted { .. }));
    assert_eq!(coord.metrics().feature_pads, 1);
}

#[test]
fn router_routes_class_batches() {
    use fsl_hdnn::coordinator::{DeviceRouter, Placement};
    // artifact-free: synthetic engines on both devices
    let cfg = ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4, 8],
        blocks_per_stage: 1,
        feature_dim: 8,
        d: 64,
        ..Default::default()
    };
    let par = ParallelConfig { workers: 2, min_batch_per_worker: 1 };
    let mut router = DeviceRouter::start(2, 2, Placement::RoundRobin, move |_i| {
        let c = cfg.clone();
        move || Ok(ComputeEngine::from_config(c).with_parallelism(par))
    })
    .unwrap();
    let gen = ImageGen::new(8, 8, 37);
    let mut rng = Rng::new(37);
    let sid = router.create_session(2, 16).unwrap();
    for class in 0..2 {
        let shots: Vec<Vec<f32>> = (0..2).map(|_| gen.sample(class, &mut rng)).collect();
        router.add_shot_batch(sid, class, shots).unwrap();
    }
    assert_eq!(router.finish_training(sid).unwrap(), 4);
    let out = router.query(sid, gen.sample(0, &mut rng), None).unwrap();
    assert!(out.prediction < 2);
    assert!(router.add_shot_batch(999, 0, vec![]).is_err(), "unknown routed session");
}

#[test]
fn early_exit_truncates_fe_compute_provably() {
    // the ISSUE 5 acceptance: an EE query that exits at block b executes
    // only stages 0..=b and encodes only b+1 branch HVs — asserted via
    // the layer-execution counters, not by timing
    let probe = ComputeEngine::from_config(synthetic_cfg(false));
    let plan = probe.fe_plan_layers() as u64;
    let coord = start_synthetic(3, ParallelConfig::default());
    let gen = ImageGen::new(8, 8, 71);
    let mut rng = Rng::new(71);
    let sid = coord.create_session(2, 16).unwrap();
    for class in 0..2 {
        for _ in 0..3 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    let m0 = coord.metrics();
    assert_eq!(
        (m0.fe_layers_executed, m0.fe_layers_skipped, m0.branch_hvs_encoded),
        (0, 0, 0),
        "training never touches the query work counters"
    );
    // (1,1) exits deterministically at block 1: only stage 0 ever runs
    let out = coord.query(sid, gen.sample(0, &mut rng), Some(EeConfig { e_s: 1, e_c: 1 })).unwrap();
    assert_eq!(out.blocks_used, 1);
    assert!(out.exited_early);
    let m1 = coord.metrics();
    assert_eq!(m1.fe_layers_executed, probe.fe_layers_through(1) as u64);
    assert_eq!(m1.fe_layers_skipped, plan - probe.fe_layers_through(1) as u64);
    assert_eq!(m1.branch_hvs_encoded, 1, "exit at block 1 encodes exactly 1 HV");
    // a no-EE query runs the whole plan but encodes only the final branch
    // (the other branch HVs used to be 3 wasted cRP encodes per query)
    let out = coord.query(sid, gen.sample(1, &mut rng), None).unwrap();
    assert_eq!(out.blocks_used, 2);
    let m2 = coord.metrics();
    assert_eq!(m2.fe_layers_executed - m1.fe_layers_executed, plan);
    assert_eq!(m2.fe_layers_skipped, m1.fe_layers_skipped, "a full pass skips nothing");
    assert_eq!(m2.branch_hvs_encoded - m1.branch_hvs_encoded, 1);
    // the per-exit-depth histogram recorded one query at each depth
    assert_eq!(m2.query_depth_hist[0], 1);
    assert_eq!(m2.query_depth_hist[1], 1);
    assert_eq!(m2.query_depth_hist[2..].iter().sum::<u64>(), 0);
}

#[test]
fn staged_query_bit_identical_to_posthoc_reference() {
    // the refactor's central contract: interleaving FE stages with the
    // controller changes the work done, never the answer. A local session
    // trained on the same deterministic engine replays the pre-refactor
    // post-hoc path (all HVs extracted, then query_early_exit).
    use fsl_hdnn::coordinator::FslSession;
    let cfg = synthetic_cfg(false);
    let engine = ComputeEngine::from_config(cfg.clone());
    let coord = start_synthetic(3, ParallelConfig::default());
    let sid = coord.create_session(3, 16).unwrap();
    let mut local = FslSession::new(0, 3, engine.model().d, engine.model().n_branches())
        .with_precision(16)
        .with_metric(fsl_hdnn::hdc::Distance::L1);
    let gen = ImageGen::new(8, 8, 83);
    let mut rng = Rng::new(83);
    for class in 0..3 {
        for _ in 0..3 {
            let img = gen.sample(class, &mut rng);
            coord.add_shot(sid, class, img.clone()).unwrap();
            let feats = engine.fe_forward(&[img]).unwrap().remove(0);
            let hvs = engine.encode(&feats).unwrap();
            local.train_shot(class, &hvs);
        }
    }
    coord.finish_training(sid).unwrap();
    for q in 0..6 {
        let img = gen.sample(q % 3, &mut rng);
        let feats = engine.fe_forward(&[img.clone()]).unwrap().remove(0);
        let hvs = engine.encode(&feats).unwrap();
        for ee in [None, Some(EeConfig { e_s: 1, e_c: 1 }), Some(EeConfig { e_s: 1, e_c: 2 })] {
            let want = match ee {
                Some(c) => local.query_early_exit(&hvs, c),
                None => local.query_full(hvs.last().unwrap()),
            };
            let got = coord.query(sid, img.clone(), ee).unwrap();
            assert_eq!(got, want, "q={q} ee={ee:?}: staged != post-hoc");
        }
    }
}

#[test]
fn query_batch_bit_identical_to_serial_across_worker_counts() {
    // ragged survivor batching (the batch shrinks stage by stage as
    // images exit) must answer exactly like serial queries, at any worker
    // count — the established determinism contract, now for inference
    let n_way = 3;
    let mk_shots = |class: usize| -> Vec<Vec<f32>> {
        let gen = ImageGen::new(8, 8, 61);
        let mut rng = Rng::new(300 + class as u64);
        (0..3).map(|_| gen.sample(class, &mut rng)).collect()
    };
    let serial = start_synthetic(3, ParallelConfig::default());
    let s_serial = serial.create_session(n_way, 16).unwrap();
    for class in 0..n_way {
        for img in mk_shots(class) {
            serial.add_shot(s_serial, class, img).unwrap();
        }
    }
    serial.finish_training(s_serial).unwrap();
    let gen = ImageGen::new(8, 8, 61);
    let mut rng = Rng::new(61);
    let images: Vec<Vec<f32>> = (0..7).map(|i| gen.sample(i % n_way, &mut rng)).collect();
    for ee in [
        None,
        Some(EeConfig { e_s: 1, e_c: 1 }),
        Some(EeConfig { e_s: 1, e_c: 2 }),
        Some(EeConfig::paper_default()),
    ] {
        let want: Vec<_> =
            images.iter().map(|img| serial.query(s_serial, img.clone(), ee).unwrap()).collect();
        for workers in [1usize, 2, 7] {
            let coord = start_synthetic(3, ParallelConfig { workers, min_batch_per_worker: 1 });
            let sid = coord.create_session(n_way, 16).unwrap();
            for class in 0..n_way {
                for img in mk_shots(class) {
                    coord.add_shot(sid, class, img).unwrap();
                }
            }
            coord.finish_training(sid).unwrap();
            let got = coord.query_batch(sid, images.clone(), ee).unwrap();
            assert_eq!(got, want, "workers={workers} ee={ee:?}");
        }
    }
}

#[test]
fn invalid_ee_config_rejected_not_panicked() {
    // EarlyExitController::new asserts on E_s/E_c = 0; a client-supplied
    // config must become Response::Error, never a dead worker (the same
    // bug class as PR 4's out-of-range hv_bits fix)
    let coord = start_synthetic(2, ParallelConfig::default());
    let gen = ImageGen::new(8, 8, 91);
    let mut rng = Rng::new(91);
    let sid = coord.create_session(2, 16).unwrap();
    for class in 0..2 {
        for _ in 0..2 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    let img = gen.sample(0, &mut rng);
    for (e_s, e_c) in [(0usize, 2usize), (2, 0), (0, 0)] {
        let err = coord
            .query(sid, img.clone(), Some(EeConfig { e_s, e_c }))
            .unwrap_err()
            .to_string();
        assert!(err.contains("e_s") || err.contains("e_c"), "({e_s},{e_c}): {err}");
        let err = coord
            .query_batch(sid, vec![img.clone()], Some(EeConfig { e_s, e_c }))
            .unwrap_err()
            .to_string();
        assert!(err.contains("e_s") || err.contains("e_c"), "batch ({e_s},{e_c}): {err}");
    }
    // the worker survived: valid queries still served, errors counted
    assert!(coord.query(sid, img, Some(EeConfig::paper_default())).is_ok());
    assert!(coord.metrics().errors >= 6);
}

#[test]
fn query_batch_error_paths_and_empty_batch() {
    let coord = start_synthetic(2, ParallelConfig { workers: 2, min_batch_per_worker: 1 });
    let gen = ImageGen::new(8, 8, 93);
    let mut rng = Rng::new(93);
    let sid = coord.create_session(2, 16).unwrap();
    for class in 0..2 {
        for _ in 0..2 {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    let img = gen.sample(0, &mut rng);
    // unknown session
    assert!(coord.query_batch(999, vec![img.clone()], None).is_err());
    // malformed image mid-batch fails the whole batch with a real error
    let mut imgs = vec![img.clone(); 4];
    imgs[2] = vec![0.0; 5];
    assert!(coord.query_batch(sid, imgs, None).is_err());
    // empty batch is a clean no-op
    assert_eq!(coord.query_batch(sid, vec![], None).unwrap().len(), 0);
    // coordinator still alive
    assert!(coord.query_batch(sid, vec![img], Some(EeConfig::paper_default())).is_ok());
}

#[test]
fn router_routes_query_batches() {
    use fsl_hdnn::coordinator::{DeviceRouter, Placement};
    let cfg = synthetic_cfg(false);
    let mut router = DeviceRouter::start(2, 2, Placement::RoundRobin, move |_i| {
        let c = cfg.clone();
        move || Ok(ComputeEngine::from_config(c))
    })
    .unwrap();
    let gen = ImageGen::new(8, 8, 95);
    let mut rng = Rng::new(95);
    let sid = router.create_session(2, 16).unwrap();
    for class in 0..2 {
        let shots: Vec<Vec<f32>> = (0..2).map(|_| gen.sample(class, &mut rng)).collect();
        router.add_shot_batch(sid, class, shots).unwrap();
    }
    router.finish_training(sid).unwrap();
    let images: Vec<Vec<f32>> = (0..3).map(|i| gen.sample(i % 2, &mut rng)).collect();
    let serial: Vec<_> = images
        .iter()
        .map(|img| router.query(sid, img.clone(), Some(EeConfig::paper_default())).unwrap())
        .collect();
    let batched = router.query_batch(sid, images, Some(EeConfig::paper_default())).unwrap();
    assert_eq!(batched, serial);
    assert!(router.query_batch(999, vec![], None).is_err(), "unknown routed session");
}

#[test]
fn zero_way_session_request_rejected_not_panicked() {
    // n_way = 0 at the request boundary must be Response::Error, never an
    // assert in FslSession::new that kills the worker
    let coord = start_synthetic(3, ParallelConfig::default());
    let err = coord.create_session(0, 16).unwrap_err().to_string();
    assert!(err.contains("n_way"), "{err}");
    // the worker survived and still serves valid requests
    assert!(coord.create_session(2, 16).is_ok());
    assert!(coord.metrics().errors >= 1);
}

#[test]
fn zero_dim_model_rejected_at_the_request_boundary() {
    // a (mis)configured engine with D=0 must turn CreateSession into a
    // Response::Error, not a dead worker (FslSession::new would assert)
    let cfg = ModelConfig { d: 0, ..synthetic_cfg(false) };
    let coord = Coordinator::start(move || Ok(ComputeEngine::from_config(cfg)), 3).unwrap();
    let err = coord.create_session(2, 16).unwrap_err().to_string();
    assert!(err.contains("d must be >= 1"), "{err}");
    assert!(coord.metrics().errors >= 1);
}

#[test]
fn backend_conformance_through_the_coordinator() {
    use fsl_hdnn::classifier::ClassifierBackend;
    use fsl_hdnn::hdc::Distance;
    // the serving battery parameterized over both classifier backends:
    // per-shot serial training must match class-batched training on
    // worker-sharded engines {1, 2, 7}, query for query
    for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
        let n_way = 3;
        let mk_shots = |class: usize| -> Vec<Vec<f32>> {
            let gen = ImageGen::new(8, 8, 47);
            let mut rng = Rng::new(400 + class as u64);
            (0..3).map(|_| gen.sample(class, &mut rng)).collect()
        };
        let serial = start_synthetic(3, ParallelConfig::default());
        let s1 = serial.create_session_full(n_way, 16, Distance::L1, backend).unwrap();
        for class in 0..n_way {
            for img in mk_shots(class) {
                serial.add_shot(s1, class, img).unwrap();
            }
        }
        serial.finish_training(s1).unwrap();
        let gen = ImageGen::new(8, 8, 47);
        let mut rng = Rng::new(474);
        let images: Vec<Vec<f32>> = (0..7).map(|i| gen.sample(i % n_way, &mut rng)).collect();
        let want: Vec<_> =
            images.iter().map(|img| serial.query(s1, img.clone(), None).unwrap()).collect();
        for workers in [1usize, 2, 7] {
            let coord = start_synthetic(3, ParallelConfig { workers, min_batch_per_worker: 1 });
            let sid = coord.create_session_full(n_way, 16, Distance::L1, backend).unwrap();
            for class in 0..n_way {
                coord.add_shot_batch(sid, class, mk_shots(class)).unwrap();
            }
            coord.finish_training(sid).unwrap();
            let got = coord.query_batch(sid, images.clone(), None).unwrap();
            assert_eq!(got, want, "{backend:?} workers={workers}: sharded must match serial");
        }
    }
}

#[test]
fn ldc_sessions_pack_denser_into_class_memory() {
    use fsl_hdnn::classifier::ClassifierBackend;
    use fsl_hdnn::hdc::Distance;
    // at D=4096 single branch, 128-way @ 4-bit HDC is the exact 256 KB
    // fit (paper capacity table); the same n_way through LDC folds to
    // 512 dims, so eight such sessions fill the memory instead of one
    let cfg = ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4],
        blocks_per_stage: 1,
        feature_dim: 4,
        d: 4096,
        ..Default::default()
    };
    let coord = {
        let c = cfg.clone();
        Coordinator::start(move || Ok(ComputeEngine::from_config(c)), 1).unwrap()
    };
    let hdc = coord.create_session_full(128, 4, Distance::L1, ClassifierBackend::Hdc).unwrap();
    assert!(
        coord.create_session_full(128, 4, Distance::L1, ClassifierBackend::Ldc).is_err(),
        "a full memory rejects LDC sessions too"
    );
    coord.call(Request::CloseSession { session: hdc });
    let sids: Vec<u64> = (0..8)
        .map(|_| {
            coord.create_session_full(128, 4, Distance::L1, ClassifierBackend::Ldc).unwrap()
        })
        .collect();
    assert_eq!(sids.len(), 8);
    let err = coord
        .create_session_full(128, 4, Distance::L1, ClassifierBackend::Ldc)
        .unwrap_err()
        .to_string();
    assert!(err.contains("exhausted"), "ninth 128-way LDC session must not fit: {err}");
    let m = coord.metrics();
    assert_eq!(m.class_mem_used_bits, 8 * 128 * 512 * 4, "LDC is charged its folded bits");
}

#[test]
fn raw_feature_input_mode() {
    // Fig. 7: raw features can bypass the FE and feed the FSL classifier
    let Some(coord) = start_native("raw_feature_input_mode") else { return };
    let sid = coord.create_session(3, 16).unwrap();
    let mut rng = Rng::new(23);
    // well-separated feature prototypes
    let protos: Vec<Vec<f32>> = (0..3)
        .map(|_| (0..128).map(|_| 3.0 * rng.gauss_f32()).collect())
        .collect();
    for (c, p) in protos.iter().enumerate() {
        for _ in 0..3 {
            let f: Vec<f32> = p.iter().map(|v| v + 0.3 * rng.gauss_f32()).collect();
            match coord.call(Request::AddFeatureShot { session: sid, class: c, feature: f }) {
                Response::ShotAccepted { .. } => {}
                other => panic!("unexpected {other:?}"),
            }
        }
    }
    let mut correct = 0;
    for (c, p) in protos.iter().enumerate() {
        let q: Vec<f32> = p.iter().map(|v| v + 0.3 * rng.gauss_f32()).collect();
        let out = coord
            .call(Request::QueryFeature { session: sid, feature: q })
            .expect_query();
        correct += (out.prediction == c) as usize;
    }
    assert_eq!(correct, 3, "feature-mode session must classify its prototypes");
    // short features are zero-padded; oversize rejected
    let ok = coord.call(Request::QueryFeature { session: sid, feature: vec![0.5; 16] });
    assert!(matches!(ok, Response::QueryResult { .. }));
    let bad = coord.call(Request::QueryFeature { session: sid, feature: vec![0.5; 4096] });
    assert!(matches!(bad, Response::Error(_)));
}
