//! Chaos battery: deterministic fault injection against the fleet
//! (DESIGN.md §Fault model).
//!
//! The fail-point registry is process-global, so every test that arms a
//! site runs in THIS integration binary (its own process, away from the
//! concurrently-running lib unit tests) and serializes on [`faults_lock`].
//! Armed state always lives inside an [`failpoint::armed_scope`] guard so
//! a panicking assertion cannot leak a live fail point into the next test.
//!
//! The headline property under test is the paper's: single-pass HDC/LDC
//! training has no hidden state beyond the retained shots, so a device
//! that dies mid-episode can be rebuilt on a survivor by journal replay
//! and the episode's outcomes are **bit-identical** to a run where
//! nothing ever failed — at any worker count.

use std::sync::{Mutex, MutexGuard};
use std::time::{Duration, Instant};

use fsl_hdnn::classifier::ClassifierBackend;
use fsl_hdnn::config::{ModelConfig, ParallelConfig, ServingConfig};
use fsl_hdnn::coordinator::session::QueryOutcome;
use fsl_hdnn::coordinator::{
    Coordinator, DeviceHealth, DeviceRouter, Gateway, Placement, Request, Response, WireClient,
};
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::hdc::Distance;
use fsl_hdnn::runtime::{ComputeEngine, WorkerPool};
use fsl_hdnn::util::failpoint;
use fsl_hdnn::util::prng::Rng;

const N_WAY: usize = 10;
const K_SHOT: usize = 5;
const QUERIES_PER_CLASS: usize = 2;

/// One lock for every fault-arming test in this binary.
fn faults_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Tiny synthetic FE so episodes run in milliseconds; identical config on
/// every device means identical synthetic weights, which is what makes
/// cross-device replay bit-identical.
fn synthetic_cfg() -> ModelConfig {
    ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4, 8],
        blocks_per_stage: 1,
        feature_dim: 8,
        d: 64,
        ch_sub: 4,
        n_centroids: 8,
        ..Default::default()
    }
}

fn start_router(workers: usize) -> DeviceRouter {
    let cfg = synthetic_cfg();
    let par = ParallelConfig { workers, min_batch_per_worker: 1 };
    DeviceRouter::start(2, K_SHOT, Placement::LeastLoaded, move |_i| {
        let c = cfg.clone();
        move || Ok(ComputeEngine::from_config(c).with_parallelism(par))
    })
    .unwrap()
}

/// A full episode's data, generated once so the baseline and chaos runs
/// consume the exact same images.
struct Episode {
    shots: Vec<Vec<Vec<f32>>>,
    queries: Vec<Vec<f32>>,
}

fn episode_data(seed: u64) -> Episode {
    let gen = ImageGen::new(8, 16.max(N_WAY), seed);
    let mut rng = Rng::new(seed);
    let shots = (0..N_WAY)
        .map(|class| (0..K_SHOT).map(|_| gen.sample(class, &mut rng)).collect())
        .collect();
    let queries = (0..N_WAY)
        .flat_map(|class| {
            (0..QUERIES_PER_CLASS).map(|_| gen.sample(class, &mut rng)).collect::<Vec<_>>()
        })
        .collect();
    Episode { shots, queries }
}

/// Run the 10-way 5-shot episode; `kill_at` arms `device.train=panic-once`
/// right before training class `kill_at` so the hosting device's worker
/// thread dies mid-episode. Returns serial predictions plus one batched
/// query pass (and asserts they agree).
fn run_episode(
    router: &mut DeviceRouter,
    ep: &Episode,
    backend: ClassifierBackend,
    kill_at: Option<usize>,
) -> Vec<QueryOutcome> {
    let sid = router.create_session_full(N_WAY, 16, Distance::L1, backend).unwrap();
    for (class, shots) in ep.shots.iter().enumerate() {
        if kill_at == Some(class) {
            failpoint::arm_spec("device.train=panic-once").unwrap();
        }
        router.add_shot_batch(sid, class, shots.clone()).unwrap();
    }
    assert_eq!(router.finish_training(sid).unwrap(), N_WAY * K_SHOT);
    let serial: Vec<QueryOutcome> =
        ep.queries.iter().map(|q| router.query(sid, q.clone(), None).unwrap()).collect();
    let batched = router.query_batch(sid, ep.queries.clone(), None).unwrap();
    assert_eq!(batched, serial, "batched queries must match serial after recovery");
    serial
}

#[test]
fn device_death_mid_episode_is_bit_identical_to_unfailed_run() {
    let _g = faults_lock();
    let ep = episode_data(0xC0FFEE);
    for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
        for workers in [1usize, 2, 7] {
            // baseline: no faults, ever
            let _scope = failpoint::armed_scope("").unwrap();
            let mut base_router = start_router(workers);
            let baseline = run_episode(&mut base_router, &ep, backend, None);
            assert_eq!(base_router.metrics().device_failures, 0);

            // chaos: the hosting device is killed mid-training (class 6 of
            // 10); every call still succeeds because the router re-places
            // the session from its shot journal and retries
            let mut router = start_router(workers);
            let survived = run_episode(&mut router, &ep, backend, Some(6));
            failpoint::disarm_all();

            assert_eq!(
                survived, baseline,
                "backend {backend:?} workers {workers}: post-recovery predictions \
                 must be bit-identical to the unfailed run"
            );
            let m = router.metrics();
            assert_eq!(m.device_failures, 1, "exactly one device died");
            assert_eq!(m.sessions_replaced, 1, "exactly one session re-placed");
            assert!(m.retrain_ms >= 0.0);
            let dead =
                (0..2).filter(|&d| router.health(d) == DeviceHealth::Dead).count();
            assert_eq!(dead, 1, "one Dead device after the kill");
            // the fleet snapshot carries the router-owned recovery counters
            let snap = router.fleet_snapshot();
            assert_eq!(snap.device_failures, 1);
            assert_eq!(snap.sessions_replaced, 1);
        }
    }
}

#[test]
fn soft_faults_strike_suspect_then_dead_and_recover() {
    let _g = faults_lock();
    let ep = episode_data(0xBEEF);
    let mut router = start_router(1);
    let sid = router.create_session_full(N_WAY, 16, Distance::L1, ClassifierBackend::Hdc).unwrap();
    for (class, shots) in ep.shots.iter().enumerate() {
        router.add_shot_batch(sid, class, shots.clone()).unwrap();
    }
    router.finish_training(sid).unwrap();
    let want = router.query(sid, ep.queries[0].clone(), None).unwrap();
    let home = router.placement(sid).unwrap().device;

    // two soft (non-fatal, retryable) faults: Suspect, errors surface
    for strike in 1..=2u32 {
        let _s = failpoint::armed_scope("device.query=fail-once").unwrap();
        let err = router.query(sid, ep.queries[0].clone(), None).unwrap_err().to_string();
        assert!(err.contains("injected"), "strike {strike}: {err}");
        assert_eq!(router.health(home), DeviceHealth::Suspect);
        assert_eq!(router.metrics().device_failures, 0);
    }
    // third strike: the device is declared Dead, the session re-places,
    // and the retry succeeds — callers see recovery, not an error
    {
        let _s = failpoint::armed_scope("device.query=fail-once").unwrap();
        let out = router.query(sid, ep.queries[0].clone(), None).unwrap();
        assert_eq!(out, want, "re-placed session answers bit-identically");
    }
    assert_eq!(router.health(home), DeviceHealth::Dead);
    let m = router.metrics();
    assert_eq!((m.device_failures, m.sessions_replaced), (1, 1));
    assert_ne!(router.placement(sid).unwrap().device, home);
    // a success on the new home resets nothing surprising: further queries fine
    assert_eq!(router.query(sid, ep.queries[0].clone(), None).unwrap(), want);
}

#[test]
fn cascading_failure_loses_cleanly_and_revive_reenters_probation() {
    let _g = faults_lock();
    let ep = episode_data(0xD00D);
    let mut router = start_router(1);
    let sid = router.create_session_full(4, 16, Distance::L1, ClassifierBackend::Hdc).unwrap();
    for class in 0..4 {
        router.add_shot_batch(sid, class, ep.shots[class].clone()).unwrap();
    }
    router.finish_training(sid).unwrap();

    // every training check panics: the home device dies on the next shot,
    // and the journal replay kills the rescue device too — the session is
    // lost, but the caller gets a clean error, never a hang or a panic
    {
        let _s = failpoint::armed_scope("device.train=panic-every-n:1").unwrap();
        let err = router.add_shot(sid, 0, ep.shots[0][0].clone()).unwrap_err().to_string();
        assert!(!err.is_empty());
    }
    assert_eq!(router.health(0), DeviceHealth::Dead);
    assert_eq!(router.health(1), DeviceHealth::Dead);
    assert_eq!(router.metrics().device_failures, 2);
    assert_eq!(router.metrics().sessions_replaced, 0, "nowhere to re-place");
    // the lost session routes as unknown, and nothing can be created
    assert!(router.query(sid, ep.queries[0].clone(), None).is_err());
    assert!(router.create_session(2, 4).is_err(), "no live devices");

    // revive: Probation until the first success, then Healthy again
    assert!(router.revive(0).is_ok());
    assert_eq!(router.health(0), DeviceHealth::Probation);
    assert!(router.revive(0).is_err(), "only Dead devices can be revived");
    let sid2 = router.create_session(2, 4).unwrap();
    assert_eq!(router.health(0), DeviceHealth::Healthy);
    router.add_shot_batch(sid2, 0, ep.shots[0].clone()).unwrap();
    router.add_shot_batch(sid2, 1, ep.shots[1].clone()).unwrap();
    router.finish_training(sid2).unwrap();
    assert!(router.query(sid2, ep.queries[0].clone(), None).is_ok());
}

#[test]
fn double_close_and_unknown_sessions_stay_clean_errors() {
    let _g = faults_lock();
    let _scope = failpoint::armed_scope("").unwrap();
    let mut router = start_router(1);
    let sid = router.create_session(2, 4).unwrap();
    assert_eq!(router.loads().iter().sum::<usize>(), 1);
    router.close_session(sid).unwrap();
    assert_eq!(router.loads().iter().sum::<usize>(), 0);
    let err = router.close_session(sid).unwrap_err().to_string();
    assert!(err.contains("unknown routed session"), "{err}");
    assert_eq!(router.loads().iter().sum::<usize>(), 0, "double close never double-decrements");
    assert!(router.add_shot(999, 0, vec![0.0; 192]).is_err());
    assert!(router.query_batch(999, vec![], None).is_err());
    assert_eq!(router.metrics().device_failures, 0, "bad session ids are not device faults");
}

#[test]
fn pool_survives_injected_task_panics_and_drop_joins() {
    let _g = faults_lock();
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    let ran = Arc::new(AtomicUsize::new(0));
    {
        // every second pool task panics inside the worker loop's
        // catch_unwind; the pool must keep serving and its Drop must still
        // drain queues and join every worker with tasks in flight
        let _s = failpoint::armed_scope("pool.task=panic-every-n:2").unwrap();
        let pool = WorkerPool::new(3);
        for _ in 0..24 {
            let ran = ran.clone();
            pool.submit(move || {
                ran.fetch_add(1, Ordering::AcqRel);
            });
        }
        drop(pool); // drains + joins with panicking tasks still queued
    }
    let n = ran.load(Ordering::Acquire);
    // the hits counter is one atomic across workers, so panic-every-n:2
    // panics exactly every second drained task regardless of interleaving
    assert_eq!(n, 12, "exactly half the tasks run, got {n}/24");
    // the registry is disarmed again: a fresh pool runs everything
    let pool = WorkerPool::new(2);
    let ran2 = Arc::new(AtomicUsize::new(0));
    for _ in 0..8 {
        let r = ran2.clone();
        pool.submit(move || {
            r.fetch_add(1, Ordering::AcqRel);
        });
    }
    drop(pool);
    assert_eq!(ran2.load(Ordering::Acquire), 8);
}

#[test]
fn deadline_bounds_caller_latency_without_killing_the_device() {
    let _g = faults_lock();
    let cfg = synthetic_cfg();
    let coord = Coordinator::start(move || Ok(ComputeEngine::from_config(cfg)), K_SHOT).unwrap();
    let sid = coord.create_session(2, 4).unwrap();
    {
        // 300 ms injected latency on queries vs a 30 ms deadline
        let _s = failpoint::armed_scope("device.query=latency-ms:300").unwrap();
        let t0 = Instant::now();
        let resp = coord
            .client()
            .call_deadline(Request::Query { session: sid, image: vec![0.1; 192], ee: None },
                Duration::from_millis(30));
        assert!(t0.elapsed() < Duration::from_millis(280), "deadline cut the wait short");
        match &resp {
            Response::RetryableError(m) => {
                assert!(m.contains("deadline"), "{m}");
                assert!(!resp.is_device_unavailable(), "a slow device is not a dead one");
            }
            other => panic!("expected a retryable deadline error, got {other:?}"),
        }
    }
    // the worker finished the stale request in the background and serves on
    let gen = ImageGen::new(8, 8, 7);
    let mut rng = Rng::new(7);
    for class in 0..2 {
        for _ in 0..K_SHOT {
            coord.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    coord.finish_training(sid).unwrap();
    assert!(coord.query(sid, gen.sample(0, &mut rng), None).is_ok());
}

#[test]
fn wire_client_redials_through_injected_gateway_write_faults() {
    let _g = faults_lock();
    let cfg = synthetic_cfg();
    let coord = Coordinator::start(move || Ok(ComputeEngine::from_config(cfg)), K_SHOT).unwrap();
    let gateway = Gateway::bind(coord.client(), &ServingConfig::default()).unwrap();
    let mut client = WireClient::connect(gateway.local_addr()).unwrap().with_retry(4, 1, 8);
    {
        // the gateway drops the connection instead of writing the reply;
        // call_retry re-dials and the second attempt lands
        let _s = failpoint::armed_scope("gateway.write=fail-once").unwrap();
        let resp = client.call_retry(&Request::GetMetrics).unwrap();
        assert!(matches!(resp, Response::Metrics(_)));
    }
    // single-attempt call reports the distinct marker error instead
    {
        let _s = failpoint::armed_scope("gateway.write=fail-once").unwrap();
        let err = client.call(&Request::GetMetrics).unwrap_err();
        assert!(
            err.is::<fsl_hdnn::coordinator::gateway::ConnectionLost>(),
            "wanted ConnectionLost, got: {err}"
        );
    }
    // and the client recovers on the very next plain call (lazy re-dial)
    let resp = client.call(&Request::GetMetrics).unwrap();
    assert!(matches!(resp, Response::Metrics(_)));
}

#[test]
fn injected_read_faults_drop_the_connection_without_a_reply() {
    let _g = faults_lock();
    let cfg = synthetic_cfg();
    let coord = Coordinator::start(move || Ok(ComputeEngine::from_config(cfg)), K_SHOT).unwrap();
    let gateway = Gateway::bind(coord.client(), &ServingConfig::default()).unwrap();
    let mut client = WireClient::connect(gateway.local_addr()).unwrap().with_retry(4, 1, 8);
    let _s = failpoint::armed_scope("gateway.read=fail-once").unwrap();
    // the first frame is swallowed server-side (request never executed);
    // retry re-dials and succeeds — session id 1 proves the dropped frame
    // never reached the worker (ids are allocated on execution, from 1)
    let resp = client.call_retry(&Request::CreateSession {
        n_way: 2,
        hv_bits: 4,
        metric: Distance::L1,
        backend: ClassifierBackend::Hdc,
    })
    .unwrap();
    match resp {
        Response::SessionCreated { session } => {
            assert_eq!(session, 1, "the swallowed frame must not have executed");
        }
        other => panic!("expected SessionCreated, got {other:?}"),
    }
    drop(coord);
}

#[test]
fn retryable_errors_surface_through_the_wire_taxonomy() {
    let _g = faults_lock();
    let cfg = synthetic_cfg();
    let coord = Coordinator::start(move || Ok(ComputeEngine::from_config(cfg)), K_SHOT).unwrap();
    let gateway = Gateway::bind(coord.client(), &ServingConfig::default()).unwrap();
    let mut client = WireClient::connect(gateway.local_addr()).unwrap().with_retry(3, 1, 4);
    let sid = client.create_session(2, 4).unwrap();
    {
        // an injected device fault crosses the wire as retryable=true and
        // call_retry absorbs it (second attempt passes: fail-once)
        let _s = failpoint::armed_scope("device.query=fail-once").unwrap();
        let resp = client
            .call_retry(&Request::Query { session: sid, image: vec![0.2; 192], ee: None })
            .unwrap();
        // untrained session still classifies (all-zero prototypes) — the
        // point is the transport recovered, not the prediction
        assert!(matches!(resp, Response::QueryResult { .. }));
    }
    // the convenience wrappers surface retryable errors as plain Errs
    let _s = failpoint::armed_scope("device.query=fail-once").unwrap();
    let err = client.query(sid, vec![0.2; 192], None).unwrap_err().to_string();
    assert!(err.contains("injected"), "{err}");
}
