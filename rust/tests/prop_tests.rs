//! Property-based tests over randomized inputs (in-tree mini-harness; the
//! offline registry has no proptest). Each property runs across many
//! random cases seeded deterministically — failures print the case seed.

use fsl_hdnn::config::EeConfig;
use fsl_hdnn::config::ModelConfig;
use fsl_hdnn::coordinator::batcher::ClassBatcher;
use fsl_hdnn::coordinator::early_exit::{EarlyExitController, EeDecision};
use fsl_hdnn::fe::conv::{
    clustered_conv2d, clustered_conv2d_lut_in_lane, clustered_conv2d_packed, conv2d, CodebookLut,
    Tensor3,
};
use fsl_hdnn::fe::kmeans::{cluster_layer, kmeans_1d};
use fsl_hdnn::fe::FeModel;
use fsl_hdnn::hdc::{quant, CrpEncoder, HdcModel};
use fsl_hdnn::sim::fe_engine::simulate_layer;
use fsl_hdnn::sim::workload::ConvGeom;
use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::util::prng::Rng;

const CASES: u64 = 40;

/// cRP encoding is linear for arbitrary (F, D, seed).
#[test]
fn prop_crp_linearity() {
    for case in 0..CASES {
        let mut rng = Rng::new(1000 + case);
        let f = 16 * (1 + rng.below(6));
        let d = 16 * (1 + rng.below(12));
        let enc = CrpEncoder::new(d, rng.next_u64());
        let x: Vec<f32> = (0..f).map(|_| rng.gauss_f32()).collect();
        let y: Vec<f32> = (0..f).map(|_| rng.gauss_f32()).collect();
        let a = rng.range_f32(-3.0, 3.0);
        let z: Vec<f32> = x.iter().zip(&y).map(|(p, q)| a * p + q).collect();
        let (hx, hy, hz) = (enc.encode(&x), enc.encode(&y), enc.encode(&z));
        for i in 0..d {
            let want = a * hx[i] + hy[i];
            assert!(
                (hz[i] - want).abs() < 1e-2 * (1.0 + want.abs()),
                "case {case}: linearity broken at {i}"
            );
        }
    }
}

/// Zero-padding features never changes the encoding of the prefix.
#[test]
fn prop_crp_padding_invariance() {
    for case in 0..CASES {
        let mut rng = Rng::new(2000 + case);
        let f = 16 * (1 + rng.below(4));
        let pad_blocks = 1 + rng.below(3);
        let d = 16 * (1 + rng.below(8));
        let enc = CrpEncoder::new(d, rng.next_u64());
        let x: Vec<f32> = (0..f).map(|_| rng.gauss_f32()).collect();
        let mut xp = x.clone();
        xp.extend(std::iter::repeat(0.0).take(16 * pad_blocks));
        assert_eq!(enc.encode(&x), enc.encode(&xp), "case {case}");
    }
}

/// Batcher conserves items, never mixes classes, never exceeds k per batch.
#[test]
fn prop_batcher_conservation() {
    for case in 0..CASES {
        let mut rng = Rng::new(3000 + case);
        let k = 1 + rng.below(6);
        let n_classes = 1 + rng.below(8);
        let n_items = rng.below(60);
        let mut b: ClassBatcher<(usize, usize)> = ClassBatcher::new(k);
        let mut emitted = 0usize;
        for i in 0..n_items {
            let class = rng.below(n_classes);
            if let Some(batch) = b.push(class, (class, i)) {
                assert_eq!(batch.items.len(), k, "case {case}");
                assert!(batch.items.iter().all(|(c, _)| *c == batch.class), "case {case}: mixed");
                emitted += batch.items.len();
            }
        }
        for batch in b.flush_all() {
            assert!(batch.items.len() < k, "flush returns only partials");
            assert!(batch.items.iter().all(|(c, _)| *c == batch.class));
            emitted += batch.items.len();
        }
        assert_eq!(emitted, n_items, "case {case}: items lost or duplicated");
    }
}

/// Batched HDC training == sequential training (any k, d, values) — the
/// row-major accumulation adds shots in `train_shot` order, so the sums
/// are bit-identical, not merely close.
#[test]
fn prop_hdc_batch_equals_sequential() {
    for case in 0..CASES {
        let mut rng = Rng::new(4000 + case);
        let d = 8 * (1 + rng.below(32));
        let k = 1 + rng.below(8);
        let hvs: Vec<Vec<f32>> = (0..k)
            .map(|_| (0..d).map(|_| 10.0 * rng.gauss_f32()).collect())
            .collect();
        let mut seq = HdcModel::new(1, d);
        for hv in &hvs {
            seq.train_shot(0, hv);
        }
        let mut bat = HdcModel::new(1, d);
        bat.train_batch(0, &hvs);
        assert_eq!(seq.raw_class_hv(0), bat.raw_class_hv(0), "case {case}: bit-identical sums");
        assert_eq!(seq.counts, bat.counts, "case {case}");
    }
}

/// The packed class-memory datapath == the dequantized-f32 oracle:
/// distances agree within f32-association tolerance (multi-bit L1 and
/// hamming exactly), predictions agree, and the sharded batch path is
/// bit-identical to serial — across the full precision x metric x
/// dimension x worker grid (ISSUE 4 acceptance).
#[test]
fn prop_packed_matches_dequantized_oracle() {
    use fsl_hdnn::hdc::{distance::argmin, Distance};
    for &d in &[64usize, 4096] {
        let cases = if d == 4096 { 2 } else { 8 };
        for case in 0..cases {
            let mut rng = Rng::new(13_000 + d as u64 * 31 + case);
            let n_classes = 3 + rng.below(3);
            let mut shots: Vec<(usize, Vec<f32>)> = Vec::new();
            for c in 0..n_classes {
                for _ in 0..(1 + rng.below(3)) {
                    shots.push((c, (0..d).map(|_| 3.0 * rng.gauss_f32()).collect()));
                }
            }
            let queries: Vec<Vec<f32>> =
                (0..7).map(|_| (0..d).map(|_| 3.0 * rng.gauss_f32()).collect()).collect();
            for bits in [1u32, 2, 4, 8, 16] {
                for metric in [Distance::L1, Distance::Hamming, Distance::Dot] {
                    let mut m =
                        HdcModel::new(n_classes, d).with_precision(bits).with_metric(metric);
                    for (c, hv) in &shots {
                        m.train_shot(*c, hv);
                    }
                    let serial = m.distances_batch(&queries, 1);
                    for (q, packed) in queries.iter().zip(&serial) {
                        let want = m.distances_oracle(q);
                        // magnitude-aware tolerance: the dot kernel rounds
                        // the scale product once instead of per element
                        let qmag: f64 = q.iter().map(|v| v.abs() as f64).sum();
                        for (c, (a, b)) in packed.iter().zip(&want).enumerate() {
                            assert!(
                                (a - b).abs() <= 1e-6 * (1.0 + b.abs() + 8.0 * qmag),
                                "d={d} case {case} bits={bits} {metric:?} class {c}: \
                                 packed {a} vs oracle {b}"
                            );
                        }
                        assert_eq!(
                            argmin(packed),
                            argmin(&want),
                            "d={d} case {case} bits={bits} {metric:?}: predictions diverged"
                        );
                        // multi-bit L1 and every hamming distance are
                        // bit-exact by construction
                        if metric == Distance::Hamming || (metric == Distance::L1 && bits > 1) {
                            assert_eq!(packed, &want, "d={d} bits={bits} {metric:?}");
                        }
                    }
                    for workers in [2usize, 7] {
                        assert_eq!(
                            m.distances_batch(&queries, workers),
                            serial,
                            "d={d} case {case} bits={bits} {metric:?} workers={workers}: \
                             sharded distances must be bit-identical to serial"
                        );
                    }
                }
            }
        }
    }
}

/// The chunked-scalar and simd kernel lanes of the packed class-memory
/// datapath are bitwise identical to each other and to the dispatching
/// `distances` entry point, across D (odd D exercises every scalar tail),
/// the full precision range, and all three metrics; where the exactness
/// contract holds (hamming at any precision, multi-bit L1) both lanes are
/// also bit-identical to the dequantized-f32 oracle (DESIGN.md §SIMD
/// datapath). With the `simd` feature off, `Lane::Simd` aliases the
/// chunked kernels, so this battery is meaningful under both builds.
#[test]
fn prop_simd_lane_bit_identity() {
    use fsl_hdnn::hdc::Distance;
    use fsl_hdnn::util::simd::Lane;
    for &d in &[64usize, 111, 4096] {
        let cases = if d == 4096 { 2 } else { 6 };
        for case in 0..cases {
            let mut rng = Rng::new(14_000 + d as u64 * 37 + case);
            let n_classes = 3 + rng.below(3);
            let mut shots: Vec<(usize, Vec<f32>)> = Vec::new();
            for c in 0..n_classes {
                for _ in 0..(1 + rng.below(3)) {
                    shots.push((c, (0..d).map(|_| 3.0 * rng.gauss_f32()).collect()));
                }
            }
            let q: Vec<f32> = (0..d).map(|_| 3.0 * rng.gauss_f32()).collect();
            for bits in [1u32, 2, 4, 8, 16] {
                for metric in [Distance::L1, Distance::Hamming, Distance::Dot] {
                    let mut m =
                        HdcModel::new(n_classes, d).with_precision(bits).with_metric(metric);
                    for (c, hv) in &shots {
                        m.train_shot(*c, hv);
                    }
                    let (chunked, vectored) = {
                        let packed = m.packed();
                        let pq = packed.quantize_query_for(&q, metric);
                        (
                            packed.distances_in_lane(&pq, metric, Lane::Chunked),
                            packed.distances_in_lane(&pq, metric, Lane::Simd),
                        )
                    };
                    assert_eq!(
                        chunked, vectored,
                        "d={d} case {case} bits={bits} {metric:?}: lanes diverged"
                    );
                    assert_eq!(
                        m.distances(&q),
                        chunked,
                        "d={d} case {case} bits={bits} {metric:?}: dispatch != explicit lane"
                    );
                    let oracle = m.distances_oracle(&q);
                    if metric == Distance::Hamming || (metric == Distance::L1 && bits > 1) {
                        assert_eq!(
                            chunked, oracle,
                            "d={d} case {case} bits={bits} {metric:?}: exact contract broken"
                        );
                    } else {
                        let qmag: f64 = q.iter().map(|v| v.abs() as f64).sum();
                        for (c, (a, b)) in chunked.iter().zip(&oracle).enumerate() {
                            assert!(
                                (a - b).abs() <= 1e-6 * (1.0 + b.abs() + 8.0 * qmag),
                                "d={d} case {case} bits={bits} {metric:?} class {c}: \
                                 lane {a} vs oracle {b}"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Both kernel lanes of the LUT-layout packed convolution are bitwise
/// identical to each other and to the compat `clustered_conv2d_packed`
/// wrapper, and match the reference clustered kernel within the usual
/// f32-association tolerance — across odd geometries, `cin` not divisible
/// by `ch_sub`, and nibble-tail `cout`.
#[test]
fn prop_conv_lut_lanes_match_reference() {
    use fsl_hdnn::util::simd::Lane;
    for case in 0..16 {
        let mut rng = Rng::new(15_000 + case);
        let cin = 1 + rng.below(12);
        let cout = 1 + rng.below(36);
        let ch_sub = 1 + rng.below(8);
        let n = 2 + rng.below(15);
        let hw = 3 + rng.below(8);
        let stride = 1 + rng.below(2);
        let k = 3;
        let w: Vec<f32> = (0..cout * k * k * cin).map(|_| rng.gauss_f32()).collect();
        let cl = cluster_layer(&w, cout, k, cin, ch_sub, n);
        let packed = cl.packed();
        let lut = CodebookLut::new(&cl.codebook, packed.cout, packed.groups() * packed.n);
        let x =
            Tensor3::from_vec(hw, hw, cin, (0..hw * hw * cin).map(|_| rng.gauss_f32()).collect());
        let chunked = clustered_conv2d_lut_in_lane(&x, &packed, &lut, stride, Lane::Chunked);
        let vectored = clustered_conv2d_lut_in_lane(&x, &packed, &lut, stride, Lane::Simd);
        assert_eq!(chunked.data, vectored.data, "case {case}: conv lanes diverged");
        let compat = clustered_conv2d_packed(&x, &packed, &cl.codebook, stride);
        assert_eq!(chunked.data, compat.data, "case {case}: compat wrapper diverged");
        let reference = clustered_conv2d(&x, &cl.idx, &cl.codebook, cout, k, stride, cl.ch_sub, n);
        assert_eq!((reference.h, reference.w, reference.c), (chunked.h, chunked.w, chunked.c));
        for (i, (a, b)) in reference.data.iter().zip(&chunked.data).enumerate() {
            assert!((a - b).abs() < 1e-3, "case {case} idx {i}: ref {a} vs lut {b}");
        }
    }
}

/// Quantization error shrinks monotonically with precision; 1-bit keeps sign.
#[test]
fn prop_quantization_monotone() {
    for case in 0..CASES {
        let mut rng = Rng::new(5000 + case);
        let d = 16 * (1 + rng.below(16));
        let hv: Vec<f32> = (0..d).map(|_| rng.gauss_f32() * rng.range_f32(0.1, 10.0)).collect();
        // monotone chain from 2 bits up (the 1-bit mode uses a different,
        // mean-magnitude scale and may beat the coarse ternary 2-bit grid)
        let mut prev = f64::INFINITY;
        for bits in [2u32, 4, 8, 16] {
            let (q, _) = quant::quantize(&hv, bits);
            let mse: f64 = hv
                .iter()
                .zip(&q)
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum::<f64>()
                / d as f64;
            assert!(mse <= prev * 1.0001, "case {case}: bits {bits} worse than coarser");
            prev = mse;
        }
        let (q1, _) = quant::quantize(&hv, 1);
        for (a, b) in hv.iter().zip(&q1) {
            assert!(a.signum() == b.signum() || *b == 0.0 || *a == 0.0);
        }
    }
}

/// Early-exit controller invariants: exits only after >= E_c counted blocks,
/// never before block E_s + E_c - 1, and the exit prediction matches the
/// last fed prediction.
#[test]
fn prop_early_exit_semantics() {
    for case in 0..CASES {
        let mut rng = Rng::new(6000 + case);
        let e_s = 1 + rng.below(3);
        let e_c = 1 + rng.below(3);
        let n_blocks = 4 + rng.below(4);
        let mut ctl = EarlyExitController::new(EeConfig { e_s, e_c });
        let mut last_pred = usize::MAX;
        for b in 0..n_blocks {
            let pred = rng.below(4);
            match ctl.feed(b, pred) {
                EeDecision::Exit(p) => {
                    assert_eq!(p, pred, "case {case}: exit pred mismatch");
                    assert!(
                        b + 1 >= e_s + e_c - 1,
                        "case {case}: exited at block {b} with E_s={e_s} E_c={e_c}"
                    );
                    // the last e_c fed predictions (from e_s on) must agree
                    let t = &ctl.table;
                    let counted: Vec<usize> = t
                        .iter()
                        .filter(|(blk, _)| blk + 1 >= e_s)
                        .map(|(_, p)| *p)
                        .collect();
                    assert!(counted.len() >= e_c);
                    assert!(counted[counted.len() - e_c..].iter().all(|&p| p == pred));
                    break;
                }
                EeDecision::Continue => {
                    last_pred = pred;
                }
            }
        }
        let _ = last_pred;
    }
}

/// A staged forward stepped to stage k is bit-identical to
/// `forward_prefix(_, k)` and to a prefix of `forward`, across
/// clustered/dense models and odd geometries — the one-code-path contract
/// behind staged early-exit inference (DESIGN.md §Staged inference). The
/// executor's layer counter must also match the plan arithmetic.
#[test]
fn prop_staged_forward_bit_identical_to_prefix_of_forward() {
    for case in 0..12u64 {
        let mut rng = Rng::new(9000 + case);
        let stages = 2 + (case as usize % 2);
        let widths: Vec<usize> = (0..stages).map(|_| 2 + rng.below(6)).collect();
        let cfg = ModelConfig {
            image_size: 8 + 4 * rng.below(2),
            in_channels: 1 + rng.below(3),
            widths: widths.clone(),
            blocks_per_stage: 1 + rng.below(2),
            feature_dim: *widths.iter().max().unwrap(),
            d: 64,
            ch_sub: 4,
            n_centroids: 4 + rng.below(5),
            clustered: case % 3 == 0,
            master_seed: 77 + case,
        };
        let m = FeModel::synthetic(cfg.clone());
        let img: Vec<f32> = (0..cfg.image_size * cfg.image_size * cfg.in_channels)
            .map(|_| rng.gauss_f32())
            .collect();
        let full = m.forward(&img).unwrap();
        assert_eq!(full.len(), stages, "case {case}");
        for k in 0..=stages {
            let prefix = m.forward_prefix(&img, k).unwrap();
            assert_eq!(prefix, full[..k].to_vec(), "case {case} k={k}: prefix != forward");
            let mut exec = m.stage_start(&img).unwrap();
            for (s, want) in full.iter().take(k).enumerate() {
                let got = exec.step().unwrap().unwrap();
                assert_eq!(&got, want, "case {case} k={k}: staged stage {s} diverged");
            }
            assert_eq!(
                exec.layers_run(),
                m.layers_through_stage(k),
                "case {case} k={k}: layer counter != plan"
            );
        }
        // plan totals agree with the geometry formula the PJRT seam uses
        assert_eq!(m.n_layers(), cfg.conv_layers_through(stages), "case {case}");
    }
}

/// Clustered conv == dense conv with reconstructed weights, for random
/// geometry (the Fig. 4(b) exactness claim as a property).
#[test]
fn prop_clustered_conv_exact() {
    for case in 0..20 {
        let mut rng = Rng::new(7000 + case);
        let cin = [2usize, 4, 8][rng.below(3)];
        let cout = 1 + rng.below(6);
        let ch_sub = [1usize, 2, 4][rng.below(3)].min(cin);
        let n = [2usize, 4, 8][rng.below(3)];
        let hw = 4 + rng.below(5);
        let stride = 1 + rng.below(2);
        let k = 3;
        let w: Vec<f32> = (0..cout * k * k * cin).map(|_| rng.gauss_f32()).collect();
        let cl = cluster_layer(&w, cout, k, cin, ch_sub, n);
        let wr = cl.reconstruct();
        let x =
            Tensor3::from_vec(hw, hw, cin, (0..hw * hw * cin).map(|_| rng.gauss_f32()).collect());
        let dense = conv2d(&x, &wr, cout, k, stride);
        let clus = clustered_conv2d(&x, &cl.idx, &cl.codebook, cout, k, stride, ch_sub, n);
        for (i, (a, b)) in dense.data.iter().zip(&clus.data).enumerate() {
            assert!((a - b).abs() < 1e-3, "case {case} idx {i}: {a} vs {b}");
        }
    }
}

/// The packed fast kernel == the reference clustered kernel == dense conv
/// with reconstructed weights, across random geometry: strides 1 and 2,
/// `cin` not divisible by `ch_sub`, odd image sizes, odd `cout` (nibble
/// tail), and `cout` crossing the 16-wide tile boundary.
#[test]
fn prop_packed_kernel_matches_reference_and_oracle() {
    for case in 0..20 {
        let mut rng = Rng::new(11_000 + case);
        let cin = 1 + rng.below(12);
        let cout = 1 + rng.below(36);
        let ch_sub = 1 + rng.below(8);
        let n = 2 + rng.below(15); // 2..=16, the nibble-packable range
        let hw = 3 + rng.below(8);
        let stride = 1 + rng.below(2);
        let k = 3;
        let w: Vec<f32> = (0..cout * k * k * cin).map(|_| rng.gauss_f32()).collect();
        let cl = cluster_layer(&w, cout, k, cin, ch_sub, n);
        let packed = cl.packed();
        assert_eq!(packed.unpack(), cl.idx, "case {case}: nibble packing must round-trip");
        let x =
            Tensor3::from_vec(hw, hw, cin, (0..hw * hw * cin).map(|_| rng.gauss_f32()).collect());
        let reference = clustered_conv2d(&x, &cl.idx, &cl.codebook, cout, k, stride, cl.ch_sub, n);
        let fast = clustered_conv2d_packed(&x, &packed, &cl.codebook, stride);
        let oracle = conv2d(&x, &cl.reconstruct(), cout, k, stride);
        assert_eq!((reference.h, reference.w, reference.c), (fast.h, fast.w, fast.c));
        for (i, (a, b)) in reference.data.iter().zip(&fast.data).enumerate() {
            assert!((a - b).abs() < 1e-3, "case {case} idx {i}: ref {a} vs packed {b}");
        }
        for (i, (a, b)) in oracle.data.iter().zip(&fast.data).enumerate() {
            assert!((a - b).abs() < 1e-3, "case {case} idx {i}: oracle {a} vs packed {b}");
        }
    }
}

/// Clustered FeModel forward == the dense-reconstruction oracle across
/// random synthetic geometries (odd image sizes, `cin` not divisible by
/// `ch_sub`), and bit-identical across worker counts.
#[test]
fn prop_clustered_femodel_matches_dense_oracle() {
    for case in 0..6 {
        let mut rng = Rng::new(12_000 + case);
        let cfg = ModelConfig {
            image_size: 6 + rng.below(5),
            in_channels: 1 + rng.below(3),
            widths: vec![2 + rng.below(6), 4 + rng.below(8)],
            blocks_per_stage: 1 + rng.below(2),
            feature_dim: 16,
            d: 32,
            ch_sub: 1 + rng.below(5),
            n_centroids: 2 + rng.below(15),
            clustered: true,
            master_seed: 0xF51_4D17 + case,
        };
        let m = FeModel::synthetic(cfg.clone());
        assert!(m.is_clustered(), "case {case}");
        let oracle = m.dense_reconstruction();
        let images: Vec<Vec<f32>> = (0..5)
            .map(|_| {
                (0..cfg.image_size * cfg.image_size * cfg.in_channels)
                    .map(|_| rng.gauss_f32())
                    .collect()
            })
            .collect();
        let serial: Vec<_> = images.iter().map(|img| m.forward(img).unwrap()).collect();
        for (img, got) in images.iter().zip(&serial) {
            let want = oracle.forward(img).unwrap();
            assert_eq!(got.len(), want.len(), "case {case}");
            for (gb, wb) in got.iter().zip(&want) {
                for (a, b) in gb.iter().zip(wb) {
                    assert!(
                        (a - b).abs() < 1e-3 * (1.0 + b.abs()),
                        "case {case}: clustered {a} vs oracle {b}"
                    );
                }
            }
        }
        for workers in [2usize, 3, 7] {
            assert_eq!(
                m.forward_batch(&images, workers).unwrap(),
                serial,
                "case {case} workers={workers}: clustered forward must be bit-identical"
            );
        }
    }
}

/// k-means labels always point at the nearest centroid; error never grows
/// when N doubles.
#[test]
fn prop_kmeans_nearest_and_monotone() {
    for case in 0..CASES {
        let mut rng = Rng::new(8000 + case);
        let size = 30 + rng.below(200);
        let v: Vec<f32> = (0..size).map(|_| rng.gauss_f32() * rng.range_f32(0.1, 5.0)).collect();
        let mut prev = f64::INFINITY;
        for n in [2usize, 4, 8] {
            let (cents, labels) = kmeans_1d(&v, n, 12);
            let mut mse = 0.0f64;
            for (x, &l) in v.iter().zip(&labels) {
                let dl = (x - cents[l as usize]).abs();
                for c in &cents {
                    assert!(dl <= (x - c).abs() + 1e-5, "case {case}: label not nearest");
                }
                mse += (dl * dl) as f64;
            }
            mse /= v.len() as f64;
            assert!(mse <= prev + 1e-9, "case {case}: error grew with more centroids");
            prev = mse;
        }
    }
}

/// Simulator sanity: cycles scale with work; batching never increases the
/// per-image cycle count; stall fraction grows with frequency.
#[test]
fn prop_sim_monotonicity() {
    for case in 0..CASES {
        let mut rng = Rng::new(9000 + case);
        let geom = ConvGeom {
            cout: 8 * (1 + rng.below(8)),
            cin: 8 * (1 + rng.below(8)),
            k: 3,
            out: 4 + rng.below(28),
            stride: 1,
            stage: 0,
        };
        let cfg = ChipConfig::default();
        let r1 = simulate_layer(&geom, &cfg, 64, 16, 1);
        let r4 = simulate_layer(&geom, &cfg, 64, 16, 4);
        assert_eq!(r4.accum_ops, 4 * r1.accum_ops, "case {case}");
        assert!(
            r4.total_cycles() <= 4 * r1.total_cycles(),
            "case {case}: batching made things worse"
        );
        let bigger = ConvGeom { out: geom.out + 4, ..geom };
        let rb = simulate_layer(&bigger, &cfg, 64, 16, 1);
        assert!(rb.compute_cycles >= r1.compute_cycles, "case {case}: more pixels, fewer cycles");
        let slow = ChipConfig { freq_mhz: 100.0, ..cfg.clone() };
        let rs = simulate_layer(&geom, &slow, 64, 16, 1);
        assert!(
            rs.stall_cycles <= r1.stall_cycles,
            "case {case}: stalls must shrink at lower frequency"
        );
    }
}

/// Session training is permutation-invariant across class order (the
/// batcher may flush classes in any order).
#[test]
fn prop_session_class_order_invariance() {
    use fsl_hdnn::coordinator::session::FslSession;
    for case in 0..20 {
        let mut rng = Rng::new(10_000 + case);
        let d = 64;
        let n_way = 2 + rng.below(4);
        let shots: Vec<Vec<Vec<Vec<f32>>>> = (0..n_way)
            .map(|_| {
                (0..3)
                    .map(|_| (0..4).map(|_| (0..d).map(|_| rng.gauss_f32()).collect()).collect())
                    .collect()
            })
            .collect();
        let mut fwd = FslSession::new(1, n_way, d, 4);
        for (c, s) in shots.iter().enumerate() {
            fwd.train_batch(c, s);
        }
        let mut rev = FslSession::new(2, n_way, d, 4);
        for (c, s) in shots.iter().enumerate().rev() {
            rev.train_batch(c, s);
        }
        let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
        assert_eq!(fwd.query_full(&q).prediction, rev.query_full(&q).prediction, "case {case}");
    }
}

/// The shipped config presets parse and apply cleanly.
#[test]
fn shipped_config_presets_load() {
    use fsl_hdnn::config::{toml::Doc, RunConfig};
    for path in ["configs/paper_10way5shot.toml", "configs/low_power.toml"] {
        let doc = Doc::load(std::path::Path::new(path)).unwrap_or_else(|e| {
            panic!("{path}: {e}");
        });
        let mut rc = RunConfig::default();
        rc.apply_toml(&doc).unwrap_or_else(|e| panic!("{path}: {e}"));
        assert!(rc.batched_training, "{path}: presets use batched training");
        assert!(rc.chip.hv_bits <= 16);
        // both presets pin the session-side HDC knobs and keep them in
        // step with the simulator-side chip precision
        assert_eq!(rc.hdc.hv_bits, rc.chip.hv_bits, "{path}: [hdc] and [chip] hv_bits agree");
    }
    let doc = Doc::load(std::path::Path::new("configs/low_power.toml")).unwrap();
    let mut rc = RunConfig::default();
    rc.apply_toml(&doc).unwrap();
    assert_eq!(rc.hdc.hv_bits, 1, "low-power corner runs binary class HVs");
    assert_eq!(rc.hdc.metric, fsl_hdnn::hdc::Distance::Hamming);
    assert_eq!(
        rc.classifier.backend,
        fsl_hdnn::classifier::ClassifierBackend::Ldc,
        "low-power corner folds to low-D prototypes"
    );
    assert_eq!(rc.classifier.ldc_d, 0, "auto fold dimension");
    // the paper preset pins the headline workload
    let doc = Doc::load(std::path::Path::new("configs/paper_10way5shot.toml")).unwrap();
    let mut rc = RunConfig::default();
    rc.apply_toml(&doc).unwrap();
    assert_eq!((rc.workload.n_way, rc.workload.k_shot), (10, 5));
    assert_eq!(rc.ee, Some(fsl_hdnn::config::EeConfig { e_s: 2, e_c: 2 }));
    assert_eq!(
        rc.classifier.backend,
        fsl_hdnn::classifier::ClassifierBackend::Hdc,
        "the headline preset runs the paper's classifier"
    );
}

/// Dataset presets stay calibrated to the paper's Fig. 15 bands
/// (5-way 5-shot): cifar100 ~72%, flower102 ~94%, trafficsign ~78%,
/// with the ordering FT >= FSL-HDnn > kNN.
#[test]
fn preset_accuracy_bands() {
    use fsl_hdnn::data::DatasetPreset;
    use fsl_hdnn::experiments::{eval_learner, sampler_for, Learner};
    let bands = [
        (DatasetPreset::Cifar100, 0.62, 0.85),
        (DatasetPreset::Flower102, 0.88, 1.0),
        (DatasetPreset::TrafficSign, 0.65, 0.88),
    ];
    for (preset, lo, hi) in bands {
        let s = sampler_for(preset, 128, 5, 5, 8, 7);
        let (hdc, _) = eval_learner(&s, Learner::FslHdnn { d: 4096, bits: 16 }, 8, 11);
        assert!(
            (lo..hi).contains(&hdc),
            "{}: FSL-HDnn accuracy {hdc:.3} outside calibrated band [{lo}, {hi})",
            preset.name()
        );
        let (knn, _) = eval_learner(&s, Learner::Knn, 8, 11);
        assert!(hdc + 0.03 > knn, "{}: HDC must not lose to 1-NN", preset.name());
    }
}
