//! Seeded schedule-perturbation race harness (DESIGN.md §Static analysis,
//! dynamic half). The `shard_map`/`shard_map_mut` determinism contract says
//! sharded results are bit-identical to the serial loop for any shard
//! count; this suite attacks the claim with *adversarial schedules*: pools
//! built with [`WorkerPool::with_perturbation`] delay every task by a
//! seed-derived sub-millisecond interval, deterministically shuffling the
//! order in which chunk jobs complete. If stitching ever depended on
//! completion order (instead of slot position), some seed here would
//! produce a different bit pattern.
//!
//! Coverage: ≥8 seeds × workers {1, 2, 7}, float workloads whose results
//! are order-sensitive under reassociation, compared by exact bit pattern.

use fsl_hdnn::runtime::pool::{with_pool, WorkerPool};
use fsl_hdnn::util::parallel::{shard_map, shard_map_mut};

const SEEDS: [u64; 10] =
    [0, 1, 2, 0xDEAD_BEEF, 42, 7777, 0xFFFF_FFFF_FFFF_FFFF, 0x40A0_2024, 9_999_999_937, 314_159];
const WORKER_COUNTS: [usize; 3] = [1, 2, 7];

/// Order-sensitive f32 fold: reassociating the reduction, or stitching
/// chunks out of order, changes low-order mantissa bits.
#[allow(clippy::ptr_arg)] // shard_map hands the worker &T with T = Vec<f32>
fn float_work(v: &Vec<f32>) -> anyhow::Result<f32> {
    Ok(v.iter().fold(0.0f32, |a, &x| a * 0.9993 + (x * 1.7).sin()))
}

fn float_items(n: usize, d: usize) -> Vec<Vec<f32>> {
    (0..n).map(|i| (0..d).map(|j| ((i * d + j) as f32) * 0.0137 - 3.0).collect()).collect()
}

fn bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn shard_map_bit_identical_under_perturbed_schedules() {
    let items = float_items(48, 32);
    let serial = shard_map(&items, 1, float_work).expect("serial reference");
    for &seed in &SEEDS {
        for &workers in &WORKER_COUNTS {
            let pool = WorkerPool::with_perturbation(workers, seed);
            for shards in [2, workers.max(2), 5, 48] {
                let got = with_pool(&pool, || shard_map(&items, shards, float_work))
                    .expect("perturbed run");
                assert_eq!(
                    bits(&got),
                    bits(&serial),
                    "seed={seed} workers={workers} shards={shards}: \
                     sharded result drifted from serial bits"
                );
            }
            assert_eq!(
                pool.queue_depth(),
                0,
                "seed={seed} workers={workers}: pool gauge must drain to zero"
            );
        }
    }
}

#[test]
fn shard_map_mut_bit_identical_under_perturbed_schedules() {
    // per-item mutable state (the StagedForward shape): each item advances
    // its own accumulator three steps; both the returned values and the
    // final mutated state must match the serial run exactly
    let run = |shards: usize, pool: Option<&WorkerPool>| -> (Vec<u32>, Vec<u32>) {
        let mut items: Vec<f32> = (0..41).map(|i| (i as f32) * 0.61 - 11.0).collect();
        let step = |x: &mut f32| -> anyhow::Result<f32> {
            let mut acc = 0.0f32;
            for _ in 0..3 {
                *x = *x * 1.0009 + 0.25;
                acc = acc * 0.5 + x.cos();
            }
            Ok(acc)
        };
        let out = match pool {
            None => shard_map_mut(&mut items, shards, step).expect("serial reference"),
            Some(p) => {
                with_pool(p, || shard_map_mut(&mut items, shards, step)).expect("perturbed run")
            }
        };
        (bits(&out), bits(&items))
    };
    let (serial_out, serial_state) = run(1, None);
    for &seed in &SEEDS {
        for &workers in &WORKER_COUNTS {
            let pool = WorkerPool::with_perturbation(workers, seed);
            for shards in [2, 7, 41] {
                let (out, state) = run(shards, Some(&pool));
                assert_eq!(out, serial_out, "seed={seed} workers={workers} shards={shards}: out");
                assert_eq!(
                    state, serial_state,
                    "seed={seed} workers={workers} shards={shards}: mutated state"
                );
            }
            assert_eq!(pool.queue_depth(), 0);
        }
    }
}

#[test]
fn perturbed_schedules_are_reproducible_per_seed() {
    // the delays are a pure function of (seed, submit index): two pools
    // with the same seed apply identical per-task delays, so a failing
    // seed from CI can be replayed locally byte-for-byte
    let items = float_items(12, 16);
    for &seed in &SEEDS[..4] {
        let a = {
            let pool = WorkerPool::with_perturbation(2, seed);
            with_pool(&pool, || shard_map(&items, 4, float_work)).expect("first run")
        };
        let b = {
            let pool = WorkerPool::with_perturbation(2, seed);
            with_pool(&pool, || shard_map(&items, 4, float_work)).expect("second run")
        };
        assert_eq!(bits(&a), bits(&b), "seed={seed}: same seed, same result bits");
    }
}
