//! Cross-language golden tests: the rust-native substrates must reproduce
//! the python pipeline's answers recorded in `artifacts/goldens/` at AOT
//! time. This is the contract that makes native class HVs interchangeable
//! with PJRT-produced ones.
//!
//! Skipped (with a distinct `SKIPPED` line, see tests/common/mod.rs) when
//! `make artifacts` has not run.

mod common;

use std::path::{Path, PathBuf};

use fsl_hdnn::fe::FeModel;
use fsl_hdnn::hdc::{distance, lfsr, CrpEncoder};
use fsl_hdnn::util::json::Json;

fn artifacts(test: &str) -> Option<PathBuf> {
    common::artifacts_or_skip(test)
}

fn read_bin(dir: &Path, name: &str) -> Vec<f32> {
    std::fs::read(dir.join("goldens").join(name))
        .unwrap_or_else(|e| panic!("missing golden {name}: {e}"))
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect()
}

fn goldens_json(dir: &Path) -> Json {
    Json::parse(&std::fs::read_to_string(dir.join("goldens").join("goldens.json")).unwrap())
        .unwrap()
}

fn max_abs_err(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn lfsr_matches_python_goldens() {
    let Some(dir) = artifacts("lfsr_matches_python_goldens") else { return };
    let g = goldens_json(&dir);
    let seq = g.get("step_seq_from_ace1").unwrap().as_u64_vec().unwrap();
    let mut s = 0xACE1u16;
    for want in seq {
        s = lfsr::step(s);
        assert_eq!(s as u64, want, "LFSR step sequence diverges");
    }
    let master = g.get("master_seed").unwrap().as_u64().unwrap();
    let row0 = g.get("row0_states").unwrap().as_u64_vec().unwrap();
    let got = lfsr::row_block_states(master, 0);
    assert_eq!(got.iter().map(|&v| v as u64).collect::<Vec<_>>(), row0);
    let row7 = g.get("row7_states").unwrap().as_u64_vec().unwrap();
    let got7 = lfsr::row_block_states(master, 7);
    assert_eq!(got7.iter().map(|&v| v as u64).collect::<Vec<_>>(), row7);
    let step16 = g.get("row0_step16").unwrap().as_u64_vec().unwrap();
    for (s0, want) in got.iter().zip(step16) {
        assert_eq!(lfsr::step16(*s0) as u64, want);
    }
}

#[test]
fn native_fe_matches_python_features() {
    let Some(dir) = artifacts("native_fe_matches_python_features") else { return };
    let fe = FeModel::load(&dir).unwrap();
    let g = goldens_json(&dir);
    let xs = g.get("shapes").unwrap().get("x").unwrap().as_usize_vec().unwrap();
    let fs = g.get("shapes").unwrap().get("feats").unwrap().as_usize_vec().unwrap();
    let x = read_bin(&dir, "x.bin");
    let feats = read_bin(&dir, "feats.bin");
    let per_img = xs[1] * xs[2] * xs[3];
    let per_feat = fs[1] * fs[2];
    for b in 0..xs[0] {
        let branches = fe.forward(&x[b * per_img..(b + 1) * per_img]).unwrap();
        let flat: Vec<f32> = branches.concat();
        let err = max_abs_err(&flat, &feats[b * per_feat..(b + 1) * per_feat]);
        assert!(err < 2e-3, "image {b}: native FE vs python err {err}");
    }
}

#[test]
fn native_crp_matches_python_hv() {
    let Some(dir) = artifacts("native_crp_matches_python_hv") else { return };
    let g = goldens_json(&dir);
    let master = g.get("master_seed").unwrap().as_u64().unwrap();
    let hv_shape = g.get("shapes").unwrap().get("hv").unwrap().as_usize_vec().unwrap();
    let d = hv_shape[1];
    let feats = read_bin(&dir, "feats.bin");
    let hv = read_bin(&dir, "hv.bin");
    let fs = g.get("shapes").unwrap().get("feats").unwrap().as_usize_vec().unwrap();
    let (nb, fdim) = (fs[1], fs[2]);
    let enc = CrpEncoder::new(d, master);
    for b in 0..hv_shape[0] {
        // python encoded the FINAL branch feature (branch nb-1)
        let base = (b * nb + (nb - 1)) * fdim;
        let got = enc.encode(&feats[base..base + fdim]);
        let err = max_abs_err(&got, &hv[b * d..(b + 1) * d]);
        assert!(err < 1e-2, "image {b}: native cRP vs python err {err}");
    }
}

#[test]
fn native_distance_matches_python_table() {
    let Some(dir) = artifacts("native_distance_matches_python_table") else { return };
    let g = goldens_json(&dir);
    let ds = g.get("shapes").unwrap().get("dist").unwrap().as_usize_vec().unwrap();
    let d = g.get("shapes").unwrap().get("hv").unwrap().as_usize_vec().unwrap()[1];
    let hv = read_bin(&dir, "hv.bin");
    let classes = read_bin(&dir, "classes.bin");
    let dist = read_bin(&dir, "dist.bin");
    for b in 0..ds[0] {
        for c in 0..ds[1] {
            let got = distance::l1(&hv[b * d..(b + 1) * d], &classes[c * d..(c + 1) * d]);
            let want = dist[b * ds[1] + c] as f64;
            assert!(
                (got - want).abs() / want.max(1.0) < 1e-4,
                "dist[{b}][{c}]: {got} vs {want}"
            );
        }
    }
}

#[test]
fn native_classes_match_python_encodings() {
    // encode the 4 class features natively and compare to classes.bin
    let Some(dir) = artifacts("native_classes_match_python_encodings") else { return };
    let g = goldens_json(&dir);
    let master = g.get("master_seed").unwrap().as_u64().unwrap();
    let cs = g.get("shapes").unwrap().get("classes").unwrap().as_usize_vec().unwrap();
    let cf = read_bin(&dir, "class_feats.bin");
    let classes = read_bin(&dir, "classes.bin");
    let fdim = g.get("shapes").unwrap().get("class_feats").unwrap().as_usize_vec().unwrap()[1];
    let enc = CrpEncoder::new(cs[1], master);
    for c in 0..cs[0] {
        let got = enc.encode(&cf[c * fdim..(c + 1) * fdim]);
        let err = max_abs_err(&got, &classes[c * cs[1]..(c + 1) * cs[1]]);
        assert!(err < 1e-2, "class {c}: err {err}");
    }
}
