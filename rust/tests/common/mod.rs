//! Shared helpers for the integration test crates.
//!
//! Integration tests that depend on `make artifacts` (and, for PJRT
//! execution, the `pjrt` cargo feature) cannot run from a clean checkout.
//! Rust's libtest has no first-class skip, so the convention here is: call
//! [`skip`] (which prints a distinct, greppable `SKIPPED` line to stderr)
//! and return early. `cargo test -- --nocapture 2>&1 | grep SKIPPED` lists
//! exactly which tests did not really run — a silently green test and a
//! skipped one are no longer indistinguishable (DESIGN.md §Test skips).

// each integration-test crate includes this module and uses a subset
#![allow(dead_code)]

use std::path::PathBuf;

/// The artifacts directory, if `make artifacts` has populated it.
///
/// Integration tests run with the package root (`rust/`) as CWD while
/// `make artifacts` writes to the repository root, so both locations are
/// probed.
pub fn artifacts_dir() -> Option<PathBuf> {
    for cand in ["artifacts", "../artifacts"] {
        let dir = PathBuf::from(cand);
        if dir.join("manifest.json").exists() {
            return Some(dir);
        }
    }
    None
}

/// Report a skipped test distinctly. Prints one machine-greppable line.
pub fn skip(test: &str, reason: &str) {
    eprintln!("SKIPPED {test}: {reason}");
}

/// `artifacts_dir()` or a distinct skip report for `test`.
pub fn artifacts_or_skip(test: &str) -> Option<PathBuf> {
    let dir = artifacts_dir();
    if dir.is_none() {
        skip(test, "no artifacts/ directory (run `make artifacts`)");
    }
    dir
}
