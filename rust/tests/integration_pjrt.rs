//! PJRT runtime integration: load every artifact, execute it, and verify
//! the PJRT and native backends produce interchangeable results — the
//! "device" and its rust mirror must agree bit-for-bit (within f32 assoc).
//!
//! Skip conditions (each reported with a distinct `SKIPPED` line, see
//! tests/common/mod.rs and DESIGN.md §Test skips):
//!  * no `artifacts/` directory — run `make artifacts`;
//!  * execution tests additionally need the `pjrt` cargo feature (the
//!    xla-rs bindings are not in the offline registry). Manifest-only
//!    tests still run with artifacts present.

mod common;

use std::path::PathBuf;

use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::runtime::ArtifactRegistry;
use fsl_hdnn::util::prng::Rng;

/// Artifacts dir for manifest-only tests (no PJRT execution involved).
fn artifacts(test: &str) -> Option<PathBuf> {
    common::artifacts_or_skip(test)
}

/// Artifacts dir for tests that execute artifacts through PJRT.
fn artifacts_with_pjrt(test: &str) -> Option<PathBuf> {
    let dir = common::artifacts_or_skip(test)?;
    if !ArtifactRegistry::pjrt_available() {
        common::skip(test, "built without the `pjrt` cargo feature (see DESIGN.md)");
        return None;
    }
    Some(dir)
}

#[test]
fn registry_loads_and_signatures_sane() {
    let Some(dir) = artifacts("registry_loads_and_signatures_sane") else { return };
    let reg = ArtifactRegistry::open(&dir).unwrap();
    let names = reg.entry_names();
    let required_entries = [
        "fe_forward_b1",
        "fe_forward_b8",
        "crp_encode_b1",
        "crp_encode_b8",
        "hdc_infer_b1",
        "hdc_train_k5",
        "fsl_infer_b1",
    ];
    for required in required_entries {
        assert!(names.iter().any(|n| n == required), "missing artifact {required}");
    }
    let sig = reg.signature("fe_forward_b1").unwrap();
    assert_eq!(sig.input_shapes.len(), 1);
    assert_eq!(sig.input_shapes[0][0], 1);
    assert_eq!(sig.output_shapes[0].len(), 3);
    assert_eq!(reg.compiled_count(), 0, "compilation must be lazy");
}

#[test]
fn exec_rejects_bad_shapes() {
    // shape/arity validation runs before compilation, so this test is
    // meaningful with or without the pjrt feature
    let Some(dir) = artifacts("exec_rejects_bad_shapes") else { return };
    let reg = ArtifactRegistry::open(&dir).unwrap();
    let bad = vec![0f32; 10];
    assert!(reg.exec_f32("fe_forward_b1", &[(&bad, &[1, 10])]).is_err());
    assert!(reg.exec_f32("nonexistent", &[]).is_err());
    let sig = reg.signature("crp_encode_b1").unwrap().clone();
    let n: usize = sig.input_shapes[0].iter().product();
    // right shape, wrong data length
    let short = vec![0f32; n - 1];
    assert!(reg
        .exec_f32("crp_encode_b1", &[(&short, &sig.input_shapes[0].clone())])
        .is_err());
}

#[test]
fn pjrt_and_native_backends_agree() {
    let Some(dir) = artifacts_with_pjrt("pjrt_and_native_backends_agree") else { return };
    let native = ComputeEngine::open(Backend::Native, &dir).unwrap();
    let pjrt = ComputeEngine::open(Backend::Pjrt, &dir).unwrap();
    let m = native.model().clone();
    let mut rng = Rng::new(33);
    let images: Vec<Vec<f32>> = (0..3)
        .map(|_| {
            (0..m.image_size * m.image_size * m.in_channels)
                .map(|_| rng.gauss_f32())
                .collect()
        })
        .collect();
    let fn_ = native.fe_forward(&images).unwrap();
    let fp = pjrt.fe_forward(&images).unwrap();
    for (bi, (a, b)) in fn_.iter().zip(&fp).enumerate() {
        for (br, (fa, fb)) in a.iter().zip(b).enumerate() {
            for (i, (x, y)) in fa.iter().zip(fb).enumerate() {
                assert!(
                    (x - y).abs() < 2e-3,
                    "image {bi} branch {br} feat {i}: native {x} vs pjrt {y}"
                );
            }
        }
    }
    // encode agreement on the final branch features
    let feats: Vec<Vec<f32>> = fn_.iter().map(|b| b[b.len() - 1].clone()).collect();
    let hn = native.encode(&feats).unwrap();
    let hp = pjrt.encode(&feats).unwrap();
    for (a, b) in hn.iter().zip(&hp) {
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-2, "encode: native {x} vs pjrt {y}");
        }
    }
}

#[test]
fn pjrt_batch8_equals_batch1() {
    let Some(dir) = artifacts_with_pjrt("pjrt_batch8_equals_batch1") else { return };
    let pjrt = ComputeEngine::open(Backend::Pjrt, &dir).unwrap();
    let m = pjrt.model().clone();
    let mut rng = Rng::new(44);
    let images: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            (0..m.image_size * m.image_size * m.in_channels)
                .map(|_| rng.gauss_f32())
                .collect()
        })
        .collect();
    // 8 at once (fe_forward_b8) vs one-by-one (fe_forward_b1)
    let batched = pjrt.fe_forward(&images).unwrap();
    for (i, img) in images.iter().enumerate() {
        let single = pjrt.fe_forward(std::slice::from_ref(img)).unwrap();
        for (br, (a, b)) in batched[i].iter().zip(&single[0]).enumerate() {
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-3, "img {i} branch {br}: b8 {x} vs b1 {y}");
            }
        }
    }
}

#[test]
fn fused_fsl_infer_matches_staged_path() {
    let Some(dir) = artifacts_with_pjrt("fused_fsl_infer_matches_staged_path") else { return };
    let reg = ArtifactRegistry::open(&dir).unwrap();
    let pjrt = ComputeEngine::open(Backend::Pjrt, &dir).unwrap();
    let m = pjrt.model().clone();
    let mut rng = Rng::new(55);
    let image: Vec<f32> =
        (0..m.image_size * m.image_size * m.in_channels).map(|_| rng.gauss_f32()).collect();
    // staged: fe -> encode -> native L1 distances
    let feats = pjrt.fe_forward(std::slice::from_ref(&image)).unwrap();
    let hv = pjrt.encode(&[feats[0][m.n_branches() - 1].clone()]).unwrap();
    // random class HVs
    let cmax = 32;
    let classes: Vec<f32> = (0..cmax * m.d).map(|_| rng.gauss_f32()).collect();
    let staged: Vec<f64> = (0..cmax)
        .map(|c| fsl_hdnn::hdc::distance::l1(&hv[0], &classes[c * m.d..(c + 1) * m.d]))
        .collect();
    // fused artifact
    let out = reg
        .exec_f32(
            "fsl_infer_b1",
            &[(&image, &[1, m.image_size, m.image_size, m.in_channels]),
              (&classes, &[cmax, m.d])],
        )
        .unwrap();
    for (c, want) in staged.iter().enumerate() {
        let got = out[0][c] as f64;
        assert!(
            (got - want).abs() / want.max(1.0) < 1e-3,
            "class {c}: fused {got} vs staged {want}"
        );
    }
}
