//! Self-check suite for `fsl_lint` (DESIGN.md §Static analysis): one tiny
//! violating fixture per rule asserting detection, allow fixtures asserting
//! suppression (with the justification requirement), allowlist fixtures for
//! the sanctioned spawn sites, and — the CI gate — a run over the real tree
//! asserting zero unsuppressed violations.
//!
//! Fixtures are in-memory [`SourceFile`]s with synthetic repo-relative
//! paths, so path-scoped rules (serving modules, kernel dirs, packed hot
//! paths) can be exercised without touching the disk tree.

use std::path::Path;

use fsl_hdnn::util::lint::{lint_files, lint_tree, Report, Rule, SourceFile};

fn sf(path: &str, text: &str) -> SourceFile {
    SourceFile { path: path.into(), text: text.into() }
}

fn lint_one(path: &str, text: &str) -> Report {
    lint_files(&[sf(path, text)])
}

fn hits(report: &Report, rule: Rule) -> usize {
    report.violations.iter().filter(|v| v.rule == rule).count()
}

// -- nan-unsafe-ord ---------------------------------------------------------

#[test]
fn detects_nan_unsafe_sorts_anywhere() {
    let bad = r#"
fn p(v: &mut Vec<f64>) {
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
}
"#;
    let r = lint_one("rust/src/data/metrics.rs", bad);
    assert_eq!(hits(&r, Rule::NanUnsafeOrd), 1, "{:?}", r.violations);

    // bare partial_cmp().unwrap() without a sort is still a violation
    let bad2 = "fn m(a: f32, b: f32) -> std::cmp::Ordering { a.partial_cmp(&b).unwrap() }\n";
    assert_eq!(hits(&lint_one("rust/benches/x.rs", bad2), Rule::NanUnsafeOrd), 1);

    // total_cmp is the sanctioned idiom
    let good = "fn p(v: &mut Vec<f64>) { v.sort_by(|a, b| a.total_cmp(b)); }\n";
    assert!(lint_one("rust/src/data/metrics.rs", good).ok());
}

#[test]
fn justified_allow_suppresses_nan_rule() {
    let src = "\
// lint:allow(nan-unsafe-ord) inputs proven finite three lines up
fn p(v: &mut Vec<f64>) { v.sort_by(|a, b| a.partial_cmp(b).unwrap()); }
";
    let r = lint_one("rust/src/data/metrics.rs", src);
    assert!(r.ok(), "{:?}", r.violations);
    assert_eq!(r.suppressed.len(), 1);
}

// -- raw-spawn --------------------------------------------------------------

#[test]
fn detects_raw_spawn_outside_allowlist() {
    let bad = "fn go() { std::thread::spawn(move || {}); }\n";
    assert_eq!(hits(&lint_one("rust/src/data/loader.rs", bad), Rule::RawSpawn), 1);
    assert_eq!(hits(&lint_one("examples/my_tool.rs", bad), Rule::RawSpawn), 1);
    let builder = "fn go() { std::thread::Builder::new().spawn(move || {}); }\n";
    assert_eq!(hits(&lint_one("rust/src/sim/run.rs", builder), Rule::RawSpawn), 1);
}

#[test]
fn sanctioned_spawn_sites_are_allowlisted() {
    // the three sanctioned sites in the real tree: the worker pool's own
    // threads, the gateway's accept/connection threads, and the
    // coordinator's event-loop thread (server.rs)
    let spawn = "fn go() { std::thread::spawn(move || {}); }\n";
    for path in [
        "rust/src/runtime/pool.rs",
        "rust/src/coordinator/gateway.rs",
        "rust/src/coordinator/server.rs",
    ] {
        let r = lint_files(&[sf(path, spawn)]);
        assert_eq!(hits(&r, Rule::RawSpawn), 0, "{path} is sanctioned");
    }
    // scoped joins are structured concurrency — never flagged (this is
    // what examples/load_gen.rs uses for its client threads)
    let scoped = "fn go() { std::thread::scope(|s| { s.spawn(|| {}); }); }\n";
    assert!(lint_one("examples/load_gen.rs", scoped).ok());
    // test modules may spawn freely
    let in_test = "#[cfg(test)]\nmod t { fn go() { std::thread::spawn(|| {}); } }\n";
    assert!(lint_one("rust/src/data/loader.rs", in_test).ok());
}

// -- panic-in-serving -------------------------------------------------------

#[test]
fn detects_panics_in_serving_modules() {
    let cases = [
        "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n",
        "fn f(x: Option<u32>) -> u32 { x.expect(\"present\") }\n",
        "fn f() { panic!(\"boom\"); }\n",
        "fn f() { unreachable!(); }\n",
    ];
    for bad in cases {
        let r = lint_one("rust/src/coordinator/session.rs", bad);
        assert_eq!(hits(&r, Rule::PanicInServing), 1, "snippet: {bad:?}");
        let r = lint_one("rust/src/classifier/ldc.rs", bad);
        assert_eq!(hits(&r, Rule::PanicInServing), 1, "classifier scope: {bad:?}");
    }
    // the same code outside serving modules is not this rule's business
    let r = lint_one("rust/src/experiments/fig3.rs", cases[0]);
    assert_eq!(hits(&r, Rule::PanicInServing), 0);
    // test modules inside serving files are exempt
    let in_test = "#[cfg(test)]\nmod t { fn f(x: Option<u32>) -> u32 { x.unwrap() } }\n";
    assert!(lint_one("rust/src/coordinator/wire.rs", in_test).ok());
}

#[test]
fn allow_without_justification_does_not_suppress() {
    let bare = "\
// lint:allow(panic-in-serving)
fn f(x: Option<u32>) -> u32 { x.unwrap() }
";
    let r = lint_one("rust/src/coordinator/router.rs", bare);
    assert_eq!(r.violations.len(), 1, "bare allow must not count");
    assert!(r.violations[0].msg.contains("justification"), "{}", r.violations[0].msg);

    let justified = "\
// lint:allow(panic-in-serving) key inserted by the entry() call above
fn f(x: Option<u32>) -> u32 { x.unwrap() }
";
    let r = lint_one("rust/src/coordinator/router.rs", justified);
    assert!(r.ok(), "{:?}", r.violations);
    assert_eq!(r.suppressed.len(), 1);
}

// -- wall-clock-in-kernel ---------------------------------------------------

#[test]
fn detects_wall_clock_in_kernels() {
    let bad = "fn conv() { let t0 = std::time::Instant::now(); let _ = t0; }\n";
    for path in ["rust/src/fe/conv.rs", "rust/src/hdc/encode.rs", "rust/src/classifier/ldc.rs"] {
        assert_eq!(hits(&lint_one(path, bad), Rule::WallClockInKernel), 1, "{path}");
    }
    let sys = "fn now() -> std::time::SystemTime { std::time::SystemTime::now() }\n";
    assert_eq!(hits(&lint_one("rust/src/fe/stages.rs", sys), Rule::WallClockInKernel), 1);
    // the coordinator layer is where timing belongs
    assert!(lint_one("rust/src/coordinator/server.rs", bad).ok());
    // kernel tests may time themselves
    let in_test = "#[cfg(test)]\nmod t { fn f() { let _ = std::time::Instant::now(); } }\n";
    assert!(lint_one("rust/src/hdc/packed.rs", in_test).ok());
}

#[test]
fn justified_allow_suppresses_wall_clock_rule() {
    let src = "\
// lint:allow(wall-clock-in-kernel) one-shot self-calibration, result cached
fn cal() { let _ = std::time::Instant::now(); }
";
    let r = lint_one("rust/src/fe/conv.rs", src);
    assert!(r.ok(), "{:?}", r.violations);
    assert_eq!(r.suppressed.len(), 1);
}

// -- unchecked-narrowing ----------------------------------------------------

#[test]
fn detects_unguarded_narrowing_in_packed_paths() {
    let bad = "fn pack(x: i32) -> u8 { x as u8 }\n";
    assert_eq!(hits(&lint_one("rust/src/hdc/packed.rs", bad), Rule::UncheckedNarrowing), 1);
    assert_eq!(hits(&lint_one("rust/src/fe/conv.rs", bad), Rule::UncheckedNarrowing), 1);
    // a guard within two lines sanctions the cast
    let guarded = "\
fn pack(x: i32) -> u8 {
    debug_assert!(u8::try_from(x).is_ok());
    x as u8
}
";
    assert!(lint_one("rust/src/hdc/packed.rs", guarded).ok());
    // the rule binds only in the packed hot paths
    assert!(lint_one("rust/src/sim/energy.rs", bad).ok());
    // widening casts are fine anywhere
    let widen = "fn w(x: u8) -> u32 { x as u32 }\n";
    assert!(lint_one("rust/src/hdc/packed.rs", widen).ok());
}

#[test]
fn justified_allow_suppresses_narrowing_rule() {
    let src = "\
fn reinterpret(n: u8) -> i8 {
    // lint:allow(unchecked-narrowing) same-width reinterpret, no bits lost
    n as i8
}
";
    let r = lint_one("rust/src/hdc/packed.rs", src);
    assert!(r.ok(), "{:?}", r.violations);
    assert_eq!(r.suppressed.len(), 1);
}

// -- failpoint-registry -----------------------------------------------------

fn registry_fixture(known: &str, call_site: &str) -> Vec<SourceFile> {
    let fp = format!("pub fn check(_s: &str) {{}}\nconst KNOWN: &[&str] = &[{known}];\n");
    let caller = format!("fn f() {{ crate::util::failpoint::check({call_site}); }}\n");
    vec![sf("rust/src/util/failpoint.rs", &fp), sf("rust/src/coordinator/server.rs", &caller)]
}

#[test]
fn detects_unregistered_failpoint_site() {
    let files = registry_fixture("\"device.query\"", "\"not.registered\"");
    let r = lint_files(&files);
    assert_eq!(hits(&r, Rule::FailpointRegistry), 2, "{:?}", r.violations);
    let msgs: Vec<&str> = r.violations.iter().map(|v| v.msg.as_str()).collect();
    assert!(msgs.iter().any(|m| m.contains("not.registered")), "unregistered site flagged");
    assert!(msgs.iter().any(|m| m.contains("device.query")), "dead registry entry flagged");
}

#[test]
fn registered_and_used_sites_are_clean() {
    let files = registry_fixture("\"device.query\"", "\"device.query\"");
    let r = lint_files(&files);
    assert!(r.ok(), "{:?}", r.violations);
}

#[test]
fn detects_wire_variant_missing_a_codec_arm() {
    let request = "\
pub enum Request {
    Ping,
    Pong,
}
pub enum Response {
    Ack,
}
";
    // Ping has encode + decode arms; Pong only encodes; Ack has both
    let wire = "\
fn encode(r: &Request) {
    match r { Request::Ping => {}, Request::Pong => {} }
}
fn decode() -> Request { Request::Ping }
fn codec_resp(x: &Response) { match x { Response::Ack => {} } }
fn decode_resp() -> Response { Response::Ack }
";
    let r = lint_files(&[
        sf("rust/src/coordinator/request.rs", request),
        sf("rust/src/coordinator/wire.rs", wire),
    ]);
    assert_eq!(hits(&r, Rule::FailpointRegistry), 1, "{:?}", r.violations);
    assert!(r.violations[0].msg.contains("Request::Pong"), "{}", r.violations[0].msg);
}

// -- diagnostics & report shape --------------------------------------------

#[test]
fn diagnostics_carry_file_line_and_rule_id() {
    let bad = "fn a() {}\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    let r = lint_one("rust/src/coordinator/session.rs", bad);
    assert_eq!(r.violations.len(), 1);
    let v = &r.violations[0];
    assert_eq!(v.line, 2, "1-based line of the offending text");
    let rendered = v.render();
    assert!(
        rendered.starts_with("rust/src/coordinator/session.rs:2: [panic-in-serving]"),
        "{rendered}"
    );
}

#[test]
fn patterns_inside_strings_and_comments_never_fire() {
    let tricky = r#"
// this comment mentions partial_cmp().unwrap() and thread::spawn(
fn f() -> &'static str {
    "sort_by(|a, b| a.partial_cmp(b).unwrap()) std::thread::spawn("
}
"#;
    let r = lint_one("rust/src/coordinator/session.rs", tricky);
    assert!(r.ok(), "{:?}", r.violations);
}

// -- the CI gate: the real tree is clean ------------------------------------

#[test]
fn real_tree_has_zero_unsuppressed_violations() {
    // CARGO_MANIFEST_DIR is <repo>/rust; the linted roots hang off <repo>
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root above rust/");
    let report = lint_tree(root).expect("tree walk");
    let rendered: Vec<String> = report.violations.iter().map(|v| v.render()).collect();
    assert!(
        report.ok(),
        "fsl-lint found unsuppressed violations in the tree:\n{}",
        rendered.join("\n")
    );
    assert!(report.files_scanned >= 60, "walked {} files — tree roots missing?", report.files_scanned);
    // the deliberate suppressions (e.g. hdc/packed.rs nibble sign-extend)
    // are present and all carry written justifications
    assert!(!report.suppressed.is_empty(), "expected at least one justified suppression");
}
