//! Serving-stack integration: the TCP gateway on a loopback socket must
//! be a transparent front for the in-process [`Coordinator`] —
//! bit-identical responses under concurrent clients at every worker
//! count — plus admission control, wire robustness against hostile
//! bytes, and the create/drop lifecycle of the persistent worker pool.
//!
//! Everything here runs on the tiny synthetic geometry: no artifacts, no
//! skips.

mod common;

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use fsl_hdnn::config::{EeConfig, ModelConfig, ParallelConfig, ServingConfig};
use fsl_hdnn::coordinator::{wire, Coordinator, Gateway, Request, Response, WireClient};
use fsl_hdnn::coordinator::session::QueryOutcome;
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::runtime::engine::ComputeEngine;
use fsl_hdnn::runtime::WorkerPool;
use fsl_hdnn::util::prng::Rng;

const N_WAY: usize = 3;
const K_SHOT: usize = 2;
const CAP: usize = 1 << 20;

/// Same tiny geometry as integration_coordinator.rs (2 branches).
fn synthetic_cfg() -> ModelConfig {
    ModelConfig {
        image_size: 8,
        in_channels: 3,
        widths: vec![4, 8],
        blocks_per_stage: 1,
        feature_dim: 8,
        d: 64,
        ch_sub: 4,
        n_centroids: 8,
        ..Default::default()
    }
}

fn start_synthetic(k_shot: usize, workers: usize) -> Coordinator {
    let cfg = synthetic_cfg();
    let par = ParallelConfig { workers, min_batch_per_worker: 1 };
    Coordinator::start(move || Ok(ComputeEngine::from_config(cfg).with_parallelism(par)), k_shot)
        .unwrap()
}

fn loopback_cfg(high_water: usize) -> ServingConfig {
    ServingConfig { high_water, ..Default::default() }
}

/// One serving surface, scripted identically in-process and over the
/// wire — the abstraction the bit-identity contract is stated against.
trait Drive {
    fn create(&mut self, n_way: usize) -> u64;
    fn add_shot(&mut self, sid: u64, class: usize, image: Vec<f32>);
    fn finish(&mut self, sid: u64) -> usize;
    fn query(&mut self, sid: u64, image: Vec<f32>, ee: Option<EeConfig>) -> QueryOutcome;
    fn query_batch(
        &mut self,
        sid: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> Vec<QueryOutcome>;
    fn close(&mut self, sid: u64);
}

impl Drive for Coordinator {
    fn create(&mut self, n_way: usize) -> u64 {
        self.create_session(n_way, 16).unwrap()
    }
    fn add_shot(&mut self, sid: u64, class: usize, image: Vec<f32>) {
        Coordinator::add_shot(self, sid, class, image).unwrap()
    }
    fn finish(&mut self, sid: u64) -> usize {
        self.finish_training(sid).unwrap()
    }
    fn query(&mut self, sid: u64, image: Vec<f32>, ee: Option<EeConfig>) -> QueryOutcome {
        Coordinator::query(self, sid, image, ee).unwrap()
    }
    fn query_batch(
        &mut self,
        sid: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> Vec<QueryOutcome> {
        Coordinator::query_batch(self, sid, images, ee).unwrap()
    }
    fn close(&mut self, sid: u64) {
        match self.call(Request::CloseSession { session: sid }) {
            Response::SessionClosed { .. } => {}
            other => panic!("close failed: {other:?}"),
        }
    }
}

impl Drive for WireClient {
    fn create(&mut self, n_way: usize) -> u64 {
        self.create_session(n_way, 16).unwrap()
    }
    fn add_shot(&mut self, sid: u64, class: usize, image: Vec<f32>) {
        WireClient::add_shot(self, sid, class, image).unwrap()
    }
    fn finish(&mut self, sid: u64) -> usize {
        self.finish_training(sid).unwrap()
    }
    fn query(&mut self, sid: u64, image: Vec<f32>, ee: Option<EeConfig>) -> QueryOutcome {
        WireClient::query(self, sid, image, ee).unwrap()
    }
    fn query_batch(
        &mut self,
        sid: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> Vec<QueryOutcome> {
        WireClient::query_batch(self, sid, images, ee).unwrap()
    }
    fn close(&mut self, sid: u64) {
        self.close_session(sid).unwrap()
    }
}

/// One client's deterministic session script, parameterized by `seed`:
/// create → train N_WAY x K_SHOT → per-image queries (EE on even seeds)
/// → one batched query → close. Returns every outcome in issue order.
fn script(d: &mut impl Drive, seed: u64) -> Vec<QueryOutcome> {
    let gen = ImageGen::new(8, 8, seed);
    let mut rng = Rng::new(seed);
    let sid = d.create(N_WAY);
    for class in 0..N_WAY {
        for _ in 0..K_SHOT {
            d.add_shot(sid, class, gen.sample(class, &mut rng));
        }
    }
    assert_eq!(d.finish(sid), N_WAY * K_SHOT);
    let ee = (seed % 2 == 0).then_some(EeConfig { e_s: 1, e_c: 1 });
    let mut outs = Vec::new();
    for i in 0..6 {
        outs.push(d.query(sid, gen.sample(i % N_WAY, &mut rng), ee));
    }
    let batch: Vec<Vec<f32>> = (0..4).map(|i| gen.sample(i % N_WAY, &mut rng)).collect();
    outs.extend(d.query_batch(sid, batch, ee));
    d.close(sid);
    outs
}

/// The tentpole acceptance check: N concurrent clients through the
/// loopback gateway get responses bit-identical to the same scripts run
/// serially against an in-process serial coordinator — at every worker
/// count the determinism contract is stated for (DESIGN.md §Threading
/// model).
#[test]
fn gateway_is_bit_identical_to_in_process_coordinator() {
    const SEEDS: [u64; 3] = [100, 101, 102];
    // ground truth: serial in-process coordinator, scripts run one by one
    let mut baseline = start_synthetic(K_SHOT, 1);
    let expected: Vec<Vec<QueryOutcome>> =
        SEEDS.iter().map(|&s| script(&mut baseline, s)).collect();
    drop(baseline);

    for workers in [1usize, 2, 7] {
        let coord = start_synthetic(K_SHOT, workers);
        let gateway = Gateway::bind(coord.client(), &loopback_cfg(10_000)).unwrap();
        let addr = gateway.local_addr();
        let handles: Vec<_> = SEEDS
            .iter()
            .map(|&seed| {
                std::thread::spawn(move || {
                    let mut wc = WireClient::connect(addr).unwrap();
                    script(&mut wc, seed)
                })
            })
            .collect();
        for (h, want) in handles.into_iter().zip(&expected) {
            let got = h.join().unwrap();
            assert_eq!(&got, want, "workers={workers}");
        }
    }
}

/// Held load slots model a backed-up queue with zero timing races: past
/// the high-water mark the gateway must shed with `Busy { queue_depth }`,
/// count the shed, and admit again once the queue drains.
#[test]
fn gateway_sheds_past_high_water_and_recovers() {
    let coord = start_synthetic(1, 1);
    let gateway = Gateway::bind(coord.client(), &loopback_cfg(2)).unwrap();
    let mut wc = WireClient::connect(gateway.local_addr()).unwrap();
    let load = coord.serving_load();

    let slots = [load.occupy(), load.occupy(), load.occupy()];
    assert_eq!(load.queue_depth(), 3);
    match wc.call(&Request::GetMetrics).unwrap() {
        Response::Busy { queue_depth } => assert_eq!(queue_depth, 3),
        other => panic!("expected Busy at depth 3 > high_water 2, got {other:?}"),
    }
    // exactly at the mark is admitted — the contract is "exceeds"
    drop(slots);
    let _at_mark = [load.occupy(), load.occupy()];
    let m = wc.metrics().unwrap();
    assert_eq!(m.requests_shed, 1, "one shed counted, then recovered");
}

/// The pool's queued-task gauge feeds the same admission signal: tasks
/// blocked in a worker pool wired to the coordinator's load must push the
/// depth past the mark and shed wire requests, deterministically.
#[test]
fn pool_queue_depth_feeds_the_admission_signal() {
    let coord = start_synthetic(1, 1); // serial engine: no pool of its own
    let load = coord.serving_load();
    let gateway = Gateway::bind(coord.client(), &loopback_cfg(2)).unwrap();
    let mut wc = WireClient::connect(gateway.local_addr()).unwrap();

    let pool = WorkerPool::with_gauge(2, load.pool_gauge());
    let gate = Arc::new((Mutex::new(false), Condvar::new()));
    for _ in 0..4 {
        let gate = gate.clone();
        pool.submit(move || {
            let (m, cv) = &*gate;
            let mut open = m.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
        });
    }
    assert_eq!(load.queue_depth(), 4, "2 in service + 2 queued");
    match wc.call(&Request::GetMetrics).unwrap() {
        Response::Busy { queue_depth } => assert_eq!(queue_depth, 4),
        other => panic!("expected Busy, got {other:?}"),
    }

    // open the gate; the gauge drains as workers finish
    {
        let (m, cv) = &*gate;
        *m.lock().unwrap() = true;
        cv.notify_all();
    }
    for _ in 0..2000 {
        if load.queue_depth() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(load.queue_depth(), 0, "pool gauge must drain after release");
    let m = wc.metrics().unwrap();
    assert_eq!(m.requests_shed, 1);
}

/// Hostile bytes against a live gateway: a well-framed garbage payload
/// gets an `Error` and the connection stays usable; a wire `Shutdown` is
/// refused; an oversized length prefix gets a final `Error` and the
/// connection closed (the stream is desynchronized beyond repair).
#[test]
fn gateway_survives_garbage_and_refuses_wire_shutdown() {
    let coord = start_synthetic(1, 1);
    let gateway = Gateway::bind(coord.client(), &loopback_cfg(64)).unwrap();
    let mut s = TcpStream::connect(gateway.local_addr()).unwrap();

    // complete frame, garbage JSON -> Error, connection survives
    wire::write_frame(&mut s, b"{\"type\":\"warp_drive\"}", CAP).unwrap();
    let frame = wire::read_frame(&mut s, CAP).unwrap().expect("reply frame");
    match wire::decode_response(&frame).unwrap() {
        Response::Error(e) => assert!(e.contains("bad request"), "{e}"),
        other => panic!("expected Error, got {other:?}"),
    }

    // same connection still aligned: a valid request round-trips
    wire::write_frame(&mut s, &wire::encode_request(&Request::GetMetrics), CAP).unwrap();
    let frame = wire::read_frame(&mut s, CAP).unwrap().expect("reply frame");
    assert!(matches!(wire::decode_response(&frame).unwrap(), Response::Metrics(_)));

    // shutdown stays a local-owner operation
    wire::write_frame(&mut s, &wire::encode_request(&Request::Shutdown), CAP).unwrap();
    let frame = wire::read_frame(&mut s, CAP).unwrap().expect("reply frame");
    match wire::decode_response(&frame).unwrap() {
        Response::Error(e) => assert!(e.contains("shutdown"), "{e}"),
        other => panic!("expected Error, got {other:?}"),
    }

    // a length prefix over the server's cap: best-effort Error, then EOF
    s.write_all(&u32::MAX.to_be_bytes()).unwrap();
    s.flush().unwrap();
    let frame = wire::read_frame(&mut s, CAP).unwrap().expect("final error frame");
    match wire::decode_response(&frame).unwrap() {
        Response::Error(e) => assert!(e.contains("framing"), "{e}"),
        other => panic!("expected framing Error, got {other:?}"),
    }
    assert!(
        wire::read_frame(&mut s, CAP).unwrap().is_none(),
        "gateway must close a desynchronized connection"
    );

    // the coordinator outlived all of it
    let mut wc = WireClient::connect(gateway.local_addr()).unwrap();
    assert!(wc.metrics().is_ok());
}

/// The classifier seam over the wire: `CreateSession` carries `backend`,
/// the gateway serves both, and wire answers stay bit-identical to the
/// in-process coordinator trained on the same shots.
#[test]
fn gateway_serves_both_classifier_backends_bit_identically() {
    use fsl_hdnn::classifier::ClassifierBackend;
    use fsl_hdnn::hdc::Distance;
    for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
        let coord = start_synthetic(K_SHOT, 2);
        let gateway = Gateway::bind(coord.client(), &loopback_cfg(64)).unwrap();
        let mut wc = WireClient::connect(gateway.local_addr()).unwrap();
        let sid_wire = wc.create_session_full(N_WAY, 16, Distance::L1, backend).unwrap();
        let sid_local = coord.create_session_full(N_WAY, 16, Distance::L1, backend).unwrap();
        let gen = ImageGen::new(8, 8, 7);
        let mut rng = Rng::new(7);
        for class in 0..N_WAY {
            for _ in 0..K_SHOT {
                let img = gen.sample(class, &mut rng);
                wc.add_shot(sid_wire, class, img.clone()).unwrap();
                Coordinator::add_shot(&coord, sid_local, class, img).unwrap();
            }
        }
        assert_eq!(wc.finish_training(sid_wire).unwrap(), N_WAY * K_SHOT);
        coord.finish_training(sid_local).unwrap();
        for i in 0..6 {
            let img = gen.sample(i % N_WAY, &mut rng);
            let got = WireClient::query(&mut wc, sid_wire, img.clone(), None).unwrap();
            let want = Coordinator::query(&coord, sid_local, img, None).unwrap();
            assert_eq!(got, want, "{backend:?} q={i}: wire must match in-process");
        }
        // an unknown backend name on the raw wire is an Error frame, not
        // a dead connection
        let mut s = TcpStream::connect(gateway.local_addr()).unwrap();
        wire::write_frame(
            &mut s,
            br#"{"type":"create_session","n_way":2,"hv_bits":16,"metric":"l1","backend":"svm"}"#,
            CAP,
        )
        .unwrap();
        let frame = wire::read_frame(&mut s, CAP).unwrap().expect("reply frame");
        match wire::decode_response(&frame).unwrap() {
            Response::Error(e) => assert!(e.contains("svm"), "{e}"),
            other => panic!("expected Error for unknown backend, got {other:?}"),
        }
    }
}

/// ISSUE acceptance: `--backend ldc` serves a full 10-way 5-shot episode
/// over TCP. D=256 folds to 64-dim LDC prototypes (a genuine 4x fold),
/// the session trains in a single pass over the wire and answers well
/// above chance.
#[test]
fn ldc_ten_way_five_shot_episode_over_tcp() {
    use fsl_hdnn::classifier::ClassifierBackend;
    use fsl_hdnn::hdc::Distance;
    let (n_way, k_shot) = (10usize, 5usize);
    let cfg = ModelConfig { d: 256, ..synthetic_cfg() };
    let par = ParallelConfig { workers: 2, min_batch_per_worker: 1 };
    let coord = Coordinator::start(
        move || Ok(ComputeEngine::from_config(cfg).with_parallelism(par)),
        k_shot,
    )
    .unwrap();
    let gateway = Gateway::bind(coord.client(), &loopback_cfg(10_000)).unwrap();
    let mut wc = WireClient::connect(gateway.local_addr()).unwrap();
    let sid = wc.create_session_full(n_way, 16, Distance::L1, ClassifierBackend::Ldc).unwrap();
    let gen = ImageGen::new(8, 16, 2026);
    let mut rng = Rng::new(2026);
    for class in 0..n_way {
        for _ in 0..k_shot {
            wc.add_shot(sid, class, gen.sample(class, &mut rng)).unwrap();
        }
    }
    assert_eq!(wc.finish_training(sid).unwrap(), n_way * k_shot);
    let mut correct = 0;
    let total = 30;
    for i in 0..total {
        let class = i % n_way;
        let out = WireClient::query(&mut wc, sid, gen.sample(class, &mut rng), None).unwrap();
        correct += (out.prediction == class) as usize;
    }
    assert!(
        correct * n_way > 2 * total,
        "10-way LDC over TCP must beat chance clearly: {correct}/{total}"
    );
    wc.close_session(sid).unwrap();
}

/// Regression for worker-pool shutdown: create/drop coordinators (each
/// owning a 2-worker persistent pool) in a tight loop, some mid-training,
/// and require every drop to join cleanly — no detached threads, no
/// poisoned-channel panics, no leak that slows later iterations.
#[test]
fn coordinator_create_drop_loop_joins_all_pool_workers() {
    for i in 0..25u64 {
        let mut coord = start_synthetic(1, 2);
        let sid = coord.create(2);
        if i % 3 == 0 {
            // leave real pool work in flight near the drop
            let gen = ImageGen::new(8, 4, i);
            let mut rng = Rng::new(i);
            Coordinator::add_shot(&coord, sid, 0, gen.sample(0, &mut rng)).unwrap();
        }
        drop(coord); // joins worker -> drops pool -> drains + joins
    }
}

/// Stopping the gateway (explicitly or by drop) must join its accept and
/// connection threads and leave the coordinator itself untouched.
#[test]
fn gateway_stop_is_idempotent_and_leaves_coordinator_alive() {
    let coord = start_synthetic(1, 1);
    let mut gateway = Gateway::bind(coord.client(), &loopback_cfg(64)).unwrap();
    let addr = gateway.local_addr();
    let mut wc = WireClient::connect(addr).unwrap();
    assert!(wc.metrics().is_ok());
    gateway.stop();
    gateway.stop(); // idempotent
    drop(gateway); // and drop after stop is a no-op
    assert!(WireClient::connect(addr).is_err() || {
        // a raced listener rebind by another process is theoretically
        // possible; what matters is OUR stack: the old client sees EOF
        let mut wc2 = wc;
        wc2.call(&Request::GetMetrics).is_err()
    });
    // in-process path unaffected
    assert_eq!(coord.metrics().errors, 0);
}

/// Regression for the shutdown hang: a client that sends a frame header
/// and then stalls mid-payload used to pin its connection thread inside a
/// blocking `read_exact`, so `Gateway::stop` never joined. The tick-poll
/// reader plus the stop-side stream shutdown must bound stop latency even
/// with a connection parked mid-frame.
#[test]
fn gateway_stop_is_not_blocked_by_a_client_stalled_mid_frame() {
    let coord = start_synthetic(1, 1);
    let mut gateway = Gateway::bind(coord.client(), &loopback_cfg(64)).unwrap();
    let mut stalled = TcpStream::connect(gateway.local_addr()).unwrap();
    // header promises 64 bytes; send only 8 and go quiet
    stalled.write_all(&64u32.to_be_bytes()).unwrap();
    stalled.write_all(&[b'{'; 8]).unwrap();
    stalled.flush().unwrap();
    // let the accept loop hand the connection to its thread
    std::thread::sleep(Duration::from_millis(100));

    let t0 = Instant::now();
    gateway.stop();
    let took = t0.elapsed();
    // generous bound: a few read ticks plus thread-join slack, far below
    // the "hangs forever" failure mode this guards against
    assert!(took < Duration::from_secs(5), "stop took {took:?} with a stalled client");
    assert_eq!(coord.metrics().errors, 0, "a stalled client is not a coordinator error");
}

/// A server that dies between request and reply must surface as the
/// distinct `ConnectionLost` marker (so retry layers know no reply was
/// seen), and the client must lazily re-dial on the next call rather than
/// staying wedged on the dead socket.
#[test]
fn wire_client_flags_lost_connections_and_redials() {
    use fsl_hdnn::coordinator::gateway::ConnectionLost;
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        // first connection: read the request, close without replying
        let (mut s, _) = listener.accept().unwrap();
        let _ = wire::read_frame(&mut s, CAP).unwrap().expect("request frame");
        drop(s);
        // second connection (the re-dial): reply properly
        let (mut s, _) = listener.accept().unwrap();
        let _ = wire::read_frame(&mut s, CAP).unwrap().expect("request frame");
        let reply = wire::encode_response(&Response::SessionClosed { session: 7 });
        wire::write_frame(&mut s, &reply, CAP).unwrap();
    });

    let mut wc = WireClient::connect(addr).unwrap();
    let err = wc.call(&Request::GetMetrics).unwrap_err();
    assert!(err.is::<ConnectionLost>(), "EOF mid-response must be ConnectionLost, got: {err}");
    assert!(err.to_string().contains("connection lost"), "{err}");
    // next call re-dials the remembered address and succeeds
    match wc.call(&Request::GetMetrics).unwrap() {
        Response::SessionClosed { session } => assert_eq!(session, 7),
        other => panic!("expected the fake server's reply, got {other:?}"),
    }
    server.join().unwrap();
}
