//! Fig. 14(a) software analogue — the class-HV precision sweep over the
//! packed class-memory datapath. The silicon plot shows training power
//! rising with precision because the distance module touches more class
//! bits; the native mirror of that tradeoff is distance-search throughput
//! vs `hv_bits`, packed integer datapath vs the dequantized-f32 oracle,
//! at the paper's 32-class / D=4096 class-memory geometry. Also prints the
//! capacity side of the precision knob (32 @ 16-bit, 128 @ 4-bit) and the
//! `sim::hdc_engine` class-bit traffic each precision pays per query.
//!
//! Numeric asserts are always live: packed distances must match the
//! oracle within f32-association tolerance, predictions must agree, the
//! simd and chunked-scalar kernel lanes must be bitwise identical per
//! (bits, metric) case (`packed_*_simd_vs_scalar_speedup` rows), and
//! the sharded batch path must be bit-identical to serial. `--smoke`
//! shrinks the timing budgets to ~1 ms so CI exercises the harness
//! without paying bench time; `--workers N` sets the sharded row's pool
//! (0 = one per core). `--backend hdc|ldc` picks which classifier the
//! sharded prediction row times; the backend-comparison table (capacity,
//! accuracy, class-mem bits per backend, with the >= 4x LDC reduction
//! assert) always runs both.

use fsl_hdnn::classifier::ClassifierBackend;
use fsl_hdnn::config::ParallelConfig;
use fsl_hdnn::hdc::distance::argmin;
use fsl_hdnn::hdc::{quant, Distance, HdcModel};
use fsl_hdnn::sim::hdc_engine::distance_tally;
use fsl_hdnn::util::args::{arg_flag, arg_str, arg_usize};
use fsl_hdnn::util::bench_log::BenchLog;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::simd::Lane;
use fsl_hdnn::util::table::Table;
use fsl_hdnn::util::timer::{bench, black_box};

fn main() {
    let smoke = arg_flag("--smoke");
    let budget = |ms: f64| if smoke { 1.0 } else { ms };
    let cls_backend = ClassifierBackend::from_name(&arg_str("--backend", "hdc"))
        .expect("--backend takes hdc|ldc");
    let par = ParallelConfig { workers: arg_usize("--workers", 0), min_batch_per_worker: 1 };
    let nw = par.resolved_workers();
    let mut log = BenchLog::new("fig14_precision_sweep");
    let mut rng = Rng::new(14);

    let (classes, d, shots) = (32usize, 4096usize, 3usize);
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..d).map(|_| 2.0 * rng.gauss_f32()).collect())
        .collect();
    let queries: Vec<Vec<f32>> = (0..9)
        .map(|i| {
            protos[i % classes].iter().map(|&p| p + 0.3 * rng.gauss_f32()).collect()
        })
        .collect();

    let mut t = Table::new(
        "Fig. 14(a) analogue: packed distance search vs precision (32 x D=4096)",
        &[
            "bits",
            "metric",
            "packed ns/query",
            "f32 ns/query",
            "speedup",
            "classes @256KB",
            "class bits/query",
        ],
    );
    // the chip's L1 datapath at every precision, plus the binary popcount
    // pairing (1-bit + hamming) the capacity story leans on
    let cases: [(u32, Distance); 5] = [
        (1, Distance::Hamming),
        (1, Distance::L1),
        (4, Distance::L1),
        (8, Distance::L1),
        (16, Distance::L1),
    ];
    for (bits, metric) in cases {
        let mut m = HdcModel::new(classes, d).with_precision(bits).with_metric(metric);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..shots {
                let hv: Vec<f32> = p.iter().map(|&v| v + 0.3 * rng.gauss_f32()).collect();
                m.train_shot(c, &hv);
            }
        }
        let q = &queries[0];

        // numerics first: packed vs oracle, per class and on the argmin
        let packed_d = m.distances(q);
        let oracle_d = m.distances_oracle(q);
        for (c, (a, b)) in packed_d.iter().zip(&oracle_d).enumerate() {
            let mag: f64 = q.iter().map(|v| v.abs() as f64).sum::<f64>() * 4.0;
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs() + mag),
                "bits={bits} {metric:?} class {c}: packed {a} vs oracle {b}"
            );
        }
        assert_eq!(argmin(&packed_d), argmin(&oracle_d), "bits={bits} {metric:?}");
        // sharded batch == serial, bit for bit
        let serial = m.distances_batch(&queries, 1);
        for shards in [2usize, nw.max(2)] {
            assert_eq!(m.distances_batch(&queries, shards), serial, "shards={shards}");
        }

        let packed_name = format!("packed {}b {} 32xD=4096", bits, metric.name());
        let rp = bench(&packed_name, budget(150.0), || {
            black_box(m.distances(black_box(q)));
        });
        // fair f32 baseline: what the pre-packed implementation did per
        // query — evaluate the metric over the cached dequantized rows
        // (distances_oracle re-quantizes per call and would flatter the
        // packed path)
        let rows = m.dequantized_class_hvs();
        let (qd, _) = quant::quantize(q, bits);
        let f32_name = format!("f32    {}b {} 32xD=4096", bits, metric.name());
        let ro = bench(&f32_name, budget(150.0), || {
            let mut acc = 0.0f64;
            for c in 0..classes {
                acc += metric.eval(black_box(&qd), &rows[c * d..(c + 1) * d]);
            }
            black_box(acc);
        });
        println!("{rp}");
        println!("{ro}");
        let tally = distance_tally(d, classes, bits);
        t.row(&[
            bits.to_string(),
            metric.name().into(),
            format!("{:.0}", rp.mean_ns),
            format!("{:.0}", ro.mean_ns),
            format!("{:.2}x", ro.mean_ns / rp.mean_ns),
            quant::classes_capacity(256, d, bits).to_string(),
            tally.class_bits.to_string(),
        ]);
        log.record(
            &format!("packed_{}_b{bits}_32xd4096", metric.name()),
            rp.mean_ns,
            rp.throughput(1.0),
            1,
        );
        log.record(
            &format!("f32_{}_b{bits}_32xd4096", metric.name()),
            ro.mean_ns,
            ro.throughput(1.0),
            1,
        );
        // simd-vs-scalar kernel lanes for this (bits, metric) case,
        // through the lane-explicit entry point (the global dispatch is
        // immutable). Every timed case here is lane-bitwise-identical —
        // asserted before timing. Without the `simd` feature both lanes
        // run the chunked kernels and the ratio sits at ~1.0.
        {
            let packed = m.packed();
            let pq = packed.quantize_query_for(q, metric);
            let chunked = packed.distances_in_lane(&pq, metric, Lane::Chunked);
            let vectored = packed.distances_in_lane(&pq, metric, Lane::Simd);
            assert_eq!(chunked, vectored, "bits={bits} {metric:?}: lanes diverged");
            let chunked_name = format!("chunked {bits}b {} 32xD=4096", metric.name());
            let rc = bench(&chunked_name, budget(150.0), || {
                black_box(packed.distances_in_lane(black_box(&pq), metric, Lane::Chunked));
            });
            println!("{rc}");
            let simd_name = format!("simd    {bits}b {} 32xD=4096", metric.name());
            let rs = bench(&simd_name, budget(150.0), || {
                black_box(packed.distances_in_lane(black_box(&pq), metric, Lane::Simd));
            });
            println!("{rs}");
            log.record_ratio(
                &format!("packed_{}_b{bits}_simd_vs_scalar_speedup", metric.name()),
                rc.mean_ns / rs.mean_ns,
            );
        }
    }
    t.print();
    println!(
        "paper shape check: class-memory capacity 32 @ 16-bit vs 128 @ 4-bit (Section IV-B3),\n\
         class-bit traffic per query scaling {}x from 1b to 16b (the Fig. 14a power slope);\n\
         the 1-bit hamming row is the LDC/ImageHD-style popcount fast path",
        distance_tally(d, classes, 16).class_bits / distance_tally(d, classes, 1).class_bits
    );

    // --- classifier backends: HDC vs LDC at matched n_way (32 x D=4096 in,
    // 4-bit rows). Capacity, accuracy and class-memory bits per backend;
    // the >= 4x LDC class-memory reduction is the PR acceptance ratio.
    let mut tb = Table::new(
        "classifier backends at 32-way, D=4096 ingest, 4-bit class rows",
        &[
            "backend",
            "stored dim",
            "class-mem bits",
            "classes @256KB",
            "accuracy",
            "ns/query",
            "dist uJ/query",
        ],
    );
    let energy = fsl_hdnn::sim::energy::EnergyModel::default();
    let mut mem_bits = Vec::new();
    let mut dist_uj = Vec::new();
    for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
        let mut m = backend.build(classes, d, 4, Distance::L1, 0);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..shots {
                let hv: Vec<f32> = p.iter().map(|&v| v + 0.3 * rng.gauss_f32()).collect();
                m.train_shot(c, &hv);
            }
        }
        // the conformance contract holds behind the trait too: sharded
        // batch distances bit-identical to serial
        let serial = m.distances_batch(&queries, 1);
        for shards in [2usize, 7] {
            assert_eq!(m.distances_batch(&queries, shards), serial, "{backend:?} shards={shards}");
        }
        let correct = queries
            .iter()
            .enumerate()
            .filter(|(i, q)| m.predict(q) == i % classes)
            .count();
        assert_eq!(correct, queries.len(), "{backend:?} must separate the synthetic protos");
        let q = &queries[0];
        let r = bench(&format!("{} dist 32x{}", backend.name(), m.stored_dim()), budget(150.0),
            || {
                black_box(m.distances(black_box(q)));
            });
        println!("{r}");
        // price the distance search with the silicon energy model: the
        // class-bit traffic of one query over this backend's STORED dim
        // (LDC's folded rows touch far fewer class bits than full-D HDC)
        let uj = energy.energy_mj(&distance_tally(m.stored_dim(), classes, 4), energy.v_ref) * 1e3;
        tb.row(&[
            backend.name().into(),
            m.stored_dim().to_string(),
            m.class_mem_bits().to_string(),
            quant::classes_capacity(256, m.stored_dim(), 4).to_string(),
            format!("{}/{}", correct, queries.len()),
            format!("{:.0}", r.mean_ns),
            format!("{uj:.3}"),
        ]);
        dist_uj.push(uj);
        log.record(
            &format!("backend_{}_dist_32way_d4096", backend.name()),
            r.mean_ns,
            r.throughput(1.0),
            1,
        );
        mem_bits.push(m.class_mem_bits());
    }
    tb.print();
    let (hdc_bits, ldc_bits) = (mem_bits[0], mem_bits[1]);
    assert!(
        hdc_bits >= 4 * ldc_bits,
        "LDC must cut class memory >= 4x at matched n_way: hdc {hdc_bits} vs ldc {ldc_bits}"
    );
    println!(
        "backend shape check: LDC class memory {:.1}x smaller than HDC at 32-way \
         (>= 4x required)",
        hdc_bits as f64 / ldc_bits as f64
    );
    assert!(
        dist_uj[1] < dist_uj[0],
        "LDC's folded distance search must cost less energy per query: \
         hdc {:.3} uJ vs ldc {:.3} uJ",
        dist_uj[0],
        dist_uj[1]
    );
    println!(
        "energy shape check: LDC distance search {:.1}x cheaper per query than HDC \
         ({:.3} vs {:.3} uJ at {:.1} V)",
        dist_uj[0] / dist_uj[1],
        dist_uj[1],
        dist_uj[0],
        energy.v_ref
    );

    // sharded prediction throughput at the default precision, through the
    // classifier seam — `--backend ldc` times the folded low-D datapath
    let mut m = cls_backend.build(classes, d, 4, Distance::L1, 0);
    for (c, p) in protos.iter().enumerate() {
        m.train_shot(c, p);
    }
    let preds_serial = m.predict_batch(&queries, 1);
    let rb = bench(
        &format!("{} predict_batch b=9 4b workers={nw}", cls_backend.name()),
        budget(150.0),
        || {
            black_box(m.predict_batch(black_box(&queries), nw));
        },
    );
    println!("{rb}");
    assert_eq!(m.predict_batch(&queries, nw), preds_serial, "sharded must equal serial");
    log.record(
        &format!("predict_batch_b9_4b_sharded_{}", cls_backend.name()),
        rb.mean_ns,
        rb.throughput(9.0),
        nw,
    );

    match log.write() {
        Ok(path) => println!("bench trajectory written to {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench trajectory: {e}"),
    }
}
