//! Hot-path microbenchmarks (the §Perf targets in DESIGN.md): native cRP
//! encode throughput, L1 distance search, the packed class-memory HDC
//! datapath vs the dequantized-f32 path (1-bit hamming popcount, 4-bit
//! L1), the simd-vs-scalar kernel lanes of both packed fast paths
//! (DESIGN.md §SIMD datapath; lanes asserted bitwise identical), the
//! clustered-conv kernels (reference vs the packed fast path, at
//! ResNet-18 stage geometries), FE forward (dense and clustered, serial
//! and batch-parallel, `--workers N`, 0 = one per core) and the chip
//! simulator itself. Not a paper figure —
//! the optimization baseline/after log in EXPERIMENTS.md §Perf comes from
//! here, and the headline numbers land in `BENCH_hotpath.json` at the repo
//! root so the perf trajectory is tracked across PRs.
//!
//! `--smoke` shrinks every timing budget to ~1 ms so CI can exercise the
//! whole harness (all asserts still run) without paying bench time.

use fsl_hdnn::config::{ChipConfig, ModelConfig, ParallelConfig};
use fsl_hdnn::fe::conv::{
    clustered_conv2d, clustered_conv2d_lut_in_lane, clustered_conv2d_packed, conv2d, CodebookLut,
    Tensor3,
};
use fsl_hdnn::fe::kmeans::cluster_layer;
use fsl_hdnn::hdc::{distance, quant, CrpEncoder, Distance, HdcModel, PackedClassHvs};
use fsl_hdnn::runtime::ComputeEngine;
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::args::{arg_flag, arg_usize};
use fsl_hdnn::util::bench_log::BenchLog;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::simd::{self, Lane};
use fsl_hdnn::util::timer::{bench, black_box};

fn main() {
    let smoke = arg_flag("--smoke");
    let budget = |ms: f64| if smoke { 1.0 } else { ms };
    let mut log = BenchLog::new("hotpath_micro");
    let mut rng = Rng::new(1);

    // --- cRP encode (F=512 -> D=4096), the HDC hot loop ---
    let enc = CrpEncoder::new(4096, 0xF51_4D17);
    let x: Vec<f32> = (0..512).map(|_| rng.gauss_f32()).collect();
    let mut out = vec![0f32; 4096];
    let r = bench("crp_encode F=512 D=4096", budget(300.0), || {
        enc.encode_into(black_box(&x), &mut out);
    });
    println!("{r}");
    println!(
        "    -> {:.1} MB/s feature throughput, {:.2} Melem/s HV",
        r.throughput(512.0 * 4.0) / 1e6,
        r.throughput(4096.0) / 1e6
    );
    log.record("crp_encode_f512_d4096", r.mean_ns, r.throughput(1.0), 1);

    // --- L1 distance search (32 classes x D=4096) ---
    let classes: Vec<Vec<f32>> =
        (0..32).map(|_| (0..4096).map(|_| rng.gauss_f32()).collect()).collect();
    let q: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
    let r = bench("l1_distance 32 x D=4096", budget(200.0), || {
        let mut best = 0.0f64;
        for c in &classes {
            best += distance::l1(black_box(&q), c);
        }
        black_box(best);
    });
    println!("{r}");
    log.record("l1_distance_32xd4096", r.mean_ns, r.throughput(1.0), 1);

    // --- HDC train + predict round (the packed class-memory datapath) ---
    let mut model = HdcModel::new(10, 4096);
    let hv: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
    for c in 0..10 {
        model.train_shot(c, &hv);
    }
    let r = bench("hdc predict 10-way D=4096", budget(200.0), || {
        black_box(model.predict(black_box(&hv)));
    });
    println!("{r}");
    log.record("hdc_predict_10way_d4096", r.mean_ns, r.throughput(1.0), 1);

    // --- packed class memory vs the dequantized-f32 path (ISSUE 4): the
    // headline is 1-bit hamming, where the integer domain is a popcount
    // over u64 sign planes; 4-bit L1 shows the narrow-code streaming win.
    // Both packed results are numerically checked against the oracle. ---
    for (bits, metric) in [(1u32, Distance::Hamming), (4, Distance::L1)] {
        let mut pm = HdcModel::new(32, 4096).with_precision(bits).with_metric(metric);
        for c in 0..32 {
            let chv: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
            pm.train_shot(c, &chv);
        }
        let q: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
        // correctness gate before timing
        let got = pm.distances(&q);
        let want = pm.distances_oracle(&q);
        for (c, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (a - b).abs() <= 1e-5 * (1.0 + b.abs()),
                "packed {bits}b {metric:?} diverged at class {c}: {a} vs {b}"
            );
        }
        let tag = format!("{}_b{bits}", metric.name());
        let rp = bench(&format!("hdc packed {metric:?} {bits}b 32 x D=4096"), budget(200.0), || {
            black_box(pm.distances(black_box(&q)));
        });
        println!("{rp}");
        log.record(&format!("hdc_{tag}_packed_32xd4096"), rp.mean_ns, rp.throughput(1.0), 1);
        // fair f32 baseline: metric over the cached dequantized rows —
        // what the pre-packed implementation executed per query
        let rows = pm.dequantized_class_hvs();
        let (qd, _) = quant::quantize(&q, bits);
        let rf = bench(&format!("hdc f32    {metric:?} {bits}b 32 x D=4096"), budget(200.0), || {
            let mut acc = 0.0f64;
            for c in 0..32 {
                acc += metric.eval(black_box(&qd), &rows[c * 4096..(c + 1) * 4096]);
            }
            black_box(acc);
        });
        println!("{rf}");
        log.record(&format!("hdc_{tag}_f32_32xd4096"), rf.mean_ns, rf.throughput(1.0), 1);
        let speedup = rf.mean_ns / rp.mean_ns;
        // the packed-vs-f32 speedup row the perf trajectory tracks
        log.record_ratio(&format!("hdc_{tag}_packed_vs_f32_speedup"), speedup);
        println!("    -> packed vs f32: {speedup:.2}x (distances checked vs oracle)");
    }

    // --- simd-vs-scalar kernel lanes (ISSUE 10): the packed distance
    // kernels on the chunked-scalar lane vs the std::simd lane, through
    // the lane-explicit entry point. Without the `simd` feature both lanes
    // run the chunked kernels and the ratio sits at ~1.0 — the row then
    // documents the chunked baseline, not a vector win. Lanes are asserted
    // bitwise identical on every timed metric before timing. ---
    println!(
        "simd dispatch: compiled={} active={:?} (FSL_NO_SIMD forces Chunked)",
        simd::SIMD_COMPILED,
        simd::active_lane()
    );
    for (bits, metric) in [(1u32, Distance::Hamming), (4, Distance::L1), (8, Distance::Dot)] {
        let rows: Vec<f32> = (0..32 * 4096).map(|_| rng.gauss_f32()).collect();
        let packed = PackedClassHvs::from_rows(&rows, 32, 4096, bits);
        let q: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
        let pq = packed.quantize_query(&q);
        let chunked = packed.distances_in_lane(&pq, metric, Lane::Chunked);
        let vectored = packed.distances_in_lane(&pq, metric, Lane::Simd);
        assert_eq!(chunked, vectored, "{bits}b {metric:?}: lanes must be bitwise identical");
        let tag = format!("{}_b{bits}", metric.name());
        let rc = bench(&format!("hdc chunked {metric:?} {bits}b 32 x D=4096"), budget(150.0), || {
            black_box(packed.distances_in_lane(black_box(&pq), metric, Lane::Chunked));
        });
        println!("{rc}");
        let rs = bench(&format!("hdc simd    {metric:?} {bits}b 32 x D=4096"), budget(150.0), || {
            black_box(packed.distances_in_lane(black_box(&pq), metric, Lane::Simd));
        });
        println!("{rs}");
        let speedup = rc.mean_ns / rs.mean_ns;
        log.record_ratio(&format!("hdc_{tag}_simd_vs_scalar_speedup"), speedup);
        println!("    -> simd vs chunked-scalar: {speedup:.2}x (bitwise identical, asserted)");
    }

    // --- clustered conv: reference kernel vs the packed fast path, at
    // ResNet-18 stage geometries (the acceptance target: packed >= 3x
    // faster than the reference at these shapes) ---
    let (k, n, ch_sub) = (3usize, 16usize, 64usize);
    for (cin, cout, hw) in [(64usize, 64usize, 28usize), (128, 128, 14)] {
        let std = (2.0 / (k * k * cin) as f32).sqrt();
        let w: Vec<f32> = (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
        let cl = cluster_layer(&w, cout, k, cin, ch_sub, n);
        let packed = cl.packed();
        let img =
            Tensor3::from_vec(hw, hw, cin, (0..hw * hw * cin).map(|_| rng.gauss_f32()).collect());
        let geo = format!("{cin}->{cout} @{hw}x{hw}");
        let rd = bench(&format!("dense conv {geo}"), budget(300.0), || {
            black_box(conv2d(black_box(&img), &w, cout, k, 1));
        });
        println!("{rd}");
        log.record(&format!("dense_conv_{cin}x{cout}_{hw}"), rd.mean_ns, rd.throughput(1.0), 1);
        let rr = bench(&format!("clustered ref {geo}"), budget(300.0), || {
            black_box(clustered_conv2d(
                black_box(&img),
                &cl.idx,
                &cl.codebook,
                cout,
                k,
                1,
                ch_sub,
                n,
            ));
        });
        println!("{rr}");
        log.record(&format!("clustered_ref_{cin}x{cout}_{hw}"), rr.mean_ns, rr.throughput(1.0), 1);
        let rp = bench(&format!("clustered packed {geo}"), budget(300.0), || {
            black_box(clustered_conv2d_packed(black_box(&img), &packed, &cl.codebook, 1));
        });
        println!("{rp}");
        log.record(
            &format!("clustered_packed_{cin}x{cout}_{hw}"),
            rp.mean_ns,
            rp.throughput(1.0),
            1,
        );
        // numerics: the fast path must match the reference kernel
        let want = clustered_conv2d(&img, &cl.idx, &cl.codebook, cout, k, 1, ch_sub, n);
        let got = clustered_conv2d_packed(&img, &packed, &cl.codebook, 1);
        for (a, b) in want.data.iter().zip(&got.data) {
            assert!((a - b).abs() < 1e-3 * (1.0 + a.abs()), "packed kernel diverged: {a} vs {b}");
        }
        println!(
            "    -> packed vs reference: {:.2}x | packed vs dense: {:.2}x (outputs checked)",
            rr.mean_ns / rp.mean_ns,
            rd.mean_ns / rp.mean_ns
        );
        // simd-vs-scalar lanes over the codebook-LUT phase-2 MAC (prebuilt
        // LUT, as resnet's hot loop runs it); lanes bitwise identical
        let lut = CodebookLut::new(&cl.codebook, packed.cout, packed.groups() * packed.n);
        let lc = clustered_conv2d_lut_in_lane(&img, &packed, &lut, 1, Lane::Chunked);
        let ls = clustered_conv2d_lut_in_lane(&img, &packed, &lut, 1, Lane::Simd);
        assert_eq!(lc.data, ls.data, "{geo}: conv lanes must be bitwise identical");
        let rlc = bench(&format!("conv lut chunked {geo}"), budget(300.0), || {
            black_box(clustered_conv2d_lut_in_lane(
                black_box(&img),
                &packed,
                &lut,
                1,
                Lane::Chunked,
            ));
        });
        println!("{rlc}");
        let rls = bench(&format!("conv lut simd    {geo}"), budget(300.0), || {
            black_box(clustered_conv2d_lut_in_lane(black_box(&img), &packed, &lut, 1, Lane::Simd));
        });
        println!("{rls}");
        let speedup = rlc.mean_ns / rls.mean_ns;
        log.record_ratio(&format!("conv_packed_{cin}x{cout}_{hw}_simd_vs_scalar_speedup"), speedup);
        println!("    -> conv simd vs chunked-scalar: {speedup:.2}x (bitwise identical, asserted)");
    }

    // --- batched native FE forward + encode: serial vs worker-sharded,
    // dense vs clustered ---
    let par = ParallelConfig { workers: arg_usize("--workers", 0), min_batch_per_worker: 1 };
    let nw = par.resolved_workers();
    let serial_engine = ComputeEngine::from_config(ModelConfig::default());
    let par_engine = ComputeEngine::from_config(ModelConfig::default()).with_parallelism(par);
    let m = serial_engine.model().clone();
    let images: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            (0..m.image_size * m.image_size * m.in_channels).map(|_| rng.gauss_f32()).collect()
        })
        .collect();
    let rs = bench("fe_forward batch=8 serial", budget(600.0), || {
        black_box(serial_engine.fe_forward(black_box(&images)).unwrap());
    });
    println!("{rs}");
    log.record("fe_forward_dense_b8", rs.mean_ns, rs.throughput(8.0), 1);
    let rp = bench(&format!("fe_forward batch=8 workers={nw}"), budget(600.0), || {
        black_box(par_engine.fe_forward(black_box(&images)).unwrap());
    });
    println!("{rp}");
    log.record("fe_forward_dense_b8_sharded", rp.mean_ns, rp.throughput(8.0), nw);
    assert_eq!(
        serial_engine.fe_forward(&images).unwrap(),
        par_engine.fe_forward(&images).unwrap(),
        "parallel output must be bit-identical to serial"
    );
    println!(
        "    -> {:.2}x speedup at {nw} workers (output bit-identical, asserted)",
        rs.mean_ns / rp.mean_ns
    );

    // clustered FE engine: the packed kernel end to end, same determinism
    // contract (bit-identical across worker counts)
    let ccfg = ModelConfig { clustered: true, ..ModelConfig::default() };
    let cl_serial = ComputeEngine::from_config(ccfg.clone());
    let cl_par = ComputeEngine::from_config(ccfg).with_parallelism(par);
    let rc = bench("fe_forward clustered batch=8 serial", budget(600.0), || {
        black_box(cl_serial.fe_forward(black_box(&images)).unwrap());
    });
    println!("{rc}");
    log.record("fe_forward_clustered_b8", rc.mean_ns, rc.throughput(8.0), 1);
    let rcp = bench(&format!("fe_forward clustered batch=8 workers={nw}"), budget(600.0), || {
        black_box(cl_par.fe_forward(black_box(&images)).unwrap());
    });
    println!("{rcp}");
    log.record("fe_forward_clustered_b8_sharded", rcp.mean_ns, rcp.throughput(8.0), nw);
    assert_eq!(
        cl_serial.fe_forward(&images).unwrap(),
        cl_par.fe_forward(&images).unwrap(),
        "clustered parallel output must be bit-identical to serial"
    );
    println!(
        "    -> clustered vs dense serial: {:.2}x | {:.2}x speedup at {nw} workers",
        rs.mean_ns / rc.mean_ns,
        rc.mean_ns / rcp.mean_ns
    );

    let feats: Vec<Vec<f32>> =
        (0..64).map(|_| (0..m.feature_dim).map(|_| rng.gauss_f32()).collect()).collect();
    let es = bench("encode batch=64 serial", budget(300.0), || {
        black_box(serial_engine.encode(black_box(&feats)).unwrap());
    });
    println!("{es}");
    log.record("encode_b64", es.mean_ns, es.throughput(64.0), 1);
    let ep = bench(&format!("encode batch=64 workers={nw}"), budget(300.0), || {
        black_box(par_engine.encode(black_box(&feats)).unwrap());
    });
    println!("{ep}");
    log.record("encode_b64_sharded", ep.mean_ns, ep.throughput(64.0), nw);
    println!("    -> {:.2}x speedup at {nw} workers", es.mean_ns / ep.mean_ns);

    // --- persistent-pool dispatch overhead: the fixed cost every sharded
    // batch call pays now that long-lived workers replace per-call thread
    // spawning, against what std::thread::scope paid for the same fan-out
    // (DESIGN.md §Serving runtime) ---
    let pool = fsl_hdnn::runtime::WorkerPool::new(nw);
    let rpool = bench(&format!("pool run_scoped {nw} no-op jobs"), budget(100.0), || {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..nw)
            .map(|_| {
                Box::new(|| {
                    black_box(0u64);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
    });
    println!("{rpool}");
    log.record("pool_dispatch_noop", rpool.mean_ns, rpool.throughput(nw as f64), nw);
    let rspawn = bench(&format!("thread::scope spawn {nw} no-op jobs"), budget(100.0), || {
        std::thread::scope(|s| {
            for _ in 0..nw {
                s.spawn(|| {
                    black_box(0u64);
                });
            }
        });
    });
    println!("{rspawn}");
    log.record("thread_scope_spawn_noop", rspawn.mean_ns, rspawn.throughput(nw as f64), nw);
    log.record_ratio("pool_vs_spawn_dispatch_speedup", rspawn.mean_ns / rpool.mean_ns);
    println!(
        "    -> pool dispatch vs per-call scoped spawn: {:.2}x",
        rspawn.mean_ns / rpool.mean_ns
    );

    // --- chip simulator speed (simulated cycles per wall second) ---
    let chip = Chip::paper(ChipConfig::default());
    let mut cycles = 0u64;
    let r = bench("chip sim: 10-way 5-shot train episode", budget(300.0), || {
        let rep = chip.train_episode(10, 5, true, false);
        cycles = rep.cycles;
        black_box(rep);
    });
    println!("{r}");
    println!(
        "    -> {:.1} M simulated cycles / wall-second",
        cycles as f64 / (r.mean_ns / 1e9) / 1e6
    );
    log.record("chip_sim_train_episode", r.mean_ns, r.throughput(1.0), 1);

    match log.write() {
        Ok(path) => println!("bench trajectory written to {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench trajectory: {e}"),
    }
}
