//! Hot-path microbenchmarks (the §Perf targets in DESIGN.md): native cRP
//! encode throughput, L1 distance search, clustered conv, FE forward
//! (serial and batch-parallel, `--workers N`, 0 = one per core) and the
//! chip simulator itself. Not a paper figure — the optimization
//! baseline/after log in EXPERIMENTS.md §Perf comes from here.

use fsl_hdnn::config::{ChipConfig, ModelConfig, ParallelConfig};
use fsl_hdnn::fe::conv::{clustered_conv2d, conv2d, Tensor3};
use fsl_hdnn::fe::kmeans::cluster_layer;
use fsl_hdnn::hdc::{distance, CrpEncoder, HdcModel};
use fsl_hdnn::runtime::ComputeEngine;
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::args::arg_usize;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::timer::{bench, black_box};

fn main() {
    let mut rng = Rng::new(1);

    // --- cRP encode (F=512 -> D=4096), the HDC hot loop ---
    let enc = CrpEncoder::new(4096, 0xF51_4D17);
    let x: Vec<f32> = (0..512).map(|_| rng.gauss_f32()).collect();
    let mut out = vec![0f32; 4096];
    let r = bench("crp_encode F=512 D=4096", 300.0, || {
        enc.encode_into(black_box(&x), &mut out);
    });
    println!("{r}");
    println!(
        "    -> {:.1} MB/s feature throughput, {:.2} Melem/s HV",
        r.throughput(512.0 * 4.0) / 1e6,
        r.throughput(4096.0) / 1e6
    );

    // --- L1 distance search (32 classes x D=4096) ---
    let classes: Vec<Vec<f32>> =
        (0..32).map(|_| (0..4096).map(|_| rng.gauss_f32()).collect()).collect();
    let q: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
    let r = bench("l1_distance 32 x D=4096", 200.0, || {
        let mut best = 0.0f64;
        for c in &classes {
            best += distance::l1(black_box(&q), c);
        }
        black_box(best);
    });
    println!("{r}");

    // --- HDC train + predict round ---
    let mut model = HdcModel::new(10, 4096);
    let hv: Vec<f32> = (0..4096).map(|_| rng.gauss_f32()).collect();
    for c in 0..10 {
        model.train_shot(c, &hv);
    }
    let r = bench("hdc predict 10-way D=4096", 200.0, || {
        black_box(model.predict(black_box(&hv)));
    });
    println!("{r}");

    // --- clustered conv vs dense conv (Cin=Cout=64 @ 16x16) ---
    let (cin, cout, k, n, ch_sub) = (64usize, 64usize, 3usize, 16usize, 64usize);
    let std = (2.0 / (k * k * cin) as f32).sqrt();
    let w: Vec<f32> = (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
    let cl = cluster_layer(&w, cout, k, cin, ch_sub, n);
    let img = Tensor3::from_vec(16, 16, cin, (0..16 * 16 * cin).map(|_| rng.gauss_f32()).collect());
    let r = bench("dense conv 64->64 @16x16", 300.0, || {
        black_box(conv2d(black_box(&img), &w, cout, k, 1));
    });
    println!("{r}");
    let r = bench("clustered conv 64->64 @16x16", 300.0, || {
        black_box(clustered_conv2d(black_box(&img), &cl.idx, &cl.codebook, cout, k, 1, ch_sub, n));
    });
    println!("{r}");

    // --- batched native FE forward + encode: serial vs worker-sharded ---
    let par = ParallelConfig { workers: arg_usize("--workers", 0), min_batch_per_worker: 1 };
    let serial_engine = ComputeEngine::from_config(ModelConfig::default());
    let par_engine = ComputeEngine::from_config(ModelConfig::default()).with_parallelism(par);
    let m = serial_engine.model().clone();
    let images: Vec<Vec<f32>> = (0..8)
        .map(|_| {
            (0..m.image_size * m.image_size * m.in_channels).map(|_| rng.gauss_f32()).collect()
        })
        .collect();
    let rs = bench("fe_forward batch=8 serial", 600.0, || {
        black_box(serial_engine.fe_forward(black_box(&images)).unwrap());
    });
    println!("{rs}");
    let nw = par.resolved_workers();
    let rp = bench(&format!("fe_forward batch=8 workers={nw}"), 600.0, || {
        black_box(par_engine.fe_forward(black_box(&images)).unwrap());
    });
    println!("{rp}");
    assert_eq!(
        serial_engine.fe_forward(&images).unwrap(),
        par_engine.fe_forward(&images).unwrap(),
        "parallel output must be bit-identical to serial"
    );
    println!(
        "    -> {:.2}x speedup at {nw} workers (output bit-identical, asserted)",
        rs.mean_ns / rp.mean_ns
    );
    let feats: Vec<Vec<f32>> =
        (0..64).map(|_| (0..m.feature_dim).map(|_| rng.gauss_f32()).collect()).collect();
    let es = bench("encode batch=64 serial", 300.0, || {
        black_box(serial_engine.encode(black_box(&feats)).unwrap());
    });
    println!("{es}");
    let ep = bench(&format!("encode batch=64 workers={nw}"), 300.0, || {
        black_box(par_engine.encode(black_box(&feats)).unwrap());
    });
    println!("{ep}");
    println!("    -> {:.2}x speedup at {nw} workers", es.mean_ns / ep.mean_ns);

    // --- chip simulator speed (simulated cycles per wall second) ---
    let chip = Chip::paper(ChipConfig::default());
    let mut cycles = 0u64;
    let r = bench("chip sim: 10-way 5-shot train episode", 300.0, || {
        let rep = chip.train_episode(10, 5, true, false);
        cycles = rep.cycles;
        black_box(rep);
    });
    println!("{r}");
    println!(
        "    -> {:.1} M simulated cycles / wall-second",
        cycles as f64 / (r.mean_ns / 1e9) / 1e6
    );
}
