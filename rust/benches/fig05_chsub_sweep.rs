//! Fig. 5 — FE output error, model compression ratio and operation
//! reduction ratio vs Ch_sub (8..256), against an INT8-quantized baseline.
//!
//! The error measurement clusters a mid-network ResNet-18-scale conv layer
//! (Cin=Cout=128, K=3) and compares conv outputs on a probe activation
//! against the INT8-quantized dense layer, exactly the Fig. 5 protocol.
//! The sweep runs both clustered kernels — the reference and the packed
//! fast path — asserting they agree, and logs the measured ns/op of each
//! into `BENCH_hotpath.json` (`--smoke` shrinks timing budgets for CI).

use fsl_hdnn::fe::conv::{clustered_conv2d, clustered_conv2d_packed, conv2d, Tensor3};
use fsl_hdnn::fe::kmeans::cluster_layer;
use fsl_hdnn::fe::quant::{mse, quantize_int8};
use fsl_hdnn::util::args::arg_flag;
use fsl_hdnn::util::bench_log::BenchLog;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::table::Table;
use fsl_hdnn::util::timer::{bench, black_box};

fn main() {
    let smoke = arg_flag("--smoke");
    let budget = if smoke { 1.0 } else { 80.0 };
    let mut log = BenchLog::new("fig05_chsub_sweep");
    let (cin, cout, k, n) = (128usize, 128usize, 3usize, 16usize);
    let mut rng = Rng::new(5);
    let std = (2.0 / (k * k * cin) as f32).sqrt();
    let w: Vec<f32> = (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
    let x = Tensor3::from_vec(
        14,
        14,
        cin,
        (0..14 * 14 * cin).map(|_| rng.gauss_f32().max(0.0)).collect(),
    );
    let y_fp32 = conv2d(&x, &w, cout, k, 1);
    let w_int8 = quantize_int8(&w);
    let y_int8 = conv2d(&x, &w_int8, cout, k, 1);
    let int8_err = mse(&y_fp32.data, &y_int8.data);

    let mut t = Table::new(
        "Fig. 5: FE error / compression / op-reduction vs Ch_sub (N=16, K=3)",
        &["Ch_sub", "FE output MSE", "vs INT8 MSE", "compression", "op reduction", "packed vs ref"],
    );
    for ch_sub in [8usize, 16, 32, 64, 128] {
        let cl = cluster_layer(&w, cout, k, cin, ch_sub, n);
        let wr = cl.reconstruct();
        let packed = cl.packed();
        let y_cl = clustered_conv2d(&x, &cl.idx, &cl.codebook, cout, k, 1, ch_sub, n);
        // sanity: clustered datapath == dense reconstruction == fast path
        let y_rec = conv2d(&x, &wr, cout, k, 1);
        assert!(mse(&y_cl.data, &y_rec.data) < 1e-6, "clustered != reconstructed");
        let y_fast = clustered_conv2d_packed(&x, &packed, &cl.codebook, 1);
        assert!(mse(&y_cl.data, &y_fast.data) < 1e-6, "packed kernel != reference");
        let rr = bench(&format!("clustered ref ch_sub={ch_sub}"), budget, || {
            black_box(clustered_conv2d(
                black_box(&x),
                &cl.idx,
                &cl.codebook,
                cout,
                k,
                1,
                ch_sub,
                n,
            ));
        });
        let rp = bench(&format!("clustered packed ch_sub={ch_sub}"), budget, || {
            black_box(clustered_conv2d_packed(black_box(&x), &packed, &cl.codebook, 1));
        });
        log.record(&format!("clustered_ref_ch{ch_sub}"), rr.mean_ns, rr.throughput(1.0), 1);
        log.record(&format!("clustered_packed_ch{ch_sub}"), rp.mean_ns, rp.throughput(1.0), 1);
        let fe_err = mse(&y_fp32.data, &y_cl.data);
        let compression = (cout * k * k * cin * 8) as f64 / cl.storage_bits() as f64;
        let dense_ops = 2.0 * (k * k * ch_sub.min(cin)) as f64;
        let clus_ops = (k * k * ch_sub.min(cin)) as f64 + 2.0 * n as f64;
        t.row(&[
            ch_sub.to_string(),
            format!("{fe_err:.3e}"),
            format!("{:.2}x", fe_err / int8_err),
            format!("{:.2}x", compression),
            format!("{:.2}x", dense_ops / clus_ops),
            format!("{:.2}x", rr.mean_ns / rp.mean_ns),
        ]);
    }
    t.print();
    println!("paper shape check: compression and op-reduction grow with Ch_sub and");
    println!("saturate near 2x, with Ch_sub=64 reaching ~1.8x memory / ~1.9x op savings");
    println!("and FE error rising only mildly across the sweep — all reproduced.");
    println!("DEVIATION (documented in EXPERIMENTS.md): the paper reports clustered FE");
    println!("error *below* the INT8 baseline; with Lloyd-Max N=16 centroids per");
    println!("(channel, group) codebook that ratio is not reachable from first");
    println!("principles against a weight-only INT8 baseline (16 vs 256 levels), so the");
    println!("paper's error metric must normalize differently. Shape (mild growth,");
    println!("saturation) holds. INT8 baseline output MSE = {int8_err:.3e}");
    match log.write() {
        Ok(path) => println!("bench trajectory written to {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench trajectory: {e}"),
    }
}
