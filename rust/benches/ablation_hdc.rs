//! Ablations over the HDC design choices the paper fixes: HDC dimension D
//! (1024-8192 supported, 4096 default), class-HV precision (INT1-16), and
//! the chip's 4-bit feature quantization. Each knob trades accuracy
//! against class-memory capacity and encode cycles — the tradeoff space
//! behind Fig. 13(b)'s spec table.

use fsl_hdnn::data::DatasetPreset;
use fsl_hdnn::experiments::{eval_learner, sampler_for, Learner};
use fsl_hdnn::hdc::{quant, CrpEncoder, HdcModel};
use fsl_hdnn::sim::hdc_engine::encode_tally;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::stats;
use fsl_hdnn::util::table::Table;

fn main() {
    let episodes = 8;

    // ---- D sweep ----
    let mut t = Table::new(
        "ablation: HDC dimension D (5-way 5-shot, cifar100 preset)",
        &["D", "accuracy", "encode cycles (F=512)", "class KB (16b, 32 cls)"],
    );
    let sampler = sampler_for(DatasetPreset::Cifar100, 128, 5, 5, 8, 7);
    for d in [512usize, 1024, 2048, 4096, 8192] {
        let (acc, _) = eval_learner(&sampler, Learner::FslHdnn { d, bits: 16 }, episodes, 3);
        t.row(&[
            d.to_string(),
            format!("{:.1}%", 100.0 * acc),
            encode_tally(512, d).total_cycles.to_string(),
            format!("{}", 32 * d * 16 / 8 / 1024),
        ]);
    }
    t.print();
    println!("expected: accuracy saturates near D=4096 (the paper's default)\n");

    // ---- class-HV precision sweep ----
    let mut t = Table::new(
        "ablation: class-HV precision (D=4096, 5-way 5-shot)",
        &["bits", "cifar100", "trafficsign", "classes @256KB (1 branch)", "w/ EE branches"],
    );
    for bits in [1u32, 2, 4, 8, 16] {
        let mut row = vec![bits.to_string()];
        for preset in [DatasetPreset::Cifar100, DatasetPreset::TrafficSign] {
            let s = sampler_for(preset, 128, 5, 5, 8, 7);
            let (acc, _) = eval_learner(&s, Learner::FslHdnn { d: 4096, bits }, episodes, 3);
            row.push(format!("{:.1}%", 100.0 * acc));
        }
        row.push(quant::classes_capacity(256, 4096, bits).to_string());
        row.push((quant::classes_capacity(256, 4096, bits) / 4).to_string());
        t.row(&row);
    }
    t.print();
    println!("expected: 4-bit matches 16-bit accuracy at 4x the class capacity\n");

    // ---- feature quantization (the chip feeds 4-bit features) ----
    let mut t = Table::new(
        "ablation: feature quantization before cRP encode (D=4096)",
        &["feature bits", "accuracy (cifar100)", "accuracy (flower102)"],
    );
    for fbits in [2u32, 4, 8, 32] {
        let mut row = vec![if fbits == 32 { "f32".into() } else { format!("INT{fbits}") }];
        for preset in [DatasetPreset::Cifar100, DatasetPreset::Flower102] {
            let s = sampler_for(preset, 128, 5, 5, 8, 7);
            let enc = CrpEncoder::new(4096, 0xF51_4D17);
            let mut rng = Rng::new(9);
            let mut accs = Vec::new();
            for _ in 0..episodes {
                let ep = s.sample(&mut rng);
                let mut model = HdcModel::new(ep.n_way, 4096);
                let q = |f: &[f32]| -> Vec<f32> {
                    if fbits == 32 {
                        f.to_vec()
                    } else {
                        quant::quantize(f, fbits).0
                    }
                };
                for (c, shots) in ep.support.iter().enumerate() {
                    let hvs: Vec<Vec<f32>> =
                        shots.iter().map(|s| enc.encode_padded(&q(s))).collect();
                    model.train_batch(c, &hvs);
                }
                let pairs: Vec<(usize, usize)> = ep
                    .queries
                    .iter()
                    .map(|(f, l)| (model.predict(&enc.encode_padded(&q(f))), *l))
                    .collect();
                accs.push(stats::accuracy(&pairs));
            }
            row.push(format!("{:.1}%", 100.0 * stats::mean(&accs)));
        }
        t.row(&row);
    }
    t.print();
    println!("expected: the chip's 4-bit feature quantization is accuracy-neutral");
}
