//! Fig. 14 — (a) HDC-classifier training power vs HV precision and
//! voltage; (b) total chip power and energy efficiency vs supply voltage.

use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::sim::hdc_engine::{distance_tally, encode_tally, train_update_tally};
use fsl_hdnn::sim::{Chip, EnergyModel};
use fsl_hdnn::util::table::Table;

fn main() {
    let em = EnergyModel::default();
    let (f, d) = (512usize, 4096usize);

    // ---- (a) HDC training power vs precision and voltage ----
    let mut t = Table::new(
        "Fig. 14(a): HDC-based FSL classifier training power (mW)",
        &["precision", "0.9 V/100 MHz", "1.0 V/150 MHz", "1.1 V/200 MHz", "1.2 V/250 MHz"],
    );
    for bits in [1u32, 4, 8, 16] {
        let mut row = vec![format!("INT{bits}")];
        for (v, mhz) in [(0.9, 100.0), (1.0, 150.0), (1.1, 200.0), (1.2, 250.0)] {
            // steady-state training stream per shot: encode + class-memory
            // update + the distance search the module runs for EE training
            // bookkeeping — the paper attributes the 1b->16b power growth
            // to "distance computations and more memory accesses"
            let mut tally = encode_tally(f, d);
            tally.add(&train_update_tally(d, 1, bits));
            tally.add(&distance_tally(d, 32, bits));
            row.push(format!("{:.1}", em.avg_power_mw(&tally, v, mhz)));
        }
        t.row(&row);
    }
    t.print();
    // the paper: +21% power from 1-b to 16-b
    let p = |bits: u32| {
        let mut tally = encode_tally(f, d);
        tally.add(&train_update_tally(d, 1, bits));
        tally.add(&distance_tally(d, 32, bits));
        em.avg_power_mw(&tally, 1.2, 250.0)
    };
    println!(
        "precision scaling 1b -> 16b: +{:.0}% (paper: +21%)\n",
        100.0 * (p(16) / p(1) - 1.0)
    );

    // ---- (b) total power + energy efficiency vs voltage ----
    let mut t = Table::new(
        "Fig. 14(b): total power and energy efficiency vs supply voltage",
        &["V", "MHz", "total power (mW)", "mJ/image", "TOPS/W"],
    );
    for &v in &[0.9, 1.0, 1.1, 1.2] {
        let mhz = em.freq_at_voltage(v);
        let chip = Chip::paper(ChipConfig { voltage: v, freq_mhz: mhz, ..Default::default() });
        let r = chip.train_episode(10, 5, true, false);
        t.row(&[
            format!("{v:.1}"),
            format!("{mhz:.0}"),
            format!("{:.0}", r.avg_power_mw),
            format!("{:.2}", r.energy_mj_per_image),
            format!("{:.2}", chip.tops_per_watt(&r)),
        ]);
    }
    t.print();
    println!("paper anchors: 59 mW @ 0.9 V/100 MHz, 305 mW (peak) @ 1.2 V/250 MHz,");
    println!("~6 mJ/image training, efficiency falling with voltage (1.4-2.9 TOPS/W band)");
}
