//! Fig. 3 — (a) FSL accuracy vs training iterations for partial/full FT
//! (FSL-HDnn converges in a single pass); (b) accuracy vs normalized
//! training complexity for kNN, partial FT, full FT and FSL-HDnn.
//!
//! Protocol: 20-way 5-shot episodes (the paper's Fig. 3 setting).

use fsl_hdnn::baselines::complexity::PassCosts;
use fsl_hdnn::data::DatasetPreset;
use fsl_hdnn::experiments::{convergence_curve, eval_learner, sampler_for, Learner};
use fsl_hdnn::util::table::Table;

fn main() {
    let (n_way, k_shot, queries, episodes) = (20, 5, 5, 8);
    let sampler = sampler_for(DatasetPreset::Cifar100, 128, n_way, k_shot, queries, 42);

    // ---- (a) accuracy vs iterations ----
    let epochs = 12;
    let partial = convergence_curve(&sampler, false, epochs, episodes, 1);
    let full = convergence_curve(&sampler, true, epochs, episodes, 1);
    let (ours, _) = eval_learner(&sampler, Learner::FslHdnn { d: 4096, bits: 16 }, episodes, 1);
    let mut t = Table::new(
        "Fig. 3(a): 20-way 5-shot accuracy vs training iterations",
        &["iteration", "partial FT", "full FT", "FSL-HDnn (single pass)"],
    );
    for e in 0..epochs {
        t.row(&[
            (e + 1).to_string(),
            format!("{:.1}%", 100.0 * partial[e]),
            format!("{:.1}%", 100.0 * full[e]),
            if e == 0 { format!("{:.1}%", 100.0 * ours) } else { "-".into() },
        ]);
    }
    t.print();

    // ---- (b) accuracy vs complexity (normalized to the smallest) ----
    let costs = PassCosts::resnet18();
    let samples = n_way * k_shot;
    let rows: Vec<(&str, f64, f64)> = vec![
        ("kNN", costs.knn(samples), {
            let (a, _) = eval_learner(&sampler, Learner::Knn, episodes, 2);
            a
        }),
        ("partial FT (15 it)", costs.partial_ft(15, samples, 0.3), {
            let (a, _) = eval_learner(&sampler, Learner::PartialFt { epochs: 15 }, episodes, 2);
            a
        }),
        ("full FT (5 it)", costs.full_ft(5, samples), {
            let (a, _) = eval_learner(&sampler, Learner::FullFt { epochs: 5 }, episodes, 2);
            a
        }),
        ("FSL-HDnn", costs.fsl_hdnn(samples, 2.1), {
            let (a, _) =
                eval_learner(&sampler, Learner::FslHdnn { d: 4096, bits: 16 }, episodes, 2);
            a
        }),
    ];
    let min_cost = rows.iter().map(|r| r.1).fold(f64::MAX, f64::min);
    let mut t = Table::new(
        "Fig. 3(b): accuracy vs training complexity (normalized)",
        &["algorithm", "norm. complexity", "accuracy"],
    );
    for (name, cost, acc) in &rows {
        t.row(&[
            name.to_string(),
            format!("{:.1}x", cost / min_cost),
            format!("{:.1}%", 100.0 * acc),
        ]);
    }
    t.print();
    println!(
        "paper shape check: FSL-HDnn is the cheapest ({}x) while matching FT-family accuracy",
        (rows[3].1 / min_cost).round()
    );
}
