//! Fig. 15 — FSL accuracy of full FT (5 epochs), partial FT (15 epochs),
//! kNN-L1 and FSL-HDnn on the three dataset presets under
//! {5,10,20}-way x {1,5}-shot settings.

use fsl_hdnn::data::DatasetPreset;
use fsl_hdnn::experiments::{eval_learner, sampler_for, Learner};
use fsl_hdnn::util::table::Table;

fn main() {
    let episodes = 10;
    let feature_dim = 128;
    let learners = [
        Learner::FullFt { epochs: 5 },
        Learner::PartialFt { epochs: 15 },
        Learner::Knn,
        Learner::FslHdnn { d: 4096, bits: 16 },
    ];
    for preset in [DatasetPreset::Cifar100, DatasetPreset::Flower102, DatasetPreset::TrafficSign] {
        let mut t = Table::new(
            &format!("Fig. 15: FSL accuracy on {} (mean over {episodes} episodes)", preset.name()),
            &["setting", "full FT", "partial FT", "kNN-L1", "FSL-HDnn"],
        );
        let mut gaps = Vec::new();
        for (n_way, k_shot) in [(5usize, 1usize), (5, 5), (10, 5), (20, 5)] {
            if n_way > preset.n_classes() {
                continue;
            }
            let sampler = sampler_for(preset, feature_dim, n_way, k_shot, 8, 7);
            let mut row = vec![format!("{n_way}-way {k_shot}-shot")];
            let mut accs = Vec::new();
            for l in &learners {
                let (a, _) = eval_learner(&sampler, *l, episodes, 11);
                accs.push(a);
                row.push(format!("{:.1}%", 100.0 * a));
            }
            gaps.push((accs[3] - accs[2], accs[0] - accs[3]));
            t.row(&row);
        }
        t.print();
        let knn_gap: f64 = gaps.iter().map(|g| g.0).sum::<f64>() / gaps.len() as f64;
        let ft_gap: f64 = gaps.iter().map(|g| g.1).sum::<f64>() / gaps.len() as f64;
        println!(
            "  {}: FSL-HDnn beats kNN by {:+.1} pts on average, trails full FT by {:+.1} pts\n",
            preset.name(),
            100.0 * knn_gap,
            100.0 * ft_gap
        );
    }
    println!("paper shape check: FSL-HDnn ~= FT-family (e.g. 94.1 vs 94.5 on Flower102),");
    println!("surpasses kNN by ~4.9 pts on average with the largest margin on Traffic-sign");
}
