//! Fig. 10 — cRP encoder vs conventional RP encoder: (a) energy,
//! (b) area, (c) weight-memory ratios.
//!
//! Energy from the calibrated event model; area from first-order 40 nm
//! macro estimates (SRAM bit-cell vs LFSR flop area); memory is the exact
//! storage accounting of Section IV-B2.

use fsl_hdnn::sim::hdc_engine::{
    conventional_rp_tally, crp_storage_bits, encode_tally, rp_storage_bits,
};
use fsl_hdnn::sim::EnergyModel;
use fsl_hdnn::util::table::Table;

fn main() {
    let em = EnergyModel::default();
    let f = 512usize;

    // --- (a) energy per encode ---
    let mut t = Table::new(
        "Fig. 10(a): encoding energy per feature (F=512)",
        &["D", "RP (uJ)", "cRP (uJ)", "ratio"],
    );
    for d in [1024usize, 2048, 4096, 8192] {
        // conventional RP additionally burns SRAM reads for the base matrix
        // held in a large macro; the paper's 22x gap also includes the
        // macro's higher per-access energy — model that with the DRAM-class
        // cost for the big-matrix fetch path
        let mut rp = conventional_rp_tally(f, d);
        // large-macro penalty: base-matrix bits cost ~6x a small SRAM bit
        rp.sram_bits += 5 * (d as u64 * f as u64);
        let crp = encode_tally(f, d);
        let e_rp = em.energy_mj(&rp, 1.2) * 1e3;
        let e_crp = em.energy_mj(&crp, 1.2) * 1e3;
        t.row(&[
            d.to_string(),
            format!("{e_rp:.2}"),
            format!("{e_crp:.2}"),
            format!("{:.1}x", e_rp / e_crp),
        ]);
    }
    t.print();

    // --- (b) area ---
    // 40 nm first-order: SRAM ~ 0.45 um^2/bit (incl. periphery), LFSR flop
    // ~ 6 um^2; adder trees shared by both designs
    let mut t = Table::new("Fig. 10(b): encoder area", &["D", "RP (mm2)", "cRP (mm2)", "ratio"]);
    for d in [1024usize, 2048, 4096, 8192] {
        let rp_area = rp_storage_bits(f, d) as f64 * 0.45e-6 + 0.02;
        let crp_area = 16.0 * 16.0 * 6e-6 + 0.02; // 16 LFSRs x 16 flops + shared logic
        t.row(&[
            d.to_string(),
            format!("{rp_area:.3}"),
            format!("{crp_area:.3}"),
            format!("{:.2}x", rp_area / crp_area),
        ]);
    }
    t.print();

    // --- (c) weight memory ---
    let mut t = Table::new(
        "Fig. 10(c): base-matrix storage (F=512)",
        &["D", "RP (KB)", "cRP (B)", "ratio"],
    );
    for d in [1024usize, 2048, 4096, 8192] {
        let rp = rp_storage_bits(f, d);
        let crp = crp_storage_bits();
        t.row(&[
            d.to_string(),
            format!("{:.0}", rp as f64 / 8.0 / 1024.0),
            format!("{}", crp / 8),
            format!("{}x", rp / crp),
        ]);
    }
    t.print();
    println!("paper shape check: ~22x energy, ~6.35x area, 512-4096x memory at the");
    println!("paper's granularity (ours stores only the 256-bit seed block -> larger ratios)");
}
