//! Fig. 13 — (a) the chip's shmoo plot (voltage/frequency pass-fail grid)
//! and (b) the specification table.
//!
//! The shmoo comes from the calibrated V/f operating curve (100 MHz @
//! 0.9 V .. 250 MHz @ 1.2 V, linear between — the measured corners); a
//! cell passes when the requested frequency is at or below the curve.

use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::sim::memory::ChipMemories;
use fsl_hdnn::sim::{Chip, EnergyModel};
use fsl_hdnn::util::table::Table;

fn main() {
    let em = EnergyModel::default();

    // ---- (a) shmoo ----
    let freqs = [275.0, 250.0, 225.0, 200.0, 175.0, 150.0, 125.0, 100.0, 75.0];
    let volts = [0.85, 0.9, 0.95, 1.0, 1.05, 1.1, 1.15, 1.2];
    let mut header: Vec<String> = vec!["MHz \\ V".into()];
    header.extend(volts.iter().map(|v| format!("{v:.2}")));
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Fig. 13(a): shmoo plot (PASS/fail)", &hdr_refs);
    for &f in &freqs {
        let mut row = vec![format!("{f:.0}")];
        for &v in &volts {
            // +0.5 MHz guard: the V/f curve arithmetic is f64 and the
            // measured corners sit exactly on it
            row.push(if f <= em.freq_at_voltage(v) + 0.5 { "PASS".into() } else { ".".into() });
        }
        t.row(&row);
    }
    t.print();
    println!("measured corners: 100 MHz @ 0.9 V and 250 MHz @ 1.2 V both PASS\n");

    // ---- (b) specification table ----
    let mem = ChipMemories::paper();
    let fast = Chip::paper(ChipConfig::default());
    let slow = Chip::paper(ChipConfig::slow_corner());
    let r_fast = fast.train_episode(10, 5, true, false);
    let r_slow = slow.train_episode(10, 5, true, false);
    let mut t = Table::new("Fig. 13(b): chip specifications", &["item", "value"]);
    t.row(&["technology".into(), "40 nm CMOS (simulated)".into()]);
    t.row(&["die area".into(), "11.3 mm2 (as published)".into()]);
    t.row(&["on-chip memory".into(), format!(
        "{} KB (act {} + idx {} + cb {} + class {})",
        mem.total_kb(), mem.activation.kb, mem.index.kb, mem.codebook.kb, mem.class.kb)]);
    t.row(&["PE array".into(), format!("{} x {}", fast.cfg.pe_rows, fast.cfg.pe_cols)]);
    t.row(&["precision".into(), "BF16 FE / INT1-16 HDC".into()]);
    t.row(&["frequency".into(), "100 - 250 MHz".into()]);
    t.row(&["voltage".into(), "0.9 - 1.2 V".into()]);
    t.row(&["power (training avg)".into(),
        format!("{:.0} - {:.0} mW", r_slow.avg_power_mw, r_fast.avg_power_mw)]);
    t.row(&["feature dim F".into(), "16 - 1024 (model default 512)".into()]);
    t.row(&["HDC dim D".into(), "1024 - 8192 (default 4096)".into()]);
    t.row(&["max classes".into(), "128 @ 4-bit class HVs".into()]);
    t.row(&["peak throughput".into(), format!("{:.0} GOPS (effective)", fast.peak_gops())]);
    t.print();
    println!("paper: 424 KB, 100-250 MHz, 0.9-1.2 V, 59-305 mW, 197 GOPS, F 16-1024, D 1024-8192");
}
