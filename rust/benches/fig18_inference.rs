//! Fig. 18 — average inference latency and energy per 224x224 image,
//! FSL-HDnn with / without early exit vs the prior ODL chips.
//!
//! The EE exit distribution comes from the Fig. 17 harness at the paper's
//! operating point (E_s=2, E_c=2) on the CIFAR-100 preset.

use fsl_hdnn::baselines::chips::table1_chips;
use fsl_hdnn::config::{ChipConfig, EeConfig};
use fsl_hdnn::data::{DatasetPreset, SyntheticDataset};
use fsl_hdnn::experiments::eval_early_exit;
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::table::Table;

fn main() {
    let chip = Chip::paper(ChipConfig::default());
    // measure the exit distribution at (2,2) on the hard preset
    let ds = SyntheticDataset::new(DatasetPreset::Cifar100, 128, 21);
    let (_, _, hist) =
        eval_early_exit(&ds, 5, 5, 10, Some(EeConfig::paper_default()), 2048, 6, 31);
    let mut exits = Vec::new();
    for (stage, &count) in hist.iter().enumerate() {
        for _ in 0..count {
            exits.push(stage);
        }
    }
    let no_ee = chip.infer_image(10, None);
    let with_ee = chip.infer_with_exit_distribution(10, &exits);

    let mut t = Table::new(
        "Fig. 18: average inference latency & energy per image",
        &["design", "latency (ms)", "energy (mJ)"],
    );
    t.row(&["FSL-HDnn (no EE)".into(), format!("{:.1}", no_ee.latency_ms),
        format!("{:.2}", no_ee.energy_mj)]);
    t.row(&["FSL-HDnn (EE 2,2)".into(), format!("{:.1}", with_ee.latency_ms),
        format!("{:.2}", with_ee.energy_mj)]);
    for c in table1_chips() {
        t.row(&[format!("{} {}", c.name, c.venue), format!("{:.1}", c.infer_latency_ms_img),
            format!("{:.2}", c.infer_energy_mj_img)]);
    }
    t.print();
    let lat_red = 1.0 - with_ee.latency_ms / no_ee.latency_ms;
    let e_red = 1.0 - with_ee.energy_mj / no_ee.energy_mj;
    println!(
        "EE reduction: latency {:.0}%, energy {:.0}% (paper: ~32% both);\n\
         exit histogram by block: {hist:?}",
        100.0 * lat_red,
        100.0 * e_red
    );
    println!("paper shape check: FSL-HDnn balances latency and energy where [7] is slow");
    println!("and [5]/[6] are energy-hungry");
}
