//! Table I — the full comparison with state-of-the-art ODL accelerators:
//! published rows for [2]-[7] plus the simulated FSL-HDnn row.

use fsl_hdnn::baselines::chips::{relative_factors, table1_chips, OurChipRow};
use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::table::Table;

fn main() {
    let fast = Chip::paper(ChipConfig::default());
    let slow = Chip::paper(ChipConfig::slow_corner());
    let r_fast = fast.train_episode(10, 5, true, false);
    let r_slow = slow.train_episode(10, 5, true, false);
    // efficiency corner (~1.0 V) for the headline mJ/image
    let eff = Chip::paper(ChipConfig { voltage: 1.0, freq_mhz: 150.0, ..Default::default() });
    let r_eff = eff.train_episode(10, 5, true, false);

    let mut t = Table::new(
        "Table I: comparison with state-of-the-art ODL accelerators",
        &["design", "tech", "area mm2", "mem KB", "power mW", "precision",
          "algorithm", "GOPS", "train ms/img", "train mJ/img"],
    );
    for c in table1_chips() {
        t.row(&[
            format!("{} {}", c.name, c.venue),
            format!("{} nm", c.tech_nm),
            format!("{}", c.die_area_mm2),
            c.on_chip_kb.to_string(),
            format!("{}", c.power_mw_max),
            c.precision.into(),
            c.algorithm.into(),
            format!("{}", c.throughput_gops),
            format!("{}", c.train_latency_ms_img),
            format!("{}", c.train_energy_mj_img),
        ]);
    }
    t.row(&[
        "FSL-HDnn (this work, simulated)".into(),
        "40 nm".into(),
        "11.3".into(),
        "424".into(),
        format!("{:.0}-{:.0}", r_slow.avg_power_mw, r_fast.avg_power_mw),
        "BF16/INT1-16".into(),
        "HDC-based FSL".into(),
        format!("{:.0}", fast.peak_gops()),
        format!("{:.0}", r_fast.latency_ms_per_image),
        format!("{:.1}", r_eff.energy_mj_per_image),
    ]);
    t.print();

    let ours = OurChipRow {
        train_latency_ms_img: r_fast.latency_ms_per_image,
        train_energy_mj_img: r_eff.energy_mj_per_image,
    };
    let mut t = Table::new(
        "Table I factors: prior chip / FSL-HDnn",
        &["design", "latency factor", "energy factor"],
    );
    for (name, lat, en) in relative_factors(&ours) {
        t.row(&[name, format!("{lat:.1}x"), format!("{en:.1}x")]);
    }
    t.print();
    println!("paper shape check: latency factors 5.3-229.1x, energy factors 2.0-20.9x");
    println!("(paper row: 35 ms/img, 6 mJ/img, 197 GOPS, 59-305 mW, 1.4-2.9 TOPS/W)");
}
