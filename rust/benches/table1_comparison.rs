//! Table I — the full comparison with state-of-the-art ODL accelerators:
//! published rows for [2]-[7] plus the simulated FSL-HDnn row, and the
//! classifier-backend comparison (HDC vs LDC) at the paper's 10-way
//! 5-shot workload: capacity, accuracy and class-memory footprint per
//! backend.

use fsl_hdnn::baselines::chips::{relative_factors, table1_chips, OurChipRow};
use fsl_hdnn::classifier::ClassifierBackend;
use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::hdc::{quant, Distance};
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::table::Table;

fn main() {
    let fast = Chip::paper(ChipConfig::default());
    let slow = Chip::paper(ChipConfig::slow_corner());
    let r_fast = fast.train_episode(10, 5, true, false);
    let r_slow = slow.train_episode(10, 5, true, false);
    // efficiency corner (~1.0 V) for the headline mJ/image
    let eff = Chip::paper(ChipConfig { voltage: 1.0, freq_mhz: 150.0, ..Default::default() });
    let r_eff = eff.train_episode(10, 5, true, false);

    let mut t = Table::new(
        "Table I: comparison with state-of-the-art ODL accelerators",
        &["design", "tech", "area mm2", "mem KB", "power mW", "precision",
          "algorithm", "GOPS", "train ms/img", "train mJ/img"],
    );
    for c in table1_chips() {
        t.row(&[
            format!("{} {}", c.name, c.venue),
            format!("{} nm", c.tech_nm),
            format!("{}", c.die_area_mm2),
            c.on_chip_kb.to_string(),
            format!("{}", c.power_mw_max),
            c.precision.into(),
            c.algorithm.into(),
            format!("{}", c.throughput_gops),
            format!("{}", c.train_latency_ms_img),
            format!("{}", c.train_energy_mj_img),
        ]);
    }
    t.row(&[
        "FSL-HDnn (this work, simulated)".into(),
        "40 nm".into(),
        "11.3".into(),
        "424".into(),
        format!("{:.0}-{:.0}", r_slow.avg_power_mw, r_fast.avg_power_mw),
        "BF16/INT1-16".into(),
        "HDC-based FSL".into(),
        format!("{:.0}", fast.peak_gops()),
        format!("{:.0}", r_fast.latency_ms_per_image),
        format!("{:.1}", r_eff.energy_mj_per_image),
    ]);
    t.print();

    let ours = OurChipRow {
        train_latency_ms_img: r_fast.latency_ms_per_image,
        train_energy_mj_img: r_eff.energy_mj_per_image,
    };
    let mut t = Table::new(
        "Table I factors: prior chip / FSL-HDnn",
        &["design", "latency factor", "energy factor"],
    );
    for (name, lat, en) in relative_factors(&ours) {
        t.row(&[name, format!("{lat:.1}x"), format!("{en:.1}x")]);
    }
    t.print();
    println!("paper shape check: latency factors 5.3-229.1x, energy factors 2.0-20.9x");
    println!("(paper row: 35 ms/img, 6 mJ/img, 197 GOPS, 59-305 mW, 1.4-2.9 TOPS/W)");

    // --- classifier backends at the paper workload (10-way 5-shot,
    // D=4096 ingest, 4-bit class rows): capacity / accuracy / class-mem
    // per backend. LDC (Duan et al.) folds to low-D prototypes and must
    // cut the class-memory footprint >= 4x at matched n_way.
    let (n_way, k_shot, d) = (10usize, 5usize, 4096usize);
    let mut rng = Rng::new(1);
    let protos: Vec<Vec<f32>> =
        (0..n_way).map(|_| (0..d).map(|_| 2.0 * rng.gauss_f32()).collect()).collect();
    let mut t = Table::new(
        "classifier backends, 10-way 5-shot @ D=4096 ingest, 4-bit class rows",
        &["backend", "stored dim", "class-mem KB", "classes @256KB", "accuracy"],
    );
    let mut mem_bits = Vec::new();
    for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
        let mut m = backend.build(n_way, d, 4, Distance::L1, 0);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..k_shot {
                let hv: Vec<f32> = p.iter().map(|&v| v + 0.3 * rng.gauss_f32()).collect();
                m.train_shot(c, &hv);
            }
        }
        let queries = 10 * n_way;
        let correct = (0..queries)
            .filter(|&i| {
                let c = i % n_way;
                let q: Vec<f32> =
                    protos[c].iter().map(|&v| v + 0.3 * rng.gauss_f32()).collect();
                m.predict(&q) == c
            })
            .count();
        t.row(&[
            backend.name().into(),
            m.stored_dim().to_string(),
            format!("{:.1}", m.class_mem_bits() as f64 / 8192.0),
            quant::classes_capacity(256, m.stored_dim(), 4).to_string(),
            format!("{:.0}% ({correct}/{queries})", 100.0 * correct as f64 / queries as f64),
        ]);
        mem_bits.push(m.class_mem_bits());
    }
    t.print();
    assert!(
        mem_bits[0] >= 4 * mem_bits[1],
        "LDC must cut class memory >= 4x at matched n_way: hdc {} vs ldc {}",
        mem_bits[0],
        mem_bits[1]
    );
    println!(
        "backend shape check: LDC stores {:.1}x less class memory than HDC at 10-way \
         (>= 4x required), same single-pass training",
        mem_bits[0] as f64 / mem_bits[1] as f64
    );
}
