//! Fig. 16 — average per-image training latency and energy with and
//! without batched single-pass training, across the V/f operating points.
//!
//! The second section is the *software* counterpart of the chip's batching
//! story: the native backend's batched FE+encode path, serial vs sharded
//! across the worker pool (`--workers N`, 0 = one per core), with
//! bit-identical output asserted.

use fsl_hdnn::config::{ChipConfig, ModelConfig, ParallelConfig};
use fsl_hdnn::runtime::ComputeEngine;
use fsl_hdnn::sim::{Chip, EnergyModel};
use fsl_hdnn::util::args::arg_usize;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::table::Table;
use fsl_hdnn::util::timer::{bench, black_box};

fn main() {
    let em = EnergyModel::default();
    let mut t = Table::new(
        "Fig. 16: 10-way 5-shot training, per-image latency & energy",
        &["V / MHz", "lat no-batch (ms)", "lat batched (ms)", "saving",
          "E no-batch (mJ)", "E batched (mJ)", "saving"],
    );
    let mut savings = Vec::new();
    for &v in &[0.9, 1.0, 1.1, 1.2] {
        let mhz = em.freq_at_voltage(v);
        let chip = Chip::paper(ChipConfig { voltage: v, freq_mhz: mhz, ..Default::default() });
        let nb = chip.train_episode(10, 5, false, false);
        let b = chip.train_episode(10, 5, true, false);
        let lat_saving = 1.0 - b.latency_ms_per_image / nb.latency_ms_per_image;
        let e_saving = 1.0 - b.energy_mj_per_image / nb.energy_mj_per_image;
        savings.push(lat_saving);
        t.row(&[
            format!("{v:.1} / {mhz:.0}"),
            format!("{:.1}", nb.latency_ms_per_image),
            format!("{:.1}", b.latency_ms_per_image),
            format!("{:.0}%", 100.0 * lat_saving),
            format!("{:.2}", nb.energy_mj_per_image),
            format!("{:.2}", b.energy_mj_per_image),
            format!("{:.0}%", 100.0 * e_saving),
        ]);
    }
    t.print();
    println!(
        "paper shape check: 18-32% per-image savings, growing with frequency \
         (ours: {:.0}%..{:.0}%, monotone: {})",
        100.0 * savings[0],
        100.0 * savings[3],
        savings.windows(2).all(|w| w[1] >= w[0])
    );
    println!("batched training reaches ~6 mJ/image at the efficiency corner");

    // --- native parallel batched execution (the software utilization fix) ---
    let par = ParallelConfig { workers: arg_usize("--workers", 0), min_batch_per_worker: 1 };
    let serial = ComputeEngine::from_config(ModelConfig::default());
    let sharded = ComputeEngine::from_config(ModelConfig::default()).with_parallelism(par);
    let m = serial.model().clone();
    let mut rng = Rng::new(16);
    // one 10-way 5-shot episode's worth of training images
    let images: Vec<Vec<f32>> = (0..50)
        .map(|_| {
            (0..m.image_size * m.image_size * m.in_channels).map(|_| rng.gauss_f32()).collect()
        })
        .collect();
    let train_pass = |e: &ComputeEngine| {
        let feats = e.fe_forward(&images).unwrap();
        let finals: Vec<Vec<f32>> = feats.into_iter().map(|mut b| b.pop().unwrap()).collect();
        e.encode(&finals).unwrap()
    };
    assert_eq!(train_pass(&serial), train_pass(&sharded), "parallel must be bit-identical");
    let rs = bench("native FE+encode, 50 imgs, serial", 800.0, || {
        black_box(train_pass(&serial));
    });
    let nw = par.resolved_workers();
    let rp = bench(&format!("native FE+encode, 50 imgs, {nw} workers"), 800.0, || {
        black_box(train_pass(&sharded));
    });
    println!("\n{rs}");
    println!("{rp}");
    println!(
        "software counterpart: {:.2} -> {:.2} ms/image at {nw} workers \
         ({:.2}x, bit-identical output)",
        rs.mean_ms() / 50.0,
        rp.mean_ms() / 50.0,
        rs.mean_ns / rp.mean_ns
    );
}
