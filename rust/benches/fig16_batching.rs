//! Fig. 16 — average per-image training latency and energy with and
//! without batched single-pass training, across the V/f operating points.

use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::sim::{Chip, EnergyModel};
use fsl_hdnn::util::table::Table;

fn main() {
    let em = EnergyModel::default();
    let mut t = Table::new(
        "Fig. 16: 10-way 5-shot training, per-image latency & energy",
        &["V / MHz", "lat no-batch (ms)", "lat batched (ms)", "saving",
          "E no-batch (mJ)", "E batched (mJ)", "saving"],
    );
    let mut savings = Vec::new();
    for &v in &[0.9, 1.0, 1.1, 1.2] {
        let mhz = em.freq_at_voltage(v);
        let chip = Chip::paper(ChipConfig { voltage: v, freq_mhz: mhz, ..Default::default() });
        let nb = chip.train_episode(10, 5, false, false);
        let b = chip.train_episode(10, 5, true, false);
        let lat_saving = 1.0 - b.latency_ms_per_image / nb.latency_ms_per_image;
        let e_saving = 1.0 - b.energy_mj_per_image / nb.energy_mj_per_image;
        savings.push(lat_saving);
        t.row(&[
            format!("{v:.1} / {mhz:.0}"),
            format!("{:.1}", nb.latency_ms_per_image),
            format!("{:.1}", b.latency_ms_per_image),
            format!("{:.0}%", 100.0 * lat_saving),
            format!("{:.2}", nb.energy_mj_per_image),
            format!("{:.2}", b.energy_mj_per_image),
            format!("{:.0}%", 100.0 * e_saving),
        ]);
    }
    t.print();
    println!(
        "paper shape check: 18-32% per-image savings, growing with frequency \
         (ours: {:.0}%..{:.0}%, monotone: {})",
        100.0 * savings[0],
        100.0 * savings[3],
        savings.windows(2).all(|w| w[1] >= w[0])
    );
    println!("batched training reaches ~6 mJ/image at the efficiency corner");
}
