//! Fig. 17 — average CONV layers executed and FSL accuracy for each
//! early-exit configuration (E_s, E_c), per dataset preset; each of the
//! 4 CONV blocks of ResNet-18 contains ~4-5 CONV layers (Fig. 11).
//!
//! Two parts:
//! 1. the accuracy/depth sweep over the calibrated synthetic branch
//!    features (the paper-shape protocol, `experiments::eval_early_exit`);
//! 2. the **measured staged hot path**: a live coordinator serving the
//!    same (E_s, E_c) grid through `Request::Query` /
//!    `Request::QueryBatch`, with measured per-query latency, the
//!    provable `fe_layers_executed` / `branch_hvs_encoded` counters
//!    (early exit truncates real FE compute — DESIGN.md §Staged
//!    inference) and the chip simulator's energy-per-query split by exit
//!    depth. Headline numbers land in `BENCH_hotpath.json`.
//!
//! `--smoke` shrinks the workload to CI size; every numeric assert
//! (counter accounting, batch-vs-serial bit-identity) still runs.

use std::time::Instant;

use fsl_hdnn::config::{ChipConfig, EeConfig, ModelConfig};
use fsl_hdnn::coordinator::Coordinator;
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::data::{DatasetPreset, SyntheticDataset};
use fsl_hdnn::experiments::eval_early_exit;
use fsl_hdnn::runtime::ComputeEngine;
use fsl_hdnn::sim::workload::{prefix, resnet18_224};
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::args::arg_flag;
use fsl_hdnn::util::bench_log::BenchLog;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::table::Table;

fn main() {
    let smoke = arg_flag("--smoke");
    let mut log = BenchLog::new("fig17_early_exit");

    // --- part 1: accuracy vs depth over calibrated branch features ---
    let (n_way, k_shot) = (5, 5);
    let (queries, episodes, d) = if smoke { (2, 1, 256) } else { (8, 6, 2048) };
    let layers = resnet18_224();
    let total_layers = layers.len();
    let layers_at_stage: Vec<usize> = (0..4).map(|s| prefix(&layers, s).len()).collect();

    let presets: &[DatasetPreset] = if smoke {
        &[DatasetPreset::Flower102]
    } else {
        &[DatasetPreset::Cifar100, DatasetPreset::Flower102, DatasetPreset::TrafficSign]
    };
    for &preset in presets {
        let ds = SyntheticDataset::new(preset, 128, 21);
        let mut t = Table::new(
            &format!("Fig. 17 on {}: EE config vs depth & accuracy", preset.name()),
            &[
                "config (E_s-E_c)",
                "avg CONV layers",
                "layers skipped",
                "accuracy",
                "exit histogram",
            ],
        );
        let (full_acc, _, _) = eval_early_exit(&ds, n_way, k_shot, queries, None, d, episodes, 31);
        t.row(&[
            "no EE".into(),
            format!("{total_layers:.1}"),
            "0%".into(),
            format!("{:.1}%", 100.0 * full_acc),
            "-".into(),
        ]);
        for (e_s, e_c) in [(1usize, 1usize), (1, 2), (1, 3), (2, 2), (2, 3), (3, 2)] {
            let (acc, avg_blocks, hist) = eval_early_exit(
                &ds, n_way, k_shot, queries, Some(EeConfig { e_s, e_c }), d, episodes, 31,
            );
            // convert average exit *block* into average CONV layers
            let total_q: u64 = hist.iter().sum();
            let avg_layers: f64 = hist
                .iter()
                .enumerate()
                .map(|(s, &c)| layers_at_stage[s] as f64 * c as f64)
                .sum::<f64>()
                / total_q as f64;
            t.row(&[
                format!("{e_s}-{e_c}"),
                format!("{avg_layers:.1}"),
                format!("{:.0}%", 100.0 * (1.0 - avg_layers / total_layers as f64)),
                format!("{:.1}%", 100.0 * acc),
                format!("{:?} (avg block {avg_blocks:.2})", hist),
            ]);
        }
        t.print();
        println!();
    }

    // --- part 2: the measured staged hot path -------------------------
    // A live coordinator on the synthetic native engine; every query runs
    // the staged loop, so the layer/encode counters report what actually
    // executed and early exit shows up as measured latency, not as an
    // after-the-fact replay.
    let cfg = if smoke {
        // same 4-branch shape, CI-sized geometry (asserts are identical)
        ModelConfig {
            image_size: 16,
            widths: vec![8, 16, 32, 64],
            blocks_per_stage: 1,
            feature_dim: 64,
            d: 512,
            ..Default::default()
        }
    } else {
        ModelConfig::default()
    };
    let probe = ComputeEngine::from_config(cfg.clone());
    let plan_layers = probe.fe_plan_layers();
    let n_branches = probe.model().n_branches();
    let coord = {
        let c = cfg.clone();
        Coordinator::start(move || Ok(ComputeEngine::from_config(c)), k_shot).unwrap()
    };
    let gen = ImageGen::new(cfg.image_size, 32, 17);
    let mut rng = Rng::new(17);
    let classes = rng.choose_k(gen.n_classes, n_way);
    let sid = coord.create_session(n_way, 4).unwrap();
    for (label, &cls) in classes.iter().enumerate() {
        let shots: Vec<Vec<f32>> = (0..k_shot).map(|_| gen.sample(cls, &mut rng)).collect();
        coord.add_shot_batch(sid, label, shots).unwrap();
    }
    coord.finish_training(sid).unwrap();
    let per_class = if smoke { 2 } else { 8 };
    let mut queryset: Vec<(Vec<f32>, usize)> = Vec::new();
    for (label, &cls) in classes.iter().enumerate() {
        let mut r = Rng::new(900 + cls as u64);
        for _ in 0..per_class {
            queryset.push((gen.sample(cls, &mut r), label));
        }
    }

    // counter accounting, asserted per query class (the ISSUE acceptance:
    // an exit at block b executes only stages 0..=b and encodes b+1 HVs)
    let before = coord.metrics();
    let out_full = coord.query(sid, queryset[0].0.clone(), None).unwrap();
    let mid = coord.metrics();
    assert_eq!(out_full.blocks_used, n_branches);
    assert_eq!(
        mid.fe_layers_executed - before.fe_layers_executed,
        plan_layers as u64,
        "a no-EE query runs the whole plan"
    );
    assert_eq!(
        mid.branch_hvs_encoded - before.branch_hvs_encoded,
        1,
        "a no-EE query encodes only the final branch"
    );
    let ee22 = EeConfig::paper_default();
    let out_ee = coord.query(sid, queryset[0].0.clone(), Some(ee22)).unwrap();
    let after = coord.metrics();
    assert_eq!(
        after.fe_layers_executed - mid.fe_layers_executed,
        probe.fe_layers_through(out_ee.blocks_used) as u64,
        "an exit at block {} executes exactly the prefix plan",
        out_ee.blocks_used
    );
    assert_eq!(
        after.branch_hvs_encoded - mid.branch_hvs_encoded,
        out_ee.blocks_used as u64,
        "an exit at block b encodes exactly b+1 branch HVs"
    );

    // ragged QueryBatch must be bit-identical to the serial loop
    let imgs: Vec<Vec<f32>> = queryset.iter().map(|(i, _)| i.clone()).collect();
    let serial: Vec<_> =
        imgs.iter().map(|i| coord.query(sid, i.clone(), Some(ee22)).unwrap()).collect();
    let batched = coord.query_batch(sid, imgs.clone(), Some(ee22)).unwrap();
    assert_eq!(batched, serial, "QueryBatch must match serial Query outcomes");

    // the measured table: per config, wall latency + counted layers +
    // chip-sim energy weighted by the live exit histogram
    let chip = Chip::paper(ChipConfig::default());
    let depth_table = chip.infer_depth_table(n_way);
    let full_sim = chip.infer_image(n_way, None);
    let mut t = Table::new(
        "measured staged serving (native engine; energy from the chip sim @250 MHz/1.2 V)",
        &[
            "config (E_s-E_c)",
            "ms/query (measured)",
            "avg FE layers (counted)",
            "layers skipped",
            "sim energy mJ/query",
            "accuracy",
        ],
    );
    let mut rows: Vec<(String, Option<EeConfig>)> = vec![("no EE".into(), None)];
    for (e_s, e_c) in [(1usize, 1usize), (1, 2), (2, 2), (2, 3)] {
        rows.push((format!("{e_s}-{e_c}"), Some(EeConfig { e_s, e_c })));
    }
    let mut full_ms = 0.0;
    let mut ee22_ms = 0.0;
    let mut ee22_mj = 0.0;
    let full_mj = full_sim.energy_mj;
    for (name, ee) in rows {
        let m0 = coord.metrics();
        let t0 = Instant::now();
        let outs = coord.query_batch(sid, imgs.clone(), ee).unwrap();
        let ms = t0.elapsed().as_secs_f64() * 1e3 / outs.len() as f64;
        let m1 = coord.metrics();
        let layers = (m1.fe_layers_executed - m0.fe_layers_executed) as f64 / outs.len() as f64;
        let correct = outs.iter().zip(&queryset).filter(|(o, (_, l))| o.prediction == *l).count();
        let mj = match ee {
            None => full_sim.energy_mj,
            Some(_) => {
                outs.iter()
                    .map(|o| depth_table[o.blocks_used - 1].energy_mj)
                    .sum::<f64>()
                    / outs.len() as f64
            }
        };
        if ee.is_none() {
            full_ms = ms;
        } else if ee == Some(ee22) {
            ee22_ms = ms;
            ee22_mj = mj;
        }
        t.row(&[
            name,
            format!("{ms:.2}"),
            format!("{layers:.1}/{plan_layers}"),
            format!("{:.0}%", 100.0 * (1.0 - layers / plan_layers as f64)),
            format!("{mj:.3}"),
            format!("{:.1}%", 100.0 * correct as f64 / outs.len() as f64),
        ]);
    }
    t.print();

    // the tracked hot-path numbers (EXPERIMENTS.md §Perf fill-in rows)
    log.record("query_full_staged", full_ms * 1e6, 1e3 / full_ms, 1);
    log.record("query_ee_2_2_staged", ee22_ms * 1e6, 1e3 / ee22_ms, 1);
    log.record_ratio("ee_2_2_vs_full_latency_speedup", full_ms / ee22_ms);
    log.record_ratio("ee_2_2_vs_full_sim_energy", ee22_mj / full_mj);
    let m = coord.metrics();
    let frac = m.fe_layers_skipped as f64
        / (m.fe_layers_executed + m.fe_layers_skipped).max(1) as f64;
    log.record_ratio("fe_layers_skipped_frac", frac);
    println!(
        "\nEE 2-2 vs full: {:.2}x measured latency, {:.2}x sim energy, \
         {:.0}% of FE layers skipped across the run",
        full_ms / ee22_ms,
        ee22_mj / full_mj,
        100.0 * frac
    );
    // saving requires queries to actually exit; the counter asserts above
    // are the deterministic gate, this one documents the energy win
    if m.early_exit_rate > 0.5 {
        assert!(
            ee22_mj < full_mj,
            "with most queries exiting, EE energy must beat the full pass: \
             {ee22_mj} vs {full_mj} mJ"
        );
    }

    match log.write() {
        Ok(path) => println!("bench trajectory written to {}", path.display()),
        Err(e) => eprintln!("warning: could not write bench trajectory: {e}"),
    }
    println!("\npaper shape check: (1,2) skips up to ~45% of layers at a ~3.5% accuracy cost;");
    println!("(1,3) keeps near-optimal accuracy skipping 15-20%; (2,2) is the sweet spot:");
    println!("20-25% skipped at <1% loss");
}
