//! Fig. 17 — average CONV layers executed and FSL accuracy for each
//! early-exit configuration (E_s, E_c), per dataset preset. Each of the
//! 4 CONV blocks of ResNet-18 contains ~4-5 CONV layers (Fig. 11).

use fsl_hdnn::config::EeConfig;
use fsl_hdnn::data::{DatasetPreset, SyntheticDataset};
use fsl_hdnn::experiments::eval_early_exit;
use fsl_hdnn::sim::workload::{prefix, resnet18_224};
use fsl_hdnn::util::table::Table;

fn main() {
    let (n_way, k_shot, queries, episodes, d) = (5, 5, 8, 6, 2048);
    let layers = resnet18_224();
    let total_layers = layers.len();
    let layers_at_stage: Vec<usize> = (0..4).map(|s| prefix(&layers, s).len()).collect();

    for preset in [DatasetPreset::Cifar100, DatasetPreset::Flower102, DatasetPreset::TrafficSign] {
        let ds = SyntheticDataset::new(preset, 128, 21);
        let mut t = Table::new(
            &format!("Fig. 17 on {}: EE config vs depth & accuracy", preset.name()),
            &[
                "config (E_s-E_c)",
                "avg CONV layers",
                "layers skipped",
                "accuracy",
                "exit histogram",
            ],
        );
        let (full_acc, _, _) = eval_early_exit(&ds, n_way, k_shot, queries, None, d, episodes, 31);
        t.row(&[
            "no EE".into(),
            format!("{total_layers:.1}"),
            "0%".into(),
            format!("{:.1}%", 100.0 * full_acc),
            "-".into(),
        ]);
        for (e_s, e_c) in [(1usize, 1usize), (1, 2), (1, 3), (2, 2), (2, 3), (3, 2)] {
            let (acc, avg_blocks, hist) = eval_early_exit(
                &ds, n_way, k_shot, queries, Some(EeConfig { e_s, e_c }), d, episodes, 31,
            );
            // convert average exit *block* into average CONV layers
            let total_q: u64 = hist.iter().sum();
            let avg_layers: f64 = hist
                .iter()
                .enumerate()
                .map(|(s, &c)| layers_at_stage[s] as f64 * c as f64)
                .sum::<f64>()
                / total_q as f64;
            t.row(&[
                format!("{e_s}-{e_c}"),
                format!("{avg_layers:.1}"),
                format!("{:.0}%", 100.0 * (1.0 - avg_layers / total_layers as f64)),
                format!("{:.1}%", 100.0 * acc),
                format!("{:?} (avg block {avg_blocks:.2})", hist),
            ]);
        }
        t.print();
        println!();
    }
    println!("paper shape check: (1,2) skips up to ~45% of layers at a ~3.5% accuracy cost;");
    println!("(1,3) keeps near-optimal accuracy skipping 15-20%; (2,2) is the sweet spot:");
    println!("20-25% skipped at <1% loss");
}
