//! Fig. 19 — end-to-end training energy vs latency for the 10-way 5-shot
//! FSL task (50 images; FT baselines use 5 epochs): the scatter the paper
//! closes with.

use fsl_hdnn::baselines::chips::table1_chips;
use fsl_hdnn::config::ChipConfig;
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::table::Table;

fn main() {
    let chip = Chip::paper(ChipConfig::default());
    let ours = chip.train_episode(10, 5, true, true);
    let ours_sec = ours.latency_ms / 1e3 * 1.0; // latency_ms is total already? see below
    let _ = ours_sec;

    let mut t = Table::new(
        "Fig. 19: end-to-end 10-way 5-shot training (50 images)",
        &["design", "latency (s)", "energy (mJ)", "lat vs ours", "E vs ours"],
    );
    let our_sec = ours.latency_ms / 1e3;
    let our_mj = ours.energy_mj;
    t.row(&[
        "FSL-HDnn (this work)".into(),
        format!("{our_sec:.2}"),
        format!("{our_mj:.0}"),
        "1.0x".into(),
        "1.0x".into(),
    ]);
    for c in table1_chips() {
        let (sec, mj) = c.end_to_end_train();
        t.row(&[
            format!("{} {}", c.name, c.venue),
            format!("{sec:.1}"),
            format!("{mj:.0}"),
            format!("{:.1}x", sec / our_sec),
            format!("{:.1}x", mj / our_mj),
        ]);
    }
    t.print();
    println!(
        "paper shape check: FSL-HDnn trains in ~1.7 s (ours: {our_sec:.2} s) vs 9.2-396 s \
         for [2]-[7], at 2-21x less energy"
    );
}
