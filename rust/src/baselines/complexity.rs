//! Training-cost model — eqs. (1), (2), (6) and the Fig. 3(b) axes.
//!
//! Costs are in dense-equivalent operations on the ResNet-18 @224 backbone
//! (Cost_FP = 2 * 1.8G MACs). Full FT pays FP+GC+BP+WU per sample per
//! iteration; partial FT drops most of BP/WU; kNN and FSL-HDnn are
//! single-pass and gradient-free.

use crate::sim::workload::{resnet18_224, total_macs};

/// Per-pass operation costs (dense-equivalent ops) for one image.
#[derive(Clone, Copy, Debug)]
pub struct PassCosts {
    pub fp: f64,
    pub gc: f64,
    pub bp: f64,
    pub wu: f64,
    pub hdc: f64,
}

impl PassCosts {
    /// ResNet-18 @ 224 with D=4096, F=512 HDC head.
    pub fn resnet18() -> Self {
        let fp = 2.0 * total_macs(&resnet18_224()) as f64;
        // standard backprop accounting: grad-wrt-input (BP) and
        // grad-wrt-weights (GC) each cost about one forward pass
        let bp = fp;
        let gc = fp;
        // weight update: one MAC per parameter
        let wu = 2.0 * 11.7e6;
        // HDC: encode (D*F sign-adds) + class update (D adds)
        let hdc = (4096.0 * 512.0) + 4096.0;
        PassCosts { fp, gc, bp, wu, hdc }
    }

    /// eq. (1): full fine-tuning.
    pub fn full_ft(&self, iters: usize, samples: usize) -> f64 {
        iters as f64 * samples as f64 * (self.fp + self.gc + self.bp + self.wu)
    }

    /// eq. (2): partial fine-tuning — only the classifier fraction `rho`
    /// of weights trains, removing most BP/WU (the paper's partial-FT
    /// baselines retrain the final block / head).
    pub fn partial_ft(&self, iters: usize, samples: usize, rho: f64) -> f64 {
        iters as f64
            * samples as f64
            * (self.fp + rho * (self.gc + self.bp + self.wu))
    }

    /// kNN: feature extraction only, single pass (plus negligible store).
    pub fn knn(&self, samples: usize) -> f64 {
        samples as f64 * self.fp
    }

    /// eq. (6): FSL-HDnn — single pass, FP (with clustered-conv reduction
    /// `op_red`) + HDC.
    pub fn fsl_hdnn(&self, samples: usize, op_red: f64) -> f64 {
        samples as f64 * (self.fp / op_red + self.hdc)
    }
}

/// The paper's headline ops claim: FSL-HDnn reduces training ops by ~21x
/// vs FT-based methods (Section VI-C1: 5 epochs, 10-way 5-shot).
pub fn ops_reduction_vs_ft(epochs: usize) -> f64 {
    let c = PassCosts::resnet18();
    let samples = 50;
    c.full_ft(epochs, samples) / c.fsl_hdnn(samples, 2.1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_ft_dominates_everything() {
        let c = PassCosts::resnet18();
        let (it, n) = (5, 50);
        let full = c.full_ft(it, n);
        let part = c.partial_ft(it, n, 0.3);
        let knn = c.knn(n);
        let ours = c.fsl_hdnn(n, 2.1);
        assert!(full > part && part > knn && knn > ours);
    }

    #[test]
    fn headline_21x_claim_shape() {
        // 5 epochs of full FT vs single-pass FSL-HDnn: the paper says 21x;
        // accept the right order of magnitude (our op accounting differs
        // in the backprop constant)
        let r = ops_reduction_vs_ft(5);
        assert!((10.0..45.0).contains(&r), "got {r:.1}x");
    }

    #[test]
    fn partial_ft_between_knn_and_full() {
        let c = PassCosts::resnet18();
        assert!(c.partial_ft(15, 50, 0.1) < c.full_ft(15, 50));
        assert!(c.partial_ft(1, 50, 0.0) >= c.knn(50));
    }

    #[test]
    fn hdc_overhead_negligible() {
        let c = PassCosts::resnet18();
        assert!(c.hdc / c.fp < 0.01, "HDC must be <1% of a forward pass");
    }

    #[test]
    fn single_pass_scales_linearly() {
        let c = PassCosts::resnet18();
        assert!((c.fsl_hdnn(100, 2.1) / c.fsl_hdnn(50, 2.1) - 2.0).abs() < 1e-9);
    }
}
