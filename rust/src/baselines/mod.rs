//! Baselines the paper compares against.
//!
//! * algorithmic: kNN-L1 [17,18], partial fine-tuning (linear probe with
//!   SGD), full fine-tuning (MLP head with backprop) — all consuming the
//!   same frozen features as FSL-HDnn (Figs. 3, 15);
//! * analytic: the training-cost model of eqs. (1), (2), (6) (Fig. 3b,
//!   the 21x ops claim) and the prior ODL chips of Table I as published
//!   cost models (Table I, Figs. 18, 19).

pub mod chips;
pub mod complexity;
pub mod full_ft;
pub mod knn;
pub mod linear_probe;

pub use knn::KnnClassifier;
pub use linear_probe::LinearProbe;
pub use full_ft::MlpHead;
