//! Baselines the paper compares against (layer map in DESIGN.md).
//!
//! * algorithmic: kNN-L1 [17,18] ([`knn`]), partial fine-tuning — a
//!   linear probe with SGD ([`linear_probe`]) — and full fine-tuning — an
//!   MLP head with backprop ([`full_ft`]) — all consuming the same frozen
//!   features as FSL-HDnn (Figs. 3, 15);
//! * analytic: the training-cost model of eqs. (1), (2), (6)
//!   ([`complexity`]; Fig. 3b, the 21x ops claim) and the prior ODL chips
//!   of Table I as published cost models ([`chips`]; Figs. 18, 19).
//!
//! Accuracy baselines run inside [`crate::experiments::eval_learner`] on
//! the synthetic episode samplers; cost baselines are pure arithmetic, so
//! every bench can regenerate the paper's comparison tables offline.

pub mod chips;
pub mod complexity;
pub mod full_ft;
pub mod knn;
pub mod linear_probe;

pub use knn::KnnClassifier;
pub use linear_probe::LinearProbe;
pub use full_ft::MlpHead;
