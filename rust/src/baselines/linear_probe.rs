//! Partial fine-tuning baseline: multinomial logistic regression (linear
//! probe) on the frozen features, trained with minibatch SGD — the
//! "retrain only the last layer(s)" family of ODL accelerators
//! ([4], [9], [10]; eq. (2)). Iterative and gradient-based, unlike
//! FSL-HDnn's single pass.

use crate::util::prng::Rng;

/// Softmax-regression head trained by SGD.
#[derive(Clone, Debug)]
pub struct LinearProbe {
    pub n_classes: usize,
    pub dim: usize,
    /// weights (n_classes x dim) + bias (n_classes)
    w: Vec<f32>,
    b: Vec<f32>,
    pub lr: f32,
    pub weight_decay: f32,
    /// feature RMS captured by `fit` and re-applied at prediction time
    scale: f32,
}

impl LinearProbe {
    pub fn new(n_classes: usize, dim: usize) -> Self {
        LinearProbe {
            n_classes,
            dim,
            w: vec![0.0; n_classes * dim],
            b: vec![0.0; n_classes],
            lr: 0.05,
            weight_decay: 1e-4,
            scale: 1.0,
        }
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.dim);
        (0..self.n_classes)
            .map(|c| {
                let row = &self.w[c * self.dim..(c + 1) * self.dim];
                let mut s = self.b[c];
                for (wi, xi) in row.iter().zip(x) {
                    s += wi * xi;
                }
                s
            })
            .collect()
    }

    fn softmax(logits: &[f32]) -> Vec<f32> {
        let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let exps: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }

    /// One SGD step on one example; returns the cross-entropy loss.
    pub fn sgd_step(&mut self, x: &[f32], label: usize) -> f32 {
        let probs = Self::softmax(&self.logits(x));
        let loss = -probs[label].max(1e-12).ln();
        for c in 0..self.n_classes {
            let g = probs[c] - if c == label { 1.0 } else { 0.0 };
            let row = &mut self.w[c * self.dim..(c + 1) * self.dim];
            for (wi, xi) in row.iter_mut().zip(x) {
                *wi -= self.lr * (g * xi + self.weight_decay * *wi);
            }
            self.b[c] -= self.lr * g;
        }
        loss
    }

    /// Train for `epochs` passes over the support set (shuffled).
    /// Returns the mean loss of the final epoch.
    pub fn fit(&mut self, xs: &[Vec<f32>], ys: &[usize], epochs: usize, rng: &mut Rng) -> f32 {
        assert_eq!(xs.len(), ys.len());
        // feature scale normalization makes the fixed lr robust
        let scale = (xs
            .iter()
            .flat_map(|x| x.iter())
            .map(|v| (v * v) as f64)
            .sum::<f64>()
            / (xs.len().max(1) * self.dim) as f64)
            .sqrt()
            .max(1e-6) as f32;
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut last = 0.0;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            last = 0.0;
            for &i in &order {
                let x: Vec<f32> = xs[i].iter().map(|v| v / scale).collect();
                last += self.sgd_step(&x, ys[i]);
            }
            last /= xs.len().max(1) as f32;
        }
        self.scale = scale;
        last
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let xs: Vec<f32> = x.iter().map(|v| v / self.scale.max(1e-6)).collect();
        let logits = self.logits(&xs);
        // shared NaN-robust selection: the hand-rolled `l > logits[best]`
        // loop silently elected class 0 on a NaN logit at index 0
        crate::hdc::distance::argmax(&logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_data(rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<usize>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for c in 0..3usize {
            for _ in 0..10 {
                let mut x = vec![0.0f32; 6];
                x[c * 2] = 2.0 + 0.3 * rng.gauss_f32();
                x[c * 2 + 1] = 2.0 + 0.3 * rng.gauss_f32();
                xs.push(x);
                ys.push(c);
            }
        }
        (xs, ys)
    }

    #[test]
    fn learns_separable_data() {
        let mut rng = Rng::new(1);
        let (xs, ys) = toy_data(&mut rng);
        let mut lp = LinearProbe::new(3, 6);
        lp.fit(&xs, &ys, 20, &mut rng);
        let correct = xs
            .iter()
            .zip(&ys)
            .filter(|(x, &y)| lp.predict(x) == y)
            .count();
        assert!(correct >= 28, "{correct}/30");
    }

    #[test]
    fn loss_decreases_over_epochs() {
        let mut rng = Rng::new(2);
        let (xs, ys) = toy_data(&mut rng);
        let mut lp = LinearProbe::new(3, 6);
        let l1 = lp.fit(&xs, &ys, 1, &mut rng);
        let mut lp2 = LinearProbe::new(3, 6);
        let l20 = lp2.fit(&xs, &ys, 20, &mut rng);
        assert!(l20 < l1, "loss should fall: {l20} vs {l1}");
    }

    #[test]
    fn untrained_predicts_first_class() {
        let lp = LinearProbe::new(4, 3);
        assert_eq!(lp.predict(&[1.0, 2.0, 3.0]), 0);
    }

    #[test]
    fn more_epochs_never_catastrophic() {
        let mut rng = Rng::new(3);
        let (xs, ys) = toy_data(&mut rng);
        let mut lp = LinearProbe::new(3, 6);
        lp.fit(&xs, &ys, 100, &mut rng);
        let correct = xs.iter().zip(&ys).filter(|(x, &y)| lp.predict(x) == y).count();
        assert!(correct >= 28);
    }
}
