//! kNN-L1 baseline [17], [18]: no training beyond storing support
//! features; classification = majority vote over the k nearest stored
//! samples under L1 distance. Cheap but accuracy-limited (Figs. 3b, 15) —
//! the gap FSL-HDnn closes.

use crate::hdc::distance::l1;

/// kNN classifier over raw feature vectors.
#[derive(Clone, Debug, Default)]
pub struct KnnClassifier {
    pub k: usize,
    store: Vec<(Vec<f32>, usize)>,
    n_classes: usize,
}

impl KnnClassifier {
    pub fn new(k: usize) -> Self {
        KnnClassifier { k: k.max(1), store: Vec::new(), n_classes: 0 }
    }

    /// "Training" = memorize the support set.
    pub fn add_example(&mut self, feature: Vec<f32>, label: usize) {
        self.n_classes = self.n_classes.max(label + 1);
        self.store.push((feature, label));
    }

    pub fn len(&self) -> usize {
        self.store.len()
    }

    pub fn is_empty(&self) -> bool {
        self.store.is_empty()
    }

    pub fn predict(&self, query: &[f32]) -> usize {
        assert!(!self.store.is_empty(), "predict on empty kNN store");
        let mut dists: Vec<(f64, usize)> = self
            .store
            .iter()
            .map(|(f, l)| (l1(query, f), *l))
            .collect();
        // total_cmp so a NaN distance (NaN feature value) sorts last as a
        // worst-possible neighbor instead of panicking the comparator
        dists.sort_by(|a, b| a.0.total_cmp(&b.0));
        let mut votes = vec![0usize; self.n_classes];
        for (_, l) in dists.iter().take(self.k.min(dists.len())) {
            votes[*l] += 1;
        }
        // majority vote; ties broken by nearer neighbor
        let max_votes = *votes.iter().max().unwrap();
        for (_, l) in dists.iter() {
            if votes[*l] == max_votes {
                return *l;
            }
        }
        unreachable!()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn nearest_neighbor_exact() {
        let mut knn = KnnClassifier::new(1);
        knn.add_example(vec![0.0, 0.0], 0);
        knn.add_example(vec![10.0, 10.0], 1);
        assert_eq!(knn.predict(&[1.0, 1.0]), 0);
        assert_eq!(knn.predict(&[9.0, 9.0]), 1);
    }

    #[test]
    fn majority_vote_overrides_single_outlier() {
        let mut knn = KnnClassifier::new(3);
        knn.add_example(vec![0.0], 0);
        knn.add_example(vec![0.2], 0);
        knn.add_example(vec![0.05], 1); // outlier of class 1 sitting in class 0
        knn.add_example(vec![5.0], 1);
        assert_eq!(knn.predict(&[0.1]), 0);
    }

    #[test]
    fn sensitive_to_outliers_with_k1() {
        // the failure mode HDC aggregation fixes: one bad shot flips 1-NN
        let mut knn = KnnClassifier::new(1);
        knn.add_example(vec![0.0], 0);
        knn.add_example(vec![0.3], 1); // class-1 outlier near class 0
        knn.add_example(vec![5.0], 1);
        assert_eq!(knn.predict(&[0.25]), 1, "1-NN grabs the outlier");
    }

    #[test]
    fn separable_clusters_high_accuracy() {
        let mut rng = Rng::new(1);
        let mut knn = KnnClassifier::new(5);
        let protos = [[0.0f32; 8], [4.0f32; 8]];
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..5 {
                let f: Vec<f32> = p.iter().map(|v| v + 0.3 * rng.gauss_f32()).collect();
                knn.add_example(f, c);
            }
        }
        let mut correct = 0;
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..20 {
                let q: Vec<f32> = p.iter().map(|v| v + 0.3 * rng.gauss_f32()).collect();
                if knn.predict(&q) == c {
                    correct += 1;
                }
            }
        }
        assert!(correct >= 38, "{correct}/40");
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn empty_store_panics() {
        KnnClassifier::new(1).predict(&[0.0]);
    }

    #[test]
    fn nan_feature_does_not_panic() {
        // regression: partial_cmp().unwrap() panicked here when any stored
        // feature produced a NaN distance; now NaN sorts as farthest-away
        let mut knn = KnnClassifier::new(1);
        knn.add_example(vec![f32::NAN, 0.0], 1);
        knn.add_example(vec![0.0, 0.0], 0);
        assert_eq!(knn.predict(&[0.1, 0.1]), 0, "finite neighbor beats NaN");
    }

    #[test]
    fn all_nan_store_does_not_panic() {
        let mut knn = KnnClassifier::new(3);
        knn.add_example(vec![f32::NAN], 0);
        knn.add_example(vec![f32::NAN], 1);
        let pred = knn.predict(&[0.5]);
        assert!(pred <= 1, "some stored label, no panic");
    }
}
