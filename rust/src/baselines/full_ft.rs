//! Full fine-tuning baseline: a 2-layer MLP head with backprop on the
//! frozen features — the accuracy proxy for "retrain everything" ODL
//! ([2], [3], [5]–[7]; eq. (1)). The *cost* of true full FT (backprop
//! through the whole CNN) is accounted separately by `complexity.rs`;
//! this module supplies the accuracy side of Figs. 3 and 15.

use crate::util::prng::Rng;

/// Two-layer MLP (dim -> hidden -> classes) trained with SGD + momentum.
#[derive(Clone, Debug)]
pub struct MlpHead {
    pub dim: usize,
    pub hidden: usize,
    pub n_classes: usize,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    m1: Vec<f32>,
    m2: Vec<f32>,
    pub lr: f32,
    pub momentum: f32,
    scale: f32,
}

impl MlpHead {
    pub fn new(n_classes: usize, dim: usize, hidden: usize, rng: &mut Rng) -> Self {
        let s1 = (2.0 / dim as f32).sqrt();
        let s2 = (2.0 / hidden as f32).sqrt();
        MlpHead {
            dim,
            hidden,
            n_classes,
            w1: (0..hidden * dim).map(|_| s1 * rng.gauss_f32()).collect(),
            b1: vec![0.0; hidden],
            w2: (0..n_classes * hidden).map(|_| s2 * rng.gauss_f32()).collect(),
            b2: vec![0.0; n_classes],
            m1: vec![0.0; hidden * dim],
            m2: vec![0.0; n_classes * hidden],
            lr: 0.005,
            momentum: 0.9,
            scale: 1.0,
        }
    }

    fn forward(&self, x: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let mut h = vec![0f32; self.hidden];
        for j in 0..self.hidden {
            let row = &self.w1[j * self.dim..(j + 1) * self.dim];
            let mut s = self.b1[j];
            for (w, xi) in row.iter().zip(x) {
                s += w * xi;
            }
            h[j] = s.max(0.0); // ReLU
        }
        let mut logits = vec![0f32; self.n_classes];
        for c in 0..self.n_classes {
            let row = &self.w2[c * self.hidden..(c + 1) * self.hidden];
            let mut s = self.b2[c];
            for (w, hj) in row.iter().zip(&h) {
                s += w * hj;
            }
            logits[c] = s;
        }
        (h, logits)
    }

    fn softmax(logits: &[f32]) -> Vec<f32> {
        let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let exps: Vec<f32> = logits.iter().map(|&l| (l - m).exp()).collect();
        let z: f32 = exps.iter().sum();
        exps.iter().map(|e| e / z).collect()
    }

    /// One backprop step; returns the loss.
    pub fn sgd_step(&mut self, x: &[f32], label: usize) -> f32 {
        let (h, logits) = self.forward(x);
        let probs = Self::softmax(&logits);
        let loss = -probs[label].max(1e-12).ln();
        // output layer grads
        let dlogits: Vec<f32> = (0..self.n_classes)
            .map(|c| probs[c] - if c == label { 1.0 } else { 0.0 })
            .collect();
        let mut dh = vec![0f32; self.hidden];
        for c in 0..self.n_classes {
            let g = dlogits[c];
            let row = &mut self.w2[c * self.hidden..(c + 1) * self.hidden];
            let mrow = &mut self.m2[c * self.hidden..(c + 1) * self.hidden];
            for j in 0..self.hidden {
                dh[j] += row[j] * g;
                let grad = g * h[j];
                mrow[j] = self.momentum * mrow[j] - self.lr * grad;
                row[j] += mrow[j];
            }
            self.b2[c] -= self.lr * g;
        }
        // hidden layer grads (through ReLU)
        for j in 0..self.hidden {
            if h[j] <= 0.0 {
                continue;
            }
            let g = dh[j];
            let row = &mut self.w1[j * self.dim..(j + 1) * self.dim];
            let mrow = &mut self.m1[j * self.dim..(j + 1) * self.dim];
            for (i, xi) in x.iter().enumerate() {
                let grad = g * xi;
                mrow[i] = self.momentum * mrow[i] - self.lr * grad;
                row[i] += mrow[i];
            }
            self.b1[j] -= self.lr * g;
        }
        loss
    }

    /// Train `epochs` shuffled passes; returns per-epoch mean losses
    /// (Fig. 3a's convergence curve).
    pub fn fit(&mut self, xs: &[Vec<f32>], ys: &[usize], epochs: usize, rng: &mut Rng) -> Vec<f32> {
        assert_eq!(xs.len(), ys.len());
        let scale = (xs
            .iter()
            .flat_map(|x| x.iter())
            .map(|v| (v * v) as f64)
            .sum::<f64>()
            / (xs.len().max(1) * self.dim) as f64)
            .sqrt()
            .max(1e-6) as f32;
        self.scale = scale;
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut curve = Vec::with_capacity(epochs);
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut acc = 0.0;
            for &i in &order {
                let x: Vec<f32> = xs[i].iter().map(|v| v / scale).collect();
                acc += self.sgd_step(&x, ys[i]);
            }
            curve.push(acc / xs.len().max(1) as f32);
        }
        curve
    }

    pub fn predict(&self, x: &[f32]) -> usize {
        let xs: Vec<f32> = x.iter().map(|v| v / self.scale.max(1e-6)).collect();
        let (_, logits) = self.forward(&xs);
        // shared NaN-robust selection: the hand-rolled `l > logits[best]`
        // loop silently elected class 0 on a NaN logit at index 0
        crate::hdc::distance::argmax(&logits)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xor_like(rng: &mut Rng) -> (Vec<Vec<f32>>, Vec<usize>) {
        // non-linearly separable: needs the hidden layer
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..40 {
            let a = rng.below(2) as f32;
            let b = rng.below(2) as f32;
            let x = vec![
                a * 2.0 - 1.0 + 0.1 * rng.gauss_f32(),
                b * 2.0 - 1.0 + 0.1 * rng.gauss_f32(),
            ];
            xs.push(x);
            ys.push(((a as i32) ^ (b as i32)) as usize);
        }
        (xs, ys)
    }

    #[test]
    fn solves_xor() {
        let mut rng = Rng::new(1);
        let (xs, ys) = xor_like(&mut rng);
        let mut mlp = MlpHead::new(2, 2, 16, &mut rng);
        mlp.fit(&xs, &ys, 60, &mut rng);
        let correct = xs.iter().zip(&ys).filter(|(x, &y)| mlp.predict(x) == y).count();
        assert!(correct >= 36, "{correct}/40");
    }

    #[test]
    fn loss_curve_monotone_ish() {
        let mut rng = Rng::new(2);
        let (xs, ys) = xor_like(&mut rng);
        let mut mlp = MlpHead::new(2, 2, 16, &mut rng);
        let curve = mlp.fit(&xs, &ys, 30, &mut rng);
        assert!(curve.last().unwrap() < &curve[0], "loss should drop: {curve:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let build = || {
            let mut rng = Rng::new(3);
            let (xs, ys) = xor_like(&mut rng);
            let mut mlp = MlpHead::new(2, 2, 8, &mut rng);
            mlp.fit(&xs, &ys, 5, &mut rng)
        };
        assert_eq!(build(), build());
    }
}
