//! Prior ODL accelerators as published cost models — the comparison
//! columns of Table I and the scatter points of Figs. 18/19. Values are
//! the paper's own table entries (which in turn come from the cited JSSC
//! papers), so regenerating the comparison means evaluating these models,
//! exactly as the paper does.

/// One state-of-the-art ODL chip from Table I.
#[derive(Clone, Debug)]
pub struct PriorChip {
    pub name: &'static str,
    pub venue: &'static str,
    pub tech_nm: u32,
    pub die_area_mm2: f64,
    pub freq_mhz_max: f64,
    pub on_chip_kb: u32,
    pub power_mw_max: f64,
    pub precision: &'static str,
    pub algorithm: &'static str,
    pub accuracy_pct: f64,
    pub accuracy_task: &'static str,
    pub throughput_gops: f64,
    pub energy_eff_tops_w: f64,
    pub hw_eff_gops_mm2: f64,
    /// FSL training latency per image (ms), 10-way 5-shot @ ResNet-18,
    /// 5 epochs (Table I footnote f)
    pub train_latency_ms_img: f64,
    /// FSL training energy per image (mJ), same protocol
    pub train_energy_mj_img: f64,
    /// average inference latency per 224x224 image (ms) — Fig. 18
    pub infer_latency_ms_img: f64,
    /// average inference energy per image (mJ) — Fig. 18
    pub infer_energy_mj_img: f64,
}

impl PriorChip {
    /// End-to-end 10-way 5-shot training (50 images, FT baselines use 5
    /// epochs — the latency/energy figures already amortize epochs per
    /// image, so end-to-end = 50x per-image). Fig. 19's axes.
    pub fn end_to_end_train(&self) -> (f64, f64) {
        let sec = self.train_latency_ms_img * 50.0 / 1e3;
        let mj = self.train_energy_mj_img * 50.0;
        (sec, mj)
    }
}

/// Technology scaling of energy to 40 nm (DeepScaleTool-style first-order:
/// energy ~ node^2, delay ~ node) — Table I footnote e.
pub fn scale_energy_to_40nm(tech_nm: u32, energy: f64) -> f64 {
    let r = 40.0 / tech_nm as f64;
    energy * r * r
}

/// The six comparison chips of Table I.
pub fn table1_chips() -> Vec<PriorChip> {
    vec![
        PriorChip {
            name: "DF-LNPU", venue: "JSSC'21 [2]", tech_nm: 65, die_area_mm2: 5.36,
            freq_mhz_max: 200.0, on_chip_kb: 168, power_mw_max: 252.4,
            precision: "INT16", algorithm: "DFA BP + Partial FT",
            accuracy_pct: 42.0, accuracy_task: "Obj. Track",
            throughput_gops: 155.2, energy_eff_tops_w: 1.5, hw_eff_gops_mm2: 78.8,
            train_latency_ms_img: 308.0, train_energy_mj_img: 39.0,
            infer_latency_ms_img: 18.0, infer_energy_mj_img: 3.2,
        },
        PriorChip {
            name: "FP8-Trainer", venue: "JSSC'22 [3]", tech_nm: 40, die_area_mm2: 6.25,
            freq_mhz_max: 180.0, on_chip_kb: 293, power_mw_max: 230.0,
            precision: "FP8", algorithm: "LP BP + Full FT",
            accuracy_pct: 69.0, accuracy_task: "ImageNet",
            throughput_gops: 567.0, energy_eff_tops_w: 1.6, hw_eff_gops_mm2: 90.7,
            train_latency_ms_img: 184.0, train_energy_mj_img: 33.0,
            infer_latency_ms_img: 11.0, infer_energy_mj_img: 2.6,
        },
        PriorChip {
            name: "CHIMERA", venue: "JSSC'22 [4]", tech_nm: 40, die_area_mm2: 29.2,
            freq_mhz_max: 200.0, on_chip_kb: 2560, power_mw_max: 135.0,
            precision: "INT8", algorithm: "LR BP + Partial FT",
            accuracy_pct: 69.3, accuracy_task: "Flower102",
            throughput_gops: 920.0, energy_eff_tops_w: 2.2, hw_eff_gops_mm2: 31.5,
            train_latency_ms_img: 795.0, train_energy_mj_img: 91.0,
            infer_latency_ms_img: 8.5, infer_energy_mj_img: 1.9,
        },
        PriorChip {
            name: "Trainer", venue: "JSSC'22 [5]", tech_nm: 28, die_area_mm2: 20.9,
            freq_mhz_max: 440.0, on_chip_kb: 634, power_mw_max: 363.0,
            precision: "FP8/16", algorithm: "Sparse BP + Full FT",
            accuracy_pct: 70.7, accuracy_task: "CUB-200",
            throughput_gops: 450.0, energy_eff_tops_w: 1.6, hw_eff_gops_mm2: 10.1,
            train_latency_ms_img: 706.0, train_energy_mj_img: 36.0,
            infer_latency_ms_img: 9.0, infer_energy_mj_img: 4.6,
        },
        PriorChip {
            name: "FP8-TensorCore", venue: "JSSC'23 [6]", tech_nm: 28, die_area_mm2: 16.4,
            freq_mhz_max: 340.0, on_chip_kb: 1280, power_mw_max: 623.7,
            precision: "INT8", algorithm: "Sparse BP + Full FT",
            accuracy_pct: 94.3, accuracy_task: "CIFAR-10",
            throughput_gops: 560.0, energy_eff_tops_w: 4.1, hw_eff_gops_mm2: 15.9,
            train_latency_ms_img: 200.0, train_energy_mj_img: 125.0,
            infer_latency_ms_img: 7.0, infer_energy_mj_img: 5.2,
        },
        PriorChip {
            name: "IC-BP", venue: "JSSC'24 [7]", tech_nm: 28, die_area_mm2: 2.0,
            freq_mhz_max: 200.0, on_chip_kb: 64, power_mw_max: 18.0,
            precision: "INT8", algorithm: "Sparse BP + Full FT",
            accuracy_pct: 96.1, accuracy_task: "AntBee",
            throughput_gops: 38.4, energy_eff_tops_w: 3.6, hw_eff_gops_mm2: 9.0,
            train_latency_ms_img: 7927.0, train_energy_mj_img: 12.0,
            infer_latency_ms_img: 95.0, infer_energy_mj_img: 0.9,
        },
    ]
}

/// FSL-HDnn's own Table-I row (from the simulated chip).
#[derive(Clone, Debug)]
pub struct OurChipRow {
    pub train_latency_ms_img: f64,
    pub train_energy_mj_img: f64,
}

/// Speedup / energy-advantage columns (the "(x.x×)" entries of Table I).
pub fn relative_factors(ours: &OurChipRow) -> Vec<(String, f64, f64)> {
    table1_chips()
        .iter()
        .map(|c| {
            (
                c.name.to_string(),
                c.train_latency_ms_img / ours.train_latency_ms_img,
                c.train_energy_mj_img / ours.train_energy_mj_img,
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn six_chips() {
        assert_eq!(table1_chips().len(), 6);
    }

    #[test]
    fn paper_factor_ranges_hold() {
        // Table I: latency factors 5.3x..229.1x; energy factors 2.0x..20.9x
        // against ours = 35 ms / 6 mJ
        let ours = OurChipRow { train_latency_ms_img: 35.0, train_energy_mj_img: 6.0 };
        let f = relative_factors(&ours);
        let lat: Vec<f64> = f.iter().map(|x| x.1).collect();
        let en: Vec<f64> = f.iter().map(|x| x.2).collect();
        let (lmin, lmax) = (lat.iter().cloned().fold(f64::MAX, f64::min),
                            lat.iter().cloned().fold(0.0, f64::max));
        let (emin, emax) = (en.iter().cloned().fold(f64::MAX, f64::min),
                            en.iter().cloned().fold(0.0, f64::max));
        assert!((lmin - 5.3).abs() < 0.2, "min latency factor {lmin}");
        // Table I prints 229.1x; 7927/35 = 226.5 — the paper's row rounds
        assert!((lmax - 229.1).abs() < 4.0, "max latency factor {lmax}");
        assert!((emin - 2.0).abs() < 0.1, "min energy factor {emin}");
        assert!((emax - 20.9).abs() < 0.3, "max energy factor {emax}");
    }

    #[test]
    fn end_to_end_matches_fig19_band() {
        // Fig. 19: prior chips take 9.2 to 396 s end-to-end
        for c in table1_chips() {
            let (sec, _) = c.end_to_end_train();
            assert!((9.0..400.0).contains(&sec), "{}: {sec}", c.name);
        }
    }

    #[test]
    fn tech_scaling_monotone() {
        assert!(scale_energy_to_40nm(65, 10.0) < 10.0);
        assert!(scale_energy_to_40nm(28, 10.0) > 10.0);
        assert_eq!(scale_energy_to_40nm(40, 10.0), 10.0);
    }
}
