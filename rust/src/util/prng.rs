//! Deterministic PRNGs: splitmix64 (the python-contract seeder) and
//! xoshiro256** for general sampling.
//!
//! `splitmix64_next` must match `python/compile/kernels/lfsr.py::splitmix64`
//! bit-for-bit — it seeds the cRP encoder's LFSRs on both sides.

/// The splitmix64 increment (golden ratio).
pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// One splitmix64 output for state `x` (mirrors the python helper, which
/// takes the *pre-increment* state and returns the mixed value).
#[inline]
pub fn splitmix64_next(x: u64) -> u64 {
    let x = x.wrapping_add(GOLDEN);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, no_std-friendly generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller sample
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via splitmix64 expansion (never all-zero).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            sm = sm.wrapping_add(GOLDEN);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            *v = z ^ (z >> 31);
        }
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9;
        }
        Rng { s, gauss_spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Box-Muller with spare caching).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let m = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * m);
                return u * m;
            }
        }
    }

    /// Standard normal as f32.
    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Student-t-ish heavy-tailed sample (normal / sqrt(chi2/df)) — used by
    /// dataset presets to create the outliers that hurt kNN.
    pub fn heavy_tail(&mut self, df: f64) -> f64 {
        let z = self.gauss();
        let mut chi2 = 0.0;
        let k = df.round().max(1.0) as usize;
        for _ in 0..k {
            let g = self.gauss();
            chi2 += g * g;
        }
        z / (chi2 / df).sqrt()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// k distinct indices out of 0..n (partial shuffle).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child generator (stable under reordering).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(GOLDEN))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_python_golden() {
        // printed by python/compile/kernels/lfsr.py::splitmix64
        assert_eq!(splitmix64_next(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64_next(1), 0x910A_2DEC_8902_5CC1);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.gauss();
            m += g;
            v += g * g;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.03, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(4);
        let ks = r.choose_k(10, 5);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 5);
        assert!(ks.iter().all(|&i| i < 10));
    }

    #[test]
    fn deterministic_and_fork_independent() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut f1 = a.fork(1);
        let mut f2 = b.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
