//! Deterministic fail-point registry for fault-injection testing.
//!
//! A fail point is a named site in the serving stack where a test (or an
//! operator, via env / `[faults]` TOML) can inject a failure, a panic, or
//! latency with a deterministic trigger. The registry is zero-dependency
//! and designed so that the **disarmed hot path is a single atomic load
//! and compare** — no allocation, no lock, no map lookup:
//!
//! ```text
//! failpoint::check("device.query")?;   // disarmed: one Relaxed load + branch
//! ```
//!
//! Sites wired into the stack (see DESIGN.md §Fault model):
//!
//! | site            | where it fires                                   |
//! |-----------------|--------------------------------------------------|
//! | `device.query`  | coordinator worker, Query / QueryBatch / QueryFeature |
//! | `device.train`  | coordinator worker, AddShot* / FinishTraining    |
//! | `gateway.read`  | gateway per-connection loop, after a frame is read |
//! | `gateway.write` | gateway per-connection loop, before the reply write |
//! | `pool.task`     | worker-pool task execution (inside `catch_unwind`) |
//!
//! Triggers are counted per site, so a sequence of checks is exactly
//! reproducible: `fail-once` fires on the first check only, `fail-every-n:3`
//! on checks 3, 6, 9, …, `fail-after-k:5` on every check past the fifth,
//! `latency-ms:10` sleeps 10 ms on every check. `panic-*` variants panic
//! instead of returning an error (that is how a chaos test kills a device
//! worker dead rather than handing it a recoverable error).
//!
//! Arming is process-global: tests that arm fail points must serialize
//! (the chaos battery shares one mutex) and disarm when done — use
//! [`armed_scope`] so a panicking assertion cannot leak an armed site into
//! the next test.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// What a firing fail point does at its site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// `check` returns an error the site maps to its natural failure
    /// (e.g. a retryable wire error).
    Fail,
    /// `check` panics — simulates a crashing worker/device.
    Panic,
}

/// When a fail point fires, counted per site from 1.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trigger {
    /// Fire on the first check, then never again.
    Once,
    /// Fire on every `n`-th check (n, 2n, 3n, …).
    EveryN(u64),
    /// Pass the first `k` checks, fire on every check after.
    AfterK(u64),
    /// Never fail; sleep this many milliseconds on every check.
    LatencyMs(u64),
}

#[derive(Clone, Copy, Debug)]
struct Site {
    trigger: Trigger,
    action: Action,
    hits: u64,
}

/// Error returned by [`check`] when an armed fail point fires.
#[derive(Debug)]
pub struct Injected {
    pub site: &'static str,
}

impl std::fmt::Display for Injected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected fault at fail point {}", self.site)
    }
}

impl std::error::Error for Injected {}

// Registry state machine. The hot path loads STATE once: DISARMED (the
// steady state with no sites armed) short-circuits before any lock.
const UNINIT: u8 = 0;
const DISARMED: u8 = 1;
const ARMED: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);

fn registry() -> &'static Mutex<HashMap<&'static str, Site>> {
    static REG: OnceLock<Mutex<HashMap<&'static str, Site>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// The env var read on first use: same syntax as [`arm_spec`], e.g.
/// `FSL_FAILPOINTS="device.query=latency-ms:1;gateway.write=fail-once"`.
pub const ENV_VAR: &str = "FSL_FAILPOINTS";

/// Fail-point site names are interned so the registry key is `&'static str`
/// and the armed path allocates nothing per check. Unknown names are
/// accepted (they just never match a wired site).
fn intern(site: &str) -> &'static str {
    const KNOWN: &[&str] =
        &["device.query", "device.train", "gateway.read", "gateway.write", "pool.task"];
    for k in KNOWN {
        if *k == site {
            return k;
        }
    }
    Box::leak(site.to_string().into_boxed_str())
}

fn init_from_env() {
    // Racing initializers both parse the env var; arming is idempotent.
    match std::env::var(ENV_VAR) {
        Ok(spec) if !spec.trim().is_empty() => {
            if let Err(e) = arm_spec(&spec) {
                eprintln!("[failpoint] ignoring bad {ENV_VAR}: {e}");
                STATE.compare_exchange(UNINIT, DISARMED, Ordering::SeqCst, Ordering::SeqCst).ok();
            }
        }
        _ => {
            STATE.compare_exchange(UNINIT, DISARMED, Ordering::SeqCst, Ordering::SeqCst).ok();
        }
    }
}

/// Arm `site` with a trigger and action. Replaces any previous arming of
/// the same site and resets its hit counter.
pub fn arm(site: &str, trigger: Trigger, action: Action) {
    let key = intern(site);
    let mut reg = registry().lock().expect("failpoint registry poisoned");
    reg.insert(key, Site { trigger, action, hits: 0 });
    STATE.store(ARMED, Ordering::SeqCst);
}

/// Disarm one site. The hot path stays in the armed (slow) state until
/// [`disarm_all`] runs; per-site disarm only stops that site firing.
pub fn disarm(site: &str) {
    let mut reg = registry().lock().expect("failpoint registry poisoned");
    reg.remove(site);
    if reg.is_empty() {
        STATE.store(DISARMED, Ordering::SeqCst);
    }
}

/// Disarm every site and restore the single-branch hot path.
pub fn disarm_all() {
    let mut reg = registry().lock().expect("failpoint registry poisoned");
    reg.clear();
    STATE.store(DISARMED, Ordering::SeqCst);
}

/// Parse a `;`/`,`-separated spec without touching the registry —
/// config loading validates specs eagerly through this. Each entry is
/// `site=trigger` where trigger is one of `fail-once`, `fail-every-n:N`,
/// `fail-after-k:K`, `latency-ms:M`, `panic-once`, `panic-every-n:N`,
/// `panic-after-k:K`, or `off`. Returns `(site, None)` for `off` entries
/// and `(site, Some((trigger, action)))` otherwise.
#[allow(clippy::type_complexity)]
pub fn parse_spec(spec: &str) -> anyhow::Result<Vec<(String, Option<(Trigger, Action)>)>> {
    let mut out = Vec::new();
    for entry in spec.split([';', ',']) {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (site, trig) = entry
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("fail-point entry `{entry}` is not site=trigger"))?;
        let (site, trig) = (site.trim(), trig.trim());
        if trig == "off" {
            out.push((site.to_string(), None));
            continue;
        }
        let (name, param) = match trig.split_once(':') {
            Some((n, p)) => (n, Some(p)),
            None => (trig, None),
        };
        let num = |what: &str| -> anyhow::Result<u64> {
            let p = param
                .ok_or_else(|| anyhow::anyhow!("trigger `{trig}` needs a `:{what}` parameter"))?;
            let v: u64 = p
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("bad {what} `{p}` in fail-point `{entry}`"))?;
            anyhow::ensure!(v >= 1 || what == "k" || what == "ms", "{what} must be >= 1");
            Ok(v)
        };
        let (trigger, action) = match name {
            "fail-once" => (Trigger::Once, Action::Fail),
            "panic-once" => (Trigger::Once, Action::Panic),
            "fail-every-n" => (Trigger::EveryN(num("n")?), Action::Fail),
            "panic-every-n" => (Trigger::EveryN(num("n")?), Action::Panic),
            "fail-after-k" => (Trigger::AfterK(num("k")?), Action::Fail),
            "panic-after-k" => (Trigger::AfterK(num("k")?), Action::Panic),
            "latency-ms" => (Trigger::LatencyMs(num("ms")?), Action::Fail),
            other => anyhow::bail!("unknown fail-point trigger `{other}` in `{entry}`"),
        };
        out.push((site.to_string(), Some((trigger, action))));
    }
    Ok(out)
}

/// Parse and apply a spec (grammar in [`parse_spec`]): arm every
/// `site=trigger` entry, disarm every `site=off` entry.
pub fn arm_spec(spec: &str) -> anyhow::Result<()> {
    for (site, entry) in parse_spec(spec)? {
        match entry {
            Some((trigger, action)) => arm(&site, trigger, action),
            None => disarm(&site),
        }
    }
    Ok(())
}

/// Check a fail-point site. Disarmed (the production steady state): one
/// relaxed atomic load and a branch — no allocation, no lock. Armed: takes
/// the registry lock, counts the hit, and fires per the site's trigger.
///
/// A firing `Action::Fail` returns `Err(Injected)`; `Action::Panic`
/// panics; `Trigger::LatencyMs` sleeps and returns `Ok(())`.
#[inline]
pub fn check(site: &'static str) -> Result<(), Injected> {
    if STATE.load(Ordering::Relaxed) == DISARMED {
        return Ok(());
    }
    check_slow(site)
}

#[cold]
fn check_slow(site: &'static str) -> Result<(), Injected> {
    if STATE.load(Ordering::SeqCst) == UNINIT {
        init_from_env();
        if STATE.load(Ordering::SeqCst) == DISARMED {
            return Ok(());
        }
    }
    let fired = {
        let mut reg = registry().lock().expect("failpoint registry poisoned");
        let Some(s) = reg.get_mut(site) else { return Ok(()) };
        s.hits += 1;
        match s.trigger {
            Trigger::Once => {
                if s.hits == 1 {
                    Some(s.action)
                } else {
                    None
                }
            }
            Trigger::EveryN(n) => {
                if s.hits % n.max(1) == 0 {
                    Some(s.action)
                } else {
                    None
                }
            }
            Trigger::AfterK(k) => {
                if s.hits > k {
                    Some(s.action)
                } else {
                    None
                }
            }
            Trigger::LatencyMs(ms) => {
                // Sleep outside the lock so latency injection on one site
                // does not stall arming/checks on others.
                drop(reg);
                std::thread::sleep(std::time::Duration::from_millis(ms));
                return Ok(());
            }
        }
    };
    match fired {
        None => Ok(()),
        Some(Action::Fail) => Err(Injected { site }),
        Some(Action::Panic) => panic!("injected panic at fail point {site}"),
    }
}

/// Number of times `site` has been checked since it was (re-)armed.
/// Test-facing: asserts that a site actually saw traffic.
pub fn hits(site: &str) -> u64 {
    registry().lock().expect("failpoint registry poisoned").get(site).map_or(0, |s| s.hits)
}

/// RAII guard: arms a spec, disarms everything on drop (even on panic).
/// Chaos tests hold this inside their shared serialization lock.
pub struct ArmedScope(());

/// Arm `spec` for the lifetime of the returned guard.
pub fn armed_scope(spec: &str) -> anyhow::Result<ArmedScope> {
    arm_spec(spec)?;
    Ok(ArmedScope(()))
}

impl Drop for ArmedScope {
    fn drop(&mut self) {
        disarm_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::MutexGuard;

    // The registry is process-global; unit tests here serialize on one
    // lock and always go through ArmedScope so state never leaks.
    fn lock() -> MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        LOCK.lock().unwrap_or_else(|p| p.into_inner())
    }

    #[test]
    fn disarmed_check_is_ok_and_counts_nothing() {
        let _g = lock();
        disarm_all();
        assert!(check("device.query").is_ok());
        assert_eq!(hits("device.query"), 0);
    }

    #[test]
    fn fail_once_fires_exactly_once() {
        let _g = lock();
        let _s = armed_scope("device.query=fail-once").unwrap();
        assert!(check("device.query").is_err());
        assert!(check("device.query").is_ok());
        assert!(check("device.query").is_ok());
        assert_eq!(hits("device.query"), 3);
        // other sites untouched
        assert!(check("device.train").is_ok());
    }

    #[test]
    fn fail_every_n_fires_on_multiples() {
        let _g = lock();
        let _s = armed_scope("device.train=fail-every-n:3").unwrap();
        let fired: Vec<bool> = (0..9).map(|_| check("device.train").is_err()).collect();
        assert_eq!(
            fired,
            [false, false, true, false, false, true, false, false, true]
        );
    }

    #[test]
    fn fail_after_k_passes_k_then_always_fires() {
        let _g = lock();
        let _s = armed_scope("gateway.read=fail-after-k:2").unwrap();
        assert!(check("gateway.read").is_ok());
        assert!(check("gateway.read").is_ok());
        assert!(check("gateway.read").is_err());
        assert!(check("gateway.read").is_err());
    }

    #[test]
    fn latency_trigger_sleeps_but_never_fails() {
        let _g = lock();
        let _s = armed_scope("gateway.write=latency-ms:1").unwrap();
        let t0 = std::time::Instant::now();
        assert!(check("gateway.write").is_ok());
        assert!(t0.elapsed() >= std::time::Duration::from_millis(1));
    }

    #[test]
    fn panic_action_panics_with_site_name() {
        let _g = lock();
        let _s = armed_scope("pool.task=panic-once").unwrap();
        let err = std::panic::catch_unwind(|| {
            let _ = check("pool.task");
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(msg.contains("pool.task"), "panic names the site: {msg}");
        // once: second check passes
        assert!(check("pool.task").is_ok());
    }

    #[test]
    fn spec_parser_rejects_garbage_and_accepts_off() {
        let _g = lock();
        assert!(arm_spec("nonsense").is_err());
        assert!(arm_spec("a=fail-every-n").is_err());
        assert!(arm_spec("a=fail-every-n:zero").is_err());
        assert!(arm_spec("a=warble-once").is_err());
        let _s = armed_scope("device.query=fail-once; device.train=latency-ms:0").unwrap();
        arm_spec("device.query=off").unwrap();
        assert!(check("device.query").is_ok());
    }

    #[test]
    fn scope_guard_disarms_on_drop() {
        let _g = lock();
        {
            let _s = armed_scope("device.query=fail-every-n:1").unwrap();
            assert!(check("device.query").is_err());
        }
        assert!(check("device.query").is_ok());
        assert_eq!(hits("device.query"), 0, "disarm_all cleared the site");
    }
}
