//! bfloat16 emulation — the chip's FE computes in BF16 (Fig. 13b).
//!
//! We round f32 -> bf16 -> f32 (round-to-nearest-even) at the points where
//! the chip would store/feed BF16 values, so the native FE reproduces the
//! chip's numerics while keeping f32 storage.

/// Round an f32 to the nearest bf16 (ties to even) and back.
#[inline]
pub fn round_f32(x: f32) -> f32 {
    let bits = x.to_bits();
    // NaN: keep quiet NaN
    if x.is_nan() {
        return f32::from_bits(bits | 0x0040_0000);
    }
    let round_bit = 0x8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7FFF + lsb) & 0xFFFF_0000;
    let _ = round_bit;
    f32::from_bits(rounded)
}

/// Pack an f32 into raw bf16 bits.
#[inline]
pub fn to_bits(x: f32) -> u16 {
    (round_f32(x).to_bits() >> 16) as u16
}

/// Unpack raw bf16 bits to f32.
#[inline]
pub fn from_bits(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round a whole slice in place.
pub fn round_slice(xs: &mut [f32]) {
    for x in xs.iter_mut() {
        *x = round_f32(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_values_roundtrip() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0, -0.09375] {
            assert_eq!(round_f32(v), v, "{v} should be exactly representable");
        }
    }

    #[test]
    fn rounds_to_nearest() {
        // 1.0 + 2^-9 is halfway-ish below the next bf16 step (2^-7 at 1.0)
        let x = 1.0f32 + 1.0 / 512.0;
        let r = round_f32(x);
        assert!((r - 1.0).abs() < 1.0 / 64.0);
        // relative error of bf16 rounding is <= 2^-8
        for v in [3.14159f32, -271.828, 1e-3, 42.42] {
            let r = round_f32(v);
            assert!(((r - v) / v).abs() <= 1.0 / 256.0, "{v} -> {r}");
        }
    }

    #[test]
    fn bits_roundtrip() {
        for v in [1.5f32, -3.25, 1024.0] {
            assert_eq!(from_bits(to_bits(v)), v);
        }
    }

    #[test]
    fn nan_stays_nan_inf_stays_inf() {
        assert!(round_f32(f32::NAN).is_nan());
        assert_eq!(round_f32(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_f32(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn idempotent() {
        let mut r = crate::util::prng::Rng::new(1);
        for _ in 0..1000 {
            let v = r.gauss_f32() * 100.0;
            let once = round_f32(v);
            assert_eq!(round_f32(once), once);
        }
    }
}
