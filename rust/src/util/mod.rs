//! Self-contained infrastructure: PRNG, JSON, stats, tables, bf16, timing,
//! scoped-thread batch sharding, machine-readable bench logging.
//!
//! The build runs against a vendored offline registry with no serde / rand /
//! criterion, so the small utilities those crates would provide live here.

pub mod args;
pub mod bench_log;
pub mod bf16;
pub mod failpoint;
pub mod json;
pub mod lint;
pub mod parallel;
pub mod prng;
pub mod simd;
pub mod stats;
pub mod table;
pub mod timer;
