//! Explicit SIMD-width kernels for the two packed fast paths (DESIGN.md
//! §SIMD datapath): chunked `u64x4`-style popcount for the 1-bit class-HV
//! planes, 4-lane dequantize-and-accumulate sinks for the multi-bit L1
//! stream, exact integer code dots, and the lane-blocked f32 MAC the
//! codebook-LUT conv runs.
//!
//! Every kernel exists in two **lanes**:
//!
//! * [`Lane::Chunked`] — plain Rust restructured for width: fixed-width
//!   chunks with independent accumulators and a scalar tail. Always
//!   compiled, every toolchain; this is the default fast path and is what
//!   the pre-SIMD scalar loops were rewritten into.
//! * [`Lane::Simd`] — `std::simd` (portable SIMD) vectors, compiled only
//!   under the `simd` cargo feature (nightly: `portable_simd`). When the
//!   feature is off, `Lane::Simd` transparently aliases the chunked
//!   kernels, so lane-explicit callers (benches, the lane bit-identity
//!   prop tests) compile and pass under both feature settings.
//!
//! **Lane bit-identity contract.** For every kernel here the two lanes
//! return *bit-identical* results: the integer kernels are
//! order-independent sums, and the floating-point kernels perform the same
//! per-lane IEEE operations in the same order and spell the horizontal
//! fold identically (`((acc0 + acc1) + acc2) + acc3`, matching
//! `hdc::distance::l1`'s accumulator fold). Rust never contracts mul+add
//! into FMA implicitly, so the contract holds on every target. This is
//! what lets the packed-distance exactness contracts (multi-bit L1
//! bit-identical to the oracle, hamming/dot exact) survive the lane switch
//! unchanged — asserted by the `prop_simd_lane_bit_identity` battery and
//! the `--smoke` bench gates.
//!
//! **Dispatch policy.** [`active_lane`] decides once per process and is
//! immutable afterwards (cached in an atomic): `Lane::Simd` iff the
//! feature is compiled in, `FSL_NO_SIMD` is not set in the environment,
//! and the host passes the hardware check (x86_64 requires `popcnt`;
//! other architectures rely on portable-SIMD lowering). Immutability
//! matters: the worker-count bit-identity tests run concurrently in one
//! process, and a lane flip mid-run would break the
//! sharded-equals-serial contract. Benches that need both lanes in one
//! process use the lane-explicit entry points
//! (`PackedClassHvs::distances_in_lane`,
//! `fe::conv::clustered_conv2d_lut_in_lane`) instead of mutating the
//! global decision.

use std::sync::atomic::{AtomicU8, Ordering};

/// Whether the crate was compiled with the `simd` cargo feature (i.e.
/// whether [`Lane::Simd`] is a real `std::simd` build rather than an alias
/// of the chunked kernels).
pub const SIMD_COMPILED: bool = cfg!(feature = "simd");

/// Which kernel implementation a call runs. See the module docs for the
/// lane bit-identity contract between the two.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Width-restructured scalar kernels (4-wide chunks, independent
    /// accumulators, scalar tail). Always available.
    Chunked,
    /// `std::simd` vector kernels; aliases `Chunked` when the `simd`
    /// feature is off.
    Simd,
}

/// One-time lane decision: 0 = undecided, 1 = chunked, 2 = simd.
static LANE: AtomicU8 = AtomicU8::new(0);

/// The process-wide lane every non-lane-explicit fast-path call runs.
/// Decided once on first use and immutable afterwards (see module docs);
/// racing first calls compute the same answer, so the benign double-store
/// needs no CAS.
pub fn active_lane() -> Lane {
    match LANE.load(Ordering::Relaxed) {
        1 => Lane::Chunked,
        2 => Lane::Simd,
        _ => {
            let lane = decide_lane();
            LANE.store(if lane == Lane::Chunked { 1 } else { 2 }, Ordering::Relaxed);
            lane
        }
    }
}

fn decide_lane() -> Lane {
    if !SIMD_COMPILED || std::env::var_os("FSL_NO_SIMD").is_some() || !hw_supported() {
        Lane::Chunked
    } else {
        Lane::Simd
    }
}

/// x86_64: the popcount planes want the `popcnt` instruction; without it
/// the chunked kernel's `count_ones` lowering is just as good.
#[cfg(target_arch = "x86_64")]
fn hw_supported() -> bool {
    std::arch::is_x86_feature_detected!("popcnt")
}

/// Non-x86 targets lean on portable-SIMD lowering unconditionally.
#[cfg(not(target_arch = "x86_64"))]
fn hw_supported() -> bool {
    true
}

// ---------------------------------------------------------------------------
// 1-bit plane kernel: XOR + popcount
// ---------------------------------------------------------------------------

/// Popcount of `a ^ b` over whole u64 words — the 1-bit class-HV distance
/// kernel (every metric at 1 bit reduces to this mismatch count). Exact
/// integer sum, so the lanes are trivially bit-identical.
pub fn xor_popcount(a: &[u64], b: &[u64], lane: Lane) -> u64 {
    debug_assert_eq!(a.len(), b.len());
    match lane {
        Lane::Chunked => xor_popcount_chunked(a, b),
        Lane::Simd => xor_popcount_simd(a, b),
    }
}

/// 4 words per step with independent accumulators, scalar tail.
fn xor_popcount_chunked(a: &[u64], b: &[u64]) -> u64 {
    let n4 = a.len() / 4 * 4;
    let mut acc = [0u64; 4];
    for (ca, cb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        for l in 0..4 {
            acc[l] += (ca[l] ^ cb[l]).count_ones() as u64;
        }
    }
    let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for i in n4..a.len() {
        s += (a[i] ^ b[i]).count_ones() as u64;
    }
    s
}

#[cfg(feature = "simd")]
fn xor_popcount_simd(a: &[u64], b: &[u64]) -> u64 {
    use std::simd::num::SimdUint;
    use std::simd::u64x4;
    let n4 = a.len() / 4 * 4;
    let mut acc = u64x4::splat(0);
    let mut i = 0;
    while i < n4 {
        let va = u64x4::from_slice(&a[i..i + 4]);
        let vb = u64x4::from_slice(&b[i..i + 4]);
        acc += (va ^ vb).count_ones();
        i += 4;
    }
    let mut s = acc.reduce_sum();
    for i in n4..a.len() {
        s += (a[i] ^ b[i]).count_ones() as u64;
    }
    s
}

#[cfg(not(feature = "simd"))]
fn xor_popcount_simd(a: &[u64], b: &[u64]) -> u64 {
    xor_popcount_chunked(a, b)
}

// ---------------------------------------------------------------------------
// Multi-bit L1 sink: dequantize-in-register 4-lane accumulation
// ---------------------------------------------------------------------------

/// A 4-lane `|q - c*scale|` accumulator with `hdc::distance::l1`'s exact
/// accumulation structure: lane `l` only ever sees elements `i` with
/// `i % 4 == l`, and [`L1Sink::finish`] folds `((a0 + a1) + a2) + a3`.
/// Implementors must keep per-lane IEEE operation order identical so the
/// sinks are bit-identical to each other *and* to the scalar oracle.
pub trait L1Sink: Default {
    /// Accumulate one aligned group of four elements.
    fn push4(&mut self, q: [f32; 4], c: [f32; 4], scale: f32);
    /// Horizontal fold, spelled exactly like `distance::l1`'s.
    fn finish(self) -> f64;
}

/// The chunked-scalar sink: four independent f64 accumulators.
#[derive(Default)]
pub struct L1Chunked([f64; 4]);

impl L1Sink for L1Chunked {
    #[inline]
    fn push4(&mut self, q: [f32; 4], c: [f32; 4], scale: f32) {
        for l in 0..4 {
            self.0[l] += (q[l] - c[l] * scale).abs() as f64;
        }
    }

    #[inline]
    fn finish(self) -> f64 {
        ((self.0[0] + self.0[1]) + self.0[2]) + self.0[3]
    }
}

/// The `std::simd` sink: one f64x4 accumulator, per-lane ops in the same
/// order as [`L1Chunked`] (f32 mul, sub, abs, exact f32→f64 cast, f64 add).
#[cfg(feature = "simd")]
pub struct L1Simd(std::simd::f64x4);

#[cfg(feature = "simd")]
impl Default for L1Simd {
    fn default() -> Self {
        L1Simd(std::simd::f64x4::splat(0.0))
    }
}

#[cfg(feature = "simd")]
impl L1Sink for L1Simd {
    #[inline]
    fn push4(&mut self, q: [f32; 4], c: [f32; 4], scale: f32) {
        use std::simd::f32x4;
        use std::simd::num::SimdFloat;
        let vq = f32x4::from_array(q);
        let vc = f32x4::from_array(c);
        self.0 += (vq - vc * f32x4::splat(scale)).abs().cast::<f64>();
    }

    #[inline]
    fn finish(self) -> f64 {
        let a = self.0.to_array();
        ((a[0] + a[1]) + a[2]) + a[3]
    }
}

/// Feature off: the simd sink *is* the chunked sink, so lane-explicit
/// callers compile unchanged.
#[cfg(not(feature = "simd"))]
pub type L1Simd = L1Chunked;

// ---------------------------------------------------------------------------
// Integer code dots (exact i64 accumulation)
// ---------------------------------------------------------------------------

/// Exact `sum(q[i] * row[i])` over i8 class codes. Integer, so any
/// accumulation order gives the same bits.
pub fn dot_codes_i8(q: &[i16], row: &[i8], lane: Lane) -> i64 {
    debug_assert_eq!(q.len(), row.len());
    match lane {
        Lane::Chunked => dot_i8_chunked(q, row),
        Lane::Simd => dot_i8_simd(q, row),
    }
}

/// Exact `sum(q[i] * row[i])` over i16 class codes.
pub fn dot_codes_i16(q: &[i16], row: &[i16], lane: Lane) -> i64 {
    debug_assert_eq!(q.len(), row.len());
    match lane {
        Lane::Chunked => dot_i16_chunked(q, row),
        Lane::Simd => dot_i16_simd(q, row),
    }
}

fn dot_i8_chunked(q: &[i16], row: &[i8]) -> i64 {
    let n4 = q.len() / 4 * 4;
    let mut acc = [0i64; 4];
    for (cq, cr) in q[..n4].chunks_exact(4).zip(row[..n4].chunks_exact(4)) {
        for l in 0..4 {
            acc[l] += cq[l] as i64 * cr[l] as i64;
        }
    }
    let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for i in n4..q.len() {
        s += q[i] as i64 * row[i] as i64;
    }
    s
}

fn dot_i16_chunked(q: &[i16], row: &[i16]) -> i64 {
    let n4 = q.len() / 4 * 4;
    let mut acc = [0i64; 4];
    for (cq, cr) in q[..n4].chunks_exact(4).zip(row[..n4].chunks_exact(4)) {
        for l in 0..4 {
            acc[l] += cq[l] as i64 * cr[l] as i64;
        }
    }
    let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for i in n4..q.len() {
        s += q[i] as i64 * row[i] as i64;
    }
    s
}

#[cfg(feature = "simd")]
fn dot_i8_simd(q: &[i16], row: &[i8]) -> i64 {
    use std::simd::num::SimdInt;
    use std::simd::{i16x8, i64x8, i8x8};
    let n8 = q.len() / 8 * 8;
    let mut acc = i64x8::splat(0);
    let mut i = 0;
    while i < n8 {
        // i16*i16 products fit i32; widen to i64 before accumulating so
        // the running sum can never wrap
        let vq = i16x8::from_slice(&q[i..i + 8]).cast::<i32>();
        let vr = i8x8::from_slice(&row[i..i + 8]).cast::<i32>();
        acc += (vq * vr).cast::<i64>();
        i += 8;
    }
    let mut s = acc.reduce_sum();
    for i in n8..q.len() {
        s += q[i] as i64 * row[i] as i64;
    }
    s
}

#[cfg(not(feature = "simd"))]
fn dot_i8_simd(q: &[i16], row: &[i8]) -> i64 {
    dot_i8_chunked(q, row)
}

#[cfg(feature = "simd")]
fn dot_i16_simd(q: &[i16], row: &[i16]) -> i64 {
    use std::simd::num::SimdInt;
    use std::simd::{i16x8, i64x8};
    let n8 = q.len() / 8 * 8;
    let mut acc = i64x8::splat(0);
    let mut i = 0;
    while i < n8 {
        let vq = i16x8::from_slice(&q[i..i + 8]).cast::<i32>();
        let vr = i16x8::from_slice(&row[i..i + 8]).cast::<i32>();
        acc += (vq * vr).cast::<i64>();
        i += 8;
    }
    let mut s = acc.reduce_sum();
    for i in n8..q.len() {
        s += q[i] as i64 * row[i] as i64;
    }
    s
}

#[cfg(not(feature = "simd"))]
fn dot_i16_simd(q: &[i16], row: &[i16]) -> i64 {
    dot_i16_chunked(q, row)
}

// ---------------------------------------------------------------------------
// f32 MAC (the codebook-LUT conv phase 2)
// ---------------------------------------------------------------------------

/// 4-lane multiply-accumulate — the phase-2 codebook MAC of the clustered
/// conv. Lanes are bit-identical (same per-lane op order, same fold);
/// callers that pad both operands to a multiple of 4
/// ([`crate::fe::conv::CodebookLut`]) never take the scalar tail.
pub fn mac_f32(a: &[f32], b: &[f32], lane: Lane) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match lane {
        Lane::Chunked => mac_f32_chunked(a, b),
        Lane::Simd => mac_f32_simd(a, b),
    }
}

fn mac_f32_chunked(a: &[f32], b: &[f32]) -> f32 {
    let n4 = a.len() / 4 * 4;
    let mut acc = [0f32; 4];
    for (ca, cb) in a[..n4].chunks_exact(4).zip(b[..n4].chunks_exact(4)) {
        for l in 0..4 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut s = ((acc[0] + acc[1]) + acc[2]) + acc[3];
    for i in n4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(feature = "simd")]
fn mac_f32_simd(a: &[f32], b: &[f32]) -> f32 {
    use std::simd::f32x4;
    let n4 = a.len() / 4 * 4;
    let mut acc = f32x4::splat(0.0);
    let mut i = 0;
    while i < n4 {
        acc += f32x4::from_slice(&a[i..i + 4]) * f32x4::from_slice(&b[i..i + 4]);
        i += 4;
    }
    let r = acc.to_array();
    let mut s = ((r[0] + r[1]) + r[2]) + r[3];
    for i in n4..a.len() {
        s += a[i] * b[i];
    }
    s
}

#[cfg(not(feature = "simd"))]
fn mac_f32_simd(a: &[f32], b: &[f32]) -> f32 {
    mac_f32_chunked(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    const LANES: [Lane; 2] = [Lane::Chunked, Lane::Simd];

    #[test]
    fn active_lane_is_stable_and_honors_feature_gate() {
        let first = active_lane();
        assert_eq!(first, active_lane(), "lane decision must be immutable");
        if !SIMD_COMPILED {
            assert_eq!(first, Lane::Chunked, "feature off always runs chunked");
        }
    }

    #[test]
    fn xor_popcount_matches_naive_on_odd_lengths() {
        let mut rng = Rng::new(1);
        for len in [0usize, 1, 3, 4, 7, 8, 64, 65] {
            let a: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            let naive: u64 = a.iter().zip(&b).map(|(x, y)| (x ^ y).count_ones() as u64).sum();
            for lane in LANES {
                assert_eq!(xor_popcount(&a, &b, lane), naive, "len={len} {lane:?}");
            }
        }
    }

    #[test]
    fn l1_sinks_are_bit_identical_to_the_scalar_oracle() {
        let mut rng = Rng::new(2);
        for len in [4usize, 8, 108, 4096] {
            let q: Vec<f32> = (0..len).map(|_| rng.gauss_f32()).collect();
            let c: Vec<f32> = (0..len).map(|_| rng.gauss_f32()).collect();
            let scale = 0.37f32;
            // the scalar oracle: distance::l1's accumulation structure
            let mut acc = [0f64; 4];
            for i in (0..len).step_by(4) {
                for l in 0..4 {
                    acc[l] += (q[i + l] - c[i + l] * scale).abs() as f64;
                }
            }
            let want = ((acc[0] + acc[1]) + acc[2]) + acc[3];
            let mut chunked = L1Chunked::default();
            let mut simd = L1Simd::default();
            for i in (0..len).step_by(4) {
                let qa = [q[i], q[i + 1], q[i + 2], q[i + 3]];
                let ca = [c[i], c[i + 1], c[i + 2], c[i + 3]];
                chunked.push4(qa, ca, scale);
                simd.push4(qa, ca, scale);
            }
            let (a, b) = (chunked.finish(), simd.finish());
            assert_eq!(a, want, "len={len}: chunked sink != scalar oracle");
            assert_eq!(a, b, "len={len}: sinks diverged");
        }
    }

    #[test]
    fn code_dots_are_exact_across_lanes_and_tails() {
        let mut rng = Rng::new(3);
        for len in [0usize, 1, 7, 8, 9, 111, 512] {
            let q: Vec<i16> = (0..len).map(|_| (rng.below(65536) as i32 - 32768) as i16).collect();
            let r8: Vec<i8> = (0..len).map(|_| (rng.below(256) as i32 - 128) as i8).collect();
            let r16: Vec<i16> =
                (0..len).map(|_| (rng.below(65536) as i32 - 32768) as i16).collect();
            let want8: i64 = q.iter().zip(&r8).map(|(&a, &b)| a as i64 * b as i64).sum();
            let want16: i64 = q.iter().zip(&r16).map(|(&a, &b)| a as i64 * b as i64).sum();
            for lane in LANES {
                assert_eq!(dot_codes_i8(&q, &r8, lane), want8, "len={len} {lane:?}");
                assert_eq!(dot_codes_i16(&q, &r16, lane), want16, "len={len} {lane:?}");
            }
        }
    }

    #[test]
    fn mac_lanes_are_bit_identical() {
        let mut rng = Rng::new(4);
        for len in [4usize, 16, 64, 100, 102] {
            let a: Vec<f32> = (0..len).map(|_| rng.gauss_f32()).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.gauss_f32()).collect();
            let c = mac_f32(&a, &b, Lane::Chunked);
            let s = mac_f32(&a, &b, Lane::Simd);
            assert_eq!(c, s, "len={len}: mac lanes diverged");
            // and both stay close to the plain serial sum
            let serial: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((c - serial).abs() <= 1e-3 * (1.0 + serial.abs()), "{c} vs {serial}");
        }
    }
}
