//! Small statistics helpers shared by benches, metrics and experiments.

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Percentile via linear interpolation on a sorted copy; p in [0, 100].
/// NaN-robust like `util::timer`: NaN samples are dropped before ranking
/// (a NaN latency must not poison the sort order or panic), and an
/// all-NaN/empty input yields 0.0 like the other empty-input helpers here.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Streaming mean/min/max/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct OnlineStats {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl OnlineStats {
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Classification accuracy from (prediction, label) pairs.
pub fn accuracy(pairs: &[(usize, usize)]) -> f64 {
    if pairs.is_empty() {
        return 0.0;
    }
    pairs.iter().filter(|(p, l)| p == l).count() as f64 / pairs.len() as f64
}

/// 95% confidence half-interval of a mean estimate.
pub fn ci95(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    1.96 * std_dev(xs) / (xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - 1.2909944487).abs() < 1e-9);
    }

    #[test]
    fn percentile_interp() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0];
        let mut o = OnlineStats::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.std() - std_dev(&xs)).abs() < 1e-12);
        assert_eq!(o.min, 1.0);
        assert_eq!(o.max, 9.0);
    }

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[(0, 0), (1, 2), (3, 3), (4, 4)]), 0.75);
        assert_eq!(accuracy(&[]), 0.0);
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn percentile_ignores_nan_samples() {
        // regression for the PR 2/PR 4 bug class: partial_cmp().unwrap()
        // panicked the moment a NaN latency reached a percentile sort
        let xs = [f64::NAN, 10.0, f64::NAN, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_all_nan_is_zero() {
        assert_eq!(percentile(&[f64::NAN, f64::NAN], 99.0), 0.0);
    }
}
