//! `fsl-lint`: the repo-invariant static analysis pass (DESIGN.md §Static
//! analysis).
//!
//! Zero-dependency, text-level linter that walks `rust/src`, `rust/benches`,
//! `rust/tests` and `examples/` and enforces the cross-cutting contracts the
//! code base documents in prose but nothing else checks mechanically:
//!
//! | rule id               | invariant                                              |
//! |-----------------------|--------------------------------------------------------|
//! | `nan-unsafe-ord`      | float ordering goes through `total_cmp`                |
//! | `raw-spawn`           | parallelism flows through `WorkerPool` / scoped joins  |
//! | `panic-in-serving`    | request-serving modules never panic                    |
//! | `wall-clock-in-kernel`| deterministic kernels read no wall clock               |
//! | `unchecked-narrowing` | packed hot-path casts carry an adjacent guard          |
//! | `failpoint-registry`  | fail-point sites and wire variants stay registered     |
//!
//! Diagnostics are `file:line: [rule-id] message`; any unsuppressed violation
//! makes [`Report::ok`] false and the `fsl_lint` binary exit non-zero. A
//! violation can be suppressed in place with a comment on the same line or
//! the line above, spelled `lint:allow` + `(<rule-id>) <justification>` —
//! the justification text is **required**; an allow with nothing after the
//! closing parenthesis does not suppress.
//!
//! This is deliberately a line-oriented scanner, not a real parser: the
//! rules it enforces are lexical (a call spelling, a cast spelling, a string
//! literal) and the repo's vendored-offline constraint rules out `syn`. The
//! scanner does strip comments and mask string/char-literal contents first,
//! so patterns inside strings or docs never fire, and it tracks the first
//! `#[cfg(test)]` line so rules that only bind non-test code can skip test
//! modules.

use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

/// The enforced rule set. Stable ids: these appear in diagnostics, allow
/// comments, DESIGN.md and CI logs, so renaming one is a breaking change.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rule {
    /// `partial_cmp().unwrap()` / float `sort_by` outside `total_cmp`.
    NanUnsafeOrd,
    /// `std::thread::spawn` outside the sanctioned runtime sites.
    RawSpawn,
    /// `unwrap`/`expect`/`panic!`/`unreachable!` in request-serving modules.
    PanicInServing,
    /// `Instant::now` / `SystemTime` inside deterministic kernels.
    WallClockInKernel,
    /// Bare truncating `as` cast in the packed hot paths without a guard.
    UncheckedNarrowing,
    /// Fail-point site registry and wire variant coverage drift.
    FailpointRegistry,
}

impl Rule {
    pub const ALL: [Rule; 6] = [
        Rule::NanUnsafeOrd,
        Rule::RawSpawn,
        Rule::PanicInServing,
        Rule::WallClockInKernel,
        Rule::UncheckedNarrowing,
        Rule::FailpointRegistry,
    ];

    pub fn id(self) -> &'static str {
        match self {
            Rule::NanUnsafeOrd => "nan-unsafe-ord",
            Rule::RawSpawn => "raw-spawn",
            Rule::PanicInServing => "panic-in-serving",
            Rule::WallClockInKernel => "wall-clock-in-kernel",
            Rule::UncheckedNarrowing => "unchecked-narrowing",
            Rule::FailpointRegistry => "failpoint-registry",
        }
    }

    pub fn from_id(id: &str) -> Option<Rule> {
        Rule::ALL.iter().copied().find(|r| r.id() == id)
    }
}

/// One diagnostic. `line` is 1-based; `file` is repo-relative.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: Rule,
    pub file: String,
    pub line: usize,
    pub msg: String,
}

impl Violation {
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.file, self.line, self.rule.id(), self.msg)
    }
}

/// Lint result over a file set.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed violations — any entry here fails the run.
    pub violations: Vec<Violation>,
    /// Violations silenced by a justified allow comment.
    pub suppressed: Vec<Violation>,
    pub files_scanned: usize,
}

impl Report {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// A source file handed to the linter: repo-relative path + full text.
/// Tests construct these in memory; the binary loads them via
/// [`collect_tree`].
#[derive(Clone, Debug)]
pub struct SourceFile {
    pub path: String,
    pub text: String,
}

// ---------------------------------------------------------------------------
// Preprocessing: comment stripping + literal masking
// ---------------------------------------------------------------------------

/// Per-file line views produced by [`preprocess`].
///
/// - `scan`: comments stripped AND string/char-literal contents masked to
///   spaces — the view most rules match against, so a pattern spelled inside
///   a string or a doc comment never fires.
/// - `code`: comments stripped, string literals kept verbatim — the view the
///   fail-point site extractor and the enum parser read.
/// - `comment`: the comment text of each line — the only place allow
///   comments are parsed from, so a fixture string containing an allow does
///   not suppress anything.
struct FileScan {
    path: String,
    scan: Vec<String>,
    code: Vec<String>,
    comment: Vec<String>,
    /// 0-based line index of the first `#[cfg(test)]`; everything from there
    /// to EOF is treated as test code by the rules that skip tests.
    test_start: Option<usize>,
}

impl FileScan {
    fn in_test(&self, idx: usize) -> bool {
        self.test_start.is_some_and(|t| idx >= t)
    }
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

fn preprocess(path: &str, text: &str) -> FileScan {
    #[derive(Clone, Copy)]
    enum St {
        Normal,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
    }

    let b = text.as_bytes();
    let n = b.len();
    let mut scan_lines = Vec::new();
    let mut code_lines = Vec::new();
    let mut comment_lines = Vec::new();
    let (mut scan, mut code, mut comment) = (Vec::new(), Vec::new(), Vec::new());
    let mut st = St::Normal;
    let mut esc = false; // inside Str: previous byte was a backslash
    let mut prev: u8 = b' '; // last byte emitted in Normal state
    let mut i = 0usize;

    macro_rules! flush {
        () => {{
            scan_lines.push(String::from_utf8_lossy(&scan).into_owned());
            code_lines.push(String::from_utf8_lossy(&code).into_owned());
            comment_lines.push(String::from_utf8_lossy(&comment).into_owned());
            scan.clear();
            code.clear();
            comment.clear();
        }};
    }

    while i < n {
        let c = b[i];
        match st {
            St::Normal => {
                if c == b'\n' {
                    flush!();
                    prev = b' ';
                    i += 1;
                } else if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
                    st = St::LineComment;
                    i += 2;
                } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    st = St::BlockComment(1);
                    i += 2;
                } else if (c == b'r' || (c == b'b' && i + 1 < n && b[i + 1] == b'r'))
                    && !is_ident(prev)
                {
                    // Possible raw-string opener: r"..." / r#"..."# / br"...".
                    let mut j = i + if c == b'b' { 2 } else { 1 };
                    let mut hashes = 0u32;
                    while j < n && b[j] == b'#' {
                        hashes += 1;
                        j += 1;
                    }
                    if j < n && b[j] == b'"' {
                        for &p in &b[i..=j] {
                            scan.push(p);
                            code.push(p);
                        }
                        st = St::RawStr(hashes);
                        prev = b'"';
                        i = j + 1;
                    } else {
                        // raw identifier (r#type) or plain ident char
                        scan.push(c);
                        code.push(c);
                        prev = c;
                        i += 1;
                    }
                } else if c == b'"' {
                    scan.push(c);
                    code.push(c);
                    st = St::Str;
                    esc = false;
                    prev = c;
                    i += 1;
                } else if c == b'\'' {
                    // Char literal vs lifetime. 'x' and b'x' are 3 bytes
                    // after the opening quote's position; escapes ('\n',
                    // '\\', '\u{FFFD}') close within a short window.
                    let close = if i + 1 < n && b[i + 1] == b'\\' {
                        (i + 3..n.min(i + 14)).find(|&j| b[j] == b'\'')
                    } else if i + 2 < n && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
                        Some(i + 2)
                    } else {
                        None
                    };
                    match close {
                        Some(e) => {
                            scan.push(b'\'');
                            code.push(b'\'');
                            for &p in &b[i + 1..e] {
                                scan.push(b' ');
                                code.push(p);
                            }
                            scan.push(b'\'');
                            code.push(b'\'');
                            prev = b'\'';
                            i = e + 1;
                        }
                        None => {
                            // lifetime ('a, 'static) — emit and move on
                            scan.push(c);
                            code.push(c);
                            prev = c;
                            i += 1;
                        }
                    }
                } else {
                    scan.push(c);
                    code.push(c);
                    prev = c;
                    i += 1;
                }
            }
            St::LineComment => {
                if c == b'\n' {
                    flush!();
                    st = St::Normal;
                    prev = b' ';
                } else {
                    comment.push(c);
                }
                i += 1;
            }
            St::BlockComment(depth) => {
                if c == b'\n' {
                    flush!();
                    i += 1;
                } else if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else if c == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    st = if depth == 1 { St::Normal } else { St::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if esc {
                    // the escaped byte, whatever it is (incl. a quote or a
                    // line-continuation newline), stays inside the string
                    if c == b'\n' {
                        flush!();
                    } else {
                        scan.push(b' ');
                        code.push(c);
                    }
                    esc = false;
                    i += 1;
                } else if c == b'\\' {
                    scan.push(b' ');
                    code.push(c);
                    esc = true;
                    i += 1;
                } else if c == b'"' {
                    scan.push(c);
                    code.push(c);
                    st = St::Normal;
                    prev = c;
                    i += 1;
                } else if c == b'\n' {
                    flush!();
                    i += 1;
                } else {
                    scan.push(b' ');
                    code.push(c);
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                let h = hashes as usize;
                if c == b'"' && i + h < n && b[i + 1..=i + h].iter().all(|&p| p == b'#') {
                    for &p in &b[i..=i + h] {
                        scan.push(p);
                        code.push(p);
                    }
                    st = St::Normal;
                    prev = b'#';
                    i += h + 1;
                } else if c == b'\n' {
                    flush!();
                    i += 1;
                } else {
                    scan.push(b' ');
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    if !scan.is_empty() || !code.is_empty() || !comment.is_empty() {
        flush!();
    }

    let test_start = scan_lines.iter().position(|l| l.contains("#[cfg(test)]"));
    FileScan { path: path.to_string(), scan: scan_lines, code: code_lines, comment: comment_lines, test_start }
}

/// Count occurrences of `pat` in `text` where the byte after the match is
/// not an identifier byte — so `Request::Query` does not also count every
/// `Request::QueryBatch`.
fn count_ident_bounded(text: &str, pat: &str) -> usize {
    let bytes = text.as_bytes();
    let mut count = 0;
    let mut from = 0;
    while let Some(p) = text[from..].find(pat) {
        let end = from + p + pat.len();
        if end >= bytes.len() || !is_ident(bytes[end]) {
            count += 1;
        }
        from = end;
    }
    count
}

// ---------------------------------------------------------------------------
// Allow comments
// ---------------------------------------------------------------------------

struct Allow {
    rule: Rule,
    /// 0-based line the comment sits on.
    line: usize,
    /// True when text follows the closing parenthesis — the justification.
    justified: bool,
}

fn parse_allows(fs: &FileScan) -> Vec<Allow> {
    // Built by concatenation so this file's own source never contains the
    // marker and cannot suppress anything when the linter scans itself.
    let marker: String = ["lint:", "allow("].concat();
    let mut allows = Vec::new();
    for (idx, text) in fs.comment.iter().enumerate() {
        let mut from = 0;
        while let Some(p) = text[from..].find(&marker) {
            let ids_start = from + p + marker.len();
            let rest = &text[ids_start..];
            let Some(close) = rest.find(')') else { break };
            let justified = !rest[close + 1..].trim().is_empty();
            for id in rest[..close].split(',') {
                if let Some(rule) = Rule::from_id(id.trim()) {
                    allows.push(Allow { rule, line: idx, justified });
                }
            }
            from = ids_start + close;
        }
    }
    allows
}

// ---------------------------------------------------------------------------
// Rule passes
// ---------------------------------------------------------------------------

/// Paths whose non-test code must never panic: one worker death kills every
/// session pinned to it, so these modules return `Response::Error` instead.
const SERVING_FILES: [&str; 6] = [
    "rust/src/coordinator/server.rs",
    "rust/src/coordinator/router.rs",
    "rust/src/coordinator/gateway.rs",
    "rust/src/coordinator/wire.rs",
    "rust/src/coordinator/session.rs",
    "rust/src/coordinator/batcher.rs",
];

/// The only files allowed to call `std::thread::spawn`: the worker pool
/// itself, the gateway's per-connection accept loop, and the coordinator's
/// event-loop thread (`Coordinator::start`). Everything else must use
/// `runtime::pool` (determinism contract) or `std::thread::scope`.
const SPAWN_ALLOWLIST: [&str; 3] = [
    "rust/src/runtime/pool.rs",
    "rust/src/coordinator/gateway.rs",
    "rust/src/coordinator/server.rs",
];

/// Deterministic-kernel directories: replay-based recovery (DESIGN.md
/// §Fault model) only holds if these never read a wall clock. The SIMD
/// kernel layer rides along — both its lanes sit under every packed fast
/// path, so a wall-clock read there would break the same contract.
const KERNEL_DIRS: [&str; 4] =
    ["rust/src/fe/", "rust/src/hdc/", "rust/src/classifier/", "rust/src/util/simd.rs"];

/// Packed hot paths where a truncating cast needs an adjacent guard.
const NARROWING_FILES: [&str; 3] =
    ["rust/src/hdc/packed.rs", "rust/src/fe/conv.rs", "rust/src/util/simd.rs"];

fn is_serving(path: &str) -> bool {
    SERVING_FILES.contains(&path) || path.starts_with("rust/src/classifier/")
}

fn rule_nan_unsafe_ord(fs: &FileScan, out: &mut Vec<Violation>) {
    for (idx, line) in fs.scan.iter().enumerate() {
        if !line.contains("partial_cmp") || line.contains("total_cmp") {
            continue;
        }
        let sorted = ["sort_by", "sort_unstable_by", "max_by", "min_by"]
            .iter()
            .any(|p| line.contains(p));
        if sorted || line.contains(".unwrap()") {
            out.push(Violation {
                rule: Rule::NanUnsafeOrd,
                file: fs.path.clone(),
                line: idx + 1,
                msg: "NaN-unsafe float ordering via partial_cmp; use total_cmp \
                      (see util/timer.rs percentile for the idiom)"
                    .into(),
            });
        }
    }
}

fn rule_raw_spawn(fs: &FileScan, out: &mut Vec<Violation>) {
    let in_scope = fs.path.starts_with("rust/src/") || fs.path.starts_with("examples/");
    if !in_scope || SPAWN_ALLOWLIST.contains(&fs.path.as_str()) {
        return;
    }
    for (idx, line) in fs.scan.iter().enumerate() {
        if fs.in_test(idx) {
            break;
        }
        if line.contains("thread::spawn(") || line.contains("thread::Builder") {
            out.push(Violation {
                rule: Rule::RawSpawn,
                file: fs.path.clone(),
                line: idx + 1,
                msg: "raw thread spawn outside the sanctioned runtime sites; route \
                      work through runtime::pool::WorkerPool or std::thread::scope"
                    .into(),
            });
        }
    }
}

fn rule_panic_in_serving(fs: &FileScan, out: &mut Vec<Violation>) {
    if !is_serving(&fs.path) {
        return;
    }
    const PATTERNS: [&str; 6] =
        [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];
    for (idx, line) in fs.scan.iter().enumerate() {
        if fs.in_test(idx) {
            break;
        }
        for p in PATTERNS {
            if line.contains(p) {
                out.push(Violation {
                    rule: Rule::PanicInServing,
                    file: fs.path.clone(),
                    line: idx + 1,
                    msg: format!(
                        "`{}` in a request-serving module; a panic here kills a worker \
                         and every session pinned to it — return Response::Error",
                        p.trim_start_matches('.')
                    ),
                });
                break;
            }
        }
    }
}

fn rule_wall_clock_in_kernel(fs: &FileScan, out: &mut Vec<Violation>) {
    if !KERNEL_DIRS.iter().any(|d| fs.path.starts_with(d)) {
        return;
    }
    for (idx, line) in fs.scan.iter().enumerate() {
        if fs.in_test(idx) {
            break;
        }
        if line.contains("Instant::now") || line.contains("SystemTime") {
            out.push(Violation {
                rule: Rule::WallClockInKernel,
                file: fs.path.clone(),
                line: idx + 1,
                msg: "wall-clock read inside a deterministic kernel breaks replay \
                      recovery; time at the coordinator or bench layer instead"
                    .into(),
            });
        }
    }
}

fn rule_unchecked_narrowing(fs: &FileScan, out: &mut Vec<Violation>) {
    if !NARROWING_FILES.contains(&fs.path.as_str()) {
        return;
    }
    const CASTS: [&str; 4] = [" as u8", " as i8", " as u16", " as i16"];
    const GUARDS: [&str; 4] = ["debug_assert", "try_from", "TryFrom", "assert!"];
    for (idx, line) in fs.scan.iter().enumerate() {
        if fs.in_test(idx) {
            break;
        }
        let cast = CASTS.iter().any(|p| count_ident_bounded(line, p) > 0);
        if !cast {
            continue;
        }
        let lo = idx.saturating_sub(2);
        let guarded =
            fs.scan[lo..=idx].iter().any(|l| GUARDS.iter().any(|g| l.contains(g)));
        if !guarded {
            out.push(Violation {
                rule: Rule::UncheckedNarrowing,
                file: fs.path.clone(),
                line: idx + 1,
                msg: "bare truncating cast in a packed hot path; add a debug_assert \
                      or try_from within two lines (or a justified allow)"
                    .into(),
            });
        }
    }
}

/// Rule 6, part 1: every literal fail-point site used in `rust/src` must be
/// in the registry's KNOWN list, and every KNOWN site must occur as a string
/// literal at some call/definition site outside the registry.
/// Part 2: every `Request`/`Response` variant must be referenced at least
/// twice (encode + decode) in `wire.rs` non-test code.
fn rule_failpoint_registry(scans: &[FileScan], out: &mut Vec<Violation>) {
    const FP_PATH: &str = "rust/src/util/failpoint.rs";
    if let Some(fp) = scans.iter().find(|f| f.path == FP_PATH) {
        let mut known: Vec<String> = Vec::new();
        let mut known_line = 1;
        if let Some(start) = fp.code.iter().position(|l| l.contains("const KNOWN")) {
            known_line = start + 1;
            for line in &fp.code[start..] {
                known.extend(quoted_strings(line));
                // "];" ends the declaration; a bare ']' also occurs in the
                // `&[&str]` type on the first line, so don't stop on that
                if line.contains("];") {
                    break;
                }
            }
        }
        // part 1a: used sites must be registered
        let check_pat: String = ["failpoint::", "check(\""].concat();
        for fs in scans.iter().filter(|f| f.path.starts_with("rust/src/") && f.path != FP_PATH) {
            for (idx, line) in fs.code.iter().enumerate() {
                if fs.in_test(idx) {
                    break;
                }
                let mut from = 0;
                while let Some(p) = line[from..].find(&check_pat) {
                    let site_start = from + p + check_pat.len();
                    let Some(len) = line[site_start..].find('"') else { break };
                    let site = &line[site_start..site_start + len];
                    if !known.iter().any(|k| k == site) {
                        out.push(Violation {
                            rule: Rule::FailpointRegistry,
                            file: fs.path.clone(),
                            line: idx + 1,
                            msg: format!(
                                "fail-point site \"{site}\" is not in util::failpoint's \
                                 KNOWN registry"
                            ),
                        });
                    }
                    from = site_start + len;
                }
            }
        }
        // part 1b: registered sites must have a literal somewhere in src
        for site in &known {
            let needle = format!("\"{site}\"");
            let used = scans.iter().any(|fs| {
                fs.path.starts_with("rust/src/")
                    && fs.path != FP_PATH
                    && fs.code.iter().enumerate().any(|(idx, l)| !fs.in_test(idx) && l.contains(&needle))
            });
            if !used {
                out.push(Violation {
                    rule: Rule::FailpointRegistry,
                    file: FP_PATH.into(),
                    line: known_line,
                    msg: format!(
                        "registry site \"{site}\" has no literal call site under rust/src \
                         — dead registry entry or a site renamed without updating KNOWN"
                    ),
                });
            }
        }
    }

    // part 2: wire coverage of every Request/Response variant
    let req = scans.iter().find(|f| f.path == "rust/src/coordinator/request.rs");
    let wire = scans.iter().find(|f| f.path == "rust/src/coordinator/wire.rs");
    if let (Some(req), Some(wire)) = (req, wire) {
        let nontest_end = wire.test_start.unwrap_or(wire.scan.len());
        let wire_text = wire.scan[..nontest_end].join("\n");
        for (enum_name, variants) in
            [("Request", enum_variants(req, "Request")), ("Response", enum_variants(req, "Response"))]
        {
            for v in variants {
                let pat = format!("{enum_name}::{v}");
                let hits = count_ident_bounded(&wire_text, &pat);
                if hits < 2 {
                    out.push(Violation {
                        rule: Rule::FailpointRegistry,
                        file: wire.path.clone(),
                        line: 1,
                        msg: format!(
                            "{pat} appears {hits}x in wire.rs non-test code; every \
                             variant needs an encode arm and a decode arm"
                        ),
                    });
                }
            }
        }
    }
}

/// Double-quoted substrings of a comment-stripped code line.
fn quoted_strings(line: &str) -> Vec<String> {
    line.split('"').skip(1).step_by(2).map(str::to_string).collect()
}

/// Variant names of `pub enum <name>` in a file, assuming the repo style of
/// one variant declaration per line (request.rs holds to this).
fn enum_variants(fs: &FileScan, name: &str) -> Vec<String> {
    let header = format!("enum {name} ");
    let Some(start) = fs
        .scan
        .iter()
        .position(|l| l.contains(&header) || l.trim_end().ends_with(&format!("enum {name}")))
    else {
        return Vec::new();
    };
    let mut variants = Vec::new();
    for line in &fs.scan[start + 1..] {
        let t = line.trim();
        if t == "}" {
            break;
        }
        let ident: String = t.bytes().take_while(|&b| is_ident(b)).map(char::from).collect();
        if ident.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
            variants.push(ident);
        }
    }
    variants
}

// ---------------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------------

/// Run every rule over a file set and fold in allow comments.
pub fn lint_files(files: &[SourceFile]) -> Report {
    let scans: Vec<FileScan> = files.iter().map(|f| preprocess(&f.path, &f.text)).collect();
    let allows: HashMap<&str, Vec<Allow>> =
        scans.iter().map(|fs| (fs.path.as_str(), parse_allows(fs))).collect();

    let mut raw = Vec::new();
    for fs in &scans {
        rule_nan_unsafe_ord(fs, &mut raw);
        rule_raw_spawn(fs, &mut raw);
        rule_panic_in_serving(fs, &mut raw);
        rule_wall_clock_in_kernel(fs, &mut raw);
        rule_unchecked_narrowing(fs, &mut raw);
    }
    rule_failpoint_registry(&scans, &mut raw);

    let mut report = Report { files_scanned: files.len(), ..Report::default() };
    for mut v in raw {
        let line0 = v.line - 1;
        let hit = allows.get(v.file.as_str()).into_iter().flatten().find(|a| {
            a.rule == v.rule && (a.line == line0 || a.line + 1 == line0)
        });
        match hit {
            Some(a) if a.justified => report.suppressed.push(v),
            Some(_) => {
                v.msg.push_str(" (allow comment present but carries no justification)");
                report.violations.push(v);
            }
            None => report.violations.push(v),
        }
    }
    report.violations.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report.suppressed.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    report
}

/// Load every `.rs` file under the linted subtrees of `root`.
pub fn collect_tree(root: &Path) -> io::Result<Vec<SourceFile>> {
    let mut out = Vec::new();
    for sub in ["rust/src", "rust/benches", "rust/tests", "examples"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            walk(&dir, root, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(out)
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path.strip_prefix(root).unwrap_or(&path).to_string_lossy().into_owned();
            out.push(SourceFile { path: rel, text: fs::read_to_string(&path)? });
        }
    }
    Ok(())
}

/// Ascend from `start` to the directory containing `rust/src` — works from
/// the repo root, from `rust/`, and from wherever CI invokes the binary.
pub fn find_repo_root(start: &Path) -> Option<PathBuf> {
    let mut dir = start.canonicalize().ok()?;
    loop {
        if dir.join("rust").join("src").is_dir() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Convenience: collect + lint in one call (the binary and the self-check
/// test share this path).
pub fn lint_tree(root: &Path) -> io::Result<Report> {
    Ok(lint_files(&collect_tree(root)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scan_one(path: &str, text: &str) -> FileScan {
        preprocess(path, text)
    }

    #[test]
    fn masker_strips_comments_and_masks_strings() {
        let fs = scan_one(
            "rust/src/x.rs",
            "let a = \"partial_cmp\"; // partial_cmp note\nlet b = 1;\n",
        );
        assert!(!fs.scan[0].contains("partial_cmp"), "string content must be masked");
        assert!(fs.code[0].contains("partial_cmp"), "code view keeps strings");
        assert!(fs.comment[0].contains("partial_cmp note"));
        assert_eq!(fs.scan[1].trim(), "let b = 1;");
    }

    #[test]
    fn masker_handles_char_literals_and_lifetimes() {
        let fs = scan_one(
            "rust/src/x.rs",
            "fn f<'a>(s: &'a str) -> char { if s.is_empty() { '\\\\' } else { 'x' } }\n",
        );
        // the lifetime must not open a string and swallow the rest
        assert!(fs.scan[0].contains("is_empty"));
        assert!(fs.scan[0].contains('{'));
    }

    #[test]
    fn masker_handles_raw_strings_and_escaped_quotes() {
        let src = "let a = r#\"thread::spawn(\"#;\nlet b = \"say \\\"hi\\\" now\";\nlet c = 2;\n";
        let fs = scan_one("rust/src/x.rs", src);
        assert!(!fs.scan[0].contains("thread::spawn"));
        assert!(!fs.scan[1].contains("hi"));
        assert_eq!(fs.scan[2].trim(), "let c = 2;");
    }

    #[test]
    fn masker_tracks_multiline_strings() {
        let src = "let s = \"line one \\\n    line two\";\nlet t = 3;\n";
        let fs = scan_one("rust/src/x.rs", src);
        assert!(!fs.scan[1].contains("line two"));
        assert_eq!(fs.scan[2].trim(), "let t = 3;");
    }

    #[test]
    fn test_region_detection() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests { }\n";
        let fs = scan_one("rust/src/x.rs", src);
        assert_eq!(fs.test_start, Some(1));
        assert!(!fs.in_test(0));
        assert!(fs.in_test(2));
    }

    #[test]
    fn ident_bounded_counting() {
        let text = "Request::Query Request::QueryBatch Request::Query(";
        assert_eq!(count_ident_bounded(text, "Request::Query"), 2);
        assert_eq!(count_ident_bounded(text, "Request::QueryBatch"), 1);
    }

    #[test]
    fn enum_variant_parse() {
        let src = "pub enum Request {\n    A { x: usize },\n    BLong(Vec<u8>),\n    C,\n}\n";
        let fs = scan_one("rust/src/coordinator/request.rs", src);
        assert_eq!(enum_variants(&fs, "Request"), vec!["A", "BLong", "C"]);
    }

    #[test]
    fn rule_ids_round_trip() {
        for r in Rule::ALL {
            assert_eq!(Rule::from_id(r.id()), Some(r));
        }
        assert_eq!(Rule::from_id("no-such-rule"), None);
    }

    #[test]
    fn allow_requires_justification() {
        let viol = "rust/src/coordinator/session.rs";
        let bad = "fn f(x: Option<u32>) -> u32 {\n    // lint:".to_string()
            + "allow(panic-in-serving)\n    x.unwrap()\n}\n";
        let good = bad.replace("serving)", "serving) checked non-empty by caller");
        let r = lint_files(&[SourceFile { path: viol.into(), text: bad }]);
        assert_eq!(r.violations.len(), 1, "bare allow must not suppress");
        assert!(r.violations[0].msg.contains("justification"));
        let r = lint_files(&[SourceFile { path: viol.into(), text: good }]);
        assert!(r.ok(), "justified allow suppresses: {:?}", r.violations);
        assert_eq!(r.suppressed.len(), 1);
    }
}
