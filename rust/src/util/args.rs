//! Tiny `--key value` argument lookup for the bench binaries (benches are
//! plain `harness = false` programs; the CLI proper has its own parser in
//! `main.rs`).

/// Value of `--name N` from the process arguments, or `default` when the
/// flag is absent or unparsable.
pub fn arg_usize(name: &str, default: usize) -> usize {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Value of `--name s` from the process arguments, or `default` when the
/// flag is absent.
pub fn arg_str(name: &str, default: &str) -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

/// True when `--name` is present (bare, or followed by anything but
/// `false`). Lets benches take boolean switches like `--smoke` or
/// `--clustered false`.
pub fn arg_flag(name: &str) -> bool {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == name)
        .map(|i| args.get(i + 1).map(|v| v != "false").unwrap_or(true))
        .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absent_flag_yields_default() {
        // the test binary's own argv has no --no-such-flag
        assert_eq!(arg_usize("--no-such-flag", 7), 7);
        assert_eq!(arg_str("--no-such-flag", "l1"), "l1");
        assert!(!arg_flag("--no-such-flag"));
    }
}
