//! Machine-readable bench trajectory: benches record their headline
//! numbers into `BENCH_hotpath.json` at the repository root so perf is
//! tracked across PRs (EXPERIMENTS.md §Perf). Each bench owns one section
//! keyed by its name; rewriting a section preserves every other bench's
//! entries, so `hotpath_micro` and `fig05_chsub_sweep` can both append to
//! the same file.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Default trajectory file name at the repo root.
pub const BENCH_FILE: &str = "BENCH_hotpath.json";

/// One bench run's entries, merged into the trajectory file on `write`.
pub struct BenchLog {
    bench: String,
    entries: Vec<Entry>,
}

enum Entry {
    /// a timed kernel measurement
    Timing { kernel: String, ns_per_op: f64, items_per_s: f64, workers: usize },
    /// a derived unitless ratio (e.g. packed-vs-f32 speedup) — kept out of
    /// the ns_per_op/items_per_s fields so trajectory tooling never reads
    /// a ratio as a throughput
    Ratio { kernel: String, ratio: f64 },
    /// named free-form values (e.g. a latency-percentile row from the
    /// serving load generator: p50_ms/p99_ms/qps) — each (name, value)
    /// pair becomes its own field next to `kernel`
    Values { kernel: String, values: Vec<(String, f64)> },
}

impl BenchLog {
    pub fn new(bench: &str) -> Self {
        BenchLog { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Record one kernel measurement. `items_per_s` is the op throughput
    /// in whatever unit the kernel processes (images/s for FE forwards,
    /// ops/s for single-kernel cases); `workers` is the sharding width
    /// (1 = serial).
    pub fn record(&mut self, kernel: &str, ns_per_op: f64, items_per_s: f64, workers: usize) {
        self.entries.push(Entry::Timing {
            kernel: kernel.to_string(),
            ns_per_op,
            items_per_s,
            workers,
        });
    }

    /// Record a derived unitless ratio (e.g. a packed-vs-f32 speedup).
    /// Written as `{kernel, ratio}` so it can never be mistaken for a
    /// timing row.
    pub fn record_ratio(&mut self, kernel: &str, ratio: f64) {
        self.entries.push(Entry::Ratio { kernel: kernel.to_string(), ratio });
    }

    /// Record a row of named values — the shape for measurements that are
    /// neither a single timing nor a ratio, like the serving load
    /// generator's `{p50_ms, p99_ms, qps, clients}` latency rows. Each
    /// pair becomes its own JSON field next to `kernel`; the names
    /// `ns_per_op`, `items_per_s`, `workers` and `ratio` stay reserved for
    /// the typed entries so trajectory tooling can keep keying on them.
    pub fn record_values(&mut self, kernel: &str, values: &[(&str, f64)]) {
        self.entries.push(Entry::Values {
            kernel: kernel.to_string(),
            values: values.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        });
    }

    /// Merge this bench's section into `BENCH_hotpath.json` at the repo
    /// root and return the path written.
    pub fn write(&self) -> anyhow::Result<PathBuf> {
        let path = repo_root().join(BENCH_FILE);
        self.write_to(&path)?;
        Ok(path)
    }

    /// Merge into an explicit file (tests). Sections from other benches
    /// are preserved; this bench's section is replaced wholesale. An
    /// unreadable or corrupt existing file is started fresh rather than
    /// failing the bench.
    pub fn write_to(&self, path: &Path) -> anyhow::Result<()> {
        let mut benches: BTreeMap<String, Json> =
            match std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok()) {
                Some(Json::Obj(mut m)) => match m.remove("benches") {
                    Some(Json::Obj(b)) => b,
                    _ => BTreeMap::new(),
                },
                _ => BTreeMap::new(),
            };
        let rows: Vec<Json> = self
            .entries
            .iter()
            .map(|e| {
                let mut o = BTreeMap::new();
                match e {
                    Entry::Timing { kernel, ns_per_op, items_per_s, workers } => {
                        o.insert("kernel".to_string(), Json::Str(kernel.clone()));
                        o.insert("ns_per_op".to_string(), Json::Num(*ns_per_op));
                        o.insert("items_per_s".to_string(), Json::Num(*items_per_s));
                        o.insert("workers".to_string(), Json::Num(*workers as f64));
                    }
                    Entry::Ratio { kernel, ratio } => {
                        o.insert("kernel".to_string(), Json::Str(kernel.clone()));
                        o.insert("ratio".to_string(), Json::Num(*ratio));
                    }
                    Entry::Values { kernel, values } => {
                        o.insert("kernel".to_string(), Json::Str(kernel.clone()));
                        for (k, v) in values {
                            o.insert(k.clone(), Json::Num(*v));
                        }
                    }
                }
                Json::Obj(o)
            })
            .collect();
        benches.insert(self.bench.clone(), Json::Arr(rows));
        let mut root = BTreeMap::new();
        root.insert("benches".to_string(), Json::Obj(benches));
        std::fs::write(path, Json::Obj(root).to_text())?;
        Ok(())
    }
}

/// The repository root: the nearest ancestor of the working directory
/// holding `ROADMAP.md`. Cargo runs benches with the package dir (`rust/`)
/// as cwd while `cargo run` from the root stays at the root — the walk
/// covers both. Falls back to the cwd itself.
fn repo_root() -> PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    let mut dir = cwd.clone();
    for _ in 0..4 {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        if !dir.pop() {
            break;
        }
    }
    cwd
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fsl_hdnn_bench_log_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn sections_merge_and_replace() {
        let path = tmp_path("merge");
        let _ = std::fs::remove_file(&path);
        let mut a = BenchLog::new("bench_a");
        a.record("k1", 1000.0, 1e6, 1);
        a.record("k2", 2000.0, 5e5, 4);
        a.write_to(&path).unwrap();
        // a second bench adds its own section without clobbering a's
        let mut b = BenchLog::new("bench_b");
        b.record("k3", 10.0, 1e8, 1);
        b.write_to(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let benches = j.get("benches").unwrap();
        assert_eq!(benches.get("bench_a").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(benches.get("bench_b").unwrap().as_arr().unwrap().len(), 1);
        // rewriting a replaces its section wholesale
        let mut a2 = BenchLog::new("bench_a");
        a2.record("k9", 7.5, 2e8, 2);
        a2.write_to(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().get("bench_a").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("kernel").unwrap().as_str(), Some("k9"));
        assert_eq!(rows[0].get("ns_per_op").unwrap().as_f64(), Some(7.5));
        assert_eq!(rows[0].get("workers").unwrap().as_usize(), Some(2));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn ratio_rows_use_the_ratio_field() {
        let path = tmp_path("ratio");
        let _ = std::fs::remove_file(&path);
        let mut log = BenchLog::new("bench_r");
        log.record("timed", 100.0, 1e7, 1);
        log.record_ratio("timed_speedup", 3.25);
        log.write_to(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().get("bench_r").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("ratio").unwrap().as_f64(), Some(3.25));
        // a ratio row never carries timing fields, and vice versa
        assert!(rows[1].get("ns_per_op").is_none());
        assert!(rows[0].get("ratio").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn values_rows_carry_each_named_field() {
        let path = tmp_path("values");
        let _ = std::fs::remove_file(&path);
        let mut log = BenchLog::new("bench_v");
        log.record_values("gateway_query", &[("p50_ms", 0.5), ("p99_ms", 2.25), ("qps", 800.0)]);
        log.write_to(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("benches").unwrap().get("bench_v").unwrap().as_arr().unwrap().to_vec();
        assert_eq!(rows[0].get("kernel").unwrap().as_str(), Some("gateway_query"));
        assert_eq!(rows[0].get("p50_ms").unwrap().as_f64(), Some(0.5));
        assert_eq!(rows[0].get("p99_ms").unwrap().as_f64(), Some(2.25));
        assert_eq!(rows[0].get("qps").unwrap().as_f64(), Some(800.0));
        assert!(rows[0].get("ns_per_op").is_none(), "typed fields stay reserved");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_existing_file_is_started_fresh() {
        let path = tmp_path("corrupt");
        std::fs::write(&path, "not json {").unwrap();
        let mut log = BenchLog::new("bench_c");
        log.record("k", 1.0, 1.0, 1);
        log.write_to(&path).unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert!(j.get("benches").unwrap().get("bench_c").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
