//! Built-in micro-bench harness (criterion is unavailable offline).
//!
//! `bench()` warms up, then runs timed repetitions and reports
//! mean ± std and median — what every `benches/*.rs` target prints.

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub reps: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput(&self, items: f64) -> f64 {
        items / (self.mean_ns / 1e9)
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<38} {:>12} ± {:>10}  (median {:>12}, n={})",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.std_ns),
            fmt_ns(self.median_ns),
            self.reps
        )
    }
}

/// Human duration formatting.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Time `f` with warmup; adapts the repetition count to the op cost so a
/// case takes roughly `budget_ms` total.
pub fn bench<F: FnMut()>(name: &str, budget_ms: f64, mut f: F) -> BenchResult {
    // warmup + cost estimate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64() * 1e9;
    let reps = ((budget_ms * 1e6 / once.max(1.0)).ceil() as usize).clamp(5, 10_000);
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e9);
    }
    samples.sort_by(|a, b| a.total_cmp(b));
    let mean = samples.iter().sum::<f64>() / reps as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / reps as f64;
    BenchResult {
        name: name.to_string(),
        reps,
        mean_ns: mean,
        std_ns: var.sqrt(),
        median_ns: samples[reps / 2],
        min_ns: samples[0],
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let mut acc = 0u64;
        let r = bench("spin", 2.0, || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
        assert!(r.reps >= 5);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
