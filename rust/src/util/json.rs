//! Minimal JSON: enough to read `artifacts/manifest.json` / `goldens.json`
//! and to write metrics/experiment reports. No external crates by design
//! (offline vendored registry — see DESIGN.md §Key design decisions).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> anyhow::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            anyhow::bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Array of numbers -> `Vec<usize>` (shape lists in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_u64_vec(&self) -> Option<Vec<u64>> {
        self.as_arr()?.iter().map(|v| v.as_u64()).collect()
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text. Round-trips through
    /// [`Json::parse`]; used to merge report files (e.g. the
    /// `BENCH_hotpath.json` trajectory) without losing other writers'
    /// sections.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        self.write_text(&mut out);
        out
    }

    fn write_text(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => {
                let _ = write!(out, "{b}");
            }
            Json::Num(v) => {
                if !v.is_finite() {
                    // JSON has no NaN/inf literal; null keeps the output
                    // parseable (a 0 ns bench mean would otherwise emit
                    // `inf` and corrupt the whole report file)
                    out.push_str("null");
                } else if v.fract() == 0.0 && v.abs() < 1e15 {
                    let _ = write!(out, "{}", *v as i64);
                } else {
                    let _ = write!(out, "{v}");
                }
            }
            Json::Str(s) => {
                let _ = write!(out, "\"{}\"", escape(s));
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_text(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    let _ = write!(out, "\"{}\":", escape(k));
                    v.write_text(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!("expected '{}' at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        match self.peek() {
            Some(b'{') => self.obj(),
            Some(b'[') => self.arr(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.num(),
            None => anyhow::bail!("unexpected end of input"),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at {}", self.i)
        }
    }

    fn num(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => anyhow::bail!("bad escape at {}", self.i),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    let start = self.i;
                    while self
                        .peek()
                        .map(|c| c != b'"' && c != b'\\')
                        .unwrap_or(false)
                    {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
                None => anyhow::bail!("unterminated string"),
            }
        }
    }

    fn arr(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => anyhow::bail!("expected ',' or ']' at {}", self.i),
            }
        }
    }

    fn obj(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => anyhow::bail!("expected ',' or '}}' at {}", self.i),
            }
        }
    }
}

/// Streaming JSON writer for reports (keeps insertion order, unlike `Json`).
#[derive(Default)]
pub struct JsonWriter {
    out: String,
    stack: Vec<bool>, // "needs comma" per open container
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn pre(&mut self) {
        if let Some(need) = self.stack.last_mut() {
            if *need {
                self.out.push(',');
            }
            *need = true;
        }
    }

    pub fn obj(&mut self) -> &mut Self {
        self.pre();
        self.out.push('{');
        self.stack.push(false);
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.stack.pop();
        self.out.push('}');
        self
    }

    pub fn arr(&mut self) -> &mut Self {
        self.pre();
        self.out.push('[');
        self.stack.push(false);
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.stack.pop();
        self.out.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.pre();
        let _ = write!(self.out, "\"{}\":", escape(k));
        // the value that follows should not emit a comma
        if let Some(need) = self.stack.last_mut() {
            *need = false;
        }
        self
    }

    pub fn str_val(&mut self, v: &str) -> &mut Self {
        self.pre();
        let _ = write!(self.out, "\"{}\"", escape(v));
        self
    }

    pub fn num(&mut self, v: f64) -> &mut Self {
        self.pre();
        if !v.is_finite() {
            // same rule as Json::to_text: non-finite -> null, never `inf`
            self.out.push_str("null");
        } else if v.fract() == 0.0 && v.abs() < 1e15 {
            let _ = write!(self.out, "{}", v as i64);
        } else {
            let _ = write!(self.out, "{v}");
        }
        self
    }

    pub fn bool_val(&mut self, v: bool) -> &mut Self {
        self.pre();
        let _ = write!(self.out, "{v}");
        self
    }

    pub fn field_str(&mut self, k: &str, v: &str) -> &mut Self {
        self.key(k).str_val(v)
    }

    pub fn field_num(&mut self, k: &str, v: f64) -> &mut Self {
        self.key(k).num(v)
    }

    pub fn finish(self) -> String {
        self.out
    }
}

fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip_basics() {
        let j = Json::parse(r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_f64(), Some(2.5));
        assert_eq!(j.get("a").unwrap().idx(2).unwrap().as_f64(), Some(-300.0));
        assert_eq!(j.get("b").unwrap().as_str(), Some("x\ny"));
        assert_eq!(j.get("c"), Some(&Json::Bool(true)));
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"o": {"p": [{"q": 7}]}}"#).unwrap();
        let q = j.get("o").unwrap().get("p").unwrap().idx(0).unwrap().get("q").unwrap();
        assert_eq!(q.as_usize(), Some(7));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn parse_empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn writer_produces_parseable_json() {
        let mut w = JsonWriter::new();
        w.obj();
        w.field_str("name", "fig16");
        w.field_num("value", 3.25);
        w.key("rows").arr();
        w.obj();
        w.field_num("x", 1.0);
        w.end_obj();
        w.end_arr();
        w.key("flag").bool_val(false);
        w.end_obj();
        let s = w.finish();
        let j = Json::parse(&s).unwrap();
        assert_eq!(j.get("value").unwrap().as_f64(), Some(3.25));
        assert_eq!(j.get("rows").unwrap().idx(0).unwrap().get("x").unwrap().as_f64(), Some(1.0));
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""\u0041b""#).unwrap();
        assert_eq!(j.as_str(), Some("Ab"));
    }

    #[test]
    fn to_text_roundtrips() {
        let src = r#"{"a": [1, 2.5, -300], "b": "x\ny", "c": true, "d": null, "e": {}}"#;
        let j = Json::parse(src).unwrap();
        let text = j.to_text();
        assert_eq!(Json::parse(&text).unwrap(), j, "{text}");
        assert_eq!(j.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("a").unwrap().as_bool(), None);
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // regression: `inf`/`NaN` are not JSON; a 0 ns bench mean must not
        // corrupt the report file
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = Json::Num(v).to_text();
            assert_eq!(text, "null", "{v}");
            assert!(Json::parse(&text).is_ok());
            let mut w = JsonWriter::new();
            w.obj();
            w.field_num("x", v);
            w.end_obj();
            assert!(Json::parse(&w.finish()).is_ok());
        }
    }
}
