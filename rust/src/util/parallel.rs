//! Deterministic batch-parallel execution: shard an item batch across a
//! `std::thread::scope` worker pool (anyhow-only dependency policy — no
//! rayon) and stitch per-item results back in input order.
//!
//! The determinism contract (DESIGN.md §Threading model): every item is
//! processed independently by a pure `&self` function, shards are
//! *contiguous* chunks, and results are concatenated in chunk order — so
//! the output is bit-identical to the serial loop for any shard count.
//! No reductions happen across shard boundaries, which is what keeps
//! floating-point results exactly reproducible.

/// Apply `f` to every item, fanning the batch out over `shards` scoped
/// worker threads. `shards <= 1` (or a batch of 0/1 items) runs the plain
/// serial loop on the caller's thread — no threads are spawned.
///
/// Errors propagate like the serial loop's `collect::<Result<_>>`: the
/// first failing item (in input order) wins. Worker panics resume on the
/// caller's thread.
pub fn shard_map<T, U, F>(items: &[T], shards: usize, f: F) -> anyhow::Result<Vec<U>>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> anyhow::Result<U> + Sync,
{
    if shards <= 1 || items.len() <= 1 {
        return items.iter().map(f).collect();
    }
    let chunk_len = items.len().div_ceil(shards.min(items.len()));
    let f = &f;
    let mut chunk_results: Vec<anyhow::Result<Vec<U>>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks(chunk_len)
            .map(|chunk| {
                s.spawn(move || chunk.iter().map(f).collect::<anyhow::Result<Vec<U>>>())
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
            chunk_results.push(r);
        }
    });
    let mut out = Vec::with_capacity(items.len());
    for r in chunk_results {
        out.extend(r?);
    }
    Ok(out)
}

/// [`shard_map`] over **mutable** items: each worker owns a contiguous
/// `chunks_mut` slice, so `f` may advance per-item state (the ragged
/// early-exit batch steps a [`crate::fe::StagedForward`] per survivor).
/// Same determinism contract — items are independent, shards contiguous,
/// results stitched in chunk order, first error in input order wins.
pub fn shard_map_mut<T, U, F>(items: &mut [T], shards: usize, f: F) -> anyhow::Result<Vec<U>>
where
    T: Send,
    U: Send,
    F: Fn(&mut T) -> anyhow::Result<U> + Sync,
{
    if shards <= 1 || items.len() <= 1 {
        return items.iter_mut().map(f).collect();
    }
    let chunk_len = items.len().div_ceil(shards.min(items.len()));
    let f = &f;
    let mut chunk_results: Vec<anyhow::Result<Vec<U>>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = items
            .chunks_mut(chunk_len)
            .map(|chunk| {
                s.spawn(move || chunk.iter_mut().map(f).collect::<anyhow::Result<Vec<U>>>())
            })
            .collect();
        for h in handles {
            let r = h.join().unwrap_or_else(|p| std::panic::resume_unwind(p));
            chunk_results.push(r);
        }
    });
    let mut out = Vec::with_capacity(items.len());
    for r in chunk_results {
        out.extend(r?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_input_order_for_any_shard_count() {
        let items: Vec<usize> = (0..23).collect();
        let want: Vec<usize> = items.iter().map(|i| i * 3).collect();
        for shards in [0, 1, 2, 3, 7, 23, 100] {
            let got = shard_map(&items, shards, |&i| Ok(i * 3)).unwrap();
            assert_eq!(got, want, "shards={shards}");
        }
    }

    #[test]
    fn empty_and_singleton_batches() {
        let got = shard_map(&[] as &[u32], 8, |&i| Ok(i)).unwrap();
        assert!(got.is_empty());
        let got = shard_map(&[42u32], 8, |&i| Ok(i + 1)).unwrap();
        assert_eq!(got, vec![43]);
    }

    #[test]
    fn first_error_in_input_order_wins() {
        let items: Vec<usize> = (0..10).collect();
        for shards in [1, 3, 10] {
            let err = shard_map(&items, shards, |&i| {
                if i >= 4 {
                    anyhow::bail!("item {i} failed")
                }
                Ok(i)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "item 4 failed", "shards={shards}");
        }
    }

    #[test]
    fn shard_map_mut_mutates_and_preserves_order() {
        // each item advances its own counter; results and final state must
        // match the serial loop for any shard count
        let want_state: Vec<u32> = (0..17u32).map(|i| i + 3).collect();
        // acc over x+1, x+2, x+3 = 3x + 6
        let want_out: Vec<u32> = (0..17u32).map(|i| 3 * i + 6).collect();
        for shards in [0, 1, 2, 5, 17, 40] {
            let mut items: Vec<u32> = (0..17).collect();
            let got = shard_map_mut(&mut items, shards, |x| {
                let mut acc = 0;
                for _ in 0..3 {
                    *x += 1;
                    acc += *x;
                }
                Ok(acc)
            })
            .unwrap();
            assert_eq!(items, want_state, "shards={shards}");
            assert_eq!(got, want_out, "shards={shards}");
        }
    }

    #[test]
    fn shard_map_mut_first_error_in_input_order_wins() {
        for shards in [1, 3, 9] {
            let mut items: Vec<usize> = (0..9).collect();
            let err = shard_map_mut(&mut items, shards, |i| {
                if *i >= 5 {
                    anyhow::bail!("item {i} failed")
                }
                Ok(*i)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "item 5 failed", "shards={shards}");
        }
    }

    #[test]
    fn results_match_serial_with_float_work() {
        // f32 math per item: parallel stitching must be bit-identical
        let items: Vec<Vec<f32>> =
            (0..9).map(|i| (0..64).map(|j| (i * 64 + j) as f32 * 0.013).collect()).collect();
        let work = |v: &Vec<f32>| -> anyhow::Result<f32> {
            Ok(v.iter().fold(0f32, |a, &x| a * 0.9993 + x.sin()))
        };
        let serial = shard_map(&items, 1, work).unwrap();
        for shards in [2, 4, 9] {
            assert_eq!(shard_map(&items, shards, work).unwrap(), serial);
        }
    }
}
