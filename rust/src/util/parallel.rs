//! Deterministic batch-parallel execution: shard an item batch across the
//! persistent worker pool (`runtime::pool`, anyhow-only dependency policy
//! — no rayon) and stitch per-item results back in input order.
//!
//! The determinism contract (DESIGN.md §Threading model): every item is
//! processed independently by a pure `&self` function, shards are
//! *contiguous* chunks, and results are concatenated in chunk order — so
//! the output is bit-identical to the serial loop for any shard count.
//! No reductions happen across shard boundaries, which is what keeps
//! floating-point results exactly reproducible.
//!
//! Since PR 6 the shards run on long-lived workers instead of per-call
//! `std::thread::scope` spawns: chunk jobs go to the thread's installed
//! pool (`pool::with_pool`, which the coordinator worker wraps around its
//! event loop) or the process-wide fallback (`pool::global`). The chunk
//! formula, stitching order and error semantics are unchanged, so the
//! contract carries over verbatim; only the per-call thread-spawn tax is
//! gone.

use crate::runtime::pool;

/// Apply `f` to every item, fanning the batch out over `shards` workers of
/// the persistent pool. `shards <= 1` (or a batch of 0/1 items) runs the
/// plain serial loop on the caller's thread — the pool is never touched.
///
/// Errors propagate like the serial loop's `collect::<Result<_>>`: the
/// first failing item (in input order) wins. Worker panics resume on the
/// caller's thread after all chunks have completed.
pub fn shard_map<T, U, F>(items: &[T], shards: usize, f: F) -> anyhow::Result<Vec<U>>
where
    T: Sync,
    U: Send,
    F: Fn(&T) -> anyhow::Result<U> + Sync,
{
    if shards <= 1 || items.len() <= 1 {
        return items.iter().map(f).collect();
    }
    let chunk_len = items.len().div_ceil(shards.min(items.len()));
    let f = &f;
    let mut chunk_results: Vec<Option<anyhow::Result<Vec<U>>>> = Vec::new();
    chunk_results.resize_with(items.len().div_ceil(chunk_len), || None);
    pool::with_current(|p| {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .chunks(chunk_len)
            .zip(chunk_results.iter_mut())
            .map(|(chunk, slot)| {
                Box::new(move || {
                    *slot = Some(chunk.iter().map(f).collect::<anyhow::Result<Vec<U>>>());
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        p.run_scoped(jobs);
    });
    let mut out = Vec::with_capacity(items.len());
    for r in chunk_results {
        out.extend(r.expect("run_scoped completed every chunk")?);
    }
    Ok(out)
}

/// [`shard_map`] over **mutable** items: each worker owns a contiguous
/// `chunks_mut` slice, so `f` may advance per-item state (the ragged
/// early-exit batch steps a [`crate::fe::StagedForward`] per survivor).
/// Same determinism contract — items are independent, shards contiguous,
/// results stitched in chunk order, first error in input order wins.
pub fn shard_map_mut<T, U, F>(items: &mut [T], shards: usize, f: F) -> anyhow::Result<Vec<U>>
where
    T: Send,
    U: Send,
    F: Fn(&mut T) -> anyhow::Result<U> + Sync,
{
    if shards <= 1 || items.len() <= 1 {
        return items.iter_mut().map(f).collect();
    }
    let chunk_len = items.len().div_ceil(shards.min(items.len()));
    let n_chunks = items.len().div_ceil(chunk_len);
    let f = &f;
    let mut chunk_results: Vec<Option<anyhow::Result<Vec<U>>>> = Vec::new();
    chunk_results.resize_with(n_chunks, || None);
    pool::with_current(|p| {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = items
            .chunks_mut(chunk_len)
            .zip(chunk_results.iter_mut())
            .map(|(chunk, slot)| {
                Box::new(move || {
                    *slot = Some(chunk.iter_mut().map(f).collect::<anyhow::Result<Vec<U>>>());
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        p.run_scoped(jobs);
    });
    let mut out = Vec::with_capacity(items.len());
    for r in chunk_results {
        out.extend(r.expect("run_scoped completed every chunk")?);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::pool::{with_pool, WorkerPool};

    #[test]
    fn preserves_input_order_for_any_shard_count() {
        let items: Vec<usize> = (0..23).collect();
        let want: Vec<usize> = items.iter().map(|i| i * 3).collect();
        for shards in [0, 1, 2, 3, 7, 23, 100] {
            let got = shard_map(&items, shards, |&i| Ok(i * 3)).unwrap();
            assert_eq!(got, want, "shards={shards}");
        }
    }

    #[test]
    fn empty_and_singleton_batches() {
        let got = shard_map(&[] as &[u32], 8, |&i| Ok(i)).unwrap();
        assert!(got.is_empty());
        let got = shard_map(&[42u32], 8, |&i| Ok(i + 1)).unwrap();
        assert_eq!(got, vec![43]);
    }

    #[test]
    fn first_error_in_input_order_wins() {
        let items: Vec<usize> = (0..10).collect();
        for shards in [1, 3, 10] {
            let err = shard_map(&items, shards, |&i| {
                if i >= 4 {
                    anyhow::bail!("item {i} failed")
                }
                Ok(i)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "item 4 failed", "shards={shards}");
        }
    }

    #[test]
    fn shard_map_mut_mutates_and_preserves_order() {
        // each item advances its own counter; results and final state must
        // match the serial loop for any shard count
        let want_state: Vec<u32> = (0..17u32).map(|i| i + 3).collect();
        // acc over x+1, x+2, x+3 = 3x + 6
        let want_out: Vec<u32> = (0..17u32).map(|i| 3 * i + 6).collect();
        for shards in [0, 1, 2, 5, 17, 40] {
            let mut items: Vec<u32> = (0..17).collect();
            let got = shard_map_mut(&mut items, shards, |x| {
                let mut acc = 0;
                for _ in 0..3 {
                    *x += 1;
                    acc += *x;
                }
                Ok(acc)
            })
            .unwrap();
            assert_eq!(items, want_state, "shards={shards}");
            assert_eq!(got, want_out, "shards={shards}");
        }
    }

    #[test]
    fn shard_map_mut_first_error_in_input_order_wins() {
        for shards in [1, 3, 9] {
            let mut items: Vec<usize> = (0..9).collect();
            let err = shard_map_mut(&mut items, shards, |i| {
                if *i >= 5 {
                    anyhow::bail!("item {i} failed")
                }
                Ok(*i)
            })
            .unwrap_err();
            assert_eq!(err.to_string(), "item 5 failed", "shards={shards}");
        }
    }

    #[test]
    fn results_match_serial_with_float_work() {
        // f32 math per item: parallel stitching must be bit-identical
        let items: Vec<Vec<f32>> =
            (0..9).map(|i| (0..64).map(|j| (i * 64 + j) as f32 * 0.013).collect()).collect();
        let work = |v: &Vec<f32>| -> anyhow::Result<f32> {
            Ok(v.iter().fold(0f32, |a, &x| a * 0.9993 + x.sin()))
        };
        let serial = shard_map(&items, 1, work).unwrap();
        for shards in [2, 4, 9] {
            assert_eq!(shard_map(&items, shards, work).unwrap(), serial);
        }
    }

    #[test]
    fn runs_on_an_installed_pool_without_residue() {
        // with_pool routes the shards onto a caller-owned pool (what the
        // coordinator worker does around its event loop); results stay
        // bit-identical and no task is left behind on the pool
        let items: Vec<u64> = (0..31).collect();
        let serial = shard_map(&items, 1, |&i| Ok(i * i)).unwrap();
        let p = WorkerPool::new(3);
        let got = with_pool(&p, || shard_map(&items, 5, |&i| Ok(i * i))).unwrap();
        assert_eq!(got, serial);
        assert_eq!(p.queue_depth(), 0);
    }

    #[test]
    fn nested_shard_map_matches_serial() {
        // an outer shard closure calling shard_map again lands on a pool
        // worker thread, where the inner call must run inline (deadlock
        // guard) and still produce the serial result
        let items: Vec<usize> = (0..12).collect();
        let work = |&i: &usize| -> anyhow::Result<usize> {
            let inner: Vec<usize> = (0..6).collect();
            let parts = shard_map(&inner, 3, |&j| Ok(i * 10 + j))?;
            Ok(parts.into_iter().sum())
        };
        let serial = shard_map(&items, 1, work).unwrap();
        let p = WorkerPool::new(2);
        for shards in [2, 4, 12] {
            assert_eq!(shard_map(&items, shards, work).unwrap(), serial, "global pool");
            let got = with_pool(&p, || shard_map(&items, shards, work)).unwrap();
            assert_eq!(got, serial, "installed pool, shards={shards}");
        }
    }

    #[test]
    fn worker_panics_resume_on_the_caller() {
        let items: Vec<usize> = (0..8).collect();
        let r = std::panic::catch_unwind(|| {
            let _ = shard_map(&items, 4, |&i| {
                if i == 5 {
                    panic!("item {i} panicked")
                }
                Ok(i)
            });
        });
        assert!(r.is_err(), "shard panic must unwind out of shard_map");
        // the pool survives a panicking shard; later calls still work
        let got = shard_map(&items, 4, |&i| Ok(i + 1)).unwrap();
        assert_eq!(got, (1..9).collect::<Vec<_>>());
    }
}
