//! ASCII table rendering for bench output — every bench prints the same
//! rows/series the paper's tables and figures report.

/// A simple column-aligned ASCII table with a title and header row.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: row from display-able items.
    pub fn row_disp<D: std::fmt::Display>(&mut self, cells: &[D]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(widths[i] - c.len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        out.push_str(&format!("# {}\n", self.title));
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `p` significant-looking decimals.
pub fn f(v: f64, p: usize) -> String {
    format!("{v:.p$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("# demo"));
        assert!(s.contains("| long-name | 2.5   |"));
        // all lines between separators have the same width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
