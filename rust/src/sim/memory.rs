//! On-chip SRAM model: banked, gateable, access-counted (Fig. 7's 8-bank
//! activation memory, 16-bank index memory, 16-bank class memory).

/// A banked SRAM with per-bank gating and access counters.
#[derive(Clone, Debug)]
pub struct Sram {
    pub name: &'static str,
    pub kb: usize,
    pub banks: usize,
    /// row width in bits (one access reads/writes a row)
    pub row_bits: usize,
    pub reads: u64,
    pub writes: u64,
    pub gated_banks: usize,
}

impl Sram {
    pub fn new(name: &'static str, kb: usize, banks: usize, row_bits: usize) -> Self {
        Sram { name, kb, banks, row_bits, reads: 0, writes: 0, gated_banks: 0 }
    }

    pub fn capacity_bits(&self) -> u64 {
        self.kb as u64 * 1024 * 8
    }

    /// Record `n` row reads; returns bits moved.
    pub fn read_rows(&mut self, n: u64) -> u64 {
        self.reads += n;
        n * self.row_bits as u64
    }

    pub fn write_rows(&mut self, n: u64) -> u64 {
        self.writes += n;
        n * self.row_bits as u64
    }

    /// Gate off unused banks (the paper gates unused class-memory banks).
    pub fn gate_unused(&mut self, used_fraction: f64) {
        let used = (used_fraction.clamp(0.0, 1.0) * self.banks as f64).ceil() as usize;
        self.gated_banks = self.banks - used.max(1);
    }

    /// Fraction of leakage remaining after gating.
    pub fn leakage_fraction(&self) -> f64 {
        (self.banks - self.gated_banks) as f64 / self.banks as f64
    }

    pub fn total_bits_moved(&self) -> u64 {
        (self.reads + self.writes) * self.row_bits as u64
    }
}

/// Double-buffer occupancy check: a working set fits the double-buffered
/// activation memory when each half holds one buffer.
pub fn fits_double_buffered(sram: &Sram, working_set_bits: u64) -> bool {
    working_set_bits * 2 <= sram.capacity_bits()
}

/// The chip's memory complement (Fig. 7 / Fig. 13b).
#[derive(Clone, Debug)]
pub struct ChipMemories {
    pub activation: Sram,
    pub index: Sram,
    pub codebook: Sram,
    pub class: Sram,
}

impl ChipMemories {
    pub fn paper() -> Self {
        ChipMemories {
            activation: Sram::new("act", 128, 8, 256),
            index: Sram::new("idx", 36, 16, 64),
            codebook: Sram::new("cb", 4, 16, 256),
            class: Sram::new("class", 256, 16, 256),
        }
    }

    pub fn total_kb(&self) -> usize {
        self.activation.kb + self.index.kb + self.codebook.kb + self.class.kb
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_totals_424kb() {
        assert_eq!(ChipMemories::paper().total_kb(), 424);
    }

    #[test]
    fn access_counting() {
        let mut s = Sram::new("t", 1, 2, 128);
        assert_eq!(s.read_rows(4), 512);
        assert_eq!(s.write_rows(2), 256);
        assert_eq!(s.total_bits_moved(), 768);
    }

    #[test]
    fn gating() {
        let mut s = Sram::new("t", 256, 16, 256);
        s.gate_unused(0.25);
        assert_eq!(s.gated_banks, 12);
        assert!((s.leakage_fraction() - 0.25).abs() < 1e-9);
        s.gate_unused(0.0);
        assert_eq!(s.gated_banks, 15, "at least one bank stays on");
    }

    #[test]
    fn double_buffer_check() {
        let s = Sram::new("act", 128, 8, 256);
        // 128 KB = 1 Mib; a 400 Kib working set double-buffers, 600 Kib not
        assert!(fits_double_buffered(&s, 400 * 1024));
        assert!(!fits_double_buffered(&s, 600 * 1024));
    }
}
