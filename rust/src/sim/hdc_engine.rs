//! HDC classifier engine: cycle + event model (Section IV-B, Fig. 9).
//!
//! * cRP encoder: one 16x16 block per cycle (16 LFSR steps + 256 binary
//!   multiplies + 16 adder trees of 16 inputs) -> D*F/256 cycles/encode.
//! * Distance module: one 256-bit class-HV segment per cycle -> C * D/16
//!   cycles per query (L1 subtract-abs-accumulate per element).
//! * Training module: one 256-bit segment per cycle -> D/16 cycles per
//!   class update, 16 parallel adders.
//! * Conventional-RP baseline numbers for Fig. 10 (base matrix stored in
//!   SRAM instead of generated).
//!
//! Like `sim::pe_array` for the conv datapath, the cycle model is
//! cross-checked against the shipped numerics: `distance_tally`'s segment
//! walk and class-memory traffic must equal what
//! [`crate::hdc::packed::PackedClassHvs`] — the datapath the native
//! classifier actually executes — reports for the same geometry, so cycle
//! accounting can never drift from the packed implementation.

use super::energy::EnergyTally;

/// Cycle/event cost of cRP-encoding one F-dim feature into a D-dim HV.
pub fn encode_tally(f: usize, d: usize) -> EnergyTally {
    let blocks = (d as u64 * f as u64) / 256;
    EnergyTally {
        lfsr_steps: blocks * 16,
        // 256 ±1 multiplies are sign-flips absorbed into the adder trees:
        // 16 trees x 15 adds, plus 16 accumulator adds
        hdc_adds: blocks * (16 * 15 + 16),
        // feature segment reads from the feature buffer (16 x 16-bit)
        sram_bits: blocks * 256,
        active_cycles: blocks,
        total_cycles: blocks,
        ..Default::default()
    }
}

/// Cycle/event cost of one query distance search over `classes` class HVs
/// at `hv_bits` precision.
pub fn distance_tally(d: usize, classes: usize, hv_bits: u32) -> EnergyTally {
    let segments = (d as u64).div_ceil(16) * classes as u64;
    EnergyTally {
        // per segment: 16 subtract + 16 abs-accumulate
        hdc_adds: segments * 32,
        class_bits: segments * 16 * hv_bits as u64,
        active_cycles: segments,
        total_cycles: segments,
        ..Default::default()
    }
}

/// Cycle/event cost of bundling `k` shot HVs into one class HV
/// (aggregation-based training, eq. 4).
pub fn train_update_tally(d: usize, k: usize, hv_bits: u32) -> EnergyTally {
    let segments = (d as u64).div_ceil(16) * k as u64;
    EnergyTally {
        hdc_adds: segments * 16,
        // read-modify-write of the class HV segment
        class_bits: segments * 2 * 16 * hv_bits as u64,
        active_cycles: segments,
        total_cycles: segments,
        ..Default::default()
    }
}

/// Conventional RP encoder (Fig. 6a / [31]) for the Fig. 10 comparison:
/// the full F x D ±1 matrix is stored and streamed from SRAM.
pub fn conventional_rp_tally(f: usize, d: usize) -> EnergyTally {
    let blocks = (d as u64 * f as u64) / 256;
    EnergyTally {
        hdc_adds: blocks * (16 * 15 + 16),
        // base matrix bits + feature segments all come from SRAM
        sram_bits: blocks * 256 + blocks * 256,
        active_cycles: blocks,
        total_cycles: blocks,
        ..Default::default()
    }
}

/// Base-matrix storage (bits) for conventional RP vs cRP (Fig. 10c).
pub fn rp_storage_bits(f: usize, d: usize) -> u64 {
    f as u64 * d as u64
}

pub fn crp_storage_bits() -> u64 {
    256 // one 16x16 initial block (seed state)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_cycles_match_paper_formula() {
        // Section IV-B2: D*F/B cycles with B = 256
        let t = encode_tally(512, 4096);
        assert_eq!(t.total_cycles, 512 * 4096 / 256);
    }

    #[test]
    fn hdc_is_tiny_next_to_fe() {
        // encode + 10-class distance at F=512, D=4096 is thousands of
        // cycles; the FE is millions — matches Fig. 2(c)'s narrative
        let t = encode_tally(512, 4096);
        let q = distance_tally(4096, 10, 16);
        assert!(t.total_cycles + q.total_cycles < 50_000);
    }

    #[test]
    fn distance_scales_with_precision_only_in_bits() {
        let a = distance_tally(4096, 8, 4);
        let b = distance_tally(4096, 8, 16);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(b.class_bits, 4 * a.class_bits);
    }

    #[test]
    fn memory_ratio_512_to_4096x() {
        // Fig. 10c: 512-4096x less weight memory for F=512, D=1024..8192
        for (d, expect) in [(1024usize, 2048u64), (4096, 8192), (8192, 16384)] {
            let ratio = rp_storage_bits(512, d) / crp_storage_bits();
            assert_eq!(ratio, expect);
        }
        // the paper quotes 512-4096x for its supported D range against a
        // per-16-row-band reseed granularity; our O(256) constant is even
        // stronger — assert at least the paper's ratios hold
        assert!(rp_storage_bits(512, 1024) / crp_storage_bits() >= 512);
    }

    #[test]
    fn crp_beats_rp_in_sram_traffic() {
        let crp = encode_tally(512, 4096);
        let rp = conventional_rp_tally(512, 4096);
        assert!(rp.sram_bits > crp.sram_bits);
    }

    #[test]
    fn train_update_cost_linear_in_k() {
        let t1 = train_update_tally(4096, 1, 16);
        let t5 = train_update_tally(4096, 5, 16);
        assert_eq!(t5.total_cycles, 5 * t1.total_cycles);
    }

    #[test]
    fn distance_tally_matches_packed_datapath() {
        // the cycle model vs the class memory the native classifier
        // actually walks (hdc::packed) — the pe_array pattern for HDC
        use crate::hdc::packed::PackedClassHvs;
        use crate::util::prng::Rng;
        let mut rng = Rng::new(1);
        let (classes, d) = (7usize, 4096usize);
        let rows: Vec<f32> = (0..classes * d).map(|_| rng.gauss_f32()).collect();
        for bits in [1u32, 4, 8, 16] {
            let p = PackedClassHvs::from_rows(&rows, classes, d, bits);
            let t = distance_tally(d, classes, bits);
            // one 16-lane segment per active cycle, every class row walked
            assert_eq!(t.active_cycles, p.segments_per_query(), "bits={bits}");
            // class-memory traffic equals the packed store's logical bits
            assert_eq!(
                t.class_bits,
                classes as u64 * p.storage_bits_per_class(),
                "bits={bits}"
            );
            // at the chip's power-of-two precisions the software store is
            // tight: it allocates exactly what the tally charges
            assert_eq!(p.allocated_bits_per_class(), p.storage_bits_per_class(), "bits={bits}");
        }
    }

    #[test]
    fn odd_dimension_segments_round_up_together() {
        use crate::hdc::packed::PackedClassHvs;
        let (classes, d) = (3usize, 100usize); // not a multiple of 16
        let rows = vec![0.5f32; classes * d];
        let p = PackedClassHvs::from_rows(&rows, classes, d, 4);
        let t = distance_tally(d, classes, 4);
        assert_eq!(t.active_cycles, p.segments_per_query());
    }
}
