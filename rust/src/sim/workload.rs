//! Workload geometry: conv layer tables fed to the FE engine.

/// Geometry of one convolution layer as the accelerator sees it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvGeom {
    pub cout: usize,
    pub cin: usize,
    pub k: usize,
    /// output spatial size (H_out == W_out assumed for these workloads)
    pub out: usize,
    pub stride: usize,
    /// ResNet stage (0-based) this layer belongs to — drives the
    /// early-exit prefix accounting (4 CONV layers per block, Fig. 11)
    pub stage: usize,
}

impl ConvGeom {
    /// Dense MAC count.
    pub fn macs(&self) -> u64 {
        (self.out * self.out * self.cout * self.k * self.k * self.cin) as u64
    }

    /// Activation-accumulate operations (phase 1 of the clustered conv) —
    /// same count as MACs: each input tap is accumulated once.
    pub fn accum_ops(&self) -> u64 {
        self.macs()
    }

    /// Weight-index storage bits at log2(N) bits per weight.
    pub fn index_bits(&self, n_centroids: usize) -> u64 {
        let idx_bits = (n_centroids as f64).log2().ceil() as u64;
        (self.cout * self.k * self.k * self.cin) as u64 * idx_bits
    }

    /// Codebook storage bits: one N x 16-bit codebook per (cout, group).
    pub fn codebook_bits(&self, ch_sub: usize, n_centroids: usize) -> u64 {
        let g = self.cin.div_ceil(ch_sub.min(self.cin)) as u64;
        self.cout as u64 * g * n_centroids as u64 * 16
    }
}

/// ResNet-18 at 224x224 — the paper's measurement workload (Table I
/// footnote f: "224x224 image @ ResNet-18"). Stage indices mark the four
/// CONV blocks whose outputs feed the early-exit branches (Fig. 11).
pub fn resnet18_224() -> Vec<ConvGeom> {
    let mut layers = vec![
        // stem: 7x7/2 conv, 3->64, out 112 (then 3x3/2 maxpool -> 56)
        ConvGeom { cout: 64, cin: 3, k: 7, out: 112, stride: 2, stage: 0 },
    ];
    // stage 1: 2 basic blocks @56, 64ch
    for _ in 0..2 {
        layers.push(ConvGeom { cout: 64, cin: 64, k: 3, out: 56, stride: 1, stage: 0 });
        layers.push(ConvGeom { cout: 64, cin: 64, k: 3, out: 56, stride: 1, stage: 0 });
    }
    // stages 2..4: first block downsamples (stride 2) + 1x1 projection
    let specs = [(128usize, 64usize, 28usize, 1usize), (256, 128, 14, 2), (512, 256, 7, 3)];
    for (w, w_prev, out, stage) in specs {
        layers.push(ConvGeom { cout: w, cin: w_prev, k: 3, out, stride: 2, stage });
        layers.push(ConvGeom { cout: w, cin: w, k: 3, out, stride: 1, stage });
        layers.push(ConvGeom { cout: w, cin: w_prev, k: 1, out, stride: 2, stage }); // proj
        layers.push(ConvGeom { cout: w, cin: w, k: 3, out, stride: 1, stage });
        layers.push(ConvGeom { cout: w, cin: w, k: 3, out, stride: 1, stage });
    }
    layers
}

/// Total dense MACs of a layer table.
pub fn total_macs(layers: &[ConvGeom]) -> u64 {
    layers.iter().map(|l| l.macs()).sum()
}

/// Layers belonging to stages `0..=stage` (early-exit prefix).
pub fn prefix(layers: &[ConvGeom], stage: usize) -> Vec<ConvGeom> {
    layers.iter().copied().filter(|l| l.stage <= stage).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_macs_about_1_8g() {
        let g = total_macs(&resnet18_224());
        // published ResNet-18 @224 is ~1.8 GMAC
        assert!(g > 1_600_000_000 && g < 2_100_000_000, "got {g}");
    }

    #[test]
    fn stages_cover_0_to_3() {
        let layers = resnet18_224();
        for s in 0..4 {
            assert!(layers.iter().any(|l| l.stage == s));
        }
        assert!(layers.iter().all(|l| l.stage < 4));
    }

    #[test]
    fn prefix_monotone() {
        let layers = resnet18_224();
        let mut prev = 0;
        for s in 0..4 {
            let macs = total_macs(&prefix(&layers, s));
            assert!(macs > prev);
            prev = macs;
        }
        assert_eq!(prev, total_macs(&layers));
    }

    #[test]
    fn index_bits_match_4bit_per_weight() {
        let l = ConvGeom { cout: 64, cin: 64, k: 3, out: 56, stride: 1, stage: 0 };
        assert_eq!(l.index_bits(16), (64 * 9 * 64 * 4) as u64);
    }

    #[test]
    fn early_stage_cheaper_than_late_but_same_order() {
        let layers = resnet18_224();
        let s0 = total_macs(&prefix(&layers, 0));
        let all = total_macs(&layers);
        assert!(s0 * 2 < all, "stage 0 should be well under half the model");
    }
}
