//! 40 nm energy model, calibrated to the chip's measured corners.
//!
//! Measured anchors (Section VI-B): 59 mW @ 100 MHz / 0.9 V and 305 mW @
//! 250 MHz / 1.2 V; ~6 mJ/image end-to-end training energy; 1.4–2.9 TOPS/W.
//!
//! Model: per-event energies at the reference corner (1.2 V), scaled by
//! (V/Vref)^GAMMA with GAMMA = 2.5 — the effective exponent fitted to the
//! two measured corners (P_slow/P_fast = 59/305 = 0.193 vs
//! (100/250)*(0.9/1.2)^2.5 = 0.195; a pure fV^2 model with non-negative
//! leakage cannot hit both corners, see DESIGN.md). Leakage is folded into
//! the per-cycle baseline.

/// Per-event energies (picojoules) at the 1.2 V reference corner, plus
/// voltage/frequency scaling.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    pub v_ref: f64,
    /// fitted effective voltage exponent
    pub gamma: f64,
    // --- FE datapath (per event, pJ @ Vref) ---
    /// one BF16 activation-accumulate into an RF (adder + RF r/w)
    pub pe_accum_pj: f64,
    /// one BF16 codebook MAC
    pub pe_mac_pj: f64,
    /// per-bit on-chip SRAM access
    pub sram_bit_pj: f64,
    /// per-bit off-chip DRAM transfer
    pub dram_bit_pj: f64,
    // --- HDC datapath ---
    /// one LFSR step (16 bits of fresh state)
    pub lfsr_step_pj: f64,
    /// one INT add in the encoder's adder trees / HV updater
    pub hdc_add_pj: f64,
    /// per-bit class-memory access
    pub class_bit_pj: f64,
    /// standby energy per powered class-memory bank per cycle — what bank
    /// gating (Fig. 9) saves when occupancy leaves banks dark
    pub class_bank_idle_pj: f64,
    // --- baseline ---
    /// idle/clock-tree energy per cycle (pJ) — covers leakage + clocking
    pub idle_cycle_pj: f64,
    /// extra per-cycle overhead while the PE array is active (control,
    /// buses, misc.) on top of the counted events
    pub active_overhead_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // Fitted so that: peak chip power @250 MHz/1.2 V ~ 305 mW,
        // training-average ~ 171 mW (6 mJ / 35 ms), slow corner ~ 59 mW.
        EnergyModel {
            v_ref: 1.2,
            gamma: 2.5,
            pe_accum_pj: 1.8,
            pe_mac_pj: 3.6,
            sram_bit_pj: 0.75,
            dram_bit_pj: 20.0,
            lfsr_step_pj: 0.12,
            hdc_add_pj: 0.35,
            class_bit_pj: 0.9,
            // 16 powered banks @ 250 MHz ≈ 24 mW standby — a plausible
            // slice of the 305 mW peak that gating can claw back
            class_bank_idle_pj: 6.0,
            idle_cycle_pj: 200.0,
            active_overhead_pj: 160.0,
        }
    }
}

impl EnergyModel {
    /// Voltage scale factor for per-event energies.
    pub fn vscale(&self, voltage: f64) -> f64 {
        (voltage / self.v_ref).powf(self.gamma)
    }

    /// The chip's V/f operating curve (shmoo, Fig. 13a): max frequency
    /// scales roughly linearly between the two measured corners.
    pub fn freq_at_voltage(&self, voltage: f64) -> f64 {
        // 0.9 V -> 100 MHz, 1.2 V -> 250 MHz (linear interpolation)
        (100.0 + (voltage - 0.9) / 0.3 * 150.0).clamp(20.0, 300.0)
    }

    /// Energy (mJ) for an event tally at `voltage`.
    pub fn energy_mj(&self, tally: &EnergyTally, voltage: f64) -> f64 {
        let s = self.vscale(voltage);
        let pj = tally.pe_accum as f64 * self.pe_accum_pj
            + tally.pe_mac as f64 * self.pe_mac_pj
            + tally.sram_bits as f64 * self.sram_bit_pj
            + tally.dram_bits as f64 * self.dram_bit_pj
            + tally.lfsr_steps as f64 * self.lfsr_step_pj
            + tally.hdc_adds as f64 * self.hdc_add_pj
            + tally.class_bits as f64 * self.class_bit_pj
            + tally.active_cycles as f64 * self.active_overhead_pj
            + tally.total_cycles as f64 * self.idle_cycle_pj;
        pj * s * 1e-9
    }

    /// Static class-memory power (mW) with `active_banks` powered at
    /// (voltage, freq) — the coordinator's `ClassMemoryManager` reports
    /// `active_banks()`/`gated_banks()`, and the difference between a
    /// fully-powered and a gated memory is the Fig. 9 saving.
    pub fn class_mem_static_mw(&self, active_banks: usize, voltage: f64, freq_mhz: f64) -> f64 {
        // pJ/cycle/bank * banks * cycles/s = pJ/s; 1 pJ/s = 1e-9 mW
        active_banks as f64 * self.class_bank_idle_pj * freq_mhz * 1e6 * 1e-9
            * self.vscale(voltage)
    }

    /// Average power (mW) given a tally executed at (voltage, freq).
    pub fn avg_power_mw(&self, tally: &EnergyTally, voltage: f64, freq_mhz: f64) -> f64 {
        let t_ms = tally.total_cycles as f64 / (freq_mhz * 1e3);
        if t_ms <= 0.0 {
            return 0.0;
        }
        self.energy_mj(tally, voltage) / t_ms * 1e3
    }
}

/// Event counters accumulated by the engines.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyTally {
    pub pe_accum: u64,
    pub pe_mac: u64,
    pub sram_bits: u64,
    pub dram_bits: u64,
    pub lfsr_steps: u64,
    pub hdc_adds: u64,
    pub class_bits: u64,
    /// cycles with the PE array switching
    pub active_cycles: u64,
    /// wall cycles including stalls
    pub total_cycles: u64,
}

impl EnergyTally {
    pub fn add(&mut self, other: &EnergyTally) {
        self.pe_accum += other.pe_accum;
        self.pe_mac += other.pe_mac;
        self.sram_bits += other.sram_bits;
        self.dram_bits += other.dram_bits;
        self.lfsr_steps += other.lfsr_steps;
        self.hdc_adds += other.hdc_adds;
        self.class_bits += other.class_bits;
        self.active_cycles += other.active_cycles;
        self.total_cycles += other.total_cycles;
    }

    pub fn scaled(&self, times: u64) -> EnergyTally {
        EnergyTally {
            pe_accum: self.pe_accum * times,
            pe_mac: self.pe_mac * times,
            sram_bits: self.sram_bits * times,
            dram_bits: self.dram_bits * times,
            lfsr_steps: self.lfsr_steps * times,
            hdc_adds: self.hdc_adds * times,
            class_bits: self.class_bits * times,
            active_cycles: self.active_cycles * times,
            total_cycles: self.total_cycles * times,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vscale_matches_corner_ratio() {
        let m = EnergyModel::default();
        // (100/250) * (0.9/1.2)^2.5 should be close to 59/305
        let ratio = (100.0 / 250.0) * m.vscale(0.9);
        assert!((ratio - 59.0 / 305.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn freq_curve_hits_corners() {
        let m = EnergyModel::default();
        assert!((m.freq_at_voltage(0.9) - 100.0).abs() < 1e-9);
        assert!((m.freq_at_voltage(1.2) - 250.0).abs() < 1e-9);
    }

    #[test]
    fn energy_scales_with_voltage() {
        let m = EnergyModel::default();
        let t = EnergyTally { pe_accum: 1000, total_cycles: 100, ..Default::default() };
        assert!(m.energy_mj(&t, 1.2) > m.energy_mj(&t, 0.9));
    }

    #[test]
    fn tally_add_and_scale() {
        let a = EnergyTally { pe_accum: 1, pe_mac: 2, total_cycles: 3, ..Default::default() };
        let mut b = a;
        b.add(&a);
        assert_eq!(b.pe_accum, 2);
        assert_eq!(a.scaled(3).total_cycles, 9);
    }

    #[test]
    fn power_of_empty_tally_is_zero() {
        let m = EnergyModel::default();
        assert_eq!(m.avg_power_mw(&EnergyTally::default(), 1.2, 250.0), 0.0);
    }

    #[test]
    fn bank_gating_saves_proportional_standby_power() {
        let m = EnergyModel::default();
        let full = m.class_mem_static_mw(16, 1.2, 250.0);
        let half = m.class_mem_static_mw(8, 1.2, 250.0);
        assert!((full - 2.0 * half).abs() < 1e-9, "gating 8 of 16 banks halves standby power");
        assert_eq!(m.class_mem_static_mw(0, 1.2, 250.0), 0.0);
        // the fully-powered memory sits in a plausible slice of the
        // 305 mW measured peak (Section VI-B)
        assert!(full > 5.0 && full < 60.0, "full-memory standby {full} mW");
        // standby power scales down with voltage like every other event
        assert!(m.class_mem_static_mw(16, 0.9, 100.0) < full);
    }
}
