//! FE engine: cycle + event model of the weight-clustered feature
//! extractor (Section IV-A).
//!
//! Mapping (Fig. 7/8): the 4x16 PE array processes 16 output channels
//! (columns) and 4 output rows in parallel; inside each PE, 3 RFs
//! accumulate 3 horizontally consecutive output pixels while the 4th RF's
//! completed window feeds the MAC — so the array retires
//! `pe_rows * 3` pixel-accumulates x 16 channels per cycle, and the MAC
//! phase is hidden by the overlap (Fig. 8c).
//!
//! Stalls: indices + codebooks stream from off-chip DRAM once per
//! (16-channel block x Ch_sub group) tile per *pass*; double-buffered
//! activations are assumed hidden. Batched training runs `batch` images
//! per tile load, amortizing the stall (Fig. 12).

use super::energy::EnergyTally;
use super::workload::ConvGeom;
use crate::config::ChipConfig;

/// Per-layer simulation result.
#[derive(Clone, Copy, Debug, Default)]
pub struct LayerReport {
    pub compute_cycles: u64,
    pub stall_cycles: u64,
    pub accum_ops: u64,
    pub mac_ops: u64,
    pub dram_bits: u64,
    pub sram_bits: u64,
}

impl LayerReport {
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.stall_cycles
    }

    pub fn utilization(&self) -> f64 {
        if self.total_cycles() == 0 {
            return 0.0;
        }
        self.compute_cycles as f64 / self.total_cycles() as f64
    }
}

/// DRAM bits deliverable per chip cycle at the configured bandwidth —
/// the reason stalls grow with frequency (Section VI-C2).
pub fn dram_bits_per_cycle(cfg: &ChipConfig) -> f64 {
    cfg.dram_gbps * 1e9 * 8.0 / (cfg.freq_mhz * 1e6)
}

/// Simulate one conv layer processed for `batch` images back-to-back
/// (batch=1 reproduces non-batched training / single-image inference).
/// Returns the report for ALL `batch` images together.
pub fn simulate_layer(
    geom: &ConvGeom,
    cfg: &ChipConfig,
    ch_sub: usize,
    n_centroids: usize,
    batch: u64,
) -> LayerReport {
    assert!(batch >= 1);
    let pixels = (geom.out * geom.out) as u64;
    let k2 = (geom.k * geom.k) as u64;
    let cin = geom.cin as u64;
    let cout = geom.cout as u64;
    let ch_sub_eff = ch_sub.min(geom.cin) as u64;
    let groups = cin.div_ceil(ch_sub_eff);

    // --- compute cycles ---
    // pixels retire in tiles of (pe_rows x 3) positions x pe_cols channels
    let pix_par = (cfg.pe_rows as u64) * 3;
    let ch_blocks = cout.div_ceil(cfg.pe_cols as u64);
    let pixel_tiles = pixels.div_ceil(pix_par);
    // every tap of every input channel streams once per (pixel tile,
    // channel block): K^2 * Cin cycles per tile position set
    let cycles_per_image = ch_blocks * pixel_tiles * k2 * cin;
    // MAC drain: N codebook MACs per (group, window) retire in parallel
    // with the next window's accumulation; only the final window of each
    // tile drains visibly.
    let drain = ch_blocks * pixel_tiles * groups * (n_centroids as u64) / 4;
    let compute_cycles = (cycles_per_image + drain) * batch;

    // --- weight/index traffic & stalls ---
    // per (channel block x group) tile: 16 channels' indices (K^2 * Ch_sub
    // weights x log2 N bits) + codebooks (16 x N x 16 bit)
    let idx_bits_tile =
        (cfg.pe_cols as u64) * k2 * ch_sub_eff * (n_centroids as f64).log2().ceil() as u64;
    let cb_bits_tile = (cfg.pe_cols as u64) * (n_centroids as u64) * 16;
    let tiles = ch_blocks * groups;
    let dram_bits = tiles * (idx_bits_tile + cb_bits_tile); // loaded once per batch
    let bits_per_cycle = dram_bits_per_cycle(cfg);
    // the index memory is single-ported per tile (Fig. 12b): the PE array
    // idles while the next tile's indices stream in — this is exactly the
    // stall batched training amortizes
    let stall_cycles = (dram_bits as f64 / bits_per_cycle).ceil() as u64;

    // --- ops & on-chip traffic (per batch of images) ---
    let accum_ops = geom.accum_ops() * batch;
    let mac_ops = pixels * cout * groups * n_centroids as u64 * batch;
    // activations: each input tap read once per (channel block); outputs
    // written once (16 bits each)
    let act_reads = ch_blocks * pixels * k2 * cin * 16;
    let out_writes = pixels * cout * 16;
    let sram_bits = (act_reads + out_writes) * batch + dram_bits; // staged via SRAM

    LayerReport {
        compute_cycles,
        stall_cycles,
        accum_ops,
        mac_ops,
        dram_bits,
        sram_bits,
    }
}

/// Simulate a whole layer table; returns (per-layer, combined tally).
pub fn simulate_model(
    layers: &[ConvGeom],
    cfg: &ChipConfig,
    ch_sub: usize,
    n_centroids: usize,
    batch: u64,
) -> (Vec<LayerReport>, EnergyTally) {
    let mut reports = Vec::with_capacity(layers.len());
    let mut tally = EnergyTally::default();
    for geom in layers {
        let r = simulate_layer(geom, cfg, ch_sub, n_centroids, batch);
        tally.pe_accum += r.accum_ops;
        tally.pe_mac += r.mac_ops;
        tally.sram_bits += r.sram_bits;
        tally.dram_bits += r.dram_bits;
        tally.active_cycles += r.compute_cycles;
        tally.total_cycles += r.total_cycles();
        reports.push(r);
    }
    (reports, tally)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::{resnet18_224, total_macs};

    fn cfg() -> ChipConfig {
        ChipConfig::default()
    }

    #[test]
    fn resnet18_latency_near_paper_35ms() {
        // Table I: 35 ms/image FSL training latency @ 250 MHz (batched);
        // non-batched carries the full per-image weight-stream stall
        let (_, t1) = simulate_model(&resnet18_224(), &cfg(), 64, 16, 1);
        let ms_nb = t1.total_cycles as f64 / (250.0 * 1e3);
        assert!((40.0..75.0).contains(&ms_nb), "non-batched ~60 ms, got {ms_nb:.1} ms");
        let (_, t5) = simulate_model(&resnet18_224(), &cfg(), 64, 16, 5);
        let ms_b = t5.total_cycles as f64 / (250.0 * 1e3) / 5.0;
        assert!((28.0..55.0).contains(&ms_b), "batched ~45 ms/image, got {ms_b:.1} ms");
    }

    #[test]
    fn accum_ops_equal_macs() {
        let layers = resnet18_224();
        let (reports, _) = simulate_model(&layers, &cfg(), 64, 16, 1);
        let accums: u64 = reports.iter().map(|r| r.accum_ops).sum();
        assert_eq!(accums, total_macs(&layers));
    }

    #[test]
    fn batching_amortizes_stalls() {
        let layers = resnet18_224();
        let (_, t1) = simulate_model(&layers, &cfg(), 64, 16, 1);
        let (_, t5) = simulate_model(&layers, &cfg(), 64, 16, 5);
        let per_img_1 = t1.total_cycles as f64;
        let per_img_5 = t5.total_cycles as f64 / 5.0;
        let saving = 1.0 - per_img_5 / per_img_1;
        assert!(saving > 0.05, "batching should save cycles, got {saving:.3}");
        // compute cycles per image identical
        assert_eq!(t5.active_cycles, t1.active_cycles * 5);
    }

    #[test]
    fn stalls_grow_with_frequency() {
        let layers = resnet18_224();
        let slow = ChipConfig { freq_mhz: 100.0, ..cfg() };
        let fast = ChipConfig { freq_mhz: 250.0, ..cfg() };
        let (_, ts) = simulate_model(&layers, &slow, 64, 16, 1);
        let (_, tf) = simulate_model(&layers, &fast, 64, 16, 1);
        let frac_s = 1.0 - ts.active_cycles as f64 / ts.total_cycles as f64;
        let frac_f = 1.0 - tf.active_cycles as f64 / tf.total_cycles as f64;
        assert!(frac_f > frac_s, "stall fraction must grow with frequency");
    }

    #[test]
    fn small_layer_underutilizes_array() {
        // 3-channel stem can't fill 16 PE columns' worth of input reuse but
        // still must round up channel blocks
        let stem = ConvGeom { cout: 8, cin: 3, k: 3, out: 8, stride: 1, stage: 0 };
        let r = simulate_layer(&stem, &cfg(), 64, 16, 1);
        assert!(r.compute_cycles > 0);
        let ideal = stem.macs().div_ceil(12 * 8);
        assert!(r.compute_cycles >= ideal);
    }

    #[test]
    fn dram_bits_independent_of_batch() {
        let l = ConvGeom { cout: 64, cin: 64, k: 3, out: 28, stride: 1, stage: 1 };
        let r1 = simulate_layer(&l, &cfg(), 64, 16, 1);
        let r4 = simulate_layer(&l, &cfg(), 64, 16, 4);
        assert_eq!(r1.dram_bits, r4.dram_bits, "weights loaded once per batch");
        assert_eq!(r4.accum_ops, 4 * r1.accum_ops);
    }
}
