//! Event-driven PE model (Fig. 8): four register files, four adders, one
//! MAC unit, with the 3-accumulate + 1-MAC rotation that overlaps the
//! codebook MAC of a finished window with the accumulation of the next.
//!
//! This is the micro-architectural validation of the analytic throughput
//! used by `fe_engine` (3 activation-accumulates per PE per cycle in
//! steady state): `pe_array::simulate_tile` steps a whole 4x16 array
//! cycle-by-cycle and the integration tests check the analytic model's
//! cycle counts against it.

/// Rotation role of one register file in a given phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RfRole {
    /// accumulating partial sums for an output pixel
    Accumulate,
    /// feeding the MAC unit with its N bins
    Draining,
    /// idle (no pixel assigned)
    Idle,
}

/// One register file: N partial-sum bins for one output pixel's window.
#[derive(Clone, Debug)]
pub struct RegFile {
    pub bins: Vec<f32>,
    pub role: RfRole,
    /// accumulate operations received for the current window
    pub accum_count: usize,
    /// window size expected (K^2 * Ch_sub taps)
    pub window_taps: usize,
    /// bins drained so far (MAC progress)
    pub drained: usize,
}

impl RegFile {
    pub fn new(n_bins: usize, window_taps: usize) -> Self {
        RegFile {
            bins: vec![0.0; n_bins],
            role: RfRole::Idle,
            accum_count: 0,
            window_taps,
            drained: 0,
        }
    }

    pub fn start_window(&mut self) {
        self.bins.iter_mut().for_each(|b| *b = 0.0);
        self.accum_count = 0;
        self.drained = 0;
        self.role = RfRole::Accumulate;
    }

    /// Accumulate one activation into bin `idx` (phase 1 of Fig. 4b).
    pub fn accumulate(&mut self, idx: usize, activation: f32) {
        debug_assert_eq!(self.role, RfRole::Accumulate);
        self.bins[idx] += activation;
        self.accum_count += 1;
    }

    pub fn window_complete(&self) -> bool {
        self.accum_count >= self.window_taps
    }

    /// One MAC-drain step: multiply the next bin by its codebook entry.
    /// Returns the partial product, and whether the drain finished.
    pub fn drain_step(&mut self, codebook: &[f32]) -> (f32, bool) {
        debug_assert_eq!(self.role, RfRole::Draining);
        let i = self.drained;
        let p = self.bins[i] * codebook[i];
        self.drained += 1;
        let done = self.drained >= self.bins.len();
        (p, done)
    }
}

/// One PE: 3 RFs accumulating 3 horizontally consecutive output pixels
/// while the 4th drains through the MAC (Fig. 8b/c).
#[derive(Clone, Debug)]
pub struct Pe {
    pub rfs: [RegFile; 4],
    /// running MAC accumulator for the draining pixel
    mac_acc: f32,
    /// finished outputs (pixel results) this PE produced
    pub outputs: Vec<f32>,
    /// cycle counters
    pub accum_ops: u64,
    pub mac_ops: u64,
    pub stall_cycles: u64,
}

impl Pe {
    pub fn new(n_bins: usize, window_taps: usize) -> Self {
        Pe {
            rfs: [
                RegFile::new(n_bins, window_taps),
                RegFile::new(n_bins, window_taps),
                RegFile::new(n_bins, window_taps),
                RegFile::new(n_bins, window_taps),
            ],
            mac_acc: 0.0,
            outputs: Vec::new(),
            accum_ops: 0,
            mac_ops: 0,
            stall_cycles: 0,
        }
    }

    /// Indices of RFs currently accumulating.
    pub fn accumulating(&self) -> Vec<usize> {
        (0..4).filter(|&i| self.rfs[i].role == RfRole::Accumulate).collect()
    }

    /// Assign a fresh window to an idle RF; returns the RF index.
    pub fn assign_window(&mut self) -> Option<usize> {
        for i in 0..4 {
            if self.rfs[i].role == RfRole::Idle {
                self.rfs[i].start_window();
                return Some(i);
            }
        }
        None
    }

    /// One cycle: up to 3 accumulates (same tap broadcast to the 3 active
    /// windows) + 1 MAC-drain step. `taps` supplies (bin index, activation)
    /// per accumulating RF.
    pub fn step(&mut self, taps: &[(usize, usize, f32)], codebook: &[f32]) {
        let mut accum_this_cycle = 0;
        for &(rf, bin, act) in taps.iter().take(3) {
            if self.rfs[rf].role == RfRole::Accumulate {
                self.rfs[rf].accumulate(bin, act);
                self.accum_ops += 1;
                accum_this_cycle += 1;
            }
        }
        if accum_this_cycle == 0 && taps.is_empty() {
            self.stall_cycles += 1;
        }
        // rotate a completed accumulation window into the drain slot if the
        // MAC is free (no RF currently draining)
        if !self.rfs.iter().any(|r| r.role == RfRole::Draining) {
            if let Some(i) = (0..4).find(|&i| {
                self.rfs[i].role == RfRole::Accumulate && self.rfs[i].window_complete()
            }) {
                self.rfs[i].role = RfRole::Draining;
                self.mac_acc = 0.0;
            }
        }
        // MAC-drain one bin per cycle
        if let Some(i) = (0..4).find(|&i| self.rfs[i].role == RfRole::Draining) {
            let (p, done) = self.rfs[i].drain_step(codebook);
            self.mac_acc += p;
            self.mac_ops += 1;
            if done {
                self.outputs.push(self.mac_acc);
                self.rfs[i].role = RfRole::Idle;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_lifecycle() {
        let mut rf = RegFile::new(4, 6);
        rf.start_window();
        for i in 0..6 {
            rf.accumulate(i % 4, 1.0);
        }
        assert!(rf.window_complete());
        rf.role = RfRole::Draining;
        let cb = [1.0, 2.0, 3.0, 4.0];
        let mut acc = 0.0;
        loop {
            let (p, done) = rf.drain_step(&cb);
            acc += p;
            if done {
                break;
            }
        }
        // bins: idx0 gets taps 0,4 -> 2.0; idx1 gets 1,5 -> 2.0; idx2,3 -> 1.0
        assert!((acc - (2.0 * 1.0 + 2.0 * 2.0 + 1.0 * 3.0 + 1.0 * 4.0)).abs() < 1e-6);
    }

    #[test]
    fn pe_produces_correct_output() {
        // single window: 2 taps into 2 bins, codebook [10, 100]
        let mut pe = Pe::new(2, 2);
        let rf = pe.assign_window().unwrap();
        pe.step(&[(rf, 0, 3.0)], &[10.0, 100.0]);
        pe.step(&[(rf, 1, 5.0)], &[10.0, 100.0]);
        // window complete; drain takes 2 more cycles
        pe.step(&[], &[10.0, 100.0]);
        pe.step(&[], &[10.0, 100.0]);
        assert_eq!(pe.outputs.len(), 1);
        assert!((pe.outputs[0] - (3.0 * 10.0 + 5.0 * 100.0)).abs() < 1e-6);
        assert_eq!(pe.accum_ops, 2);
        assert_eq!(pe.mac_ops, 2);
    }

    #[test]
    fn mac_overlaps_next_accumulation() {
        // two windows: while the first drains, the second accumulates
        let mut pe = Pe::new(2, 2);
        let a = pe.assign_window().unwrap();
        pe.step(&[(a, 0, 1.0)], &[1.0, 1.0]);
        pe.step(&[(a, 1, 1.0)], &[1.0, 1.0]);
        let b = pe.assign_window().unwrap();
        assert_ne!(a, b);
        // drain of a proceeds in the same cycles as accumulation of b
        pe.step(&[(b, 0, 2.0)], &[1.0, 1.0]);
        pe.step(&[(b, 1, 2.0)], &[1.0, 1.0]);
        assert_eq!(pe.outputs.len(), 1, "first window drained during second's accumulation");
        pe.step(&[], &[1.0, 1.0]);
        pe.step(&[], &[1.0, 1.0]);
        assert_eq!(pe.outputs.len(), 2);
        assert!((pe.outputs[1] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn three_windows_accumulate_in_parallel() {
        let mut pe = Pe::new(1, 1);
        let r0 = pe.assign_window().unwrap();
        let r1 = pe.assign_window().unwrap();
        let r2 = pe.assign_window().unwrap();
        assert_eq!(pe.accumulating().len(), 3);
        pe.step(&[(r0, 0, 1.0), (r1, 0, 2.0), (r2, 0, 3.0)], &[1.0]);
        assert_eq!(pe.accum_ops, 3);
    }
}
