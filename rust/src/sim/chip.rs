//! Whole-chip simulation: composes the FE and HDC engines into the
//! end-to-end training / inference flows the paper measures (Figs. 14–19,
//! Table I).

use super::energy::{EnergyModel, EnergyTally};
use super::fe_engine;
use super::hdc_engine;
use super::workload::{self, ConvGeom};
use crate::config::{ChipConfig, EeConfig};

/// The simulated FSL-HDnn chip.
#[derive(Clone, Debug)]
pub struct Chip {
    pub cfg: ChipConfig,
    pub energy: EnergyModel,
    /// conv layer table of the frozen FE workload
    pub layers: Vec<ConvGeom>,
    /// feature dim fed to the encoder (final stage width)
    pub feature_dim: usize,
    /// HDC dimension
    pub d: usize,
    pub ch_sub: usize,
    pub n_centroids: usize,
}

/// Result of simulating a training workload.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrainReport {
    pub images: u64,
    pub cycles: u64,
    pub fe_stall_cycles: u64,
    pub latency_ms: f64,
    pub energy_mj: f64,
    pub avg_power_mw: f64,
    /// per-image numbers (Fig. 16's y-axes)
    pub latency_ms_per_image: f64,
    pub energy_mj_per_image: f64,
    pub pe_utilization: f64,
}

/// Result of simulating inference for one image.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct InferReport {
    pub cycles: u64,
    pub latency_ms: f64,
    pub energy_mj: f64,
    /// conv layers executed (early exit skips the tail)
    pub conv_layers_run: usize,
    pub conv_layers_total: usize,
}

impl Chip {
    /// The paper's measurement configuration: ResNet-18 @ 224x224, F=512,
    /// D=4096, Ch_sub=64, N=16.
    pub fn paper(cfg: ChipConfig) -> Self {
        Chip {
            cfg,
            energy: EnergyModel::default(),
            layers: workload::resnet18_224(),
            feature_dim: 512,
            d: 4096,
            ch_sub: 64,
            n_centroids: 16,
        }
    }

    /// A chip running an arbitrary layer table (e.g. the small AOT model).
    pub fn with_layers(
        cfg: ChipConfig,
        layers: Vec<ConvGeom>,
        feature_dim: usize,
        d: usize,
    ) -> Self {
        let energy = EnergyModel::default();
        Chip { cfg, energy, layers, feature_dim, d, ch_sub: 64, n_centroids: 16 }
    }

    fn seconds(&self, cycles: u64) -> f64 {
        cycles as f64 / (self.cfg.freq_mhz * 1e6)
    }

    /// Simulate N-way k-shot single-pass training.
    ///
    /// `batched`: process the k same-class shots back-to-back through the
    /// FE with one index/codebook load (Fig. 12); otherwise each image
    /// reloads weights. Early-exit training additionally encodes + updates
    /// all 4 branch HVs per image (Section V-A); plain training encodes
    /// the final feature only.
    pub fn train_episode(
        &self,
        n_way: usize,
        k_shot: usize,
        batched: bool,
        ee_branches: bool,
    ) -> TrainReport {
        let mut tally = EnergyTally::default();
        let images = (n_way * k_shot) as u64;
        // --- FE ---
        let fe_batch = if batched { k_shot as u64 } else { 1 };
        let passes = if batched { n_way as u64 } else { images };
        let (reports, fe_tally) = fe_engine::simulate_model(
            &self.layers,
            &self.cfg,
            self.ch_sub,
            self.n_centroids,
            fe_batch,
        );
        let fe_stalls: u64 = reports.iter().map(|r| r.stall_cycles).sum::<u64>() * passes;
        tally.add(&fe_tally.scaled(passes));
        // --- HDC encode + update ---
        let n_branches = if ee_branches { 4 } else { 1 };
        for _ in 0..n_branches {
            tally.add(&hdc_engine::encode_tally(self.feature_dim, self.d).scaled(images));
        }
        // batched single-pass: aggregate k HVs then one class update per
        // class; non-batched: one update per shot — same adds, more
        // read-modify-writes
        let updates = if batched { n_way as u64 } else { images };
        let k_per_update = if batched { k_shot } else { 1 };
        for _ in 0..n_branches {
            tally.add(
                &hdc_engine::train_update_tally(self.d, k_per_update, self.cfg.hv_bits)
                    .scaled(updates),
            );
        }
        let energy_mj = self.energy.energy_mj(&tally, self.cfg.voltage);
        let latency_ms = self.seconds(tally.total_cycles) * 1e3;
        TrainReport {
            images,
            cycles: tally.total_cycles,
            fe_stall_cycles: fe_stalls,
            latency_ms,
            energy_mj,
            avg_power_mw: self.energy.avg_power_mw(&tally, self.cfg.voltage, self.cfg.freq_mhz),
            latency_ms_per_image: latency_ms / images as f64,
            energy_mj_per_image: energy_mj / images as f64,
            pe_utilization: tally.active_cycles as f64 / tally.total_cycles.max(1) as f64,
        }
    }

    /// Simulate inference of one image that exits after `exit_stage`
    /// CONV blocks (0-based; `None` = full network, no EE datapath).
    pub fn infer_image(&self, n_classes: usize, exit_stage: Option<usize>) -> InferReport {
        let (layers, checks): (Vec<ConvGeom>, usize) = match exit_stage {
            Some(s) => (workload::prefix(&self.layers, s), s + 1),
            None => (self.layers.clone(), 1),
        };
        let mut tally = EnergyTally::default();
        let (_, fe_tally) =
            fe_engine::simulate_model(&layers, &self.cfg, self.ch_sub, self.n_centroids, 1);
        tally.add(&fe_tally);
        // each confidence check = encode branch feature + distance search
        for _ in 0..checks {
            tally.add(&hdc_engine::encode_tally(self.feature_dim, self.d));
            tally.add(&hdc_engine::distance_tally(self.d, n_classes, self.cfg.hv_bits));
        }
        InferReport {
            cycles: tally.total_cycles,
            latency_ms: self.seconds(tally.total_cycles) * 1e3,
            energy_mj: self.energy.energy_mj(&tally, self.cfg.voltage),
            conv_layers_run: layers.len(),
            conv_layers_total: self.layers.len(),
        }
    }

    /// Average inference over an empirical exit-stage distribution
    /// (produced by the coordinator's EE logic on real episodes).
    pub fn infer_with_exit_distribution(
        &self,
        n_classes: usize,
        exit_stages: &[usize],
    ) -> InferReport {
        assert!(!exit_stages.is_empty());
        let mut acc = InferReport::default();
        for &s in exit_stages {
            let r = self.infer_image(n_classes, Some(s));
            acc.cycles += r.cycles;
            acc.latency_ms += r.latency_ms;
            acc.energy_mj += r.energy_mj;
            acc.conv_layers_run += r.conv_layers_run;
            acc.conv_layers_total = r.conv_layers_total;
        }
        let n = exit_stages.len() as f64;
        InferReport {
            cycles: (acc.cycles as f64 / n) as u64,
            latency_ms: acc.latency_ms / n,
            energy_mj: acc.energy_mj / n,
            conv_layers_run: (acc.conv_layers_run as f64 / n).round() as usize,
            conv_layers_total: acc.conv_layers_total,
        }
    }

    /// Peak throughput in effective GOPS (dense-equivalent ops/s): the
    /// paper counts clustered ops at their dense equivalence (Table I).
    pub fn peak_gops(&self) -> f64 {
        // per cycle: pe_rows*3*pe_cols accumulates ~= dense MACs = 2 ops,
        // scaled by the clustering op-equivalence (2K^2-1)/(K^2+N-1) ~ 2.1/2
        let dense_ops_per_cycle = (self.cfg.pe_rows * 3 * self.cfg.pe_cols) as f64 * 2.0;
        let k2 = 9.0;
        let equiv = (2.0 * k2 * self.ch_sub as f64)
            / (k2 * self.ch_sub as f64 + 2.0 * self.n_centroids as f64);
        dense_ops_per_cycle * equiv * self.cfg.freq_mhz * 1e6 / 1e9
    }

    /// Energy efficiency in TOPS/W: effective (dense-equivalent) ops
    /// retired per joule during the workload. NOTE: the paper quotes
    /// 1.4-2.9 TOPS/W; the throughput-based figure from its own Table-I
    /// numbers (197 GOPS / 305 mW = 0.65) is lower — the quoted band
    /// evidently counts reduced-precision HDC ops. We report the
    /// work-based number and document the difference in EXPERIMENTS.md.
    pub fn tops_per_watt(&self, report: &TrainReport) -> f64 {
        let total_ops = (report.images as f64)
            * (workload::total_macs(&self.layers) as f64)
            * 2.0;
        total_ops / (report.energy_mj * 1e-3) / 1e12
    }

    /// Per-exit-depth inference costs: entry *s* prices one image that
    /// exits after CONV block `s` (0-based) — the energy-per-query split
    /// by exit depth. The serving driver and `fig17_early_exit` weight
    /// this table by the coordinator's live `query_depth_hist` to price
    /// what the staged path actually executed.
    pub fn infer_depth_table(&self, n_classes: usize) -> Vec<InferReport> {
        let n_stages = self.layers.iter().map(|l| l.stage + 1).max().unwrap_or(0);
        (0..n_stages).map(|s| self.infer_image(n_classes, Some(s))).collect()
    }

    /// Check that every EE config's class HVs fit the class memory
    /// (Section V-A: 4*C*D*B bits vs 256 KB).
    pub fn ee_class_memory_fits(&self, n_classes: usize) -> bool {
        let bits = 4 * n_classes as u64 * self.d as u64 * self.cfg.hv_bits as u64;
        bits <= self.cfg.class_mem_kb as u64 * 1024 * 8
    }

    /// Exit stage implied by an (E_s, E_c) policy if predictions agree
    /// from stage `first_agree` on — pure policy arithmetic used by tests;
    /// the real decision comes from the coordinator's distance tables.
    pub fn ee_exit_stage(ee: &EeConfig, n_stages: usize, agree_from: usize) -> usize {
        let start = ee.e_s.max(1) - 1; // convert to 0-based stage
        let mut consistent = 0;
        for s in 0..n_stages {
            if s >= start && s >= agree_from {
                consistent += 1;
                if consistent >= ee.e_c {
                    return s;
                }
            }
        }
        n_stages - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chip() -> Chip {
        Chip::paper(ChipConfig::default())
    }

    #[test]
    fn training_latency_matches_table1() {
        // Table I: 35 ms/image at the fast corner (batched)
        let r = chip().train_episode(10, 5, true, false);
        assert!(
            (20.0..55.0).contains(&r.latency_ms_per_image),
            "got {} ms/image",
            r.latency_ms_per_image
        );
    }

    #[test]
    fn training_energy_close_to_6mj() {
        // 6 mJ/image at the efficiency corner (~1.0 V); allow a band
        let cfg = ChipConfig { voltage: 1.0, freq_mhz: 150.0, ..Default::default() };
        let r = Chip::paper(cfg).train_episode(10, 5, true, false);
        assert!(
            (3.0..12.0).contains(&r.energy_mj_per_image),
            "got {} mJ/image",
            r.energy_mj_per_image
        );
    }

    #[test]
    fn training_power_between_measured_corners() {
        // Fig. 14b: 59 mW (slow) .. 305 mW (fast, peak). Training-average
        // power at the fast corner must land inside the measured envelope.
        let r = chip().train_episode(10, 5, true, false);
        assert!(
            (120.0..330.0).contains(&r.avg_power_mw),
            "got {} mW",
            r.avg_power_mw
        );
        let slow = Chip::paper(ChipConfig::slow_corner()).train_episode(10, 5, true, false);
        assert!(slow.avg_power_mw < r.avg_power_mw);
        assert!(slow.avg_power_mw > 20.0, "got {} mW", slow.avg_power_mw);
    }

    #[test]
    fn batching_saves_18_to_32_percent() {
        // Fig. 16's headline: 18-32% per-image savings; assert the effect
        // exists and is material at the fast corner
        let c = chip();
        let nb = c.train_episode(10, 5, false, false);
        let b = c.train_episode(10, 5, true, false);
        let saving = 1.0 - b.latency_ms_per_image / nb.latency_ms_per_image;
        assert!(saving > 0.15, "batched saving too small: {saving:.3}");
        assert!(saving < 0.40, "batched saving implausibly large: {saving:.3}");
    }

    #[test]
    fn early_exit_reduces_latency_monotonically() {
        let c = chip();
        let full = c.infer_image(10, None);
        let mut prev = 0.0;
        for s in 0..4 {
            let r = c.infer_image(10, Some(s));
            assert!(r.latency_ms > prev);
            prev = r.latency_ms;
            if s < 3 {
                assert!(r.latency_ms < full.latency_ms);
            }
        }
    }

    #[test]
    fn depth_table_prices_each_exit_depth() {
        let c = chip();
        let table = c.infer_depth_table(10);
        assert_eq!(table.len(), 4, "ResNet-18 has 4 CONV blocks");
        for (s, r) in table.iter().enumerate() {
            assert_eq!(*r, c.infer_image(10, Some(s)), "depth {s}");
        }
        // deeper exits cost strictly more energy and layers
        for w in table.windows(2) {
            assert!(w[1].energy_mj > w[0].energy_mj);
            assert!(w[1].conv_layers_run > w[0].conv_layers_run);
        }
    }

    #[test]
    fn throughput_near_197_gops() {
        let g = chip().peak_gops();
        assert!((120.0..260.0).contains(&g), "got {g} GOPS");
    }

    #[test]
    fn tops_per_watt_in_paper_band() {
        // work-based TOPS/W lands below the paper's 1.4-2.9 quote (see
        // tops_per_watt doc); assert the plausible band and that the slow
        // corner is more efficient (matches Fig. 14b's trend)
        let fast = chip().train_episode(10, 5, true, false);
        let tw_fast = chip().tops_per_watt(&fast);
        assert!((0.2..3.5).contains(&tw_fast), "got {tw_fast} TOPS/W");
        let slow = Chip::paper(ChipConfig::slow_corner());
        let r_slow = slow.train_episode(10, 5, true, false);
        assert!(slow.tops_per_watt(&r_slow) > tw_fast, "efficiency should rise at low V");
    }

    #[test]
    fn ee_memory_capacity() {
        let c = chip();
        // 4 branches x 32 classes x 4096 x 4-bit = 256 KB exactly
        let c4 = Chip { cfg: ChipConfig { hv_bits: 4, ..ChipConfig::default() }, ..c.clone() };
        assert!(c4.ee_class_memory_fits(32));
        assert!(!c.ee_class_memory_fits(32), "16-bit HVs: only 8 classes fit with EE");
    }

    #[test]
    fn ee_exit_policy_arithmetic() {
        let ee = EeConfig { e_s: 2, e_c: 2 };
        // agreement from stage 0: checks start at stage 1; exit at stage 2
        assert_eq!(Chip::ee_exit_stage(&ee, 4, 0), 2);
        // never agrees until the last stage
        assert_eq!(Chip::ee_exit_stage(&ee, 4, 3), 3);
        let eager = EeConfig { e_s: 1, e_c: 1 };
        assert_eq!(Chip::ee_exit_stage(&eager, 4, 0), 0);
    }

    #[test]
    fn ee_training_costs_more_encodes() {
        let c = chip();
        let plain = c.train_episode(5, 5, true, false);
        let ee = c.train_episode(5, 5, true, true);
        assert!(ee.energy_mj > plain.energy_mj);
        // but FE dominates: overhead should be small (<10%)
        assert!(ee.energy_mj / plain.energy_mj < 1.10);
    }
}
