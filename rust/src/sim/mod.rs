//! Cycle-approximate simulator of the FSL-HDnn chip (Figs. 7–9, 12, 13).
//!
//! The fabricated 40 nm ASIC is not available (repro band 0), so every
//! latency/energy experiment runs on this model instead. It reproduces the
//! architecture at the level the paper's evaluation depends on:
//!
//! * 4x16 PE array, each PE with 3 accumulation RFs + 1 MAC (Fig. 8):
//!   3 activation-accumulates per PE per cycle, MAC overlapped;
//! * codebook-stationary dataflow with per-(channel-block, Ch_sub-group)
//!   index/codebook loads from off-chip DRAM — the stall source that
//!   batched training amortizes (Fig. 12);
//! * double-buffered 128 KB activation SRAM (activation loads hidden);
//! * cRP encoder at one 16x16 block/cycle, distance/update modules at one
//!   256-bit HV segment/cycle (Fig. 9);
//! * a 40 nm energy model fitted to the measured corners
//!   (59 mW @ 100 MHz/0.9 V, 305 mW @ 250 MHz/1.2 V, 6 mJ/image training).
//!
//! [`workload`] carries the ResNet-18 @ 224x224 layer table the paper
//! measures with; the simulator equally accepts the small AOT model's
//! geometry ([`crate::fe::FeModel::layer_geometries`]).
//!
//! Two abstraction levels deliberately coexist (DESIGN.md): [`fe_engine`]
//! and [`hdc_engine`] are fast *analytic* cycle/event models used by every
//! bench, while [`pe`]/[`pe_array`] step a real 4x16 array cycle by cycle
//! — the micro-architectural ground truth the analytic counts are
//! validated against (and its outputs must equal both
//! [`crate::fe::conv::clustered_conv2d`] and the packed fast kernel
//! [`crate::fe::conv::clustered_conv2d_packed`] numerically). [`energy`]
//! turns
//! event tallies into millijoules at any (V, f) point on the measured
//! curve; [`memory`] models the banked, gateable SRAMs of Fig. 7.

pub mod chip;
pub mod energy;
pub mod fe_engine;
pub mod hdc_engine;
pub mod memory;
pub mod pe;
pub mod pe_array;
pub mod workload;

pub use chip::{Chip, InferReport, TrainReport};
pub use energy::EnergyModel;
pub use workload::{resnet18_224, ConvGeom};
