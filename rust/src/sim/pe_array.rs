//! Cycle-stepped 4x16 PE-array simulation of one conv tile — the
//! micro-architectural ground truth the analytic `fe_engine` model is
//! validated against (and the numerical ground truth for the clustered
//! dataflow: the array's outputs must equal `fe::conv::clustered_conv2d`
//! *and* the packed fast kernel `fe::conv::clustered_conv2d_packed` that
//! the native FE actually executes — both cross-checks are tests here, so
//! the cycle model can never drift from the shipped numerics).
//!
//! Mapping (Section IV-A1): PE columns own output channels, the 4 PE rows
//! own 4 consecutive output rows, and each PE's 3 accumulation RFs walk 3
//! horizontally consecutive output pixels. All PEs in a column share the
//! broadcast weight index/codebook; all PEs in a row share the activation
//! stream.

use crate::fe::conv::Tensor3;
use crate::sim::pe::Pe;

/// Result of simulating one tile on the array.
#[derive(Clone, Debug)]
pub struct TileReport {
    pub cycles: u64,
    pub accum_ops: u64,
    pub mac_ops: u64,
    /// output pixel values, indexed `[pixel][channel]` for the tile
    pub outputs: Tensor3,
    pub pe_utilization: f64,
}

/// Simulate one (pixel-block x channel-block) tile of a clustered conv,
/// cycle by cycle. Geometry: `x` input (padded SAME externally is not
/// needed — we take the same padding rule as `fe::conv`), 3x3 kernel,
/// stride 1, `cout <= 16` channels, tile covers the whole (small) image.
#[allow(clippy::too_many_arguments)]
pub fn simulate_tile(
    x: &Tensor3,
    idx: &[u8],      // (cout, K*K*Cin)
    codebook: &[f32], // (cout, G*N)
    cout: usize,
    ch_sub: usize,
    n: usize,
    pe_rows: usize,
    rf_per_pe: usize, // horizontally consecutive pixels per PE (3 on chip)
) -> TileReport {
    let k = 3usize;
    let cin = x.c;
    let ch_sub = ch_sub.min(cin);
    let g = cin.div_ceil(ch_sub);
    let kkc = k * k * cin;
    assert_eq!(idx.len(), cout * kkc);
    assert_eq!(codebook.len(), cout * g * n);
    let (ho, wo) = (x.h, x.w); // stride 1 SAME

    let mut pes: Vec<Pe> = (0..cout).map(|_| Pe::new(g * n, 0)).collect();
    let mut out = Tensor3::zeros(ho, wo, cout);
    let mut cycles = 0u64;
    let mut accum_ops = 0u64;
    let mut mac_ops = 0u64;

    // process output rows in bands of pe_rows, columns in groups of
    // rf_per_pe; within a group, stream every (tap, channel) once —
    // exactly the chip's "window shifts after all channels are covered"
    for row0 in (0..ho).step_by(pe_rows) {
        for col0 in (0..wo).step_by(rf_per_pe) {
            let rows = pe_rows.min(ho - row0);
            let cols = rf_per_pe.min(wo - col0);
            // per (pe-row r, rf c): accumulate the full window, then drain
            // through the MAC; MAC overlap is modeled by charging
            // max(window_taps, N) cycles per rf *set* instead of taps + N
            for r in 0..rows {
                let oy = row0 + r;
                for c in 0..cols {
                    let ox = col0 + c;
                    // stream taps: for each (ky, kx, ci) in window order
                    for co in 0..cout {
                        let pe = &mut pes[co];
                        // direct bin accumulation (RF state reused)
                        let mut bins = vec![0f32; g * n];
                        let mut taps = 0u64;
                        for ky in 0..k {
                            let iy = oy as isize + ky as isize - 1;
                            if iy < 0 || iy >= x.h as isize {
                                continue;
                            }
                            for kx in 0..k {
                                let ix = ox as isize + kx as isize - 1;
                                if ix < 0 || ix >= x.w as isize {
                                    continue;
                                }
                                for ci in 0..cin {
                                    let widx =
                                        idx[co * kkc + ((ky * k + kx) * cin + ci)] as usize;
                                    let gi = ci / ch_sub;
                                    bins[gi * n + widx] += x.at(iy as usize, ix as usize, ci);
                                    taps += 1;
                                }
                            }
                        }
                        let cb = &codebook[co * g * n..(co + 1) * g * n];
                        let mut acc = 0f32;
                        for (b, w) in bins.iter().zip(cb) {
                            acc += b * w;
                        }
                        *out.at_mut(oy, ox, co) = acc;
                        pe.accum_ops += taps;
                        pe.mac_ops += (g * n) as u64;
                        accum_ops += taps;
                        mac_ops += (g * n) as u64;
                    }
                }
            }
            // cycle accounting for this (rows x cols) position set:
            // the array streams K^2*Cin taps once per column group, the 3
            // RFs retire `cols` pixels in parallel per row band; the MAC
            // drain (g*n cycles) hides under the next window unless it is
            // longer than the window stream (Fig. 8c)
            let window_taps = (k * k * cin) as u64;
            let drain = (g * n) as u64;
            let stream = window_taps.max(drain / rf_per_pe as u64);
            cycles += stream;
        }
    }
    // final drain that cannot overlap anything
    cycles += (g * n) as u64;

    let active = accum_ops.max(1);
    let capacity = cycles * (pe_rows * rf_per_pe * cout.min(16)) as u64;
    TileReport {
        cycles,
        accum_ops,
        mac_ops,
        outputs: out,
        pe_utilization: active as f64 / capacity.max(1) as f64,
    }
    .tap_pes(&pes)
}

impl TileReport {
    fn tap_pes(self, _pes: &[Pe]) -> Self {
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fe::conv::clustered_conv2d;
    use crate::fe::kmeans::cluster_layer;
    use crate::util::prng::Rng;

    fn setup(
        seed: u64,
        cin: usize,
        cout: usize,
        hw: usize,
    ) -> (Tensor3, Vec<u8>, Vec<f32>, usize, usize) {
        let mut rng = Rng::new(seed);
        let (ch_sub, n) = (cin.min(64), 8);
        let w: Vec<f32> = (0..cout * 9 * cin).map(|_| rng.gauss_f32()).collect();
        let cl = cluster_layer(&w, cout, 3, cin, ch_sub, n);
        let x =
            Tensor3::from_vec(hw, hw, cin, (0..hw * hw * cin).map(|_| rng.gauss_f32()).collect());
        (x, cl.idx, cl.codebook, ch_sub, n)
    }

    #[test]
    fn array_outputs_equal_clustered_conv() {
        let (x, idx, cb, ch_sub, n) = setup(1, 4, 6, 8);
        let rep = simulate_tile(&x, &idx, &cb, 6, ch_sub, n, 4, 3);
        let want = clustered_conv2d(&x, &idx, &cb, 6, 3, 1, ch_sub, n);
        assert_eq!(rep.outputs.data.len(), want.data.len());
        for (a, b) in rep.outputs.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn array_outputs_equal_packed_fast_kernel() {
        // the cycle model vs the kernel the native FE actually runs
        use crate::fe::conv::{clustered_conv2d_packed, PackedIdx};
        let (x, idx, cb, ch_sub, n) = setup(5, 4, 6, 8);
        let rep = simulate_tile(&x, &idx, &cb, 6, ch_sub, n, 4, 3);
        let pidx = PackedIdx::pack(&idx, 6, 3, 4, ch_sub, n);
        let fast = clustered_conv2d_packed(&x, &pidx, &cb, 1);
        assert_eq!(rep.outputs.data.len(), fast.data.len());
        for (a, b) in rep.outputs.data.iter().zip(&fast.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn op_counts_match_dense_taps() {
        let (x, idx, cb, ch_sub, n) = setup(2, 4, 4, 6);
        let rep = simulate_tile(&x, &idx, &cb, 4, ch_sub, n, 4, 3);
        // interior taps only (SAME padding skips border taps)
        assert!(rep.accum_ops > 0);
        let upper = (6 * 6 * 9 * 4 * 4) as u64;
        assert!(rep.accum_ops <= upper);
        assert_eq!(rep.mac_ops, (6 * 6 * 4) as u64 * (cb.len() / 4) as u64);
    }

    #[test]
    fn cycles_close_to_analytic_model() {
        // the analytic model says: cycles ~ ch_blocks * pixel_tiles * K^2 * Cin
        let (x, idx, cb, ch_sub, n) = setup(3, 8, 16, 12);
        let rep = simulate_tile(&x, &idx, &cb, 16, ch_sub, n, 4, 3);
        let pixel_tiles = (12f64 / 4.0).ceil() * (12f64 / 3.0).ceil();
        let analytic = pixel_tiles * (9 * 8) as f64;
        let ratio = rep.cycles as f64 / analytic;
        assert!(
            (0.8..1.4).contains(&ratio),
            "event-driven {} vs analytic {analytic} (ratio {ratio:.2})",
            rep.cycles
        );
    }

    #[test]
    fn utilization_reasonable() {
        let (x, idx, cb, ch_sub, n) = setup(4, 8, 16, 12);
        let rep = simulate_tile(&x, &idx, &cb, 16, ch_sub, n, 4, 3);
        assert!(rep.pe_utilization > 0.3, "util {}", rep.pe_utilization);
        assert!(rep.pe_utilization <= 1.0);
    }
}
