//! Trace-driven workloads: Poisson request arrivals over few-shot
//! sessions, plus SLO accounting — the serving-side evaluation harness
//! (edge devices see bursty personalize-then-query traffic, not batch
//! sweeps).

use crate::util::prng::Rng;
use crate::util::stats;

/// One timed event in a workload trace.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceOp {
    /// open a new N-way session
    NewSession { n_way: usize },
    /// labeled shot for an open session (indices into the open-session list)
    Shot { session_slot: usize, class: usize },
    /// finish training an open session
    Train { session_slot: usize },
    /// query against a trained session
    Query { session_slot: usize, class: usize },
}

/// (arrival time in seconds, operation)
pub type TraceEvent = (f64, TraceOp);

/// Poisson-arrival trace generator: sessions open at `session_rate` Hz;
/// each runs shots -> train -> queries with exponential gaps at `op_rate`.
#[derive(Clone, Debug)]
pub struct TraceGen {
    pub n_way: usize,
    pub k_shot: usize,
    pub queries_per_session: usize,
    pub session_rate_hz: f64,
    pub op_rate_hz: f64,
}

impl Default for TraceGen {
    fn default() -> Self {
        TraceGen {
            n_way: 5,
            k_shot: 5,
            queries_per_session: 20,
            session_rate_hz: 0.5,
            op_rate_hz: 50.0,
        }
    }
}

impl TraceGen {
    fn exp(&self, rate: f64, rng: &mut Rng) -> f64 {
        -(1.0 - rng.uniform()).ln() / rate
    }

    /// Generate a trace of `n_sessions` session lifecycles, sorted by time.
    pub fn generate(&self, n_sessions: usize, rng: &mut Rng) -> Vec<TraceEvent> {
        let mut events = Vec::new();
        let mut session_t = 0.0f64;
        for slot in 0..n_sessions {
            session_t += self.exp(self.session_rate_hz, rng);
            let mut t = session_t;
            events.push((t, TraceOp::NewSession { n_way: self.n_way }));
            // shots arrive class-grouped (user labels one class at a time)
            for class in 0..self.n_way {
                for _ in 0..self.k_shot {
                    t += self.exp(self.op_rate_hz, rng);
                    events.push((t, TraceOp::Shot { session_slot: slot, class }));
                }
            }
            t += self.exp(self.op_rate_hz, rng);
            events.push((t, TraceOp::Train { session_slot: slot }));
            for q in 0..self.queries_per_session {
                t += self.exp(self.op_rate_hz, rng);
                events.push((t, TraceOp::Query { session_slot: slot, class: q % self.n_way }));
            }
        }
        // total_cmp: replaying a trace with a non-finite timestamp must
        // not panic the sort (NaNs order after +inf and stay at the tail)
        events.sort_by(|a, b| a.0.total_cmp(&b.0));
        events
    }
}

/// SLO accounting over measured (latency_ms, deadline_ms) pairs.
#[derive(Clone, Debug, Default)]
pub struct SloReport {
    pub latencies_ms: Vec<f64>,
    pub deadline_ms: f64,
}

impl SloReport {
    pub fn new(deadline_ms: f64) -> Self {
        SloReport { latencies_ms: Vec::new(), deadline_ms }
    }

    pub fn record(&mut self, latency_ms: f64) {
        self.latencies_ms.push(latency_ms);
    }

    pub fn attainment(&self) -> f64 {
        if self.latencies_ms.is_empty() {
            return 1.0;
        }
        self.latencies_ms.iter().filter(|&&l| l <= self.deadline_ms).count() as f64
            / self.latencies_ms.len() as f64
    }

    pub fn p50(&self) -> f64 {
        stats::percentile(&self.latencies_ms, 50.0)
    }

    pub fn p99(&self) -> f64 {
        stats::percentile(&self.latencies_ms, 99.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_time_ordered_and_complete() {
        let gen = TraceGen::default();
        let mut rng = Rng::new(1);
        let trace = gen.generate(3, &mut rng);
        let expected = 3 * (1 + gen.n_way * gen.k_shot + 1 + gen.queries_per_session);
        assert_eq!(trace.len(), expected);
        for w in trace.windows(2) {
            assert!(w[0].0 <= w[1].0, "trace not sorted");
        }
    }

    #[test]
    fn per_session_causality() {
        // within a slot: NewSession < all Shots < Train < all Queries
        let gen = TraceGen::default();
        let mut rng = Rng::new(2);
        let trace = gen.generate(4, &mut rng);
        for slot in 0..4 {
            let mut t_new = f64::NAN;
            let mut t_train = f64::NAN;
            let mut last_shot: f64 = 0.0;
            let mut first_query = f64::INFINITY;
            for (t, op) in &trace {
                match op {
                    TraceOp::NewSession { .. } => {
                        if t_new.is_nan() {
                            // NewSession events are per slot in order
                        }
                        let _ = &mut t_new;
                    }
                    TraceOp::Shot { session_slot, .. } if *session_slot == slot => {
                        last_shot = last_shot.max(*t);
                    }
                    TraceOp::Train { session_slot } if *session_slot == slot => t_train = *t,
                    TraceOp::Query { session_slot, .. } if *session_slot == slot => {
                        first_query = first_query.min(*t);
                    }
                    _ => {}
                }
            }
            assert!(last_shot < t_train, "slot {slot}: shot after train");
            assert!(t_train < first_query, "slot {slot}: query before train");
        }
    }

    #[test]
    fn arrival_rate_roughly_matches() {
        let gen = TraceGen { session_rate_hz: 2.0, ..Default::default() };
        let mut rng = Rng::new(3);
        let trace = gen.generate(40, &mut rng);
        let t_last_session = trace
            .iter()
            .filter(|(_, op)| matches!(op, TraceOp::NewSession { .. }))
            .map(|(t, _)| *t)
            .fold(0.0, f64::max);
        let rate = 40.0 / t_last_session;
        assert!((1.0..4.0).contains(&rate), "empirical session rate {rate}");
    }

    #[test]
    fn slo_accounting() {
        let mut slo = SloReport::new(10.0);
        for l in [1.0, 5.0, 9.0, 11.0, 20.0] {
            slo.record(l);
        }
        assert!((slo.attainment() - 0.6).abs() < 1e-9);
        assert_eq!(slo.p50(), 9.0);
        assert!(slo.p99() > 19.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = TraceGen::default();
        let a = gen.generate(2, &mut Rng::new(7));
        let b = gen.generate(2, &mut Rng::new(7));
        assert_eq!(a, b);
    }
}
