//! Synthetic embedding-space datasets with per-dataset difficulty presets.
//!
//! Class geometry model: each class is a Gaussian cluster around a
//! prototype on a scaled hypersphere, with
//!   * anisotropic within-class covariance (a few high-variance directions
//!     shared across classes — the "nuisance subspace" real embeddings
//!     have), and
//!   * heavy-tailed shot noise (student-t) producing the outlier support
//!     samples that hurt kNN far more than centroid-based HDC.
//!
//! Presets are calibrated so 5-way 5-shot accuracy ordering and gaps match
//! Fig. 15: flower102 (easy, ~94%), trafficsign (medium, ~78%, largest
//! kNN gap), cifar100 (hard, ~72%).

use crate::util::prng::Rng;

/// Difficulty preset mirroring one of the paper's evaluation datasets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetPreset {
    Cifar100,
    Flower102,
    TrafficSign,
}

impl DatasetPreset {
    pub fn from_name(name: &str) -> anyhow::Result<Self> {
        match name.to_ascii_lowercase().as_str() {
            "cifar100" => Ok(DatasetPreset::Cifar100),
            "flower102" => Ok(DatasetPreset::Flower102),
            "trafficsign" | "traffic-sign" | "traffic_sign" => Ok(DatasetPreset::TrafficSign),
            other => anyhow::bail!("unknown dataset preset: {other}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetPreset::Cifar100 => "cifar100",
            DatasetPreset::Flower102 => "flower102",
            DatasetPreset::TrafficSign => "trafficsign",
        }
    }

    /// Number of classes in the underlying pool.
    pub fn n_classes(&self) -> usize {
        match self {
            DatasetPreset::Cifar100 => 100,
            DatasetPreset::Flower102 => 102,
            DatasetPreset::TrafficSign => 43,
        }
    }

    /// (proto_scale, within_noise, nuisance_scale, tail_df, outlier_rate)
    fn params(&self) -> (f32, f32, f32, f64, f64) {
        match self {
            // hard: small separation, strong shared nuisance directions
            // (calibrated to ~72% HDC accuracy at 5-way 5-shot, Fig. 15)
            DatasetPreset::Cifar100 => (1.0, 1.42, 1.5, 7.0, 0.05),
            // easy: well-separated prototypes, light noise (~94%)
            DatasetPreset::Flower102 => (1.0, 1.15, 0.8, 12.0, 0.03),
            // medium separation, heavy tails + many outlier shots: the
            // preset where 1-NN suffers most (~78%, largest kNN gap)
            DatasetPreset::TrafficSign => (1.0, 0.95, 1.7, 8.0, 0.10),
        }
    }
}

/// Generator of class-conditional feature vectors in R^F.
#[derive(Clone, Debug)]
pub struct SyntheticDataset {
    pub preset: DatasetPreset,
    pub feature_dim: usize,
    /// class prototypes (n_classes x F)
    protos: Vec<Vec<f32>>,
    /// shared nuisance directions (r x F, orthogonalized)
    nuisance: Vec<Vec<f32>>,
    within_noise: f32,
    nuisance_scale: f32,
    tail_df: f64,
    outlier_rate: f64,
}

impl SyntheticDataset {
    pub fn new(preset: DatasetPreset, feature_dim: usize, seed: u64) -> Self {
        let (proto_scale, within_noise, nuisance_scale, tail_df, outlier_rate) = preset.params();
        let mut rng = Rng::new(seed ^ 0xD47A_5E7);
        let n = preset.n_classes();
        // prototypes: unit-norm gaussian directions * sqrt(F) * scale
        let protos: Vec<Vec<f32>> = (0..n)
            .map(|_| {
                let mut v: Vec<f32> = (0..feature_dim).map(|_| rng.gauss_f32()).collect();
                let norm = (v.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-6);
                let s = proto_scale * (feature_dim as f32).sqrt() / norm;
                v.iter_mut().for_each(|x| *x *= s);
                v
            })
            .collect();
        // a small shared nuisance subspace (Gram-Schmidt over 8 directions)
        let r = 8.min(feature_dim);
        let mut nuisance: Vec<Vec<f32>> = Vec::with_capacity(r);
        for _ in 0..r {
            let mut v: Vec<f32> = (0..feature_dim).map(|_| rng.gauss_f32()).collect();
            for u in &nuisance {
                let d: f32 = v.iter().zip(u).map(|(a, b)| a * b).sum();
                v.iter_mut().zip(u).for_each(|(a, b)| *a -= d * b);
            }
            let norm = (v.iter().map(|x| x * x).sum::<f32>()).sqrt().max(1e-6);
            v.iter_mut().for_each(|x| *x /= norm);
            nuisance.push(v);
        }
        SyntheticDataset {
            preset,
            feature_dim,
            protos,
            nuisance,
            within_noise,
            nuisance_scale,
            tail_df,
            outlier_rate,
        }
    }

    pub fn n_classes(&self) -> usize {
        self.protos.len()
    }

    /// Sample one feature vector of class `class`.
    pub fn sample(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        let proto = &self.protos[class];
        let f = self.feature_dim;
        let outlier = rng.uniform() < self.outlier_rate;
        let noise_scale = if outlier { 3.0 * self.within_noise } else { self.within_noise };
        let mut x: Vec<f32> = (0..f)
            .map(|i| {
                let t = rng.heavy_tail(self.tail_df) as f32;
                proto[i] + noise_scale * t
            })
            .collect();
        // shared nuisance wander: same directions for every class
        for u in &self.nuisance {
            let a = self.nuisance_scale * (f as f32).sqrt() * rng.gauss_f32() * 0.35;
            x.iter_mut().zip(u).for_each(|(xi, ui)| *xi += a * ui);
        }
        // embeddings from a ReLU network are non-negative-ish: softplus-like
        // clamp keeps the marginal distribution realistic
        for v in x.iter_mut() {
            if *v < 0.0 {
                *v *= 0.25;
            }
        }
        x
    }

    /// Sample `count` features for a class.
    pub fn sample_n(&self, class: usize, count: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        (0..count).map(|_| self.sample(class, rng)).collect()
    }

    /// Per-branch SNR profile: how much class signal each CONV block's
    /// branch feature carries. Shallow features are less discriminative —
    /// the property the early-exit confidence check exploits (Fig. 11/17).
    pub const BRANCH_SNR: [f32; 4] = [0.40, 0.62, 0.85, 1.0];

    /// Sample the 4 branch features of one input (Fig. 11): branch b mixes
    /// `BRANCH_SNR[b]` of the class sample with extra depth-dependent noise,
    /// correlated across branches (they come from the same image).
    pub fn sample_branches(&self, class: usize, rng: &mut Rng) -> Vec<Vec<f32>> {
        let base = self.sample(class, rng);
        Self::BRANCH_SNR
            .iter()
            .map(|&snr| {
                base.iter()
                    .map(|&v| snr * v + (1.0 - snr) * 1.2 * rng.gauss_f32())
                    .collect()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        assert_eq!(DatasetPreset::from_name("cifar100").unwrap(), DatasetPreset::Cifar100);
        assert_eq!(DatasetPreset::from_name("Traffic-Sign").unwrap(), DatasetPreset::TrafficSign);
        assert!(DatasetPreset::from_name("imagenet").is_err());
    }

    #[test]
    fn deterministic_given_seed() {
        let ds1 = SyntheticDataset::new(DatasetPreset::Cifar100, 64, 7);
        let ds2 = SyntheticDataset::new(DatasetPreset::Cifar100, 64, 7);
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        assert_eq!(ds1.sample(3, &mut r1), ds2.sample(3, &mut r2));
    }

    #[test]
    fn class_clusters_are_separable_in_expectation() {
        let ds = SyntheticDataset::new(DatasetPreset::Flower102, 128, 3);
        let mut rng = Rng::new(2);
        // distance to own prototype should be below distance to another's
        let own = ds.sample_n(0, 20, &mut rng);
        let other_proto = &ds.protos[1];
        let own_proto = &ds.protos[0];
        let mut closer = 0;
        for x in &own {
            let d_own: f32 = x.iter().zip(own_proto).map(|(a, b)| (a - b).powi(2)).sum();
            let d_oth: f32 = x.iter().zip(other_proto).map(|(a, b)| (a - b).powi(2)).sum();
            if d_own < d_oth {
                closer += 1;
            }
        }
        assert!(closer >= 16, "only {closer}/20 samples closer to own prototype");
    }

    #[test]
    fn harder_preset_has_more_overlap() {
        // cifar100 within-class scatter (relative to prototype distance)
        // should exceed flower102's
        fn scatter_ratio(preset: DatasetPreset) -> f64 {
            let ds = SyntheticDataset::new(preset, 128, 11);
            let mut rng = Rng::new(5);
            let xs = ds.sample_n(0, 30, &mut rng);
            let proto = &ds.protos[0];
            let within: f64 = xs
                .iter()
                .map(|x| {
                    x.iter().zip(proto).map(|(a, b)| ((a - b) * (a - b)) as f64).sum::<f64>()
                })
                .sum::<f64>()
                / 30.0;
            let between: f64 = proto
                .iter()
                .zip(&ds.protos[1])
                .map(|(a, b)| ((a - b) * (a - b)) as f64)
                .sum();
            within / between
        }
        assert!(scatter_ratio(DatasetPreset::Cifar100) > scatter_ratio(DatasetPreset::Flower102));
    }

    #[test]
    fn pool_sizes_match_paper() {
        assert_eq!(DatasetPreset::Cifar100.n_classes(), 100);
        assert_eq!(DatasetPreset::Flower102.n_classes(), 102);
        assert_eq!(DatasetPreset::TrafficSign.n_classes(), 43);
    }
}
