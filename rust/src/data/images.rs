//! Procedural class-structured images for the conv/PJRT path.
//!
//! Each class is a texture family (oriented sinusoid + Gaussian blob +
//! color tint parameterized by the class id); samples within a class vary
//! phase, position and noise. The point is not visual realism but a
//! *class-conditional image distribution* whose CNN features cluster, so
//! the end-to-end FE -> cRP -> HDC pipeline can be exercised on real conv
//! compute through the AOT artifacts.

use crate::util::prng::Rng;

/// Procedural image generator: HxWx3 f32 (NHWC flattening).
#[derive(Clone, Debug)]
pub struct ImageGen {
    pub size: usize,
    pub n_classes: usize,
    seed: u64,
}

impl ImageGen {
    pub fn new(size: usize, n_classes: usize, seed: u64) -> Self {
        ImageGen { size, n_classes, seed }
    }

    /// Deterministic per-class texture parameters.
    fn class_params(&self, class: usize) -> (f32, f32, [f32; 3], f32) {
        let mut r = Rng::new(self.seed ^ (class as u64).wrapping_mul(0x9E37_79B9));
        let angle = r.range_f32(0.0, std::f32::consts::PI);
        let freq = r.range_f32(0.15, 0.8);
        let tint = [r.range_f32(0.2, 1.0), r.range_f32(0.2, 1.0), r.range_f32(0.2, 1.0)];
        let blob_scale = r.range_f32(0.15, 0.4);
        (angle, freq, tint, blob_scale)
    }

    /// Sample one image of `class` into a flat vec (H*W*3, NHWC order).
    pub fn sample(&self, class: usize, rng: &mut Rng) -> Vec<f32> {
        assert!(class < self.n_classes);
        let (angle, freq, tint, blob_scale) = self.class_params(class);
        let n = self.size;
        let phase = rng.range_f32(0.0, std::f32::consts::TAU);
        let cx = rng.range_f32(0.25, 0.75) * n as f32;
        let cy = rng.range_f32(0.25, 0.75) * n as f32;
        let sigma = blob_scale * n as f32;
        let (sa, ca) = angle.sin_cos();
        let mut out = Vec::with_capacity(n * n * 3);
        for y in 0..n {
            for x in 0..n {
                let u = ca * x as f32 + sa * y as f32;
                let stripe = (freq * u + phase).sin();
                let dx = x as f32 - cx;
                let dy = y as f32 - cy;
                let blob = (-(dx * dx + dy * dy) / (2.0 * sigma * sigma)).exp();
                let base = 0.6 * stripe + 0.8 * blob;
                for t in tint {
                    let noise = 0.15 * rng.gauss_f32();
                    out.push(t * base + noise);
                }
            }
        }
        out
    }

    /// Sample a batch: (count x H*W*3) flattened consecutively.
    pub fn sample_batch(&self, class: usize, count: usize, rng: &mut Rng) -> Vec<f32> {
        let mut out = Vec::with_capacity(count * self.size * self.size * 3);
        for _ in 0..count {
            out.extend(self.sample(class, rng));
        }
        out
    }

    pub fn pixels_per_image(&self) -> usize {
        self.size * self.size * 3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_shape_and_range() {
        let gen = ImageGen::new(16, 4, 1);
        let mut rng = Rng::new(1);
        let img = gen.sample(0, &mut rng);
        assert_eq!(img.len(), 16 * 16 * 3);
        assert!(img.iter().all(|v| v.is_finite()));
        let m = img.iter().map(|v| v.abs()).fold(0f32, f32::max);
        assert!(m < 10.0, "pixels should be O(1), got {m}");
    }

    #[test]
    fn classes_have_distinct_textures() {
        let gen = ImageGen::new(16, 8, 2);
        let mut rng = Rng::new(3);
        // average over several samples: within-class mean image correlates
        // more than across-class
        let avg = |cls: usize, rng: &mut Rng| -> Vec<f32> {
            let mut acc = vec![0f32; 16 * 16 * 3];
            for _ in 0..6 {
                for (a, v) in acc.iter_mut().zip(gen.sample(cls, rng)) {
                    *a += v / 6.0;
                }
            }
            acc
        };
        let a1 = avg(0, &mut rng);
        let a2 = avg(0, &mut rng);
        let b = avg(1, &mut rng);
        let corr = |x: &[f32], y: &[f32]| {
            let mx = x.iter().sum::<f32>() / x.len() as f32;
            let my = y.iter().sum::<f32>() / y.len() as f32;
            let num: f32 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
            let dx: f32 = x.iter().map(|a| (a - mx) * (a - mx)).sum::<f32>().sqrt();
            let dy: f32 = y.iter().map(|a| (a - my) * (a - my)).sum::<f32>().sqrt();
            num / (dx * dy).max(1e-9)
        };
        assert!(corr(&a1, &a2) > corr(&a1, &b), "within-class corr should dominate");
    }

    #[test]
    fn batch_is_concatenation_sized() {
        let gen = ImageGen::new(8, 2, 5);
        let mut rng = Rng::new(1);
        let b = gen.sample_batch(1, 3, &mut rng);
        assert_eq!(b.len(), 3 * gen.pixels_per_image());
    }
}
