//! N-way k-shot episode sampling — the paper's FSL protocol (footnote 1):
//! an episode draws N unseen classes, k labeled support samples per class
//! and a query set to evaluate on.

use super::synth::SyntheticDataset;
use crate::util::prng::Rng;

/// One few-shot episode over feature vectors.
#[derive(Clone, Debug)]
pub struct Episode {
    pub n_way: usize,
    pub k_shot: usize,
    /// `support[c]` = k feature vectors for episode-class c
    pub support: Vec<Vec<Vec<f32>>>,
    /// (feature, episode-class label)
    pub queries: Vec<(Vec<f32>, usize)>,
    /// which pool classes were drawn (for image regeneration)
    pub pool_classes: Vec<usize>,
}

/// Samples episodes from a synthetic dataset.
#[derive(Clone, Debug)]
pub struct EpisodeSampler {
    pub dataset: SyntheticDataset,
    pub n_way: usize,
    pub k_shot: usize,
    pub queries_per_class: usize,
}

impl EpisodeSampler {
    pub fn new(
        dataset: SyntheticDataset,
        n_way: usize,
        k_shot: usize,
        queries_per_class: usize,
    ) -> Self {
        assert!(n_way <= dataset.n_classes(), "n_way exceeds class pool");
        EpisodeSampler { dataset, n_way, k_shot, queries_per_class }
    }

    pub fn sample(&self, rng: &mut Rng) -> Episode {
        let pool_classes = rng.choose_k(self.dataset.n_classes(), self.n_way);
        let mut support = Vec::with_capacity(self.n_way);
        let mut queries = Vec::new();
        for (label, &pc) in pool_classes.iter().enumerate() {
            support.push(self.dataset.sample_n(pc, self.k_shot, rng));
            for _ in 0..self.queries_per_class {
                queries.push((self.dataset.sample(pc, rng), label));
            }
        }
        rng.shuffle(&mut queries);
        Episode { n_way: self.n_way, k_shot: self.k_shot, support, queries, pool_classes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::DatasetPreset;

    fn sampler() -> EpisodeSampler {
        let ds = SyntheticDataset::new(DatasetPreset::Cifar100, 32, 1);
        EpisodeSampler::new(ds, 5, 3, 4)
    }

    #[test]
    fn episode_shape() {
        let mut rng = Rng::new(1);
        let ep = sampler().sample(&mut rng);
        assert_eq!(ep.support.len(), 5);
        assert!(ep.support.iter().all(|s| s.len() == 3));
        assert_eq!(ep.queries.len(), 20);
        assert!(ep.queries.iter().all(|(_, l)| *l < 5));
        assert_eq!(ep.pool_classes.len(), 5);
        let mut pc = ep.pool_classes.clone();
        pc.sort_unstable();
        pc.dedup();
        assert_eq!(pc.len(), 5, "episode classes must be distinct");
    }

    #[test]
    fn labels_balanced() {
        let mut rng = Rng::new(2);
        let ep = sampler().sample(&mut rng);
        let mut counts = [0usize; 5];
        for (_, l) in &ep.queries {
            counts[*l] += 1;
        }
        assert!(counts.iter().all(|&c| c == 4));
    }

    #[test]
    fn episodes_differ() {
        let mut rng = Rng::new(3);
        let s = sampler();
        let a = s.sample(&mut rng);
        let b = s.sample(&mut rng);
        assert!(a.pool_classes != b.pool_classes || a.queries[0].0 != b.queries[0].0);
    }

    #[test]
    #[should_panic(expected = "n_way exceeds class pool")]
    fn n_way_bounds() {
        let ds = SyntheticDataset::new(DatasetPreset::TrafficSign, 16, 1);
        EpisodeSampler::new(ds, 100, 1, 1);
    }
}
