//! Synthetic few-shot data substrates.
//!
//! The paper evaluates on CIFAR-100 / Flower102 / Traffic-sign features
//! from an ImageNet-pretrained ResNet-18 — neither the datasets nor the
//! pretrained weights are available here (repro band 0), so `synth`
//! generates embedding-space class clusters whose difficulty presets are
//! calibrated to the paper's accuracy bands, and `images` generates
//! procedural class-structured images for the conv/PJRT path
//! (substitution table in DESIGN.md).

pub mod episodes;
pub mod images;
pub mod synth;
pub mod trace;

pub use episodes::{Episode, EpisodeSampler};
pub use synth::{DatasetPreset, SyntheticDataset};
