//! Artifact registry: manifest-driven loading, one-time compilation and
//! typed execution of the `artifacts/*.hlo.txt` modules.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos that jax >= 0.5
//! serializes and xla_extension 0.5.1 rejects (see DESIGN.md).
//!
//! The actual PJRT client lives behind the `pjrt` cargo feature because the
//! xla-rs bindings are not in the offline vendored registry (DESIGN.md
//! §PJRT gating). Without the feature this module still parses manifests
//! and reports signatures — only [`ArtifactRegistry::exec_f32`] is
//! unavailable, and it fails with a descriptive error instead of linking
//! against a crate the build cannot resolve.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Compiled-executable cache entry. With the `pjrt` feature this is the
/// loaded PJRT executable; without it the cache stays empty forever.
#[cfg(feature = "pjrt")]
type Executable = xla::PjRtLoadedExecutable;
#[cfg(not(feature = "pjrt"))]
type Executable = ();

/// Shape+dtype signature of one artifact entry.
#[derive(Clone, Debug)]
pub struct EntrySig {
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Loaded registry: manifest signatures plus (with the `pjrt` feature) a
/// PJRT client and lazily compiled executables.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub model: ModelConfig,
    entries: HashMap<String, EntrySig>,
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, Executable>>,
}

impl std::fmt::Debug for ArtifactRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactRegistry")
            .field("dir", &self.dir)
            .field("entries", &self.entries.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl ArtifactRegistry {
    /// True when this build can execute artifacts (the `pjrt` feature is
    /// enabled). Callers use this to skip rather than fail — see
    /// `rust/tests/integration_pjrt.rs`.
    pub fn pjrt_available() -> bool {
        cfg!(feature = "pjrt")
    }

    /// Open `artifacts/` (parses manifest and, with the `pjrt` feature,
    /// creates the PJRT CPU client; compilation happens on first use of
    /// each entry).
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let man_text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!("cannot read manifest.json in {dir:?}: {e} — run `make artifacts`")
        })?;
        let man = Json::parse(&man_text)?;
        let model = ModelConfig::from_manifest(&man)?;
        let mut entries = HashMap::new();
        for e in man
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
        {
            let name = e.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let file = e.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                e.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|s| s.get("shape").and_then(|x| x.as_usize_vec()))
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.insert(
                name,
                EntrySig { file, input_shapes: shapes("inputs"), output_shapes: shapes("outputs") },
            );
        }
        #[cfg(feature = "pjrt")]
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            model,
            entries,
            #[cfg(feature = "pjrt")]
            client,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn entry_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn signature(&self, name: &str) -> Option<&EntrySig> {
        self.entries.get(name)
    }

    /// Compile (once) and cache an entry.
    #[cfg(feature = "pjrt")]
    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        let mut compiled = self.compiled.lock().unwrap();
        if compiled.contains_key(name) {
            return Ok(());
        }
        let sig = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact entry: {name}"))?;
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Validate `inputs` against the manifest signature of `name`.
    fn validate(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> anyhow::Result<()> {
        let sig = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact entry: {name}"))?;
        anyhow::ensure!(
            inputs.len() == sig.input_shapes.len(),
            "{name}: expected {} inputs, got {}",
            sig.input_shapes.len(),
            inputs.len()
        );
        for (i, ((data, dims), want)) in inputs.iter().zip(&sig.input_shapes).enumerate() {
            anyhow::ensure!(
                *dims == want.as_slice(),
                "{name}: input {i} shape {dims:?} != manifest {want:?}"
            );
            let n: usize = dims.iter().product();
            anyhow::ensure!(data.len() == n, "{name}: input {i} data len {} != {n}", data.len());
        }
        Ok(())
    }

    /// Execute an entry on f32 inputs; inputs are (data, dims) pairs that
    /// must match the manifest signature. Returns flattened f32 outputs.
    ///
    /// Without the `pjrt` feature, input validation still runs (shape
    /// errors are reported the same way) but execution fails with a
    /// descriptive "built without PJRT support" error.
    #[cfg(feature = "pjrt")]
    pub fn exec_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        self.validate(name, inputs)?;
        self.ensure_compiled(name)?;
        let compiled = self.compiled.lock().unwrap();
        let exe = compiled.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// See the `pjrt`-enabled variant: this build validates, then reports
    /// that execution is unavailable.
    #[cfg(not(feature = "pjrt"))]
    pub fn exec_f32(
        &self,
        name: &str,
        inputs: &[(&[f32], &[usize])],
    ) -> anyhow::Result<Vec<Vec<f32>>> {
        self.validate(name, inputs)?;
        anyhow::bail!(
            "cannot execute artifact {name}: built without PJRT support \
             (enable the `pjrt` cargo feature and vendor xla-rs — see DESIGN.md §PJRT gating)"
        )
    }

    /// Number of compiled (cached) executables — used by tests/metrics.
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_missing_dir_mentions_make_artifacts() {
        let err = ArtifactRegistry::open(Path::new("definitely/not/a/dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn pjrt_availability_tracks_feature() {
        assert_eq!(ArtifactRegistry::pjrt_available(), cfg!(feature = "pjrt"));
    }

    #[test]
    fn registry_parses_minimal_manifest() {
        // a synthetic artifacts dir exercising the manifest parser without
        // any HLO files (they are only touched at exec time)
        let dir = std::env::temp_dir().join(format!("fsl-hdnn-art-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "config": {"image_size": 8, "in_channels": 3, "widths": [4, 8],
                         "feature_dim": 8, "d": 64, "ch_sub": 4,
                         "n_centroids": 4, "master_seed": 7},
              "entries": [
                {"name": "fe_forward_b1", "file": "fe_forward_b1.hlo.txt",
                 "inputs": [{"shape": [1, 8, 8, 3]}],
                 "outputs": [{"shape": [1, 2, 8]}]}
              ]
            }"#,
        )
        .unwrap();
        let reg = ArtifactRegistry::open(&dir).unwrap();
        assert_eq!(reg.model.d, 64);
        assert_eq!(reg.entry_names(), vec!["fe_forward_b1".to_string()]);
        let sig = reg.signature("fe_forward_b1").unwrap();
        assert_eq!(sig.input_shapes, vec![vec![1, 8, 8, 3]]);
        assert_eq!(reg.compiled_count(), 0);
        // validation errors surface identically with and without pjrt
        let bad = vec![0f32; 4];
        assert!(reg.exec_f32("fe_forward_b1", &[(&bad, &[1, 4])]).is_err());
        assert!(reg.exec_f32("nope", &[]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
