//! Artifact registry: manifest-driven loading, one-time compilation and
//! typed execution of the `artifacts/*.hlo.txt` modules.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, sidestepping the 64-bit-id protos that jax >= 0.5
//! serializes and xla_extension 0.5.1 rejects (see DESIGN.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Shape+dtype signature of one artifact entry.
#[derive(Clone, Debug)]
pub struct EntrySig {
    pub file: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub output_shapes: Vec<Vec<usize>>,
}

/// Loaded registry: PJRT client + lazily compiled executables.
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    pub model: ModelConfig,
    entries: HashMap<String, EntrySig>,
    client: xla::PjRtClient,
    compiled: Mutex<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl std::fmt::Debug for ArtifactRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ArtifactRegistry")
            .field("dir", &self.dir)
            .field("entries", &self.entries.keys().collect::<Vec<_>>())
            .finish()
    }
}

impl ArtifactRegistry {
    /// Open `artifacts/` (parses manifest, creates the PJRT CPU client;
    /// compilation happens on first use of each entry).
    pub fn open(dir: &Path) -> anyhow::Result<Self> {
        let man_text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow::anyhow!("cannot read manifest.json in {dir:?}: {e} — run `make artifacts`"))?;
        let man = Json::parse(&man_text)?;
        let model = ModelConfig::from_manifest(&man)?;
        let mut entries = HashMap::new();
        for e in man
            .get("entries")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing entries"))?
        {
            let name = e.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let file = e.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let shapes = |key: &str| -> Vec<Vec<usize>> {
                e.get(key)
                    .and_then(|v| v.as_arr())
                    .map(|arr| {
                        arr.iter()
                            .filter_map(|s| s.get("shape").and_then(|x| x.as_usize_vec()))
                            .collect()
                    })
                    .unwrap_or_default()
            };
            entries.insert(
                name,
                EntrySig { file, input_shapes: shapes("inputs"), output_shapes: shapes("outputs") },
            );
        }
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), model, entries, client, compiled: Mutex::new(HashMap::new()) })
    }

    pub fn entry_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    pub fn signature(&self, name: &str) -> Option<&EntrySig> {
        self.entries.get(name)
    }

    /// Compile (once) and cache an entry.
    fn ensure_compiled(&self, name: &str) -> anyhow::Result<()> {
        let mut compiled = self.compiled.lock().unwrap();
        if compiled.contains_key(name) {
            return Ok(());
        }
        let sig = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact entry: {name}"))?;
        let path = self.dir.join(&sig.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {name}: {e:?}"))?;
        compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entry on f32 inputs; inputs are (data, dims) pairs that
    /// must match the manifest signature. Returns flattened f32 outputs.
    pub fn exec_f32(&self, name: &str, inputs: &[(&[f32], &[usize])]) -> anyhow::Result<Vec<Vec<f32>>> {
        let sig = self
            .entries
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact entry: {name}"))?;
        anyhow::ensure!(
            inputs.len() == sig.input_shapes.len(),
            "{name}: expected {} inputs, got {}",
            sig.input_shapes.len(),
            inputs.len()
        );
        for (i, ((data, dims), want)) in inputs.iter().zip(&sig.input_shapes).enumerate() {
            anyhow::ensure!(
                *dims == want.as_slice(),
                "{name}: input {i} shape {dims:?} != manifest {want:?}"
            );
            let n: usize = dims.iter().product();
            anyhow::ensure!(data.len() == n, "{name}: input {i} data len {} != {n}", data.len());
        }
        self.ensure_compiled(name)?;
        let compiled = self.compiled.lock().unwrap();
        let exe = compiled.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data)
                    .reshape(&dims_i64)
                    .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("to_tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec: {e:?}")))
            .collect()
    }

    /// Number of compiled (cached) executables — used by tests/metrics.
    pub fn compiled_count(&self) -> usize {
        self.compiled.lock().unwrap().len()
    }
}
