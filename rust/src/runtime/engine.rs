//! Compute engine: one typed API over two backends.
//!
//! * `Pjrt` — the production path: every FE/encode/distance call executes
//!   an AOT-compiled artifact on the PJRT CPU client (the "device").
//!   Requires both `make artifacts` and the `pjrt` cargo feature.
//! * `Native` — the rust mirror (same weights, bit-compatible cRP): used
//!   by the simulator, the baselines and as a fast fallback. Cross-checked
//!   against the PJRT path by integration tests. A native engine can be
//!   built from an artifacts directory ([`ComputeEngine::open`]) or from a
//!   [`ModelConfig`] alone with deterministic synthetic weights
//!   ([`ComputeEngine::from_config`]) — no `make artifacts` needed.

use std::path::Path;

use crate::config::{ModelConfig, ParallelConfig};
use crate::fe::{FeModel, StagedForward};
use crate::hdc::CrpEncoder;
use crate::runtime::ArtifactRegistry;

/// One in-flight staged FE pass — the backend seam of the early-exit
/// inference loop (DESIGN.md §Staged inference). Created by
/// [`ComputeEngine::fe_stage_start`]; each [`FeStageExec::step`] yields
/// the next stage's branch feature.
///
/// * `Native` wraps [`StagedForward`]: stopping after stage *b* means the
///   remaining stages are **never computed** — early exit truncates real
///   FE work.
/// * `Whole` is the PJRT / whole-prefix fallback: the artifact's
///   `fe_forward` entry computes every branch in one execution, so the
///   features are materialized up front and `step` merely replays them.
///   The API shape is identical; only the work saved differs (and
///   [`FeStageExec::layers_run`] reports it honestly).
pub enum FeStageExec<'e> {
    Native(StagedForward<'e>),
    Whole { feats: Vec<Vec<f32>>, next: usize, layers_total: usize },
}

impl FeStageExec<'_> {
    /// Stages in the plan (= branch count).
    pub fn n_stages(&self) -> usize {
        match self {
            FeStageExec::Native(s) => s.n_stages(),
            FeStageExec::Whole { feats, .. } => feats.len(),
        }
    }

    /// Stages stepped so far.
    pub fn stages_run(&self) -> usize {
        match self {
            FeStageExec::Native(s) => s.stages_run(),
            FeStageExec::Whole { next, .. } => *next,
        }
    }

    /// Whether every stage has been stepped.
    pub fn is_done(&self) -> bool {
        self.stages_run() >= self.n_stages()
    }

    /// Conv layers actually executed for this pass. Native: the staged
    /// executor's running count (grows with each step). Whole-prefix: the
    /// full plan, however early the caller stops — that backend really did
    /// run everything, and the metric must say so.
    pub fn layers_run(&self) -> usize {
        match self {
            FeStageExec::Native(s) => s.layers_run(),
            FeStageExec::Whole { layers_total, .. } => *layers_total,
        }
    }

    /// Yield the next stage's branch feature (padded to `feature_dim`),
    /// or `None` when every stage has been stepped.
    pub fn step(&mut self) -> anyhow::Result<Option<Vec<f32>>> {
        match self {
            FeStageExec::Native(s) => s.step(),
            FeStageExec::Whole { feats, next, .. } => {
                if *next >= feats.len() {
                    return Ok(None);
                }
                let f = std::mem::take(&mut feats[*next]);
                *next += 1;
                Ok(Some(f))
            }
        }
    }
}

/// Backend selection for the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Native,
    Pjrt,
}

impl Backend {
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => anyhow::bail!("unknown backend {other} (native|pjrt)"),
        }
    }
}

/// The engine. Both variants load the same `artifacts/` directory so the
/// weights and cRP seeds always agree; the native variant can also run
/// without artifacts on synthetic weights.
///
/// The native variant carries a [`ParallelConfig`]: `fe_forward` / `encode`
/// batches are sharded across the persistent worker pool
/// (`runtime::pool::WorkerPool` — long-lived channel-fed threads, no
/// per-call spawns) with bit-identical output for any worker count
/// (DESIGN.md §Threading model). The default is serial; see
/// [`ComputeEngine::with_parallelism`].
pub enum ComputeEngine {
    Native { fe: FeModel, enc: CrpEncoder, par: ParallelConfig },
    Pjrt { reg: ArtifactRegistry, enc: CrpEncoder },
}

impl std::fmt::Debug for ComputeEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ComputeEngine::Native { .. } => write!(f, "ComputeEngine::Native"),
            ComputeEngine::Pjrt { .. } => write!(f, "ComputeEngine::Pjrt"),
        }
    }
}

impl ComputeEngine {
    /// Open an engine over an artifacts directory (strict: missing
    /// artifacts are an error for both backends).
    pub fn open(backend: Backend, artifacts_dir: &Path) -> anyhow::Result<Self> {
        match backend {
            Backend::Native => {
                let fe = FeModel::load(artifacts_dir)?;
                let enc = CrpEncoder::new(fe.cfg.d, fe.cfg.master_seed);
                Ok(ComputeEngine::Native { fe, enc, par: ParallelConfig::default() })
            }
            Backend::Pjrt => {
                anyhow::ensure!(
                    ArtifactRegistry::pjrt_available(),
                    "PJRT backend unavailable: built without the `pjrt` cargo feature \
                     (see DESIGN.md §PJRT gating)"
                );
                let reg = ArtifactRegistry::open(artifacts_dir)?;
                let enc = CrpEncoder::new(reg.model.d, reg.model.master_seed);
                Ok(ComputeEngine::Pjrt { reg, enc })
            }
        }
    }

    /// Build a native engine from a model configuration alone: the FE gets
    /// deterministic synthetic (He-initialized) weights seeded from
    /// `cfg.master_seed`, and the cRP encoder uses the same seed contract
    /// as the artifacts. This is the path every bench, example and test
    /// takes when `make artifacts` has not run. When `cfg.clustered` is
    /// set, every FE layer is quantized once here and `fe_forward` runs
    /// the packed weight-clustered kernel (DESIGN.md §Clustered
    /// execution).
    pub fn from_config(cfg: ModelConfig) -> Self {
        let enc = CrpEncoder::new(cfg.d, cfg.master_seed);
        let fe = FeModel::synthetic(cfg);
        ComputeEngine::Native { fe, enc, par: ParallelConfig::default() }
    }

    /// Set the batch-parallel execution policy (native backend only — the
    /// PJRT client owns its own threading). Parallel output is bit-identical
    /// to serial, so this never changes results, only throughput.
    pub fn with_parallelism(mut self, par: ParallelConfig) -> Self {
        self.set_parallelism(par);
        self
    }

    /// In-place variant of [`ComputeEngine::with_parallelism`].
    pub fn set_parallelism(&mut self, par: ParallelConfig) {
        if let ComputeEngine::Native { par: p, .. } = self {
            *p = par;
        }
    }

    /// The active batch-parallel policy (PJRT reports the serial default).
    pub fn parallelism(&self) -> ParallelConfig {
        match self {
            ComputeEngine::Native { par, .. } => *par,
            ComputeEngine::Pjrt { .. } => ParallelConfig::default(),
        }
    }

    /// Open `backend` over `artifacts_dir`, falling back to a synthetic
    /// native engine (default [`ModelConfig`]) when the directory has no
    /// artifacts. The fallback only fires when `manifest.json` is absent —
    /// a *present but broken* artifacts directory (truncated weights,
    /// malformed manifest) stays an error, so corruption can never be
    /// silently papered over with synthetic weights. The PJRT backend
    /// never falls back at all: a missing runtime is an error the caller
    /// must see.
    pub fn open_or_synthetic(backend: Backend, artifacts_dir: &Path) -> anyhow::Result<Self> {
        Self::open_or_synthetic_with(backend, artifacts_dir, ModelConfig::default())
    }

    /// Like [`ComputeEngine::open_or_synthetic`], but the synthetic
    /// fallback uses the caller's [`ModelConfig`] instead of the default —
    /// the CLI/TOML synthetic-geometry knob. With artifacts present the
    /// manifest still owns the geometry, but `cfg.clustered` /
    /// `cfg.ch_sub` / `cfg.n_centroids` are applied on top: quantized
    /// execution is a load-time choice, not an artifact property.
    pub fn open_or_synthetic_with(
        backend: Backend,
        artifacts_dir: &Path,
        cfg: ModelConfig,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            !cfg.clustered || (2..=16).contains(&cfg.n_centroids),
            "clustered FE needs 2 <= n_centroids <= 16, got {}",
            cfg.n_centroids
        );
        match backend {
            Backend::Native => {
                if artifacts_dir.join("manifest.json").exists() {
                    let mut fe = FeModel::load(artifacts_dir)?;
                    if cfg.clustered {
                        fe.cfg.ch_sub = cfg.ch_sub;
                        fe.cfg.n_centroids = cfg.n_centroids;
                        fe = fe.into_clustered();
                    }
                    let enc = CrpEncoder::new(fe.cfg.d, fe.cfg.master_seed);
                    return Ok(ComputeEngine::Native { fe, enc, par: ParallelConfig::default() });
                }
                eprintln!(
                    "note: no artifacts in {artifacts_dir:?}; using synthetic native model \
                     (run `make artifacts` for the AOT weights)"
                );
                Ok(Self::from_config(cfg))
            }
            Backend::Pjrt => Self::open(Backend::Pjrt, artifacts_dir),
        }
    }

    /// Whether the FE runs the packed weight-clustered kernel (native
    /// backend only — the PJRT artifacts bake their own weights in).
    pub fn is_clustered(&self) -> bool {
        match self {
            ComputeEngine::Native { fe, .. } => fe.is_clustered(),
            ComputeEngine::Pjrt { .. } => false,
        }
    }

    pub fn backend(&self) -> Backend {
        match self {
            ComputeEngine::Native { .. } => Backend::Native,
            ComputeEngine::Pjrt { .. } => Backend::Pjrt,
        }
    }

    pub fn model(&self) -> &ModelConfig {
        match self {
            ComputeEngine::Native { fe, .. } => &fe.cfg,
            ComputeEngine::Pjrt { reg, .. } => &reg.model,
        }
    }

    /// FE forward for a batch of images (each flat H*W*C). Returns, per
    /// image, the `n_branches` branch features padded to `feature_dim`.
    ///
    /// Native: the batch is sharded across the persistent worker pool per
    /// the engine's [`ParallelConfig`]; output is bit-identical to serial.
    /// PJRT: batches stream through the `fe_forward_b8` artifact; tails of
    /// 2..=7 images are zero-padded up to the b8 entry and the padded rows
    /// truncated — one batched execution instead of up to 7 serial b1 calls
    /// (the software mirror of the chip's batched-training utilization fix,
    /// Fig. 16). A single-image call keeps the b1 entry so query latency
    /// never pays for 7 discarded rows.
    pub fn fe_forward(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
        match self {
            ComputeEngine::Native { fe, par, .. } => {
                fe.forward_batch(images, par.shards_for(images.len()))
            }
            ComputeEngine::Pjrt { reg, .. } => {
                let m = &reg.model;
                let (s, c) = (m.image_size, m.in_channels);
                let fdim = m.feature_dim;
                let nb = m.n_branches();
                let mut out = Vec::with_capacity(images.len());
                let mut i = 0;
                while i < images.len() {
                    let take = (images.len() - i).min(8);
                    // pad 2..=7-image tails up to the b8 entry point
                    let exec_batch = if take == 1 { 1 } else { 8 };
                    let entry = format!("fe_forward_b{exec_batch}");
                    let mut flat = Vec::with_capacity(exec_batch * s * s * c);
                    for img in &images[i..i + take] {
                        anyhow::ensure!(img.len() == s * s * c, "image size mismatch");
                        flat.extend_from_slice(img);
                    }
                    flat.resize(exec_batch * s * s * c, 0.0);
                    let res = reg.exec_f32(&entry, &[(&flat, &[exec_batch, s, s, c])])?;
                    let feats = &res[0]; // (exec_batch, nb, fdim); padded rows dropped
                    for b in 0..take {
                        let mut branches = Vec::with_capacity(nb);
                        for br in 0..nb {
                            let base = (b * nb + br) * fdim;
                            branches.push(feats[base..base + fdim].to_vec());
                        }
                        out.push(branches);
                    }
                    i += take;
                }
                Ok(out)
            }
        }
    }

    /// cRP-encode a batch of `feature_dim` features into D-dim HVs.
    ///
    /// Same batching policy as [`ComputeEngine::fe_forward`]: native shards
    /// across the worker pool (bit-identical to serial), PJRT pads 2..=7
    /// tails up to the `crp_encode_b8` entry and truncates.
    pub fn encode(&self, feats: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        match self {
            ComputeEngine::Native { enc, par, .. } => {
                Ok(enc.encode_batch(feats, par.shards_for(feats.len())))
            }
            ComputeEngine::Pjrt { reg, .. } => {
                let m = &reg.model;
                let fdim = m.feature_dim;
                let d = m.d;
                let mut out = Vec::with_capacity(feats.len());
                let mut i = 0;
                while i < feats.len() {
                    let take = (feats.len() - i).min(8);
                    let exec_batch = if take == 1 { 1 } else { 8 };
                    let entry = format!("crp_encode_b{exec_batch}");
                    let mut flat = Vec::with_capacity(exec_batch * fdim);
                    for f in &feats[i..i + take] {
                        anyhow::ensure!(f.len() == fdim, "feature dim mismatch");
                        flat.extend_from_slice(f);
                    }
                    flat.resize(exec_batch * fdim, 0.0);
                    let res = reg.exec_f32(&entry, &[(&flat, &[exec_batch, fdim])])?;
                    for b in 0..take {
                        out.push(res[0][b * d..(b + 1) * d].to_vec());
                    }
                    i += take;
                }
                Ok(out)
            }
        }
    }

    /// Begin a staged FE pass for one image (DESIGN.md §Staged inference).
    /// Native: runs the stem only; every further stage is paid for by an
    /// explicit [`FeStageExec::step`], so an early exit after stage *b*
    /// provably skips stages *b+1..*. PJRT: falls back to one whole-prefix
    /// `fe_forward` execution behind the same seam (the AOT entry points
    /// compute all branches at once).
    pub fn fe_stage_start(&self, image: &[f32]) -> anyhow::Result<FeStageExec<'_>> {
        match self {
            ComputeEngine::Native { fe, .. } => Ok(FeStageExec::Native(fe.stage_start(image)?)),
            ComputeEngine::Pjrt { .. } => {
                let feats = self.fe_forward(&[image.to_vec()])?.remove(0);
                let m = self.model();
                let layers_total = m.conv_layers_through(m.n_branches());
                Ok(FeStageExec::Whole { feats, next: 0, layers_total })
            }
        }
    }

    /// cRP-encode a single branch feature — the per-stage encode of the
    /// early-exit loop. Exactly [`ComputeEngine::encode`] on a batch of
    /// one, so a staged query's HVs are bit-identical to the batched
    /// whole-image path.
    pub fn encode_one(&self, feat: &[f32]) -> anyhow::Result<Vec<f32>> {
        Ok(self.encode(&[feat.to_vec()])?.remove(0))
    }

    /// Total conv layers in the FE plan — the denominator of the
    /// `fe_layers_executed` / `fe_layers_skipped` accounting. Native
    /// reports its real block plan; PJRT derives the standard plan from
    /// the model geometry.
    pub fn fe_plan_layers(&self) -> usize {
        match self {
            ComputeEngine::Native { fe, .. } => fe.n_layers(),
            ComputeEngine::Pjrt { reg, .. } => {
                reg.model.conv_layers_through(reg.model.n_branches())
            }
        }
    }

    /// Conv layers the plan executes through the first `n_stages` stages
    /// (what a query exiting at that depth costs on the native backend).
    pub fn fe_layers_through(&self, n_stages: usize) -> usize {
        match self {
            ComputeEngine::Native { fe, .. } => fe.layers_through_stage(n_stages),
            ComputeEngine::Pjrt { reg, .. } => reg.model.conv_layers_through(n_stages),
        }
    }

    /// The native encoder is always available (HV post-processing,
    /// baselines) regardless of backend.
    pub fn native_encoder(&self) -> &CrpEncoder {
        match self {
            ComputeEngine::Native { enc, .. } => enc,
            ComputeEngine::Pjrt { enc, .. } => enc,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8],
            blocks_per_stage: 1,
            feature_dim: 8,
            d: 64,
            ..Default::default()
        }
    }

    #[test]
    fn backend_from_name_accepts_both_cases() {
        assert_eq!(Backend::from_name("native").unwrap(), Backend::Native);
        assert_eq!(Backend::from_name("NATIVE").unwrap(), Backend::Native);
        assert_eq!(Backend::from_name("Pjrt").unwrap(), Backend::Pjrt);
    }

    #[test]
    fn backend_from_name_error_names_the_choices() {
        let err = Backend::from_name("tpu").unwrap_err().to_string();
        assert!(err.contains("tpu"), "{err}");
        assert!(err.contains("native|pjrt"), "{err}");
    }

    #[test]
    fn native_from_config_needs_no_artifacts() {
        let engine = ComputeEngine::from_config(tiny_cfg());
        assert_eq!(engine.backend(), Backend::Native);
        let m = engine.model();
        assert_eq!((m.image_size, m.feature_dim, m.d), (8, 8, 64));
        let img = vec![0.25f32; 8 * 8 * 3];
        let branches = engine.fe_forward(&[img]).unwrap();
        assert_eq!(branches.len(), 1);
        assert_eq!(branches[0].len(), 2, "one feature per CONV branch");
        assert!(branches[0].iter().all(|f| f.len() == 8));
        let hvs = engine.encode(&[branches[0][1].clone()]).unwrap();
        assert_eq!(hvs[0].len(), 64);
    }

    #[test]
    fn from_config_is_deterministic() {
        let a = ComputeEngine::from_config(tiny_cfg());
        let b = ComputeEngine::from_config(tiny_cfg());
        let img = vec![0.5f32; 8 * 8 * 3];
        assert_eq!(a.fe_forward(&[img.clone()]).unwrap(), b.fe_forward(&[img]).unwrap());
    }

    /// Deterministic pseudo-images without threading a PRNG through.
    fn test_images(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|i| (0..len).map(|j| ((i * 193 + j * 7) % 97) as f32 / 97.0 - 0.5).collect())
            .collect()
    }

    #[test]
    fn parallel_fe_forward_and_encode_bit_identical_to_serial() {
        // the acceptance invariant: any worker count, any (odd) batch size
        let serial = ComputeEngine::from_config(tiny_cfg());
        let images = test_images(11, 8 * 8 * 3);
        let want_feats = serial.fe_forward(&images).unwrap();
        let finals: Vec<Vec<f32>> =
            want_feats.iter().map(|b| b.last().unwrap().clone()).collect();
        let want_hvs = serial.encode(&finals).unwrap();
        for workers in [1usize, 2, 7] {
            let par = ComputeEngine::from_config(tiny_cfg())
                .with_parallelism(ParallelConfig { workers, min_batch_per_worker: 1 });
            for batch in [1usize, 3, 7, 11] {
                assert_eq!(
                    par.fe_forward(&images[..batch]).unwrap(),
                    want_feats[..batch].to_vec(),
                    "fe_forward workers={workers} batch={batch}"
                );
                assert_eq!(
                    par.encode(&finals[..batch]).unwrap(),
                    want_hvs[..batch].to_vec(),
                    "encode workers={workers} batch={batch}"
                );
            }
        }
    }

    #[test]
    fn parallel_auto_workers_also_bit_identical() {
        let serial = ComputeEngine::from_config(tiny_cfg());
        let auto = ComputeEngine::from_config(tiny_cfg()).with_parallelism(ParallelConfig::auto());
        let images = test_images(9, 8 * 8 * 3);
        assert_eq!(auto.fe_forward(&images).unwrap(), serial.fe_forward(&images).unwrap());
    }

    #[test]
    fn parallel_errors_surface_from_any_shard() {
        let par = ComputeEngine::from_config(tiny_cfg())
            .with_parallelism(ParallelConfig { workers: 4, min_batch_per_worker: 1 });
        let mut images = test_images(8, 8 * 8 * 3);
        images[5] = vec![0.0; 3]; // wrong size, lands in a later shard
        assert!(par.fe_forward(&images).is_err());
    }

    #[test]
    fn parallelism_is_settable_on_native_only() {
        let mut e = ComputeEngine::from_config(tiny_cfg());
        assert_eq!(e.parallelism(), ParallelConfig::default());
        let p = ParallelConfig { workers: 3, min_batch_per_worker: 4 };
        e.set_parallelism(p);
        assert_eq!(e.parallelism(), p);
    }

    fn clustered_cfg() -> ModelConfig {
        ModelConfig { clustered: true, ch_sub: 4, n_centroids: 8, ..tiny_cfg() }
    }

    #[test]
    fn clustered_engine_runs_and_is_deterministic() {
        let a = ComputeEngine::from_config(clustered_cfg());
        assert!(a.is_clustered());
        let b = ComputeEngine::from_config(clustered_cfg());
        let images = test_images(3, 8 * 8 * 3);
        let fa = a.fe_forward(&images).unwrap();
        assert_eq!(fa, b.fe_forward(&images).unwrap());
        // clustered features differ from the dense model's (quantized
        // weights), but keep the same shape
        let dense = ComputeEngine::from_config(tiny_cfg());
        let fd = dense.fe_forward(&images).unwrap();
        assert_eq!(fa.len(), fd.len());
        assert_ne!(fa, fd);
    }

    #[test]
    fn clustered_parallel_bit_identical_to_serial() {
        let serial = ComputeEngine::from_config(clustered_cfg());
        let images = test_images(9, 8 * 8 * 3);
        let want = serial.fe_forward(&images).unwrap();
        for workers in [2usize, 7] {
            let par = ComputeEngine::from_config(clustered_cfg())
                .with_parallelism(ParallelConfig { workers, min_batch_per_worker: 1 });
            assert_eq!(par.fe_forward(&images).unwrap(), want, "workers={workers}");
        }
    }

    #[test]
    fn open_or_synthetic_with_uses_caller_geometry() {
        let missing = PathBuf::from("no/such/artifacts");
        let cfg = clustered_cfg();
        let e =
            ComputeEngine::open_or_synthetic_with(Backend::Native, &missing, cfg.clone()).unwrap();
        assert_eq!(e.model(), &FeModel::synthetic(cfg).cfg, "geometry + clustered flag kept");
        assert!(e.is_clustered());
        // invalid clustering knobs fail fast with a clean error
        let bad = ModelConfig { n_centroids: 32, ..clustered_cfg() };
        let err = ComputeEngine::open_or_synthetic_with(Backend::Native, &missing, bad)
            .unwrap_err()
            .to_string();
        assert!(err.contains("n_centroids"), "{err}");
    }

    #[test]
    fn staged_exec_matches_fe_forward_and_counts_layers() {
        let e = ComputeEngine::from_config(tiny_cfg());
        let img = test_images(1, 8 * 8 * 3).remove(0);
        let want = e.fe_forward(&[img.clone()]).unwrap().remove(0);
        let mut exec = e.fe_stage_start(&img).unwrap();
        assert_eq!(exec.n_stages(), 2);
        assert_eq!(exec.layers_run(), 1, "stem only before the first step");
        let f0 = exec.step().unwrap().unwrap();
        assert_eq!(f0, want[0], "staged stage 0 must be bit-identical to fe_forward");
        assert!(!exec.is_done());
        let f1 = exec.step().unwrap().unwrap();
        assert_eq!(f1, want[1]);
        assert!(exec.is_done());
        assert!(exec.step().unwrap().is_none());
        assert_eq!(exec.layers_run(), e.fe_plan_layers());
        // plan accounting agrees between the real plan and the geometry
        // formula (tiny_cfg: stem + s0b0 (2) + s1b0 (2 + proj) = 6)
        let m = e.model();
        assert_eq!(e.fe_plan_layers(), 6);
        assert_eq!(e.fe_plan_layers(), m.conv_layers_through(m.n_branches()));
        assert_eq!(e.fe_layers_through(1), 3);
        assert_eq!(e.fe_layers_through(1), m.conv_layers_through(1));
    }

    #[test]
    fn staged_exec_clustered_matches_fe_forward() {
        let e = ComputeEngine::from_config(clustered_cfg());
        let img = test_images(1, 8 * 8 * 3).remove(0);
        let want = e.fe_forward(&[img.clone()]).unwrap().remove(0);
        let mut exec = e.fe_stage_start(&img).unwrap();
        let mut got = Vec::new();
        while let Some(f) = exec.step().unwrap() {
            got.push(f);
        }
        assert_eq!(got, want);
    }

    #[test]
    fn encode_one_matches_batched_encode() {
        let e = ComputeEngine::from_config(tiny_cfg());
        let feats = test_images(3, 8);
        let want = e.encode(&feats).unwrap();
        for (f, w) in feats.iter().zip(&want) {
            assert_eq!(&e.encode_one(f).unwrap(), w);
        }
    }

    #[test]
    fn staged_exec_rejects_wrong_image_size() {
        let e = ComputeEngine::from_config(tiny_cfg());
        assert!(e.fe_stage_start(&[0.0; 5]).is_err());
    }

    #[test]
    fn open_native_without_artifacts_is_an_error() {
        let missing = PathBuf::from("no/such/artifacts");
        assert!(ComputeEngine::open(Backend::Native, &missing).is_err());
    }

    #[test]
    fn open_or_synthetic_falls_back_for_native_only() {
        let missing = PathBuf::from("no/such/artifacts");
        let e = ComputeEngine::open_or_synthetic(Backend::Native, &missing).unwrap();
        assert_eq!(e.backend(), Backend::Native);
        assert_eq!(e.model(), &ModelConfig::default());
        // PJRT must surface an error (unavailable feature or missing dir)
        assert!(ComputeEngine::open_or_synthetic(Backend::Pjrt, &missing).is_err());
    }
}
