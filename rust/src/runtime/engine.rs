//! Compute engine: one typed API over two backends.
//!
//! * `Pjrt` — the production path: every FE/encode/distance call executes
//!   an AOT-compiled artifact on the PJRT CPU client (the "device").
//! * `Native` — the rust mirror (same weights, bit-compatible cRP): used
//!   by the simulator, the baselines and as a fast fallback. Cross-checked
//!   against the PJRT path by integration tests.

use std::path::Path;

use crate::config::ModelConfig;
use crate::fe::FeModel;
use crate::hdc::CrpEncoder;
use crate::runtime::ArtifactRegistry;

/// Backend selection for the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    Native,
    Pjrt,
}

impl Backend {
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(Backend::Native),
            "pjrt" => Ok(Backend::Pjrt),
            other => anyhow::bail!("unknown backend {other} (native|pjrt)"),
        }
    }
}

/// The engine. Both variants load the same `artifacts/` directory so the
/// weights and cRP seeds always agree.
pub enum ComputeEngine {
    Native { fe: FeModel, enc: CrpEncoder },
    Pjrt { reg: ArtifactRegistry, enc: CrpEncoder },
}

impl std::fmt::Debug for ComputeEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ComputeEngine::Native { .. } => write!(f, "ComputeEngine::Native"),
            ComputeEngine::Pjrt { .. } => write!(f, "ComputeEngine::Pjrt"),
        }
    }
}

impl ComputeEngine {
    pub fn open(backend: Backend, artifacts_dir: &Path) -> anyhow::Result<Self> {
        match backend {
            Backend::Native => {
                let fe = FeModel::load(artifacts_dir)?;
                let enc = CrpEncoder::new(fe.cfg.d, fe.cfg.master_seed);
                Ok(ComputeEngine::Native { fe, enc })
            }
            Backend::Pjrt => {
                let reg = ArtifactRegistry::open(artifacts_dir)?;
                let enc = CrpEncoder::new(reg.model.d, reg.model.master_seed);
                Ok(ComputeEngine::Pjrt { reg, enc })
            }
        }
    }

    pub fn backend(&self) -> Backend {
        match self {
            ComputeEngine::Native { .. } => Backend::Native,
            ComputeEngine::Pjrt { .. } => Backend::Pjrt,
        }
    }

    pub fn model(&self) -> &ModelConfig {
        match self {
            ComputeEngine::Native { fe, .. } => &fe.cfg,
            ComputeEngine::Pjrt { reg, .. } => &reg.model,
        }
    }

    /// FE forward for a batch of images (each flat H*W*C). Returns, per
    /// image, the `n_branches` branch features padded to `feature_dim`.
    pub fn fe_forward(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
        match self {
            ComputeEngine::Native { fe, .. } => {
                images.iter().map(|img| fe.forward(img)).collect()
            }
            ComputeEngine::Pjrt { reg, .. } => {
                let m = &reg.model;
                let (s, c) = (m.image_size, m.in_channels);
                let fdim = m.feature_dim;
                let nb = m.n_branches();
                let mut out = Vec::with_capacity(images.len());
                let mut i = 0;
                while i < images.len() {
                    let take = if images.len() - i >= 8 { 8 } else { 1 };
                    let entry = format!("fe_forward_b{take}");
                    let mut flat = Vec::with_capacity(take * s * s * c);
                    for img in &images[i..i + take] {
                        anyhow::ensure!(img.len() == s * s * c, "image size mismatch");
                        flat.extend_from_slice(img);
                    }
                    let res = reg.exec_f32(&entry, &[(&flat, &[take, s, s, c])])?;
                    let feats = &res[0]; // (take, nb, fdim)
                    for b in 0..take {
                        let mut branches = Vec::with_capacity(nb);
                        for br in 0..nb {
                            let base = (b * nb + br) * fdim;
                            branches.push(feats[base..base + fdim].to_vec());
                        }
                        out.push(branches);
                    }
                    i += take;
                }
                Ok(out)
            }
        }
    }

    /// cRP-encode a batch of `feature_dim` features into D-dim HVs.
    pub fn encode(&self, feats: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        match self {
            ComputeEngine::Native { enc, .. } => {
                Ok(feats.iter().map(|f| enc.encode_padded(f)).collect())
            }
            ComputeEngine::Pjrt { reg, .. } => {
                let m = &reg.model;
                let fdim = m.feature_dim;
                let d = m.d;
                let mut out = Vec::with_capacity(feats.len());
                let mut i = 0;
                while i < feats.len() {
                    let take = if feats.len() - i >= 8 { 8 } else { 1 };
                    let entry = format!("crp_encode_b{take}");
                    let mut flat = Vec::with_capacity(take * fdim);
                    for f in &feats[i..i + take] {
                        anyhow::ensure!(f.len() == fdim, "feature dim mismatch");
                        flat.extend_from_slice(f);
                    }
                    let res = reg.exec_f32(&entry, &[(&flat, &[take, fdim])])?;
                    for b in 0..take {
                        out.push(res[0][b * d..(b + 1) * d].to_vec());
                    }
                    i += take;
                }
                Ok(out)
            }
        }
    }

    /// The native encoder is always available (HV post-processing,
    /// baselines) regardless of backend.
    pub fn native_encoder(&self) -> &CrpEncoder {
        match self {
            ComputeEngine::Native { enc, .. } => enc,
            ComputeEngine::Pjrt { enc, .. } => enc,
        }
    }
}
