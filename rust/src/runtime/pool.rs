//! Persistent worker runtime: long-lived threads, each owning an mpsc task
//! queue (the kubecl `Worker`/`InnerWorker` shape), replacing the
//! per-call `std::thread::scope` spawns that `util::parallel::shard_map`
//! used through PR 5. Spawning a thread costs tens of microseconds; a
//! queue send costs well under one — at serving rates the spawn tax was
//! the dominant per-batch overhead.
//!
//! Three pieces:
//! * [`WorkerPool`] — N workers, each with its own queue; tasks are
//!   dispatched round-robin. Dropping the pool drops every sender first,
//!   so each worker *drains its remaining queue* and exits, then all
//!   threads are joined — no detached threads, no abandoned tasks.
//! * [`WorkerPool::run_scoped`] — fork-join over borrowed data on the
//!   persistent workers. This is what `shard_map` builds on: it blocks
//!   until every job has signalled completion, which is what makes the
//!   (carefully scoped) lifetime transmute sound.
//! * [`with_pool`] / [`global`] — pool selection without threading a pool
//!   handle through every signature: the coordinator worker installs its
//!   own pool for the duration of its event loop (so `Coordinator::drop`
//!   joins those workers); direct callers fall back to a process-wide
//!   pool sized to the machine, which lives for the process like rayon's.
//!
//! The determinism contract (DESIGN.md §Threading model) is unaffected:
//! the pool only changes *where* shard closures run, never how batches
//! are chunked or stitched.

use std::any::Any;
use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, OnceLock};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;
type PanicPayload = Box<dyn Any + Send + 'static>;

thread_local! {
    /// Set for the lifetime of every pool worker thread. A nested
    /// `run_scoped` from inside a worker runs its jobs inline: a worker
    /// queueing behind the very call it is executing would deadlock.
    static IS_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
    /// Pool installed for the current thread by [`with_pool`]; null means
    /// "use [`global`]". Raw pointer, never read outside the `with_pool`
    /// frame that set it (the guard restores the previous value on exit).
    static CURRENT_POOL: Cell<*const WorkerPool> = const { Cell::new(std::ptr::null()) };
}

struct WorkerHandle {
    /// `None` once shutdown has begun; dropping the sender is what makes
    /// the worker's `recv` loop terminate after draining its queue.
    tx: Option<Sender<Task>>,
    handle: Option<JoinHandle<()>>,
}

/// A fixed set of long-lived worker threads, each owning one task queue.
pub struct WorkerPool {
    workers: Vec<WorkerHandle>,
    /// round-robin dispatch cursor
    next: AtomicUsize,
    /// tasks submitted but not yet finished, across all queues — the load
    /// signal the serving gateway's admission control reads
    queued: Arc<AtomicUsize>,
    /// schedule-perturbation seed (tests only): when set, every dispatched
    /// task sleeps a short seed-derived interval before running, shuffling
    /// worker completion order deterministically per (seed, submit index)
    perturb: Option<u64>,
    /// monotone task counter feeding the perturbation hash
    task_seq: AtomicU64,
}

impl WorkerPool {
    /// Spawn `n` workers (clamped to at least 1).
    pub fn new(n: usize) -> Self {
        Self::with_gauge(n, Arc::new(AtomicUsize::new(0)))
    }

    /// A pool whose task *completion order* is deterministically shuffled:
    /// every dispatched task first sleeps a `splitmix64(seed, index)`-derived
    /// sub-millisecond interval. The race harness (`tests/sched_perturb.rs`)
    /// uses this to prove the `shard_map` bit-identity contract holds under
    /// adversarial schedules, not just the ones the OS happens to produce —
    /// the dynamic complement to the `raw-spawn` lint rule.
    pub fn with_perturbation(n: usize, seed: u64) -> Self {
        let mut pool = Self::new(n);
        pool.perturb = Some(seed);
        pool
    }

    /// [`WorkerPool::new`] with a caller-owned queue-depth gauge, so an
    /// embedding serving stack (`coordinator::server::ServingLoad`) can
    /// watch pool backlog without polling the pool itself.
    pub fn with_gauge(n: usize, queued: Arc<AtomicUsize>) -> Self {
        let workers = (0..n.max(1))
            .map(|i| {
                let (tx, rx) = channel::<Task>();
                let gauge = queued.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("fsl-pool-{i}"))
                    .spawn(move || {
                        IS_POOL_WORKER.with(|f| f.set(true));
                        // recv() serves every queued task before erroring
                        // once all senders are gone, so shutdown drains
                        // in-flight work instead of abandoning it
                        while let Ok(task) = rx.recv() {
                            // a panicking task must not kill the long-lived
                            // worker or wedge the gauge; run_scoped catches
                            // first and re-raises on the submitting thread.
                            // The `pool.task` fail point fires inside the
                            // same catch, replacing the task body with an
                            // injected panic — chaos tests prove drop still
                            // drains and joins under mid-flight panics.
                            let _ = catch_unwind(AssertUnwindSafe(|| {
                                crate::util::failpoint::check("pool.task")
                                    .expect("injected pool.task fault");
                                task()
                            }));
                            gauge.fetch_sub(1, Ordering::AcqRel);
                        }
                    })
                    .expect("spawn pool worker");
                WorkerHandle { tx: Some(tx), handle: Some(handle) }
            })
            .collect();
        WorkerPool { workers, next: AtomicUsize::new(0), queued, perturb: None, task_seq: AtomicU64::new(0) }
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Tasks submitted but not yet finished (queued + in service).
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::Acquire)
    }

    /// Fire-and-forget: run `task` on some worker. Panics inside the task
    /// are swallowed (the worker survives); use [`WorkerPool::run_scoped`]
    /// when completion or panics must reach the caller.
    pub fn submit(&self, task: impl FnOnce() + Send + 'static) {
        self.dispatch(Box::new(task));
    }

    fn dispatch(&self, task: Task) {
        let task: Task = match self.perturb {
            None => task,
            Some(seed) => {
                // hash (seed, submit index) to a 0..293 us delay: co-prime
                // with common timer quanta, long enough to reorder short
                // tasks, short enough that a 10k-task harness stays fast
                let k = self.task_seq.fetch_add(1, Ordering::Relaxed);
                let delay_us =
                    crate::util::prng::splitmix64_next(seed.wrapping_add(k.wrapping_mul(0x9E37_79B9_7F4A_7C15))) % 293;
                Box::new(move || {
                    std::thread::sleep(std::time::Duration::from_micros(delay_us));
                    task();
                })
            }
        };
        self.queued.fetch_add(1, Ordering::AcqRel);
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.workers.len();
        let tx = self.workers[i].tx.as_ref().expect("dispatch after shutdown");
        if let Err(e) = tx.send(task) {
            // unreachable in practice (a worker only exits when its sender
            // drops), but losing a task would hang run_scoped forever —
            // run it inline instead
            self.queued.fetch_sub(1, Ordering::AcqRel);
            (e.0)();
        }
    }

    /// Fork-join over borrowed data: run every job to completion on the
    /// pool, blocking until the last one finishes. The first job panic is
    /// re-raised on the calling thread *after* all jobs have completed
    /// (so no job is ever left running against dropped borrows). Called
    /// from inside a pool worker, jobs run inline serially — see
    /// `IS_POOL_WORKER`.
    pub fn run_scoped<'s>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 's>>) {
        if jobs.is_empty() {
            return;
        }
        if IS_POOL_WORKER.with(|f| f.get()) {
            return run_inline(jobs);
        }
        let n = jobs.len();
        let (done_tx, done_rx) = channel::<Option<PanicPayload>>();
        for job in jobs {
            let done = done_tx.clone();
            let wrapped: Box<dyn FnOnce() + Send + 's> = Box::new(move || {
                let r = catch_unwind(AssertUnwindSafe(job));
                let _ = done.send(r.err());
            });
            // SAFETY: the loop below blocks until every job has sent its
            // completion signal (sent unconditionally — panics are caught
            // inside `wrapped`), so the non-'static borrows captured by
            // the job cannot be invalidated while the pool can still run
            // it. This is the classic scoped-pool erasure; the 'static
            // lie never escapes this function.
            let task =
                unsafe { std::mem::transmute::<Box<dyn FnOnce() + Send + 's>, Task>(wrapped) };
            self.dispatch(task);
        }
        drop(done_tx);
        let mut first_panic: Option<PanicPayload> = None;
        for _ in 0..n {
            match done_rx.recv() {
                Ok(p) => {
                    if first_panic.is_none() {
                        first_panic = p;
                    }
                }
                // every job signals exactly once; a missing signal means a
                // worker thread died, and unblocking with a hard error
                // beats hanging the caller forever
                Err(_) => panic!("worker pool: a worker died mid-scope"),
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // drop every sender first: each worker drains what is already in
        // its queue, then its recv() errors and the thread exits
        for w in &mut self.workers {
            w.tx.take();
        }
        for w in &mut self.workers {
            if let Some(h) = w.handle.take() {
                // workers never panic outside caught task code, but a join
                // error must not double-panic Drop
                let _ = h.join();
            }
        }
    }
}

fn run_inline(jobs: Vec<Box<dyn FnOnce() + Send + '_>>) {
    let mut first_panic: Option<PanicPayload> = None;
    for job in jobs {
        if let Err(p) = catch_unwind(AssertUnwindSafe(job)) {
            if first_panic.is_none() {
                first_panic = Some(p);
            }
        }
    }
    if let Some(p) = first_panic {
        resume_unwind(p);
    }
}

/// The process-wide fallback pool, one worker per available core, created
/// on first use. Like rayon's global pool it lives for the process;
/// callers that need joined shutdown (the coordinator) install their own
/// pool with [`with_pool`] instead.
pub fn global() -> &'static WorkerPool {
    static GLOBAL: OnceLock<WorkerPool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        WorkerPool::new(std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
    })
}

/// Install `pool` as the current thread's pool for the duration of `f`:
/// `shard_map` calls made on this thread (and only this thread) dispatch
/// to it instead of the global pool. Restores the previous installation
/// on exit, including on panic.
pub fn with_pool<R>(pool: &WorkerPool, f: impl FnOnce() -> R) -> R {
    struct Restore(*const WorkerPool);
    impl Drop for Restore {
        fn drop(&mut self) {
            CURRENT_POOL.with(|c| c.set(self.0));
        }
    }
    let prev = CURRENT_POOL.with(|c| c.replace(pool as *const WorkerPool));
    let _restore = Restore(prev);
    f()
}

/// Run `f` against the current thread's installed pool, or the global one.
pub(crate) fn with_current<R>(f: impl FnOnce(&WorkerPool) -> R) -> R {
    let p = CURRENT_POOL.with(|c| c.get());
    if p.is_null() {
        f(global())
    } else {
        // SAFETY: a non-null CURRENT_POOL was set by a `with_pool` frame
        // still on this thread's stack (its guard restores the slot before
        // the pool borrow it holds can end), so the pointee is alive.
        f(unsafe { &*p })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Condvar, Mutex};

    #[test]
    fn submit_runs_tasks_and_drop_drains_the_queue() {
        let counter = Arc::new(AtomicUsize::new(0));
        let pool = WorkerPool::new(2);
        for _ in 0..50 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // drop joins every worker after its queue drains: all 50 must have
        // run even if none had started yet
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn run_scoped_joins_jobs_over_borrowed_data() {
        let pool = WorkerPool::new(3);
        let mut slots = vec![0usize; 7];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .enumerate()
            .map(|(i, s)| Box::new(move || *s = i * i) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(slots, vec![0, 1, 4, 9, 16, 25, 36]);
        assert_eq!(pool.queue_depth(), 0, "all scoped work accounted for");
    }

    #[test]
    fn run_scoped_propagates_panics_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("job {i} exploded")
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }));
        assert!(r.is_err(), "the job panic must reach the caller");
        // the workers caught the panic and live on
        let mut out = vec![0; 3];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
            .iter_mut()
            .map(|s| Box::new(move || *s = 7) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(out, vec![7, 7, 7]);
    }

    #[test]
    fn nested_run_scoped_inlines_instead_of_deadlocking() {
        // a 1-worker pool is the acid test: the outer job occupies the only
        // worker, so a queued inner job could never start
        let pool = Arc::new(WorkerPool::new(1));
        let mut outer_done = false;
        let p2 = pool.clone();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            let mut inner = vec![0usize; 4];
            let inner_jobs: Vec<Box<dyn FnOnce() + Send + '_>> = inner
                .iter_mut()
                .enumerate()
                .map(|(i, s)| Box::new(move || *s = i + 1) as Box<dyn FnOnce() + Send + '_>)
                .collect();
            p2.run_scoped(inner_jobs);
            assert_eq!(inner, vec![1, 2, 3, 4]);
            outer_done = true;
        })];
        pool.run_scoped(jobs);
        assert!(outer_done);
    }

    #[test]
    fn queue_depth_counts_queued_and_in_service_tasks() {
        let pool = WorkerPool::new(1);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        for _ in 0..3 {
            let g = gate.clone();
            pool.submit(move || {
                let (lock, cv) = &*g;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        // depth counts at submit time: one task blocked in service on the
        // single worker, two waiting behind it
        assert_eq!(pool.queue_depth(), 3);
        *gate.0.lock().unwrap() = true;
        gate.1.notify_all();
        for _ in 0..200 {
            if pool.queue_depth() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(pool.queue_depth(), 0, "depth returns to zero after the queue drains");
    }

    #[test]
    fn hundred_pools_create_and_drop_without_leaking_work() {
        // regression for the worker-pool shutdown contract: every pool
        // joins its threads and drains its queue on drop, so this loop
        // neither hangs, panics, nor loses tasks
        let ran = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let pool = WorkerPool::new(2);
            for _ in 0..4 {
                let r = ran.clone();
                pool.submit(move || {
                    r.fetch_add(1, Ordering::SeqCst);
                });
            }
        }
        assert_eq!(ran.load(Ordering::SeqCst), 400);
    }

    #[test]
    fn perturbed_pool_still_runs_every_task_and_drains() {
        // the perturbation wrapper delays tasks but must not drop, reorder
        // results (run_scoped joins by slot, not by completion), or wedge
        // the gauge
        let pool = WorkerPool::with_perturbation(2, 0xF51D);
        let mut slots = vec![0usize; 9];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .enumerate()
            .map(|(i, s)| Box::new(move || *s = i + 1) as Box<dyn FnOnce() + Send + '_>)
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(slots, (1..=9).collect::<Vec<_>>());
        assert_eq!(pool.queue_depth(), 0);
    }

    #[test]
    fn with_pool_installs_and_restores_the_current_pool() {
        let pool = WorkerPool::new(2);
        let installed = with_pool(&pool, || with_current(|c| std::ptr::eq(c, &pool)));
        assert!(installed, "inside with_pool, shard_map dispatches to the installed pool");
        // nesting restores the outer installation, not the global fallback
        let outer = WorkerPool::new(1);
        with_pool(&outer, || {
            with_pool(&pool, || assert!(with_current(|c| std::ptr::eq(c, &pool))));
            assert!(with_current(|c| std::ptr::eq(c, &outer)));
        });
        assert!(with_current(|c| std::ptr::eq(c, global())), "outside, the global pool serves");
    }
}
