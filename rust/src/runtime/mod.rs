//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them from the coordinator's hot path. Python never runs here — the
//! artifacts directory is the entire compile-path hand-off.

pub mod artifacts;
pub mod engine;

pub use artifacts::ArtifactRegistry;
pub use engine::ComputeEngine;
