//! PJRT runtime: loads the AOT-compiled HLO-text artifacts and executes
//! them from the coordinator's hot path. Python never runs here — the
//! artifacts directory is the entire compile-path hand-off.
//!
//! Artifact *execution* requires the `pjrt` cargo feature (the xla-rs
//! bindings are outside the offline registry — DESIGN.md §PJRT gating);
//! manifest parsing, signatures and the native backend work without it,
//! and [`ComputeEngine::from_config`] builds a native engine from
//! configuration alone, with no artifacts directory at all.
//!
//! [`pool`] is the persistent worker runtime the native backend's batch
//! sharding executes on (DESIGN.md §Serving runtime): long-lived workers
//! with per-worker mpsc queues, replacing per-call thread spawns.

pub mod artifacts;
pub mod engine;
pub mod pool;

pub use artifacts::ArtifactRegistry;
pub use engine::{ComputeEngine, FeStageExec};
pub use pool::WorkerPool;
