//! fsl-hdnn CLI — drive the coordinator, the chip simulator and the
//! artifact checks from the command line.
//!
//! Subcommands:
//!   episode         run N-way k-shot ODL episodes through the coordinator
//!   serve           expose one coordinator over the TCP gateway
//!   sim             chip-simulator report (training / inference)
//!   check-artifacts load artifacts, execute them, compare vs goldens
//!   info            print model / chip configuration
//!
//! Examples:
//!   fsl-hdnn episode --n-way 10 --k-shot 5 --episodes 3 --backend native
//!   fsl-hdnn episode --workers 0 --batched true   # 0 = one worker per core
//!   fsl-hdnn episode --clustered --ch-sub 64 --n-centroids 16  # Fig. 4b FE
//!   fsl-hdnn episode --hv-bits 1 --metric hamming # packed binary classifier
//!   fsl-hdnn episode --backend ldc --ldc-d 0      # low-dimensional classifier (LDC)
//!   fsl-hdnn episode --base-width 32 --stages 3 --image-size 64  # synthetic geometry
//!   fsl-hdnn episode --backend pjrt --ee 2,2
//!   fsl-hdnn serve --addr 127.0.0.1:7878 --workers 0 --high-water 64
//!   fsl-hdnn serve --deadline-ms 250                # bound caller waits
//!   fsl-hdnn episode --faults "device.query=latency-ms:1"  # fault drill
//!   fsl-hdnn sim --task train --batched true --voltage 1.2 --freq 250
//!   fsl-hdnn check-artifacts

use std::collections::HashMap;
use std::path::PathBuf;

use fsl_hdnn::classifier::ClassifierBackend;
use fsl_hdnn::config::{ChipConfig, ClassifierConfig, EeConfig, ParallelConfig};
use fsl_hdnn::coordinator::Coordinator;
use fsl_hdnn::data::images::ImageGen;
use fsl_hdnn::runtime::engine::{Backend, ComputeEngine};
use fsl_hdnn::runtime::ArtifactRegistry;
use fsl_hdnn::sim::Chip;
use fsl_hdnn::util::prng::Rng;
use fsl_hdnn::util::stats;
use fsl_hdnn::util::table::Table;

/// Minimal `--key value` argument parser.
struct Args {
    cmd: String,
    kv: HashMap<String, String>,
}

impl Args {
    fn parse() -> Self {
        let mut it = std::env::args().skip(1);
        let cmd = it.next().unwrap_or_else(|| "help".into());
        let mut kv = HashMap::new();
        let rest: Vec<String> = it.collect();
        let mut i = 0;
        while i < rest.len() {
            let k = rest[i].trim_start_matches("--").to_string();
            if i + 1 < rest.len() && !rest[i + 1].starts_with("--") {
                kv.insert(k, rest[i + 1].clone());
                i += 2;
            } else {
                kv.insert(k, "true".into());
                i += 1;
            }
        }
        Args { cmd, kv }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    fn get_str(&self, key: &str, default: &str) -> String {
        self.kv.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// `--ee E_S,E_C` through the shared validated parser — malformed
    /// input is an error, not a silent fall-back to the paper default
    /// (the examples' `--ee` flags parse identically).
    fn ee(&self) -> anyhow::Result<Option<EeConfig>> {
        self.kv.get("ee").map(|s| EeConfig::parse(s)).transpose()
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_str("artifacts", "artifacts"))
}

/// Resolve the overloaded `--backend` flag. Historically it named the
/// compute *engine* (`native|pjrt`); since the classifier seam it also
/// accepts a *classifier* backend (`hdc|ldc`) — `--backend ldc` runs the
/// low-dimensional classifier on the native engine. Returns
/// `(engine, classifier)` with the TOML `[classifier]` section as the
/// classifier default; any other name errors with the full menu.
fn resolve_backends(
    args: &Args,
    rc: &fsl_hdnn::config::RunConfig,
) -> anyhow::Result<(Backend, ClassifierBackend)> {
    let mut engine = Backend::Native;
    let mut classifier = rc.classifier.backend;
    if let Some(v) = args.kv.get("backend") {
        if let Ok(b) = Backend::from_name(v) {
            engine = b;
        } else if let Ok(c) = ClassifierBackend::from_name(v) {
            classifier = c;
        } else {
            anyhow::bail!("unknown backend {v} (native|pjrt|hdc|ldc)");
        }
    }
    Ok((engine, classifier))
}

/// Arm fail points from `[faults] points` and/or the `--faults` flag —
/// shared by `episode` and `serve` so fault drills are reproducible from
/// either entry point (`FSL_FAILPOINTS` is read lazily regardless).
fn arm_faults(args: &Args, rc: &fsl_hdnn::config::RunConfig) -> anyhow::Result<()> {
    if !rc.faults.points.is_empty() {
        fsl_hdnn::util::failpoint::arm_spec(&rc.faults.points)?;
    }
    if let Some(spec) = args.kv.get("faults") {
        fsl_hdnn::util::failpoint::arm_spec(spec)?;
    }
    Ok(())
}

fn cmd_episode(args: &Args) -> anyhow::Result<()> {
    // optional TOML-subset config file, overridden by CLI flags
    let mut rc = fsl_hdnn::config::RunConfig::default();
    if let Some(path) = args.kv.get("config") {
        let doc = fsl_hdnn::config::toml::Doc::load(std::path::Path::new(path))?;
        rc.apply_toml(&doc)?;
    }
    arm_faults(args, &rc)?;
    let (backend, cls_backend) = resolve_backends(args, &rc)?;
    let cls = ClassifierConfig {
        backend: cls_backend,
        ldc_d: args.get("ldc-d", rc.classifier.ldc_d),
    };
    let n_way: usize = args.get("n-way", rc.workload.n_way);
    let k_shot: usize = args.get("k-shot", rc.workload.k_shot);
    let queries: usize = args.get("queries", rc.workload.queries_per_class);
    let episodes: usize = args.get("episodes", rc.workload.episodes.min(3));
    let seed: u64 = args.get("seed", rc.workload.seed);
    // --hv-bits / --metric: class-memory precision and distance metric for
    // the packed HDC datapath ([hdc] TOML section)
    let hv_bits: u32 = args.get("hv-bits", rc.hdc.hv_bits);
    anyhow::ensure!((1..=16).contains(&hv_bits), "--hv-bits must be 1..=16, got {hv_bits}");
    let metric = fsl_hdnn::hdc::Distance::from_name(
        &args.get_str("metric", rc.hdc.metric.name()),
    )?;
    let ee = args.ee()?.or(rc.ee);
    // --workers: 0 = auto (one per core), 1 = serial; bit-identical output
    // either way (DESIGN.md §Threading model)
    let par = ParallelConfig {
        workers: args.get("workers", rc.parallel.workers),
        min_batch_per_worker: args.get("min-batch-per-worker", rc.parallel.min_batch_per_worker),
    };
    // --batched: send each class's shots as one request so batched
    // single-pass training (Fig. 12) exercises the sharded FE path
    let batched: bool = args.get("batched", rc.batched_training);

    // synthetic-FE geometry + clustered-execution knobs ([fe]/[model] TOML
    // sections, overridable here; geometry only applies when there are no
    // artifacts — the manifest owns it otherwise)
    let mut mc = rc.model.clone();
    mc.image_size = args.get("image-size", mc.image_size);
    mc.in_channels = args.get("in-channels", mc.in_channels);
    mc.blocks_per_stage = args.get("blocks-per-stage", mc.blocks_per_stage);
    if args.kv.contains_key("base-width") || args.kv.contains_key("stages") {
        let bw = args.get("base-width", mc.widths.first().copied().unwrap_or(16));
        let ns = args.get("stages", mc.widths.len());
        mc.set_geometry(bw, ns)?;
    }
    mc.ch_sub = args.get("ch-sub", mc.ch_sub);
    mc.n_centroids = args.get("n-centroids", mc.n_centroids);
    // --clustered: quantize the FE once at load and run the packed
    // weight-clustered kernel (Fig. 4b) — the chip's cheap path
    mc.clustered = args.get("clustered", mc.clustered);

    let dir = artifacts_dir(args);
    // model geometry read on this thread; the engine itself is built
    // inside the coordinator worker (PJRT clients are not Send). With no
    // artifacts directory the native backend runs on synthetic weights.
    // The probe skips quantization — it only needs the geometry.
    let probe_cfg = fsl_hdnn::config::ModelConfig { clustered: false, ..mc.clone() };
    let model =
        ComputeEngine::open_or_synthetic_with(Backend::Native, &dir, probe_cfg)?.model().clone();
    // report what actually runs: clustering and worker sharding are
    // native-backend knobs the PJRT path ignores
    let (eff_workers, eff_clustered) = match backend {
        Backend::Native => (par.resolved_workers(), mc.clustered),
        Backend::Pjrt => (1, false),
    };
    if backend == Backend::Pjrt && (mc.clustered || par.workers != 1) {
        eprintln!("note: --clustered/--workers are native-backend knobs; PJRT ignores them");
    }
    println!(
        "backend={backend:?} model: {}x{}x{} -> F={} D={} | workers={eff_workers} \
         batched={batched} clustered={eff_clustered} | classifier={} hv_bits={hv_bits} metric={}",
        model.image_size,
        model.image_size,
        model.in_channels,
        model.feature_dim,
        model.d,
        cls.backend.name(),
        metric.name()
    );
    let dir2 = dir.clone();
    let mc2 = mc.clone();
    let coord = Coordinator::start_with_classifier(
        move || {
            Ok(ComputeEngine::open_or_synthetic_with(backend, &dir2, mc2)?.with_parallelism(par))
        },
        k_shot,
        cls,
    )?;
    let gen = ImageGen::new(model.image_size, 64.max(n_way), seed);
    let mut rng = Rng::new(seed);
    let mut accs = Vec::new();
    let mut blocks = Vec::new();
    for ep in 0..episodes {
        let classes = rng.choose_k(gen.n_classes, n_way);
        let sid = coord.create_session_full(n_way, hv_bits, metric, cls.backend)?;
        for (label, &cls) in classes.iter().enumerate() {
            if batched {
                let shots: Vec<Vec<f32>> =
                    (0..k_shot).map(|_| gen.sample(cls, &mut rng)).collect();
                coord.add_shot_batch(sid, label, shots)?;
            } else {
                for _ in 0..k_shot {
                    coord.add_shot(sid, label, gen.sample(cls, &mut rng))?;
                }
            }
        }
        coord.finish_training(sid)?;
        let mut pairs = Vec::new();
        for (label, &cls) in classes.iter().enumerate() {
            for _ in 0..queries {
                let out = coord.query(sid, gen.sample(cls, &mut rng), ee)?;
                pairs.push((out.prediction, label));
                blocks.push(out.blocks_used as f64);
            }
        }
        let acc = stats::accuracy(&pairs);
        accs.push(acc);
        println!("episode {ep}: accuracy {:.1}%", 100.0 * acc);
        coord.call(fsl_hdnn::coordinator::Request::CloseSession { session: sid });
    }
    let m = coord.metrics();
    println!(
        "\nmean accuracy {:.1}% ± {:.1} | avg blocks used {:.2}/{} | early-exit rate {:.0}%",
        100.0 * stats::mean(&accs),
        100.0 * stats::ci95(&accs),
        stats::mean(&blocks),
        model.n_branches(),
        100.0 * m.early_exit_rate
    );
    println!(
        "latency: add_shot {:.2} ms | train {:.2} ms | query {:.2} ms (max {:.2})",
        m.add_shot_ms_mean, m.train_ms_mean, m.query_ms_mean, m.query_ms_max
    );
    Ok(())
}

/// `serve`: one coordinator behind the TCP gateway, until killed. The
/// `[serving]` TOML section supplies defaults; `--addr`, `--high-water`,
/// `--max-frame-bytes` and `--deadline-ms` override. Model/engine knobs
/// mirror `episode`.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let mut rc = fsl_hdnn::config::RunConfig::default();
    if let Some(path) = args.kv.get("config") {
        let doc = fsl_hdnn::config::toml::Doc::load(std::path::Path::new(path))?;
        rc.apply_toml(&doc)?;
    }
    arm_faults(args, &rc)?;
    let (backend, cls_backend) = resolve_backends(args, &rc)?;
    let cls = ClassifierConfig {
        backend: cls_backend,
        ldc_d: args.get("ldc-d", rc.classifier.ldc_d),
    };
    let k_shot: usize = args.get("k-shot", rc.workload.k_shot);
    let par = ParallelConfig {
        workers: args.get("workers", rc.parallel.workers),
        min_batch_per_worker: args.get("min-batch-per-worker", rc.parallel.min_batch_per_worker),
    };
    let mut serving = rc.serving.clone();
    serving.addr = args.get_str("addr", &serving.addr);
    serving.high_water = args.get("high-water", serving.high_water);
    serving.max_frame_bytes = args.get("max-frame-bytes", serving.max_frame_bytes);
    serving.deadline_ms = args.get("deadline-ms", serving.deadline_ms);
    let mut mc = rc.model.clone();
    mc.clustered = args.get("clustered", mc.clustered);
    let dir = artifacts_dir(args);
    let coord = Coordinator::start_with_classifier(
        move || {
            Ok(ComputeEngine::open_or_synthetic_with(backend, &dir, mc)?.with_parallelism(par))
        },
        k_shot,
        cls,
    )?;
    let gateway = fsl_hdnn::coordinator::Gateway::bind(coord.client(), &serving)?;
    println!(
        "serving on {} (workers={}, high_water={}, k_shot={k_shot}, classifier={})",
        gateway.local_addr(),
        par.resolved_workers(),
        serving.high_water,
        cls.backend.name()
    );
    // serve until the process is killed; `gateway` and `coord` stay owned
    // for the whole loop so their drop-time shutdown chains remain intact.
    // --metrics-every N prints a snapshot every N seconds instead of
    // parking silently.
    let every: u64 = args.get("metrics-every", 0);
    loop {
        if every == 0 {
            std::thread::park();
        } else {
            std::thread::sleep(std::time::Duration::from_secs(every));
            let m = coord.metrics();
            println!(
                "queries={} query_ms_mean={:.3} shed={} depth={}",
                m.queries,
                m.query_ms_mean,
                m.requests_shed,
                coord.serving_load().queue_depth()
            );
        }
    }
}

fn cmd_sim(args: &Args) -> anyhow::Result<()> {
    let cfg = ChipConfig {
        freq_mhz: args.get("freq", 250.0),
        voltage: args.get("voltage", 1.2),
        hv_bits: args.get("hv-bits", 16),
        ..Default::default()
    };
    let chip = Chip::paper(cfg.clone());
    match args.get_str("task", "train").as_str() {
        "train" => {
            let batched: bool = args.get("batched", true);
            let n_way: usize = args.get("n-way", 10);
            let k_shot: usize = args.get("k-shot", 5);
            let r = chip.train_episode(n_way, k_shot, batched, args.get("ee-train", false));
            let mut t = Table::new(
                &format!(
                    "chip sim: {n_way}-way {k_shot}-shot training (batched={batched}, {} MHz, {} V)",
                    cfg.freq_mhz, cfg.voltage
                ),
                &["metric", "value"],
            );
            t.row(&["images".into(), r.images.to_string()]);
            t.row(&["cycles".into(), r.cycles.to_string()]);
            t.row(&["latency (ms/img)".into(), format!("{:.1}", r.latency_ms_per_image)]);
            t.row(&["energy (mJ/img)".into(), format!("{:.2}", r.energy_mj_per_image)]);
            t.row(&["avg power (mW)".into(), format!("{:.1}", r.avg_power_mw)]);
            t.row(&["PE utilization".into(), format!("{:.1}%", 100.0 * r.pe_utilization)]);
            t.row(&["TOPS/W".into(), format!("{:.2}", chip.tops_per_watt(&r))]);
            t.print();
        }
        "infer" => {
            let n_classes: usize = args.get("classes", 10);
            let mut t = Table::new(
                &format!("chip sim: inference ({} MHz, {} V)", cfg.freq_mhz, cfg.voltage),
                &["exit after block", "latency (ms)", "energy (mJ)", "conv layers"],
            );
            for s in 0..4 {
                let r = chip.infer_image(n_classes, Some(s));
                t.row(&[
                    (s + 1).to_string(),
                    format!("{:.2}", r.latency_ms),
                    format!("{:.3}", r.energy_mj),
                    format!("{}/{}", r.conv_layers_run, r.conv_layers_total),
                ]);
            }
            let full = chip.infer_image(n_classes, None);
            t.row(&[
                "none (full)".into(),
                format!("{:.2}", full.latency_ms),
                format!("{:.3}", full.energy_mj),
                format!("{}/{}", full.conv_layers_run, full.conv_layers_total),
            ]);
            t.print();
        }
        other => anyhow::bail!("unknown sim task {other} (train|infer)"),
    }
    Ok(())
}

fn cmd_check_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    let reg = ArtifactRegistry::open(&dir)?;
    println!("artifacts: {:?}", reg.entry_names());
    // run the goldens through the PJRT path
    let g = fsl_hdnn::util::json::Json::parse(&std::fs::read_to_string(
        dir.join("goldens").join("goldens.json"),
    )?)?;
    let shape = |k: &str| g.get("shapes").and_then(|s| s.get(k)).and_then(|v| v.as_usize_vec());
    let read_bin = |name: &str| -> anyhow::Result<Vec<f32>> {
        let bytes = std::fs::read(dir.join("goldens").join(name))?;
        Ok(bytes
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    };
    let xs = shape("x").ok_or_else(|| anyhow::anyhow!("missing x shape"))?;
    let x = read_bin("x.bin")?;
    let feats_want = read_bin("feats.bin")?;
    let fshape = shape("feats").unwrap();
    // run image 0 through fe_forward_b1
    let per_img = xs[1] * xs[2] * xs[3];
    let out = reg.exec_f32("fe_forward_b1", &[(&x[..per_img], &[1, xs[1], xs[2], xs[3]])])?;
    let got = &out[0];
    let want = &feats_want[..fshape[1] * fshape[2]];
    let mut max_err = 0f32;
    for (a, b) in got.iter().zip(want) {
        max_err = max_err.max((a - b).abs());
    }
    println!("fe_forward_b1 vs python golden: max |err| = {max_err:.2e}");
    anyhow::ensure!(max_err < 1e-3, "feature mismatch vs goldens");
    println!("check-artifacts OK ({} modules)", reg.entry_names().len());
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = artifacts_dir(args);
    match ArtifactRegistry::open(&dir) {
        Ok(reg) => {
            println!("model config (from {dir:?}): {:#?}", reg.model);
            println!("entries: {:?}", reg.entry_names());
        }
        Err(e) => println!("no artifacts ({e}); chip defaults:\n{:#?}", ChipConfig::default()),
    }
    Ok(())
}

fn main() {
    let args = Args::parse();
    let result = match args.cmd.as_str() {
        "episode" => cmd_episode(&args),
        "serve" => cmd_serve(&args),
        "sim" => cmd_sim(&args),
        "check-artifacts" => cmd_check_artifacts(&args),
        "info" => cmd_info(&args),
        _ => {
            println!(
                "usage: fsl-hdnn <episode|serve|sim|check-artifacts|info> [--key value ...]\n\
                 see doc comments in rust/src/main.rs"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
