//! The coordinator event loop: a worker thread owns the compute engine
//! (PJRT or native) and all session state; clients talk over an mpsc
//! channel exactly like a host driving the device.
//!
//! The worker also owns the persistent [`WorkerPool`] its batch sharding
//! runs on (installed with `pool::with_pool` around the event loop, so
//! every `shard_map` it triggers dispatches there), and a [`ServingLoad`]
//! signal shared with [`CoordinatorClient`] handles and the TCP gateway —
//! the admission-control input (DESIGN.md §Serving runtime). Dropping the
//! `Coordinator` joins the worker thread, which drops the pool, which
//! drains every queue and joins every pool thread: no detached threads
//! survive.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::classifier::ClassifierBackend;
use crate::config::{ClassifierConfig, EeConfig};
use crate::coordinator::batcher::ClassBatcher;
use crate::coordinator::early_exit::{EarlyExitController, EeDecision};
use crate::coordinator::metrics::{Metrics, Op};
use crate::coordinator::request::{Request, Response, DEVICE_UNAVAILABLE};
use crate::coordinator::session::{FslSession, QueryOutcome};
use crate::hdc::class_mem::{Allocation, ClassMemoryManager};
use crate::runtime::{pool, ComputeEngine, FeStageExec, WorkerPool};
use crate::util::parallel::{shard_map, shard_map_mut};

/// Live load signal shared by the coordinator handle, its clients and the
/// TCP gateway: outstanding requests (queued on the channel or in
/// service) plus tasks sitting in the worker pool's queues. The gateway
/// sheds with `Response::Busy` when [`ServingLoad::queue_depth`] exceeds
/// the configured high-water mark, and counts each shed here so
/// `GetMetrics` can report `requests_shed`.
#[derive(Debug, Default)]
pub struct ServingLoad {
    /// requests admitted and not yet answered (one [`LoadSlot`] each)
    requests: AtomicUsize,
    /// the coordinator pool's queued-task gauge (see
    /// [`WorkerPool::with_gauge`]); zero when the engine runs serial
    pool_tasks: Arc<AtomicUsize>,
    shed: AtomicU64,
}

impl ServingLoad {
    /// Current serving queue depth: admitted-but-unanswered requests plus
    /// pool tasks submitted and not yet finished.
    pub fn queue_depth(&self) -> usize {
        self.requests.load(Ordering::Acquire) + self.pool_tasks.load(Ordering::Acquire)
    }

    /// Count one request as outstanding until the returned slot drops.
    /// Every [`CoordinatorClient::call`] holds a slot for its duration;
    /// tests hold slots directly to model a backed-up queue without
    /// timing races.
    pub fn occupy(&self) -> LoadSlot<'_> {
        self.requests.fetch_add(1, Ordering::AcqRel);
        LoadSlot(self)
    }

    /// Record one request refused with `Response::Busy`.
    pub fn note_shed(&self) {
        self.shed.fetch_add(1, Ordering::AcqRel);
    }

    /// Total requests refused with `Response::Busy` so far.
    pub fn requests_shed(&self) -> u64 {
        self.shed.load(Ordering::Acquire)
    }

    /// The gauge the coordinator's worker pool reports queued tasks into.
    /// Exposed so a serving stack embedding its own [`WorkerPool`] (tests,
    /// future multi-pool fleets) can feed the same admission signal.
    pub fn pool_gauge(&self) -> Arc<AtomicUsize> {
        self.pool_tasks.clone()
    }
}

/// RAII token for one outstanding request (see [`ServingLoad::occupy`]).
pub struct LoadSlot<'a>(&'a ServingLoad);

impl Drop for LoadSlot<'_> {
    fn drop(&mut self) {
        self.0.requests.fetch_sub(1, Ordering::AcqRel);
    }
}

struct SessionState {
    session: FslSession,
    batcher: ClassBatcher<Vec<f32>>,
}

struct Worker {
    engine: ComputeEngine,
    k_shot: usize,
    /// server-side classifier defaults: LDC fold dimension (`0` = auto).
    /// The *backend* arrives per request on `CreateSession`; only the
    /// knobs a wire client cannot express live here.
    classifier: ClassifierConfig,
    sessions: HashMap<u64, SessionState>,
    next_id: u64,
    metrics: Metrics,
    /// models the chip's 256 KB class memory: sessions that do not fit on
    /// the device are rejected exactly like the hardware would
    class_mem: ClassMemoryManager,
    /// shared load signal — read here only to surface `requests_shed`
    /// (counted by the gateway) in metrics snapshots
    load: Arc<ServingLoad>,
}

impl Worker {
    /// Encode one raw feature vector (pad/validate against the model's F).
    /// Empty features are rejected — they would encode to a valid all-zero
    /// HV and silently train a garbage class prototype — and short features
    /// are zero-padded with the pad counted in the metrics.
    fn encode_feature(&mut self, feature: &[f32]) -> anyhow::Result<Vec<f32>> {
        let fdim = self.engine.model().feature_dim;
        anyhow::ensure!(
            !feature.is_empty(),
            "empty feature vector (an all-zero HV would train a garbage prototype)"
        );
        anyhow::ensure!(
            feature.len() <= fdim,
            "feature length {} exceeds model F={fdim}",
            feature.len()
        );
        if feature.len() < fdim {
            self.metrics.record_feature_pad(feature.len(), fdim);
        }
        let mut f = feature.to_vec();
        f.resize(fdim, 0.0);
        Ok(self.engine.encode(&[f])?.remove(0))
    }

    /// FE + encode for a batch of images -> per image per branch HVs.
    fn extract_hvs(&self, images: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
        let branches = self.engine.fe_forward(images)?;
        let nb = self.engine.model().n_branches();
        // flatten to one encode batch: image-major, branch-minor
        let mut feats = Vec::with_capacity(images.len() * nb);
        for image_branches in &branches {
            for f in image_branches {
                feats.push(f.clone());
            }
        }
        let hvs = self.engine.encode(&feats)?;
        Ok(hvs
            .chunks(nb)
            .map(|c| c.to_vec())
            .collect())
    }

    fn train_full_batch(
        &mut self,
        session_id: u64,
        class: usize,
        images: Vec<Vec<f32>>,
    ) -> anyhow::Result<()> {
        let shots_hvs = self.extract_hvs(&images)?;
        let st = self
            .sessions
            .get_mut(&session_id)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session_id}"))?;
        st.session.train_batch(class, &shots_hvs);
        Ok(())
    }

    /// Staged early-exit inference (DESIGN.md §Staged inference): FE
    /// stages, per-branch encode and the (E_s, E_c) controller
    /// interleave, so an exit at block *b* means stages *b+1..* are
    /// **never computed** and only *b+1* branch HVs are ever encoded.
    /// Without `ee`, every stage runs but only the final branch feature
    /// is encoded (the other branches feed nothing). Predictions are
    /// bit-identical to the post-hoc path
    /// ([`FslSession::query_early_exit`] over pre-extracted HVs).
    ///
    /// Batches run stage by stage over a **ragged survivor set**: every
    /// round steps the surviving images' FE executors one stage (sharded
    /// over the worker pool), encodes their branch features as one batch,
    /// classifies them through the shared branch model, and feeds each
    /// image's controller — images that exit drop out, so the batch
    /// shrinks as it deepens. Outcomes are bit-identical to serial
    /// one-image calls in input order, for any worker count (DESIGN.md
    /// §Threading model); `Request::Query` IS the one-image call, so the
    /// two requests share this single decision path.
    ///
    /// Split borrows (engine / session / metrics are disjoint `Worker`
    /// fields) keep the staged executors borrowing the engine while the
    /// session predicts.
    fn query_batch_staged(
        engine: &ComputeEngine,
        session: &mut FslSession,
        metrics: &mut Metrics,
        images: &[Vec<f32>],
        ee: Option<EeConfig>,
    ) -> anyhow::Result<Vec<QueryOutcome>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        let par = engine.parallelism();
        // stems run up front; the native fan-out captures the FeModel
        // (always Sync) rather than the engine, which with the `pjrt`
        // feature owns a thread-bound client — that backend instead takes
        // one batched whole-prefix fe_forward behind the same seam
        let mut execs: Vec<FeStageExec> = match engine {
            ComputeEngine::Native { fe, .. } => {
                shard_map(images, par.shards_for(images.len()), |img| fe.stage_start(img))?
                    .into_iter()
                    .map(FeStageExec::Native)
                    .collect()
            }
            ComputeEngine::Pjrt { .. } => {
                let m = engine.model();
                let layers_total = m.conv_layers_through(m.n_branches());
                engine
                    .fe_forward(images)?
                    .into_iter()
                    .map(|feats| FeStageExec::Whole { feats, next: 0, layers_total })
                    .collect()
            }
        };
        let n_stages = execs[0].n_stages();
        let mut ctls: Vec<Option<EarlyExitController>> =
            images.iter().map(|_| ee.map(EarlyExitController::new)).collect();
        let mut outcomes: Vec<Option<QueryOutcome>> = vec![None; images.len()];
        let mut hvs_encoded = 0u64;
        for stage in 0..n_stages {
            let last = stage + 1 == n_stages;
            // the ragged survivor set: images still in flight, input order
            let alive: Vec<usize> =
                (0..images.len()).filter(|&i| outcomes[i].is_none()).collect();
            if alive.is_empty() {
                break;
            }
            let mut survivors: Vec<&mut FeStageExec> = execs
                .iter_mut()
                .zip(&outcomes)
                .filter_map(|(e, o)| o.is_none().then_some(e))
                .collect();
            let feats: Vec<Vec<f32>> =
                shard_map_mut(&mut survivors, par.shards_for(alive.len()), |e| {
                    e.step()?.ok_or_else(|| anyhow::anyhow!("FE plan exhausted mid-batch"))
                })?;
            if ee.is_none() && !last {
                continue; // no-EE: nothing to encode until the final stage
            }
            let hvs = engine.encode(&feats)?;
            hvs_encoded += hvs.len() as u64;
            let preds = session.predict_branch_batch(stage, &hvs, par.shards_for(hvs.len()));
            for (k, &i) in alive.iter().enumerate() {
                let pred = preds[k];
                match &mut ctls[i] {
                    Some(c) => {
                        if let EeDecision::Exit(p) = c.feed(stage, pred) {
                            outcomes[i] = Some(QueryOutcome {
                                prediction: p,
                                blocks_used: stage + 1,
                                exited_early: !last,
                            });
                        } else if last {
                            outcomes[i] = Some(QueryOutcome {
                                prediction: pred,
                                blocks_used: n_stages,
                                exited_early: false,
                            });
                        }
                    }
                    None => {
                        outcomes[i] = Some(QueryOutcome {
                            prediction: pred,
                            blocks_used: n_stages,
                            exited_early: false,
                        });
                    }
                }
            }
        }
        let executed: u64 = execs.iter().map(|e| e.layers_run() as u64).sum();
        let plan = engine.fe_plan_layers() as u64 * images.len() as u64;
        metrics.record_query_work(executed, plan.saturating_sub(executed), hvs_encoded);
        outcomes
            .into_iter()
            .map(|o| o.ok_or_else(|| anyhow::anyhow!("query left without outcome")))
            .collect()
    }

    /// Serve requests until `Shutdown` arrives or every sender is gone.
    /// Runs inside `pool::with_pool` when the engine is parallel, so all
    /// `shard_map` calls made while handling requests dispatch to the
    /// coordinator-owned pool.
    fn event_loop(&mut self, rx: std::sync::mpsc::Receiver<(Request, Sender<Response>)>) {
        while let Ok((req, reply)) = rx.recv() {
            let shutdown = matches!(req, Request::Shutdown);
            let resp = self.handle(req);
            let _ = reply.send(resp);
            if shutdown {
                break;
            }
        }
    }

    fn handle(&mut self, req: Request) -> Response {
        // Fail-point sites fire *before* any session/batcher mutation, so a
        // request that draws an injected fault (or an injected panic that
        // kills this worker) has provably not executed — the router can
        // retry it after recovery without double-training a shot. Disarmed,
        // each check is a single atomic load (util::failpoint).
        let site = match &req {
            Request::AddShot { .. }
            | Request::AddShotBatch { .. }
            | Request::AddFeatureShot { .. }
            | Request::FinishTraining { .. } => Some("device.train"),
            Request::Query { .. } | Request::QueryBatch { .. } | Request::QueryFeature { .. } => {
                Some("device.query")
            }
            _ => None,
        };
        if let Some(site) = site {
            if let Err(e) = crate::util::failpoint::check(site) {
                self.metrics.errors += 1;
                return Response::RetryableError(e.to_string());
            }
        }
        match req {
            Request::CreateSession { n_way, hv_bits, metric, backend } => {
                // reject malformed geometry here: it used to slip into the
                // session and panic the worker (the hv_bits bug class) —
                // a zero-way session would assert inside FslSession::new
                if !(1..=16).contains(&hv_bits) {
                    self.metrics.errors += 1;
                    return Response::Error(format!("hv_bits must be 1..=16, got {hv_bits}"));
                }
                if n_way == 0 {
                    self.metrics.errors += 1;
                    return Response::Error("n_way must be >= 1".into());
                }
                let model = self.engine.model();
                if model.d == 0 {
                    self.metrics.errors += 1;
                    return Response::Error("model HV dimension d must be >= 1".into());
                }
                let ldc_d = self.classifier.ldc_d;
                if backend == ClassifierBackend::Ldc && ldc_d > model.d {
                    self.metrics.errors += 1;
                    return Response::Error(format!(
                        "ldc_d {ldc_d} exceeds encoder dimension D={}",
                        model.d
                    ));
                }
                let id = self.next_id;
                let session = FslSession::new(id, n_way, model.d, model.n_branches())
                    .with_precision(hv_bits)
                    .with_metric(metric)
                    .with_backend(backend, ldc_d);
                // sessions are admitted through the class-memory manager:
                // what does not fit on chip (32 @ 16-bit, 128 @ 4-bit at
                // D=4096, scaled by EE branches) is rejected like hardware.
                // `d` here is the *stored* dimension — the HDC backend
                // stores full-D class HVs, LDC stores folded prototypes,
                // so an LDC session charges ~8x fewer bits at D=4096.
                let alloc = Allocation {
                    session: id,
                    n_classes: n_way,
                    n_branches: model.n_branches(),
                    hv_bits,
                    d: session.stored_dim(),
                };
                if let Err(e) = self.class_mem.allocate(alloc) {
                    self.metrics.errors += 1;
                    return Response::Error(e.to_string());
                }
                self.next_id += 1;
                self.sessions.insert(
                    id,
                    SessionState { session, batcher: ClassBatcher::new(self.k_shot) },
                );
                Response::SessionCreated { session: id }
            }
            Request::AddShot { session, class, image } => {
                let t0 = Instant::now();
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                if class >= st.session.n_way {
                    self.metrics.errors += 1;
                    return Response::Error(format!(
                        "class {class} out of range for {}-way session",
                        st.session.n_way
                    ));
                }
                let maybe_batch = st.batcher.push(class, image);
                if let Some(batch) = maybe_batch {
                    if let Err(e) = self.train_full_batch(session, batch.class, batch.items) {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                }
                // re-borrow after train_full_batch; a missing entry here
                // means the train path dropped the session, which the
                // client should see as an error, not a dead worker
                let Some(st) = self.sessions.get(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("session {session} lost during training"));
                };
                self.metrics.record(Op::AddShot, t0.elapsed().as_secs_f64());
                Response::ShotAccepted {
                    session,
                    pending: st.batcher.pending_shots(),
                    trained_classes: st.session.shots_seen / self.k_shot.max(1),
                }
            }
            Request::AddShotBatch { session, class, images } => {
                let t0 = Instant::now();
                let n = images.len();
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                if class >= st.session.n_way {
                    self.metrics.errors += 1;
                    return Response::Error(format!(
                        "class {class} out of range for {}-way session",
                        st.session.n_way
                    ));
                }
                // same k-shot flush semantics as per-shot arrival; full
                // batches reach train_full_batch (and with it the engine's
                // batched, worker-sharded FE path) in one call each
                let mut full = Vec::new();
                for image in images {
                    if let Some(batch) = st.batcher.push(class, image) {
                        full.push(batch);
                    }
                }
                for batch in full {
                    if let Err(e) = self.train_full_batch(session, batch.class, batch.items) {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                }
                let Some(st) = self.sessions.get(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("session {session} lost during training"));
                };
                self.metrics.record_batch(Op::AddShot, n, t0.elapsed().as_secs_f64());
                Response::ShotAccepted {
                    session,
                    pending: st.batcher.pending_shots(),
                    trained_classes: st.session.shots_seen / self.k_shot.max(1),
                }
            }
            Request::AddFeatureShot { session, class, feature } => {
                let t0 = Instant::now();
                let hv = match self.encode_feature(&feature) {
                    Ok(h) => h,
                    Err(e) => {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                };
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                if class >= st.session.n_way {
                    self.metrics.errors += 1;
                    return Response::Error(format!("class {class} out of range"));
                }
                // raw-feature input bypasses the FE (Fig. 7): every branch
                // sees the same classifier input, so all branch models get
                // the identical HV — EE queries stay well-defined
                let hvs = vec![hv; st.session.n_branches];
                st.session.train_shot(class, &hvs);
                self.metrics.record(Op::AddShot, t0.elapsed().as_secs_f64());
                Response::ShotAccepted {
                    session,
                    pending: st.batcher.pending_shots(),
                    trained_classes: st.session.shots_seen / self.k_shot.max(1),
                }
            }
            Request::QueryFeature { session, feature } => {
                let t0 = Instant::now();
                let hv = match self.encode_feature(&feature) {
                    Ok(h) => h,
                    Err(e) => {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                };
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                let outcome = st.session.query_full(&hv);
                // feature-mode queries bypass the FE entirely: one encode,
                // zero conv layers, and no entry in the exit-depth
                // histogram (which prices FE work by depth)
                self.metrics.record_query_work(0, 0, 1);
                self.metrics.record(Op::Query, t0.elapsed().as_secs_f64());
                self.metrics.record_feature_query_depth(outcome.blocks_used);
                Response::QueryResult { session, outcome }
            }
            Request::FinishTraining { session } => {
                let t0 = Instant::now();
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                let partials = st.batcher.flush_all();
                for batch in partials {
                    if let Err(e) = self.train_full_batch(session, batch.class, batch.items) {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                }
                let Some(st) = self.sessions.get(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("session {session} lost during training"));
                };
                let shots = st.session.shots_seen;
                self.metrics.record(Op::Train, t0.elapsed().as_secs_f64());
                Response::TrainingDone { session, shots }
            }
            Request::Query { session, image, ee } => {
                let t0 = Instant::now();
                // client-supplied (E_s, E_c) is validated at the request
                // boundary: a zero field used to panic the worker thread
                // inside EarlyExitController::new (the hv_bits bug class)
                if let Some(cfg) = &ee {
                    if let Err(e) = cfg.validate() {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                }
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                // one image through the shared staged decision path
                let outcome = match Self::query_batch_staged(
                    &self.engine,
                    &mut st.session,
                    &mut self.metrics,
                    std::slice::from_ref(&image),
                    ee,
                ) {
                    Ok(mut o) => o.remove(0),
                    Err(e) => {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                };
                self.metrics.record(Op::Query, t0.elapsed().as_secs_f64());
                self.metrics.record_query_depth(outcome.blocks_used, outcome.exited_early);
                Response::QueryResult { session, outcome }
            }
            Request::QueryBatch { session, images, ee } => {
                let t0 = Instant::now();
                if let Some(cfg) = &ee {
                    if let Err(e) = cfg.validate() {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                }
                let n = images.len();
                let Some(st) = self.sessions.get_mut(&session) else {
                    self.metrics.errors += 1;
                    return Response::Error(format!("unknown session {session}"));
                };
                let outcomes = match Self::query_batch_staged(
                    &self.engine,
                    &mut st.session,
                    &mut self.metrics,
                    &images,
                    ee,
                ) {
                    Ok(o) => o,
                    Err(e) => {
                        self.metrics.errors += 1;
                        return Response::Error(e.to_string());
                    }
                };
                self.metrics.record_batch(Op::Query, n, t0.elapsed().as_secs_f64());
                for o in &outcomes {
                    self.metrics.record_query_depth(o.blocks_used, o.exited_early);
                }
                Response::QueryBatchResult { session, outcomes }
            }
            Request::CloseSession { session } => {
                if self.sessions.remove(&session).is_some() {
                    self.class_mem.release(session);
                    Response::SessionClosed { session }
                } else {
                    Response::Error(format!("unknown session {session}"))
                }
            }
            Request::GetMetrics => {
                let mut snap = self.metrics.snapshot();
                // bank-gating view of the class memory (Fig. 9): occupancy
                // decides how many of the 16 banks stay powered; the
                // energy model turns gated banks into saved standby mW
                // (sim::energy::EnergyModel::class_mem_static_mw)
                snap.class_mem_used_bits = self.class_mem.used_bits();
                snap.class_mem_active_banks = self.class_mem.active_banks();
                snap.class_mem_gated_banks = self.class_mem.gated_banks();
                // admission control happens at the gateway, before the
                // worker ever sees a request — the count lives in the
                // shared load signal, not in worker-owned Metrics
                snap.requests_shed = self.load.requests_shed();
                Response::Metrics(snap)
            }
            Request::Shutdown => Response::ShuttingDown,
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    client: CoordinatorClient,
    handle: Option<JoinHandle<()>>,
}

/// Cloneable client handle: the request channel plus the shared load
/// signal. This is what the TCP gateway's connection handlers hold — they
/// must outlive no part of the `Coordinator` itself, which keeps worker
/// shutdown (a `Coordinator::drop` concern) in exactly one place.
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: Sender<(Request, Sender<Response>)>,
    load: Arc<ServingLoad>,
}

impl CoordinatorClient {
    /// Synchronous request/response. Holds a [`LoadSlot`] for the full
    /// round trip, so the serving queue depth counts in-service requests.
    ///
    /// A dead worker (send fails: the thread exited and dropped its
    /// receiver) or a worker that crashed mid-request (the reply sender
    /// was dropped during an unwind) both come back as a
    /// [`Response::RetryableError`] carrying the [`DEVICE_UNAVAILABLE`]
    /// prefix — the signal the [`crate::coordinator::DeviceRouter`] keys
    /// device death and session re-placement off.
    pub fn call(&self, req: Request) -> Response {
        let _slot = self.load.occupy();
        let (rtx, rrx) = channel();
        if self.tx.send((req, rtx)).is_err() {
            return Response::RetryableError(format!("{DEVICE_UNAVAILABLE}: coordinator stopped"));
        }
        rrx.recv().unwrap_or_else(|_| {
            Response::RetryableError(format!(
                "{DEVICE_UNAVAILABLE}: worker dropped the reply (crashed mid-request?)"
            ))
        })
    }

    /// [`CoordinatorClient::call`] with a per-request deadline: if the
    /// worker has not answered within `deadline`, give up and return a
    /// retryable deadline error. The worker still finishes the request
    /// eventually (its reply lands in a dropped channel); the deadline
    /// bounds *caller* latency, it does not cancel device work — which is
    /// why the error is retryable but NOT marked device-unavailable: a
    /// slow device is not a dead one.
    pub fn call_deadline(&self, req: Request, deadline: std::time::Duration) -> Response {
        let _slot = self.load.occupy();
        let (rtx, rrx) = channel();
        if self.tx.send((req, rtx)).is_err() {
            return Response::RetryableError(format!("{DEVICE_UNAVAILABLE}: coordinator stopped"));
        }
        match rrx.recv_timeout(deadline) {
            Ok(resp) => resp,
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => Response::RetryableError(format!(
                "deadline of {} ms exceeded",
                deadline.as_millis()
            )),
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => Response::RetryableError(
                format!("{DEVICE_UNAVAILABLE}: worker dropped the reply (crashed mid-request?)"),
            ),
        }
    }

    /// The load signal admission control reads (shared with the
    /// coordinator that created this client).
    pub fn load(&self) -> &ServingLoad {
        &self.load
    }
}

impl Coordinator {
    /// Spawn the worker thread. The engine is *constructed inside* the
    /// worker (PJRT clients are not `Send`); `factory` runs there once and
    /// any construction error is reported back before `start` returns.
    /// When the engine's [`crate::config::ParallelConfig`] resolves to
    /// more than one worker, the thread also builds the persistent
    /// [`WorkerPool`] its `shard_map` calls run on and installs it for the
    /// lifetime of the event loop.
    pub fn start<F>(factory: F, k_shot: usize) -> anyhow::Result<Self>
    where
        F: FnOnce() -> anyhow::Result<ComputeEngine> + Send + 'static,
    {
        Self::start_with_classifier(factory, k_shot, ClassifierConfig::default())
    }

    /// [`Coordinator::start`] with explicit server-side classifier
    /// defaults (`[classifier]` in the TOML presets): the LDC fold
    /// dimension applied to every `backend = ldc` session this worker
    /// creates. The backend itself still arrives per `CreateSession`.
    pub fn start_with_classifier<F>(
        factory: F,
        k_shot: usize,
        classifier: ClassifierConfig,
    ) -> anyhow::Result<Self>
    where
        F: FnOnce() -> anyhow::Result<ComputeEngine> + Send + 'static,
    {
        let (tx, rx) = channel::<(Request, Sender<Response>)>();
        let (ready_tx, ready_rx) = channel::<Result<(), String>>();
        let load = Arc::new(ServingLoad::default());
        let worker_load = load.clone();
        let handle = std::thread::spawn(move || {
            let engine = match factory() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let shards = engine.parallelism().resolved_workers();
            let mut worker = Worker {
                engine,
                k_shot,
                classifier,
                sessions: HashMap::new(),
                next_id: 1,
                metrics: Metrics::default(),
                class_mem: ClassMemoryManager::paper(),
                load: worker_load.clone(),
            };
            if shards > 1 {
                // the long-lived pool replaces per-call thread spawning;
                // owned by this thread, so the drop below (after the event
                // loop exits) drains its queues and joins its workers —
                // that is what `Coordinator::drop` waits on via the thread
                // join
                let pool = WorkerPool::with_gauge(shards, worker_load.pool_gauge());
                pool::with_pool(&pool, || worker.event_loop(rx));
            } else {
                worker.event_loop(rx);
            }
        });
        match ready_rx.recv() {
            Ok(Ok(())) => {
                Ok(Coordinator { client: CoordinatorClient { tx, load }, handle: Some(handle) })
            }
            Ok(Err(e)) => {
                let _ = handle.join();
                anyhow::bail!("engine construction failed: {e}")
            }
            Err(_) => anyhow::bail!("coordinator worker died during startup"),
        }
    }

    /// Synchronous request/response.
    pub fn call(&self, req: Request) -> Response {
        self.client.call(req)
    }

    /// A cloneable client (request channel + load signal) for the TCP
    /// gateway and anything else that must issue requests without owning
    /// the coordinator's lifetime.
    pub fn client(&self) -> CoordinatorClient {
        self.client.clone()
    }

    /// The serving load signal (admission control, tests).
    pub fn serving_load(&self) -> Arc<ServingLoad> {
        self.client.load.clone()
    }

    /// Convenience wrappers -----------------------------------------------

    pub fn create_session(&self, n_way: usize, hv_bits: u32) -> anyhow::Result<u64> {
        self.create_session_with(n_way, hv_bits, crate::hdc::Distance::L1)
    }

    /// [`Coordinator::create_session`] with an explicit distance metric
    /// (the chip's datapath is L1; hamming pairs with 1-bit class HVs).
    pub fn create_session_with(
        &self,
        n_way: usize,
        hv_bits: u32,
        metric: crate::hdc::Distance,
    ) -> anyhow::Result<u64> {
        self.create_session_full(n_way, hv_bits, metric, ClassifierBackend::Hdc)
    }

    /// Fully explicit session creation: metric *and* classifier backend
    /// (`hdc` full-D class HVs or `ldc` folded low-D prototypes).
    pub fn create_session_full(
        &self,
        n_way: usize,
        hv_bits: u32,
        metric: crate::hdc::Distance,
        backend: ClassifierBackend,
    ) -> anyhow::Result<u64> {
        match self.call(Request::CreateSession { n_way, hv_bits, metric, backend }) {
            Response::SessionCreated { session } => Ok(session),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn add_shot(&self, session: u64, class: usize, image: Vec<f32>) -> anyhow::Result<()> {
        match self.call(Request::AddShot { session, class, image }) {
            Response::ShotAccepted { .. } => Ok(()),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    /// Submit a whole class batch in one request (Fig. 12 batched
    /// single-pass training); full k-shot groups train through the
    /// engine's batched FE entry point.
    pub fn add_shot_batch(
        &self,
        session: u64,
        class: usize,
        images: Vec<Vec<f32>>,
    ) -> anyhow::Result<()> {
        match self.call(Request::AddShotBatch { session, class, images }) {
            Response::ShotAccepted { .. } => Ok(()),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn finish_training(&self, session: u64) -> anyhow::Result<usize> {
        match self.call(Request::FinishTraining { session }) {
            Response::TrainingDone { shots, .. } => Ok(shots),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn query(
        &self,
        session: u64,
        image: Vec<f32>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<crate::coordinator::session::QueryOutcome> {
        match self.call(Request::Query { session, image, ee }) {
            Response::QueryResult { outcome, .. } => Ok(outcome),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    /// Classify a whole batch in one request: staged early exit per image
    /// over the ragged survivor set, bit-identical to serial
    /// [`Coordinator::query`] calls (outcomes in input order).
    pub fn query_batch(
        &self,
        session: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<Vec<crate::coordinator::session::QueryOutcome>> {
        match self.call(Request::QueryBatch { session, images, ee }) {
            Response::QueryBatchResult { outcomes, .. } => Ok(outcomes),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn metrics(&self) -> crate::coordinator::metrics::MetricsSnapshot {
        match self.call(Request::GetMetrics) {
            Response::Metrics(m) => m,
            _ => Default::default(),
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let (rtx, _rrx) = channel();
        let _ = self.client.tx.send((Request::Shutdown, rtx));
        // joining the worker thread transitively joins the pool: the event
        // loop returns, `with_pool` unwinds, and the pool's Drop drains
        // every task queue and joins every long-lived worker
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}
