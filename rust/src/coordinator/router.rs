//! Multi-device router: fans few-shot sessions out over a fleet of
//! FSL-HDnn devices (coordinators), vLLM-router style. Edge deployments
//! gang several accelerators behind one endpoint; the router places each
//! new session on the least-loaded device (class-memory pressure counts
//! as load) and pins all of a session's traffic to its device.

use std::collections::HashMap;

use crate::config::EeConfig;
use crate::coordinator::server::Coordinator;
use crate::coordinator::session::QueryOutcome;
use crate::runtime::ComputeEngine;

/// Routing policy for new sessions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    RoundRobin,
    LeastLoaded,
}

/// A routed session id: (device index, device-local session id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RoutedSession {
    pub device: usize,
    pub local: u64,
}

/// The router: owns `n` coordinators and the session placement table.
pub struct DeviceRouter {
    devices: Vec<Coordinator>,
    policy: Placement,
    /// open sessions per device (load proxy)
    load: Vec<usize>,
    /// global session id -> placement
    table: HashMap<u64, RoutedSession>,
    next_global: u64,
    rr_next: usize,
}

impl DeviceRouter {
    /// Spawn `n_devices` coordinators from a factory-of-factories (each
    /// device's engine is constructed inside its own worker thread).
    pub fn start<F, G>(
        n_devices: usize,
        k_shot: usize,
        policy: Placement,
        make: F,
    ) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> G,
        G: FnOnce() -> anyhow::Result<ComputeEngine> + Send + 'static,
    {
        anyhow::ensure!(n_devices >= 1, "need at least one device");
        let mut devices = Vec::with_capacity(n_devices);
        for i in 0..n_devices {
            devices.push(Coordinator::start(make(i), k_shot)?);
        }
        Ok(DeviceRouter {
            load: vec![0; n_devices],
            devices,
            policy,
            table: HashMap::new(),
            next_global: 1,
            rr_next: 0,
        })
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    fn pick_device(&mut self) -> usize {
        match self.policy {
            Placement::RoundRobin => {
                let d = self.rr_next % self.devices.len();
                self.rr_next += 1;
                d
            }
            Placement::LeastLoaded => {
                let mut best = 0;
                for (i, &l) in self.load.iter().enumerate() {
                    if l < self.load[best] {
                        best = i;
                    }
                }
                best
            }
        }
    }

    /// Create a session somewhere in the fleet; on a full device, falls
    /// back to any device with room (backpressure surfaces only when the
    /// whole fleet is out of class memory).
    pub fn create_session(&mut self, n_way: usize, hv_bits: u32) -> anyhow::Result<u64> {
        self.create_session_with(n_way, hv_bits, crate::hdc::Distance::L1)
    }

    /// [`DeviceRouter::create_session`] with an explicit distance metric.
    pub fn create_session_with(
        &mut self,
        n_way: usize,
        hv_bits: u32,
        metric: crate::hdc::Distance,
    ) -> anyhow::Result<u64> {
        self.create_session_full(n_way, hv_bits, metric, crate::classifier::ClassifierBackend::Hdc)
    }

    /// Fully explicit placement: metric *and* classifier backend. An LDC
    /// session charges its folded (low-D) footprint to the device's class
    /// memory, so mixed fleets pack many more LDC sessions per device.
    pub fn create_session_full(
        &mut self,
        n_way: usize,
        hv_bits: u32,
        metric: crate::hdc::Distance,
        backend: crate::classifier::ClassifierBackend,
    ) -> anyhow::Result<u64> {
        let first = self.pick_device();
        let n = self.devices.len();
        let mut last_err = None;
        for off in 0..n {
            let d = (first + off) % n;
            match self.devices[d].create_session_full(n_way, hv_bits, metric, backend) {
                Ok(local) => {
                    let gid = self.next_global;
                    self.next_global += 1;
                    self.table.insert(gid, RoutedSession { device: d, local });
                    self.load[d] += 1;
                    return Ok(gid);
                }
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no devices")))
    }

    fn route(&self, session: u64) -> anyhow::Result<RoutedSession> {
        self.table
            .get(&session)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown routed session {session}"))
    }

    pub fn placement(&self, session: u64) -> Option<RoutedSession> {
        self.table.get(&session).copied()
    }

    pub fn add_shot(&self, session: u64, class: usize, image: Vec<f32>) -> anyhow::Result<()> {
        let r = self.route(session)?;
        self.devices[r.device].add_shot(r.local, class, image)
    }

    /// Route a whole class batch to the session's device in one request,
    /// so batched single-pass training crosses the fleet boundary as one
    /// message and hits the device's batched (worker-sharded) FE path.
    pub fn add_shot_batch(
        &self,
        session: u64,
        class: usize,
        images: Vec<Vec<f32>>,
    ) -> anyhow::Result<()> {
        let r = self.route(session)?;
        self.devices[r.device].add_shot_batch(r.local, class, images)
    }

    pub fn finish_training(&self, session: u64) -> anyhow::Result<usize> {
        let r = self.route(session)?;
        self.devices[r.device].finish_training(r.local)
    }

    pub fn query(
        &self,
        session: u64,
        image: Vec<f32>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<QueryOutcome> {
        let r = self.route(session)?;
        self.devices[r.device].query(r.local, image, ee)
    }

    /// Route a whole query batch to the session's device in one request —
    /// the inference mirror of [`DeviceRouter::add_shot_batch`]: the
    /// device runs the staged ragged-survivor loop over its worker pool.
    pub fn query_batch(
        &self,
        session: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<Vec<QueryOutcome>> {
        let r = self.route(session)?;
        self.devices[r.device].query_batch(r.local, images, ee)
    }

    pub fn close_session(&mut self, session: u64) -> anyhow::Result<()> {
        let r = self.route(session)?;
        self.devices[r.device]
            .call(crate::coordinator::request::Request::CloseSession { session: r.local });
        self.load[r.device] = self.load[r.device].saturating_sub(1);
        self.table.remove(&session);
        Ok(())
    }

    /// Per-device open-session counts.
    pub fn loads(&self) -> &[usize] {
        &self.load
    }

    /// Aggregate metrics across the fleet.
    pub fn fleet_metrics(&self) -> Vec<crate::coordinator::metrics::MetricsSnapshot> {
        self.devices.iter().map(|d| d.metrics()).collect()
    }
}

#[cfg(test)]
mod tests {
    // Router tests that need a real engine live in
    // rust/tests/integration_coordinator.rs; placement arithmetic is
    // covered there too (it needs running devices).
}
