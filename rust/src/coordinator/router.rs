//! Multi-device router: fans few-shot sessions out over a fleet of
//! FSL-HDnn devices (coordinators), vLLM-router style. Edge deployments
//! gang several accelerators behind one endpoint; the router places each
//! new session on the least-loaded device (class-memory pressure counts
//! as load) and pins all of a session's traffic to its device.
//!
//! The router is also the fleet's fault domain (DESIGN.md §Fault model):
//! it tracks per-device health (Healthy / Suspect / Dead / Probation),
//! keeps a shot journal per session, and when a device dies — its worker
//! thread panicked or its channel closed — re-places every session that
//! lived there onto the least-loaded surviving devices, replaying each
//! journal through the normal request path. Because single-pass HDC/LDC
//! training has no state beyond the retained shots, the retrained class
//! memory is **bit-identical** to the never-failed run, and the request
//! that observed the failure is retried exactly once (fail points fire
//! before any mutation, so the failed request provably never executed).

use std::collections::HashMap;
use std::time::Instant;

use crate::classifier::ClassifierBackend;
use crate::config::EeConfig;
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::request::{Request, Response, DEVICE_UNAVAILABLE};
use crate::coordinator::server::Coordinator;
use crate::coordinator::session::QueryOutcome;
use crate::hdc::Distance;
use crate::runtime::ComputeEngine;

/// Routing policy for new sessions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Placement {
    RoundRobin,
    LeastLoaded,
}

/// A routed session id: (device index, device-local session id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RoutedSession {
    pub device: usize,
    pub local: u64,
}

/// Device health as the router sees it.
///
/// `Healthy --soft fault--> Suspect --strikes/unavailable--> Dead`;
/// a Dead device revived through [`DeviceRouter::revive`] re-enters as
/// `Probation`, where its first successful call promotes it to Healthy
/// and its first fault of any kind kills it again (no strike allowance).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceHealth {
    Healthy,
    /// Served a retryable fault recently; still placeable, but striking
    /// out ([`DEAD_AFTER_STRIKES`]) declares it Dead.
    Suspect,
    /// Worker gone. Its sessions were re-placed; it takes no traffic
    /// until [`DeviceRouter::revive`].
    Dead,
    /// Freshly revived: one fault away from Dead, one success from
    /// Healthy.
    Probation,
}

/// Consecutive soft (retryable, non-fatal) faults before a Suspect device
/// is declared Dead and its sessions are re-placed.
pub const DEAD_AFTER_STRIKES: u32 = 3;

/// Fault-recovery counters, reported through the fleet metrics snapshot
/// ([`DeviceRouter::fleet_snapshot`]) since the failed device itself can
/// no longer answer `GetMetrics`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RouterMetrics {
    /// devices declared Dead (a revive that fails again counts again)
    pub device_failures: u64,
    /// sessions successfully re-placed and retrained from their journal
    pub sessions_replaced: u64,
    /// total wall time spent replaying shot journals, milliseconds
    pub retrain_ms: f64,
}

/// One record in a session's shot journal — the router-side training
/// history, retained so a dead device's sessions can be rebuilt on a
/// surviving one by replaying the exact request sequence. Replay order
/// equals arrival order, so the k-shot batcher flushes at the same points
/// and the rebuilt class memory is bit-identical.
#[derive(Clone, Debug)]
enum ShotRecord {
    Shot { class: usize, image: Vec<f32> },
    Batch { class: usize, images: Vec<Vec<f32>> },
    Finish,
}

#[derive(Clone, Debug)]
struct SessionJournal {
    n_way: usize,
    hv_bits: u32,
    metric: Distance,
    backend: ClassifierBackend,
    records: Vec<ShotRecord>,
}

type BoxedEngineFactory = Box<dyn FnOnce() -> anyhow::Result<ComputeEngine> + Send + 'static>;

struct Device {
    /// `None` once the device is Dead (dropping the handle joins its
    /// worker thread, so no stray threads outlive the failure).
    coord: Option<Coordinator>,
    health: DeviceHealth,
    strikes: u32,
}

/// The router: owns `n` coordinators, the session placement table, the
/// per-session shot journals, and the per-device health state.
pub struct DeviceRouter {
    devices: Vec<Device>,
    /// respawns a device's engine for [`DeviceRouter::revive`]
    factory: Box<dyn Fn(usize) -> BoxedEngineFactory>,
    k_shot: usize,
    policy: Placement,
    /// open sessions per device (load proxy)
    load: Vec<usize>,
    /// global session id -> placement
    table: HashMap<u64, RoutedSession>,
    journals: HashMap<u64, SessionJournal>,
    metrics: RouterMetrics,
    next_global: u64,
    rr_next: usize,
}

impl DeviceRouter {
    /// Spawn `n_devices` coordinators from a factory-of-factories (each
    /// device's engine is constructed inside its own worker thread). The
    /// factory is retained so a Dead device can be respawned later
    /// ([`DeviceRouter::revive`]).
    pub fn start<F, G>(
        n_devices: usize,
        k_shot: usize,
        policy: Placement,
        make: F,
    ) -> anyhow::Result<Self>
    where
        F: Fn(usize) -> G + 'static,
        G: FnOnce() -> anyhow::Result<ComputeEngine> + Send + 'static,
    {
        anyhow::ensure!(n_devices >= 1, "need at least one device");
        let factory: Box<dyn Fn(usize) -> BoxedEngineFactory> =
            Box::new(move |i| Box::new(make(i)) as BoxedEngineFactory);
        let mut devices = Vec::with_capacity(n_devices);
        for i in 0..n_devices {
            devices.push(Device {
                coord: Some(Coordinator::start(factory(i), k_shot)?),
                health: DeviceHealth::Healthy,
                strikes: 0,
            });
        }
        Ok(DeviceRouter {
            load: vec![0; n_devices],
            devices,
            factory,
            k_shot,
            policy,
            table: HashMap::new(),
            journals: HashMap::new(),
            metrics: RouterMetrics::default(),
            next_global: 1,
            rr_next: 0,
        })
    }

    pub fn n_devices(&self) -> usize {
        self.devices.len()
    }

    /// Current health of device `d`.
    pub fn health(&self, d: usize) -> DeviceHealth {
        self.devices[d].health
    }

    /// Fault-recovery counters (also folded into
    /// [`DeviceRouter::fleet_snapshot`]).
    pub fn metrics(&self) -> RouterMetrics {
        self.metrics
    }

    fn alive(&self, d: usize) -> bool {
        self.devices[d].health != DeviceHealth::Dead && self.devices[d].coord.is_some()
    }

    fn pick_device(&mut self) -> usize {
        match self.policy {
            Placement::RoundRobin => {
                // skip Dead devices; bounded by the fleet size
                for _ in 0..self.devices.len() {
                    let d = self.rr_next % self.devices.len();
                    self.rr_next += 1;
                    if self.alive(d) {
                        return d;
                    }
                }
                0
            }
            Placement::LeastLoaded => {
                let mut best = 0;
                let mut best_load = usize::MAX;
                for (i, &l) in self.load.iter().enumerate() {
                    if self.alive(i) && l < best_load {
                        best = i;
                        best_load = l;
                    }
                }
                best
            }
        }
    }

    /// A device fault was observed. Returns `true` if the device must now
    /// be declared Dead: Probation devices get no strike allowance, others
    /// strike out at [`DEAD_AFTER_STRIKES`].
    fn strike(&mut self, d: usize) -> bool {
        let dev = &mut self.devices[d];
        match dev.health {
            DeviceHealth::Dead => true,
            DeviceHealth::Probation => true,
            DeviceHealth::Healthy | DeviceHealth::Suspect => {
                dev.health = DeviceHealth::Suspect;
                dev.strikes += 1;
                dev.strikes >= DEAD_AFTER_STRIKES
            }
        }
    }

    fn note_success(&mut self, d: usize) {
        let dev = &mut self.devices[d];
        if matches!(dev.health, DeviceHealth::Suspect | DeviceHealth::Probation) {
            dev.health = DeviceHealth::Healthy;
        }
        dev.strikes = 0;
    }

    /// Declare device `d` Dead, join its worker, and re-place every
    /// session it hosted onto surviving devices (journal retrain).
    fn fail_device(&mut self, d: usize) {
        if self.devices[d].health == DeviceHealth::Dead {
            return;
        }
        self.devices[d].health = DeviceHealth::Dead;
        self.devices[d].strikes = 0;
        // dropping the handle sends Shutdown (a no-op if the worker is
        // already gone) and joins the thread — no stray threads survive
        self.devices[d].coord = None;
        self.load[d] = 0;
        self.metrics.device_failures += 1;
        self.replace_sessions_of(d);
    }

    fn replace_sessions_of(&mut self, dead: usize) {
        let sids: Vec<u64> = self
            .table
            .iter()
            .filter(|(_, r)| r.device == dead)
            .map(|(s, _)| *s)
            .collect();
        if sids.is_empty() {
            return;
        }
        let t0 = Instant::now();
        for sid in sids {
            match self.replace_session(sid) {
                Ok(()) => self.metrics.sessions_replaced += 1,
                Err(e) => {
                    // nowhere to put it: drop the route so callers get a
                    // clean "unknown routed session" instead of a wedge
                    self.table.remove(&sid);
                    self.journals.remove(&sid);
                    eprintln!("[router] session {sid} lost with device {dead}: {e}");
                }
            }
        }
        self.metrics.retrain_ms += t0.elapsed().as_secs_f64() * 1e3;
    }

    /// Re-place one session: pick the least-loaded live device not yet
    /// tried, re-create the session there, and replay its journal.
    fn replace_session(&mut self, sid: u64) -> anyhow::Result<()> {
        let j = self
            .journals
            .get(&sid)
            .cloned()
            .ok_or_else(|| anyhow::anyhow!("no journal for session {sid}"))?;
        let mut tried = vec![false; self.devices.len()];
        loop {
            let target = self
                .devices
                .iter()
                .enumerate()
                .filter(|(i, _)| !tried[*i] && self.alive(*i))
                .min_by_key(|(i, _)| self.load[*i])
                .map(|(i, _)| i)
                .ok_or_else(|| anyhow::anyhow!("no live device could host session {sid}"))?;
            tried[target] = true;
            match self.replay_on(target, &j) {
                Ok(local) => {
                    self.table.insert(sid, RoutedSession { device: target, local });
                    self.load[target] += 1;
                    self.note_success(target);
                    return Ok(());
                }
                Err(e) if e.to_string().contains(DEVICE_UNAVAILABLE) => {
                    // the rescue device died too: recurse (its own sessions
                    // re-place first), then try the next candidate
                    self.fail_device(target);
                }
                Err(_) => {
                    // e.g. the target's class memory is full — try another
                    // device without penalizing this one
                }
            }
        }
    }

    /// Replay a session journal on device `d`: create with the original
    /// geometry, then re-issue every training record in arrival order.
    fn replay_on(&self, d: usize, j: &SessionJournal) -> anyhow::Result<u64> {
        let c = self.devices[d]
            .coord
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("{DEVICE_UNAVAILABLE}: device {d} is dead"))?;
        let local = match c.call(Request::CreateSession {
            n_way: j.n_way,
            hv_bits: j.hv_bits,
            metric: j.metric,
            backend: j.backend,
        }) {
            Response::SessionCreated { session } => session,
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected re-create reply: {other:?}"),
        };
        for rec in &j.records {
            let req = match rec {
                ShotRecord::Shot { class, image } => {
                    Request::AddShot { session: local, class: *class, image: image.clone() }
                }
                ShotRecord::Batch { class, images } => {
                    Request::AddShotBatch { session: local, class: *class, images: images.clone() }
                }
                ShotRecord::Finish => Request::FinishTraining { session: local },
            };
            match c.call(req) {
                Response::ShotAccepted { .. } | Response::TrainingDone { .. } => {}
                Response::Error(e) | Response::RetryableError(e) => {
                    // best-effort cleanup of the half-replayed session
                    c.call(Request::CloseSession { session: local });
                    anyhow::bail!("journal replay failed: {e}")
                }
                other => {
                    c.call(Request::CloseSession { session: local });
                    anyhow::bail!("unexpected replay reply: {other:?}")
                }
            }
        }
        Ok(local)
    }

    /// Issue a routed request with fault handling: device-unavailable
    /// faults kill the device, re-place its sessions (journal retrain) and
    /// retry this request on the session's new home; soft retryable faults
    /// strike the device and surface to the caller (who may retry).
    fn call_routed(&mut self, session: u64, mk: &dyn Fn(u64) -> Request) -> anyhow::Result<Response> {
        // each failed attempt kills one device, so n_devices+1 bounds it
        for _ in 0..=self.devices.len() {
            let r = self.route(session)?;
            let resp = match self.devices[r.device].coord.as_ref() {
                Some(c) => c.call(mk(r.local)),
                None => Response::RetryableError(format!(
                    "{DEVICE_UNAVAILABLE}: device {} is dead",
                    r.device
                )),
            };
            match resp {
                Response::RetryableError(m) if m.starts_with(DEVICE_UNAVAILABLE) => {
                    self.fail_device(r.device);
                    // loop: the session either has a new home now or
                    // route() reports it lost
                }
                Response::RetryableError(m) => {
                    if self.strike(r.device) {
                        self.fail_device(r.device);
                    } else {
                        anyhow::bail!(m);
                    }
                }
                other => {
                    self.note_success(r.device);
                    return Ok(other);
                }
            }
        }
        anyhow::bail!("session {session}: retries exhausted across the fleet")
    }

    /// Respawn a Dead device through the retained engine factory. It
    /// re-enters as [`DeviceHealth::Probation`]: eligible for placement,
    /// promoted to Healthy on its first success, Dead again on any fault.
    pub fn revive(&mut self, d: usize) -> anyhow::Result<()> {
        anyhow::ensure!(d < self.devices.len(), "no device {d}");
        anyhow::ensure!(
            self.devices[d].health == DeviceHealth::Dead,
            "device {d} is {:?}, only Dead devices can be revived",
            self.devices[d].health
        );
        let coord = Coordinator::start((self.factory)(d), self.k_shot)?;
        self.devices[d].coord = Some(coord);
        self.devices[d].health = DeviceHealth::Probation;
        self.devices[d].strikes = 0;
        Ok(())
    }

    /// Create a session somewhere in the fleet; on a full device, falls
    /// back to any live device with room (backpressure surfaces only when
    /// the whole fleet is out of class memory).
    pub fn create_session(&mut self, n_way: usize, hv_bits: u32) -> anyhow::Result<u64> {
        self.create_session_with(n_way, hv_bits, Distance::L1)
    }

    /// [`DeviceRouter::create_session`] with an explicit distance metric.
    pub fn create_session_with(
        &mut self,
        n_way: usize,
        hv_bits: u32,
        metric: Distance,
    ) -> anyhow::Result<u64> {
        self.create_session_full(n_way, hv_bits, metric, ClassifierBackend::Hdc)
    }

    /// Fully explicit placement: metric *and* classifier backend. An LDC
    /// session charges its folded (low-D) footprint to the device's class
    /// memory, so mixed fleets pack many more LDC sessions per device.
    pub fn create_session_full(
        &mut self,
        n_way: usize,
        hv_bits: u32,
        metric: Distance,
        backend: ClassifierBackend,
    ) -> anyhow::Result<u64> {
        let first = self.pick_device();
        let n = self.devices.len();
        let mut last_err = None;
        for off in 0..n {
            let d = (first + off) % n;
            let Some(c) = self.devices[d].coord.as_ref().filter(|_| self.alive(d)) else {
                continue;
            };
            match c.call(Request::CreateSession { n_way, hv_bits, metric, backend }) {
                Response::SessionCreated { session: local } => {
                    self.note_success(d);
                    let gid = self.next_global;
                    self.next_global += 1;
                    self.table.insert(gid, RoutedSession { device: d, local });
                    self.journals.insert(
                        gid,
                        SessionJournal { n_way, hv_bits, metric, backend, records: Vec::new() },
                    );
                    self.load[d] += 1;
                    return Ok(gid);
                }
                Response::RetryableError(m) if m.starts_with(DEVICE_UNAVAILABLE) => {
                    self.fail_device(d);
                    last_err = Some(anyhow::anyhow!(m));
                }
                Response::RetryableError(m) => {
                    if self.strike(d) {
                        self.fail_device(d);
                    }
                    last_err = Some(anyhow::anyhow!(m));
                }
                Response::Error(e) => last_err = Some(anyhow::anyhow!(e)),
                other => last_err = Some(anyhow::anyhow!("unexpected: {other:?}")),
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow::anyhow!("no live devices")))
    }

    fn route(&self, session: u64) -> anyhow::Result<RoutedSession> {
        self.table
            .get(&session)
            .copied()
            .ok_or_else(|| anyhow::anyhow!("unknown routed session {session}"))
    }

    pub fn placement(&self, session: u64) -> Option<RoutedSession> {
        self.table.get(&session).copied()
    }

    pub fn add_shot(&mut self, session: u64, class: usize, image: Vec<f32>) -> anyhow::Result<()> {
        let resp = self.call_routed(session, &|local| Request::AddShot {
            session: local,
            class,
            image: image.clone(),
        })?;
        match resp {
            Response::ShotAccepted { .. } => {
                if let Some(j) = self.journals.get_mut(&session) {
                    j.records.push(ShotRecord::Shot { class, image });
                }
                Ok(())
            }
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    /// Route a whole class batch to the session's device in one request,
    /// so batched single-pass training crosses the fleet boundary as one
    /// message and hits the device's batched (worker-sharded) FE path.
    pub fn add_shot_batch(
        &mut self,
        session: u64,
        class: usize,
        images: Vec<Vec<f32>>,
    ) -> anyhow::Result<()> {
        let resp = self.call_routed(session, &|local| Request::AddShotBatch {
            session: local,
            class,
            images: images.clone(),
        })?;
        match resp {
            Response::ShotAccepted { .. } => {
                if let Some(j) = self.journals.get_mut(&session) {
                    j.records.push(ShotRecord::Batch { class, images });
                }
                Ok(())
            }
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn finish_training(&mut self, session: u64) -> anyhow::Result<usize> {
        let resp =
            self.call_routed(session, &|local| Request::FinishTraining { session: local })?;
        match resp {
            Response::TrainingDone { shots, .. } => {
                if let Some(j) = self.journals.get_mut(&session) {
                    j.records.push(ShotRecord::Finish);
                }
                Ok(shots)
            }
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn query(
        &mut self,
        session: u64,
        image: Vec<f32>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<QueryOutcome> {
        let resp = self.call_routed(session, &|local| Request::Query {
            session: local,
            image: image.clone(),
            ee,
        })?;
        match resp {
            Response::QueryResult { outcome, .. } => Ok(outcome),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    /// Route a whole query batch to the session's device in one request —
    /// the inference mirror of [`DeviceRouter::add_shot_batch`]: the
    /// device runs the staged ragged-survivor loop over its worker pool.
    pub fn query_batch(
        &mut self,
        session: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<Vec<QueryOutcome>> {
        let resp = self.call_routed(session, &|local| Request::QueryBatch {
            session: local,
            images: images.clone(),
            ee,
        })?;
        match resp {
            Response::QueryBatchResult { outcomes, .. } => Ok(outcomes),
            Response::Error(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn close_session(&mut self, session: u64) -> anyhow::Result<()> {
        let r = self.route(session)?;
        if let Some(c) = self.devices[r.device].coord.as_ref() {
            c.call(Request::CloseSession { session: r.local });
        }
        self.load[r.device] = self.load[r.device].saturating_sub(1);
        self.table.remove(&session);
        self.journals.remove(&session);
        Ok(())
    }

    /// Per-device open-session counts.
    pub fn loads(&self) -> &[usize] {
        &self.load
    }

    /// Per-device metrics across the live fleet (Dead devices cannot
    /// answer and are skipped).
    pub fn fleet_metrics(&self) -> Vec<MetricsSnapshot> {
        self.devices.iter().filter_map(|d| d.coord.as_ref().map(|c| c.metrics())).collect()
    }

    /// One fleet-wide snapshot: every live device's metrics merged
    /// ([`MetricsSnapshot::absorb`]) plus the router-owned recovery
    /// counters (`device_failures` / `sessions_replaced` / `retrain_ms`).
    pub fn fleet_snapshot(&self) -> MetricsSnapshot {
        let mut agg = MetricsSnapshot::default();
        for d in &self.devices {
            if let Some(c) = d.coord.as_ref() {
                agg.absorb(&c.metrics());
            }
        }
        agg.device_failures = self.metrics.device_failures;
        agg.sessions_replaced = self.metrics.sessions_replaced;
        agg.retrain_ms = self.metrics.retrain_ms;
        agg
    }
}

#[cfg(test)]
mod tests {
    // Router tests that need a real engine live in
    // rust/tests/integration_coordinator.rs (placement arithmetic) and
    // rust/tests/integration_chaos.rs (health, re-placement, journal
    // retrain bit-identity) — they need running devices.
}
