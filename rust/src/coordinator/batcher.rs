//! Class batcher (Fig. 12): groups incoming same-class shots so the FE
//! processes them back-to-back under one weight-stream pass and the HDC
//! trainer aggregates them in one class-memory sweep.

use std::collections::BTreeMap;

/// A batch of same-class shots ready for the FE.
#[derive(Clone, Debug, PartialEq)]
pub struct ClassBatch<T> {
    pub class: usize,
    pub items: Vec<T>,
}

/// Accumulates shots per class; flushes when a class reaches `k_shot`
/// (or on demand at train time).
#[derive(Clone, Debug)]
pub struct ClassBatcher<T> {
    pub k_shot: usize,
    pending: BTreeMap<usize, Vec<T>>,
}

impl<T> ClassBatcher<T> {
    pub fn new(k_shot: usize) -> Self {
        assert!(k_shot >= 1);
        ClassBatcher { k_shot, pending: BTreeMap::new() }
    }

    /// Add one shot; returns a full batch if the class just reached k.
    pub fn push(&mut self, class: usize, item: T) -> Option<ClassBatch<T>> {
        let slot = self.pending.entry(class).or_default();
        slot.push(item);
        if slot.len() >= self.k_shot {
            // the entry above guarantees the key exists; map instead of
            // unwrap keeps this serving path structurally panic-free
            self.pending.remove(&class).map(|items| ClassBatch { class, items })
        } else {
            None
        }
    }

    /// Flush every partially filled class (train-now request).
    pub fn flush_all(&mut self) -> Vec<ClassBatch<T>> {
        let pending = std::mem::take(&mut self.pending);
        pending
            .into_iter()
            .map(|(class, items)| ClassBatch { class, items })
            .collect()
    }

    pub fn pending_shots(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }

    pub fn pending_classes(&self) -> usize {
        self.pending.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_on_k_reached() {
        let mut b = ClassBatcher::new(3);
        assert!(b.push(0, "a").is_none());
        assert!(b.push(1, "x").is_none());
        assert!(b.push(0, "b").is_none());
        let full = b.push(0, "c").unwrap();
        assert_eq!(full.class, 0);
        assert_eq!(full.items, vec!["a", "b", "c"]);
        assert_eq!(b.pending_shots(), 1);
    }

    #[test]
    fn preserves_arrival_order_within_class() {
        let mut b = ClassBatcher::new(2);
        b.push(5, 1);
        let batch = b.push(5, 2).unwrap();
        assert_eq!(batch.items, vec![1, 2]);
    }

    #[test]
    fn flush_returns_partials_sorted_by_class() {
        let mut b = ClassBatcher::new(5);
        b.push(2, "q");
        b.push(0, "p");
        b.push(2, "r");
        let flushed = b.flush_all();
        assert_eq!(flushed.len(), 2);
        assert_eq!(flushed[0].class, 0);
        assert_eq!(flushed[1].class, 2);
        assert_eq!(flushed[1].items, vec!["q", "r"]);
        assert!(b.is_empty());
    }

    #[test]
    fn no_cross_class_mixing() {
        let mut b = ClassBatcher::new(2);
        b.push(0, 10);
        b.push(1, 20);
        let f0 = b.push(0, 11).unwrap();
        assert!(f0.items.iter().all(|&v| v < 20));
    }

    #[test]
    fn counts() {
        let mut b: ClassBatcher<u8> = ClassBatcher::new(4);
        b.push(0, 1);
        b.push(1, 2);
        b.push(1, 3);
        assert_eq!(b.pending_shots(), 3);
        assert_eq!(b.pending_classes(), 2);
    }
}
