//! TCP gateway in front of the [`Coordinator`]: an accept loop, one
//! thread per connection speaking the length-prefixed [`wire`] format,
//! and admission control that sheds load with [`Response::Busy`] when the
//! serving queue runs past the `[serving]` high-water mark (DESIGN.md
//! §Serving runtime).
//!
//! The gateway never owns the coordinator — it holds a cloneable
//! [`CoordinatorClient`], so worker shutdown stays a `Coordinator::drop`
//! concern. [`Gateway::stop`] (also run on drop) closes the listener and
//! every live connection and joins all gateway threads; no detached
//! threads survive. Connection threads read with a short poll tick
//! ([`READ_TICK_MS`]) and check the stop flag between ticks, so a client
//! stalled mid-frame can never pin `stop` (DESIGN.md §Fault model).
//!
//! Failure handling: with `[serving] deadline_ms` set, coordinator calls
//! are bounded by [`CoordinatorClient::call_deadline`]; the
//! `gateway.read` / `gateway.write` fail points simulate transport loss
//! on either side of a request; and [`WireClient`] survives a dropped
//! connection — it reports [`ConnectionLost`], re-dials lazily, and
//! [`WireClient::call_retry`] retries with deterministic capped backoff.
//!
//! [`Coordinator`]: crate::coordinator::Coordinator

use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::classifier::ClassifierBackend;
use crate::config::{EeConfig, ServingConfig};
use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::server::CoordinatorClient;
use crate::coordinator::session::QueryOutcome;
use crate::coordinator::wire;
use crate::hdc::Distance;

/// One live client connection: a handle for `stop` to close the socket
/// out from under the blocked `read_frame`, plus the serving thread.
struct Conn {
    stream: TcpStream,
    handle: JoinHandle<()>,
}

/// A running TCP front end for one coordinator.
pub struct Gateway {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<Conn>>>,
    accept: Option<JoinHandle<()>>,
}

impl Gateway {
    /// Bind `cfg.addr` and start serving `client`. With the default
    /// `addr = "127.0.0.1:0"` the OS picks a free loopback port — read it
    /// back with [`Gateway::local_addr`].
    pub fn bind(client: CoordinatorClient, cfg: &ServingConfig) -> anyhow::Result<Gateway> {
        let listener = TcpListener::bind(&cfg.addr)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let stop = stop.clone();
            let conns = conns.clone();
            let cfg = cfg.clone();
            std::thread::Builder::new().name("fsl-gateway-accept".into()).spawn(move || {
                accept_loop(&listener, &client, &cfg, &stop, &conns);
            })?
        };
        Ok(Gateway { addr, stop, conns, accept: Some(accept) })
    }

    /// The bound address (the resolved port when `cfg.addr` ended in `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, close every live connection, join all gateway
    /// threads. Idempotent; also runs on drop.
    pub fn stop(&mut self) {
        if self.stop.swap(true, Ordering::AcqRel) {
            return;
        }
        // the accept loop blocks in `accept()`; a throwaway self-connect
        // wakes it so it can observe the flag and exit
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let drained: Vec<Conn> = {
            let mut conns = self.conns.lock().unwrap_or_else(|p| p.into_inner());
            conns.drain(..).collect()
        };
        for c in drained {
            // unblocks the handler's read_frame with EOF
            let _ = c.stream.shutdown(Shutdown::Both);
            let _ = c.handle.join();
        }
    }
}

impl Drop for Gateway {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(
    listener: &TcpListener,
    client: &CoordinatorClient,
    cfg: &ServingConfig,
    stop: &Arc<AtomicBool>,
    conns: &Arc<Mutex<Vec<Conn>>>,
) {
    loop {
        let stream = match listener.accept() {
            Ok((s, _)) => s,
            Err(_) => {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                continue; // transient accept error (e.g. ECONNABORTED)
            }
        };
        if stop.load(Ordering::Acquire) {
            return; // the self-connect wake-up, or a client racing stop
        }
        let _ = stream.set_nodelay(true);
        let Ok(for_stop) = stream.try_clone() else { continue };
        let client = client.clone();
        let cfg = cfg.clone();
        let stop = stop.clone();
        let spawned = std::thread::Builder::new()
            .name("fsl-gateway-conn".into())
            .spawn(move || handle_conn(stream, &client, &cfg, &stop));
        let Ok(handle) = spawned else { continue };
        let mut conns = conns.lock().unwrap_or_else(|p| p.into_inner());
        // reap connections that already hung up, so a long-lived gateway
        // does not accumulate one dead entry per past client
        let mut i = 0;
        while i < conns.len() {
            if conns[i].handle.is_finished() {
                let c = conns.swap_remove(i);
                let _ = c.handle.join();
            } else {
                i += 1;
            }
        }
        conns.push(Conn { stream: for_stop, handle });
    }
}

/// Read poll tick for connection threads: the upper bound on how long a
/// stalled client can delay a connection thread's reaction to
/// [`Gateway::stop`].
pub const READ_TICK_MS: u64 = 50;

/// Serve one connection until EOF, a framing error, gateway stop, or an
/// injected `gateway.read` / `gateway.write` transport fault.
fn handle_conn(
    mut stream: TcpStream,
    client: &CoordinatorClient,
    cfg: &ServingConfig,
    stop: &AtomicBool,
) {
    // a short read timeout turns the blocking read into a poll loop; the
    // cancellable reader resumes partial frames across ticks and checks
    // the stop flag between them, so a client stalled mid-frame cannot
    // pin this thread across Gateway::stop
    let _ = stream.set_read_timeout(Some(Duration::from_millis(READ_TICK_MS)));
    serve_conn(&mut stream, client, cfg, stop);
    // the accept loop holds a try_clone of this socket as its stop-side
    // handle, so dropping `stream` alone would not send FIN until that
    // clone is reaped; an explicit shutdown makes the peer see EOF
    // promptly on every exit path instead of blocking on a dead reply
    let _ = stream.shutdown(Shutdown::Both);
}

fn serve_conn(
    stream: &mut TcpStream,
    client: &CoordinatorClient,
    cfg: &ServingConfig,
    stop: &AtomicBool,
) {
    loop {
        let mut cancelled = || stop.load(Ordering::Acquire);
        let frame =
            match wire::read_frame_cancellable(&mut stream, cfg.max_frame_bytes, &mut cancelled) {
                Ok(Some(f)) => f,
                Ok(None) => return, // clean EOF at a frame boundary, or stop
                Err(e) => {
                    // the stream is desynchronized (truncated/oversized
                    // frame): answer best-effort and close — replying to
                    // misaligned bytes would corrupt every later exchange
                    let resp = Response::Error(format!("framing error: {e}"));
                    let _ = wire::write_frame(
                        &mut stream,
                        &wire::encode_response(&resp),
                        cfg.max_frame_bytes,
                    );
                    return;
                }
            };
        if crate::util::failpoint::check("gateway.read").is_err() {
            // injected inbound transport fault: the frame counts as never
            // received — drop the connection without a reply, exactly like
            // a peer that vanished mid-exchange (clients re-dial)
            return;
        }
        // a complete frame that fails to decode leaves the stream aligned:
        // reply Error and keep the connection
        let resp = match wire::decode_request(&frame) {
            Err(e) => Response::Error(format!("bad request: {e}")),
            // shutdown stays a local-owner operation (Coordinator::drop);
            // accepting it from any TCP peer would let one client kill the
            // device for everyone
            Ok(Request::Shutdown) => {
                Response::Error("shutdown is not accepted over the wire".into())
            }
            Ok(req) => {
                let depth = client.load().queue_depth();
                if depth > cfg.high_water {
                    client.load().note_shed();
                    Response::Busy { queue_depth: depth }
                } else if cfg.deadline_ms > 0 {
                    client.call_deadline(req, Duration::from_millis(cfg.deadline_ms))
                } else {
                    client.call(req)
                }
            }
        };
        if crate::util::failpoint::check("gateway.write").is_err() {
            return; // injected outbound fault: reply lost, connection drops
        }
        let payload = wire::encode_response(&resp);
        if wire::write_frame(&mut stream, &payload, cfg.max_frame_bytes).is_err() {
            return; // peer went away mid-reply
        }
    }
}

/// Marker error: the TCP connection to the gateway died mid-call — the
/// request may or may not have executed, but no reply will ever arrive on
/// this stream. Detect it with `err.is::<ConnectionLost>()`. The client
/// drops the dead stream and re-dials on the next call;
/// [`WireClient::call_retry`] does so automatically with backoff.
#[derive(Debug)]
pub struct ConnectionLost(pub String);

impl std::fmt::Display for ConnectionLost {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "connection lost: {}", self.0)
    }
}

impl std::error::Error for ConnectionLost {}

/// Blocking client for the gateway's wire protocol — the remote
/// counterpart of [`crate::coordinator::Coordinator`]'s convenience
/// methods, one frame round trip per call.
///
/// The client owns at most one live stream. Any transport failure (send
/// error, EOF before the reply, torn frame) surfaces as [`ConnectionLost`]
/// and poisons the stream; the next call re-dials the resolved address.
pub struct WireClient {
    stream: Option<TcpStream>,
    addr: SocketAddr,
    max_frame_bytes: usize,
    max_attempts: u32,
    backoff_base_ms: u64,
    backoff_cap_ms: u64,
}

impl WireClient {
    /// Connect with the default frame cap ([`ServingConfig::default`]).
    pub fn connect(addr: impl ToSocketAddrs) -> anyhow::Result<WireClient> {
        Self::connect_with(addr, ServingConfig::default().max_frame_bytes)
    }

    /// Connect with an explicit frame cap (must match the server's to
    /// move frames near the cap in either direction).
    pub fn connect_with(
        addr: impl ToSocketAddrs,
        max_frame_bytes: usize,
    ) -> anyhow::Result<WireClient> {
        let addr = addr
            .to_socket_addrs()?
            .next()
            .ok_or_else(|| anyhow::anyhow!("address resolved to nothing"))?;
        let stream = Self::dial(addr)?;
        Ok(WireClient {
            stream: Some(stream),
            addr,
            max_frame_bytes,
            max_attempts: 4,
            backoff_base_ms: 10,
            backoff_cap_ms: 250,
        })
    }

    /// Tune [`WireClient::call_retry`]: total attempts and the
    /// deterministic backoff schedule (`base * 2^(attempt-1)`, capped).
    pub fn with_retry(mut self, max_attempts: u32, base_ms: u64, cap_ms: u64) -> WireClient {
        self.max_attempts = max_attempts.max(1);
        self.backoff_base_ms = base_ms;
        self.backoff_cap_ms = cap_ms.max(base_ms);
        self
    }

    fn dial(addr: SocketAddr) -> anyhow::Result<TcpStream> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(stream)
    }

    /// Deterministic capped exponential backoff — no jitter, so failure
    /// reproductions see the exact same retry schedule every run.
    fn backoff(&self, attempt: u32) -> Duration {
        let exp = attempt.saturating_sub(1).min(16);
        Duration::from_millis(self.backoff_base_ms.saturating_mul(1 << exp).min(self.backoff_cap_ms))
    }

    /// One request/response round trip over the wire (single attempt).
    /// Transport failures return [`ConnectionLost`] and drop the stream;
    /// the next call on this client transparently re-dials.
    pub fn call(&mut self, req: &Request) -> anyhow::Result<Response> {
        let max = self.max_frame_bytes;
        let mut stream = match self.stream.take() {
            Some(s) => s,
            None => Self::dial(self.addr)?, // lazy re-dial after a loss
        };
        if let Err(e) = wire::write_frame(&mut stream, &wire::encode_request(req), max) {
            return Err(anyhow::Error::new(ConnectionLost(format!("send failed: {e}"))));
        }
        match wire::read_frame(&mut stream, max) {
            Ok(Some(frame)) => {
                // a complete frame leaves the stream aligned even if the
                // payload fails to decode — keep the connection
                let resp = wire::decode_response(&frame);
                self.stream = Some(stream);
                resp
            }
            Ok(None) => Err(anyhow::Error::new(ConnectionLost(
                "connection closed before the reply arrived".into(),
            ))),
            Err(e) => Err(anyhow::Error::new(ConnectionLost(format!("receive failed: {e}")))),
        }
    }

    /// [`WireClient::call`] with automatic recovery: re-dials and retries
    /// on [`ConnectionLost`] and on server-side [`Response::RetryableError`]
    /// replies, sleeping the deterministic [`WireClient::with_retry`]
    /// schedule between attempts. Non-retryable errors and
    /// [`Response::Busy`] pass straight through — admission backoff is an
    /// application policy, not a transport one.
    pub fn call_retry(&mut self, req: &Request) -> anyhow::Result<Response> {
        let mut attempt = 0u32;
        loop {
            let transient = match self.call(req) {
                Ok(Response::RetryableError(m)) => m,
                Ok(resp) => return Ok(resp),
                Err(e) if e.is::<ConnectionLost>() => e.to_string(),
                Err(e) => return Err(e),
            };
            attempt += 1;
            if attempt >= self.max_attempts {
                anyhow::bail!("request failed after {attempt} attempts: {transient}");
            }
            std::thread::sleep(self.backoff(attempt));
        }
    }

    /// Convenience wrappers mirroring [`crate::coordinator::Coordinator`]'s,
    /// so a serving script can swap in-process for remote unchanged.
    pub fn create_session(&mut self, n_way: usize, hv_bits: u32) -> anyhow::Result<u64> {
        self.create_session_with(n_way, hv_bits, Distance::L1)
    }

    pub fn create_session_with(
        &mut self,
        n_way: usize,
        hv_bits: u32,
        metric: Distance,
    ) -> anyhow::Result<u64> {
        self.create_session_full(n_way, hv_bits, metric, ClassifierBackend::Hdc)
    }

    /// Fully explicit remote session creation: metric *and* classifier
    /// backend (the wire frame's `backend` field).
    pub fn create_session_full(
        &mut self,
        n_way: usize,
        hv_bits: u32,
        metric: Distance,
        backend: ClassifierBackend,
    ) -> anyhow::Result<u64> {
        match self.call(&Request::CreateSession { n_way, hv_bits, metric, backend })? {
            Response::SessionCreated { session } => Ok(session),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn add_shot(&mut self, session: u64, class: usize, image: Vec<f32>) -> anyhow::Result<()> {
        match self.call(&Request::AddShot { session, class, image })? {
            Response::ShotAccepted { .. } => Ok(()),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn finish_training(&mut self, session: u64) -> anyhow::Result<usize> {
        match self.call(&Request::FinishTraining { session })? {
            Response::TrainingDone { shots, .. } => Ok(shots),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn query(
        &mut self,
        session: u64,
        image: Vec<f32>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<QueryOutcome> {
        match self.call(&Request::Query { session, image, ee })? {
            Response::QueryResult { outcome, .. } => Ok(outcome),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn query_batch(
        &mut self,
        session: u64,
        images: Vec<Vec<f32>>,
        ee: Option<EeConfig>,
    ) -> anyhow::Result<Vec<QueryOutcome>> {
        match self.call(&Request::QueryBatch { session, images, ee })? {
            Response::QueryBatchResult { outcomes, .. } => Ok(outcomes),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn close_session(&mut self, session: u64) -> anyhow::Result<()> {
        match self.call(&Request::CloseSession { session })? {
            Response::SessionClosed { .. } => Ok(()),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }

    pub fn metrics(&mut self) -> anyhow::Result<MetricsSnapshot> {
        match self.call(&Request::GetMetrics)? {
            Response::Metrics(m) => Ok(m),
            Response::Error(e) | Response::RetryableError(e) => anyhow::bail!(e),
            other => anyhow::bail!("unexpected: {other:?}"),
        }
    }
}
