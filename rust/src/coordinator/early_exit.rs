//! Early-exit controller (Section V-A, Fig. 11).
//!
//! The FSL classifier terminates inference when predictions stay
//! consistent across `E_c` consecutive CONV blocks, starting from the
//! `E_s`-th block (both 1-based in the paper). The distance table keeps
//! each block's prediction so the consistency check needs no extra
//! hardware — here it is exactly that table plus a counter.

use crate::config::EeConfig;

/// Decision returned after feeding one block's prediction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EeDecision {
    /// keep extracting features
    Continue,
    /// exit now with this prediction
    Exit(usize),
}

/// Per-query controller state.
#[derive(Clone, Debug)]
pub struct EarlyExitController {
    pub cfg: EeConfig,
    /// distance-table record: (block index, prediction)
    pub table: Vec<(usize, usize)>,
    consecutive: usize,
    last_pred: Option<usize>,
}

impl EarlyExitController {
    pub fn new(cfg: EeConfig) -> Self {
        assert!(cfg.e_s >= 1, "E_s is 1-based");
        assert!(cfg.e_c >= 1, "E_c must be at least 1");
        EarlyExitController { cfg, table: Vec::new(), consecutive: 0, last_pred: None }
    }

    /// Validating constructor for client-supplied configs: returns an
    /// error instead of panicking. The coordinator runs every
    /// `Request::Query{,Batch}` config through this (or
    /// [`EeConfig::validate`]) so a bad (E_s, E_c) becomes a
    /// `Response::Error`, never a dead worker thread.
    pub fn try_new(cfg: EeConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        Ok(Self::new(cfg))
    }

    /// Feed the prediction of CONV block `block` (0-based). Returns the
    /// decision; callers must feed blocks in order.
    pub fn feed(&mut self, block: usize, pred: usize) -> EeDecision {
        debug_assert_eq!(block, self.table.len(), "blocks must be fed in order");
        self.table.push((block, pred));
        // blocks before E_s do not participate in the consistency check
        if block + 1 < self.cfg.e_s {
            return EeDecision::Continue;
        }
        if self.last_pred == Some(pred) || (self.consecutive == 0 && self.last_pred.is_none()) {
            self.consecutive += 1;
        } else {
            self.consecutive = 1;
        }
        self.last_pred = Some(pred);
        if self.consecutive >= self.cfg.e_c {
            EeDecision::Exit(pred)
        } else {
            EeDecision::Continue
        }
    }

    /// Reset for the next query.
    pub fn reset(&mut self) {
        self.table.clear();
        self.consecutive = 0;
        self.last_pred = None;
    }

    /// Blocks consumed so far (= exit depth once Exit is returned).
    pub fn blocks_used(&self) -> usize {
        self.table.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ee(e_s: usize, e_c: usize) -> EarlyExitController {
        EarlyExitController::new(EeConfig { e_s, e_c })
    }

    #[test]
    fn exits_after_ec_consistent_blocks() {
        let mut c = ee(1, 2);
        assert_eq!(c.feed(0, 3), EeDecision::Continue);
        assert_eq!(c.feed(1, 3), EeDecision::Exit(3));
        assert_eq!(c.blocks_used(), 2);
    }

    #[test]
    fn disagreement_resets_counter() {
        let mut c = ee(1, 2);
        assert_eq!(c.feed(0, 3), EeDecision::Continue);
        assert_eq!(c.feed(1, 4), EeDecision::Continue);
        assert_eq!(c.feed(2, 4), EeDecision::Exit(4));
    }

    #[test]
    fn es_delays_participation() {
        // E_s = 3: blocks 0 and 1 are ignored entirely
        let mut c = ee(3, 2);
        assert_eq!(c.feed(0, 1), EeDecision::Continue);
        assert_eq!(c.feed(1, 1), EeDecision::Continue);
        assert_eq!(c.feed(2, 1), EeDecision::Continue); // first counted block
        assert_eq!(c.feed(3, 1), EeDecision::Exit(1));
    }

    #[test]
    fn ec1_exits_immediately_at_es() {
        let mut c = ee(2, 1);
        assert_eq!(c.feed(0, 9), EeDecision::Continue);
        assert_eq!(c.feed(1, 9), EeDecision::Exit(9));
    }

    #[test]
    fn paper_default_2_2() {
        let mut c = EarlyExitController::new(EeConfig::paper_default());
        assert_eq!(c.feed(0, 5), EeDecision::Continue); // block 1 ignored (E_s=2)
        assert_eq!(c.feed(1, 5), EeDecision::Continue); // 1st counted
        assert_eq!(c.feed(2, 5), EeDecision::Exit(5)); // 2nd consistent
    }

    #[test]
    fn reset_clears_state() {
        let mut c = ee(1, 2);
        c.feed(0, 1);
        c.reset();
        assert_eq!(c.blocks_used(), 0);
        assert_eq!(c.feed(0, 2), EeDecision::Continue);
        assert_eq!(c.feed(1, 2), EeDecision::Exit(2));
    }

    #[test]
    fn distance_table_records_history() {
        let mut c = ee(1, 4);
        for (b, p) in [(0, 1), (1, 2), (2, 2), (3, 2)] {
            c.feed(b, p);
        }
        assert_eq!(c.table, vec![(0, 1), (1, 2), (2, 2), (3, 2)]);
    }

    #[test]
    #[should_panic(expected = "E_s is 1-based")]
    fn rejects_zero_es() {
        ee(0, 1);
    }

    #[test]
    fn try_new_reports_instead_of_panicking() {
        let err = EarlyExitController::try_new(EeConfig { e_s: 0, e_c: 1 })
            .unwrap_err()
            .to_string();
        assert!(err.contains("e_s"), "{err}");
        let err = EarlyExitController::try_new(EeConfig { e_s: 1, e_c: 0 })
            .unwrap_err()
            .to_string();
        assert!(err.contains("e_c"), "{err}");
        assert!(EarlyExitController::try_new(EeConfig::paper_default()).is_ok());
    }
}
