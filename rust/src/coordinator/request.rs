//! Request/response protocol between clients and the coordinator's worker
//! thread — the host<->device command stream of the test setup (Fig. 13a).

use crate::classifier::ClassifierBackend;
use crate::config::EeConfig;
use crate::coordinator::session::QueryOutcome;
use crate::hdc::Distance;

/// Commands accepted by the coordinator. `Clone + PartialEq` so the wire
/// codec (`coordinator::wire`) can be round-trip tested variant by
/// variant.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Create a few-shot session at `hv_bits` class-memory precision with
    /// the given distance metric and classifier backend (wire field
    /// `backend`, absent = `hdc` for frames from older clients); replies
    /// `SessionCreated` (or `Error` when `n_way == 0`, when the session
    /// does not fit in class memory, or when the backend name is unknown).
    CreateSession { n_way: usize, hv_bits: u32, metric: Distance, backend: ClassifierBackend },
    /// Add one labeled shot (raw image, flat NHWC). The coordinator
    /// batches same-class shots and trains when a class reaches k_shot
    /// or on `FinishTraining`.
    AddShot { session: u64, class: usize, image: Vec<f32> },
    /// Add a whole class batch of labeled shots in one request (Fig. 12
    /// batched single-pass training). The images flow through the class
    /// batcher with the same k-shot flush semantics as per-shot arrival,
    /// but full batches reach the engine's batched FE entry point in one
    /// call — which the native backend shards across its worker pool.
    /// Replies `ShotAccepted` covering the whole batch.
    AddShotBatch { session: u64, class: usize, images: Vec<Vec<f32>> },
    /// Add one labeled shot given as a pre-extracted feature vector,
    /// bypassing the FE — Fig. 7: "either the features extracted by FE or
    /// the raw input data can serve as the input to the FSL classifier".
    /// Trains the final branch only (no EE branch HVs exist without FE).
    AddFeatureShot { session: u64, class: usize, feature: Vec<f32> },
    /// Classify a pre-extracted feature vector (final branch, no EE).
    QueryFeature { session: u64, feature: Vec<f32> },
    /// Flush partial batches and finish single-pass training.
    FinishTraining { session: u64 },
    /// Classify an image; `ee` enables early exit. Runs the staged
    /// inference loop: FE stages interleave with per-branch encode +
    /// predict, so an exit at block *b* means stages *b+1..* are never
    /// computed (DESIGN.md §Staged inference).
    Query { session: u64, image: Vec<f32>, ee: Option<EeConfig> },
    /// Classify a whole batch of images in one request, with the same
    /// staged early-exit semantics per image. The batch is processed
    /// stage by stage over a **ragged survivor set** — images that exit
    /// drop out, so later stages run on an ever-smaller batch sharded
    /// across the engine's worker pool. Outcomes are bit-identical to
    /// issuing serial `Query` requests for any worker count. Replies
    /// `QueryBatchResult` with one outcome per image in input order.
    QueryBatch { session: u64, images: Vec<Vec<f32>>, ee: Option<EeConfig> },
    /// Drop a session.
    CloseSession { session: u64 },
    /// Snapshot metrics.
    GetMetrics,
    /// Stop the worker loop.
    Shutdown,
}

/// Replies.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    SessionCreated { session: u64 },
    ShotAccepted { session: u64, pending: usize, trained_classes: usize },
    TrainingDone { session: u64, shots: usize },
    QueryResult { session: u64, outcome: QueryOutcome },
    QueryBatchResult { session: u64, outcomes: Vec<QueryOutcome> },
    SessionClosed { session: u64 },
    Metrics(crate::coordinator::metrics::MetricsSnapshot),
    ShuttingDown,
    /// Load shed at the gateway's admission gate: the serving queue
    /// (outstanding coordinator requests + pooled tasks) exceeded the
    /// configured high-water mark when this request arrived. The request
    /// was **not** executed; `queue_depth` is the depth that triggered the
    /// shed, so clients can back off proportionally and retry.
    Busy { queue_depth: usize },
    /// The request failed for a reason the *caller* should treat as
    /// transient: a deadline elapsed, an injected fault fired, or the
    /// session's device became unavailable. On the wire this travels as an
    /// `error` frame with `retryable: true`, so pre-taxonomy clients still
    /// decode it as a plain [`Response::Error`] (they ignore the extra
    /// field); taxonomy-aware clients retry, and the [`DeviceRouter`]
    /// treats the [`DEVICE_UNAVAILABLE`]-prefixed subset as a device
    /// failure that triggers session re-placement.
    ///
    /// [`DeviceRouter`]: crate::coordinator::DeviceRouter
    RetryableError(String),
    Error(String),
}

/// Message prefix marking a [`Response::RetryableError`] whose cause is the
/// device itself (worker thread gone or crashed mid-request) rather than a
/// transient condition on a healthy device. The router keys re-placement
/// off this prefix; deadline and injected-fault errors deliberately do not
/// carry it.
pub const DEVICE_UNAVAILABLE: &str = "device unavailable";

impl Response {
    /// True for retryable errors whose message marks the device itself as
    /// gone (see [`DEVICE_UNAVAILABLE`]).
    pub fn is_device_unavailable(&self) -> bool {
        matches!(self, Response::RetryableError(m) if m.starts_with(DEVICE_UNAVAILABLE))
    }

    /// Convenience for tests: unwrap a query result.
    pub fn expect_query(self) -> QueryOutcome {
        match self {
            Response::QueryResult { outcome, .. } => outcome,
            other => panic!("expected QueryResult, got {other:?}"),
        }
    }

    /// Convenience for tests: unwrap a batched query result.
    pub fn expect_query_batch(self) -> Vec<QueryOutcome> {
        match self {
            Response::QueryBatchResult { outcomes, .. } => outcomes,
            other => panic!("expected QueryBatchResult, got {other:?}"),
        }
    }
}
