//! The on-device-learning coordinator — the paper's L3 system logic.
//!
//! A few-shot session accumulates labeled shots, trains the HDC model in a
//! single pass (batched per class, Fig. 12), and serves queries with the
//! early-exit policy (Fig. 11). `server` wraps it all behind an
//! mpsc-request event loop with a worker thread owning the compute engine,
//! so callers interact with the device the way a host driver would.

pub mod batcher;
pub mod early_exit;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod session;

pub use early_exit::EarlyExitController;
pub use request::{Request, Response};
pub use router::{DeviceRouter, Placement};
pub use server::Coordinator;
pub use session::FslSession;
