//! The on-device-learning coordinator — the paper's L3 system logic
//! (layer map in DESIGN.md).
//!
//! A few-shot session accumulates labeled shots, trains the HDC model in a
//! single pass (batched per class, Fig. 12), and serves queries with the
//! early-exit policy (Fig. 11) — **staged**: FE stages, per-branch encode
//! and the (E_s, E_c) controller interleave, so an exit truncates real FE
//! compute instead of being decided post hoc (DESIGN.md §Staged
//! inference). [`server`] wraps it all behind an
//! mpsc-request event loop with a worker thread owning the compute engine
//! (engines are built *inside* the worker: PJRT clients are not `Send`),
//! so callers interact with the device the way a host driver would.
//!
//! Module tour:
//! * [`session`] — per-session state: one [`crate::hdc::HdcModel`] per FE
//!   branch, single-pass / batched training, early-exit queries;
//! * [`batcher`] — groups same-class shots so the FE streams them under
//!   one weight load (the Fig. 12 saving the simulator quantifies);
//! * [`early_exit`] — the (E_s, E_c) consistency controller of Fig. 11;
//! * [`server`] — the [`Coordinator`] event loop, chip-faithful class
//!   memory admission, [`metrics`] accounting; since PR 6 its worker owns
//!   the persistent [`crate::runtime::WorkerPool`] batch sharding runs on
//!   and a [`ServingLoad`] signal for admission control;
//! * [`router`] — [`DeviceRouter`]: fans sessions over a fleet of
//!   coordinators with least-loaded/round-robin placement and spill;
//!   since PR 8 also the fleet's fault domain — per-device health
//!   (Healthy/Suspect/Dead/Probation), shot-journal session re-placement
//!   with bit-identical retrain, and probation re-admission
//!   (DESIGN.md §Fault model);
//! * [`wire`] — length-prefixed JSON wire codec for [`Request`] /
//!   [`Response`] (no new deps — `util::json` only);
//! * [`gateway`] — the TCP front end: accept loop, per-connection
//!   framing, and load shedding with `Response::Busy` past the
//!   `[serving]` high-water mark (DESIGN.md §Serving runtime).

pub mod batcher;
pub mod early_exit;
pub mod gateway;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;
pub mod session;
pub mod wire;

pub use early_exit::EarlyExitController;
pub use gateway::{Gateway, WireClient};
pub use request::{Request, Response, DEVICE_UNAVAILABLE};
pub use router::{DeviceHealth, DeviceRouter, Placement, RouterMetrics};
pub use server::{Coordinator, CoordinatorClient, ServingLoad};
pub use session::{FslSession, SessionSnapshot};
