//! A few-shot learning session: the device-side state for one N-way
//! k-shot task — per-branch HDC models (branch class HVs for early exit,
//! Section V-A) plus the single-pass training and query logic.

use crate::classifier::{ClassifierBackend, FslClassifier};
use crate::config::EeConfig;
use crate::coordinator::early_exit::{EarlyExitController, EeDecision};
use crate::hdc::{distance::argmin, Distance};

/// Outcome of one query.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct QueryOutcome {
    pub prediction: usize,
    /// CONV blocks evaluated (4 = ran the whole FE)
    pub blocks_used: usize,
    /// whether early exit fired before the final block
    pub exited_early: bool,
}

/// Everything needed to rebuild a session's class memory from scratch:
/// backend + geometry knobs and the retained shots (encoded branch HVs in
/// training order). Because HDC/LDC training is single-pass with no
/// gradient state, [`FslSession::rebuild`] replaying this snapshot
/// produces class memory **bit-identical** to the original session — the
/// paper property that makes device failure cost one bounded retrain
/// instead of a lost model (DESIGN.md §Fault model).
#[derive(Clone, Debug)]
pub struct SessionSnapshot {
    pub n_way: usize,
    pub d: usize,
    pub n_branches: usize,
    pub backend: ClassifierBackend,
    pub hv_bits: u32,
    pub metric: Distance,
    pub ldc_d: usize,
    /// `(class, one encoded HV per branch)` for every trained shot, in
    /// training order.
    pub shots: Vec<(usize, Vec<Vec<f32>>)>,
}

/// Session state: one classifier per FE branch, behind the
/// [`FslClassifier`] seam — the session no longer knows (or branches on)
/// which backend it runs; the backend choice happens once, at
/// construction, through [`ClassifierBackend::build`].
#[derive(Clone, Debug)]
pub struct FslSession {
    pub id: u64,
    pub n_way: usize,
    /// Encoded HV dimension each branch *ingests* (the cRP encoder's D).
    /// What each branch *stores* is [`FslSession::stored_dim`].
    pub d: usize,
    pub n_branches: usize,
    backend: ClassifierBackend,
    hv_bits: u32,
    metric: Distance,
    /// LDC fold dimension (`0` = auto); ignored by the HDC backend.
    ldc_d: usize,
    /// `branch_models[b]` = classifier fed by CONV block b's features
    branch_models: Vec<Box<dyn FslClassifier>>,
    pub shots_seen: usize,
    /// Shot journal backing [`FslSession::snapshot`]: the session's entire
    /// training history (single-pass training has no other state). Few-shot
    /// sessions retain k·N·B HVs — small by construction.
    retained: Vec<(usize, Vec<Vec<f32>>)>,
}

impl FslSession {
    pub fn new(id: u64, n_way: usize, d: usize, n_branches: usize) -> Self {
        assert!(n_way >= 1, "a session needs at least one class");
        assert!(d >= 1, "a session needs a non-empty HV dimension");
        assert!(n_branches >= 1);
        let mut s = FslSession {
            id,
            n_way,
            d,
            n_branches,
            backend: ClassifierBackend::default(),
            hv_bits: 16,
            metric: Distance::L1,
            ldc_d: 0,
            branch_models: Vec::new(),
            shots_seen: 0,
            retained: Vec::new(),
        };
        s.rebuild_models();
        s
    }

    /// Re-derive every branch classifier from the current knobs. Only
    /// legal before training (the builders are constructor sugar, not a
    /// live reconfiguration path).
    fn rebuild_models(&mut self) {
        assert_eq!(self.shots_seen, 0, "cannot reconfigure a session after training");
        self.branch_models = (0..self.n_branches)
            .map(|_| self.backend.build(self.n_way, self.d, self.hv_bits, self.metric, self.ldc_d))
            .collect();
    }

    pub fn with_precision(mut self, bits: u32) -> Self {
        self.hv_bits = bits;
        self.rebuild_models();
        self
    }

    pub fn with_metric(mut self, metric: Distance) -> Self {
        self.metric = metric;
        self.rebuild_models();
        self
    }

    /// Select the classifier backend (and, for LDC, the fold dimension —
    /// `0` = auto). Builder-order independent with the other knobs.
    pub fn with_backend(mut self, backend: ClassifierBackend, ldc_d: usize) -> Self {
        self.backend = backend;
        self.ldc_d = ldc_d;
        self.rebuild_models();
        self
    }

    /// Snapshot the session's configuration and full training history.
    pub fn snapshot(&self) -> SessionSnapshot {
        SessionSnapshot {
            n_way: self.n_way,
            d: self.d,
            n_branches: self.n_branches,
            backend: self.backend,
            hv_bits: self.hv_bits,
            metric: self.metric,
            ldc_d: self.ldc_d,
            shots: self.retained.clone(),
        }
    }

    /// Rebuild a session from a snapshot by replaying single-pass training
    /// shot by shot. Training is order-dependent but batch/serial
    /// bit-identical, so the rebuilt class memory matches the snapshotted
    /// session's exactly — for both HDC and LDC backends.
    pub fn rebuild(snap: &SessionSnapshot, id: u64) -> FslSession {
        let mut s = FslSession::new(id, snap.n_way, snap.d, snap.n_branches)
            .with_precision(snap.hv_bits)
            .with_metric(snap.metric)
            .with_backend(snap.backend, snap.ldc_d);
        for (class, hvs) in &snap.shots {
            s.train_shot(*class, hvs);
        }
        s
    }

    /// The classifier backend every branch runs.
    pub fn backend(&self) -> ClassifierBackend {
        self.backend
    }

    /// Class-memory precision (bits per stored element).
    pub fn hv_bits(&self) -> u32 {
        self.hv_bits
    }

    /// Distance metric used for inference.
    pub fn metric(&self) -> Distance {
        self.metric
    }

    /// Per-class *stored* dimension — what the class-memory admission
    /// accounting charges. HDC stores full-D class HVs (`== self.d`); LDC
    /// stores folded prototypes in `64..=512`.
    pub fn stored_dim(&self) -> usize {
        self.branch_models[0].stored_dim()
    }

    /// Total class-memory bits this session occupies across branches.
    pub fn class_mem_bits(&self) -> u64 {
        self.branch_models.iter().map(|m| m.class_mem_bits()).sum()
    }

    /// Single-pass training on one shot: `branch_hvs[b]` is the encoded HV
    /// of CONV block b's feature (all branches trained — EE training).
    pub fn train_shot(&mut self, class: usize, branch_hvs: &[Vec<f32>]) {
        assert_eq!(branch_hvs.len(), self.n_branches, "one HV per branch");
        for (m, hv) in self.branch_models.iter_mut().zip(branch_hvs) {
            m.train_shot(class, hv);
        }
        self.shots_seen += 1;
        self.retained.push((class, branch_hvs.to_vec()));
    }

    /// Batched single-pass training: all k same-class shots at once
    /// (Fig. 12) — bit-identical math to `train_shot` k times. Every shot
    /// is validated up front (a malformed request used to raw-index
    /// `shot[b]` and panic), and the per-branch views borrow the shot HVs
    /// instead of cloning them (the old path copied O(k·B·D) floats).
    pub fn train_batch(&mut self, class: usize, shots_branch_hvs: &[Vec<Vec<f32>>]) {
        for (s, shot) in shots_branch_hvs.iter().enumerate() {
            assert_eq!(
                shot.len(),
                self.n_branches,
                "shot {s}: {} branch HVs for a {}-branch session (one HV per branch)",
                shot.len(),
                self.n_branches
            );
        }
        for (b, m) in self.branch_models.iter_mut().enumerate() {
            let hvs: Vec<&[f32]> =
                shots_branch_hvs.iter().map(|shot| shot[b].as_slice()).collect();
            m.train_batch(class, &hvs);
        }
        self.shots_seen += shots_branch_hvs.len();
        // journal per shot: replay goes through train_shot, which is
        // bit-identical to the batched accumulation by contract
        for shot in shots_branch_hvs {
            self.retained.push((class, shot.clone()));
        }
    }

    pub fn is_trained(&self) -> bool {
        self.branch_models.iter().all(|m| m.is_trained())
    }

    /// Query using only the final branch (no early exit).
    pub fn query_full(&mut self, final_hv: &[f32]) -> QueryOutcome {
        let pred = self.branch_models[self.n_branches - 1].predict(final_hv);
        QueryOutcome { prediction: pred, blocks_used: self.n_branches, exited_early: false }
    }

    /// Prediction of CONV block `b`'s classifier for one encoded HV — the
    /// per-stage step of the coordinator's staged inference loop
    /// (DESIGN.md §Staged inference).
    pub fn predict_branch(&mut self, b: usize, hv: &[f32]) -> usize {
        self.branch_models[b].predict(hv)
    }

    /// Batched [`FslSession::predict_branch`] for a ragged survivor set:
    /// every HV is classified by the *same* branch model `b`, sharded over
    /// the worker pool with output bit-identical to the serial loop
    /// (DESIGN.md §Threading model).
    pub fn predict_branch_batch(
        &mut self,
        b: usize,
        hvs: &[Vec<f32>],
        shards: usize,
    ) -> Vec<usize> {
        self.branch_models[b].predict_batch(hvs, shards)
    }

    /// Query with early exit over **pre-computed** branch HVs: the
    /// controller stops as soon as (E_s, E_c) is satisfied. This is the
    /// post-hoc reference path (all features already extracted — what the
    /// coordinator executed before the staged refactor); the serving path
    /// in `coordinator::server` interleaves FE stages with these same
    /// predictions so the skipped tail is never computed, and property
    /// tests hold the two bit-identical.
    pub fn query_early_exit(&mut self, branch_hvs: &[Vec<f32>], ee: EeConfig) -> QueryOutcome {
        assert_eq!(branch_hvs.len(), self.n_branches);
        let mut ctl = EarlyExitController::new(ee);
        for (b, hv) in branch_hvs.iter().enumerate() {
            let pred = self.branch_models[b].predict(hv);
            if let EeDecision::Exit(p) = ctl.feed(b, pred) {
                return QueryOutcome {
                    prediction: p,
                    blocks_used: b + 1,
                    exited_early: b + 1 < self.n_branches,
                };
            }
        }
        // no exit fired: use the final block's prediction
        let final_pred = ctl.table.last().map(|&(_, p)| p).unwrap_or(0);
        QueryOutcome {
            prediction: final_pred,
            blocks_used: self.n_branches,
            exited_early: false,
        }
    }

    /// Distances from the final-branch model (for inspection / metrics).
    pub fn final_distances(&mut self, hv: &[f32]) -> Vec<f64> {
        self.branch_models[self.n_branches - 1].distances(hv)
    }

    /// Prediction from distances (exposed for the fused-PJRT path, where
    /// the distance table arrives from the artifact).
    pub fn predict_from_distances(dists: &[f64]) -> usize {
        argmin(dists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn hv(rng: &mut Rng, proto: &[f32]) -> Vec<f32> {
        proto.iter().map(|p| p + 0.3 * rng.gauss_f32()).collect()
    }

    fn protos(rng: &mut Rng, n: usize, d: usize) -> Vec<Vec<f32>> {
        (0..n).map(|_| (0..d).map(|_| 2.0 * rng.gauss_f32()).collect()).collect()
    }

    #[test]
    fn train_and_query_full() {
        let d = 256;
        let mut rng = Rng::new(1);
        let ps = protos(&mut rng, 3, d);
        let mut s = FslSession::new(1, 3, d, 4);
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..5 {
                let hvs: Vec<Vec<f32>> = (0..4).map(|_| hv(&mut rng, p)).collect();
                s.train_shot(c, &hvs);
            }
        }
        assert!(s.is_trained());
        assert_eq!(s.shots_seen, 15);
        for (c, p) in ps.iter().enumerate() {
            let out = s.query_full(&hv(&mut rng, p));
            assert_eq!(out.prediction, c);
            assert_eq!(out.blocks_used, 4);
        }
    }

    #[test]
    fn batch_matches_sequential() {
        let d = 64;
        let mut rng = Rng::new(2);
        let p = protos(&mut rng, 1, d).remove(0);
        let shots: Vec<Vec<Vec<f32>>> = (0..5)
            .map(|_| (0..2).map(|_| hv(&mut rng, &p)).collect())
            .collect();
        let mut seq = FslSession::new(1, 2, d, 2);
        for shot in &shots {
            seq.train_shot(0, shot);
        }
        let mut bat = FslSession::new(2, 2, d, 2);
        bat.train_batch(0, &shots);
        assert_eq!(seq.shots_seen, bat.shots_seen);
        // row-major batched accumulation is bit-identical to sequential
        let q = hv(&mut rng, &p);
        assert_eq!(seq.final_distances(&q), bat.final_distances(&q));
    }

    #[test]
    #[should_panic(expected = "one HV per branch")]
    fn batch_shot_arity_checked() {
        // regression: a malformed shot used to raw-index shot[b] and panic
        // with an opaque out-of-bounds message
        let mut s = FslSession::new(1, 2, 16, 4);
        let good: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; 16]).collect();
        let short: Vec<Vec<f32>> = (0..2).map(|_| vec![0.0; 16]).collect();
        s.train_batch(0, &[good, short]);
    }

    #[test]
    fn nan_distance_row_cannot_elect_class_zero() {
        // regression: hdc::distance::argmin was NaN-blind — with
        // dists[0] = NaN every comparison was false and class 0 won
        assert_eq!(FslSession::predict_from_distances(&[f64::NAN, 5.0, 3.0]), 2);
        assert_eq!(FslSession::predict_from_distances(&[f64::NAN, f64::NAN, 1.0, 2.0]), 2);
        assert_eq!(FslSession::predict_from_distances(&[f64::NAN]), 0, "all-NaN falls back");
    }

    #[test]
    fn predict_branch_matches_query_paths() {
        let d = 64;
        let mut rng = Rng::new(9);
        let ps = protos(&mut rng, 2, d);
        let mut s = FslSession::new(1, 2, d, 2);
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..4 {
                let hvs: Vec<Vec<f32>> = (0..2).map(|_| hv(&mut rng, p)).collect();
                s.train_shot(c, &hvs);
            }
        }
        let q = hv(&mut rng, &ps[1]);
        // the final branch's predict_branch IS query_full's prediction
        assert_eq!(s.predict_branch(1, &q), s.query_full(&q).prediction);
        // batched branch prediction is bit-identical to the serial loop
        let qs: Vec<Vec<f32>> = (0..5).map(|_| hv(&mut rng, &ps[0])).collect();
        for b in 0..2 {
            let serial: Vec<usize> = qs.iter().map(|x| s.predict_branch(b, x)).collect();
            for shards in [1, 2, 7] {
                assert_eq!(s.predict_branch_batch(b, &qs, shards), serial, "b={b}");
            }
        }
    }

    #[test]
    fn early_exit_uses_fewer_blocks_when_confident() {
        let d = 256;
        let mut rng = Rng::new(3);
        let ps = protos(&mut rng, 2, d);
        let mut s = FslSession::new(1, 2, d, 4);
        for (c, p) in ps.iter().enumerate() {
            for _ in 0..5 {
                let hvs: Vec<Vec<f32>> = (0..4).map(|_| hv(&mut rng, p)).collect();
                s.train_shot(c, &hvs);
            }
        }
        // every branch agrees -> exit at block E_s..E_s+E_c-1
        let hvs: Vec<Vec<f32>> = (0..4).map(|_| hv(&mut rng, &ps[0])).collect();
        let out = s.query_early_exit(&hvs, crate::config::EeConfig { e_s: 1, e_c: 2 });
        assert_eq!(out.prediction, 0);
        assert_eq!(out.blocks_used, 2);
        assert!(out.exited_early);
    }

    #[test]
    fn early_exit_runs_full_when_branches_disagree() {
        let d = 128;
        let mut rng = Rng::new(4);
        let ps = protos(&mut rng, 2, d);
        let mut s = FslSession::new(1, 2, d, 4);
        for (c, p) in ps.iter().enumerate() {
            let hvs: Vec<Vec<f32>> = (0..4).map(|_| hv(&mut rng, p)).collect();
            s.train_shot(c, &hvs);
        }
        // feed alternating-class branch HVs: no two consecutive agree
        let hvs = vec![
            hv(&mut rng, &ps[0]),
            hv(&mut rng, &ps[1]),
            hv(&mut rng, &ps[0]),
            hv(&mut rng, &ps[1]),
        ];
        let out = s.query_early_exit(&hvs, crate::config::EeConfig { e_s: 1, e_c: 2 });
        assert_eq!(out.blocks_used, 4);
        assert!(!out.exited_early);
    }

    #[test]
    #[should_panic(expected = "one HV per branch")]
    fn branch_arity_checked() {
        let mut s = FslSession::new(1, 2, 16, 4);
        s.train_shot(0, &[vec![0.0; 16]]);
    }

    #[test]
    #[should_panic(expected = "at least one class")]
    fn zero_way_session_rejected() {
        FslSession::new(1, 0, 16, 1);
    }

    #[test]
    #[should_panic(expected = "non-empty HV dimension")]
    fn zero_dim_session_rejected() {
        FslSession::new(1, 2, 0, 1);
    }

    #[test]
    fn backend_conformance_train_query_and_shards() {
        // the same session battery over every backend: train/query
        // accuracy, batch-vs-sequential bit-identity, sharded prediction
        // bit-identity — the seam must not change any serving contract
        let d = 256;
        for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
            let mut rng = Rng::new(31);
            let ps = protos(&mut rng, 3, d);
            let mut s = FslSession::new(1, 3, d, 2).with_precision(8).with_backend(backend, 0);
            assert_eq!(s.backend(), backend);
            let shots: Vec<Vec<Vec<f32>>> =
                (0..5).map(|_| (0..2).map(|_| hv(&mut rng, &ps[0])).collect()).collect();
            let mut seq = s.clone();
            for shot in &shots {
                seq.train_shot(0, shot);
            }
            let mut bat = s.clone();
            bat.train_batch(0, &shots);
            let q = hv(&mut rng, &ps[0]);
            assert_eq!(seq.final_distances(&q), bat.final_distances(&q), "{backend:?}");

            for (c, p) in ps.iter().enumerate() {
                for _ in 0..5 {
                    let hvs: Vec<Vec<f32>> = (0..2).map(|_| hv(&mut rng, p)).collect();
                    s.train_shot(c, &hvs);
                }
            }
            assert!(s.is_trained());
            for (c, p) in ps.iter().enumerate() {
                assert_eq!(s.query_full(&hv(&mut rng, p)).prediction, c, "{backend:?}");
            }
            let qs: Vec<Vec<f32>> = (0..6).map(|_| hv(&mut rng, &ps[1])).collect();
            let serial: Vec<usize> = qs.iter().map(|x| s.predict_branch(1, x)).collect();
            for shards in [1, 2, 7] {
                assert_eq!(s.predict_branch_batch(1, &qs, shards), serial, "{backend:?}");
            }
        }
    }

    #[test]
    fn rebuild_from_snapshot_is_bit_identical_for_both_backends() {
        let d = 256;
        for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
            let mut rng = Rng::new(77);
            let ps = protos(&mut rng, 4, d);
            let mut s = FslSession::new(1, 4, d, 3)
                .with_precision(4)
                .with_metric(Distance::L1)
                .with_backend(backend, 0);
            // mix per-shot and batched training so the journal covers both
            for (c, p) in ps.iter().enumerate().take(2) {
                for _ in 0..5 {
                    let hvs: Vec<Vec<f32>> = (0..3).map(|_| hv(&mut rng, p)).collect();
                    s.train_shot(c, &hvs);
                }
            }
            for (c, p) in ps.iter().enumerate().skip(2) {
                let shots: Vec<Vec<Vec<f32>>> =
                    (0..5).map(|_| (0..3).map(|_| hv(&mut rng, p)).collect()).collect();
                s.train_batch(c, &shots);
            }
            let snap = s.snapshot();
            assert_eq!(snap.shots.len(), 20, "{backend:?}: journal retains every shot");
            let mut r = FslSession::rebuild(&snap, 99);
            assert_eq!(r.shots_seen, s.shots_seen);
            assert_eq!(r.backend(), backend);
            assert_eq!(r.stored_dim(), s.stored_dim());
            // the recovery invariant: distances (hence predictions) from
            // the rebuilt class memory are bit-identical
            for p in &ps {
                let q = hv(&mut rng, p);
                assert_eq!(s.final_distances(&q), r.final_distances(&q), "{backend:?}");
                for b in 0..3 {
                    assert_eq!(s.predict_branch(b, &q), r.predict_branch(b, &q), "{backend:?}");
                }
            }
            // a rebuilt session can itself be snapshotted and rebuilt
            let rr = FslSession::rebuild(&r.snapshot(), 100);
            let q = hv(&mut rng, &ps[0]);
            assert_eq!(r.final_distances(&q), FslSession::rebuild(&rr.snapshot(), 101).final_distances(&q));
        }
    }

    #[test]
    fn untrained_snapshot_rebuilds_untrained() {
        let s = FslSession::new(1, 3, 64, 2).with_precision(8);
        let r = FslSession::rebuild(&s.snapshot(), 2);
        assert_eq!(r.shots_seen, 0);
        assert!(!r.is_trained());
        assert_eq!(r.hv_bits(), 8);
    }

    #[test]
    fn backend_builder_order_independent() {
        let a = FslSession::new(1, 4, 512, 2)
            .with_backend(ClassifierBackend::Ldc, 0)
            .with_precision(4);
        let b = FslSession::new(1, 4, 512, 2)
            .with_precision(4)
            .with_backend(ClassifierBackend::Ldc, 0);
        assert_eq!(a.backend(), b.backend());
        assert_eq!(a.hv_bits(), b.hv_bits());
        assert_eq!(a.stored_dim(), b.stored_dim());
        assert_eq!(a.class_mem_bits(), b.class_mem_bits());
    }

    #[test]
    fn class_mem_bits_reflect_the_backend() {
        // matched n_way/D/bits: LDC's folded store is the class-memory win
        let hdc = FslSession::new(1, 10, 4096, 2).with_precision(4);
        let ldc =
            FslSession::new(2, 10, 4096, 2).with_precision(4).with_backend(ClassifierBackend::Ldc, 0);
        assert_eq!(hdc.stored_dim(), 4096);
        assert_eq!(ldc.stored_dim(), 512);
        assert_eq!(hdc.class_mem_bits(), 2 * 10 * 4096 * 4);
        assert!(hdc.class_mem_bits() >= 4 * ldc.class_mem_bits());
    }
}
