//! Coordinator metrics: request counters and latency distributions for
//! every operation class, snapshotted on demand.

use crate::util::stats::OnlineStats;

/// Operation classes tracked separately.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    AddShot,
    Train,
    Query,
}

/// Exit-depth histogram bins: queries that used `bin + 1` CONV blocks.
/// Sized for the deepest synthetic geometry (`[model] stages` is capped at
/// 8); deeper models clamp into the last bin.
pub const DEPTH_BINS: usize = 8;

/// Live metrics owned by the worker thread.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub add_shot: OnlineStats,
    pub train: OnlineStats,
    pub query: OnlineStats,
    pub queries_exited_early: u64,
    pub blocks_used_total: u64,
    /// per-exit-depth query counts: `query_depth_hist[b]` = queries that
    /// used b+1 CONV blocks (the Fig. 17 exit histogram, live)
    pub query_depth_hist: [u64; DEPTH_BINS],
    /// FE conv layers actually executed across queries — with staged
    /// inference an early exit truncates real compute, so this is a work
    /// counter, not an inference from `blocks_used`
    pub fe_layers_executed: u64,
    /// FE conv layers early exit skipped (plan total minus executed)
    pub fe_layers_skipped: u64,
    /// branch HVs cRP-encoded for queries (an exit at block b encodes
    /// exactly b+1; a no-EE query encodes only the final branch)
    pub branch_hvs_encoded: u64,
    pub errors: u64,
    /// feature-mode inputs shorter than the model's F that were zero-padded
    /// — legal but usually a client bug worth surfacing (empty features are
    /// rejected outright: an all-zero HV would train a garbage prototype)
    pub feature_pads: u64,
}

impl Metrics {
    pub fn record(&mut self, op: Op, seconds: f64) {
        let s = seconds * 1e3; // store milliseconds
        match op {
            Op::AddShot => self.add_shot.push(s),
            Op::Train => self.train.push(s),
            Op::Query => self.query.push(s),
        }
    }

    /// Record `n` operations served by one batched call: each gets the
    /// per-item share of the wall time, so batch and per-shot arrivals
    /// report comparable per-op latencies and identical op counts.
    pub fn record_batch(&mut self, op: Op, n: usize, seconds: f64) {
        let per = seconds / n.max(1) as f64;
        for _ in 0..n {
            self.record(op, per);
        }
    }

    /// Count a zero-padded short feature and warn once (the counter keeps
    /// the full tally; the log line avoids per-request spam).
    pub fn record_feature_pad(&mut self, got: usize, fdim: usize) {
        self.feature_pads += 1;
        if self.feature_pads == 1 {
            eprintln!(
                "warning: feature length {got} < model F={fdim}, zero-padding \
                 (further pads counted in metrics.feature_pads only)"
            );
        }
    }

    pub fn record_query_depth(&mut self, blocks_used: usize, exited_early: bool) {
        self.blocks_used_total += blocks_used as u64;
        self.query_depth_hist[blocks_used.saturating_sub(1).min(DEPTH_BINS - 1)] += 1;
        if exited_early {
            self.queries_exited_early += 1;
        }
    }

    /// Depth accounting for FE-bypassing feature queries: they count into
    /// the blocks average (the classifier used the final branch) but NOT
    /// into `query_depth_hist` — the histogram weights FE energy by exit
    /// depth, and a query that ran zero FE stages must not be priced as a
    /// full FE pass.
    pub fn record_feature_query_depth(&mut self, blocks_used: usize) {
        self.blocks_used_total += blocks_used as u64;
    }

    /// Account the work one query (or one batch of queries) actually
    /// executed: conv layers run, conv layers the exit skipped, branch HVs
    /// encoded. Fed from the staged executor's counters, so the numbers
    /// prove what ran rather than inferring it from `blocks_used`.
    pub fn record_query_work(&mut self, layers_executed: u64, layers_skipped: u64, hvs: u64) {
        self.fe_layers_executed += layers_executed;
        self.fe_layers_skipped += layers_skipped;
        self.branch_hvs_encoded += hvs;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let q = self.query.n.max(1) as f64;
        MetricsSnapshot {
            shots: self.add_shot.n,
            trains: self.train.n,
            queries: self.query.n,
            errors: self.errors,
            feature_pads: self.feature_pads,
            add_shot_ms_mean: self.add_shot.mean(),
            train_ms_mean: self.train.mean(),
            query_ms_mean: self.query.mean(),
            query_ms_max: if self.query.n == 0 { 0.0 } else { self.query.max },
            early_exit_rate: self.queries_exited_early as f64 / q,
            avg_blocks_used: self.blocks_used_total as f64 / q,
            query_depth_hist: self.query_depth_hist,
            fe_layers_executed: self.fe_layers_executed,
            fe_layers_skipped: self.fe_layers_skipped,
            branch_hvs_encoded: self.branch_hvs_encoded,
            // class-memory occupancy/gating are owned by the coordinator
            // worker's ClassMemoryManager, and the shed counter by the
            // serving load signal — both filled in at GetMetrics time
            class_mem_used_bits: 0,
            class_mem_active_banks: 0,
            class_mem_gated_banks: 0,
            requests_shed: 0,
            // fault-recovery counters are owned by the DeviceRouter (the
            // device whose sessions were re-placed is dead and cannot
            // report them) — filled into the fleet snapshot by the router
            device_failures: 0,
            sessions_replaced: 0,
            retrain_ms: 0.0,
        }
    }
}

/// Immutable snapshot returned over the wire.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub shots: u64,
    pub trains: u64,
    pub queries: u64,
    pub errors: u64,
    pub feature_pads: u64,
    pub add_shot_ms_mean: f64,
    pub train_ms_mean: f64,
    pub query_ms_mean: f64,
    pub query_ms_max: f64,
    pub early_exit_rate: f64,
    pub avg_blocks_used: f64,
    /// queries per exit depth: bin b = queries that used b+1 CONV blocks
    pub query_depth_hist: [u64; DEPTH_BINS],
    /// FE conv layers actually executed across all queries
    pub fe_layers_executed: u64,
    /// FE conv layers early exit skipped (never computed, not post-hoc)
    pub fe_layers_skipped: u64,
    /// branch HVs cRP-encoded for queries (exit at block b ⇒ b+1 encodes)
    pub branch_hvs_encoded: u64,
    /// class-memory occupancy (bits) across open sessions
    pub class_mem_used_bits: u64,
    /// banks that must stay powered for that occupancy (Fig. 9)
    pub class_mem_active_banks: usize,
    /// banks gated off — the energy model prices the standby saving
    pub class_mem_gated_banks: usize,
    /// requests refused with `Response::Busy` by the TCP gateway's
    /// admission control; counted by the gateway (the shed happens before
    /// the worker ever sees the request) and filled in at `GetMetrics`
    pub requests_shed: u64,
    /// devices the router declared Dead (worker gone or struck out).
    /// Router-owned; 0 in a single-device snapshot. Wire decode tolerates
    /// absence (old frames) by defaulting to 0.
    pub device_failures: u64,
    /// sessions re-placed onto a healthy device and retrained from their
    /// shot journal after a device failure (router-owned, see above)
    pub sessions_replaced: u64,
    /// total wall time spent in journal-replay retrains (router-owned)
    pub retrain_ms: f64,
}

impl MetricsSnapshot {
    /// Merge another device's snapshot into this one for fleet-wide
    /// aggregation: counts and histograms add, means combine weighted by
    /// their op counts, maxes take the max. Gauges (class-memory occupancy
    /// and bank counts) add — the fleet's total occupancy is the sum of
    /// per-device occupancies.
    pub fn absorb(&mut self, o: &MetricsSnapshot) {
        fn wmean(a: f64, na: u64, b: f64, nb: u64) -> f64 {
            let n = na + nb;
            if n == 0 {
                0.0
            } else {
                (a * na as f64 + b * nb as f64) / n as f64
            }
        }
        self.add_shot_ms_mean = wmean(self.add_shot_ms_mean, self.shots, o.add_shot_ms_mean, o.shots);
        self.train_ms_mean = wmean(self.train_ms_mean, self.trains, o.train_ms_mean, o.trains);
        self.query_ms_mean = wmean(self.query_ms_mean, self.queries, o.query_ms_mean, o.queries);
        self.early_exit_rate = wmean(self.early_exit_rate, self.queries, o.early_exit_rate, o.queries);
        self.avg_blocks_used = wmean(self.avg_blocks_used, self.queries, o.avg_blocks_used, o.queries);
        self.query_ms_max = self.query_ms_max.max(o.query_ms_max);
        self.shots += o.shots;
        self.trains += o.trains;
        self.queries += o.queries;
        self.errors += o.errors;
        self.feature_pads += o.feature_pads;
        for (b, ob) in self.query_depth_hist.iter_mut().zip(o.query_depth_hist.iter()) {
            *b += ob;
        }
        self.fe_layers_executed += o.fe_layers_executed;
        self.fe_layers_skipped += o.fe_layers_skipped;
        self.branch_hvs_encoded += o.branch_hvs_encoded;
        self.class_mem_used_bits += o.class_mem_used_bits;
        self.class_mem_active_banks += o.class_mem_active_banks;
        self.class_mem_gated_banks += o.class_mem_gated_banks;
        self.requests_shed += o.requests_shed;
        self.device_failures += o.device_failures;
        self.sessions_replaced += o.sessions_replaced;
        self.retrain_ms += o.retrain_ms;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let mut m = Metrics::default();
        m.record(Op::AddShot, 0.001);
        m.record(Op::AddShot, 0.003);
        m.record(Op::Query, 0.010);
        m.record_query_depth(2, true);
        let s = m.snapshot();
        assert_eq!(s.shots, 2);
        assert_eq!(s.queries, 1);
        assert!((s.add_shot_ms_mean - 2.0).abs() < 1e-9);
        assert!((s.early_exit_rate - 1.0).abs() < 1e-9);
        assert!((s.avg_blocks_used - 2.0).abs() < 1e-9);
    }

    #[test]
    fn empty_snapshot_is_zeroed() {
        let s = Metrics::default().snapshot();
        assert_eq!(s.queries, 0);
        assert_eq!(s.query_ms_max, 0.0);
        assert_eq!(s.feature_pads, 0);
    }

    #[test]
    fn record_batch_counts_per_item() {
        let mut m = Metrics::default();
        m.record_batch(Op::AddShot, 5, 0.010);
        let s = m.snapshot();
        assert_eq!(s.shots, 5, "one op per batched item");
        assert!((s.add_shot_ms_mean - 2.0).abs() < 1e-9, "per-item share of wall time");
        // n = 0 records nothing (and must not divide by zero)
        m.record_batch(Op::Train, 0, 1.0);
        assert_eq!(m.snapshot().trains, 0);
    }

    #[test]
    fn depth_histogram_and_work_counters() {
        let mut m = Metrics::default();
        m.record_query_depth(2, true);
        m.record_query_depth(2, true);
        m.record_query_depth(4, false);
        m.record_query_depth(99, false); // deeper than DEPTH_BINS clamps
        let mut want = [0u64; DEPTH_BINS];
        want[1] = 2;
        want[3] = 1;
        want[DEPTH_BINS - 1] = 1;
        assert_eq!(m.snapshot().query_depth_hist, want);
        // FE-bypassing feature queries count blocks but never enter the
        // histogram that prices FE energy by exit depth
        m.record_feature_query_depth(4);
        assert_eq!(m.snapshot().query_depth_hist, want);
        assert_eq!(m.blocks_used_total, 2 + 2 + 4 + 99 + 4);
        // work counters accumulate what the staged executor reports
        m.record_query_work(7, 13, 2);
        m.record_query_work(20, 0, 1);
        let s = m.snapshot();
        assert_eq!(s.fe_layers_executed, 27);
        assert_eq!(s.fe_layers_skipped, 13);
        assert_eq!(s.branch_hvs_encoded, 3);
    }

    #[test]
    fn absorb_merges_counts_and_weights_means() {
        let mut a = Metrics::default();
        a.record(Op::Query, 0.002);
        a.record(Op::Query, 0.004);
        let mut b = Metrics::default();
        b.record(Op::Query, 0.010);
        let mut sa = a.snapshot();
        let sb = b.snapshot();
        let mut merged = sa;
        merged.absorb(&sb);
        assert_eq!(merged.queries, 3);
        assert!((merged.query_ms_mean - (2.0 + 4.0 + 10.0) / 3.0).abs() < 1e-9);
        assert!((merged.query_ms_max - 10.0).abs() < 1e-9);
        // absorbing an empty snapshot changes nothing
        sa.absorb(&MetricsSnapshot::default());
        assert_eq!(sa, a.snapshot());
        // router-owned recovery counters add
        let mut r = MetricsSnapshot { device_failures: 1, sessions_replaced: 2, ..Default::default() };
        r.absorb(&MetricsSnapshot { sessions_replaced: 3, retrain_ms: 1.5, ..Default::default() });
        assert_eq!((r.device_failures, r.sessions_replaced), (1, 5));
        assert!((r.retrain_ms - 1.5).abs() < 1e-12);
    }

    #[test]
    fn feature_pads_counted() {
        let mut m = Metrics::default();
        m.record_feature_pad(16, 128);
        m.record_feature_pad(8, 128);
        assert_eq!(m.snapshot().feature_pads, 2);
    }
}
