//! Length-prefixed JSON wire format for [`Request`] / [`Response`] — the
//! serialization layer under the TCP gateway (DESIGN.md §Serving runtime).
//! Built on `util::json` only: the offline registry policy (anyhow is the
//! sole external crate) rules out serde.
//!
//! Framing: a 4-byte big-endian `u32` payload length, then that many
//! bytes of UTF-8 JSON. Every message is an object with a `"type"` tag
//! (`snake_case` of the variant name) plus the variant's fields.
//!
//! Exactness: `f32` image/feature data round-trips bit-exactly for all
//! finite values — each `f32` widens losslessly to `f64`, prints via
//! Rust's shortest-roundtrip float formatting, reparses to the same
//! `f64`, and narrows back to the original `f32`. Non-finite floats are
//! the documented exception: JSON has no NaN/inf literal, `util::json`
//! writes them as `null`, and decode rejects the frame — a query carrying
//! NaN pixels fails loudly at the boundary instead of corrupting a
//! session. Session ids and counters are exact below 2^53 (ids are
//! sequential from 1, so this never binds in practice).
//!
//! Panic audit (PR 9, enforced by `fsl_lint`'s `panic-in-serving` rule):
//! every `unwrap`/`panic!` in this file lives in `#[cfg(test)]`. The
//! non-test decode path is fully typed — malformed frames, unknown tags,
//! oversized lengths and non-finite floats all surface as `Err`/`Error`
//! frames, never as a gateway death.

use std::io::{Read, Write};

use crate::classifier::ClassifierBackend;
use crate::config::EeConfig;
use crate::coordinator::metrics::{MetricsSnapshot, DEPTH_BINS};
use crate::coordinator::request::{Request, Response};
use crate::coordinator::session::QueryOutcome;
use crate::hdc::Distance;
use crate::util::json::{Json, JsonWriter};

/// Write one frame: 4-byte big-endian length prefix + payload.
pub fn write_frame(w: &mut impl Write, payload: &[u8], max_bytes: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        payload.len() <= max_bytes && payload.len() <= u32::MAX as usize,
        "frame of {} bytes exceeds the {max_bytes}-byte cap",
        payload.len()
    );
    w.write_all(&(payload.len() as u32).to_be_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. `Ok(None)` is a clean EOF at a frame boundary (the
/// peer closed between messages). Errors — truncated header, truncated
/// payload, or a length prefix over `max_bytes` — leave the stream
/// desynchronized; the connection handler answers best-effort and closes.
pub fn read_frame(r: &mut impl Read, max_bytes: usize) -> anyhow::Result<Option<Vec<u8>>> {
    read_frame_cancellable(r, max_bytes, &mut || false)
}

/// [`read_frame`] for streams carrying a read timeout: a timed-out read
/// (`WouldBlock`/`TimedOut`) polls `cancelled` and, if the caller still
/// wants the frame, resumes exactly where it left off — partial header or
/// payload bytes are never lost, so a slow peer's frame is not torn by the
/// timeout tick. `cancelled() == true` returns `Ok(None)` (treated like a
/// clean close; the gateway uses this so a client stalled mid-frame cannot
/// block joined shutdown). `Interrupted` reads always resume.
pub fn read_frame_cancellable(
    r: &mut impl Read,
    max_bytes: usize,
    cancelled: &mut dyn FnMut() -> bool,
) -> anyhow::Result<Option<Vec<u8>>> {
    fn fill(
        r: &mut impl Read,
        buf: &mut [u8],
        cancelled: &mut dyn FnMut() -> bool,
        what: &str,
    ) -> anyhow::Result<Option<usize>> {
        // Ok(Some(n)): n bytes read before EOF (n == buf.len() means done);
        // Ok(None): cancelled mid-read.
        let mut got = 0;
        while got < buf.len() {
            match r.read(&mut buf[got..]) {
                Ok(0) => return Ok(Some(got)),
                Ok(n) => got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
                {
                    if cancelled() {
                        return Ok(None);
                    }
                }
                Err(e) => anyhow::bail!("read failed mid-{what}: {e}"),
            }
        }
        Ok(Some(got))
    }

    let mut hdr = [0u8; 4];
    let got = match fill(r, &mut hdr, cancelled, "header")? {
        None => return Ok(None),
        Some(g) => g,
    };
    if got == 0 {
        return Ok(None);
    }
    anyhow::ensure!(got == 4, "truncated frame header ({got}/4 bytes)");
    let len = u32::from_be_bytes(hdr) as usize;
    anyhow::ensure!(len <= max_bytes, "oversized frame: {len} bytes exceeds the cap {max_bytes}");
    let mut buf = vec![0u8; len];
    match fill(r, &mut buf, cancelled, "payload")? {
        None => Ok(None),
        Some(g) if g == len => Ok(Some(buf)),
        Some(g) => anyhow::bail!("truncated frame payload: {g}/{len} bytes"),
    }
}

// --- encoding ------------------------------------------------------------

fn f32_arr(w: &mut JsonWriter, key: &str, v: &[f32]) {
    w.key(key).arr();
    for &x in v {
        w.num(f64::from(x));
    }
    w.end_arr();
}

fn f32_mat(w: &mut JsonWriter, key: &str, vs: &[Vec<f32>]) {
    w.key(key).arr();
    for v in vs {
        w.arr();
        for &x in v {
            w.num(f64::from(x));
        }
        w.end_arr();
    }
    w.end_arr();
}

fn ee_field(w: &mut JsonWriter, ee: &Option<EeConfig>) {
    if let Some(e) = ee {
        w.key("ee").obj();
        w.field_num("e_s", e.e_s as f64);
        w.field_num("e_c", e.e_c as f64);
        w.end_obj();
    }
}

fn outcome_obj(w: &mut JsonWriter, o: &QueryOutcome) {
    w.obj();
    w.field_num("prediction", o.prediction as f64);
    w.field_num("blocks_used", o.blocks_used as f64);
    w.key("exited_early").bool_val(o.exited_early);
    w.end_obj();
}

/// Serialize a request to its JSON payload (no frame prefix).
pub fn encode_request(req: &Request) -> Vec<u8> {
    let mut w = JsonWriter::new();
    w.obj();
    match req {
        Request::CreateSession { n_way, hv_bits, metric, backend } => {
            w.field_str("type", "create_session");
            w.field_num("n_way", *n_way as f64);
            w.field_num("hv_bits", f64::from(*hv_bits));
            w.field_str("metric", metric.name());
            w.field_str("backend", backend.name());
        }
        Request::AddShot { session, class, image } => {
            w.field_str("type", "add_shot");
            w.field_num("session", *session as f64);
            w.field_num("class", *class as f64);
            f32_arr(&mut w, "image", image);
        }
        Request::AddShotBatch { session, class, images } => {
            w.field_str("type", "add_shot_batch");
            w.field_num("session", *session as f64);
            w.field_num("class", *class as f64);
            f32_mat(&mut w, "images", images);
        }
        Request::AddFeatureShot { session, class, feature } => {
            w.field_str("type", "add_feature_shot");
            w.field_num("session", *session as f64);
            w.field_num("class", *class as f64);
            f32_arr(&mut w, "feature", feature);
        }
        Request::QueryFeature { session, feature } => {
            w.field_str("type", "query_feature");
            w.field_num("session", *session as f64);
            f32_arr(&mut w, "feature", feature);
        }
        Request::FinishTraining { session } => {
            w.field_str("type", "finish_training");
            w.field_num("session", *session as f64);
        }
        Request::Query { session, image, ee } => {
            w.field_str("type", "query");
            w.field_num("session", *session as f64);
            f32_arr(&mut w, "image", image);
            ee_field(&mut w, ee);
        }
        Request::QueryBatch { session, images, ee } => {
            w.field_str("type", "query_batch");
            w.field_num("session", *session as f64);
            f32_mat(&mut w, "images", images);
            ee_field(&mut w, ee);
        }
        Request::CloseSession { session } => {
            w.field_str("type", "close_session");
            w.field_num("session", *session as f64);
        }
        Request::GetMetrics => {
            w.field_str("type", "get_metrics");
        }
        Request::Shutdown => {
            w.field_str("type", "shutdown");
        }
    }
    w.end_obj();
    w.finish().into_bytes()
}

/// Serialize a response to its JSON payload (no frame prefix).
pub fn encode_response(resp: &Response) -> Vec<u8> {
    let mut w = JsonWriter::new();
    w.obj();
    match resp {
        Response::SessionCreated { session } => {
            w.field_str("type", "session_created");
            w.field_num("session", *session as f64);
        }
        Response::ShotAccepted { session, pending, trained_classes } => {
            w.field_str("type", "shot_accepted");
            w.field_num("session", *session as f64);
            w.field_num("pending", *pending as f64);
            w.field_num("trained_classes", *trained_classes as f64);
        }
        Response::TrainingDone { session, shots } => {
            w.field_str("type", "training_done");
            w.field_num("session", *session as f64);
            w.field_num("shots", *shots as f64);
        }
        Response::QueryResult { session, outcome } => {
            w.field_str("type", "query_result");
            w.field_num("session", *session as f64);
            w.key("outcome");
            outcome_obj(&mut w, outcome);
        }
        Response::QueryBatchResult { session, outcomes } => {
            w.field_str("type", "query_batch_result");
            w.field_num("session", *session as f64);
            w.key("outcomes").arr();
            for o in outcomes {
                outcome_obj(&mut w, o);
            }
            w.end_arr();
        }
        Response::SessionClosed { session } => {
            w.field_str("type", "session_closed");
            w.field_num("session", *session as f64);
        }
        Response::Metrics(m) => {
            w.field_str("type", "metrics");
            w.field_num("shots", m.shots as f64);
            w.field_num("trains", m.trains as f64);
            w.field_num("queries", m.queries as f64);
            w.field_num("errors", m.errors as f64);
            w.field_num("feature_pads", m.feature_pads as f64);
            w.field_num("add_shot_ms_mean", m.add_shot_ms_mean);
            w.field_num("train_ms_mean", m.train_ms_mean);
            w.field_num("query_ms_mean", m.query_ms_mean);
            w.field_num("query_ms_max", m.query_ms_max);
            w.field_num("early_exit_rate", m.early_exit_rate);
            w.field_num("avg_blocks_used", m.avg_blocks_used);
            w.key("query_depth_hist").arr();
            for &b in &m.query_depth_hist {
                w.num(b as f64);
            }
            w.end_arr();
            w.field_num("fe_layers_executed", m.fe_layers_executed as f64);
            w.field_num("fe_layers_skipped", m.fe_layers_skipped as f64);
            w.field_num("branch_hvs_encoded", m.branch_hvs_encoded as f64);
            w.field_num("class_mem_used_bits", m.class_mem_used_bits as f64);
            w.field_num("class_mem_active_banks", m.class_mem_active_banks as f64);
            w.field_num("class_mem_gated_banks", m.class_mem_gated_banks as f64);
            w.field_num("requests_shed", m.requests_shed as f64);
            w.field_num("device_failures", m.device_failures as f64);
            w.field_num("sessions_replaced", m.sessions_replaced as f64);
            w.field_num("retrain_ms", m.retrain_ms);
        }
        Response::ShuttingDown => {
            w.field_str("type", "shutting_down");
        }
        Response::Busy { queue_depth } => {
            w.field_str("type", "busy");
            w.field_num("queue_depth", *queue_depth as f64);
        }
        // Both error flavors share the "error" type tag so pre-taxonomy
        // clients (which read only "message") keep decoding them; the
        // retryable flag is an extra field new clients key retries off.
        Response::RetryableError(msg) => {
            w.field_str("type", "error");
            w.field_str("message", msg);
            w.key("retryable").bool_val(true);
        }
        Response::Error(msg) => {
            w.field_str("type", "error");
            w.field_str("message", msg);
        }
    }
    w.end_obj();
    w.finish().into_bytes()
}

// --- decoding ------------------------------------------------------------

fn get_f64(j: &Json, key: &str) -> anyhow::Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow::anyhow!("missing or non-numeric field {key:?}"))
}

fn get_usize(j: &Json, key: &str) -> anyhow::Result<usize> {
    Ok(get_f64(j, key)? as usize)
}

fn get_u64(j: &Json, key: &str) -> anyhow::Result<u64> {
    Ok(get_f64(j, key)? as u64)
}

/// Absent-tolerant u64: fields added after a wire release use this so
/// frames from older peers (which lack the field) still decode.
fn get_u64_or(j: &Json, key: &str, default: u64) -> anyhow::Result<u64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(_) => get_u64(j, key),
    }
}

/// Absent-tolerant f64 (see [`get_u64_or`]).
fn get_f64_or(j: &Json, key: &str, default: f64) -> anyhow::Result<f64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(default),
        Some(_) => get_f64(j, key),
    }
}

fn get_str<'a>(j: &'a Json, key: &str) -> anyhow::Result<&'a str> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("missing or non-string field {key:?}"))
}

fn get_bool(j: &Json, key: &str) -> anyhow::Result<bool> {
    j.get(key)
        .and_then(Json::as_bool)
        .ok_or_else(|| anyhow::anyhow!("missing or non-bool field {key:?}"))
}

fn f32_vec_of(v: &Json, what: &str) -> anyhow::Result<Vec<f32>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("{what} must be an array"))?
        .iter()
        .map(|x| {
            // a NaN/inf f32 was serialized as null — reject it here
            x.as_f64().map(|f| f as f32).ok_or_else(|| {
                anyhow::anyhow!("non-numeric element in {what} (NaN/inf is not wire-encodable)")
            })
        })
        .collect()
}

fn get_f32_vec(j: &Json, key: &str) -> anyhow::Result<Vec<f32>> {
    f32_vec_of(j.get(key).ok_or_else(|| anyhow::anyhow!("missing field {key:?}"))?, key)
}

fn get_f32_mat(j: &Json, key: &str) -> anyhow::Result<Vec<Vec<f32>>> {
    j.get(key)
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow::anyhow!("missing or non-array field {key:?}"))?
        .iter()
        .map(|row| f32_vec_of(row, key))
        .collect()
}

fn get_ee(j: &Json) -> anyhow::Result<Option<EeConfig>> {
    match j.get("ee") {
        None | Some(Json::Null) => Ok(None),
        Some(o) => {
            Ok(Some(EeConfig { e_s: get_usize(o, "e_s")?, e_c: get_usize(o, "e_c")? }))
        }
    }
}

fn outcome_of(j: &Json) -> anyhow::Result<QueryOutcome> {
    Ok(QueryOutcome {
        prediction: get_usize(j, "prediction")?,
        blocks_used: get_usize(j, "blocks_used")?,
        exited_early: get_bool(j, "exited_early")?,
    })
}

fn parse_payload(payload: &[u8]) -> anyhow::Result<Json> {
    Json::parse(std::str::from_utf8(payload)?)
}

/// Decode a request payload. Never panics: garbage, wrong shapes and
/// unknown type tags all come back as errors.
pub fn decode_request(payload: &[u8]) -> anyhow::Result<Request> {
    let j = parse_payload(payload)?;
    let ty = get_str(&j, "type")?;
    match ty {
        "create_session" => Ok(Request::CreateSession {
            n_way: get_usize(&j, "n_way")?,
            hv_bits: get_u64(&j, "hv_bits")? as u32,
            metric: Distance::from_name(get_str(&j, "metric")?)?,
            // absent on frames from pre-backend clients: default to hdc so
            // old peers keep working; an unknown *named* backend is a
            // decode error the gateway answers with an error frame
            backend: match j.get("backend") {
                None | Some(Json::Null) => ClassifierBackend::Hdc,
                Some(b) => ClassifierBackend::from_name(
                    b.as_str().ok_or_else(|| anyhow::anyhow!("non-string field \"backend\""))?,
                )?,
            },
        }),
        "add_shot" => Ok(Request::AddShot {
            session: get_u64(&j, "session")?,
            class: get_usize(&j, "class")?,
            image: get_f32_vec(&j, "image")?,
        }),
        "add_shot_batch" => Ok(Request::AddShotBatch {
            session: get_u64(&j, "session")?,
            class: get_usize(&j, "class")?,
            images: get_f32_mat(&j, "images")?,
        }),
        "add_feature_shot" => Ok(Request::AddFeatureShot {
            session: get_u64(&j, "session")?,
            class: get_usize(&j, "class")?,
            feature: get_f32_vec(&j, "feature")?,
        }),
        "query_feature" => Ok(Request::QueryFeature {
            session: get_u64(&j, "session")?,
            feature: get_f32_vec(&j, "feature")?,
        }),
        "finish_training" => Ok(Request::FinishTraining { session: get_u64(&j, "session")? }),
        "query" => Ok(Request::Query {
            session: get_u64(&j, "session")?,
            image: get_f32_vec(&j, "image")?,
            ee: get_ee(&j)?,
        }),
        "query_batch" => Ok(Request::QueryBatch {
            session: get_u64(&j, "session")?,
            images: get_f32_mat(&j, "images")?,
            ee: get_ee(&j)?,
        }),
        "close_session" => Ok(Request::CloseSession { session: get_u64(&j, "session")? }),
        "get_metrics" => Ok(Request::GetMetrics),
        "shutdown" => Ok(Request::Shutdown),
        other => anyhow::bail!("unknown request type {other:?}"),
    }
}

/// Decode a response payload. Never panics (see [`decode_request`]).
pub fn decode_response(payload: &[u8]) -> anyhow::Result<Response> {
    let j = parse_payload(payload)?;
    let ty = get_str(&j, "type")?;
    match ty {
        "session_created" => Ok(Response::SessionCreated { session: get_u64(&j, "session")? }),
        "shot_accepted" => Ok(Response::ShotAccepted {
            session: get_u64(&j, "session")?,
            pending: get_usize(&j, "pending")?,
            trained_classes: get_usize(&j, "trained_classes")?,
        }),
        "training_done" => Ok(Response::TrainingDone {
            session: get_u64(&j, "session")?,
            shots: get_usize(&j, "shots")?,
        }),
        "query_result" => Ok(Response::QueryResult {
            session: get_u64(&j, "session")?,
            outcome: outcome_of(
                j.get("outcome").ok_or_else(|| anyhow::anyhow!("missing field \"outcome\""))?,
            )?,
        }),
        "query_batch_result" => Ok(Response::QueryBatchResult {
            session: get_u64(&j, "session")?,
            outcomes: j
                .get("outcomes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("missing or non-array field \"outcomes\""))?
                .iter()
                .map(outcome_of)
                .collect::<anyhow::Result<_>>()?,
        }),
        "session_closed" => Ok(Response::SessionClosed { session: get_u64(&j, "session")? }),
        "metrics" => {
            let hist_j = j
                .get("query_depth_hist")
                .and_then(Json::as_u64_vec)
                .ok_or_else(|| anyhow::anyhow!("missing or bad query_depth_hist"))?;
            anyhow::ensure!(
                hist_j.len() == DEPTH_BINS,
                "query_depth_hist has {} bins, expected {DEPTH_BINS}",
                hist_j.len()
            );
            let mut query_depth_hist = [0u64; DEPTH_BINS];
            query_depth_hist.copy_from_slice(&hist_j);
            Ok(Response::Metrics(MetricsSnapshot {
                shots: get_u64(&j, "shots")?,
                trains: get_u64(&j, "trains")?,
                queries: get_u64(&j, "queries")?,
                errors: get_u64(&j, "errors")?,
                feature_pads: get_u64(&j, "feature_pads")?,
                add_shot_ms_mean: get_f64(&j, "add_shot_ms_mean")?,
                train_ms_mean: get_f64(&j, "train_ms_mean")?,
                query_ms_mean: get_f64(&j, "query_ms_mean")?,
                query_ms_max: get_f64(&j, "query_ms_max")?,
                early_exit_rate: get_f64(&j, "early_exit_rate")?,
                avg_blocks_used: get_f64(&j, "avg_blocks_used")?,
                query_depth_hist,
                fe_layers_executed: get_u64(&j, "fe_layers_executed")?,
                fe_layers_skipped: get_u64(&j, "fe_layers_skipped")?,
                branch_hvs_encoded: get_u64(&j, "branch_hvs_encoded")?,
                class_mem_used_bits: get_u64(&j, "class_mem_used_bits")?,
                class_mem_active_banks: get_usize(&j, "class_mem_active_banks")?,
                class_mem_gated_banks: get_usize(&j, "class_mem_gated_banks")?,
                requests_shed: get_u64(&j, "requests_shed")?,
                // post-PR-8 fields: absent on frames from older peers
                device_failures: get_u64_or(&j, "device_failures", 0)?,
                sessions_replaced: get_u64_or(&j, "sessions_replaced", 0)?,
                retrain_ms: get_f64_or(&j, "retrain_ms", 0.0)?,
            }))
        }
        "shutting_down" => Ok(Response::ShuttingDown),
        "busy" => Ok(Response::Busy { queue_depth: get_usize(&j, "queue_depth")? }),
        "error" => {
            let msg = get_str(&j, "message")?.to_string();
            // absent/false retryable (old peers never send it) = fatal
            match j.get("retryable").and_then(Json::as_bool) {
                Some(true) => Ok(Response::RetryableError(msg)),
                _ => Ok(Response::Error(msg)),
            }
        }
        other => anyhow::bail!("unknown response type {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    const CAP: usize = 1 << 20;

    fn roundtrip_req(req: Request) {
        let bytes = encode_request(&req);
        let back = decode_request(&bytes)
            .unwrap_or_else(|e| panic!("decode failed for {req:?}: {e} ({bytes:?})"));
        assert_eq!(back, req);
    }

    fn roundtrip_resp(resp: Response) {
        let bytes = encode_response(&resp);
        let back = decode_response(&bytes)
            .unwrap_or_else(|e| panic!("decode failed for {resp:?}: {e}"));
        assert_eq!(back, resp);
    }

    /// f32 values that stress the float-exactness contract: subnormals,
    /// extremes, negative zero, values with no short decimal form.
    fn tricky_f32s() -> Vec<f32> {
        vec![
            0.0,
            -0.0,
            1.0,
            -1.5,
            0.1,
            std::f32::consts::PI,
            f32::MAX,
            f32::MIN,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0, // subnormal
            1.0e-44,                 // smallest subnormals
            -3.402_822e38,
            1.000_000_1,
        ]
    }

    #[test]
    fn every_request_variant_roundtrips_exactly() {
        let img = tricky_f32s();
        let mat = vec![img.clone(), vec![], vec![42.5]];
        let ee = Some(EeConfig { e_s: 2, e_c: 3 });
        for metric in [Distance::L1, Distance::Dot, Distance::Cosine, Distance::Hamming] {
            for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
                roundtrip_req(Request::CreateSession { n_way: 10, hv_bits: 4, metric, backend });
            }
        }
        roundtrip_req(Request::AddShot { session: 1, class: 3, image: img.clone() });
        roundtrip_req(Request::AddShotBatch { session: 2, class: 0, images: mat.clone() });
        roundtrip_req(Request::AddFeatureShot { session: 3, class: 9, feature: img.clone() });
        roundtrip_req(Request::QueryFeature { session: 4, feature: vec![] });
        roundtrip_req(Request::FinishTraining { session: 5 });
        roundtrip_req(Request::Query { session: 6, image: img.clone(), ee });
        roundtrip_req(Request::Query { session: 6, image: img, ee: None });
        roundtrip_req(Request::QueryBatch { session: 7, images: mat.clone(), ee });
        roundtrip_req(Request::QueryBatch { session: 7, images: mat, ee: None });
        roundtrip_req(Request::CloseSession { session: u64::MAX >> 12 });
        roundtrip_req(Request::GetMetrics);
        roundtrip_req(Request::Shutdown);
    }

    #[test]
    fn every_response_variant_roundtrips_exactly() {
        let o = QueryOutcome { prediction: 3, blocks_used: 2, exited_early: true };
        let o2 = QueryOutcome { prediction: 0, blocks_used: 4, exited_early: false };
        roundtrip_resp(Response::SessionCreated { session: 11 });
        roundtrip_resp(Response::ShotAccepted { session: 1, pending: 2, trained_classes: 3 });
        roundtrip_resp(Response::TrainingDone { session: 1, shots: 50 });
        roundtrip_resp(Response::QueryResult { session: 1, outcome: o.clone() });
        roundtrip_resp(Response::QueryBatchResult { session: 1, outcomes: vec![o, o2] });
        roundtrip_resp(Response::QueryBatchResult { session: 1, outcomes: vec![] });
        roundtrip_resp(Response::SessionClosed { session: 9 });
        let mut m = MetricsSnapshot {
            shots: 10,
            trains: 2,
            queries: 31,
            errors: 1,
            feature_pads: 4,
            add_shot_ms_mean: 0.125,
            train_ms_mean: 3.5,
            query_ms_mean: 0.013671875,
            query_ms_max: 17.75,
            early_exit_rate: 0.25,
            avg_blocks_used: 2.5,
            fe_layers_executed: 1000,
            fe_layers_skipped: 200,
            branch_hvs_encoded: 77,
            class_mem_used_bits: 1 << 20,
            class_mem_active_banks: 5,
            class_mem_gated_banks: 11,
            requests_shed: 6,
            ..Default::default()
        };
        m.query_depth_hist = [1, 2, 3, 4, 5, 6, 7, 8];
        roundtrip_resp(Response::Metrics(m));
        roundtrip_resp(Response::Metrics(MetricsSnapshot::default()));
        roundtrip_resp(Response::ShuttingDown);
        roundtrip_resp(Response::Busy { queue_depth: 129 });
        roundtrip_resp(Response::Error("bad \"quoted\" \n multiline".into()));
        roundtrip_resp(Response::RetryableError("device unavailable: device 2 is dead".into()));
    }

    #[test]
    fn error_taxonomy_is_backward_compatible_on_the_wire() {
        // a retryable error still travels under the "error" type tag, so a
        // pre-taxonomy client's decoder sees a plain Error frame
        let bytes = encode_response(&Response::RetryableError("deadline exceeded".into()));
        let j = Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        assert_eq!(j.get("type").and_then(Json::as_str), Some("error"));
        assert_eq!(j.get("message").and_then(Json::as_str), Some("deadline exceeded"));
        // an old peer's error frame (no retryable field) decodes as fatal
        let old = b"{\"type\":\"error\",\"message\":\"boom\"}";
        assert_eq!(decode_response(old).unwrap(), Response::Error("boom".into()));
        // explicit retryable:false is also fatal
        let fatal = b"{\"type\":\"error\",\"message\":\"boom\",\"retryable\":false}";
        assert_eq!(decode_response(fatal).unwrap(), Response::Error("boom".into()));
    }

    #[test]
    fn metrics_frames_without_recovery_fields_decode_with_zero_defaults() {
        // simulate a pre-PR-8 peer: encode, then strip the new fields
        let m = MetricsSnapshot {
            shots: 3,
            device_failures: 7,
            sessions_replaced: 9,
            retrain_ms: 1.25,
            ..Default::default()
        };
        let s = String::from_utf8(encode_response(&Response::Metrics(m))).unwrap();
        let old = s
            .replace(",\"device_failures\":7", "")
            .replace(",\"sessions_replaced\":9", "")
            .replace(",\"retrain_ms\":1.25", "");
        assert!(!old.contains("retrain_ms"), "strip failed: {old}");
        match decode_response(old.as_bytes()).unwrap() {
            Response::Metrics(b) => {
                assert_eq!(b.shots, 3);
                assert_eq!((b.device_failures, b.sessions_replaced), (0, 0));
                assert_eq!(b.retrain_ms, 0.0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn cancellable_read_resumes_after_timeouts_and_honors_cancel() {
        use std::io::Read;

        // a reader that yields WouldBlock between every real byte —
        // read_frame_cancellable must reassemble the frame across ticks
        struct Chopper {
            data: Vec<u8>,
            pos: usize,
            tick: bool,
        }
        impl Read for Chopper {
            fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
                self.tick = !self.tick;
                if self.tick {
                    return Err(std::io::ErrorKind::WouldBlock.into());
                }
                if self.pos >= self.data.len() {
                    return Ok(0);
                }
                buf[0] = self.data[self.pos];
                self.pos += 1;
                Ok(1)
            }
        }

        let mut framed = Vec::new();
        write_frame(&mut framed, b"{\"type\":\"get_metrics\"}", CAP).unwrap();
        let mut r = Chopper { data: framed.clone(), pos: 0, tick: false };
        let frame = read_frame_cancellable(&mut r, CAP, &mut || false).unwrap().unwrap();
        assert_eq!(decode_request(&frame).unwrap(), Request::GetMetrics);

        // cancel mid-frame: Ok(None), no panic, no partial-frame error
        let mut r = Chopper { data: framed, pos: 0, tick: false };
        let mut polls = 0;
        let got = read_frame_cancellable(&mut r, CAP, &mut || {
            polls += 1;
            polls > 2
        })
        .unwrap();
        assert!(got.is_none(), "cancelled read reports a clean close");
    }

    #[test]
    fn float_means_roundtrip_bitwise_via_shortest_repr() {
        // non-dyadic f64 means (latencies) must survive the text format
        for v in [0.1, 1.0 / 3.0, 2.5e-7, 123456.789012345, f64::MIN_POSITIVE] {
            let m = MetricsSnapshot { query_ms_mean: v, ..Default::default() };
            let back = decode_response(&encode_response(&Response::Metrics(m))).unwrap();
            match back {
                Response::Metrics(b) => {
                    assert_eq!(b.query_ms_mean.to_bits(), v.to_bits(), "{v}")
                }
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn create_session_backend_defaults_to_hdc_for_old_frames() {
        // a frame from a pre-backend client has no "backend" field; it
        // must decode as an hdc session, not error
        let old = b"{\"type\":\"create_session\",\"n_way\":5,\"hv_bits\":8,\"metric\":\"l1\"}";
        match decode_request(old).unwrap() {
            Request::CreateSession { n_way, hv_bits, metric, backend } => {
                assert_eq!((n_way, hv_bits, metric), (5, 8, Distance::L1));
                assert_eq!(backend, ClassifierBackend::Hdc);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_backend_name_is_a_decode_error_not_a_panic() {
        let bad = b"{\"type\":\"create_session\",\"n_way\":5,\"hv_bits\":8,\
                     \"metric\":\"l1\",\"backend\":\"svm\"}";
        let err = decode_request(bad).unwrap_err().to_string();
        assert!(err.contains("svm") && err.contains("hdc|ldc"), "{err}");
    }

    #[test]
    fn non_finite_floats_fail_decode_instead_of_corrupting() {
        // util::json writes NaN/inf as null; the decoder must refuse the
        // frame rather than hand the worker a zeroed pixel
        let req = Request::Query { session: 1, image: vec![1.0, f32::NAN], ee: None };
        let err = decode_request(&encode_request(&req)).unwrap_err().to_string();
        assert!(err.contains("NaN"), "{err}");
    }

    #[test]
    fn frame_roundtrip_over_a_stream() {
        let mut buf = Vec::new();
        let reqs = [
            Request::GetMetrics,
            Request::AddShot { session: 1, class: 0, image: vec![0.5; 16] },
            Request::Shutdown,
        ];
        for r in &reqs {
            write_frame(&mut buf, &encode_request(r), CAP).unwrap();
        }
        let mut cur = Cursor::new(buf);
        for want in &reqs {
            let frame = read_frame(&mut cur, CAP).unwrap().expect("frame present");
            assert_eq!(&decode_request(&frame).unwrap(), want);
        }
        assert!(read_frame(&mut cur, CAP).unwrap().is_none(), "clean EOF after the last frame");
    }

    #[test]
    fn truncated_and_oversized_frames_error_without_panicking() {
        // EOF mid-header
        let mut cur = Cursor::new(vec![0u8, 0]);
        assert!(read_frame(&mut cur, CAP).unwrap_err().to_string().contains("header"));
        // EOF mid-payload
        let mut buf = Vec::new();
        write_frame(&mut buf, b"{\"type\":\"get_metrics\"}", CAP).unwrap();
        buf.truncate(buf.len() - 5);
        let mut cur = Cursor::new(buf);
        assert!(read_frame(&mut cur, CAP).unwrap_err().to_string().contains("payload"));
        // length prefix over the cap (e.g. a peer speaking a different
        // protocol): rejected before allocating the claimed buffer
        let mut cur = Cursor::new(u32::MAX.to_be_bytes().to_vec());
        assert!(read_frame(&mut cur, CAP).unwrap_err().to_string().contains("oversized"));
        // writer side refuses frames it could not prefix
        let mut sink = Vec::new();
        assert!(write_frame(&mut sink, &[0u8; 32], 16).is_err());
    }

    #[test]
    fn garbage_payloads_error_without_panicking() {
        for garbage in [
            &b"not json at all"[..],
            b"{",
            b"[1,2,3]",
            b"{\"no_type\":1}",
            b"{\"type\":\"warp_drive\"}",
            b"{\"type\":\"query\"}",                     // missing fields
            b"{\"type\":\"add_shot\",\"session\":\"x\"}", // wrong field type
            b"\xff\xfe\x00",                            // invalid UTF-8
        ] {
            assert!(decode_request(garbage).is_err(), "{garbage:?}");
            assert!(decode_response(garbage).is_err(), "{garbage:?}");
        }
        // a response tag is not a request tag and vice versa
        assert!(decode_request(b"{\"type\":\"busy\",\"queue_depth\":1}").is_err());
        assert!(decode_response(b"{\"type\":\"get_metrics\"}").is_err());
    }
}
