//! The classifier seam: pluggable FSL backends behind one trait.
//!
//! `FslSession` used to hard-code [`HdcModel`]; this module extracts the
//! interface the session already implied — single-pass shot/batch
//! training, packed distance evaluation, sharded batch prediction, and
//! class-memory accounting — so a second backend can sit beside HDC
//! without touching the coordinator's serving logic.
//!
//! Two backends implement it today (DESIGN.md §Classifier backends):
//! * [`ClassifierBackend::Hdc`] — the paper's hyperdimensional classifier
//!   ([`HdcModel`], D in the thousands), packed fast path and bit-identity
//!   oracles untouched.
//! * [`ClassifierBackend::Ldc`] — the brain-inspired low-dimensional
//!   classifier ([`ldc::LdcModel`], Duan et al., PAPERS.md): a value-level
//!   fold to D in the 64–512 range over the same packed narrow-code
//!   machinery, for a ~8x class-memory and distance-compute reduction at
//!   D=4096.

pub mod ldc;

use crate::hdc::{Distance, HdcModel};
pub use ldc::LdcModel;

/// Which FSL classifier a session runs on. Carried by
/// `Request::CreateSession` (wire name `backend`) and the `[classifier]`
/// config section.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ClassifierBackend {
    /// Hyperdimensional classifier at the cRP encoder's full D (paper).
    #[default]
    Hdc,
    /// Low-dimensional classifier: value-level fold to 64–512 dims.
    Ldc,
}

impl ClassifierBackend {
    /// Parse a backend name (CLI `--backend`, TOML `classifier.backend`,
    /// wire `backend` field). Unknown names are an error the caller must
    /// surface (`Response::Error` at the request boundary, never a panic).
    pub fn from_name(s: &str) -> anyhow::Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hdc" => Ok(ClassifierBackend::Hdc),
            "ldc" => Ok(ClassifierBackend::Ldc),
            other => anyhow::bail!("unknown classifier backend {other} (hdc|ldc)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ClassifierBackend::Hdc => "hdc",
            ClassifierBackend::Ldc => "ldc",
        }
    }

    /// Build a fully configured classifier for one FE branch.
    ///
    /// `d` is the encoded HV dimension the branch receives; `ldc_d` is the
    /// LDC fold dimension (`0` = auto, [`LdcModel::auto_dim`]) and is
    /// ignored by the HDC backend.
    pub fn build(
        &self,
        n_way: usize,
        d: usize,
        hv_bits: u32,
        metric: Distance,
        ldc_d: usize,
    ) -> Box<dyn FslClassifier> {
        match self {
            ClassifierBackend::Hdc => {
                Box::new(HdcModel::new(n_way, d).with_precision(hv_bits).with_metric(metric))
            }
            ClassifierBackend::Ldc => {
                let d_low = if ldc_d == 0 { LdcModel::auto_dim(d) } else { ldc_d };
                Box::new(
                    LdcModel::new(n_way, d, d_low).with_precision(hv_bits).with_metric(metric),
                )
            }
        }
    }
}

/// The per-branch classifier seam behind `FslSession`.
///
/// Contract (what the coordinator's serving paths rely on):
/// * `train_batch` is **bit-identical** to the same shots through
///   `train_shot` in order (row-major accumulation).
/// * `distances_batch`/`predict_batch` are **bit-identical** to the
///   serial loop for any shard count (DESIGN.md §Threading model).
/// * `distances` runs the packed integer-domain datapath; per-metric
///   exactness versus the f32 oracle is the `hdc/packed.rs` contract.
/// * `class_mem_bits` is what the session occupies in the 256 KB class
///   memory for this branch: `n_classes * stored_dim * hv_bits`.
pub trait FslClassifier: Send + std::fmt::Debug {
    /// Which backend this classifier is (metrics, debugging).
    fn backend(&self) -> ClassifierBackend;
    /// Input HV dimension `train_shot`/`distances` expect.
    fn hv_dim(&self) -> usize;
    /// Per-class stored dimension — the class-memory footprint dimension.
    /// HDC stores full-D class HVs; LDC stores folded `d_low` prototypes.
    fn stored_dim(&self) -> usize;
    /// Class-memory precision (bits per stored element).
    fn hv_bits(&self) -> u32;
    /// Distance metric used for inference.
    fn metric(&self) -> Distance;
    /// Class-memory bits this branch classifier occupies when admitted.
    fn class_mem_bits(&self) -> u64;
    /// True when every class has at least one shot.
    fn is_trained(&self) -> bool;
    /// Single-pass training: bundle one encoded shot into its class row.
    fn train_shot(&mut self, class: usize, hv: &[f32]);
    /// Batched single-pass training — bit-identical to sequential shots.
    fn train_batch(&mut self, class: usize, hvs: &[&[f32]]);
    /// Distance from a query HV to every class, packed datapath.
    fn distances(&mut self, q: &[f32]) -> Vec<f64>;
    /// Sharded batch distances — bit-identical to serial for any shards.
    fn distances_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<Vec<f64>>;
    /// Predict the class of a query HV (NaN-robust argmin of distances).
    fn predict(&mut self, q: &[f32]) -> usize;
    /// Sharded batch prediction — bit-identical to serial.
    fn predict_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<usize>;
    /// Clone behind the object (FslSession is `Clone`).
    fn clone_box(&self) -> Box<dyn FslClassifier>;
}

impl Clone for Box<dyn FslClassifier> {
    fn clone(&self) -> Self {
        self.clone_box()
    }
}

/// First implementor: the paper's HDC model, delegating straight to the
/// inherent methods (packed fast path, sharded batches and the
/// bit-identity oracles carried over untouched).
impl FslClassifier for HdcModel {
    fn backend(&self) -> ClassifierBackend {
        ClassifierBackend::Hdc
    }

    fn hv_dim(&self) -> usize {
        self.d
    }

    fn stored_dim(&self) -> usize {
        self.d
    }

    fn hv_bits(&self) -> u32 {
        self.hv_bits
    }

    fn metric(&self) -> Distance {
        self.metric
    }

    fn class_mem_bits(&self) -> u64 {
        self.n_classes as u64 * self.d as u64 * self.hv_bits as u64
    }

    fn is_trained(&self) -> bool {
        HdcModel::is_trained(self)
    }

    fn train_shot(&mut self, class: usize, hv: &[f32]) {
        HdcModel::train_shot(self, class, hv);
    }

    fn train_batch(&mut self, class: usize, hvs: &[&[f32]]) {
        HdcModel::train_batch(self, class, hvs);
    }

    fn distances(&mut self, q: &[f32]) -> Vec<f64> {
        HdcModel::distances(self, q)
    }

    fn distances_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<Vec<f64>> {
        HdcModel::distances_batch(self, queries, shards)
    }

    fn predict(&mut self, q: &[f32]) -> usize {
        HdcModel::predict(self, q)
    }

    fn predict_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<usize> {
        HdcModel::predict_batch(self, queries, shards)
    }

    fn clone_box(&self) -> Box<dyn FslClassifier> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn backend_names_round_trip() {
        for b in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
            assert_eq!(ClassifierBackend::from_name(b.name()).unwrap(), b);
        }
        assert_eq!(ClassifierBackend::from_name("HDC").unwrap(), ClassifierBackend::Hdc);
        assert_eq!(ClassifierBackend::from_name("Ldc").unwrap(), ClassifierBackend::Ldc);
        let err = ClassifierBackend::from_name("svm").unwrap_err().to_string();
        assert!(err.contains("svm") && err.contains("hdc|ldc"), "{err}");
    }

    #[test]
    fn default_backend_is_hdc() {
        assert_eq!(ClassifierBackend::default(), ClassifierBackend::Hdc);
    }

    #[test]
    fn factory_builds_configured_classifiers() {
        let hdc = ClassifierBackend::Hdc.build(10, 4096, 4, Distance::L1, 0);
        assert_eq!(hdc.backend(), ClassifierBackend::Hdc);
        assert_eq!((hdc.hv_dim(), hdc.stored_dim()), (4096, 4096));
        assert_eq!((hdc.hv_bits(), hdc.metric()), (4, Distance::L1));
        assert_eq!(hdc.class_mem_bits(), 10 * 4096 * 4);

        let ldc = ClassifierBackend::Ldc.build(10, 4096, 4, Distance::Hamming, 0);
        assert_eq!(ldc.backend(), ClassifierBackend::Ldc);
        assert_eq!(ldc.hv_dim(), 4096, "LDC still ingests full-D HVs");
        assert_eq!(ldc.stored_dim(), LdcModel::auto_dim(4096));
        assert_eq!(ldc.metric(), Distance::Hamming);
        // the acceptance ratio: >= 4x class-memory reduction at matched
        // n_way (auto dim gives 8x at D=4096)
        assert!(hdc.class_mem_bits() >= 4 * ldc.class_mem_bits());

        // explicit fold dimension override
        let ldc128 = ClassifierBackend::Ldc.build(10, 4096, 4, Distance::L1, 128);
        assert_eq!(ldc128.stored_dim(), 128);
    }

    #[test]
    fn hdc_through_the_trait_is_bit_identical_to_direct() {
        let d = 96;
        let mut rng = Rng::new(11);
        let shots: Vec<Vec<f32>> =
            (0..6).map(|_| (0..d).map(|_| rng.gauss_f32()).collect()).collect();
        let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();

        let mut direct = HdcModel::new(2, d).with_precision(4).with_metric(Distance::L1);
        let mut boxed = ClassifierBackend::Hdc.build(2, d, 4, Distance::L1, 0);
        for (i, hv) in shots.iter().enumerate() {
            direct.train_shot(i % 2, hv);
            boxed.train_shot(i % 2, hv);
        }
        assert_eq!(HdcModel::distances(&mut direct, &q), boxed.distances(&q));
        assert_eq!(HdcModel::predict(&mut direct, &q), boxed.predict(&q));
    }

    #[test]
    fn boxed_clone_preserves_training() {
        let d = 32;
        let mut rng = Rng::new(12);
        for backend in [ClassifierBackend::Hdc, ClassifierBackend::Ldc] {
            let mut m = backend.build(2, d, 8, Distance::L1, 0);
            let hv: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
            m.train_shot(0, &hv);
            m.train_shot(1, &hv);
            let mut c = m.clone();
            let q: Vec<f32> = (0..d).map(|_| rng.gauss_f32()).collect();
            assert_eq!(m.distances(&q), c.distances(&q), "{backend:?}");
        }
    }
}
