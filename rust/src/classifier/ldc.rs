//! LDC: brain-inspired low-dimensional classifier (Duan et al.,
//! arXiv 2203.04894 — see PAPERS.md).
//!
//! The key observation of the LDC line of work is that the accuracy HDC
//! reaches with binary hypervectors at D in the thousands is reachable
//! with *value-level* (non-binary) representations at D in the hundreds —
//! a ~10x class-memory and distance-compute reduction. This module adapts
//! that to the FSL-HDnn pipeline: the cRP encoder still produces full-D
//! HVs (the encoder is the chip's fixed datapath), and the LDC backend
//! folds each HV down to `d_low in 64..=512` with a deterministic
//! sign-weighted cyclic accumulation before single-pass prototype
//! training. Values stay in the real domain through the fold (value-level
//! mapping, not binarization); the folded prototypes are then stored and
//! compared through `hdc/packed.rs`'s narrow-code machinery at the
//! session's `hv_bits`, so the packed integer-domain distance datapath,
//! the bit-identical sharded batch contract and the quantization oracles
//! all carry over unchanged.
//!
//! SynergicLearning (PAPERS.md) supplies the accuracy-per-dimension
//! framing: `fig14_precision_sweep --backend ldc` and
//! `table1_comparison` print the capacity/accuracy columns per backend.

// the seam lands lint-clean: warnings and clippy findings are hard errors
// scoped to this module (the CI clippy step enforces it)
#![deny(warnings, clippy::all)]

use crate::classifier::{ClassifierBackend, FslClassifier};
use crate::hdc::{lfsr, Distance, HdcModel};

/// Smallest fold dimension `auto_dim` will pick.
pub const D_LOW_MIN: usize = 64;
/// Largest fold dimension `auto_dim` will pick.
pub const D_LOW_MAX: usize = 512;
/// Auto fold factor: `d_low = d_in / 8`, clamped to the LDC range.
pub const FOLD_FACTOR: usize = 8;

/// Seed for the fold-sign LFSR stream (mixed with `d_in`, so encoders of
/// different widths never share a sign sequence).
const SIGN_SEED: u64 = 0x1DC0DE;

/// Low-dimensional FSL classifier: a deterministic value-level fold
/// (`d_in -> d_low`) in front of a packed prototype memory.
///
/// The prototype memory reuses [`HdcModel`] at `d_low` — that is not an
/// implementation shortcut but the point of the design: LDC differs from
/// HDC in *where the dimensionality lives*, not in the single-pass
/// bundle/nearest-prototype algebra, so the folded path inherits the
/// packed store, the sharded batch determinism contract and the
/// quantization oracles verbatim.
#[derive(Clone, Debug)]
pub struct LdcModel {
    d_in: usize,
    /// `±1` fold signs, length `d_in`, from the cRP LFSR family.
    signs: Vec<f32>,
    /// Low-dimensional prototype memory (the packed narrow-code store).
    proto: HdcModel,
}

impl LdcModel {
    /// Build an LDC classifier ingesting `d_in`-dim HVs and storing
    /// `d_low`-dim prototypes. `d_low` must be in `1..=d_in`; use
    /// [`LdcModel::auto_dim`] for the paper-range default.
    pub fn new(n_classes: usize, d_in: usize, d_low: usize) -> Self {
        assert!(d_in >= 1, "LDC needs a non-empty input HV");
        assert!(
            (1..=d_in).contains(&d_low),
            "LDC fold dim {d_low} out of range 1..={d_in}"
        );
        // one maximal-period 16-bit LFSR, advanced a full word per
        // element: deterministic, balanced, and seeded per input width so
        // the sign sequence is a function of the geometry alone
        let mut state = lfsr::row_block_states(SIGN_SEED ^ d_in as u64, 0)[0];
        let signs = (0..d_in)
            .map(|_| {
                state = lfsr::step16_fast(state);
                if state & 1 == 1 {
                    1.0
                } else {
                    -1.0
                }
            })
            .collect();
        LdcModel { d_in, signs, proto: HdcModel::new(n_classes, d_low) }
    }

    /// The fold dimension the auto policy picks for a `d_in`-dim encoder:
    /// `d_in / 8`, clamped to the LDC range `64..=512` (never above
    /// `d_in`). At the paper's D=4096 this is 512 — an 8x class-memory
    /// reduction at matched precision.
    pub fn auto_dim(d_in: usize) -> usize {
        (d_in / FOLD_FACTOR).clamp(D_LOW_MIN, D_LOW_MAX).min(d_in).max(1)
    }

    /// Class-memory precision of the packed prototype store.
    pub fn with_precision(mut self, bits: u32) -> Self {
        self.proto = self.proto.with_precision(bits);
        self
    }

    /// Distance metric for prototype inference.
    pub fn with_metric(mut self, metric: Distance) -> Self {
        self.proto = self.proto.with_metric(metric);
        self
    }

    /// The stored prototype dimension.
    pub fn d_low(&self) -> usize {
        self.proto.d
    }

    /// The value-level fold: sign-weighted cyclic accumulation of the
    /// full-D HV into `d_low` lanes. Linear, deterministic, and applied
    /// identically at train and query time, so nearest-prototype geometry
    /// is preserved in expectation (the signs decorrelate the lanes the
    /// way the cRP rows decorrelate features).
    pub fn fold(&self, hv: &[f32]) -> Vec<f32> {
        assert_eq!(hv.len(), self.d_in, "LDC fold expects a {}-dim HV", self.d_in);
        let d_low = self.proto.d;
        let mut out = vec![0.0f32; d_low];
        for (i, (&v, &s)) in hv.iter().zip(&self.signs).enumerate() {
            out[i % d_low] += v * s;
        }
        out
    }

    fn fold_all(&self, hvs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        hvs.iter().map(|hv| self.fold(hv)).collect()
    }
}

impl FslClassifier for LdcModel {
    fn backend(&self) -> ClassifierBackend {
        ClassifierBackend::Ldc
    }

    fn hv_dim(&self) -> usize {
        self.d_in
    }

    fn stored_dim(&self) -> usize {
        self.proto.d
    }

    fn hv_bits(&self) -> u32 {
        self.proto.hv_bits
    }

    fn metric(&self) -> Distance {
        self.proto.metric
    }

    fn class_mem_bits(&self) -> u64 {
        self.proto.n_classes as u64 * self.proto.d as u64 * self.proto.hv_bits as u64
    }

    fn is_trained(&self) -> bool {
        self.proto.is_trained()
    }

    fn train_shot(&mut self, class: usize, hv: &[f32]) {
        let folded = self.fold(hv);
        self.proto.train_shot(class, &folded);
    }

    fn train_batch(&mut self, class: usize, hvs: &[&[f32]]) {
        // fold in arrival order, then row-major accumulate — bit-identical
        // to the same shots through train_shot one by one
        let folded: Vec<Vec<f32>> = hvs.iter().map(|hv| self.fold(hv)).collect();
        self.proto.train_batch(class, &folded);
    }

    fn distances(&mut self, q: &[f32]) -> Vec<f64> {
        let folded = self.fold(q);
        self.proto.distances(&folded)
    }

    fn distances_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<Vec<f64>> {
        // the fold is per-query deterministic; sharding happens inside the
        // prototype memory's batch path, so serial == sharded carries over
        let folded = self.fold_all(queries);
        self.proto.distances_batch(&folded, shards)
    }

    fn predict(&mut self, q: &[f32]) -> usize {
        let folded = self.fold(q);
        self.proto.predict(&folded)
    }

    fn predict_batch(&mut self, queries: &[Vec<f32>], shards: usize) -> Vec<usize> {
        let folded = self.fold_all(queries);
        self.proto.predict_batch(&folded, shards)
    }

    fn clone_box(&self) -> Box<dyn FslClassifier> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn cluster_hv(rng: &mut Rng, proto: &[f32], noise: f32) -> Vec<f32> {
        proto.iter().map(|&p| p + noise * rng.gauss_f32()).collect()
    }

    #[test]
    fn auto_dim_clamps_to_the_ldc_range() {
        assert_eq!(LdcModel::auto_dim(4096), 512, "paper D -> 8x fold");
        assert_eq!(LdcModel::auto_dim(1024), 128);
        assert_eq!(LdcModel::auto_dim(512), 64);
        assert_eq!(LdcModel::auto_dim(256), 64, "clamped up to D_LOW_MIN");
        assert_eq!(LdcModel::auto_dim(64), 64, "never above d_in");
        assert_eq!(LdcModel::auto_dim(16), 16);
        assert_eq!(LdcModel::auto_dim(100_000), 512, "clamped down to D_LOW_MAX");
    }

    #[test]
    fn fold_is_deterministic_and_signs_balanced() {
        let a = LdcModel::new(2, 1024, 128);
        let b = LdcModel::new(2, 1024, 128);
        assert_eq!(a.signs, b.signs);
        let plus = a.signs.iter().filter(|&&s| s > 0.0).count();
        assert!(
            (358..=666).contains(&plus),
            "LFSR fold signs should be roughly balanced, got {plus}/1024"
        );
        // different input widths draw different sign sequences
        let c = LdcModel::new(2, 512, 128);
        assert_ne!(a.signs[..512], c.signs[..]);
        let hv: Vec<f32> = (0..1024).map(|i| (i as f32).sin()).collect();
        assert_eq!(a.fold(&hv), b.fold(&hv));
        assert_eq!(a.fold(&hv).len(), 128);
    }

    #[test]
    #[should_panic(expected = "LDC fold expects")]
    fn fold_rejects_wrong_input_dim() {
        LdcModel::new(2, 64, 64).fold(&[0.0; 32]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn fold_dim_above_input_rejected() {
        LdcModel::new(2, 64, 128);
    }

    #[test]
    fn separable_classes_survive_the_fold() {
        let d_in = 1024;
        let mut rng = Rng::new(21);
        let protos: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..d_in).map(|_| 3.0 * rng.gauss_f32()).collect())
            .collect();
        let mut m = LdcModel::new(4, d_in, LdcModel::auto_dim(d_in)).with_precision(8);
        for (c, p) in protos.iter().enumerate() {
            for _ in 0..5 {
                m.train_shot(c, &cluster_hv(&mut rng, p, 0.5));
            }
        }
        assert!(m.is_trained());
        for (c, p) in protos.iter().enumerate() {
            assert_eq!(m.predict(&cluster_hv(&mut rng, p, 0.5)), c);
        }
    }

    #[test]
    fn batch_training_bit_identical_to_sequential() {
        let d_in = 256;
        let mut rng = Rng::new(22);
        let shots: Vec<Vec<f32>> =
            (0..5).map(|_| (0..d_in).map(|_| rng.gauss_f32()).collect()).collect();
        let mut seq = LdcModel::new(2, d_in, 64).with_precision(4);
        for hv in &shots {
            seq.train_shot(0, hv);
        }
        let mut bat = LdcModel::new(2, d_in, 64).with_precision(4);
        let views: Vec<&[f32]> = shots.iter().map(|h| h.as_slice()).collect();
        bat.train_batch(0, &views);
        let q: Vec<f32> = (0..d_in).map(|_| rng.gauss_f32()).collect();
        assert_eq!(seq.distances(&q), bat.distances(&q));
    }

    #[test]
    fn batch_paths_bit_identical_across_shards() {
        let d_in = 256;
        let mut rng = Rng::new(23);
        let mut m = LdcModel::new(3, d_in, 64).with_precision(4);
        for c in 0..3 {
            let hv: Vec<f32> = (0..d_in).map(|_| rng.gauss_f32()).collect();
            m.train_shot(c, &hv);
        }
        let queries: Vec<Vec<f32>> =
            (0..9).map(|_| (0..d_in).map(|_| rng.gauss_f32()).collect()).collect();
        let dists = m.distances_batch(&queries, 1);
        let preds = m.predict_batch(&queries, 1);
        for shards in [2usize, 7] {
            assert_eq!(m.distances_batch(&queries, shards), dists, "shards={shards}");
            assert_eq!(m.predict_batch(&queries, shards), preds, "shards={shards}");
        }
        // the serial batch agrees with the one-query path
        for (q, want) in queries.iter().zip(&dists) {
            assert_eq!(&m.distances(q), want);
        }
    }

    #[test]
    fn class_mem_reduction_at_paper_dims() {
        // ISSUE 7 acceptance: >= 4x class-memory-bits reduction at matched
        // n_way. Auto fold at D=4096 stores 512 dims -> exactly 8x.
        let n_way = 32;
        let hdc_bits = n_way as u64 * 4096 * 4;
        let ldc = LdcModel::new(n_way, 4096, LdcModel::auto_dim(4096)).with_precision(4);
        assert_eq!(ldc.class_mem_bits(), n_way as u64 * 512 * 4);
        assert!(hdc_bits >= 4 * ldc.class_mem_bits());
        assert_eq!(hdc_bits / ldc.class_mem_bits(), 8);
    }

    #[test]
    fn metric_and_precision_flow_into_the_prototype_store() {
        let m = LdcModel::new(2, 128, 64).with_precision(1).with_metric(Distance::Hamming);
        assert_eq!(m.hv_bits(), 1);
        assert_eq!(m.metric(), Distance::Hamming);
        assert_eq!(m.class_mem_bits(), 2 * 64);
    }
}
