//! `fsl_lint` — run the repo-invariant static analysis pass over the tree.
//!
//! ```text
//! cargo run --bin fsl_lint              # lint from anywhere inside the repo
//! cargo run --bin fsl_lint -- --root .. # or point at the repo root
//! cargo run --bin fsl_lint -- --list    # print the rule table and exit
//! ```
//!
//! Exit status: 0 when clean (justified suppressions are fine), 1 on any
//! unsuppressed violation, 2 on usage/io errors. CI runs this as the
//! blocking `lint` job; `make lint` wraps it locally. Rules and escape-hatch
//! policy are documented in DESIGN.md §Static analysis.

use std::path::PathBuf;
use std::process::ExitCode;

use fsl_hdnn::util::lint;

fn main() -> ExitCode {
    let mut root_arg: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--root" => match args.next() {
                Some(p) => root_arg = Some(PathBuf::from(p)),
                None => {
                    eprintln!("fsl-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--quiet" | "-q" => quiet = true,
            "--list" => {
                for r in lint::Rule::ALL {
                    println!("{}", r.id());
                }
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("fsl-lint: unknown argument `{other}` (flags: --root <dir>, --quiet, --list)");
                return ExitCode::from(2);
            }
        }
    }

    let cwd = match std::env::current_dir() {
        Ok(d) => d,
        Err(e) => {
            eprintln!("fsl-lint: cannot read current dir: {e}");
            return ExitCode::from(2);
        }
    };
    let Some(root) = lint::find_repo_root(&root_arg.unwrap_or(cwd)) else {
        eprintln!("fsl-lint: no directory containing rust/src above here; pass --root");
        return ExitCode::from(2);
    };

    let report = match lint::lint_tree(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("fsl-lint: walking {} failed: {e}", root.display());
            return ExitCode::from(2);
        }
    };

    for v in &report.violations {
        println!("{}", v.render());
    }
    if !quiet {
        println!(
            "fsl-lint: {} files scanned, {} violation(s), {} suppressed (justified)",
            report.files_scanned,
            report.violations.len(),
            report.suppressed.len()
        );
    }
    if report.ok() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
