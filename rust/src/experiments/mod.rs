//! Shared experiment harnesses used by the `benches/` targets and
//! integration tests: each function evaluates one learner family over
//! synthetic few-shot episodes, exactly the protocol of Figs. 3, 15, 17.

use crate::baselines::{KnnClassifier, LinearProbe, MlpHead};
use crate::config::EeConfig;
use crate::coordinator::session::FslSession;
use crate::data::{DatasetPreset, EpisodeSampler, SyntheticDataset};
use crate::hdc::CrpEncoder;
use crate::util::prng::Rng;
use crate::util::stats;

/// Which learner to evaluate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Learner {
    /// kNN-L1 on raw features [17,18]
    Knn,
    /// partial FT: SGD linear probe, `epochs` passes
    PartialFt { epochs: usize },
    /// full FT proxy: MLP head with backprop, `epochs` passes
    FullFt { epochs: usize },
    /// FSL-HDnn: cRP encode + single-pass HDC, class HVs at `bits`
    FslHdnn { d: usize, bits: u32 },
}

impl Learner {
    pub fn name(&self) -> String {
        match self {
            Learner::Knn => "kNN-L1".into(),
            Learner::PartialFt { epochs } => format!("partial FT ({epochs} ep)"),
            Learner::FullFt { epochs } => format!("full FT ({epochs} ep)"),
            Learner::FslHdnn { .. } => "FSL-HDnn".into(),
        }
    }
}

/// Accuracy of one learner over `episodes` episodes; returns (mean, ci95).
pub fn eval_learner(
    sampler: &EpisodeSampler,
    learner: Learner,
    episodes: usize,
    seed: u64,
) -> (f64, f64) {
    let mut rng = Rng::new(seed);
    let mut accs = Vec::with_capacity(episodes);
    for _ in 0..episodes {
        let ep = sampler.sample(&mut rng);
        let mut pairs = Vec::with_capacity(ep.queries.len());
        match learner {
            Learner::Knn => {
                // 1-NN with L1, matching the SAPIENS-style associative
                // memory baseline [18] the paper compares against
                let mut knn = KnnClassifier::new(1);
                for (c, shots) in ep.support.iter().enumerate() {
                    for s in shots {
                        knn.add_example(s.clone(), c);
                    }
                }
                for (q, l) in &ep.queries {
                    pairs.push((knn.predict(q), *l));
                }
            }
            Learner::PartialFt { epochs } => {
                let (xs, ys) = flatten_support(&ep.support);
                let mut lp = LinearProbe::new(ep.n_way, sampler.dataset.feature_dim);
                lp.fit(&xs, &ys, epochs, &mut rng);
                for (q, l) in &ep.queries {
                    pairs.push((lp.predict(q), *l));
                }
            }
            Learner::FullFt { epochs } => {
                // In pure feature space, full FT's extra capacity has no
                // additional signal to exploit over the convex head — the
                // paper itself reports full FT ~= partial FT accuracy
                // (Fig. 15). We model full FT's *accuracy* with the same
                // softmax head driven harder (its vastly higher compute is
                // accounted by eq. (1) in baselines::complexity); the MLP
                // backprop learner remains the Fig. 3(a) convergence probe.
                let (xs, ys) = flatten_support(&ep.support);
                let mut lp = LinearProbe::new(ep.n_way, sampler.dataset.feature_dim);
                lp.lr = 0.1;
                lp.fit(&xs, &ys, epochs * 2, &mut rng);
                for (q, l) in &ep.queries {
                    pairs.push((lp.predict(q), *l));
                }
            }
            Learner::FslHdnn { d, bits } => {
                let enc = CrpEncoder::new(d, 0xF51_4D17);
                let mut model = crate::hdc::HdcModel::new(ep.n_way, d).with_precision(bits);
                for (c, shots) in ep.support.iter().enumerate() {
                    let hvs: Vec<Vec<f32>> =
                        shots.iter().map(|s| enc.encode_padded(s)).collect();
                    model.train_batch(c, &hvs);
                }
                for (q, l) in &ep.queries {
                    pairs.push((model.predict(&enc.encode_padded(q)), *l));
                }
            }
        }
        accs.push(stats::accuracy(&pairs));
    }
    (stats::mean(&accs), stats::ci95(&accs))
}

fn flatten_support(support: &[Vec<Vec<f32>>]) -> (Vec<Vec<f32>>, Vec<usize>) {
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for (c, shots) in support.iter().enumerate() {
        for s in shots {
            xs.push(s.clone());
            ys.push(c);
        }
    }
    (xs, ys)
}

/// Fig. 3(a): accuracy after each training epoch for an iterative learner.
/// Returns accuracy at epochs 1..=max_epochs (FSL-HDnn needs exactly one).
pub fn convergence_curve(
    sampler: &EpisodeSampler,
    full_ft: bool,
    max_epochs: usize,
    episodes: usize,
    seed: u64,
) -> Vec<f64> {
    (1..=max_epochs)
        .map(|e| {
            if full_ft {
                // true backprop MLP head: the slow-convergence probe
                let mut rng = Rng::new(seed);
                let mut accs = Vec::new();
                for _ in 0..episodes {
                    let ep = sampler.sample(&mut rng);
                    let (xs, ys) = flatten_support(&ep.support);
                    let mut mlp =
                        MlpHead::new(ep.n_way, sampler.dataset.feature_dim, 32, &mut rng);
                    mlp.fit(&xs, &ys, e, &mut rng);
                    let pairs: Vec<(usize, usize)> =
                        ep.queries.iter().map(|(q, l)| (mlp.predict(q), *l)).collect();
                    accs.push(stats::accuracy(&pairs));
                }
                stats::mean(&accs)
            } else {
                eval_learner(sampler, Learner::PartialFt { epochs: e }, episodes, seed).0
            }
        })
        .collect()
}

/// Fig. 17 protocol: early-exit accuracy and average depth for one
/// (E_s, E_c) configuration over branch-feature episodes.
/// Returns (accuracy, avg_blocks_used, exit_stage_histogram[4]).
pub fn eval_early_exit(
    dataset: &SyntheticDataset,
    n_way: usize,
    k_shot: usize,
    queries_per_class: usize,
    ee: Option<EeConfig>,
    d: usize,
    episodes: usize,
    seed: u64,
) -> (f64, f64, [u64; 4]) {
    let enc = CrpEncoder::new(d, 0xF51_4D17);
    let mut rng = Rng::new(seed);
    let mut accs = Vec::new();
    let mut blocks = Vec::new();
    let mut hist = [0u64; 4];
    for _ in 0..episodes {
        let classes = rng.choose_k(dataset.n_classes(), n_way);
        let mut session = FslSession::new(0, n_way, d, 4);
        for (label, &pc) in classes.iter().enumerate() {
            let shots: Vec<Vec<Vec<f32>>> = (0..k_shot)
                .map(|_| {
                    dataset
                        .sample_branches(pc, &mut rng)
                        .iter()
                        .map(|f| enc.encode_padded(f))
                        .collect()
                })
                .collect();
            session.train_batch(label, &shots);
        }
        let mut pairs = Vec::new();
        for (label, &pc) in classes.iter().enumerate() {
            for _ in 0..queries_per_class {
                let hvs: Vec<Vec<f32>> = dataset
                    .sample_branches(pc, &mut rng)
                    .iter()
                    .map(|f| enc.encode_padded(f))
                    .collect();
                let out = match ee {
                    Some(cfg) => session.query_early_exit(&hvs, cfg),
                    None => session.query_full(&hvs[3]),
                };
                pairs.push((out.prediction, label));
                blocks.push(out.blocks_used as f64);
                hist[out.blocks_used - 1] += 1;
            }
        }
        accs.push(stats::accuracy(&pairs));
    }
    (stats::mean(&accs), stats::mean(&blocks), hist)
}

/// Build the sampler for a named preset at the paper's feature scale.
pub fn sampler_for(
    preset: DatasetPreset,
    feature_dim: usize,
    n_way: usize,
    k_shot: usize,
    queries_per_class: usize,
    seed: u64,
) -> EpisodeSampler {
    let ds = SyntheticDataset::new(preset, feature_dim, seed);
    EpisodeSampler::new(ds, n_way, k_shot, queries_per_class)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sampler() -> EpisodeSampler {
        sampler_for(DatasetPreset::Flower102, 64, 5, 5, 6, 3)
    }

    #[test]
    fn learner_ordering_on_easy_preset() {
        // the paper's qualitative ordering: FT >= FSL-HDnn > kNN
        let s = sampler();
        let (knn, _) = eval_learner(&s, Learner::Knn, 8, 1);
        let (ours, _) = eval_learner(&s, Learner::FslHdnn { d: 1024, bits: 16 }, 8, 1);
        let (probe, _) = eval_learner(&s, Learner::PartialFt { epochs: 15 }, 8, 1);
        assert!(ours > 0.5, "FSL-HDnn should work on the easy preset: {ours}");
        assert!(probe + 0.05 >= ours, "partial FT roughly >= ours");
        assert!(ours + 0.02 >= knn, "ours should not lose badly to kNN: {ours} vs {knn}");
    }

    #[test]
    fn convergence_improves_with_epochs() {
        let s = sampler();
        let curve = convergence_curve(&s, false, 8, 4, 2);
        assert_eq!(curve.len(), 8);
        assert!(curve[7] >= curve[0] - 0.05, "late epochs should not collapse");
    }

    #[test]
    fn early_exit_depth_monotone_in_ec() {
        let ds = SyntheticDataset::new(DatasetPreset::Flower102, 64, 5);
        let (_, d1, _) =
            eval_early_exit(&ds, 5, 5, 4, Some(EeConfig { e_s: 1, e_c: 1 }), 512, 3, 7);
        let (_, d3, _) =
            eval_early_exit(&ds, 5, 5, 4, Some(EeConfig { e_s: 1, e_c: 3 }), 512, 3, 7);
        assert!(d1 < d3, "stricter E_c must use more blocks: {d1} vs {d3}");
    }

    #[test]
    fn early_exit_accuracy_close_to_full_at_paper_config() {
        let ds = SyntheticDataset::new(DatasetPreset::Flower102, 64, 5);
        let (full, _, _) = eval_early_exit(&ds, 5, 5, 6, None, 1024, 4, 11);
        let (ee, blocks, _) =
            eval_early_exit(&ds, 5, 5, 6, Some(EeConfig::paper_default()), 1024, 4, 11);
        assert!(blocks < 4.0, "EE should skip some blocks");
        assert!(full - ee < 0.08, "EE 2,2 should cost little accuracy: {full} vs {ee}");
    }
}
