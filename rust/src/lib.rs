//! # FSL-HDnn — few-shot on-device learning, full-system reproduction
//!
//! Rust coordinator (L3) for the FSL-HDnn accelerator paper: a few-shot
//! on-device-learning system combining a weight-clustered frozen feature
//! extractor with a hyperdimensional-computing (HDC) classifier, plus a
//! cycle-approximate simulator of the 40 nm chip and all the baselines the
//! paper compares against.
//!
//! Layer map (see `DESIGN.md`):
//! * [`runtime`] — PJRT client loading the AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`); python never runs at request time.
//!   Execution of artifacts is gated behind the `pjrt` cargo feature; the
//!   native backend also builds straight from a
//!   [`config::ModelConfig`] with no artifacts directory at all
//!   ([`runtime::ComputeEngine::from_config`]).
//! * [`coordinator`] — the ODL device logic: few-shot sessions, batched
//!   single-pass training (Fig. 12), early-exit inference (Fig. 11).
//! * [`hdc`], [`fe`] — native compute substrates mirroring the kernels
//!   bit-for-bit (LFSR contract) for the simulator and fast experiments.
//! * [`sim`] — cycle-approximate model of the chip (Figs. 7–9) with a
//!   calibrated 40 nm energy model.
//! * [`baselines`] — kNN / partial-FT / full-FT learners and the prior
//!   ODL chips of Table I as analytic cost models.
//! * [`data`] — synthetic few-shot datasets and episode samplers.
//!
//! The README's rust walkthrough compiles and runs under
//! `cargo test --doc` (via a doctest-only module at the bottom of this
//! file), so the documented quickstart can never drift from the real API.

// `std::simd` is nightly-only; the `simd` feature opts into it (DESIGN.md
// §SIMD datapath). Without the feature the crate is stable-only and the
// chunked-scalar lanes in `util::simd` serve every fast path.
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod baselines;
pub mod classifier;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod fe;
pub mod hdc;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;

/// The README's rust code blocks, compiled and run as doctests so the
/// documented walkthrough can never drift from the crate's real API.
/// Doctest-only: this module is invisible to `cargo doc` and rustc.
#[cfg(doctest)]
#[doc = include_str!("../../README.md")]
pub mod readme_doctests {}
