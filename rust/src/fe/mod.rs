//! Feature-extractor substrate: weight clustering (Fig. 4), the clustered
//! convolution (reference kernel + the nibble-packed fast kernel the
//! native FE executes), an INT8 baseline, and the ResNet-18-shaped frozen
//! FE that loads the AOT-exported weights (`artifacts/fe_weights.bin`) so
//! the native path computes the same features as the PJRT artifacts.

pub mod conv;
pub mod kmeans;
pub mod quant;
pub mod resnet;

pub use conv::{
    clustered_conv2d, clustered_conv2d_lut, clustered_conv2d_lut_in_lane,
    clustered_conv2d_packed, conv2d, CodebookLut, PackedIdx, Tensor3,
};
pub use kmeans::{cluster_layer, ClusteredLayer};
pub use resnet::{FeModel, StagedForward};
