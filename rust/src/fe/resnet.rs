//! The frozen ResNet-18-shaped FE, loading AOT-exported clustered weights
//! so the native forward pass computes the same features as the PJRT
//! artifacts (cross-checked against `artifacts/goldens/feats.bin`).
//!
//! Structure mirrors `python/compile/resnet.py`: stem conv -> 4 stages x
//! `blocks_per_stage` basic blocks (stride 2 from stage 1) -> per-stage
//! global-avg-pool branch features padded to Fmax (Fig. 11 branch taps).

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::ModelConfig;
use crate::fe::conv::{conv2d, Tensor3};
use crate::util::json::Json;

/// Loaded FE: named conv weights + geometry.
#[derive(Clone, Debug)]
pub struct FeModel {
    pub cfg: ModelConfig,
    /// layer name -> (weights row-major (Cout,K,K,Cin), cout, k, cin)
    layers: BTreeMap<String, (Vec<f32>, usize, usize, usize)>,
}

impl FeModel {
    /// Load from `artifacts/` (manifest.json + fe_weights.bin).
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let man_text = std::fs::read_to_string(artifacts_dir.join("manifest.json"))?;
        let man = Json::parse(&man_text)?;
        let cfg = ModelConfig::from_manifest(&man)?;
        let blob = std::fs::read(artifacts_dir.join("fe_weights.bin"))?;
        let layers_json = man
            .get("weights")
            .and_then(|w| w.get("layers"))
            .and_then(|l| l.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing weights.layers"))?;
        let mut layers = BTreeMap::new();
        let mut off = 0usize;
        for l in layers_json {
            let name = l
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow::anyhow!("layer missing name"))?
                .to_string();
            let shape = l
                .get("shape")
                .and_then(|s| s.as_usize_vec())
                .ok_or_else(|| anyhow::anyhow!("layer missing shape"))?;
            anyhow::ensure!(shape.len() == 4, "conv weights must be 4-D");
            let count: usize = shape.iter().product();
            anyhow::ensure!(blob.len() >= (off + count) * 4, "fe_weights.bin too short");
            let mut w = Vec::with_capacity(count);
            for i in 0..count {
                let b = &blob[(off + i) * 4..(off + i) * 4 + 4];
                w.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += count;
            layers.insert(name, (w, shape[0], shape[1], shape[3]));
        }
        anyhow::ensure!(off * 4 == blob.len(), "fe_weights.bin has trailing bytes");
        Ok(FeModel { cfg, layers })
    }

    /// Build from explicit weights (tests / synthetic configs).
    pub fn from_parts(
        cfg: ModelConfig,
        layers: BTreeMap<String, (Vec<f32>, usize, usize, usize)>,
    ) -> Self {
        FeModel { cfg, layers }
    }

    /// Build an FE with deterministic synthetic weights for an arbitrary
    /// [`ModelConfig`] — He-initialized convs seeded from
    /// `cfg.master_seed`, with the same layer naming scheme the AOT
    /// exporter uses (`stem`, `s{stage}b{block}_conv1/_conv2/_proj`).
    ///
    /// This makes [`crate::runtime::ComputeEngine`]'s native backend
    /// constructible without an artifacts directory; the resulting
    /// features are not the AOT model's but are class-separable on the
    /// procedural image generator, which is what the examples and
    /// integration paths need.
    pub fn synthetic(cfg: ModelConfig) -> Self {
        let mut rng = crate::util::prng::Rng::new(cfg.master_seed ^ 0x5E_7EC7);
        let mut layers = BTreeMap::new();
        let add = |layers: &mut BTreeMap<String, (Vec<f32>, usize, usize, usize)>,
                   name: String,
                   cout: usize,
                   k: usize,
                   cin: usize,
                   rng: &mut crate::util::prng::Rng| {
            let std = (2.0 / (k * k * cin) as f32).sqrt();
            let w: Vec<f32> = (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
            layers.insert(name, (w, cout, k, cin));
        };
        let mut cin = cfg.in_channels;
        add(&mut layers, "stem".to_string(), cfg.widths[0], 3, cin, &mut rng);
        cin = cfg.widths[0];
        for (si, &w) in cfg.widths.iter().enumerate() {
            for b in 0..cfg.blocks_per_stage {
                add(&mut layers, format!("s{si}b{b}_conv1"), w, 3, cin, &mut rng);
                add(&mut layers, format!("s{si}b{b}_conv2"), w, 3, w, &mut rng);
                // projection shortcut when the block changes channel count
                // (`forward` subsamples the skip when channels match)
                if cin != w {
                    add(&mut layers, format!("s{si}b{b}_proj"), w, 1, cin, &mut rng);
                }
                cin = w;
            }
        }
        FeModel { cfg, layers }
    }

    fn conv(&self, name: &str, x: &Tensor3, stride: usize) -> anyhow::Result<Tensor3> {
        let (w, cout, k, cin) = self
            .layers
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing FE layer {name}"))?;
        anyhow::ensure!(*cin == x.c, "{name}: cin {cin} != input {c}", c = x.c);
        Ok(conv2d(x, w, *cout, *k, stride))
    }

    /// Forward pass: image (H*W*3 flat NHWC) -> 4 branch features, each
    /// padded to `feature_dim`.
    pub fn forward(&self, image: &[f32]) -> anyhow::Result<Vec<Vec<f32>>> {
        let s = self.cfg.image_size;
        anyhow::ensure!(
            image.len() == s * s * self.cfg.in_channels,
            "image size mismatch: {} vs {}",
            image.len(),
            s * s * self.cfg.in_channels
        );
        let x = Tensor3::from_vec(s, s, self.cfg.in_channels, image.to_vec());
        let mut h = self.conv("stem", &x, 1)?.relu();
        let fmax = self.cfg.feature_dim;
        let mut branches = Vec::with_capacity(self.cfg.widths.len());
        for (si, _w) in self.cfg.widths.iter().enumerate() {
            let stage_stride = if si == 0 { 1 } else { 2 };
            for b in 0..self.cfg.blocks_per_stage {
                let pre = format!("s{si}b{b}");
                let st = if b == 0 { stage_stride } else { 1 };
                let y = self.conv(&format!("{pre}_conv1"), &h, st)?.relu();
                let y = self.conv(&format!("{pre}_conv2"), &y, 1)?;
                let skip = if self.layers.contains_key(&format!("{pre}_proj")) {
                    self.conv(&format!("{pre}_proj"), &h, st)?
                } else if st != 1 {
                    h.subsample(st)
                } else {
                    h.clone()
                };
                h = y.add(&skip).relu();
            }
            let mut feat = h.global_avg_pool();
            feat.resize(fmax, 0.0);
            branches.push(feat);
        }
        Ok(branches)
    }

    /// Batched forward pass, sharded across scoped worker threads
    /// (`shards <= 1` runs serially on the caller's thread). Weights are
    /// borrowed, never cloned — `forward` is `&self` — and the result is
    /// bit-identical to calling [`FeModel::forward`] per image in order
    /// (DESIGN.md §Threading model).
    pub fn forward_batch(
        &self,
        images: &[Vec<f32>],
        shards: usize,
    ) -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
        crate::util::parallel::shard_map(images, shards, |img| self.forward(img))
    }

    /// Forward only through the first `n_blocks` stages (early-exit body
    /// computation): returns the branch features produced so far.
    pub fn forward_prefix(&self, image: &[f32], n_stages: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let s = self.cfg.image_size;
        let x = Tensor3::from_vec(s, s, self.cfg.in_channels, image.to_vec());
        let mut h = self.conv("stem", &x, 1)?.relu();
        let fmax = self.cfg.feature_dim;
        let mut branches = Vec::new();
        for si in 0..n_stages.min(self.cfg.widths.len()) {
            let stage_stride = if si == 0 { 1 } else { 2 };
            for b in 0..self.cfg.blocks_per_stage {
                let pre = format!("s{si}b{b}");
                let st = if b == 0 { stage_stride } else { 1 };
                let y = self.conv(&format!("{pre}_conv1"), &h, st)?.relu();
                let y = self.conv(&format!("{pre}_conv2"), &y, 1)?;
                let skip = if self.layers.contains_key(&format!("{pre}_proj")) {
                    self.conv(&format!("{pre}_proj"), &h, st)?
                } else if st != 1 {
                    h.subsample(st)
                } else {
                    h.clone()
                };
                h = y.add(&skip).relu();
            }
            let mut feat = h.global_avg_pool();
            feat.resize(fmax, 0.0);
            branches.push(feat);
        }
        Ok(branches)
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.values().map(|(w, ..)| w.len()).sum()
    }

    /// Layer geometries for the chip simulator: (name, cout, k, cin).
    pub fn layer_geometries(&self) -> Vec<(String, usize, usize, usize)> {
        self.layers
            .iter()
            .map(|(n, (_, cout, k, cin))| (n.clone(), *cout, *k, *cin))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Build a tiny synthetic FE without artifacts.
    pub fn tiny_model(seed: u64) -> FeModel {
        let cfg = ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8],
            blocks_per_stage: 1,
            feature_dim: 8,
            d: 64,
            ..Default::default()
        };
        let mut rng = Rng::new(seed);
        let mut layers = BTreeMap::new();
        let mut add = |name: &str, cout: usize, k: usize, cin: usize, rng: &mut Rng| {
            let std = (2.0 / (k * k * cin) as f32).sqrt();
            let w: Vec<f32> =
                (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
            layers.insert(name.to_string(), (w, cout, k, cin));
        };
        add("stem", 4, 3, 3, &mut rng);
        add("s0b0_conv1", 4, 3, 4, &mut rng);
        add("s0b0_conv2", 4, 3, 4, &mut rng);
        add("s1b0_conv1", 8, 3, 4, &mut rng);
        add("s1b0_conv2", 8, 3, 8, &mut rng);
        add("s1b0_proj", 8, 1, 4, &mut rng);
        FeModel::from_parts(cfg, layers)
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let mut rng = Rng::new(2);
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
        let branches = m.forward(&img).unwrap();
        assert_eq!(branches.len(), 2);
        assert!(branches.iter().all(|b| b.len() == 8));
        // stage-0 branch has width 4 -> padding above index 4
        assert!(branches[0][4..].iter().all(|&v| v == 0.0));
        assert!(branches[0][..4].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn prefix_matches_full_forward() {
        let m = tiny_model(3);
        let mut rng = Rng::new(4);
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
        let full = m.forward(&img).unwrap();
        let prefix = m.forward_prefix(&img, 1).unwrap();
        assert_eq!(prefix.len(), 1);
        assert_eq!(prefix[0], full[0]);
    }

    #[test]
    fn deterministic() {
        let m = tiny_model(5);
        let img = vec![0.5f32; 8 * 8 * 3];
        assert_eq!(m.forward(&img).unwrap(), m.forward(&img).unwrap());
    }

    #[test]
    fn rejects_wrong_image_size() {
        let m = tiny_model(6);
        assert!(m.forward(&vec![0.0; 10]).is_err());
    }

    #[test]
    fn forward_batch_bit_identical_to_serial() {
        let m = tiny_model(8);
        let mut rng = Rng::new(9);
        let images: Vec<Vec<f32>> =
            (0..7).map(|_| (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect()).collect();
        let serial: Vec<_> = images.iter().map(|img| m.forward(img).unwrap()).collect();
        for shards in [1, 2, 3, 7, 16] {
            assert_eq!(m.forward_batch(&images, shards).unwrap(), serial, "shards={shards}");
        }
    }

    #[test]
    fn forward_batch_propagates_errors() {
        let m = tiny_model(10);
        let mut images = vec![vec![0.1f32; 8 * 8 * 3]; 5];
        images[3] = vec![0.0; 4]; // wrong size mid-batch
        for shards in [1, 2, 5] {
            assert!(m.forward_batch(&images, shards).is_err(), "shards={shards}");
        }
    }

    #[test]
    fn param_count_positive() {
        assert!(tiny_model(7).n_params() > 500);
    }

    #[test]
    fn synthetic_model_runs_any_geometry() {
        let cfg = ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8, 8],
            blocks_per_stage: 2,
            feature_dim: 16,
            d: 64,
            ..Default::default()
        };
        let m = FeModel::synthetic(cfg.clone());
        let img = vec![0.3f32; 8 * 8 * 3];
        let branches = m.forward(&img).unwrap();
        assert_eq!(branches.len(), 3);
        assert!(branches.iter().all(|b| b.len() == 16));
        // deterministic in the master seed
        let m2 = FeModel::synthetic(cfg);
        assert_eq!(m.forward(&img).unwrap(), m2.forward(&img).unwrap());
        // a different seed produces different features
        let other = FeModel::synthetic(ModelConfig {
            master_seed: 999,
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8, 8],
            blocks_per_stage: 2,
            feature_dim: 16,
            d: 64,
            ..Default::default()
        });
        assert_ne!(m.forward(&img).unwrap(), other.forward(&img).unwrap());
    }
}
