//! The frozen ResNet-18-shaped FE, loading AOT-exported clustered weights
//! so the native forward pass computes the same features as the PJRT
//! artifacts (cross-checked against `artifacts/goldens/feats.bin`).
//!
//! Structure mirrors `python/compile/resnet.py`: stem conv -> 4 stages x
//! `blocks_per_stage` basic blocks (stride 2 from stage 1) -> per-stage
//! global-avg-pool branch features padded to Fmax (Fig. 11 branch taps).
//!
//! Execution is driven by a **block plan** resolved once at model build
//! (layer indices into a flat `Vec`), so the per-image hot loop never
//! formats layer names or walks a map. When `cfg.clustered` is set, every
//! layer is quantized through [`cluster_layer`] once at construction and
//! `forward` runs the packed two-phase kernel over a per-layer
//! lane-padded codebook LUT ([`clustered_conv2d_lut`]) instead of the
//! dense conv — the chip's cheap path (Fig. 4b) is then also the native
//! fast path.
//!
//! All forwards run through the resumable [`StagedForward`] executor
//! ([`FeModel::stage_start`] + `step`), so the early-exit loop can stop
//! the FE *between* stages and the skipped tail is provably never
//! computed (DESIGN.md §Staged inference).

use std::collections::BTreeMap;
use std::path::Path;

use crate::config::ModelConfig;
use crate::fe::conv::{clustered_conv2d_lut, conv2d, CodebookLut, PackedIdx, Tensor3};
use crate::fe::kmeans::{cluster_layer, ClusteredLayer};
use crate::util::json::Json;

/// One conv layer: dense weights plus, once quantized, the packed
/// clustered kernel the fast path executes.
#[derive(Clone, Debug)]
struct Layer {
    name: String,
    w: Vec<f32>,
    cout: usize,
    k: usize,
    cin: usize,
    clustered: Option<ClusteredKernel>,
}

#[derive(Clone, Debug)]
struct ClusteredKernel {
    idx: PackedIdx,
    /// lane-padded codebook, built once here so the per-image hot loop
    /// never re-lays-out the centroid table
    lut: CodebookLut,
}

/// One basic block of the execution plan: layer indices resolved at model
/// build, so `forward` does plain `Vec` indexing per image.
#[derive(Clone, Copy, Debug)]
struct BlockPlan {
    conv1: usize,
    conv2: usize,
    proj: Option<usize>,
    stride: usize,
}

/// Loaded FE: conv layers + the precomputed block execution plan.
#[derive(Clone, Debug)]
pub struct FeModel {
    pub cfg: ModelConfig,
    layers: Vec<Layer>,
    stem: usize,
    /// per stage, the blocks in execution order (branch tap after each
    /// stage — Fig. 11)
    stages: Vec<Vec<BlockPlan>>,
}

impl FeModel {
    /// Load from `artifacts/` (manifest.json + fe_weights.bin).
    pub fn load(artifacts_dir: &Path) -> anyhow::Result<Self> {
        let man_text = std::fs::read_to_string(artifacts_dir.join("manifest.json"))?;
        let man = Json::parse(&man_text)?;
        let cfg = ModelConfig::from_manifest(&man)?;
        let blob = std::fs::read(artifacts_dir.join("fe_weights.bin"))?;
        let layers_json = man
            .get("weights")
            .and_then(|w| w.get("layers"))
            .and_then(|l| l.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest missing weights.layers"))?;
        let mut layers = BTreeMap::new();
        let mut off = 0usize;
        for l in layers_json {
            let name = l
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| anyhow::anyhow!("layer missing name"))?
                .to_string();
            let shape = l
                .get("shape")
                .and_then(|s| s.as_usize_vec())
                .ok_or_else(|| anyhow::anyhow!("layer missing shape"))?;
            anyhow::ensure!(shape.len() == 4, "conv weights must be 4-D");
            let count: usize = shape.iter().product();
            anyhow::ensure!(blob.len() >= (off + count) * 4, "fe_weights.bin too short");
            let mut w = Vec::with_capacity(count);
            for i in 0..count {
                let b = &blob[(off + i) * 4..(off + i) * 4 + 4];
                w.push(f32::from_le_bytes([b[0], b[1], b[2], b[3]]));
            }
            off += count;
            layers.insert(name, (w, shape[0], shape[1], shape[3]));
        }
        anyhow::ensure!(off * 4 == blob.len(), "fe_weights.bin has trailing bytes");
        Self::from_parts(cfg, layers)
    }

    /// Build from explicit weights (tests / synthetic configs), resolving
    /// the block execution plan once. Errors if the layer set is missing a
    /// conv the plan needs. When `cfg.clustered` is set the model is
    /// quantized immediately (see [`FeModel::into_clustered`]).
    pub fn from_parts(
        cfg: ModelConfig,
        layers: BTreeMap<String, (Vec<f32>, usize, usize, usize)>,
    ) -> anyhow::Result<Self> {
        let mut flat: Vec<Layer> = Vec::with_capacity(layers.len());
        let mut index: BTreeMap<String, usize> = BTreeMap::new();
        for (name, (w, cout, k, cin)) in layers {
            index.insert(name.clone(), flat.len());
            flat.push(Layer { name, w, cout, k, cin, clustered: None });
        }
        let lookup = |name: String| -> anyhow::Result<usize> {
            index.get(&name).copied().ok_or_else(|| anyhow::anyhow!("missing FE layer {name}"))
        };
        let stem = lookup("stem".to_string())?;
        let mut stages = Vec::with_capacity(cfg.widths.len());
        for si in 0..cfg.widths.len() {
            let stage_stride = if si == 0 { 1 } else { 2 };
            let mut blocks = Vec::with_capacity(cfg.blocks_per_stage);
            for b in 0..cfg.blocks_per_stage {
                blocks.push(BlockPlan {
                    conv1: lookup(format!("s{si}b{b}_conv1"))?,
                    conv2: lookup(format!("s{si}b{b}_conv2"))?,
                    proj: index.get(&format!("s{si}b{b}_proj")).copied(),
                    stride: if b == 0 { stage_stride } else { 1 },
                });
            }
            stages.push(blocks);
        }
        let clustered = cfg.clustered;
        let model = FeModel { cfg, layers: flat, stem, stages };
        Ok(if clustered { model.into_clustered() } else { model })
    }

    /// Build an FE with deterministic synthetic weights for an arbitrary
    /// [`ModelConfig`] — He-initialized convs seeded from
    /// `cfg.master_seed`, with the same layer naming scheme the AOT
    /// exporter uses (`stem`, `s{stage}b{block}_conv1/_conv2/_proj`).
    ///
    /// This makes [`crate::runtime::ComputeEngine`]'s native backend
    /// constructible without an artifacts directory; the resulting
    /// features are not the AOT model's but are class-separable on the
    /// procedural image generator, which is what the examples and
    /// integration paths need. Honors `cfg.clustered`.
    pub fn synthetic(cfg: ModelConfig) -> Self {
        let mut rng = crate::util::prng::Rng::new(cfg.master_seed ^ 0x5E_7EC7);
        let mut layers = BTreeMap::new();
        let add = |layers: &mut BTreeMap<String, (Vec<f32>, usize, usize, usize)>,
                   name: String,
                   cout: usize,
                   k: usize,
                   cin: usize,
                   rng: &mut crate::util::prng::Rng| {
            let std = (2.0 / (k * k * cin) as f32).sqrt();
            let w: Vec<f32> = (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
            layers.insert(name, (w, cout, k, cin));
        };
        let mut cin = cfg.in_channels;
        add(&mut layers, "stem".to_string(), cfg.widths[0], 3, cin, &mut rng);
        cin = cfg.widths[0];
        for (si, &w) in cfg.widths.iter().enumerate() {
            for b in 0..cfg.blocks_per_stage {
                add(&mut layers, format!("s{si}b{b}_conv1"), w, 3, cin, &mut rng);
                add(&mut layers, format!("s{si}b{b}_conv2"), w, 3, w, &mut rng);
                // projection shortcut when the block changes channel count
                // (`forward` subsamples the skip when channels match)
                if cin != w {
                    add(&mut layers, format!("s{si}b{b}_proj"), w, 1, cin, &mut rng);
                }
                cin = w;
            }
        }
        Self::from_parts(cfg, layers).expect("synthetic FE emits every planned layer")
    }

    /// Quantize every layer through [`cluster_layer`] (Fig. 4a) once and
    /// switch `forward` to the packed two-phase kernel; `cfg.ch_sub` /
    /// `cfg.n_centroids` size the codebooks. Deterministic (Lloyd with
    /// quantile init), so clustered forwards stay bit-identical across
    /// worker counts. The dense weights are kept so
    /// [`FeModel::dense_reconstruction`] can rebuild the numerical oracle.
    ///
    /// Panics unless `2 <= cfg.n_centroids <= 16` (nibble-packed indices);
    /// config loaders validate this before construction.
    pub fn into_clustered(mut self) -> Self {
        assert!(
            (2..=16).contains(&self.cfg.n_centroids),
            "clustered FE needs 2 <= n_centroids <= 16 (nibble-packed indices), got {}",
            self.cfg.n_centroids
        );
        for l in &mut self.layers {
            let cl = cluster_layer(&l.w, l.cout, l.k, l.cin, self.cfg.ch_sub, self.cfg.n_centroids);
            let idx = cl.packed();
            let lut = CodebookLut::new(&cl.codebook, idx.cout, idx.groups() * idx.n);
            l.clustered = Some(ClusteredKernel { idx, lut });
        }
        self.cfg.clustered = true;
        self
    }

    /// Whether `forward` runs the packed clustered kernel.
    pub fn is_clustered(&self) -> bool {
        self.cfg.clustered
    }

    /// The numerical oracle for clustered execution: a **dense** FeModel
    /// whose weights are reconstructed from each layer's codebook, so its
    /// `forward` computes the clustered numerics through the reference
    /// dense conv. Clustered forward == oracle forward (up to f32
    /// association) is the equivalence contract asserted by tests.
    pub fn dense_reconstruction(&self) -> FeModel {
        let mut m = self.clone();
        m.cfg.clustered = false;
        for l in &mut m.layers {
            if let Some(ck) = l.clustered.take() {
                let cl = ClusteredLayer {
                    cout: l.cout,
                    k: l.k,
                    cin: l.cin,
                    ch_sub: ck.idx.ch_sub,
                    n: ck.idx.n,
                    idx: ck.idx.unpack(),
                    codebook: ck.lut.to_flat(),
                };
                l.w = cl.reconstruct();
            }
        }
        m
    }

    /// Run one planned layer: packed clustered kernel when quantized,
    /// dense conv otherwise.
    fn run_layer(&self, li: usize, x: &Tensor3, stride: usize) -> anyhow::Result<Tensor3> {
        let l = &self.layers[li];
        anyhow::ensure!(l.cin == x.c, "{}: cin {} != input {}", l.name, l.cin, x.c);
        Ok(match &l.clustered {
            Some(ck) => clustered_conv2d_lut(x, &ck.idx, &ck.lut, stride),
            None => conv2d(x, &l.w, l.cout, l.k, stride),
        })
    }

    /// Begin a resumable staged forward pass (Section V-A): runs the stem
    /// and returns an executor whose [`StagedForward::step`] runs one
    /// stage's blocks at a time, yielding that stage's branch feature.
    /// Stopping after stage *b* means stages *b+1..* are **never
    /// computed** — the early-exit truncation the chip gets for free by
    /// streaming the FE block by block. `forward` / `forward_prefix` are
    /// reimplemented on top of this executor, so there is exactly one
    /// forward code path and a stepped pass is bit-identical to both.
    pub fn stage_start(&self, image: &[f32]) -> anyhow::Result<StagedForward<'_>> {
        let s = self.cfg.image_size;
        anyhow::ensure!(
            image.len() == s * s * self.cfg.in_channels,
            "image size mismatch: {} vs {}",
            image.len(),
            s * s * self.cfg.in_channels
        );
        let x = Tensor3::from_vec(s, s, self.cfg.in_channels, image.to_vec());
        let h = self.run_layer(self.stem, &x, 1)?.relu();
        Ok(StagedForward { model: self, h, next_stage: 0, layers_run: 1 })
    }

    /// Shared body of `forward` / `forward_prefix`: one staged executor
    /// stepped through the first `n_stages` stages of the plan, tapping a
    /// branch feature after each stage.
    fn forward_stages(&self, image: &[f32], n_stages: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        let mut exec = self.stage_start(image)?;
        let n_stages = n_stages.min(self.stages.len());
        let mut branches = Vec::with_capacity(n_stages);
        while exec.stages_run() < n_stages {
            let feat = exec.step()?.expect("plan has n_stages stages");
            branches.push(feat);
        }
        Ok(branches)
    }

    /// Conv layers (stem + block convs + projection shortcuts) executed
    /// through the first `n_stages` stages of the plan — the unit of the
    /// coordinator's `fe_layers_executed` / `fe_layers_skipped` counters.
    pub fn layers_through_stage(&self, n_stages: usize) -> usize {
        1 + self.stages[..n_stages.min(self.stages.len())]
            .iter()
            .flatten()
            .map(|bp| 2 + bp.proj.is_some() as usize)
            .sum::<usize>()
    }

    /// Total planned conv layers (= `layers_through_stage` of every stage).
    pub fn n_layers(&self) -> usize {
        self.layers_through_stage(self.stages.len())
    }

    /// Forward pass: image (H*W*3 flat NHWC) -> 4 branch features, each
    /// padded to `feature_dim`.
    pub fn forward(&self, image: &[f32]) -> anyhow::Result<Vec<Vec<f32>>> {
        self.forward_stages(image, self.stages.len())
    }

    /// Batched forward pass, sharded across scoped worker threads
    /// (`shards <= 1` runs serially on the caller's thread). Weights are
    /// borrowed, never cloned — `forward` is `&self` — and the result is
    /// bit-identical to calling [`FeModel::forward`] per image in order
    /// (DESIGN.md §Threading model).
    pub fn forward_batch(
        &self,
        images: &[Vec<f32>],
        shards: usize,
    ) -> anyhow::Result<Vec<Vec<Vec<f32>>>> {
        crate::util::parallel::shard_map(images, shards, |img| self.forward(img))
    }

    /// Forward only through the first `n_stages` stages (early-exit body
    /// computation): returns the branch features produced so far.
    pub fn forward_prefix(&self, image: &[f32], n_stages: usize) -> anyhow::Result<Vec<Vec<f32>>> {
        self.forward_stages(image, n_stages)
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.len()).sum()
    }

    /// Layer geometries for the chip simulator: (name, cout, k, cin).
    pub fn layer_geometries(&self) -> Vec<(String, usize, usize, usize)> {
        self.layers.iter().map(|l| (l.name.clone(), l.cout, l.k, l.cin)).collect()
    }
}

/// A resumable staged forward pass: holds the activation between stages so
/// the early-exit controller can decide *between* stages whether the next
/// one runs at all. Created by [`FeModel::stage_start`] (which runs the
/// stem); each [`StagedForward::step`] runs one stage's blocks and yields
/// that stage's branch feature, padded to `feature_dim`.
///
/// The executor borrows the model (weights are never cloned), so stepping
/// is `&mut self` on the executor but `&self` on the model — a batch of
/// executors can be stepped in parallel under the DESIGN.md §Threading
/// model contract.
#[derive(Clone, Debug)]
pub struct StagedForward<'m> {
    model: &'m FeModel,
    /// activation after the stem / the last completed stage
    h: Tensor3,
    next_stage: usize,
    /// conv layers executed so far (stem counts as one)
    layers_run: usize,
}

impl StagedForward<'_> {
    /// Stages in the plan (= branch count).
    pub fn n_stages(&self) -> usize {
        self.model.stages.len()
    }

    /// Stages completed so far (0 right after `stage_start`).
    pub fn stages_run(&self) -> usize {
        self.next_stage
    }

    /// Whether every stage has run.
    pub fn is_done(&self) -> bool {
        self.next_stage >= self.model.stages.len()
    }

    /// Conv layers executed so far (stem + block convs + projections) —
    /// the provable-work counter behind `fe_layers_executed`.
    pub fn layers_run(&self) -> usize {
        self.layers_run
    }

    /// Run the next stage's blocks and return its branch feature (padded
    /// to `feature_dim`), or `None` when every stage has already run.
    pub fn step(&mut self) -> anyhow::Result<Option<Vec<f32>>> {
        let Some(stage) = self.model.stages.get(self.next_stage) else {
            return Ok(None);
        };
        for bp in stage {
            let y = self.model.run_layer(bp.conv1, &self.h, bp.stride)?.relu();
            let y = self.model.run_layer(bp.conv2, &y, 1)?;
            self.layers_run += 2;
            let skip = match bp.proj {
                Some(pi) => {
                    self.layers_run += 1;
                    self.model.run_layer(pi, &self.h, bp.stride)?
                }
                None if bp.stride != 1 => self.h.subsample(bp.stride),
                None => self.h.clone(),
            };
            self.h = y.add(&skip).relu();
        }
        self.next_stage += 1;
        let mut feat = self.h.global_avg_pool();
        feat.resize(self.model.cfg.feature_dim, 0.0);
        Ok(Some(feat))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// Build a tiny synthetic FE without artifacts.
    pub fn tiny_model(seed: u64) -> FeModel {
        let cfg = ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8],
            blocks_per_stage: 1,
            feature_dim: 8,
            d: 64,
            ..Default::default()
        };
        let mut rng = Rng::new(seed);
        let mut layers = BTreeMap::new();
        let mut add = |name: &str, cout: usize, k: usize, cin: usize, rng: &mut Rng| {
            let std = (2.0 / (k * k * cin) as f32).sqrt();
            let w: Vec<f32> =
                (0..cout * k * k * cin).map(|_| std * rng.gauss_f32()).collect();
            layers.insert(name.to_string(), (w, cout, k, cin));
        };
        add("stem", 4, 3, 3, &mut rng);
        add("s0b0_conv1", 4, 3, 4, &mut rng);
        add("s0b0_conv2", 4, 3, 4, &mut rng);
        add("s1b0_conv1", 8, 3, 4, &mut rng);
        add("s1b0_conv2", 8, 3, 8, &mut rng);
        add("s1b0_proj", 8, 1, 4, &mut rng);
        FeModel::from_parts(cfg, layers).unwrap()
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_model(1);
        let mut rng = Rng::new(2);
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
        let branches = m.forward(&img).unwrap();
        assert_eq!(branches.len(), 2);
        assert!(branches.iter().all(|b| b.len() == 8));
        // stage-0 branch has width 4 -> padding above index 4
        assert!(branches[0][4..].iter().all(|&v| v == 0.0));
        assert!(branches[0][..4].iter().any(|&v| v != 0.0));
    }

    #[test]
    fn prefix_matches_full_forward() {
        let m = tiny_model(3);
        let mut rng = Rng::new(4);
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
        let full = m.forward(&img).unwrap();
        let prefix = m.forward_prefix(&img, 1).unwrap();
        assert_eq!(prefix.len(), 1);
        assert_eq!(prefix[0], full[0]);
    }

    #[test]
    fn deterministic() {
        let m = tiny_model(5);
        let img = vec![0.5f32; 8 * 8 * 3];
        assert_eq!(m.forward(&img).unwrap(), m.forward(&img).unwrap());
    }

    #[test]
    fn from_parts_rejects_missing_layer() {
        // the execution plan is resolved at build: a layer set without a
        // planned conv errors immediately instead of at forward time
        let cfg = ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4],
            blocks_per_stage: 1,
            feature_dim: 4,
            d: 64,
            ..Default::default()
        };
        let mut layers = BTreeMap::new();
        layers.insert("stem".to_string(), (vec![0.0; 4 * 9 * 3], 4, 3, 3));
        let err = FeModel::from_parts(cfg, layers).unwrap_err().to_string();
        assert!(err.contains("s0b0_conv1"), "{err}");
    }

    #[test]
    fn rejects_wrong_image_size() {
        let m = tiny_model(6);
        assert!(m.forward(&vec![0.0; 10]).is_err());
        assert!(m.forward_prefix(&vec![0.0; 10], 1).is_err());
    }

    #[test]
    fn forward_batch_bit_identical_to_serial() {
        let m = tiny_model(8);
        let mut rng = Rng::new(9);
        let images: Vec<Vec<f32>> =
            (0..7).map(|_| (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect()).collect();
        let serial: Vec<_> = images.iter().map(|img| m.forward(img).unwrap()).collect();
        for shards in [1, 2, 3, 7, 16] {
            assert_eq!(m.forward_batch(&images, shards).unwrap(), serial, "shards={shards}");
        }
    }

    #[test]
    fn forward_batch_propagates_errors() {
        let m = tiny_model(10);
        let mut images = vec![vec![0.1f32; 8 * 8 * 3]; 5];
        images[3] = vec![0.0; 4]; // wrong size mid-batch
        for shards in [1, 2, 5] {
            assert!(m.forward_batch(&images, shards).is_err(), "shards={shards}");
        }
    }

    #[test]
    fn param_count_positive() {
        assert!(tiny_model(7).n_params() > 500);
    }

    #[test]
    fn staged_steps_match_forward_and_count_layers() {
        // tiny_model plan: stem(1) + s0b0(2 convs) + s1b0(2 convs + proj)
        let m = tiny_model(20);
        assert_eq!(m.n_layers(), 6);
        assert_eq!(m.layers_through_stage(0), 1);
        assert_eq!(m.layers_through_stage(1), 3);
        assert_eq!(m.layers_through_stage(2), 6);
        let mut rng = Rng::new(21);
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
        let full = m.forward(&img).unwrap();
        let mut exec = m.stage_start(&img).unwrap();
        assert_eq!((exec.n_stages(), exec.stages_run(), exec.layers_run()), (2, 0, 1));
        assert!(!exec.is_done());
        let f0 = exec.step().unwrap().unwrap();
        assert_eq!(f0, full[0], "stage 0 branch must equal the full pass");
        assert_eq!(exec.layers_run(), m.layers_through_stage(1));
        let f1 = exec.step().unwrap().unwrap();
        assert_eq!(f1, full[1]);
        assert_eq!(exec.layers_run(), m.n_layers());
        assert!(exec.is_done());
        // stepping past the plan is a clean None, not an error
        assert!(exec.step().unwrap().is_none());
        assert_eq!(exec.layers_run(), m.n_layers(), "exhausted executor runs nothing");
    }

    #[test]
    fn staged_rejects_wrong_image_size() {
        let m = tiny_model(22);
        assert!(m.stage_start(&[0.0; 10]).is_err());
    }

    #[test]
    fn staged_clustered_matches_forward() {
        let m = tiny_model(23).into_clustered();
        let mut rng = Rng::new(24);
        let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
        let full = m.forward(&img).unwrap();
        let mut exec = m.stage_start(&img).unwrap();
        let mut stepped = Vec::new();
        while let Some(f) = exec.step().unwrap() {
            stepped.push(f);
        }
        assert_eq!(stepped, full, "clustered staged pass must be bit-identical to forward");
    }

    #[test]
    fn clustered_matches_dense_reconstruction_oracle() {
        // tiny_model weights: clustered forward == oracle forward within
        // f32 association, and the prefix path agrees with the full pass
        let m = tiny_model(12).into_clustered();
        assert!(m.is_clustered());
        let oracle = m.dense_reconstruction();
        assert!(!oracle.is_clustered());
        let mut rng = Rng::new(13);
        for _ in 0..3 {
            let img: Vec<f32> = (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect();
            let got = m.forward(&img).unwrap();
            let want = oracle.forward(&img).unwrap();
            assert_eq!(got.len(), want.len());
            for (gb, wb) in got.iter().zip(&want) {
                for (a, b) in gb.iter().zip(wb) {
                    assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()), "{a} vs {b}");
                }
            }
            let prefix = m.forward_prefix(&img, 1).unwrap();
            assert_eq!(prefix[0], got[0]);
        }
    }

    #[test]
    fn clustered_forward_batch_bit_identical_across_workers() {
        let m = tiny_model(14).into_clustered();
        let mut rng = Rng::new(15);
        let images: Vec<Vec<f32>> =
            (0..5).map(|_| (0..8 * 8 * 3).map(|_| rng.gauss_f32()).collect()).collect();
        let serial: Vec<_> = images.iter().map(|img| m.forward(img).unwrap()).collect();
        for shards in [1, 2, 5, 8] {
            assert_eq!(m.forward_batch(&images, shards).unwrap(), serial, "shards={shards}");
        }
    }

    #[test]
    fn synthetic_honors_clustered_config() {
        let cfg = ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8],
            blocks_per_stage: 1,
            feature_dim: 8,
            d: 64,
            ch_sub: 4,
            n_centroids: 8,
            clustered: true,
            ..Default::default()
        };
        let m = FeModel::synthetic(cfg.clone());
        assert!(m.is_clustered());
        // deterministic: same cfg -> same clustered features
        let img = vec![0.3f32; 8 * 8 * 3];
        let m2 = FeModel::synthetic(cfg);
        assert_eq!(m.forward(&img).unwrap(), m2.forward(&img).unwrap());
    }

    #[test]
    fn synthetic_model_runs_any_geometry() {
        let cfg = ModelConfig {
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8, 8],
            blocks_per_stage: 2,
            feature_dim: 16,
            d: 64,
            ..Default::default()
        };
        let m = FeModel::synthetic(cfg.clone());
        let img = vec![0.3f32; 8 * 8 * 3];
        let branches = m.forward(&img).unwrap();
        assert_eq!(branches.len(), 3);
        assert!(branches.iter().all(|b| b.len() == 16));
        // deterministic in the master seed
        let m2 = FeModel::synthetic(cfg);
        assert_eq!(m.forward(&img).unwrap(), m2.forward(&img).unwrap());
        // a different seed produces different features
        let other = FeModel::synthetic(ModelConfig {
            master_seed: 999,
            image_size: 8,
            in_channels: 3,
            widths: vec![4, 8, 8],
            blocks_per_stage: 2,
            feature_dim: 16,
            d: 64,
            ..Default::default()
        });
        assert_ne!(m.forward(&img).unwrap(), other.forward(&img).unwrap());
    }
}
