//! INT8 weight quantization — the Fig. 5 baseline ("INT8-quantized
//! ResNet-18 serves as the baseline" for FE output error / compression).

/// Symmetric per-tensor INT8 quantization; returns the dequantized weights
/// the INT8 datapath would effectively apply.
pub fn quantize_int8(w: &[f32]) -> Vec<f32> {
    let max_abs = w.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if max_abs == 0.0 {
        return vec![0.0; w.len()];
    }
    let scale = max_abs / 127.0;
    w.iter().map(|&v| (v / scale).round().clamp(-127.0, 127.0) * scale).collect()
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter().zip(b).map(|(x, y)| ((x - y) * (x - y)) as f64).sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn int8_error_small() {
        let mut rng = Rng::new(1);
        let w: Vec<f32> = (0..1000).map(|_| rng.gauss_f32() * 0.1).collect();
        let q = quantize_int8(&w);
        // max error is half an LSB = max_abs/254
        let max_abs = w.iter().fold(0f32, |m, &v| m.max(v.abs()));
        let lsb = max_abs / 127.0;
        for (a, b) in w.iter().zip(&q) {
            assert!((a - b).abs() <= lsb / 2.0 + 1e-7);
        }
    }

    #[test]
    fn zero_safe() {
        assert_eq!(quantize_int8(&[0.0, 0.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn mse_basics() {
        assert_eq!(mse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((mse(&[0.0, 0.0], &[1.0, 1.0]) - 1.0).abs() < 1e-12);
    }
}
