//! 1-D K-means weight clustering (Fig. 4a) — rust mirror of
//! `python/compile/clustering.py` (quantile init, Lloyd iterations).

/// A clustered conv layer: per-output-channel indices + codebooks.
#[derive(Clone, Debug)]
pub struct ClusteredLayer {
    pub cout: usize,
    pub k: usize,
    pub cin: usize,
    pub ch_sub: usize,
    pub n: usize,
    /// (Cout, K*K*Cin) centroid indices, layout (ky*K+kx)*Cin + ci
    pub idx: Vec<u8>,
    /// (Cout, G, N) centroids
    pub codebook: Vec<f32>,
}

impl ClusteredLayer {
    pub fn groups(&self) -> usize {
        self.cin.div_ceil(self.ch_sub.min(self.cin))
    }

    /// Reconstruct dense weights (Cout, K, K, Cin) row-major.
    pub fn reconstruct(&self) -> Vec<f32> {
        let kkc = self.k * self.k * self.cin;
        let g = self.groups();
        let ch_sub = self.ch_sub.min(self.cin);
        let mut w = vec![0f32; self.cout * kkc];
        for co in 0..self.cout {
            for kk in 0..kkc {
                let ci = kk % self.cin;
                let gi = ci / ch_sub;
                let ni = self.idx[co * kkc + kk] as usize;
                w[co * kkc + kk] = self.codebook[(co * g + gi) * self.n + ni];
            }
        }
        w
    }

    /// Nibble-pack the index tensor for the fast kernel
    /// ([`crate::fe::conv::clustered_conv2d_packed`]). Requires `n <= 16`.
    pub fn packed(&self) -> crate::fe::conv::PackedIdx {
        crate::fe::conv::PackedIdx::pack(
            &self.idx, self.cout, self.k, self.cin, self.ch_sub, self.n,
        )
    }

    /// Storage cost in bits: indices (log2 N each) + codebooks (16-bit).
    pub fn storage_bits(&self) -> u64 {
        let idx_bits = (self.n as f64).log2().ceil() as u64;
        let kkc = (self.k * self.k * self.cin) as u64;
        self.cout as u64 * (kkc * idx_bits + self.groups() as u64 * self.n as u64 * 16)
    }
}

/// Linear-interpolated quantile (numpy default) on a sorted slice.
fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let rank = q * (n - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (rank - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

/// Lloyd's 1-D k-means with deterministic quantile init.
/// Returns (centroids (n,), labels).
pub fn kmeans_1d(values: &[f32], n: usize, iters: usize) -> (Vec<f32>, Vec<u8>) {
    assert!(n <= 256, "u8 label space");
    let v: Vec<f64> = values.iter().map(|&x| x as f64).collect();
    if v.len() <= n {
        // degenerate: every value its own centroid (sorted order)
        let mut order: Vec<usize> = (0..v.len()).collect();
        // total_cmp: a NaN weight (corrupt checkpoint, bad cast) must not
        // panic the quantizer — NaNs sort to the end and cluster there
        order.sort_by(|&a, &b| v[a].total_cmp(&v[b]));
        let mut cents = vec![0f64; n];
        let mut labels = vec![0u8; v.len()];
        for (slot, &i) in order.iter().enumerate() {
            cents[slot] = v[i];
            labels[i] = slot as u8;
        }
        return (cents.iter().map(|&c| c as f32).collect(), labels);
    }
    let mut sorted = v.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let mut cents: Vec<f64> =
        (0..n).map(|i| quantile_sorted(&sorted, (i as f64 + 0.5) / n as f64)).collect();
    // spread over the finite range only: a NaN at either end of the sorted
    // values must not poison the tie-break epsilon
    let lo = sorted.iter().find(|x| x.is_finite()).copied().unwrap_or(0.0);
    let hi = sorted.iter().rev().find(|x| x.is_finite()).copied().unwrap_or(0.0);
    let eps = 1e-12 + 1e-9 * (hi - lo);
    for i in 1..n {
        if cents[i] <= cents[i - 1] {
            cents[i] = cents[i - 1] + eps;
        }
    }
    // NaN-robust nearest centroid: a NaN distance (NaN value or NaN
    // centroid) never beats `bd`, so such pairs fall through to slot 0
    // instead of corrupting the argmin
    let assign = |cents: &[f64], x: f64| -> usize {
        let mut best = 0;
        let mut bd = f64::INFINITY;
        for (j, &c) in cents.iter().enumerate() {
            let d = (x - c).abs();
            if d < bd {
                bd = d;
                best = j;
            }
        }
        best
    };
    for _ in 0..iters {
        let mut sums = vec![0f64; n];
        let mut cnts = vec![0u64; n];
        for &x in &v {
            // non-finite values keep their label but must not drag a
            // centroid to NaN/inf
            if !x.is_finite() {
                continue;
            }
            let j = assign(&cents, x);
            sums[j] += x;
            cnts[j] += 1;
        }
        for j in 0..n {
            if cnts[j] > 0 {
                cents[j] = sums[j] / cnts[j] as f64;
            }
        }
    }
    let labels: Vec<u8> = v.iter().map(|&x| assign(&cents, x) as u8).collect();
    (cents.iter().map(|&c| c as f32).collect(), labels)
}

/// Cluster a conv layer's weights: `w` is (Cout, K, K, Cin) row-major.
pub fn cluster_layer(
    w: &[f32],
    cout: usize,
    k: usize,
    cin: usize,
    ch_sub: usize,
    n: usize,
) -> ClusteredLayer {
    assert_eq!(w.len(), cout * k * k * cin);
    let ch_sub_eff = ch_sub.min(cin);
    let g = cin.div_ceil(ch_sub_eff);
    let kkc = k * k * cin;
    let mut idx = vec![0u8; cout * kkc];
    let mut codebook = vec![0f32; cout * g * n];
    let mut member_pos: Vec<usize> = Vec::new();
    let mut member_val: Vec<f32> = Vec::new();
    for co in 0..cout {
        for gi in 0..g {
            member_pos.clear();
            member_val.clear();
            for kk in 0..kkc {
                let ci = kk % cin;
                if ci / ch_sub_eff == gi {
                    member_pos.push(kk);
                    member_val.push(w[co * kkc + kk]);
                }
            }
            let (cents, labels) = kmeans_1d(&member_val, n, 15);
            codebook[(co * g + gi) * n..(co * g + gi + 1) * n].copy_from_slice(&cents);
            for (m, &pos) in member_pos.iter().enumerate() {
                idx[co * kkc + pos] = labels[m];
            }
        }
    }
    ClusteredLayer { cout, k, cin, ch_sub: ch_sub_eff, n, idx, codebook }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn labels_are_nearest() {
        let mut rng = Rng::new(1);
        let v: Vec<f32> = (0..200).map(|_| rng.gauss_f32()).collect();
        let (cents, labels) = kmeans_1d(&v, 8, 15);
        for (x, &l) in v.iter().zip(&labels) {
            let d_l = (x - cents[l as usize]).abs();
            for c in &cents {
                assert!(d_l <= (x - c).abs() + 1e-6);
            }
        }
    }

    #[test]
    fn error_decreases_with_n() {
        let mut rng = Rng::new(2);
        let v: Vec<f32> = (0..500).map(|_| rng.gauss_f32()).collect();
        let mut prev = f64::INFINITY;
        for n in [2, 4, 8, 16] {
            let (cents, labels) = kmeans_1d(&v, n, 15);
            let mse: f64 = v
                .iter()
                .zip(&labels)
                .map(|(x, &l)| ((x - cents[l as usize]) as f64).powi(2))
                .sum::<f64>()
                / v.len() as f64;
            assert!(mse <= prev + 1e-12);
            prev = mse;
        }
    }

    #[test]
    fn degenerate_fewer_values_than_centroids() {
        let (cents, labels) = kmeans_1d(&[3.0, 1.0], 4, 15);
        assert_eq!(cents[labels[0] as usize], 3.0);
        assert_eq!(cents[labels[1] as usize], 1.0);
    }

    #[test]
    fn nan_weight_does_not_panic() {
        // regression: the quantile-init sort used partial_cmp().unwrap(),
        // so one NaN weight panicked the whole quantizer
        let mut rng = Rng::new(9);
        let mut v: Vec<f32> = (0..100).map(|_| rng.gauss_f32()).collect();
        v[17] = f32::NAN;
        let (cents, labels) = kmeans_1d(&v, 4, 10);
        assert_eq!(labels.len(), v.len());
        assert_eq!(cents.len(), 4);
        // finite values still get a nearest finite centroid
        assert!(v
            .iter()
            .zip(&labels)
            .filter(|(x, _)| x.is_finite())
            .any(|(_, &l)| cents[l as usize].is_finite()));
        // degenerate (fewer values than centroids) path too
        let (_c, l) = kmeans_1d(&[f32::NAN, 1.0], 4, 5);
        assert_eq!(l.len(), 2);
        // and a whole layer with one poisoned weight
        let mut w = vec![0.1f32; 2 * 3 * 3 * 4];
        w[5] = f32::NAN;
        let cl = cluster_layer(&w, 2, 3, 4, 4, 4);
        assert_eq!(cl.idx.len(), w.len());
    }

    #[test]
    fn cluster_layer_reconstruction_error_bounded() {
        let mut rng = Rng::new(3);
        let (cout, k, cin) = (4, 3, 16);
        let w: Vec<f32> = (0..cout * k * k * cin).map(|_| rng.gauss_f32() * 0.1).collect();
        let cl = cluster_layer(&w, cout, k, cin, 8, 16);
        let rec = cl.reconstruct();
        let mse: f64 = w
            .iter()
            .zip(&rec)
            .map(|(a, b)| ((a - b) * (a - b)) as f64)
            .sum::<f64>()
            / w.len() as f64;
        // 16 centroids over 72 values per group: should be tight
        assert!(mse < 1e-4, "mse {mse}");
    }

    #[test]
    fn smaller_ch_sub_lower_error() {
        let mut rng = Rng::new(4);
        let (cout, k, cin) = (2, 3, 32);
        let w: Vec<f32> = (0..cout * k * k * cin).map(|_| rng.gauss_f32()).collect();
        let err = |ch_sub: usize| {
            let cl = cluster_layer(&w, cout, k, cin, ch_sub, 8);
            let rec = cl.reconstruct();
            w.iter().zip(&rec).map(|(a, b)| ((a - b) * (a - b)) as f64).sum::<f64>()
        };
        assert!(err(8) <= err(32) + 1e-9);
    }

    #[test]
    fn storage_accounting() {
        let cl = ClusteredLayer {
            cout: 2, k: 3, cin: 8, ch_sub: 4, n: 16,
            idx: vec![0; 2 * 72], codebook: vec![0.0; 2 * 2 * 16],
        };
        // per channel: 72 indices * 4b + 2 codebooks * 16 * 16b = 288 + 512
        assert_eq!(cl.storage_bits(), 2 * (288 + 512));
    }
}
