//! Convolution substrate: dense NHWC conv with XLA-compatible SAME padding
//! and the weight-clustered two-phase convolution of Fig. 4(b).
//!
//! Padding matches `jax.lax.conv_general_dilated(..., padding="SAME")`
//! exactly (out = ceil(in/stride), asymmetric low/high pads) so the native
//! FE reproduces the artifact numerics.
//!
//! The clustered fast path runs phase 2 over a flat `[group][centroid]`
//! codebook LUT ([`CodebookLut`]) with `util::simd`'s lane-blocked MAC
//! (DESIGN.md §SIMD datapath); [`clustered_conv2d_lut_in_lane`] is the
//! lane-explicit entry the simd-vs-scalar benches use, and
//! [`clustered_conv2d_packed`] keeps the pre-LUT signature as a
//! compatibility wrapper.

use crate::util::simd::{self, Lane};

/// A minimal HxWxC tensor (row-major, NHWC per image).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor3 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor3 {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Tensor3 { h, w, c, data: vec![0.0; h * w * c] }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), h * w * c);
        Tensor3 { h, w, c, data }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn at_mut(&mut self, y: usize, x: usize, ch: usize) -> &mut f32 {
        &mut self.data[(y * self.w + x) * self.c + ch]
    }

    pub fn relu(mut self) -> Self {
        for v in self.data.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        self
    }

    /// Global average pool -> length-C feature.
    pub fn global_avg_pool(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.c];
        let hw = (self.h * self.w) as f32;
        for y in 0..self.h {
            for x in 0..self.w {
                let base = (y * self.w + x) * self.c;
                for ch in 0..self.c {
                    out[ch] += self.data[base + ch];
                }
            }
        }
        out.iter_mut().for_each(|v| *v /= hw);
        out
    }

    /// Strided spatial subsample (python's `h[:, ::s, ::s, :]`).
    pub fn subsample(&self, s: usize) -> Tensor3 {
        let ho = self.h.div_ceil(s);
        let wo = self.w.div_ceil(s);
        let mut out = Tensor3::zeros(ho, wo, self.c);
        for y in 0..ho {
            for x in 0..wo {
                for ch in 0..self.c {
                    *out.at_mut(y, x, ch) = self.at(y * s, x * s, ch);
                }
            }
        }
        out
    }

    /// Elementwise addition.
    pub fn add(mut self, other: &Tensor3) -> Tensor3 {
        assert_eq!((self.h, self.w, self.c), (other.h, other.w, other.c));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        self
    }
}

/// XLA SAME padding: (out_size, pad_lo) for one spatial dim.
#[inline]
fn same_pad(input: usize, k: usize, stride: usize) -> (usize, isize) {
    let out = input.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(input) as isize;
    (out, pad_total / 2)
}

/// Multi-accumulator dot product — breaks the serial FP dependency chain
/// so LLVM vectorizes the FE hot loop (EXPERIMENTS.md §Perf).
#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() / 8 * 8;
    let mut acc = [0f32; 8];
    let (a8, b8) = (&a[..n8], &b[..n8]);
    for (ca, cb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in n8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Dense conv: weights (Cout, K, K, Cin) flattened row-major.
pub fn conv2d(x: &Tensor3, w: &[f32], cout: usize, k: usize, stride: usize) -> Tensor3 {
    assert_eq!(w.len(), cout * k * k * x.c);
    let (ho, pad_y) = same_pad(x.h, k, stride);
    let (wo, pad_x) = same_pad(x.w, k, stride);
    let cin = x.c;
    let kkc = k * k * cin;
    let mut out = Tensor3::zeros(ho, wo, cout);
    for oy in 0..ho {
        for ox in 0..wo {
            let obase = (oy * wo + ox) * cout;
            for ky in 0..k {
                let iy = oy as isize * stride as isize + ky as isize - pad_y;
                if iy < 0 || iy >= x.h as isize {
                    continue;
                }
                // contiguous kx run that stays inside the image: fuse the
                // (kx, ci) loop into one long dot product per channel
                let ix0 = ox as isize * stride as isize - pad_x;
                let kx_lo = (-ix0).clamp(0, k as isize) as usize;
                let kx_hi = ((x.w as isize - ix0).clamp(0, k as isize)) as usize;
                if kx_lo >= kx_hi {
                    continue;
                }
                let run = kx_hi - kx_lo;
                let ibase = (iy as usize * x.w + (ix0 + kx_lo as isize) as usize) * cin;
                let xrow = &x.data[ibase..ibase + run * cin];
                let wbase = (ky * k + kx_lo) * cin;
                for co in 0..cout {
                    let wrow = &w[co * kkc + wbase..co * kkc + wbase + run * cin];
                    out.data[obase + co] += dot_f32(xrow, wrow);
                }
            }
        }
    }
    out
}

/// Weight-clustered conv, **reference kernel** (Fig. 4b): phase 1 bins
/// activations by weight index into per-(group, centroid) partial sums,
/// phase 2 multiplies the bins by the codebook. Numerically equals
/// `conv2d` with reconstructed weights (up to f32 association) — asserted
/// by tests. This is the readable spec and the oracle that
/// [`clustered_conv2d_packed`] (the fast path the native FE executes) is
/// checked against; it is deliberately left unoptimized.
///
/// `idx`: (Cout, K*K*Cin) centroid indices; `codebook`: (Cout, G, N).
pub fn clustered_conv2d(
    x: &Tensor3,
    idx: &[u8],
    codebook: &[f32],
    cout: usize,
    k: usize,
    stride: usize,
    ch_sub: usize,
    n: usize,
) -> Tensor3 {
    let cin = x.c;
    let ch_sub = ch_sub.min(cin);
    let g = cin.div_ceil(ch_sub);
    assert_eq!(idx.len(), cout * k * k * cin);
    assert_eq!(codebook.len(), cout * g * n);
    let (ho, pad_y) = same_pad(x.h, k, stride);
    let (wo, pad_x) = same_pad(x.w, k, stride);
    let mut out = Tensor3::zeros(ho, wo, cout);
    let mut bins = vec![0f32; g * n];
    for oy in 0..ho {
        for ox in 0..wo {
            for co in 0..cout {
                bins.iter_mut().for_each(|b| *b = 0.0);
                // phase 1: accumulate activations into (group, index) bins
                for ky in 0..k {
                    let iy = oy as isize * stride as isize + ky as isize - pad_y;
                    if iy < 0 || iy >= x.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = ox as isize * stride as isize + kx as isize - pad_x;
                        if ix < 0 || ix >= x.w as isize {
                            continue;
                        }
                        let ibase = (iy as usize * x.w + ix as usize) * cin;
                        let kbase = co * k * k * cin + (ky * k + kx) * cin;
                        for ci in 0..cin {
                            let gidx = ci / ch_sub;
                            let nidx = idx[kbase + ci] as usize;
                            bins[gidx * n + nidx] += x.data[ibase + ci];
                        }
                    }
                }
                // phase 2: MAC with codebook centroids
                let cb = &codebook[co * g * n..(co + 1) * g * n];
                let mut acc = 0f32;
                for (b, c) in bins.iter().zip(cb) {
                    acc += b * c;
                }
                out.data[(oy * wo + ox) * cout + co] = acc;
            }
        }
    }
    out
}

/// Output-channel tile width for [`clustered_conv2d_packed`]: matches the
/// chip's 16 PE columns and keeps the per-tile bin scratch (16 x G x N
/// floats) inside L1. Even, so nibble pairs never straddle a tile edge.
const COUT_TILE: usize = 16;

/// Nibble-packed clustered-weight indices, laid out **tap-major**: for a
/// flat tap `p = (ky*K + kx)*Cin + ci`, `data[p * cpb ..]` holds the
/// centroid indices of *all* output channels (two channels per byte, even
/// channel in the low nibble). The transpose is what makes the phase-1
/// inner loop of [`clustered_conv2d_packed`] read contiguous bytes while
/// each activation is loaded once per channel tile instead of once per
/// output channel. `goff[p]` caches `(ci / ch_sub) * n` so the hot loop
/// never divides.
#[derive(Clone, Debug, PartialEq)]
pub struct PackedIdx {
    pub cout: usize,
    pub k: usize,
    pub cin: usize,
    /// effective group size (already clamped to `cin`)
    pub ch_sub: usize,
    pub n: usize,
    /// bytes per tap row: `ceil(cout / 2)`
    cpb: usize,
    /// (K*K*Cin, cpb) nibble pairs
    data: Vec<u8>,
    /// per-tap bin base offset `(ci / ch_sub) * n`
    goff: Vec<u16>,
}

impl PackedIdx {
    /// Pack a (Cout, K*K*Cin) index tensor (the layout of
    /// [`crate::fe::kmeans::ClusteredLayer::idx`]). Requires `n <= 16`
    /// (4-bit indices — the paper's N=16 codebooks are exactly this).
    pub fn pack(idx: &[u8], cout: usize, k: usize, cin: usize, ch_sub: usize, n: usize) -> Self {
        let kkc = k * k * cin;
        assert_eq!(idx.len(), cout * kkc);
        assert!((1..=16).contains(&n), "nibble packing needs 1 <= N <= 16, got {n}");
        let ch_sub = ch_sub.min(cin).max(1);
        let g = cin.div_ceil(ch_sub);
        assert!(g * n <= u16::MAX as usize, "bin space {g}*{n} overflows the u16 offset table");
        let cpb = cout.div_ceil(2);
        let mut data = vec![0u8; kkc * cpb];
        for co in 0..cout {
            for p in 0..kkc {
                let v = idx[co * kkc + p];
                assert!((v as usize) < n, "index {v} out of range for N={n}");
                let b = &mut data[p * cpb + co / 2];
                *b |= if co % 2 == 0 { v } else { v << 4 };
            }
        }
        let goff: Vec<u16> = (0..kkc)
            .map(|p| {
                let off = ((p % cin) / ch_sub) * n;
                debug_assert!(u16::try_from(off).is_ok(), "bin offset checked above");
                off as u16
            })
            .collect();
        PackedIdx { cout, k, cin, ch_sub, n, cpb, data, goff }
    }

    /// Number of channel groups G.
    pub fn groups(&self) -> usize {
        self.cin.div_ceil(self.ch_sub)
    }

    /// Unpack back to the (Cout, K*K*Cin) u8 layout. Exact round-trip with
    /// [`PackedIdx::pack`] — asserted by a regression test.
    pub fn unpack(&self) -> Vec<u8> {
        let kkc = self.k * self.k * self.cin;
        let mut idx = vec![0u8; self.cout * kkc];
        for co in 0..self.cout {
            for p in 0..kkc {
                let b = self.data[p * self.cpb + co / 2];
                idx[co * kkc + p] = if co % 2 == 0 { b & 0x0F } else { b >> 4 };
            }
        }
        idx
    }

    /// Packed index storage in bytes (half the u8 tensor).
    pub fn bytes(&self) -> usize {
        self.data.len()
    }
}

/// Flat `[group][centroid]` codebook layout for the clustered fast path:
/// row `co` holds that output channel's G*N centroid table contiguously,
/// zero-padded to a multiple of 4 so the phase-2 [`simd::mac_f32`] runs
/// whole aligned lane groups (the zero pad MACs against zeroed bin pad —
/// an exact `+0.0` contribution). Built once per layer
/// (`fe::resnet::into_clustered`), not per call.
#[derive(Clone, Debug, PartialEq)]
pub struct CodebookLut {
    pub cout: usize,
    /// logical row length G*N
    pub gn: usize,
    /// padded row stride: `gn` rounded up to a multiple of 4
    row_len: usize,
    data: Vec<f32>,
}

impl CodebookLut {
    /// Lay out a flat (Cout, G*N) codebook (the layout of
    /// [`crate::fe::kmeans::ClusteredLayer::codebook`]) into padded rows.
    pub fn new(codebook: &[f32], cout: usize, gn: usize) -> Self {
        assert_eq!(codebook.len(), cout * gn, "codebook must be cout x G*N");
        let row_len = gn.div_ceil(4) * 4;
        let mut data = vec![0f32; cout * row_len];
        for co in 0..cout {
            data[co * row_len..co * row_len + gn]
                .copy_from_slice(&codebook[co * gn..(co + 1) * gn]);
        }
        CodebookLut { cout, gn, row_len, data }
    }

    /// Padded centroid row of output channel `co` (length
    /// [`CodebookLut::padded_row_len`]).
    #[inline]
    pub fn row(&self, co: usize) -> &[f32] {
        &self.data[co * self.row_len..(co + 1) * self.row_len]
    }

    /// Row stride including lane padding (a multiple of 4).
    pub fn padded_row_len(&self) -> usize {
        self.row_len
    }

    /// The flat (Cout, G*N) codebook this LUT was built from — exact
    /// round-trip with [`CodebookLut::new`].
    pub fn to_flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cout * self.gn);
        for co in 0..self.cout {
            out.extend_from_slice(&self.row(co)[..self.gn]);
        }
        out
    }
}

/// Weight-clustered conv, **fast kernel** — the native FE hot path.
/// Same two-phase dataflow as [`clustered_conv2d`] and numerically equal
/// to it up to f32 association, but restructured for speed:
///
/// * output channels are processed in `COUT_TILE`-wide (16) tiles, so
///   each activation is read once per tile instead of once per channel;
/// * the index tensor is nibble-packed and tap-major ([`PackedIdx`]), so
///   the inner channel loop walks contiguous bytes, two channels per byte;
/// * padding is handled by the same trimmed contiguous-run structure as
///   `conv2d` — no per-element bounds checks;
/// * the `ci / ch_sub` group map is precomputed (`PackedIdx::goff`);
/// * phase 2 MACs each tile's bins against the contiguous, lane-padded
///   [`CodebookLut`] row with [`simd::mac_f32`] — 4 independent f32
///   accumulators per lane, no scalar tail.
pub fn clustered_conv2d_lut_in_lane(
    x: &Tensor3,
    idx: &PackedIdx,
    lut: &CodebookLut,
    stride: usize,
    lane: Lane,
) -> Tensor3 {
    let (cout, k, cin) = (idx.cout, idx.k, idx.cin);
    assert_eq!(cin, x.c, "packed indices built for Cin={cin}, input has {}", x.c);
    let gn = idx.groups() * idx.n;
    assert_eq!(lut.cout, cout, "LUT built for a different cout");
    assert_eq!(lut.gn, gn, "LUT built for a different G*N bin space");
    let (ho, pad_y) = same_pad(x.h, k, stride);
    let (wo, pad_x) = same_pad(x.w, k, stride);
    let cpb = idx.cpb;
    // bins share the LUT's padded row stride; the pad stays zero (phase 1
    // only writes offsets < gn), so phase 2 needs no per-row trim
    let rl = lut.padded_row_len();
    let mut out = Tensor3::zeros(ho, wo, cout);
    let mut bins = vec![0f32; COUT_TILE * rl];
    for oy in 0..ho {
        for ox in 0..wo {
            let obase = (oy * wo + ox) * cout;
            let mut t0 = 0;
            while t0 < cout {
                let tlen = COUT_TILE.min(cout - t0);
                let pairs = tlen / 2;
                bins[..tlen * rl].fill(0.0);
                // phase 1: accumulate each in-bounds activation into the
                // tile's (group, index) bins — one pass over the window
                for ky in 0..k {
                    let iy = oy as isize * stride as isize + ky as isize - pad_y;
                    if iy < 0 || iy >= x.h as isize {
                        continue;
                    }
                    let ix0 = ox as isize * stride as isize - pad_x;
                    let kx_lo = (-ix0).clamp(0, k as isize) as usize;
                    let kx_hi = ((x.w as isize - ix0).clamp(0, k as isize)) as usize;
                    if kx_lo >= kx_hi {
                        continue;
                    }
                    let run = kx_hi - kx_lo;
                    let ibase = (iy as usize * x.w + (ix0 + kx_lo as isize) as usize) * cin;
                    let xrow = &x.data[ibase..ibase + run * cin];
                    let p0 = (ky * k + kx_lo) * cin;
                    for (j, &a) in xrow.iter().enumerate() {
                        let p = p0 + j;
                        let boff = idx.goff[p] as usize;
                        let row = &idx.data[p * cpb + t0 / 2..p * cpb + t0 / 2 + tlen.div_ceil(2)];
                        for (tc, &byte) in row[..pairs].iter().enumerate() {
                            bins[2 * tc * rl + boff + (byte & 0x0F) as usize] += a;
                            bins[(2 * tc + 1) * rl + boff + (byte >> 4) as usize] += a;
                        }
                        if tlen % 2 == 1 {
                            let byte = row[pairs];
                            bins[(tlen - 1) * rl + boff + (byte & 0x0F) as usize] += a;
                        }
                    }
                }
                // phase 2: lane-blocked codebook MAC over contiguous rows
                for tc in 0..tlen {
                    let co = t0 + tc;
                    out.data[obase + co] =
                        simd::mac_f32(&bins[tc * rl..(tc + 1) * rl], lut.row(co), lane);
                }
                t0 += tlen;
            }
        }
    }
    out
}

/// [`clustered_conv2d_lut_in_lane`] on the immutable process-wide kernel
/// lane — what `fe::resnet::run_layer` executes.
pub fn clustered_conv2d_lut(
    x: &Tensor3,
    idx: &PackedIdx,
    lut: &CodebookLut,
    stride: usize,
) -> Tensor3 {
    clustered_conv2d_lut_in_lane(x, idx, lut, stride, simd::active_lane())
}

/// Compatibility wrapper over the LUT kernel for callers that still hold a
/// flat (Cout, G*N) codebook — builds the [`CodebookLut`] per call, so hot
/// paths should build it once and use [`clustered_conv2d_lut`] instead.
pub fn clustered_conv2d_packed(
    x: &Tensor3,
    idx: &PackedIdx,
    codebook: &[f32],
    stride: usize,
) -> Tensor3 {
    let lut = CodebookLut::new(codebook, idx.cout, idx.groups() * idx.n);
    clustered_conv2d_lut(x, idx, &lut, stride)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_tensor(h: usize, w: usize, c: usize, rng: &mut Rng) -> Tensor3 {
        Tensor3::from_vec(h, w, c, (0..h * w * c).map(|_| rng.gauss_f32()).collect())
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 conv with identity weights = channel copy
        let mut rng = Rng::new(1);
        let x = rand_tensor(4, 4, 3, &mut rng);
        let mut w = vec![0f32; 3 * 1 * 1 * 3];
        for c in 0..3 {
            w[c * 3 + c] = 1.0;
        }
        let y = conv2d(&x, &w, 3, 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn same_padding_stride1_shape() {
        let mut rng = Rng::new(2);
        let x = rand_tensor(5, 7, 2, &mut rng);
        let w = vec![0.1f32; 4 * 3 * 3 * 2];
        let y = conv2d(&x, &w, 4, 3, 1);
        assert_eq!((y.h, y.w, y.c), (5, 7, 4));
    }

    #[test]
    fn same_padding_stride2_shape_and_xla_asymmetry() {
        // in=32, k=3, s=2 -> out=16, pad_total=1 -> pad_lo=0 (XLA rule)
        let mut rng = Rng::new(3);
        let x = rand_tensor(32, 32, 1, &mut rng);
        let w = vec![1.0f32; 1 * 3 * 3 * 1];
        let y = conv2d(&x, &w, 1, 3, 2);
        assert_eq!((y.h, y.w), (16, 16));
        // output (0,0) with pad_lo=0 sums x[0..3, 0..3]
        let mut want = 0.0;
        for yy in 0..3 {
            for xx in 0..3 {
                want += x.at(yy, xx, 0);
            }
        }
        assert!((y.at(0, 0, 0) - want).abs() < 1e-4);
    }

    #[test]
    fn clustered_matches_dense_reconstruction() {
        let mut rng = Rng::new(4);
        let (cin, cout, k, ch_sub, n) = (8, 5, 3, 4, 4);
        let x = rand_tensor(9, 9, cin, &mut rng);
        let g = cin / ch_sub;
        let idx: Vec<u8> = (0..cout * k * k * cin).map(|_| rng.below(n) as u8).collect();
        let cb: Vec<f32> = (0..cout * g * n).map(|_| rng.gauss_f32()).collect();
        // dense reconstruction
        let mut w = vec![0f32; cout * k * k * cin];
        for co in 0..cout {
            for kk in 0..k * k {
                for ci in 0..cin {
                    let flat = co * k * k * cin + kk * cin + ci;
                    let gi = ci / ch_sub;
                    w[flat] = cb[co * g * n + gi * n + idx[flat] as usize];
                }
            }
        }
        for stride in [1, 2] {
            let dense = conv2d(&x, &w, cout, k, stride);
            let clus = clustered_conv2d(&x, &idx, &cb, cout, k, stride, ch_sub, n);
            assert_eq!((dense.h, dense.w, dense.c), (clus.h, clus.w, clus.c));
            for (a, b) in dense.data.iter().zip(&clus.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn packed_idx_roundtrips_exactly() {
        // regression: nibble packing must round-trip the index tensor
        // bit-exactly, including odd cout (unused high nibble in the last
        // byte) and cin not divisible by ch_sub
        let mut rng = Rng::new(11);
        for (cout, k, cin, ch_sub, n) in
            [(5usize, 3usize, 7usize, 4usize, 16usize), (4, 1, 3, 8, 3), (16, 3, 8, 2, 16)]
        {
            let idx: Vec<u8> = (0..cout * k * k * cin).map(|_| rng.below(n) as u8).collect();
            let packed = PackedIdx::pack(&idx, cout, k, cin, ch_sub, n);
            assert_eq!(packed.unpack(), idx, "cout={cout} cin={cin} n={n}");
            assert_eq!(packed.bytes(), k * k * cin * cout.div_ceil(2));
        }
    }

    #[test]
    fn packed_kernel_matches_reference() {
        // the fast path vs the reference kernel, across strides, odd cout
        // (nibble tail), cin not divisible by ch_sub, and a tile-straddling
        // cout > COUT_TILE
        let mut rng = Rng::new(12);
        let cases = [(8usize, 5usize, 4usize, 4usize), (6, 21, 4, 16), (3, 2, 8, 2)];
        for (cin, cout, ch_sub, n) in cases {
            let k = 3;
            let x = rand_tensor(9, 7, cin, &mut rng);
            let idx: Vec<u8> = (0..cout * k * k * cin).map(|_| rng.below(n) as u8).collect();
            let g = cin.div_ceil(ch_sub.min(cin));
            let cb: Vec<f32> = (0..cout * g * n).map(|_| rng.gauss_f32()).collect();
            let packed = PackedIdx::pack(&idx, cout, k, cin, ch_sub, n);
            for stride in [1, 2] {
                let want = clustered_conv2d(&x, &idx, &cb, cout, k, stride, ch_sub, n);
                let got = clustered_conv2d_packed(&x, &packed, &cb, stride);
                assert_eq!((want.h, want.w, want.c), (got.h, got.w, got.c));
                for (a, b) in want.data.iter().zip(&got.data) {
                    assert!(
                        (a - b).abs() < 1e-3,
                        "cin={cin} cout={cout} stride={stride}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn lut_roundtrips_and_pads_to_lanes() {
        let mut rng = Rng::new(13);
        for (cout, gn) in [(5usize, 7usize), (16, 16), (3, 1)] {
            let cb: Vec<f32> = (0..cout * gn).map(|_| rng.gauss_f32()).collect();
            let lut = CodebookLut::new(&cb, cout, gn);
            assert_eq!(lut.padded_row_len() % 4, 0);
            assert!(lut.padded_row_len() >= gn && lut.padded_row_len() < gn + 4);
            assert_eq!(lut.to_flat(), cb, "cout={cout} gn={gn}");
            for co in 0..cout {
                assert!(lut.row(co)[gn..].iter().all(|&v| v == 0.0), "pad must be zero");
            }
        }
    }

    #[test]
    fn lut_kernel_lanes_are_bit_identical() {
        use crate::util::simd::Lane;
        // odd geometry: cin % ch_sub != 0, odd cout (nibble tail), gn % 4 != 0
        let mut rng = Rng::new(14);
        let (cin, cout, k, ch_sub, n) = (6usize, 21usize, 3usize, 4usize, 5usize);
        let x = rand_tensor(9, 7, cin, &mut rng);
        let idx: Vec<u8> = (0..cout * k * k * cin).map(|_| rng.below(n) as u8).collect();
        let g = cin.div_ceil(ch_sub.min(cin));
        let cb: Vec<f32> = (0..cout * g * n).map(|_| rng.gauss_f32()).collect();
        let packed = PackedIdx::pack(&idx, cout, k, cin, ch_sub, n);
        let lut = CodebookLut::new(&cb, cout, g * n);
        for stride in [1, 2] {
            let chunked = clustered_conv2d_lut_in_lane(&x, &packed, &lut, stride, Lane::Chunked);
            let simd = clustered_conv2d_lut_in_lane(&x, &packed, &lut, stride, Lane::Simd);
            assert_eq!(chunked.data, simd.data, "stride={stride}: lanes diverged");
            // the compat wrapper runs the same kernel on the active lane
            let compat = clustered_conv2d_packed(&x, &packed, &cb, stride);
            assert_eq!(chunked.data, compat.data, "stride={stride}: wrapper diverged");
            // and both stay within f32 association of the reference kernel
            let want = clustered_conv2d(&x, &idx, &cb, cout, k, stride, ch_sub, n);
            for (a, b) in want.data.iter().zip(&chunked.data) {
                assert!((a - b).abs() < 1e-3, "stride={stride}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn global_avg_pool_means() {
        let x = Tensor3::from_vec(2, 2, 2, vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        assert_eq!(x.global_avg_pool(), vec![2.5, 25.0]);
    }

    #[test]
    fn subsample_matches_python_slicing() {
        let x = Tensor3::from_vec(4, 4, 1, (0..16).map(|v| v as f32).collect());
        let y = x.subsample(2);
        assert_eq!((y.h, y.w), (2, 2));
        assert_eq!(y.data, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn relu_and_add() {
        let x = Tensor3::from_vec(1, 1, 3, vec![-1.0, 0.5, 2.0]).relu();
        assert_eq!(x.data, vec![0.0, 0.5, 2.0]);
        let y = x.add(&Tensor3::from_vec(1, 1, 3, vec![1.0, 1.0, 1.0]));
        assert_eq!(y.data, vec![1.0, 1.5, 3.0]);
    }
}
