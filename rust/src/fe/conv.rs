//! Convolution substrate: dense NHWC conv with XLA-compatible SAME padding
//! and the weight-clustered two-phase convolution of Fig. 4(b).
//!
//! Padding matches `jax.lax.conv_general_dilated(..., padding="SAME")`
//! exactly (out = ceil(in/stride), asymmetric low/high pads) so the native
//! FE reproduces the artifact numerics.

/// A minimal HxWxC tensor (row-major, NHWC per image).
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor3 {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor3 {
    pub fn zeros(h: usize, w: usize, c: usize) -> Self {
        Tensor3 { h, w, c, data: vec![0.0; h * w * c] }
    }

    pub fn from_vec(h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), h * w * c);
        Tensor3 { h, w, c, data }
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }

    #[inline]
    pub fn at_mut(&mut self, y: usize, x: usize, ch: usize) -> &mut f32 {
        &mut self.data[(y * self.w + x) * self.c + ch]
    }

    pub fn relu(mut self) -> Self {
        for v in self.data.iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        self
    }

    /// Global average pool -> length-C feature.
    pub fn global_avg_pool(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.c];
        let hw = (self.h * self.w) as f32;
        for y in 0..self.h {
            for x in 0..self.w {
                let base = (y * self.w + x) * self.c;
                for ch in 0..self.c {
                    out[ch] += self.data[base + ch];
                }
            }
        }
        out.iter_mut().for_each(|v| *v /= hw);
        out
    }

    /// Strided spatial subsample (python's `h[:, ::s, ::s, :]`).
    pub fn subsample(&self, s: usize) -> Tensor3 {
        let ho = self.h.div_ceil(s);
        let wo = self.w.div_ceil(s);
        let mut out = Tensor3::zeros(ho, wo, self.c);
        for y in 0..ho {
            for x in 0..wo {
                for ch in 0..self.c {
                    *out.at_mut(y, x, ch) = self.at(y * s, x * s, ch);
                }
            }
        }
        out
    }

    /// Elementwise addition.
    pub fn add(mut self, other: &Tensor3) -> Tensor3 {
        assert_eq!((self.h, self.w, self.c), (other.h, other.w, other.c));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
        self
    }
}

/// XLA SAME padding: (out_size, pad_lo) for one spatial dim.
#[inline]
fn same_pad(input: usize, k: usize, stride: usize) -> (usize, isize) {
    let out = input.div_ceil(stride);
    let pad_total = ((out - 1) * stride + k).saturating_sub(input) as isize;
    (out, pad_total / 2)
}

/// Multi-accumulator dot product — breaks the serial FP dependency chain
/// so LLVM vectorizes the FE hot loop (EXPERIMENTS.md §Perf).
#[inline]
fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n8 = a.len() / 8 * 8;
    let mut acc = [0f32; 8];
    let (a8, b8) = (&a[..n8], &b[..n8]);
    for (ca, cb) in a8.chunks_exact(8).zip(b8.chunks_exact(8)) {
        for l in 0..8 {
            acc[l] += ca[l] * cb[l];
        }
    }
    let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
    for i in n8..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Dense conv: weights (Cout, K, K, Cin) flattened row-major.
pub fn conv2d(x: &Tensor3, w: &[f32], cout: usize, k: usize, stride: usize) -> Tensor3 {
    assert_eq!(w.len(), cout * k * k * x.c);
    let (ho, pad_y) = same_pad(x.h, k, stride);
    let (wo, pad_x) = same_pad(x.w, k, stride);
    let cin = x.c;
    let kkc = k * k * cin;
    let mut out = Tensor3::zeros(ho, wo, cout);
    for oy in 0..ho {
        for ox in 0..wo {
            let obase = (oy * wo + ox) * cout;
            for ky in 0..k {
                let iy = oy as isize * stride as isize + ky as isize - pad_y;
                if iy < 0 || iy >= x.h as isize {
                    continue;
                }
                // contiguous kx run that stays inside the image: fuse the
                // (kx, ci) loop into one long dot product per channel
                let ix0 = ox as isize * stride as isize - pad_x;
                let kx_lo = (-ix0).clamp(0, k as isize) as usize;
                let kx_hi = ((x.w as isize - ix0).clamp(0, k as isize)) as usize;
                if kx_lo >= kx_hi {
                    continue;
                }
                let run = kx_hi - kx_lo;
                let ibase = (iy as usize * x.w + (ix0 + kx_lo as isize) as usize) * cin;
                let xrow = &x.data[ibase..ibase + run * cin];
                let wbase = (ky * k + kx_lo) * cin;
                for co in 0..cout {
                    let wrow = &w[co * kkc + wbase..co * kkc + wbase + run * cin];
                    out.data[obase + co] += dot_f32(xrow, wrow);
                }
            }
        }
    }
    out
}

/// Weight-clustered conv (Fig. 4b): phase 1 bins activations by weight
/// index into per-(group, centroid) partial sums, phase 2 multiplies the
/// bins by the codebook. Numerically equals `conv2d` with reconstructed
/// weights (up to f32 association) — asserted by tests.
///
/// `idx`: (Cout, K*K*Cin) centroid indices; `codebook`: (Cout, G, N).
pub fn clustered_conv2d(
    x: &Tensor3,
    idx: &[u8],
    codebook: &[f32],
    cout: usize,
    k: usize,
    stride: usize,
    ch_sub: usize,
    n: usize,
) -> Tensor3 {
    let cin = x.c;
    let ch_sub = ch_sub.min(cin);
    let g = cin.div_ceil(ch_sub);
    assert_eq!(idx.len(), cout * k * k * cin);
    assert_eq!(codebook.len(), cout * g * n);
    let (ho, pad_y) = same_pad(x.h, k, stride);
    let (wo, pad_x) = same_pad(x.w, k, stride);
    let mut out = Tensor3::zeros(ho, wo, cout);
    let mut bins = vec![0f32; g * n];
    for oy in 0..ho {
        for ox in 0..wo {
            for co in 0..cout {
                bins.iter_mut().for_each(|b| *b = 0.0);
                // phase 1: accumulate activations into (group, index) bins
                for ky in 0..k {
                    let iy = oy as isize * stride as isize + ky as isize - pad_y;
                    if iy < 0 || iy >= x.h as isize {
                        continue;
                    }
                    for kx in 0..k {
                        let ix = ox as isize * stride as isize + kx as isize - pad_x;
                        if ix < 0 || ix >= x.w as isize {
                            continue;
                        }
                        let ibase = (iy as usize * x.w + ix as usize) * cin;
                        let kbase = co * k * k * cin + (ky * k + kx) * cin;
                        for ci in 0..cin {
                            let gidx = ci / ch_sub;
                            let nidx = idx[kbase + ci] as usize;
                            bins[gidx * n + nidx] += x.data[ibase + ci];
                        }
                    }
                }
                // phase 2: MAC with codebook centroids
                let cb = &codebook[co * g * n..(co + 1) * g * n];
                let mut acc = 0f32;
                for (b, c) in bins.iter().zip(cb) {
                    acc += b * c;
                }
                out.data[(oy * wo + ox) * cout + co] = acc;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_tensor(h: usize, w: usize, c: usize, rng: &mut Rng) -> Tensor3 {
        Tensor3::from_vec(h, w, c, (0..h * w * c).map(|_| rng.gauss_f32()).collect())
    }

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 conv with identity weights = channel copy
        let mut rng = Rng::new(1);
        let x = rand_tensor(4, 4, 3, &mut rng);
        let mut w = vec![0f32; 3 * 1 * 1 * 3];
        for c in 0..3 {
            w[c * 3 + c] = 1.0;
        }
        let y = conv2d(&x, &w, 3, 1, 1);
        assert_eq!(y.data, x.data);
    }

    #[test]
    fn same_padding_stride1_shape() {
        let mut rng = Rng::new(2);
        let x = rand_tensor(5, 7, 2, &mut rng);
        let w = vec![0.1f32; 4 * 3 * 3 * 2];
        let y = conv2d(&x, &w, 4, 3, 1);
        assert_eq!((y.h, y.w, y.c), (5, 7, 4));
    }

    #[test]
    fn same_padding_stride2_shape_and_xla_asymmetry() {
        // in=32, k=3, s=2 -> out=16, pad_total=1 -> pad_lo=0 (XLA rule)
        let mut rng = Rng::new(3);
        let x = rand_tensor(32, 32, 1, &mut rng);
        let w = vec![1.0f32; 1 * 3 * 3 * 1];
        let y = conv2d(&x, &w, 1, 3, 2);
        assert_eq!((y.h, y.w), (16, 16));
        // output (0,0) with pad_lo=0 sums x[0..3, 0..3]
        let mut want = 0.0;
        for yy in 0..3 {
            for xx in 0..3 {
                want += x.at(yy, xx, 0);
            }
        }
        assert!((y.at(0, 0, 0) - want).abs() < 1e-4);
    }

    #[test]
    fn clustered_matches_dense_reconstruction() {
        let mut rng = Rng::new(4);
        let (cin, cout, k, ch_sub, n) = (8, 5, 3, 4, 4);
        let x = rand_tensor(9, 9, cin, &mut rng);
        let g = cin / ch_sub;
        let idx: Vec<u8> = (0..cout * k * k * cin).map(|_| rng.below(n) as u8).collect();
        let cb: Vec<f32> = (0..cout * g * n).map(|_| rng.gauss_f32()).collect();
        // dense reconstruction
        let mut w = vec![0f32; cout * k * k * cin];
        for co in 0..cout {
            for kk in 0..k * k {
                for ci in 0..cin {
                    let flat = co * k * k * cin + kk * cin + ci;
                    let gi = ci / ch_sub;
                    w[flat] = cb[co * g * n + gi * n + idx[flat] as usize];
                }
            }
        }
        for stride in [1, 2] {
            let dense = conv2d(&x, &w, cout, k, stride);
            let clus = clustered_conv2d(&x, &idx, &cb, cout, k, stride, ch_sub, n);
            assert_eq!((dense.h, dense.w, dense.c), (clus.h, clus.w, clus.c));
            for (a, b) in dense.data.iter().zip(&clus.data) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn global_avg_pool_means() {
        let x = Tensor3::from_vec(2, 2, 2, vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        assert_eq!(x.global_avg_pool(), vec![2.5, 25.0]);
    }

    #[test]
    fn subsample_matches_python_slicing() {
        let x = Tensor3::from_vec(4, 4, 1, (0..16).map(|v| v as f32).collect());
        let y = x.subsample(2);
        assert_eq!((y.h, y.w), (2, 2));
        assert_eq!(y.data, vec![0.0, 2.0, 8.0, 10.0]);
    }

    #[test]
    fn relu_and_add() {
        let x = Tensor3::from_vec(1, 1, 3, vec![-1.0, 0.5, 2.0]).relu();
        assert_eq!(x.data, vec![0.0, 0.5, 2.0]);
        let y = x.add(&Tensor3::from_vec(1, 1, 3, vec![1.0, 1.0, 1.0]));
        assert_eq!(y.data, vec![1.0, 1.5, 3.0]);
    }
}
