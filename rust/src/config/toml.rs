//! TOML-subset parser: `[section]` headers and `key = value` pairs with
//! string / integer / float / boolean values and `#` comments. That covers
//! every config file this project ships; nested tables and arrays are
//! intentionally out of scope.

/// A scalar config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_int(&self) -> anyhow::Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            _ => anyhow::bail!("expected integer, got {self:?}"),
        }
    }

    pub fn as_float(&self) -> anyhow::Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            _ => anyhow::bail!("expected float, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> anyhow::Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => anyhow::bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> anyhow::Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => anyhow::bail!("expected bool, got {self:?}"),
        }
    }
}

/// A parsed document: ordered (section, key, value) triples.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    entries: Vec<(String, String, Value)>,
}

impl Doc {
    pub fn parse(src: &str) -> anyhow::Result<Doc> {
        let mut section = String::new();
        let mut entries = Vec::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: unclosed section", lineno + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = k.trim().to_string();
            if key.is_empty() {
                anyhow::bail!("line {}: empty key", lineno + 1);
            }
            entries.push((section.clone(), key, parse_value(v.trim(), lineno + 1)?));
        }
        Ok(Doc { entries })
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Doc> {
        Doc::parse(&std::fs::read_to_string(path)?)
    }

    pub fn entries(&self) -> impl Iterator<Item = (&str, &str, &Value)> {
        self.entries.iter().map(|(s, k, v)| (s.as_str(), k.as_str(), v))
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.entries
            .iter()
            .find(|(s, k, _)| s == section && k == key)
            .map(|(_, _, v)| v)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> anyhow::Result<Value> {
    if let Some(stripped) = s.strip_prefix('"') {
        let inner = stripped
            .strip_suffix('"')
            .ok_or_else(|| anyhow::anyhow!("line {lineno}: unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    anyhow::bail!("line {lineno}: cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            "top = 1\n# comment\n[a]\nx = 2.5\ny = \"hi # not comment\"\n[b]\nz = false # tail\n",
        )
        .unwrap();
        assert_eq!(doc.get("", "top"), Some(&Value::Int(1)));
        assert_eq!(doc.get("a", "x"), Some(&Value::Float(2.5)));
        assert_eq!(doc.get("a", "y"), Some(&Value::Str("hi # not comment".into())));
        assert_eq!(doc.get("b", "z"), Some(&Value::Bool(false)));
    }

    #[test]
    fn int_coerces_to_float() {
        assert_eq!(Value::Int(3).as_float().unwrap(), 3.0);
        assert!(Value::Str("x".into()).as_float().is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Doc::parse("[unclosed\n").is_err());
        assert!(Doc::parse("novalue\n").is_err());
        assert!(Doc::parse("k = @@@\n").is_err());
        assert!(Doc::parse(" = 3\n").is_err());
    }

    #[test]
    fn empty_ok() {
        let doc = Doc::parse("\n# only comments\n").unwrap();
        assert_eq!(doc.entries().count(), 0);
    }
}
